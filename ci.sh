#!/usr/bin/env bash
# CI for the lkgp repo.
#
#   tier-1 (hard gate):  cargo build --release && cargo test -q
#   api    (hard gate):  deny-warnings build (no in-crate deprecated-shim callers)
#   lint   (hard gate):  `lkgp lint` — the in-tree invariant analyzer
#                        (lock-order graph, poison policy, unsafe audit,
#                        panic/float discipline, stats/bench drift; see
#                        docs/static_analysis.md). Writes ANALYSIS.json at
#                        the repo root; any unjustified finding fails.
#   san    (detection-gated): nightly-only race check on
#                        tests/parallel_determinism.rs — cargo miri when
#                        installed, else ThreadSanitizer, else `skip`
#   style  (strict when available): cargo fmt --check, cargo clippy -- -D warnings
#   perf   (hard gates): cargo bench --bench hotpath -- --quick
#                        -> BENCH_hotpath.json (record) plus gated
#                           BENCH_pcg.json, BENCH_queries.json,
#                           BENCH_replicas.json, BENCH_ingest.json,
#                           BENCH_chaos.json (seeded fault-injection soak:
#                           zero lost requests, typed errors only, healthy
#                           shards bit-identical, recovery engaged)
#   par    (hard gate):  cargo bench --bench simd twice (LKGP_THREADS=1 / =4),
#                        cross-process PAR_CHECKSUM bitwise parity on the f64
#                        path + BENCH_simd.json asserts (in-process thread
#                        parity, >=1.5x batched-MVM speedup floor at 4 threads
#                        on >=4-core runners, f32 refinement parity)
#   samples (hard gate): cargo bench --bench samples twice (LKGP_THREADS=1 / =4),
#                        cross-process SAMPLES_CHECKSUM bitwise parity on the
#                        pathwise draws + BENCH_samples.json asserts (zero-solve
#                        warm sampling, marginal cost per extra sample within a
#                        small multiple of one MVM, >=5x throughput over the
#                        per-sample-solve baseline, writer/replica bitwise
#                        parity; docs/sampling.md)
#   scale  (hard gate):  cargo bench --bench scale -> BENCH_scale.json asserts
#                        (10k-task admission >= 2 tasks/s through hash-bucketed
#                        routing, steady-state observe+query throughput floor,
#                        resident engines bounded by the bucket count with idle
#                        eviction engaged, Observe zero MLL evals and >= 10x
#                        fewer MVM rows than a Refit; docs/serving.md)
#   docsgate (hard gate when the toolchain exists): cargo doc --no-deps with
#                        -D warnings — broken intra-doc links and malformed
#                        doc comments fail CI (docs/ci.md); skipped under
#                        CI_QUICK
#   smoke  (hard gates): trace replay through `lkgp pool --replay traces/smoke.jsonl`,
#                        sequentially (exact stats equalities) AND with
#                        --concurrent (storm + parity pass, relaxed bounds)
#
# Environment knobs:
#   CI_STRICT=0|1  Make fmt/clippy failures fatal. DEFAULTS TO 1 when both
#                  rustfmt and clippy are installed (detected up front); a
#                  minimal offline toolchain without the components falls
#                  back to soft reporting so a missing component never
#                  masks a real build/test regression. Set explicitly to
#                  override the detection either way.
#   CI_QUICK=0|1   Skip the bench/perf gates and the trace-replay smoke
#                  (everything below the style section) for fast local
#                  tier-1 iteration. The pipeline path runs with CI_QUICK
#                  unset, so the perf gates stay mandatory there.
#
# The script always ends by printing a machine-readable one-line summary
# with ALL of these gates present, in this order:
#   CI_SUMMARY build=pass test=pass shims=pass lint=pass san=skip \
#              fmt=pass clippy=pass docsgate=pass bench=pass pcg=pass \
#              queries=pass replicas=pass ingest=pass chaos=pass par=pass \
#              samples=pass scale=pass replay=pass creplay=pass
# Each gate is one of pass|fail|soft-fail|skip (skip = component missing,
# CI_QUICK, or never reached because an earlier gate failed; soft-fail =
# style finding under CI_STRICT=0). Exit code is non-zero iff any hard
# gate failed.
set -uo pipefail
cd "$(dirname "$0")"

MANIFEST=rust/Cargo.toml
SUMMARY=""
FAILED=0

note() { # note <gate> <pass|fail|soft-fail|skip>
  SUMMARY="$SUMMARY $1=$2"
  if [ "$2" = "fail" ]; then FAILED=1; fi
}
finish() {
  # gates never reached (early exit) report as skip, so the summary always
  # carries the full fixed field set parsers rely on
  for g in build test shims lint san fmt clippy docsgate bench pcg queries replicas ingest chaos par samples scale replay creplay; do
    case " $SUMMARY " in
      *" $g="*) ;;
      *) SUMMARY="$SUMMARY $g=skip" ;;
    esac
  done
  echo "CI_SUMMARY${SUMMARY}"
  if [ "$FAILED" -ne 0 ]; then
    echo "CI FAILED"
  fi
}
trap finish EXIT

# ---- component detection (drives the CI_STRICT default) -------------------
HAVE_FMT=0
HAVE_CLIPPY=0
cargo fmt --version >/dev/null 2>&1 && HAVE_FMT=1
cargo clippy --version >/dev/null 2>&1 && HAVE_CLIPPY=1
if [ -z "${CI_STRICT:-}" ]; then
  if [ "$HAVE_FMT" = "1" ] && [ "$HAVE_CLIPPY" = "1" ]; then
    CI_STRICT=1
  else
    CI_STRICT=0
  fi
fi
echo "components: rustfmt=$HAVE_FMT clippy=$HAVE_CLIPPY -> CI_STRICT=$CI_STRICT CI_QUICK=${CI_QUICK:-0}"

echo "== tier-1: build =="
if cargo build --release --manifest-path "$MANIFEST"; then
  note build pass
else
  note build fail
  exit 1
fi

echo "== tier-1: test =="
if cargo test -q --manifest-path "$MANIFEST"; then
  note test pass
else
  note test fail
  exit 1
fi

echo "== api gate: deny-warnings build (no in-crate deprecated-shim callers) =="
# The session-API redesign left the old free functions (`predict_final*`,
# `mll_value_grad*`, `posterior_samples`, `predict_mean`) as #[deprecated]
# shims. This pass fails if any lib/bin code still calls one (deprecation
# is a warning, -D warnings makes it fatal). Tests/benches that exercise
# the shims on purpose carry #![allow(deprecated)] and are not built here.
if RUSTFLAGS="${RUSTFLAGS:-} -D warnings" cargo build --manifest-path "$MANIFEST"; then
  note shims pass
  echo "deprecated-shim gate OK"
else
  note shims fail
  exit 1
fi

echo "== lint gate: in-tree invariant analyzer (lkgp lint) =="
# Lock-order cycles, poison-policy mismatches, undocumented unsafe, naked
# hot-path panics, float ==, dead stats counters, ungated bench artifacts
# (docs/static_analysis.md). Also refreshes ANALYSIS.json at the repo root.
# The same analysis runs as tests/lint.rs under the tier-1 test gate; this
# pass exercises the CLI entry point and publishes the inventory.
if cargo run --release --manifest-path "$MANIFEST" -- lint; then
  note lint pass
  echo "lint gate OK"
else
  note lint fail
  exit 1
fi

echo "== san gate: nightly race check (detection-gated) =="
# Runs tests/parallel_determinism.rs under cargo miri when a nightly
# toolchain with miri is installed, else under ThreadSanitizer when plain
# nightly is available; reports `skip` otherwise (the offline pinned
# toolchain has neither — a missing component must never mask a real
# build/test regression, same policy as the style gates).
SAN_RAN=0
if cargo +nightly miri --version >/dev/null 2>&1; then
  SAN_RAN=1
  if cargo +nightly miri test --manifest-path "$MANIFEST" --test parallel_determinism; then
    note san pass
    echo "san gate OK (miri)"
  else
    note san fail
    exit 1
  fi
elif cargo +nightly --version >/dev/null 2>&1 && rustc +nightly --version >/dev/null 2>&1; then
  SAN_RAN=1
  SAN_TARGET=$(rustc +nightly -vV | sed -n 's/^host: //p')
  if RUSTFLAGS="${RUSTFLAGS:-} -Zsanitizer=thread" \
      cargo +nightly test --manifest-path "$MANIFEST" \
      --test parallel_determinism --target "$SAN_TARGET"; then
    note san pass
    echo "san gate OK (tsan)"
  else
    note san fail
    exit 1
  fi
fi
if [ "$SAN_RAN" = "0" ]; then
  echo "no nightly toolchain; skipped"
  note san skip
fi

# ---- style gates (strict by default when the components exist) ------------
style_status=0

echo "== style: cargo fmt --check =="
if [ "$HAVE_FMT" = "1" ]; then
  if cargo fmt --manifest-path "$MANIFEST" -- --check; then
    note fmt pass
  elif [ "$CI_STRICT" = "1" ]; then
    echo "fmt check failed"
    note fmt fail
    style_status=1
  else
    echo "fmt check failed (CI_STRICT=0: reported, non-fatal)"
    note fmt soft-fail
  fi
else
  echo "rustfmt not installed; skipped"
  note fmt skip
fi

echo "== lint: cargo clippy -- -D warnings =="
if [ "$HAVE_CLIPPY" = "1" ]; then
  if cargo clippy --manifest-path "$MANIFEST" --all-targets -- -D warnings; then
    note clippy pass
  elif [ "$CI_STRICT" = "1" ]; then
    echo "clippy failed"
    note clippy fail
    style_status=1
  else
    echo "clippy failed (CI_STRICT=0: reported, non-fatal)"
    note clippy soft-fail
  fi
else
  echo "clippy not installed; skipped"
  note clippy skip
fi

if [ "$style_status" -ne 0 ]; then
  exit 1
fi

# ---- perf + smoke gates (mandatory in the pipeline; CI_QUICK skips) -------
if [ "${CI_QUICK:-0}" = "1" ]; then
  echo "== perf/smoke gates skipped (CI_QUICK=1) =="
  for gate in docsgate bench pcg queries replicas ingest chaos par samples scale replay creplay; do note "$gate" skip; done
  exit 0
fi

echo "== docs gate: cargo doc --no-deps (deny warnings) =="
# Broken intra-doc links ([`Foo`] to a renamed item) and malformed doc
# comments rot silently without this; the doc_drift lint rule covers the
# prose side (docs/*.md paths named in source must exist), this covers the
# rustdoc side. Skipped under CI_QUICK above.
if RUSTDOCFLAGS="${RUSTDOCFLAGS:-} -D warnings" cargo doc --no-deps --manifest-path "$MANIFEST"; then
  note docsgate pass
  echo "docs gate OK"
else
  note docsgate fail
  exit 1
fi

echo "== perf: hotpath bench (quick) =="
if cargo bench --manifest-path "$MANIFEST" --bench hotpath -- --quick; then
  note bench pass
else
  note bench fail
  exit 1
fi
if [ -f BENCH_hotpath.json ]; then
  echo "perf record:"
  cat BENCH_hotpath.json
fi

# gate_file <gate-name> <file> <assert...>: every listed assert must be
# literally `"<assert>": true` in the bench's JSON output.
gate_file() {
  local gate="$1" file="$2"
  shift 2
  if [ ! -f "$file" ]; then
    echo "FAIL: $file not produced by the hotpath bench"
    note "$gate" fail
    exit 1
  fi
  cat "$file"
  for a in "$@"; do
    if ! grep -q "\"$a\": true" "$file"; then
      echo "FAIL: $a is not true in $file"
      note "$gate" fail
      exit 1
    fi
  done
  note "$gate" pass
  echo "$gate gates OK"
}

echo "== perf gate: preconditioned CG =="
# PCG must never use more MVM rows than plain CG on the benchmark systems,
# warm+PCG must stay strictly below warm-only, and the ill-conditioned
# regime must show a >= 2x iteration cut.
gate_file pcg BENCH_pcg.json \
  assert_pcg_never_worse assert_warm_pcg_below assert_pcg_2x_ill

echo "== perf gate: multi-query amortization =="
# One session solve must serve MeanAtFinal + Variance + Quantiles +
# MeanAtSteps, and apply strictly fewer operator rows than the
# one-solve-per-statistic path.
gate_file queries BENCH_queries.json \
  assert_shared_single_solve assert_shared_fewer_rows

echo "== perf gate: read-only replica shards =="
# A single-task read burst behind a busy writer must finish >= 2x faster
# with replicas than serialized, add ZERO underlying solves (lineage fast
# path), and every replica answer must be bit-identical to the writer's
# for the same (generation, theta, query).
gate_file replicas BENCH_replicas.json \
  assert_replica_speedup assert_replica_no_extra_solves assert_replica_parity

echo "== perf gate: corpus ingestion =="
# Many-task cold admission through ServicePool::from_corpus must sustain
# the throughput floor with zero errors, shards must materialize lazily
# (and evict when idle), the real-shaped fixture corpus must ingest with
# its ragged rows intact, and sequential smoke replay must hold its
# request-rate floor.
gate_file ingest BENCH_ingest.json \
  assert_ingest_zero_errors assert_ingest_lazy \
  assert_ingest_admission_floor assert_ingest_replay_floor

echo "== perf gate: chaos soak =="
# Seeded fault injection (engine panics, forced CG divergence, slow
# solves, near-expired deadlines) over a mixed-shard pool: every request
# must resolve to an answer or a typed error within the bound (zero
# hangs, zero lost replies), no NaN may escape, the clean shard must stay
# bit-identical to a chaos-free pool, and the recovery machinery
# (catch-unwind + breaker, escalation ladder) must visibly engage
# (docs/robustness.md).
gate_file chaos BENCH_chaos.json \
  assert_chaos_no_lost_requests assert_chaos_typed_errors_only \
  assert_chaos_healthy_parity assert_chaos_recovered

echo "== perf gate: data-parallel compute core =="
# Runs the simd bench twice — pinned to LKGP_THREADS=1 and =4 — and
# compares the PAR_CHECKSUM lines bitwise: the cross-process half of the
# f64 determinism contract (docs/parallelism.md). The in-process halves
# (pinned-thread MVM/solve parity, the >=1.5x batched-MVM speedup floor
# at 4 threads, f32 iterative-refinement parity) are asserted inside
# BENCH_simd.json. On runners with < 4 cores the speedup is not
# measurable; the bench records speedup_measured=false and the assert
# passes vacuously (see docs/ci.md).
PAR_LOG1=$(mktemp)
PAR_LOG4=$(mktemp)
if LKGP_THREADS=1 cargo bench --manifest-path "$MANIFEST" --bench simd -- --quick \
    > "$PAR_LOG1" 2>&1 \
   && LKGP_THREADS=4 cargo bench --manifest-path "$MANIFEST" --bench simd -- --quick \
    > "$PAR_LOG4" 2>&1; then
  cat "$PAR_LOG4"
  CK1=$(grep '^PAR_CHECKSUM ' "$PAR_LOG1" | tail -n 1)
  CK4=$(grep '^PAR_CHECKSUM ' "$PAR_LOG4" | tail -n 1)
  rm -f "$PAR_LOG1" "$PAR_LOG4"
  if [ -z "$CK1" ] || [ "$CK1" != "$CK4" ]; then
    echo "FAIL: PAR_CHECKSUM differs across LKGP_THREADS=1/4 ('$CK1' vs '$CK4')"
    note par fail
    exit 1
  fi
  echo "cross-process checksum parity OK ($CK1)"
  gate_file par BENCH_simd.json \
    assert_par_parity_mvm assert_par_parity_solve \
    assert_simd_speedup assert_f32_refine_parity
else
  cat "$PAR_LOG1" "$PAR_LOG4"
  rm -f "$PAR_LOG1" "$PAR_LOG4"
  echo "FAIL: simd bench run failed"
  note par fail
  exit 1
fi

echo "== perf gate: pathwise posterior sampling =="
# Runs the samples bench twice — pinned to LKGP_THREADS=1 and =4 — and
# compares the SAMPLES_CHECKSUM lines bitwise: for a fixed seed the
# pathwise draws must be identical across worker-team widths, cross
# process (docs/sampling.md). The in-process halves (zero CG solves on a
# warm lineage, marginal per-sample cost within a small multiple of one
# MVM, the >=5x throughput floor over the per-sample-solve baseline,
# writer/replica bitwise parity) are asserted inside BENCH_samples.json.
SAMP_LOG1=$(mktemp)
SAMP_LOG4=$(mktemp)
if LKGP_THREADS=1 cargo bench --manifest-path "$MANIFEST" --bench samples -- --quick \
    > "$SAMP_LOG1" 2>&1 \
   && LKGP_THREADS=4 cargo bench --manifest-path "$MANIFEST" --bench samples -- --quick \
    > "$SAMP_LOG4" 2>&1; then
  cat "$SAMP_LOG4"
  SCK1=$(grep '^SAMPLES_CHECKSUM ' "$SAMP_LOG1" | tail -n 1)
  SCK4=$(grep '^SAMPLES_CHECKSUM ' "$SAMP_LOG4" | tail -n 1)
  rm -f "$SAMP_LOG1" "$SAMP_LOG4"
  if [ -z "$SCK1" ] || [ "$SCK1" != "$SCK4" ]; then
    echo "FAIL: SAMPLES_CHECKSUM differs across LKGP_THREADS=1/4 ('$SCK1' vs '$SCK4')"
    note samples fail
    exit 1
  fi
  echo "cross-process sample checksum parity OK ($SCK1)"
  gate_file samples BENCH_samples.json \
    assert_samples_zero_solve_warm assert_samples_marginal_mvm \
    assert_samples_speedup assert_samples_replica_parity
else
  cat "$SAMP_LOG1" "$SAMP_LOG4"
  rm -f "$SAMP_LOG1" "$SAMP_LOG4"
  echo "FAIL: samples bench run failed"
  note samples fail
  exit 1
fi

echo "== perf gate: online-ingestion scale =="
# 10k simulated tasks folded onto hash-routed shard buckets, with a live
# epoch-arrival hot set streaming Observe + query traffic: admission must
# clear 2 tasks/s, the steady state must sustain the ops/s floor, the
# resident engine set must stay bounded by the bucket count (idle eviction
# frees quiet shards between hot-set waves), and an Observe must perform
# zero MLL evaluations while costing >= 10x fewer operator MVM rows than
# an equivalent Refit (docs/serving.md).
if cargo bench --manifest-path "$MANIFEST" --bench scale; then
  gate_file scale BENCH_scale.json \
    assert_scale_admission assert_scale_throughput \
    assert_scale_resident_bounded assert_scale_observe_zero_fit \
    assert_scale_observe_cheap
else
  echo "FAIL: scale bench run failed"
  note scale fail
  exit 1
fi

echo "== smoke gate: trace replay =="
# Replays traces/smoke.jsonl (typed queries, 3 tasks, mixed generations)
# through `lkgp pool --replay` sequentially; the replayer itself asserts
# zero errors plus exact stats invariants (warm_cache_hits + misses ==
# requests, engine_solves == requests, misses == distinct generations)
# and exits non-zero on any violation.
REPLAY_LOG=$(mktemp)
if cargo run --release --manifest-path "$MANIFEST" -- pool --replay traces/smoke.jsonl \
    > "$REPLAY_LOG" 2>&1 && grep -q "^REPLAY_OK$" "$REPLAY_LOG"; then
  cat "$REPLAY_LOG"
  note replay pass
  echo "replay gate OK"
else
  cat "$REPLAY_LOG"
  echo "FAIL: trace replay reported errors or invariant violations"
  note replay fail
  rm -f "$REPLAY_LOG"
  exit 1
fi
rm -f "$REPLAY_LOG"

echo "== smoke gate: concurrent trace replay =="
# The same trace replayed as a storm (every request in flight at once,
# replicas stealing reads) with relaxed invariants: zero errors, solve
# counts bounded by submissions, and a post-storm parity pass — each
# distinct (task, generation, signature) submitted twice back-to-back
# must answer bit-identically (docs/ci.md).
CREPLAY_LOG=$(mktemp)
if cargo run --release --manifest-path "$MANIFEST" -- pool --replay traces/smoke.jsonl \
    --concurrent > "$CREPLAY_LOG" 2>&1 && grep -q "^REPLAY_OK$" "$CREPLAY_LOG"; then
  cat "$CREPLAY_LOG"
  note creplay pass
  echo "concurrent replay gate OK"
else
  cat "$CREPLAY_LOG"
  echo "FAIL: concurrent trace replay reported errors or invariant violations"
  note creplay fail
  rm -f "$CREPLAY_LOG"
  exit 1
fi
rm -f "$CREPLAY_LOG"

echo "CI OK"
