#!/usr/bin/env bash
# CI for the lkgp repo.
#
#   tier-1 (hard gate):  cargo build --release && cargo test -q
#   style  (soft gate):  cargo fmt --check, cargo clippy -- -D warnings
#   perf   (record):     cargo bench --bench hotpath -- --quick
#                        -> BENCH_hotpath.json at the repo root
#
# Style/lint failures are reported but non-fatal unless CI_STRICT=1, so a
# missing rustfmt/clippy component (minimal offline toolchains) or a
# legacy-formatting file never masks a real build/test regression.
set -euo pipefail
cd "$(dirname "$0")"

MANIFEST=rust/Cargo.toml

echo "== tier-1: build =="
cargo build --release --manifest-path "$MANIFEST"

echo "== tier-1: test =="
cargo test -q --manifest-path "$MANIFEST"

echo "== api gate: deny-warnings build (no in-crate deprecated-shim callers) =="
# The session-API redesign left the old free functions (`predict_final*`,
# `mll_value_grad*`, `posterior_samples`, `predict_mean`) as #[deprecated]
# shims. This pass fails if any lib/bin code still calls one (deprecation
# is a warning, -D warnings makes it fatal). Tests/benches that exercise
# the shims on purpose carry #![allow(deprecated)] and are not built here.
RUSTFLAGS="${RUSTFLAGS:-} -D warnings" cargo build --manifest-path "$MANIFEST"
echo "deprecated-shim gate OK"

soft_status=0

echo "== style: cargo fmt --check =="
if cargo fmt --version >/dev/null 2>&1; then
  if ! cargo fmt --manifest-path "$MANIFEST" -- --check; then
    echo "WARN: cargo fmt --check failed"
    soft_status=1
  fi
else
  echo "rustfmt not installed; skipped"
fi

echo "== lint: cargo clippy -- -D warnings =="
if cargo clippy --version >/dev/null 2>&1; then
  if ! cargo clippy --manifest-path "$MANIFEST" --all-targets -- -D warnings; then
    echo "WARN: clippy failed"
    soft_status=1
  fi
else
  echo "clippy not installed; skipped"
fi

echo "== perf: hotpath bench (quick) =="
cargo bench --manifest-path "$MANIFEST" --bench hotpath -- --quick
if [ -f BENCH_hotpath.json ]; then
  echo "perf record:"
  cat BENCH_hotpath.json
fi

echo "== perf gate: preconditioned CG =="
# The hotpath bench dumps BENCH_pcg.json with acceptance booleans:
# PCG must never use more MVM rows than plain CG on the benchmark
# systems, warm+PCG must stay strictly below warm-only, and the
# ill-conditioned regime must show a >= 2x iteration cut.
if [ ! -f BENCH_pcg.json ]; then
  echo "FAIL: BENCH_pcg.json not produced by the hotpath bench"
  exit 1
fi
cat BENCH_pcg.json
for gate in assert_pcg_never_worse assert_warm_pcg_below assert_pcg_2x_ill; do
  if ! grep -q "\"$gate\": true" BENCH_pcg.json; then
    echo "FAIL: $gate is not true in BENCH_pcg.json"
    exit 1
  fi
done
echo "pcg gates OK"

echo "== perf gate: multi-query amortization =="
# The hotpath bench dumps BENCH_queries.json: one session solve must serve
# MeanAtFinal + Variance + Quantiles + MeanAtSteps, and apply strictly
# fewer operator rows than the one-solve-per-statistic path.
if [ ! -f BENCH_queries.json ]; then
  echo "FAIL: BENCH_queries.json not produced by the hotpath bench"
  exit 1
fi
cat BENCH_queries.json
for gate in assert_shared_single_solve assert_shared_fewer_rows; do
  if ! grep -q "\"$gate\": true" BENCH_queries.json; then
    echo "FAIL: $gate is not true in BENCH_queries.json"
    exit 1
  fi
done
echo "query gates OK"

if [ "$soft_status" -ne 0 ]; then
  echo "style/lint warnings present (set CI_STRICT=1 to make them fatal)"
  if [ "${CI_STRICT:-0}" = "1" ]; then
    exit "$soft_status"
  fi
fi
echo "CI OK"
