//! Figure 3 driver (small interactive version of the fig3_scaling bench):
//! time + memory of LKGP (iterative) vs naive Cholesky as n = m grows.
//!
//! ```bash
//! cargo run --release --example scaling [-- --max-size 64 --naive-max 32]
//! ```
//!
//! The criterion-style sweep with CSV output lives in
//! `rust/benches/fig3_scaling.rs` (`make fig3`); this example prints a
//! quick table so the crossover is visible in seconds.

use lkgp::gp::lkgp::SolverCfg;
use lkgp::gp::{naive, Theta};
use lkgp::lcbench::fig3_dataset;
use lkgp::linalg::Matrix;
use lkgp::metrics::alloc::AllocTracker;
use lkgp::rng::Pcg64;
use lkgp::util::{fmt_bytes, Args};

fn main() -> lkgp::Result<()> {
    let args = Args::from_env();
    let max_size = args.get_usize("max-size", 64);
    let naive_max = args.get_usize("naive-max", 32);
    let steps = args.get_usize("train-steps", 3);

    println!("size | engine | train (s) | predict (s) | peak alloc");
    println!("-----+--------+-----------+-------------+-----------");
    let mut size = 16;
    while size <= max_size {
        let mut rng = Pcg64::new(size as u64);
        let data = fig3_dataset(size, &mut rng);
        let theta0 = Theta::default_packed(10);
        let xq = Matrix::from_vec(16, 10, rng.uniform_vec(160, 0.0, 1.0));

        // --- LKGP (iterative, session API) ---
        let cfg = SolverCfg::default();
        let tracker = AllocTracker::start();
        let t0 = std::time::Instant::now();
        let probes = Pcg64::new(1).rademacher_vec(cfg.probes * size * size);
        let mut session = lkgp::gp::FitSession::with_probes(
            std::sync::Arc::new(data.clone()),
            cfg.clone(),
            probes,
        )?;
        let trace = session.fit(
            &theta0,
            &lkgp::gp::FitMethod::Adam(lkgp::gp::trainer::AdamCfg {
                steps,
                ..Default::default()
            }),
        )?;
        let theta = trace.theta;
        let train_t = t0.elapsed();
        let t1 = std::time::Instant::now();
        // the posterior inherits the fit's preconditioner lineage
        let mut post = session.posterior(theta.clone());
        let mut prng = Pcg64::new(2);
        let _samples = post.sample_curves_with(&xq, 4, &mut prng)?;
        let pred_t = t1.elapsed();
        println!(
            "{size:>4} | lkgp   | {:>9.3} | {:>11.3} | {}",
            train_t.as_secs_f64(),
            pred_t.as_secs_f64(),
            fmt_bytes(tracker.peak_noted())
        );

        // --- naive Cholesky ---
        if size <= naive_max {
            let tracker = AllocTracker::start();
            let t0 = std::time::Instant::now();
            let mut obj_n =
                |p: &[f64]| naive::mll_value_grad_exact(p, &data);
            let trace = lkgp::gp::trainer::adam(
                &mut obj_n,
                &theta0,
                &lkgp::gp::trainer::AdamCfg { steps, ..Default::default() },
            )?;
            let train_t = t0.elapsed();
            let t1 = std::time::Instant::now();
            let mut prng = Pcg64::new(2);
            let _s = naive::sample_curves_exact(&trace.theta, &data, &xq, 4, &mut prng)?;
            let pred_t = t1.elapsed();
            println!(
                "{size:>4} | naive  | {:>9.3} | {:>11.3} | {}",
                train_t.as_secs_f64(),
                pred_t.as_secs_f64(),
                fmt_bytes(tracker.peak_noted())
            );
        } else {
            println!("{size:>4} | naive  | (skipped: O(n^3 m^3) wall, see --naive-max)");
        }
        size *= 2;
    }
    Ok(())
}
