//! Quickstart: fit a Latent Kronecker GP on partially observed learning
//! curves and predict final values + sampled continuations.
//!
//! ```bash
//! cargo run --release --example quickstart [-- --engine rust|xla --seed 0]
//! ```
//!
//! Uses the AOT XLA artifacts when built (`make artifacts`), otherwise the
//! pure-rust engine — the numbers agree either way (see
//! rust/tests/engine_parity.rs).

use std::sync::Arc;

use lkgp::gp::{Answer, Query, Theta};
use lkgp::lcbench::{build_problem, PartialView, Preset, Task};
use lkgp::rng::Pcg64;
use lkgp::util::Args;

fn main() -> lkgp::Result<()> {
    let args = Args::from_env();
    let seed = args.get_u64("seed", 0);
    let prefer_xla = args.get("engine").unwrap_or("xla") == "xla";

    // 1. A learning-curve workload: 24 configs of a simulated LCBench task,
    //    each trained for a random number of epochs (early stopping).
    let mut rng = Pcg64::new(seed);
    let task = Task::generate(Preset::FashionMnist, 24, &mut rng);
    let view = PartialView::sample(&task, 16, 300, &mut rng);
    let problem = build_problem(&task, &view);
    println!(
        "task {}: {} curves, {} observed values, grid of {} epochs",
        task.name,
        problem.data.n(),
        view.observed(),
        problem.data.m()
    );

    // 2. Fit the 10-parameter LKGP by MAP (Adam on MLL + priors).
    let mut engine = lkgp::runtime::open_engine(prefer_xla);
    println!("engine: {}", engine.name());
    let theta0 = Theta::default_packed(problem.data.d());
    let theta = engine.fit(&theta0, &problem.data, seed)?;
    let unpacked = Theta::unpack(&theta);
    println!(
        "fitted: t-lengthscale={:.3} outputscale={:.3} noise={:.2e}",
        unpacked.t_lengthscale, unpacked.outputscale, unpacked.sigma2
    );

    // 3. Predict each curve's final validation accuracy PLUS an 80%
    //    predictive band — one typed-query batch, one underlying solve
    //    (the session API; see docs/api.md).
    let data = Arc::new(problem.data.clone());
    let outcome = engine.answer_batch(
        &theta,
        &data,
        &[
            Query::MeanAtFinal { xq: problem.xq.clone() },
            Query::Quantiles { xq: problem.xq.clone(), ps: vec![0.1, 0.9] },
        ],
        None,
        None,
    )?;
    let (preds, bands) = match (&outcome.answers[0], &outcome.answers[1]) {
        (Answer::Final(f), Answer::Quantiles(q)) => (f, q),
        _ => unreachable!("queries answer Final + Quantiles"),
    };
    println!("\n  curve  observed  predicted final      80% band         truth");
    let mut se = 0.0;
    for (i, (mu, var)) in preds.iter().enumerate() {
        let mean = problem.ytf.undo_mean(*mu);
        let sd = problem.ytf.undo_var(*var).sqrt();
        let lo = problem.ytf.undo_mean(bands[(i, 0)]);
        let hi = problem.ytf.undo_mean(bands[(i, 1)]);
        let truth = problem.targets[i];
        se += (mean - truth) * (mean - truth);
        println!(
            "  {i:>5}  {:>8}  {mean:.4} +- {sd:.4}  [{lo:.4}, {hi:.4}]   {truth:.4}",
            view.lengths[i]
        );
    }
    println!("\nMSE = {:.6}", se / preds.len() as f64);

    // 4. Sample full posterior curves for the first config (Matheron).
    let xq1 = {
        let mut m = lkgp::linalg::Matrix::zeros(1, problem.data.d());
        m.row_mut(0).copy_from_slice(problem.xq.row(0));
        m
    };
    let samples = engine.sample_curves(&theta, &problem.data, &xq1, 5, seed + 1)?;
    let n = problem.data.n();
    println!("\n5 sampled continuations of curve 0 (last 6 epochs, original units):");
    for (si, s) in samples.iter().enumerate() {
        let tail: Vec<String> = (problem.data.m() - 6..problem.data.m())
            .map(|j| format!("{:.3}", problem.ytf.undo_mean(s[(n, j)])))
            .collect();
        println!("  sample {si}: {}", tail.join(" "));
    }
    Ok(())
}
