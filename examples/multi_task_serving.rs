//! Multi-task serving: one freeze-thaw AutoML coordinator per corpus
//! task, running concurrently against a single sharded [`ServicePool`]
//! admitted from a [`Corpus`] (the data plane, docs/data.md).
//!
//! Each scheduler drives its own shard through a `ShardHandle`; the pool
//! routes by task id, coalesces same-generation prediction batches per
//! shard, applies backpressure, warm-starts every solve from the shard's
//! cached previous-generation solution, and pre-warms freshly refitted
//! generations (see docs/serving.md). Shards materialize lazily on first
//! request (`ServicePool::from_corpus`).
//!
//! Prints a per-shard report (regret, batching factor, warm hits, CG
//! iterations, latency) and writes `results/multi_task_serving.json`.
//!
//! ```bash
//! cargo run --release --example multi_task_serving \
//!     [-- --corpus sim|data/lcbench_mini --configs 16 --budget 200 --workers 3 --precond auto]
//! ```

use std::sync::Arc;

use lkgp::coordinator::{
    CorpusRunner, EngineFactory, PoolCfg, RunReport, Scheduler, SchedulerCfg, ServicePool,
};
use lkgp::gp::PrecondCfg;
use lkgp::json::Json;
use lkgp::lcbench::corpus::{Corpus, JsonDirCorpus, SimCorpus};
use lkgp::runtime::{Engine, RustEngine};
use lkgp::util::Args;

fn main() -> lkgp::Result<()> {
    let args = Args::from_env();
    let seed = args.get_u64("seed", 0);
    let n_configs = args.get_usize("configs", 16);
    let budget = args.get_usize("budget", 200);
    let warm = args.get("warm").unwrap_or("on") != "off";
    let replicas = args.get_usize("replicas", PoolCfg::default().max_replicas);
    let precond_arg = args.get("precond").unwrap_or("auto");
    let precond = PrecondCfg::parse(precond_arg).ok_or_else(|| {
        lkgp::LkgpError::Coordinator(format!(
            "bad --precond '{precond_arg}' (expected off|auto|rank=R)"
        ))
    })?;

    // The data plane: the three-preset simulator by default, or any
    // directory of LCBench-style JSON dumps.
    let corpus_arg = args.get("corpus").unwrap_or("sim");
    let corpus: Arc<dyn Corpus> = if corpus_arg == "sim" {
        Arc::new(SimCorpus::new(3, n_configs, seed))
    } else {
        Arc::new(JsonDirCorpus::open(corpus_arg)?)
    };
    let tasks = corpus.len();
    let workers = args.get_usize("workers", tasks);

    let factory: EngineFactory = Box::new(move |_| {
        let mut eng = RustEngine::default();
        eng.cfg.precond = precond;
        Box::new(eng) as Box<dyn Engine>
    });
    let pool = ServicePool::from_corpus(
        &*corpus,
        factory,
        PoolCfg {
            workers,
            warm_start: warm,
            max_replicas: replicas,
            ..Default::default()
        },
    );
    println!(
        "pool: {tasks} shards from corpus {} ({}), {workers} workers, warm_start={warm}, \
         max_replicas={replicas}, precond={precond:?}\n",
        corpus.name(),
        corpus.fingerprint(),
    );

    let t0 = std::time::Instant::now();
    let mut results: Vec<(usize, String, RunReport, f64)> = Vec::new();
    std::thread::scope(|scope| -> lkgp::Result<()> {
        let mut joins = Vec::new();
        for t in 0..tasks {
            let task = match corpus.task(t) {
                Ok(task) => task,
                Err(e) => {
                    eprintln!("shard {t}: skipped (corrupt task isolated): {e}");
                    continue;
                }
            };
            let handle = pool.handle(t);
            joins.push(scope.spawn(
                move || -> lkgp::Result<(usize, String, RunReport, f64)> {
                    let oracle = (0..task.n())
                        .map(|i| task.curves[(i, task.lengths[i].max(1) - 1)])
                        .fold(f64::NEG_INFINITY, f64::max);
                    let cfg = SchedulerCfg {
                        epoch_budget: budget,
                        seed: seed + t as u64,
                        ..Default::default()
                    };
                    let mut sched = Scheduler::new(task.m(), cfg);
                    let configs: Vec<Vec<f64>> =
                        (0..task.n()).map(|i| task.configs.row(i).to_vec()).collect();
                    sched.add_candidates(&configs);
                    let name = task.name.clone();
                    let mut runner = CorpusRunner { task };
                    let report = sched.run(&mut runner, &handle)?;
                    Ok((t, name, report, oracle))
                },
            ));
        }
        for j in joins {
            let out = j
                .join()
                .map_err(|_| lkgp::LkgpError::Coordinator("shard panicked".into()))??;
            results.push(out);
        }
        Ok(())
    })?;
    let wall = t0.elapsed();

    // Dashboard traffic through the typed-query surface: variance bands,
    // quantiles and step-wise extrapolation ride the exact same
    // coalescing/backpressure/warm machinery as the schedulers' MeanAtFinal
    // queries — one underlying solve serves the whole batch per generation.
    // dashboard demo: first loadable task (per-task error isolation — a
    // corrupt leading dump must not abort the report below)
    if let Some((shard, task)) = (0..tasks).find_map(|t| corpus.task(t).ok().map(|k| (t, k))) {
        use lkgp::coordinator::{Answer, CurveStore, PredictClient, Query, Registry};
        let mut reg = Registry::new();
        for i in 0..task.n() {
            let id = reg.add(task.configs.row(i).to_vec());
            for j in 0..task.lengths[i].min(4) {
                reg.observe(id, task.curves[(i, j)], task.m()).unwrap();
            }
        }
        let snap = CurveStore::new(task.m()).snapshot(&reg).unwrap();
        let theta = lkgp::gp::Theta::default_packed(snap.data.d());
        let xq = lkgp::linalg::Matrix::from_vec(1, snap.data.d(), snap.all_x.row(0).to_vec());
        let m = snap.data.m();
        let answers = pool.handle(shard).query(
            snap,
            theta,
            vec![
                Query::MeanAtFinal { xq: xq.clone() },
                Query::Variance { xq: xq.clone() },
                Query::Quantiles { xq: xq.clone(), ps: vec![0.1, 0.9] },
                Query::MeanAtSteps { xq, steps: vec![m / 2, m - 1] },
            ],
        )?;
        if let (Answer::Final(f), Answer::Quantiles(q), Answer::Steps(s)) =
            (&answers[0], &answers[2], &answers[3])
        {
            println!(
                "dashboard (shard {shard}, config 0): final={:.4}±{:.4} band=[{:.4},{:.4}] \
                 mid-curve={:.4} (standardized units, 1 solve for 4 queries)\n",
                f[0].0,
                f[0].1.sqrt(),
                q[(0, 0)],
                q[(0, 1)],
                s[(0, 0)],
            );
        }
    }

    results.sort_by_key(|r| r.0);
    let mut shard_json = Vec::new();
    for (t, name, report, oracle) in &results {
        let stats = pool.stats(*t);
        let warm_hits = stats.warm_hits.load(std::sync::atomic::Ordering::Relaxed);
        let cg_iters = stats.cg_iters.load(std::sync::atomic::Ordering::Relaxed);
        let mvm_rows = stats.cg_mvm_rows.load(std::sync::atomic::Ordering::Relaxed);
        let replica_hits = stats.replica_hits.load(std::sync::atomic::Ordering::Relaxed);
        let replica_solves = stats.replica_solves.load(std::sync::atomic::Ordering::Relaxed);
        let prewarmed = stats.prewarmed.load(std::sync::atomic::Ordering::Relaxed);
        let precond_rank = stats.precond_rank.load(std::sync::atomic::Ordering::Relaxed);
        let escalations = stats.escalations.load(std::sync::atomic::Ordering::Relaxed);
        let panics_recovered = stats.panics_recovered.load(std::sync::atomic::Ordering::Relaxed);
        let p50 = stats.latency.lock().unwrap_or_else(|p| p.into_inner()).quantile_micros(0.5);
        let p99 = stats.latency.lock().unwrap_or_else(|p| p.into_inner()).quantile_micros(0.99);
        println!(
            "shard {t} ({name}): best={:.4} regret={:.4} epochs={} \
             batch_factor={:.2} warm_hits={warm_hits} replicas={replica_hits}h/{replica_solves}s \
             prewarmed={prewarmed} precond_rank={precond_rank} \
             cg_iters={cg_iters} mvm_rows={mvm_rows} escalations={escalations} \
             panics_recovered={panics_recovered} p50={p50}us p99={p99}us",
            report.best_value,
            oracle - report.best_value,
            report.epochs_spent,
            report.batch_factor,
        );
        shard_json.push(Json::obj(vec![
            ("shard", Json::Num(*t as f64)),
            ("task", Json::Str(name.to_string())),
            ("best", Json::Num(report.best_value)),
            ("regret", Json::Num(oracle - report.best_value)),
            ("epochs", Json::Num(report.epochs_spent as f64)),
            ("batch_factor", Json::Num(report.batch_factor)),
            ("warm_hits", Json::Num(warm_hits as f64)),
            ("replica_hits", Json::Num(replica_hits as f64)),
            ("replica_solves", Json::Num(replica_solves as f64)),
            ("prewarmed", Json::Num(prewarmed as f64)),
            ("precond_rank", Json::Num(precond_rank as f64)),
            ("cg_iters", Json::Num(cg_iters as f64)),
            ("cg_mvm_rows", Json::Num(mvm_rows as f64)),
            ("escalations", Json::Num(escalations as f64)),
            ("panics_recovered", Json::Num(panics_recovered as f64)),
            ("p50_us", Json::Num(p50 as f64)),
            ("p99_us", Json::Num(p99 as f64)),
        ]));
    }
    println!(
        "\nwall time: {wall:.2?} (admission: {} materialized / {} shards, {} evicted)",
        pool.materialized(),
        tasks,
        pool.evicted(),
    );

    let summary = Json::obj(vec![
        ("tasks", Json::Num(tasks as f64)),
        ("corpus", Json::Str(corpus.name())),
        ("fingerprint", Json::Str(corpus.fingerprint())),
        ("workers", Json::Num(workers as f64)),
        ("warm_start", Json::Bool(warm)),
        ("max_replicas", Json::Num(replicas as f64)),
        ("materialized", Json::Num(pool.materialized() as f64)),
        ("precond", Json::Str(format!("{precond:?}"))),
        ("wall_seconds", Json::Num(wall.as_secs_f64())),
        ("shards", Json::Arr(shard_json)),
    ]);
    std::fs::create_dir_all("results")?;
    std::fs::write("results/multi_task_serving.json", summary.pretty())?;
    println!("wrote results/multi_task_serving.json");
    Ok(())
}
