//! Figure 1 reproduction: posterior samples extrapolating partially
//! observed learning curves.
//!
//! Fits the LKGP to 16 partially observed curves of the simulated
//! Fashion-MNIST LCBench task, then draws posterior samples of the full
//! curves. Writes `results/fig1_curves.csv` with columns
//! (curve, epoch, kind, value) where kind in {observed, truth, sample<k>,
//! mean}, prints an ASCII rendition of three representative panels
//! (confident / uncertain / spiky, like the paper's figure), and checks
//! the coverage claim: ground-truth continuations fall inside the spread
//! of posterior samples.
//!
//! ```bash
//! cargo run --release --example lc_extrapolation [-- --seed 0 --samples 64]
//! ```

use lkgp::gp::Theta;
use lkgp::lcbench::{build_problem, PartialView, Preset, Task};
use lkgp::rng::Pcg64;
use lkgp::util::Args;

fn main() -> lkgp::Result<()> {
    let args = Args::from_env();
    let seed = args.get_u64("seed", 0);
    let n_samples = args.get_usize("samples", 64);
    let prefer_xla = args.get("engine").unwrap_or("xla") == "xla";

    // 16 partially observed curves (the paper fits 16; Figure 1 shows 3).
    let mut rng = Pcg64::new(seed);
    let task = Task::generate(Preset::FashionMnist, 64, &mut rng);
    let mut view = PartialView::sample(&task, 16, 320, &mut rng);
    // make the panels interesting: one long, one short prefix
    view.lengths[0] = 40; // observed close to convergence -> confident
    view.lengths[1] = 8; // short prefix -> uncertain
    let problem = build_problem(&task, &view);
    let m = problem.data.m();
    let n = problem.data.n();

    let mut engine = lkgp::runtime::open_engine(prefer_xla);
    println!("engine: {}", engine.name());
    let theta0 = Theta::default_packed(problem.data.d());
    let theta = engine.fit(&theta0, &problem.data, seed)?;

    // Posterior samples over the TRAINING configs' full curves: query the
    // same configs (their rows also appear in the train block; we read the
    // query block to get clean continuations).
    let samples = engine.sample_curves(&theta, &problem.data, &problem.xq, n_samples, seed + 1)?;

    // ---- CSV dump ----
    let mut rows: Vec<Vec<String>> = Vec::new();
    for (ci, (&task_idx, &len)) in view.config_idx.iter().zip(&view.lengths).enumerate() {
        for j in 0..m {
            let truth = task.curves[(task_idx, j)];
            let kind = if j < len { "observed" } else { "truth" };
            rows.push(vec![
                ci.to_string(),
                (j + 1).to_string(),
                kind.to_string(),
                format!("{truth:.6}"),
            ]);
        }
        for (si, s) in samples.iter().enumerate() {
            for j in 0..m {
                rows.push(vec![
                    ci.to_string(),
                    (j + 1).to_string(),
                    format!("sample{si}"),
                    format!("{:.6}", problem.ytf.undo_mean(s[(n + ci, j)])),
                ]);
            }
        }
    }
    lkgp::util::write_csv(
        "results/fig1_curves.csv",
        &["curve", "epoch", "kind", "value"],
        &rows,
    )?;
    println!("wrote results/fig1_curves.csv ({} rows)", rows.len());

    // ---- coverage check (the figure's visual claim, quantified) ----
    let mut covered = 0usize;
    let mut total = 0usize;
    for (ci, (&task_idx, &len)) in view.config_idx.iter().zip(&view.lengths).enumerate() {
        for j in len..m {
            let truth = task.curves[(task_idx, j)];
            let mut lo = f64::INFINITY;
            let mut hi = f64::NEG_INFINITY;
            for s in samples.iter() {
                let v = problem.ytf.undo_mean(s[(n + ci, j)]);
                lo = lo.min(v);
                hi = hi.max(v);
            }
            total += 1;
            if truth >= lo - 1e-9 && truth <= hi + 1e-9 {
                covered += 1;
            }
        }
    }
    let cov = covered as f64 / total.max(1) as f64;
    println!("ground-truth continuation coverage by sample spread: {:.1}%", cov * 100.0);

    // ---- ASCII panels (confident / uncertain / representative) ----
    for (panel, ci) in [(0usize, 0usize), (1, 1), (2, 2)] {
        let task_idx = view.config_idx[ci];
        let len = view.lengths[ci];
        println!("\npanel {panel}: curve {ci} ({} observed epochs)", len);
        plot_ascii(&task, task_idx, len, &samples, n + ci, &problem.ytf, m);
    }
    Ok(())
}

/// Tiny ASCII plot: o = observed, + = truth, | = sample band (10-90%).
fn plot_ascii(
    task: &Task,
    task_idx: usize,
    len: usize,
    samples: &[lkgp::linalg::Matrix],
    row: usize,
    ytf: &lkgp::gp::transforms::YTransform,
    m: usize,
) {
    let height = 12;
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for j in 0..m {
        lo = lo.min(task.curves[(task_idx, j)]);
        hi = hi.max(task.curves[(task_idx, j)]);
    }
    for s in samples {
        for j in 0..m {
            let v = ytf.undo_mean(s[(row, j)]);
            lo = lo.min(v.max(0.0));
            hi = hi.max(v.min(1.0));
        }
    }
    let span = (hi - lo).max(1e-6);
    let mut grid = vec![vec![b' '; m]; height];
    let to_row = |v: f64| -> usize {
        let z = ((v - lo) / span).clamp(0.0, 1.0);
        ((1.0 - z) * (height - 1) as f64).round() as usize
    };
    // sample band
    for j in 0..m {
        let mut vals: Vec<f64> = samples.iter().map(|s| ytf.undo_mean(s[(row, j)])).collect();
        vals.sort_by(f64::total_cmp);
        let b_lo = vals[vals.len() / 10];
        let b_hi = vals[vals.len() - 1 - vals.len() / 10];
        for r in to_row(b_hi)..=to_row(b_lo) {
            grid[r][j] = b'.';
        }
    }
    // truth + observed on top
    for j in 0..m {
        let v = task.curves[(task_idx, j)];
        grid[to_row(v)][j] = if j < len { b'o' } else { b'+' };
    }
    for line in grid {
        println!("  {}", String::from_utf8_lossy(&line));
    }
    println!("  {}", "-".repeat(m));
    println!("  o observed   + ground truth   . posterior sample band (10-90%)");
}
