//! End-to-end driver: the full freeze-thaw AutoML loop on a simulated
//! LCBench workload — all three layers composing.
//!
//! The coordinator (L3) schedules trials and batches prediction requests;
//! the prediction service executes the AOT-compiled LKGP artifacts (L2
//! jax graphs with the L1 pallas masked-Kronecker MVM inside) through the
//! PJRT runtime; nothing on this path touches Python.
//!
//! Reports: best config found vs the oracle, epochs spent vs exhaustive
//! training, early-stop counts, GP-request batching factor and latency.
//! Writes `results/automl_loop.csv` (per-round trace) and
//! `results/automl_loop_summary.json`. Recorded in EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --example automl_loop [-- --configs 24 --budget 400]
//! ```

use lkgp::coordinator::{
    EpochRunner, Policy, PredictionService, Scheduler, SchedulerCfg, TrialId, TrialStatus,
};
use lkgp::json::Json;
use lkgp::lcbench::{Preset, Task};
use lkgp::rng::Pcg64;
use lkgp::util::Args;

struct SimRunner {
    task: Task,
    /// Simulated cost bookkeeping: epochs actually "trained".
    epochs_run: usize,
}

impl EpochRunner for SimRunner {
    fn run_epoch(&mut self, trial: TrialId, _config: &[f64], epoch: usize) -> f64 {
        self.epochs_run += 1;
        self.task.curves[(trial.0, epoch.min(self.task.m() - 1))]
    }
}

fn main() -> lkgp::Result<()> {
    let args = Args::from_env();
    let seed = args.get_u64("seed", 0);
    let n_configs = args.get_usize("configs", 24);
    let budget = args.get_usize("budget", 400);
    let concurrent = args.get_usize("concurrent", 4);
    let prefer_xla = args.get("engine").unwrap_or("xla") == "xla";

    let mut rng = Pcg64::new(seed);
    let task = Task::generate(Preset::FashionMnist, n_configs, &mut rng);
    let oracle_best = (0..task.n())
        .map(|i| task.curves[(i, task.m() - 1)])
        .fold(f64::NEG_INFINITY, f64::max);
    let full_cost = n_configs * task.m();

    let engine = lkgp::runtime::open_engine(prefer_xla);
    println!("engine: {}", engine.name());
    let service = PredictionService::spawn(engine);

    let cfg = SchedulerCfg {
        max_concurrent: concurrent,
        refit_every: 5,
        epoch_budget: budget,
        policy: Policy::PredictedFinal { delta: 0.0, threshold: 0.95 },
        seed,
    };
    let mut sched = Scheduler::new(task.m(), cfg);
    let configs: Vec<Vec<f64>> = (0..task.n()).map(|i| task.configs.row(i).to_vec()).collect();
    sched.add_candidates(&configs);

    let mut runner = SimRunner { task, epochs_run: 0 };
    let t0 = std::time::Instant::now();
    let report = sched.run(&mut runner, &service)?;
    let wall = t0.elapsed();

    // ---- outputs ----
    let rows: Vec<Vec<String>> = report
        .trace
        .iter()
        .map(|(round, epochs, best)| {
            vec![round.to_string(), epochs.to_string(), format!("{best:.6}")]
        })
        .collect();
    lkgp::util::write_csv(
        "results/automl_loop.csv",
        &["round", "epochs_spent", "best_so_far"],
        &rows,
    )?;

    let regret = oracle_best - report.best_value;
    let p50 = service.stats.latency.lock().unwrap().quantile_micros(0.5);
    let p99 = service.stats.latency.lock().unwrap().quantile_micros(0.99);
    let summary = Json::obj(vec![
        ("engine", Json::Str("per --engine flag".into())),
        ("configs", Json::Num(n_configs as f64)),
        ("epoch_budget", Json::Num(budget as f64)),
        ("epochs_spent", Json::Num(report.epochs_spent as f64)),
        ("full_grid_epochs", Json::Num(full_cost as f64)),
        ("best_found", Json::Num(report.best_value)),
        ("oracle_best", Json::Num(oracle_best)),
        ("regret", Json::Num(regret)),
        ("stopped", Json::Num(report.stopped as f64)),
        ("completed", Json::Num(report.completed as f64)),
        ("batch_factor", Json::Num(report.batch_factor)),
        ("predict_p50_us", Json::Num(p50 as f64)),
        ("predict_p99_us", Json::Num(p99 as f64)),
        ("wall_seconds", Json::Num(wall.as_secs_f64())),
    ]);
    std::fs::create_dir_all("results")?;
    std::fs::write("results/automl_loop_summary.json", summary.pretty())?;

    println!("\n=== freeze-thaw AutoML run ===");
    println!("configs:        {n_configs} (full training would cost {full_cost} epochs)");
    println!(
        "epochs spent:   {} ({:.0}% of exhaustive)",
        report.epochs_spent,
        100.0 * report.epochs_spent as f64 / full_cost as f64
    );
    println!("best found:     {:.4}", report.best_value);
    println!("oracle best:    {oracle_best:.4}  (regret {regret:.4})");
    println!(
        "trials:         {} stopped early, {} completed, {} paused",
        report.stopped,
        report.completed,
        sched.registry.by_status(TrialStatus::Paused).len()
    );
    println!(
        "gp service:     batch factor {:.2}, predict p50 {p50}us p99 {p99}us",
        report.batch_factor
    );
    println!("wall time:      {:.2?}", wall);
    println!("\nwrote results/automl_loop.csv, results/automl_loop_summary.json");
    Ok(())
}
