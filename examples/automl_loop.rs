//! End-to-end driver: seeded Hyperband/ASHA-style Thompson sampling on a
//! simulated LCBench workload, served by the multi-shard `ServicePool` —
//! the library-level version of `lkgp pool --sample-storm`.
//!
//! Each rung refits the LKGP on the observed curve prefixes, then draws
//! joint posterior curves over the surviving arms with seeded
//! `CurveSamples` bursts. Selection is Thompson sampling: every joint
//! draw votes for its argmax final-epoch value, and the top `1/eta` arms
//! by vote count survive to train `eta` times deeper. The sampling rides
//! the pathwise fast path (docs/sampling.md): after a generation's first
//! draw builds the factored lineage, every further burst is solve-free —
//! the printed `pathwise_hits`/`sample_mvms` counters are the receipt.
//!
//! Reports: best arm found vs the oracle, epochs spent vs exhaustive
//! training, per-rung survivor trace, and the pool's sampling counters.
//! Writes `results/automl_loop.csv` (per-rung trace) and
//! `results/automl_loop_summary.json`.
//!
//! ```bash
//! cargo run --release --example automl_loop [-- --configs 24 --draws 16 --bursts 4 --eta 2]
//! ```

use std::collections::HashMap;

use lkgp::coordinator::{
    CurveStore, PoolCfg, PredictClient, Registry, ServicePool, TrialId,
};
use lkgp::json::Json;
use lkgp::lcbench::{Preset, Task};
use lkgp::linalg::Matrix;
use lkgp::rng::Pcg64;
use lkgp::runtime::{Engine, RustEngine};
use lkgp::util::Args;

fn main() -> lkgp::Result<()> {
    let args = Args::from_env();
    let seed = args.get_u64("seed", 0);
    let n_configs = args.get_usize("configs", 24).max(2);
    let draws = args.get_usize("draws", 16).max(1);
    let bursts = args.get_usize("bursts", 4).max(1);
    let eta = args.get_usize("eta", 2).max(2);
    let workers = args.get_usize("workers", 2).max(1);

    let mut rng = Pcg64::new(seed);
    let task = Task::generate(Preset::FashionMnist, n_configs, &mut rng);
    let m = task.m();
    let oracle_best = (0..task.n())
        .map(|i| task.curves[(i, m - 1)])
        .fold(f64::NEG_INFINITY, f64::max);
    let full_cost = n_configs * m;

    // One shard, a couple of workers: spare workers let read-only replicas
    // steal sampling bursts behind a busy writer (docs/serving.md) —
    // seeded draws are bit-identical either way.
    let engine = Box::new(RustEngine::default()) as Box<dyn Engine>;
    let pool = ServicePool::spawn(vec![engine], PoolCfg { workers, ..Default::default() });
    let handle = pool.handle(0);

    // Every arm is registered up front; rung 0 observes one epoch each.
    let mut reg = Registry::new();
    let ids: Vec<TrialId> = (0..task.n()).map(|i| reg.add(task.configs.row(i).to_vec())).collect();
    let mut store = CurveStore::new(m);
    let mut observed = vec![0usize; task.n()];
    for (i, &id) in ids.iter().enumerate() {
        reg.observe(id, task.curves[(i, 0)], m)?;
        observed[i] = 1;
    }
    let mut epochs_spent = task.n();

    let mut survivors: Vec<usize> = (0..task.n()).collect();
    let mut rung = 0usize;
    let mut trace_rows: Vec<Vec<String>> = Vec::new();
    let t0 = std::time::Instant::now();
    while survivors.len() > 1 {
        let snapshot = store.snapshot(&reg)?;
        let theta = handle.refit(snapshot.clone(), Vec::new(), seed.wrapping_add(rung as u64))?;
        let n_train = snapshot.data.n();
        let pos: HashMap<TrialId, usize> = snapshot
            .all_ids
            .iter()
            .enumerate()
            .map(|(r, &id)| (id, r))
            .collect();
        let mut xq = Matrix::zeros(survivors.len(), snapshot.all_x.cols());
        for (r, &arm) in survivors.iter().enumerate() {
            xq.row_mut(r).copy_from_slice(snapshot.all_x.row(pos[&ids[arm]]));
        }

        // Thompson sampling over seeded joint draws: one argmax vote per
        // drawn curve bundle (standardized values; the output transform
        // is monotone, so the argmax is unchanged).
        let mut wins = vec![0usize; survivors.len()];
        for b in 0..bursts {
            let burst_seed = seed
                .wrapping_add(((rung * bursts + b) as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15))
                & ((1u64 << 53) - 1);
            let samples = handle.sample_curves(
                snapshot.clone(),
                theta.clone(),
                xq.clone(),
                draws,
                burst_seed,
            )?;
            for smp in &samples {
                let (mut best, mut best_v) = (0usize, f64::NEG_INFINITY);
                for r in 0..survivors.len() {
                    let v = smp[(n_train + r, m - 1)];
                    if v > best_v {
                        best_v = v;
                        best = r;
                    }
                }
                wins[best] += 1;
            }
        }

        // ASHA successive halving: keep the top 1/eta arms by vote count
        // (ties break toward the lower row index — fully deterministic).
        let keep = ((survivors.len() + eta - 1) / eta).max(1);
        let mut order: Vec<usize> = (0..survivors.len()).collect();
        order.sort_by(|&a, &b| wins[b].cmp(&wins[a]).then(a.cmp(&b)));
        let mut kept: Vec<usize> = order[..keep].iter().map(|&r| survivors[r]).collect();
        kept.sort_unstable();
        println!(
            "rung {rung}: {} arms -> {keep} survivors (top vote {}/{})",
            survivors.len(),
            wins[order[0]],
            bursts * draws,
        );
        trace_rows.push(vec![
            rung.to_string(),
            survivors.len().to_string(),
            keep.to_string(),
            epochs_spent.to_string(),
        ]);
        survivors = kept;
        for &arm in &survivors {
            let target = (observed[arm] * eta).min(task.lengths[arm]).min(m);
            while observed[arm] < target {
                reg.observe(ids[arm], task.curves[(arm, observed[arm])], m)?;
                observed[arm] += 1;
                epochs_spent += 1;
            }
        }
        rung += 1;
    }
    let wall = t0.elapsed();

    // ---- outputs ----
    let winner = survivors[0];
    let best_found = task.curves[(winner, m - 1)];
    let regret = oracle_best - best_found;
    use std::sync::atomic::Ordering::Relaxed;
    let stats = pool.stats(0);
    let pathwise_hits = stats.pathwise_hits.load(Relaxed);
    let sample_mvms = stats.sample_mvms.load(Relaxed);
    let solves = stats.engine_solves.load(Relaxed);

    lkgp::util::write_csv(
        "results/automl_loop.csv",
        &["rung", "arms", "survivors", "epochs_spent"],
        &trace_rows,
    )?;
    let summary = Json::obj(vec![
        ("configs", Json::Num(n_configs as f64)),
        ("draws", Json::Num(draws as f64)),
        ("bursts", Json::Num(bursts as f64)),
        ("eta", Json::Num(eta as f64)),
        ("rungs", Json::Num(rung as f64)),
        ("epochs_spent", Json::Num(epochs_spent as f64)),
        ("full_grid_epochs", Json::Num(full_cost as f64)),
        ("best_found", Json::Num(best_found)),
        ("oracle_best", Json::Num(oracle_best)),
        ("regret", Json::Num(regret)),
        ("engine_solves", Json::Num(solves as f64)),
        ("pathwise_hits", Json::Num(pathwise_hits as f64)),
        ("sample_mvms", Json::Num(sample_mvms as f64)),
        ("wall_seconds", Json::Num(wall.as_secs_f64())),
    ]);
    std::fs::create_dir_all("results")?;
    std::fs::write("results/automl_loop_summary.json", summary.pretty())?;

    println!("\n=== Thompson-sampling ASHA run ===");
    println!("configs:        {n_configs} (full training would cost {full_cost} epochs)");
    println!(
        "epochs spent:   {epochs_spent} ({:.0}% of exhaustive)",
        100.0 * epochs_spent as f64 / full_cost as f64
    );
    println!("best found:     {best_found:.4} (arm {winner})");
    println!("oracle best:    {oracle_best:.4}  (regret {regret:.4})");
    println!(
        "gp service:     {solves} solves for {} draws — {pathwise_hits} pathwise hits, \
         {sample_mvms} sample MVMs (docs/sampling.md)",
        rung * bursts * draws,
    );
    println!("wall time:      {:.2?}", wall);
    println!("\nwrote results/automl_loop.csv, results/automl_loop_summary.json");
    Ok(())
}
