use lkgp::gp::Theta;
use lkgp::runtime::Engine;
fn main() -> lkgp::Result<()> {
    let mut eng = lkgp::runtime::XlaEngine::load(&lkgp::runtime::XlaEngine::default_dir())?;
    for (n, m, d) in [(16usize, 16usize, 3usize), (16, 52, 7), (32, 52, 7), (64, 52, 7)] {
        let data = lkgp::lcbench::toy_dataset(n, m, d, 1);
        let theta0 = Theta::default_packed(d);
        // compile
        let t0 = std::time::Instant::now();
        let (_v, _g, iters) = eng.mll_grad(&theta0, &data, 1)?;
        let compile_plus = t0.elapsed();
        let t1 = std::time::Instant::now();
        let _ = eng.mll_grad(&theta0, &data, 1)?;
        let one = t1.elapsed();
        println!("n={n} m={m}: mll_grad {one:?} (first {compile_plus:?}, cg {iters})");
        if n <= 32 {
            let t2 = std::time::Instant::now();
            let _theta = eng.fit(&theta0, &data, 1)?;
            println!("   fit_adam(150 steps) {:?}", t2.elapsed());
        }
    }
    Ok(())
}
