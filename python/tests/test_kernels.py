"""L1 Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes, dtypes, tile sizes and mask patterns; every case
asserts allclose against the reference. This is the core correctness signal
for the kernels that end up inside the AOT artifacts.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import kron_mvm, pairwise, ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _rng(seed):
    return np.random.default_rng(seed)


shapes = st.tuples(
    st.integers(1, 48),  # n
    st.integers(1, 40),  # m
    st.integers(1, 9),  # d
)


@given(shapes, st.integers(0, 2**31 - 1), st.sampled_from([8, 16, 64]))
def test_masked_kron_mvm_matches_ref(shape, seed, tile):
    n, m, d = shape
    rng = _rng(seed)
    x = rng.standard_normal((n, d))
    k1 = ref.rbf_kernel(x, x, np.full(d, 1.3))
    t = np.linspace(0.0, 1.0, m)
    k2 = ref.matern12_kernel(t, t, 0.4, 1.7)
    mask = (rng.uniform(size=(n, m)) < 0.75).astype(np.float64)
    v = rng.standard_normal((n, m))
    want = ref.masked_kron_mvm(k1, k2, mask, 0.05, v)
    got = kron_mvm.masked_kron_mvm(
        np.asarray(k1), np.asarray(k2), mask, 0.05, v, tile=tile
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-10, atol=1e-10)


@given(shapes, st.integers(0, 2**31 - 1), st.integers(1, 4))
def test_masked_kron_mvm_batched(shape, seed, b):
    n, m, d = shape
    rng = _rng(seed)
    x = rng.standard_normal((n, d))
    k1 = ref.rbf_kernel(x, x, np.full(d, 0.9))
    t = np.linspace(0.0, 1.0, m)
    k2 = ref.matern12_kernel(t, t, 0.3, 0.8)
    mask = (rng.uniform(size=(n, m)) < 0.6).astype(np.float64)
    v = rng.standard_normal((b, n, m))
    want = ref.masked_kron_mvm(k1, k2, mask, 0.11, v)
    got = kron_mvm.masked_kron_mvm(np.asarray(k1), np.asarray(k2), mask, 0.11, v, tile=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-10, atol=1e-10)


@given(
    st.integers(1, 40), st.integers(1, 40), st.integers(1, 9),
    st.integers(0, 2**31 - 1), st.sampled_from([16, 128]),
)
def test_rbf_kernel_matches_ref(n1, n2, d, seed, tile):
    rng = _rng(seed)
    x1 = rng.standard_normal((n1, d))
    x2 = rng.standard_normal((n2, d))
    ls = rng.uniform(0.2, 3.0, d)
    want = ref.rbf_kernel(x1, x2, ls)
    got = pairwise.rbf_kernel(x1, x2, ls, tile=tile)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12, atol=1e-12)


@given(
    st.integers(1, 60), st.integers(1, 60),
    st.floats(0.05, 5.0), st.floats(0.05, 5.0),
    st.integers(0, 2**31 - 1), st.sampled_from([16, 128]),
)
def test_matern12_kernel_matches_ref(m1, m2, ls, os_, seed, tile):
    rng = _rng(seed)
    t1 = np.sort(rng.uniform(0, 1, m1))
    t2 = np.sort(rng.uniform(0, 1, m2))
    want = ref.matern12_kernel(t1, t2, ls, os_)
    got = pairwise.matern12_kernel(t1, t2, ls, os_, tile=tile)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-12, atol=1e-12)


@given(st.floats(32.1, 64.0))
def test_rbf_float32_path(dummy):
    """Kernels must also work in f32 (dtype sweep)."""
    rng = _rng(int(dummy * 1000))
    x = rng.standard_normal((12, 4)).astype(np.float32)
    ls = np.full(4, 1.1, dtype=np.float32)
    want = ref.rbf_kernel(x, x, ls)
    got = pairwise.rbf_kernel(x, x, ls, tile=8)
    assert np.asarray(got).dtype == np.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_mvm_float32_path():
    rng = _rng(7)
    n, m = 12, 10
    k1 = np.eye(n, dtype=np.float32) + 0.1
    k2 = np.eye(m, dtype=np.float32) * 2.0
    mask = np.ones((n, m), dtype=np.float32)
    v = rng.standard_normal((n, m)).astype(np.float32)
    want = ref.masked_kron_mvm(k1, k2, mask, np.float32(0.1), v)
    got = kron_mvm.masked_kron_mvm(k1, k2, mask, np.float32(0.1), v, tile=8)
    assert np.asarray(got).dtype == np.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_mvm_equals_dense_operator():
    """The masked MVM agrees with the dense (P K P^T + s I) embedding."""
    rng = _rng(3)
    n, m, d = 9, 7, 4
    x = rng.standard_normal((n, d))
    k1 = np.asarray(ref.rbf_kernel(x, x, np.full(d, 1.0)))
    t = np.linspace(0, 1, m)
    k2 = np.asarray(ref.matern12_kernel(t, t, 0.5, 1.2))
    mask = (rng.uniform(size=(n, m)) < 0.5).astype(np.float64)
    dense = np.asarray(ref.dense_joint_kernel(k1, k2, mask, 0.07))
    v = rng.standard_normal((n, m)) * mask  # observed-supported
    want = (dense @ v.reshape(-1)).reshape(n, m)
    got = np.asarray(kron_mvm.masked_kron_mvm(k1, k2, mask, 0.07, v, tile=8))
    # On the missing entries the dense embedding gives sigma2*0 = 0 too.
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-10)


def test_mvm_projection_submatrix_semantics():
    """P (K1 x K2) P^T equals slicing rows/cols of the Kronecker product.

    This is Figure 2 of the paper as a unit test.
    """
    rng = _rng(11)
    n, m = 4, 3
    a = rng.standard_normal((n, n)); k1 = a @ a.T + np.eye(n)
    b = rng.standard_normal((m, m)); k2 = b @ b.T + np.eye(m)
    mask = np.array([[1, 1, 0], [1, 1, 1], [0, 1, 0], [1, 0, 1]], dtype=np.float64)
    kk = np.kron(k1, k2)
    idx = np.nonzero(mask.reshape(-1))[0]
    sub = kk[np.ix_(idx, idx)]  # P K P^T by explicit row selection
    dense = np.asarray(ref.dense_joint_kernel(k1, k2, mask, 0.0))
    np.testing.assert_allclose(dense[np.ix_(idx, idx)], sub, rtol=1e-12)
    # and rows/cols outside the mask are zero
    off = np.nonzero(1 - mask.reshape(-1))[0]
    assert np.all(dense[np.ix_(off, idx)] == 0)
    assert np.all(dense[np.ix_(idx, off)] == 0)
