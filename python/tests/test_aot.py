"""AOT exports: manifest consistency and HLO-text invariants.

Executing the artifacts end-to-end is the job of the rust integration tests
(rust/tests/); here we verify the build-time contract the runtime relies on.
"""

import json
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)

ENTRIES = {"mvm", "kernel_matrices", "mll_grad", "fit_adam", "predict_mean", "posterior"}


def load_manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_manifest_lists_existing_files():
    man = load_manifest()
    assert man["format"] == 1
    assert man["dtype"] == "f64"
    for rec in man["artifacts"]:
        path = os.path.join(ART, rec["file"])
        assert os.path.exists(path), rec["file"]
        assert os.path.getsize(path) > 1000


def test_every_bucket_has_all_entries():
    man = load_manifest()
    by_bucket = {}
    for rec in man["artifacts"]:
        by_bucket.setdefault((rec["n"], rec["m"], rec["d"]), set()).add(rec["entry"])
    assert by_bucket, "no buckets"
    for bucket, entries in by_bucket.items():
        assert entries == ENTRIES, f"bucket {bucket} missing {ENTRIES - entries}"


def test_quality_bucket_matches_lcbench_shape():
    """The quality experiment needs (m=52, d=7) buckets (LCBench grids)."""
    man = load_manifest()
    assert any(r["m"] == 52 and r["d"] == 7 for r in man["artifacts"])


def test_input_specs_are_complete():
    man = load_manifest()
    want_inputs = {
        "mvm": ["theta", "x", "t", "mask", "v"],
        "kernel_matrices": ["theta", "x", "t"],
        "mll_grad": ["theta", "x", "t", "y", "mask", "probes"],
        "fit_adam": ["theta0", "x", "t", "y", "mask", "probes"],
        "predict_mean": ["theta", "x", "t", "y", "mask", "xq"],
        "posterior": ["theta", "x", "t", "y", "mask", "xq", "zeta", "eps"],
    }
    for rec in man["artifacts"]:
        names = [i["name"] for i in rec["inputs"]]
        assert names == want_inputs[rec["entry"]], rec["file"]
        n, m, d = rec["n"], rec["m"], rec["d"]
        shapes = {i["name"]: i["shape"] for i in rec["inputs"]}
        if "x" in shapes:
            assert shapes["x"] == [n, d]
        if "mask" in shapes:
            assert shapes["mask"] == [n, m]
        if "probes" in shapes:
            assert shapes["probes"] == [rec["p"], n, m]
        if "zeta" in shapes:
            assert shapes["zeta"] == [rec["s"], n + rec["q"], m]


def test_hlo_text_is_parsable_format():
    """Text artifacts must look like HLO modules (ENTRY + f64 types)."""
    man = load_manifest()
    for rec in man["artifacts"][:6]:
        with open(os.path.join(ART, rec["file"])) as f:
            text = f.read()
        assert "HloModule" in text
        assert "ENTRY" in text
        assert "f64" in text


def test_no_unsupported_custom_calls():
    """The rust CPU client cannot run LAPACK/Mosaic custom calls; the whole
    portability strategy (own cholesky/jacobi, pallas interpret) exists to
    keep these out of the artifacts."""
    man = load_manifest()
    for rec in man["artifacts"]:
        with open(os.path.join(ART, rec["file"])) as f:
            text = f.read()
        assert "lapack" not in text.lower(), rec["file"]
        assert "mosaic" not in text.lower(), rec["file"]


def test_no_truncated_constants():
    """The default HLO printer elides large constants as `constant({...})`
    and xla_extension 0.5.1 silently ZERO-FILLS them (this turned Jacobi
    rotations into no-ops). aot.to_hlo_text must print full payloads."""
    man = load_manifest()
    for rec in man["artifacts"]:
        with open(os.path.join(ART, rec["file"])) as f:
            text = f.read()
        assert "{...}" not in text, rec["file"]


def test_no_unparsable_metadata():
    """jax >= 0.5 emits metadata attributes (source_end_line etc.) the old
    text parser rejects; aot.to_hlo_text disables metadata printing."""
    man = load_manifest()
    for rec in man["artifacts"]:
        with open(os.path.join(ART, rec["file"])) as f:
            text = f.read()
        assert "source_end_line" not in text, rec["file"]
