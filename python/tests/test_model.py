"""L2 model vs dense oracles: CG, SLQ, MLL, gradients, Matheron sampling."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

settings.register_profile("model", max_examples=10, deadline=None)
settings.load_profile("model")


def make_problem(n, m, d, seed, frac=0.7, prefix=True):
    rng = np.random.default_rng(seed)
    x = rng.uniform(size=(n, d))
    t = np.linspace(0.0, 1.0, m)
    if prefix:
        lens = rng.integers(max(1, int(frac * m) - 2), m + 1, n)
        mask = (np.arange(m)[None, :] < lens[:, None]).astype(np.float64)
    else:
        mask = (rng.uniform(size=(n, m)) < frac).astype(np.float64)
    y = rng.standard_normal((n, m)) * mask
    theta = np.asarray(model.default_theta(d))
    return x, t, y, mask, theta, rng


@given(st.integers(2, 14), st.integers(2, 10), st.integers(1, 5), st.integers(0, 2**31 - 1))
def test_cg_matches_dense_solve(n, m, d, seed):
    x, t, y, mask, theta, rng = make_problem(n, m, d, seed, prefix=False)
    p = model.unpack_theta(theta)
    k1 = np.asarray(ref.rbf_kernel(x, x, p.lengthscales))
    k2 = np.asarray(ref.matern12_kernel(t, t, p.t_lengthscale, p.outputscale))
    s2 = float(p.sigma2)
    dense = np.asarray(ref.dense_joint_kernel(k1, k2, mask, s2))
    # keep the missing-subspace identity so dense is invertible
    rhs = (rng.standard_normal((n, m)) * mask)
    matvec = model.masked_operator(k1, k2, mask, s2, use_pallas=False)
    sol, iters = model.cg_solve(matvec, rhs[None], tol=1e-10, max_iters=5000)
    want = np.linalg.solve(dense, rhs.reshape(-1)).reshape(n, m)
    np.testing.assert_allclose(np.asarray(sol[0]), want, rtol=1e-6, atol=1e-8)


def test_cg_stays_in_observed_subspace():
    x, t, y, mask, theta, rng = make_problem(10, 8, 3, 5)
    p = model.unpack_theta(theta)
    k1 = np.asarray(ref.rbf_kernel(x, x, p.lengthscales))
    k2 = np.asarray(ref.matern12_kernel(t, t, p.t_lengthscale, p.outputscale))
    matvec = model.masked_operator(k1, k2, mask, float(p.sigma2), use_pallas=False)
    sol, _ = model.cg_solve(matvec, (y * mask)[None], tol=1e-8, max_iters=2000)
    assert np.all(np.asarray(sol[0])[mask == 0] == 0.0)


def test_cholesky_jnp_matches_numpy():
    rng = np.random.default_rng(0)
    for n in (1, 2, 5, 17, 40):
        a = rng.standard_normal((n, n))
        spd = a @ a.T + n * np.eye(n)
        l = np.asarray(model.cholesky_jnp(spd))
        np.testing.assert_allclose(l, np.linalg.cholesky(spd), rtol=1e-9, atol=1e-9)


def test_jacobi_eigh_matches_numpy():
    rng = np.random.default_rng(1)
    for k in (2, 3, 8, 20):
        a = rng.standard_normal((k, k))
        sym = (a + a.T) / 2
        evals, evecs = model.jacobi_eigh(sym)
        evals = np.sort(np.asarray(evals))
        want = np.sort(np.linalg.eigvalsh(sym))
        np.testing.assert_allclose(evals, want, rtol=1e-8, atol=1e-8)
        # eigenvector property: A v = lambda v
        ev, V = model.jacobi_eigh(sym)
        np.testing.assert_allclose(sym @ np.asarray(V), np.asarray(V) * np.asarray(ev)[None, :], atol=1e-8)


def test_slq_logdet_close_to_exact():
    x, t, y, mask, theta, rng = make_problem(12, 9, 3, 9)
    p = model.unpack_theta(theta)
    k1 = np.asarray(ref.rbf_kernel(x, x, p.lengthscales))
    k2 = np.asarray(ref.matern12_kernel(t, t, p.t_lengthscale, p.outputscale))
    s2 = float(p.sigma2)
    dense = np.asarray(ref.dense_joint_kernel(k1, k2, mask, s2))
    want = np.linalg.slogdet(dense)[1]
    matvec = model.masked_operator(k1, k2, mask, s2, use_pallas=False)
    probes = rng.choice([-1.0, 1.0], size=(64, 12, 9))
    got = float(model.slq_logdet(matvec, probes, iters=20))
    assert abs(got - want) / abs(want) < 0.05


def test_mll_value_close_to_exact():
    x, t, y, mask, theta, rng = make_problem(12, 8, 3, 1)
    probes = rng.choice([-1.0, 1.0], size=(32, 12, 8))
    v, g, _ = model.mll_value_and_grad(theta, x, t, y, mask, probes, use_pallas=False)
    ve = float(model.mll_exact(theta, x, t, y, mask))
    assert abs(float(v) - ve) / abs(ve) < 0.02


@given(st.integers(0, 2**31 - 1))
def test_mll_grad_matches_exact_fd(seed):
    n, m, d = 10, 7, 2
    x, t, y, mask, theta, rng = make_problem(n, m, d, seed)
    theta = theta + rng.normal(0, 0.2, theta.shape)  # random parameter point
    probes = rng.choice([-1.0, 1.0], size=(64, n, m))
    _, g, _ = model.mll_value_and_grad(theta, x, t, y, mask, probes,
                                       use_pallas=False, cg_tol=1e-8)
    h = 1e-5
    ge = np.zeros_like(theta)
    for i in range(len(theta)):
        tp = theta.copy(); tp[i] += h
        tm = theta.copy(); tm[i] -= h
        ge[i] = (float(model.mll_exact(tp, x, t, y, mask))
                 - float(model.mll_exact(tm, x, t, y, mask))) / (2 * h)
    # Hutchinson noise scales with trace magnitude; compare directionally
    denom = np.linalg.norm(ge) + 1e-12
    assert np.linalg.norm(np.asarray(g) - ge) / denom < 0.15


def test_predict_mean_matches_dense_posterior():
    n, m, d, q = 10, 6, 3, 4
    x, t, y, mask, theta, rng = make_problem(n, m, d, 3)
    xq = rng.uniform(size=(q, d))
    p = model.unpack_theta(theta)
    k1 = np.asarray(ref.rbf_kernel(x, x, p.lengthscales))
    k2 = np.asarray(ref.matern12_kernel(t, t, p.t_lengthscale, p.outputscale))
    s2 = float(p.sigma2)
    idx = np.nonzero(mask.reshape(-1))[0]
    kk = np.kron(k1, k2)
    kobs = kk[np.ix_(idx, idx)] + s2 * np.eye(len(idx))
    k1q = np.asarray(ref.rbf_kernel(xq, x, p.lengthscales))
    kcross = np.kron(k1q, k2)[:, idx]  # (q*m, n_obs)
    alpha = np.linalg.solve(kobs, (y * mask).reshape(-1)[idx])
    want = (kcross @ alpha).reshape(q, m)
    got, _ = model.predict_mean(theta, x, t, y, mask, xq, cg_tol=1e-10, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6, atol=1e-8)


def test_matheron_samples_have_posterior_moments():
    """Sample mean/cov over many Matheron draws matches the dense posterior."""
    n, m, d, q, s = 6, 5, 2, 3, 3000
    x, t, y, mask, theta, rng = make_problem(n, m, d, 21)
    xq = rng.uniform(size=(q, d))
    zeta = rng.standard_normal((s, n + q, m))
    eps = rng.standard_normal((s, n, m))
    samples, _ = model.posterior_samples(theta, x, t, y, mask, xq, zeta, eps,
                                         cg_tol=1e-8, use_pallas=False)
    samples = np.asarray(samples)[:, n:, :]  # query configs only

    p = model.unpack_theta(theta)
    k1j = np.asarray(ref.rbf_kernel(np.concatenate([x, xq]), np.concatenate([x, xq]),
                                    p.lengthscales))
    k2 = np.asarray(ref.matern12_kernel(t, t, p.t_lengthscale, p.outputscale))
    s2 = float(p.sigma2)
    kk = np.kron(k1j, k2)
    nm = n * m
    idx = np.nonzero(mask.reshape(-1))[0]
    qidx = nm + np.arange(q * m)
    kobs = kk[np.ix_(idx, idx)] + s2 * np.eye(len(idx))
    kcross = kk[np.ix_(qidx, idx)]
    yobs = (y * mask).reshape(-1)[idx]
    mean = (kcross @ np.linalg.solve(kobs, yobs)).reshape(q, m)
    cov = kk[np.ix_(qidx, qidx)] - kcross @ np.linalg.solve(kobs, kcross.T)

    emp_mean = samples.mean(axis=0)
    np.testing.assert_allclose(emp_mean, mean, atol=4 * np.sqrt(np.diag(cov).max() / s) + 5e-2)
    emp_cov = np.cov(samples.reshape(s, -1).T)
    assert np.abs(emp_cov - cov).max() < 0.15 * max(1.0, np.abs(cov).max())


def test_fit_adam_improves_objective():
    n, m, d = 16, 12, 3
    x, t, y, mask, theta0, rng = make_problem(n, m, d, 4)
    # targets with actual structure: smooth curves
    base = 1.0 - np.exp(-3 * np.linspace(0, 1, m))
    y = (base[None, :] * rng.uniform(0.5, 1.0, (n, 1)) + 0.01 * rng.standard_normal((n, m))) * mask
    y = (y - y.max()) / (y.std() + 1e-12)
    probes = rng.choice([-1.0, 1.0], size=(8, n, m))
    theta, (values, iters) = model.fit_adam(theta0, x, t, y, mask, probes,
                                            steps=40, lr=0.1, use_pallas=False)
    assert float(values[-1]) > float(values[0])
    # exact MLL agrees that the fit improved
    assert float(model.mll_exact(np.asarray(theta), x, t, y, mask)) > float(
        model.mll_exact(np.asarray(theta0), x, t, y, mask))


def test_transform_roundtrip_conventions():
    """Document/lock the paper's §B transforms (implemented rust-side)."""
    # t -> log-spaced unit interval
    t = np.arange(1, 53, dtype=np.float64)
    lt = np.log(t)
    tn = (lt - lt[0]) / (lt[-1] - lt[0])
    assert tn[0] == 0.0 and tn[-1] == 1.0 and np.all(np.diff(tn) > 0)
    # y -> subtract max, divide by std
    rng = np.random.default_rng(0)
    y = rng.uniform(0.3, 0.9, size=(8, 52))
    ys = (y - y.max()) / y.std()
    assert ys.max() == 0.0
    np.testing.assert_allclose(ys.std(), 1.0, rtol=1e-12)


@given(st.integers(2, 24), st.integers(0, 2**31 - 1))
def test_jacobi_evals_w_matches_full_eigh(k, seed):
    """The SLQ fast path (first-row-only eigenvector carry) must agree
    with the full decomposition on eigenvalues and quadrature weights."""
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((k, k))
    a = (a + a.T) / 2
    ev_full, V = model.jacobi_eigh(a)
    ev_fast, w = model.jacobi_evals_w(a)
    np.testing.assert_allclose(np.sort(np.asarray(ev_fast)),
                               np.sort(np.asarray(ev_full)), atol=1e-10)
    want_w = np.asarray(V)[0, :] ** 2
    # match by eigenvalue ordering
    order_full = np.argsort(np.asarray(ev_full))
    order_fast = np.argsort(np.asarray(ev_fast))
    np.testing.assert_allclose(np.asarray(w)[order_fast], want_w[order_full],
                               atol=1e-9)
    # weights sum to 1 (e1 has unit norm)
    np.testing.assert_allclose(np.asarray(w).sum(), 1.0, atol=1e-10)


def test_jacobi_evals_w_odd_size():
    rng = np.random.default_rng(0)
    a = rng.standard_normal((7, 7))
    a = (a + a.T) / 2
    ev, w = model.jacobi_evals_w(a)
    assert np.asarray(ev).shape == (7,)
    np.testing.assert_allclose(np.sort(np.asarray(ev)),
                               np.sort(np.linalg.eigvalsh(a)), atol=1e-9)
