"""AOT export: lower every LKGP entry point to HLO text + manifest.json.

This is the only place Python touches the artifact boundary. Each entry
point is lowered for a grid of static shape buckets; the rust runtime picks
the smallest bucket that fits a live problem and pads with fully-masked
rows (mathematically inert for the masked operator — see model.py).

Interchange format is HLO **text**, not serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published `xla` crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out ../artifacts [--preset core|scaling|all]

`make artifacts` is a no-op when the manifest is newer than the sources.
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc

from . import model

F64 = jnp.float64


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange).

    Two print options matter for the old parser in xla_extension 0.5.1:
    * ``print_large_constants=True`` — the default printer elides big
      constant payloads as ``constant({...})`` and the old parser silently
      zero-fills them (one-hot masks became zeros: rotations vanished).
    * ``print_metadata=False`` — jax >= 0.5 emits metadata attributes
      (``source_end_line`` etc.) the old parser rejects outright.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F64)


# ---------------------------------------------------------------------------
# Entry-point wrappers: array-only signatures, f64-only outputs
# (the iteration counter is cast to f64 so the rust side handles one dtype).

def entry_mvm(theta, x, t, mask, v):
    p = model.unpack_theta(theta)
    k1, k2 = model.kernel_matrices(theta, x, t, use_pallas=True)
    out = model.masked_operator(k1, k2, mask, p.sigma2, use_pallas=True)(v)
    return (out,)


def entry_kernel_matrices(theta, x, t):
    k1, k2 = model.kernel_matrices(theta, x, t, use_pallas=True)
    return (k1, k2)


def entry_mll_grad(theta, x, t, y, mask, probes):
    value, grad, iters = model.mll_value_and_grad(theta, x, t, y, mask, probes)
    return (value, grad, iters.astype(F64))


def entry_fit_adam(steps, lr, theta0, x, t, y, mask, probes):
    theta, (values, iters) = model.fit_adam(
        theta0, x, t, y, mask, probes, steps=steps, lr=lr
    )
    return (theta, values, iters.astype(F64))


def entry_predict_mean(theta, x, t, y, mask, xq):
    mean, iters = model.predict_mean(theta, x, t, y, mask, xq)
    return (mean, jnp.asarray(iters, F64))


def entry_posterior(theta, x, t, y, mask, xq, zeta, eps):
    samples, iters = model.posterior_samples(theta, x, t, y, mask, xq, zeta, eps)
    return (samples, jnp.asarray(iters, F64))


# ---------------------------------------------------------------------------
# Bucket grids

def core_buckets():
    """Buckets used by the quality experiment, examples, and coordinator.

    (n, m, d, q, s, p): n configs, m grid epochs, d hyper-params, q query
    configs, s posterior samples, p probes. LCBench tasks have d = 7 and
    52-epoch curves.
    """
    out = []
    for n in (16, 32, 64):
        out.append(dict(n=n, m=52, d=7, q=16, s=32, p=8))
    out.append(dict(n=16, m=16, d=3, q=8, s=16, p=8))  # quickstart/tests
    return out


def scaling_buckets():
    """Buckets for the Figure-3 scaling series (paper §C: d = 10)."""
    return [dict(n=s, m=s, d=10, q=16, s=16, p=8) for s in (16, 32, 64, 128)]


# ---------------------------------------------------------------------------

def lower_bucket(b: dict, out_dir: str, fit_steps: int, fit_lr: float):
    """Lower all entry points for one bucket; returns manifest records."""
    n, m, d, q, s, p = b["n"], b["m"], b["d"], b["q"], b["s"], b["p"]
    nt = d + 3
    records = []

    def emit(name, fn, in_specs, in_names, out_names, extra=None):
        t0 = time.time()
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}_n{n}_m{m}_d{d}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        rec = {
            "entry": name,
            "file": fname,
            "n": n, "m": m, "d": d, "q": q, "s": s, "p": p,
            "inputs": [
                {"name": nm_, "shape": list(sp.shape)} for nm_, sp in zip(in_names, in_specs)
            ],
            "outputs": out_names,
        }
        if extra:
            rec.update(extra)
        records.append(rec)
        print(f"  {fname}: {len(text)} chars in {time.time()-t0:.1f}s", flush=True)

    emit(
        "mvm", entry_mvm,
        [spec(nt), spec(n, d), spec(m), spec(n, m), spec(n, m)],
        ["theta", "x", "t", "mask", "v"], ["out"],
    )
    emit(
        "kernel_matrices", entry_kernel_matrices,
        [spec(nt), spec(n, d), spec(m)],
        ["theta", "x", "t"], ["k1", "k2"],
    )
    emit(
        "mll_grad", entry_mll_grad,
        [spec(nt), spec(n, d), spec(m), spec(n, m), spec(n, m), spec(p, n, m)],
        ["theta", "x", "t", "y", "mask", "probes"], ["value", "grad", "iters"],
    )
    emit(
        "fit_adam", functools.partial(entry_fit_adam, fit_steps, fit_lr),
        [spec(nt), spec(n, d), spec(m), spec(n, m), spec(n, m), spec(p, n, m)],
        ["theta0", "x", "t", "y", "mask", "probes"], ["theta", "values", "iters"],
        extra={"steps": fit_steps, "lr": fit_lr},
    )
    emit(
        "predict_mean", entry_predict_mean,
        [spec(nt), spec(n, d), spec(m), spec(n, m), spec(n, m), spec(q, d)],
        ["theta", "x", "t", "y", "mask", "xq"], ["mean", "iters"],
    )
    emit(
        "posterior", entry_posterior,
        [spec(nt), spec(n, d), spec(m), spec(n, m), spec(n, m), spec(q, d),
         spec(s, n + q, m), spec(s, n, m)],
        ["theta", "x", "t", "y", "mask", "xq", "zeta", "eps"],
        ["samples", "iters"],
    )
    return records


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--preset", default="all", choices=["core", "scaling", "all"])
    # §Perf: 80 warm-startable Adam steps at lr 0.08 reach the same MAP
    # objective as the initial 150 x 0.05 on the quality workloads in
    # roughly half the wall time (validated by fig4 + parity tests).
    ap.add_argument("--fit-steps", type=int, default=80)
    ap.add_argument("--fit-lr", type=float, default=0.08)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    buckets = []
    if args.preset in ("core", "all"):
        buckets += core_buckets()
    if args.preset in ("scaling", "all"):
        buckets += scaling_buckets()

    records = []
    for b in buckets:
        print(f"bucket n={b['n']} m={b['m']} d={b['d']}", flush=True)
        records += lower_bucket(b, args.out, args.fit_steps, args.fit_lr)

    manifest = {
        "format": 1,
        "dtype": "f64",
        "fit_steps": args.fit_steps,
        "fit_lr": args.fit_lr,
        "artifacts": records,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(records)} artifacts + manifest to {args.out}")


if __name__ == "__main__":
    main()
