"""L1 Pallas kernels: pairwise kernel-matrix construction.

Builds the two factor matrices of the latent Kronecker product:

  * ARD RBF over hyper-parameter configurations x in R^d
  * Matern-1/2 (exponential) over learning-curve progressions t in R

Each output tile (bi, bj) is computed from a (bi, d) and a (bj, d) strip of
inputs held in VMEM; d is small (LCBench: 7), so the tile working set is
dominated by the (bi, bj) output block. The exp epilogue is fused — on TPU
this runs on the VPU directly after the MXU distance accumulation, with no
HBM round-trip for the squared distances.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rbf_kernel_body(x1_ref, x2_ref, ls_ref, o_ref):
    """RBF tile: o[i, j] = exp(-0.5 * sum_d ((x1[i,d]-x2[j,d])/ls[d])^2)."""
    z1 = x1_ref[...] / ls_ref[...]
    z2 = x2_ref[...] / ls_ref[...]
    d2 = (
        jnp.sum(z1 * z1, axis=1)[:, None]
        + jnp.sum(z2 * z2, axis=1)[None, :]
        - 2.0 * (z1 @ z2.T)
    )
    o_ref[...] = jnp.exp(-0.5 * jnp.maximum(d2, 0.0))


def _matern12_kernel_body(t1_ref, t2_ref, p_ref, o_ref):
    """Matern-1/2 tile: o[i, j] = os * exp(-|t1[i]-t2[j]| / ls).

    p_ref holds (lengthscale, outputscale).
    """
    d = jnp.abs(t1_ref[...][:, None] - t2_ref[...][None, :])
    o_ref[...] = p_ref[1] * jnp.exp(-d / p_ref[0])


def _block(size: int, tile: int) -> int:
    b = min(size, tile)
    while size % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("tile",))
def rbf_kernel(x1, x2, lengthscales, *, tile=128):
    """ARD RBF kernel matrix via tiled Pallas evaluation.

    Args:
        x1: (n1, d) inputs.
        x2: (n2, d) inputs.
        lengthscales: (d,) positive length scales.

    Returns:
        (n1, n2) kernel matrix.
    """
    n1, d = x1.shape
    n2, _ = x2.shape
    bi = _block(n1, tile)
    bj = _block(n2, tile)
    grid = (n1 // bi, n2 // bj)
    return pl.pallas_call(
        _rbf_kernel_body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bi, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bj, d), lambda i, j: (j, 0)),
            pl.BlockSpec((d,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n1, n2), x1.dtype),
        interpret=True,
    )(x1, x2, lengthscales)


@functools.partial(jax.jit, static_argnames=("tile",))
def matern12_kernel(t1, t2, lengthscale, outputscale, *, tile=128):
    """Matern-1/2 kernel matrix via tiled Pallas evaluation.

    Args:
        t1: (m1,) progressions.
        t2: (m2,) progressions.
        lengthscale: scalar length scale.
        outputscale: scalar output scale.

    Returns:
        (m1, m2) kernel matrix.
    """
    m1 = t1.shape[0]
    m2 = t2.shape[0]
    bi = _block(m1, tile)
    bj = _block(m2, tile)
    grid = (m1 // bi, m2 // bj)
    p = jnp.stack(
        [jnp.asarray(lengthscale, t1.dtype), jnp.asarray(outputscale, t1.dtype)]
    ).reshape((2,))
    return pl.pallas_call(
        _matern12_kernel_body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bi,), lambda i, j: (i,)),
            pl.BlockSpec((bj,), lambda i, j: (j,)),
            pl.BlockSpec((2,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m1, m2), t1.dtype),
        interpret=True,
    )(t1, t2, p)
