"""Pure-jnp reference oracles for every Pallas kernel.

These are the ground truth used by pytest/hypothesis to validate the L1
Pallas kernels, and they double as the building blocks of the L2 model when
a shape is too small/awkward to tile (the model dispatches to the Pallas
variant for the hot path and to these references elsewhere — both lower into
the same HLO artifact, so the choice is a build-time detail).

All math is float64 (the paper runs in double precision, Appendix B).
"""

from __future__ import annotations

import jax.numpy as jnp


def rbf_kernel(x1: jnp.ndarray, x2: jnp.ndarray, lengthscales: jnp.ndarray) -> jnp.ndarray:
    """ARD RBF kernel matrix.

    k(x, x') = exp(-0.5 * sum_d ((x_d - x'_d) / ls_d)^2)

    Args:
        x1: (n1, d) inputs.
        x2: (n2, d) inputs.
        lengthscales: (d,) positive length scales.

    Returns:
        (n1, n2) kernel matrix.
    """
    z1 = x1 / lengthscales
    z2 = x2 / lengthscales
    # Clamp tiny negatives from cancellation before exp.
    d2 = (
        jnp.sum(z1 * z1, axis=-1)[:, None]
        + jnp.sum(z2 * z2, axis=-1)[None, :]
        - 2.0 * z1 @ z2.T
    )
    d2 = jnp.maximum(d2, 0.0)
    return jnp.exp(-0.5 * d2)


def matern12_kernel(
    t1: jnp.ndarray, t2: jnp.ndarray, lengthscale: jnp.ndarray, outputscale: jnp.ndarray
) -> jnp.ndarray:
    """Matern-1/2 (exponential) kernel matrix over scalar progressions.

    k(t, t') = outputscale * exp(-|t - t'| / lengthscale)

    Args:
        t1: (m1,) progression values.
        t2: (m2,) progression values.
        lengthscale: scalar positive length scale.
        outputscale: scalar positive output scale (variance).

    Returns:
        (m1, m2) kernel matrix.
    """
    d = jnp.abs(t1[:, None] - t2[None, :])
    return outputscale * jnp.exp(-d / lengthscale)


def masked_kron_mvm(
    k1: jnp.ndarray,
    k2: jnp.ndarray,
    mask: jnp.ndarray,
    sigma2: jnp.ndarray,
    v: jnp.ndarray,
) -> jnp.ndarray:
    """Masked latent-Kronecker matrix-vector product (the paper's core op).

    Computes ``M . (K1 (M . V) K2) + sigma2 * V`` where ``.`` is elementwise,
    which is the full-space embedding of ``(P (K1 x K2) P^T + sigma2 I)``
    acting on an observed-supported vector (P = row-selection of observed
    entries, implemented as mask instead of slicing to keep shapes static
    for AOT export).

    Args:
        k1: (n, n) hyper-parameter kernel matrix.
        k2: (m, m) progression kernel matrix (symmetric).
        mask: (n, m) observation mask in {0, 1}.
        sigma2: scalar noise variance.
        v: (..., n, m) input (batched over leading dims).

    Returns:
        (..., n, m) result of the masked operator.
    """
    mv = mask * v
    w = jnp.einsum("ij,...jm->...im", k1, mv)
    w = jnp.einsum("...im,mk->...ik", w, k2)
    return mask * w + sigma2 * v


def kron_mvm(k1: jnp.ndarray, k2: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Plain Kronecker MVM ``(K1 x K2) vec(V)`` in row-major layout.

    With V of shape (n, m) indexed row-major, (K1 x K2) vec(V) reshapes to
    ``K1 V K2^T`` (= ``K1 V K2`` for symmetric K2).
    """
    w = jnp.einsum("ij,...jm->...im", k1, v)
    return jnp.einsum("...im,mk->...ik", w, k2.T)


def dense_joint_kernel(
    k1: jnp.ndarray, k2: jnp.ndarray, mask: jnp.ndarray, sigma2: jnp.ndarray
) -> jnp.ndarray:
    """Dense full-space operator matrix (for oracle tests only).

    Returns the (n*m, n*m) matrix of the masked operator
    ``diag(m) (K1 x K2) diag(m) + sigma2 I`` with row-major vec layout.
    """
    n = k1.shape[0]
    m = k2.shape[0]
    kk = jnp.kron(k1, k2)
    dm = mask.reshape(n * m)
    return dm[:, None] * kk * dm[None, :] + sigma2 * jnp.eye(n * m, dtype=kk.dtype)
