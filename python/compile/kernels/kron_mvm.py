"""L1 Pallas kernel: masked latent-Kronecker matrix-vector product.

The paper's inference hot spot is

    A v = M . (K1 (M . V) K2) + sigma2 * V          (".": elementwise)

i.e. the full-space embedding of ``(P (K1 x K2) P^T + sigma2 I) v`` — two
dense matmuls with a mask applied before the first and after the second.
One CG iteration performs exactly one such MVM, so everything else in the
solver is O(nm) vector work.

TPU mapping (DESIGN.md §Hardware-Adaptation): the paper runs this as cuBLAS
GEMMs on a V100. On TPU the natural shape is two MXU matmul pipelines with
the mask multiply and sigma2-shift fused into the epilogues. We express the
HBM<->VMEM schedule with BlockSpecs: the output tile (bi, bj) accumulates
over the contraction grid axis, K tiles stream while the V tile stays
resident. On this image Pallas must run ``interpret=True`` (the CPU PJRT
plugin cannot execute Mosaic custom calls), so these kernels are validated
for correctness here and their VMEM/MXU characteristics are analyzed
statically (EXPERIMENTS.md §Perf).

Both matmuls are instances of one generic tiled kernel with optional
pre-mask, post-mask, and axpy epilogue; ``masked_kron_mvm`` composes them:

    W   = (M . V) @ K2        -- pre-mask on the left operand
    out = M . (K1 @ W) + sigma2 * V   -- post-mask + shift epilogue
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref, *, nk: int):
    """Tiled matmul body: o[bi, bj] += x[bi, k] @ y[k, bj] over grid axis k."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += x_ref[...] @ y_ref[...]


def _matmul_mask_lhs_kernel(x_ref, m_ref, y_ref, o_ref, *, nk: int):
    """Tiled matmul with the left operand masked: o += (m . x) @ y."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += (m_ref[...] * x_ref[...]) @ y_ref[...]


def _matmul_mask_shift_kernel(x_ref, y_ref, m_ref, v_ref, s_ref, o_ref, *, nk: int):
    """Tiled matmul with fused epilogue: o = m . (x @ y) + s * v.

    The mask/shift epilogue only fires on the last contraction step, so the
    accumulator never round-trips to HBM between steps.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += x_ref[...] @ y_ref[...]

    @pl.when(k == nk - 1)
    def _epilogue():
        o_ref[...] = m_ref[...] * o_ref[...] + s_ref[0] * v_ref[...]


def _block(size: int, tile: int) -> int:
    """Largest tile that divides ``size`` and is at most ``tile``."""
    b = min(size, tile)
    while size % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bi", "bj", "bk"))
def matmul_masked_lhs(x, mask, y, *, bi=64, bj=64, bk=64):
    """Pallas ``(mask . x) @ y`` with tiles (bi, bk) x (bk, bj).

    Args:
        x: (n, k) left operand.
        mask: (n, k) elementwise mask for the left operand.
        y: (k, m) right operand.

    Returns:
        (n, m) product.
    """
    n, kk = x.shape
    _, m = y.shape
    bi = _block(n, bi)
    bj = _block(m, bj)
    bk = _block(kk, bk)
    nk = kk // bk
    grid = (n // bi, m // bj, nk)
    return pl.pallas_call(
        functools.partial(_matmul_mask_lhs_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bi, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bi, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bj), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), x.dtype),
        interpret=True,
    )(x, mask, y)


@functools.partial(jax.jit, static_argnames=("bi", "bj", "bk"))
def matmul_mask_shift(x, y, mask, v, sigma2, *, bi=64, bj=64, bk=64):
    """Pallas ``mask . (x @ y) + sigma2 * v`` with a fused epilogue.

    Args:
        x: (n, k) left operand.
        y: (k, m) right operand.
        mask: (n, m) output mask.
        v: (n, m) shift operand.
        sigma2: scalar shift coefficient, shaped (1,).

    Returns:
        (n, m) result.
    """
    n, kk = x.shape
    _, m = y.shape
    bi = _block(n, bi)
    bj = _block(m, bj)
    bk = _block(kk, bk)
    nk = kk // bk
    grid = (n // bi, m // bj, nk)
    return pl.pallas_call(
        functools.partial(_matmul_mask_shift_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bi, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bj), lambda i, j, k: (k, j)),
            pl.BlockSpec((bi, bj), lambda i, j, k: (i, j)),
            pl.BlockSpec((bi, bj), lambda i, j, k: (i, j)),
            pl.BlockSpec((1,), lambda i, j, k: (0,)),
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), x.dtype),
        interpret=True,
    )(x, y, mask, v, sigma2)


def masked_kron_mvm(k1, k2, mask, sigma2, v, *, tile=64):
    """Masked latent-Kronecker MVM via two tiled Pallas matmuls.

    Computes ``M . (K1 (M . V) K2) + sigma2 * V`` (see ref.masked_kron_mvm).

    Args:
        k1: (n, n) config kernel matrix.
        k2: (m, m) progression kernel matrix (symmetric).
        mask: (n, m) observation mask.
        sigma2: scalar noise variance (python float, 0-d or (1,) array).
        v: (n, m) or (b, n, m) input.

    Returns:
        Result with the same shape as ``v``.
    """
    s = jnp.asarray(sigma2, dtype=k1.dtype).reshape((1,))

    def one(vi):
        w = matmul_masked_lhs(vi, mask, k2, bi=tile, bj=tile, bk=tile)
        return matmul_mask_shift(k1, w, mask, vi, s, bi=tile, bj=tile, bk=tile)

    if v.ndim == 2:
        return one(v)
    return jax.vmap(one)(v)
