#!/usr/bin/env python3
"""Regenerate the lcbench_mini fixture corpus (deterministic).

12 LCBench-shaped tasks: 10 configs x up to 20 epochs of validation
accuracy, d = 7 hyper-parameters in plausible LCBench ranges, saturating
power-law curves with config-dependent asymptotes, and EARLY-STOPPED rows
(ragged curve lengths) like a real dump of a freeze-thaw run. Values are
rounded to 6 decimals so the JSON is small and byte-stable.

Uses a hand-rolled 64-bit LCG (no `random` module) so the output is
identical on every Python version/platform. Run from the repo root:

    python3 data/lcbench_mini/generate.py

Tests, the ingest bench, and the record/replay smoke consume these files;
regenerating them changes the corpus fingerprint, so any recorded trace
pinned to the old bytes will (correctly) refuse to replay.
"""
import json
import os

MULT = 6364136223846793005
INC = 1442695040888963407
MASK = (1 << 64) - 1


class Lcg:
    def __init__(self, seed):
        self.state = (seed * 2862933555777941757 + 3037000493) & MASK

    def next_u64(self):
        self.state = (self.state * MULT + INC) & MASK
        return self.state

    def uniform(self):
        return (self.next_u64() >> 11) / float(1 << 53)

    def uniform_in(self, lo, hi):
        return lo + (hi - lo) * self.uniform()


TASKS = 12
CONFIGS = 10
MAX_EPOCHS = 20


def gen_task(t):
    rng = Lcg(1000 + t)
    # per-task accuracy regime (fashion-mnist-ish .. higgs-ish)
    floor = 0.10 + 0.04 * (t % 3)
    a_center = 0.60 + 0.03 * (t % 5)
    configs, curves = [], []
    for i in range(CONFIGS):
        log_lr = rng.uniform_in(-4.0, -1.0)
        batch = rng.uniform_in(4.0, 9.0)
        momentum = rng.uniform_in(0.1, 0.99)
        weight_decay = rng.uniform_in(-5.0, -2.0)
        layers = rng.uniform_in(1.0, 5.0)
        units = rng.uniform_in(4.0, 10.0)
        dropout = rng.uniform_in(0.0, 0.8)
        configs.append([round(v, 6) for v in
                        (log_lr, batch, momentum, weight_decay, layers, units, dropout)])
        quality = max(-1.0, min(1.0, 1.0 - ((log_lr + 2.5) / 1.5) ** 2
                                - 0.3 * (dropout - 0.4) ** 2))
        a_inf = min(0.97, a_center + 0.08 * quality)
        a_0 = floor + 0.05 * rng.uniform()
        tau = 1.0 + 6.0 * rng.uniform()
        beta = rng.uniform_in(0.7, 1.5)
        # early stopping: ~half the configs stop before the full grid,
        # mimicking a freeze-thaw scheduler's pause/stop decisions
        if i % 2 == 1:
            length = 3 + (i * 5 + t * 3) % (MAX_EPOCHS - 6)
        else:
            length = MAX_EPOCHS
        row = []
        for j in range(length):
            e = j + 1
            acc = a_inf - (a_inf - a_0) * (1.0 + e / tau) ** (-beta)
            acc += 0.004 * (rng.uniform() - 0.5)
            row.append(round(max(0.0, min(1.0, acc)), 6))
        curves.append(row)
    return {
        "name": "lcbench_mini_%02d" % t,
        "ids": list(range(CONFIGS)),
        "configs": configs,
        "curves": curves,
    }


def main():
    out_dir = os.path.dirname(os.path.abspath(__file__))
    for t in range(TASKS):
        task = gen_task(t)
        path = os.path.join(out_dir, "task_%02d.json" % t)
        with open(path, "w") as f:
            json.dump(task, f, separators=(",", ":"))
            f.write("\n")
        print("wrote", path)


if __name__ == "__main__":
    main()
