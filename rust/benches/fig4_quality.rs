//! Figure 4 reproduction: MSE and log-likelihood of predicted final
//! validation accuracy given partially observed learning curves.
//!
//! Protocol (paper §3 + Rakotoarison et al. 2024 §5.1): per task, draw a
//! set of curves with random observation cutoffs (total observed values =
//! the "# of training examples" axis), predict each partially observed
//! curve's final-epoch value, score MSE and Gaussian LLH in original
//! units, aggregate mean ± standard error over seeds.
//!
//! Methods: LKGP (ours, both engines), power-law ensemble (DPL stand-in),
//! per-curve GP (no cross-config correlations — the FT-PFN (no HPs) /
//! DyHPO axis), last-value. FT-PFN itself cannot be re-pretrained offline
//! (see DESIGN.md §Substitutions).
//!
//! Output: results/fig4_quality.csv (+ stdout table).
//! Flags: --quick (fewer seeds/budgets), --seeds N, --curves K, --xla.

use lkgp::baselines::{FinalPredictor, LastValue, PerCurveGp, PowerLawEnsemble};
use lkgp::bench_util::Table;
use lkgp::gp::Theta;
use lkgp::lcbench::{build_problem, PartialView, Preset, Task};
use lkgp::linalg::Matrix;
use lkgp::metrics::{gaussian_llh, mean_stderr, mse};
use lkgp::rng::Pcg64;
use lkgp::runtime::{Engine, RustEngine};
use lkgp::util::Args;

fn main() -> lkgp::Result<()> {
    let args = Args::from_env();
    let quick = lkgp::bench_util::is_quick();
    // paper protocol: 100 seeds (pass --seeds 100); default bounded for 1 core
    let seeds = args.get_usize("seeds", if quick { 5 } else { 15 });
    let curves = args.get_usize("curves", 24);
    let task_size = args.get_usize("task-size", 200);
    let budgets: Vec<usize> = if quick {
        vec![100, 300]
    } else {
        vec![50, 100, 200, 400, 800]
    };

    let mut table = Table::new(&[
        "task", "train_examples", "method", "mse_mean", "mse_stderr", "llh_mean", "llh_stderr",
    ]);

    for preset in Preset::all() {
        let mut task_rng = Pcg64::new(42);
        let task = Task::generate(preset, task_size, &mut task_rng);

        for &budget in &budgets {
            // per-method metric accumulators over seeds
            let mut results: std::collections::BTreeMap<&str, (Vec<f64>, Vec<f64>)> =
                Default::default();

            for seed in 0..seeds {
                let mut rng = Pcg64::new(1000 + seed as u64);
                let view = PartialView::sample(&task, curves, budget, &mut rng);
                let problem = build_problem(&task, &view);

                // raw-space inputs for the baselines
                let k = view.config_idx.len();
                let m = task.m();
                let mut raw = Matrix::zeros(k, m);
                for (row, &ci) in view.config_idx.iter().enumerate() {
                    raw.row_mut(row).copy_from_slice(task.curves.row(ci));
                }

                // ---- LKGP (rust engine; exact predictive variance) ----
                {
                    let mut eng = RustEngine::default();
                    let theta0 = Theta::default_packed(problem.data.d());
                    let theta = eng.fit(&theta0, &problem.data, seed as u64)?;
                    let preds = eng.predict_final(&theta, &problem.data, &problem.xq)?;
                    score("lkgp", &preds, &problem, &mut results);
                }

                // ---- LKGP through AOT artifacts ----
                #[cfg(feature = "xla")]
                if args.has("xla") {
                    if let Ok(mut eng) = lkgp::runtime::XlaEngine::load(
                        &lkgp::runtime::artifacts_dir(),
                    ) {
                        if eng
                            .manifest()
                            .pick("fit_adam", problem.data.n(), problem.data.m(), problem.data.d())
                            .is_ok()
                        {
                            let theta0 = Theta::default_packed(problem.data.d());
                            let theta = eng.fit(&theta0, &problem.data, seed as u64)?;
                            let preds = eng.predict_final(&theta, &problem.data, &problem.xq)?;
                            score("lkgp_xla", &preds, &problem, &mut results);
                        }
                    }
                }

                // ---- baselines on raw prefixes ----
                let mut pl = PowerLawEnsemble { members: 8, seed: seed as u64 };
                let preds = pl.predict(&raw, &view.lengths, &task.epochs);
                score_raw("power_law", &preds, &problem, &mut results);

                let mut pg = PerCurveGp::default();
                let preds = pg.predict(&raw, &view.lengths, &task.epochs);
                score_raw("percurve_gp", &preds, &problem, &mut results);

                let preds = LastValue.predict(&raw, &view.lengths, &task.epochs);
                score_raw("last_value", &preds, &problem, &mut results);
            }

            for (method, (mses, llhs)) in &results {
                let (mm, ms) = mean_stderr(mses);
                let (lm, ls) = mean_stderr(llhs);
                table.row(vec![
                    task.name.clone(),
                    budget.to_string(),
                    method.to_string(),
                    format!("{mm:.6}"),
                    format!("{ms:.6}"),
                    format!("{lm:.4}"),
                    format!("{ls:.4}"),
                ]);
            }
        }
    }

    table.write_csv("results/fig4_quality.csv")?;
    println!("\nwrote results/fig4_quality.csv");
    Ok(())
}

/// Score LKGP predictions (standardized units -> original units).
fn score(
    name: &'static str,
    preds: &[(f64, f64)],
    problem: &lkgp::lcbench::ModelProblem,
    results: &mut std::collections::BTreeMap<&'static str, (Vec<f64>, Vec<f64>)>,
) {
    let means: Vec<f64> = preds.iter().map(|p| problem.ytf.undo_mean(p.0)).collect();
    let pairs: Vec<(f64, f64)> = preds
        .iter()
        .map(|p| (problem.ytf.undo_mean(p.0), problem.ytf.undo_var(p.1)))
        .collect();
    let e = results.entry(name).or_default();
    e.0.push(mse(&means, &problem.targets));
    e.1.push(gaussian_llh(&pairs, &problem.targets));
}

/// Score baseline predictions (already in original units).
fn score_raw(
    name: &'static str,
    preds: &[(f64, f64)],
    problem: &lkgp::lcbench::ModelProblem,
    results: &mut std::collections::BTreeMap<&'static str, (Vec<f64>, Vec<f64>)>,
) {
    let means: Vec<f64> = preds.iter().map(|p| p.0).collect();
    let e = results.entry(name).or_default();
    e.0.push(mse(&means, &problem.targets));
    e.1.push(gaussian_llh(preds, &problem.targets));
}
