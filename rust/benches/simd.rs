//! Data-parallel + mixed-precision compute-core bench (`ci.sh` `par`
//! gate):
//!
//! * thread parity — the batched masked-Kronecker MVM and a full PCG
//!   solve must be *bit-identical* across worker-team widths on the f64
//!   path (pinned in-process at 1/2/N threads)
//! * batched-MVM speedup — the worker team must clear a 1.5x floor at 4
//!   threads over the sequential path (skipped, with
//!   `speedup_measured: false`, on boxes with < 4 cores — the gate then
//!   passes vacuously and says so)
//! * f32 + iterative refinement — the mixed-precision solve must land
//!   within tolerance of the f64 oracle while converging on the *exact*
//!   operator's residual
//!
//! Besides BENCH_simd.json / results/simd.csv, the bench prints one
//! `PAR_CHECKSUM <hex>` line: an FNV-1a digest over the result bits of an
//! MVM + solve run at the *ambient* `util::num_threads()`. ci.sh runs the
//! bench twice (LKGP_THREADS=1 and =4) and compares the lines — the
//! cross-process half of the determinism contract (docs/parallelism.md).

use std::time::Duration;

use lkgp::bench_util::{bench, Table};
use lkgp::gp::kernels;
use lkgp::gp::operator::{MaskedKronOp, MaskedKronOpF32};
use lkgp::gp::Theta;
use lkgp::json::Json;
use lkgp::lcbench::fig3_dataset;
use lkgp::linalg::{pcg_batch_warm, refined_solve, LinOp};
use lkgp::rng::Pcg64;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv_bits(values: &[f64], mut h: u64) -> u64 {
    for v in values {
        for b in v.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    }
    h
}

/// `LinOp` adapter pinning the operator's worker-thread count.
struct PinnedOp<'a> {
    op: &'a MaskedKronOp<'a>,
    threads: usize,
}

impl LinOp for PinnedOp<'_> {
    fn len(&self) -> usize {
        self.op.len()
    }

    fn apply_batch(&self, x: &[f64], out: &mut [f64], batch: usize) {
        self.op.apply_batch_with_threads(x, out, batch, self.threads);
    }
}

fn main() -> lkgp::Result<()> {
    let quick = lkgp::bench_util::is_quick();
    let nn = if quick { 96 } else { 192 };
    let batch = if quick { 8 } else { 16 };
    let mut table = Table::new(&["op", "threads", "median_us", "note"]);

    let mut rng = Pcg64::new(nn as u64);
    let data = fig3_dataset(nn, &mut rng);
    let theta = Theta::unpack(&Theta::default_packed(10));
    let k1 = kernels::rbf(&data.x, &data.x, &theta.lengthscales);
    let k2 = kernels::matern12(&data.t, &data.t, theta.t_lengthscale, theta.outputscale);
    let op = MaskedKronOp::new(&k1, &k2, &data.mask, theta.sigma2);
    let nm = op.len();
    let x = rng.normal_vec(batch * nm);

    // ---- (a) f64 MVM parity across pinned thread counts ------------------
    let mut base = vec![0.0; batch * nm];
    op.apply_batch_with_threads(&x, &mut base, batch, 1);
    let ambient = lkgp::util::num_threads();
    let mut parity_mvm = true;
    for threads in [2usize, 4, ambient.max(2)] {
        let mut out = vec![0.0; batch * nm];
        op.apply_batch_with_threads(&x, &mut out, batch, threads);
        let ok = out.iter().zip(&base).all(|(a, b)| a.to_bits() == b.to_bits());
        parity_mvm &= ok;
        table.row(vec![
            "mvm_parity".into(),
            threads.to_string(),
            "-".into(),
            if ok { "bitwise==T1".into() } else { "DIVERGED".into() },
        ]);
    }

    // ---- (b) f64 PCG solve parity across pinned thread counts ------------
    let solve_batch = 3usize;
    let b = rng.normal_vec(solve_batch * nm);
    let p1 = PinnedOp { op: &op, threads: 1 };
    let (x1, s1) = pcg_batch_warm(&p1, &b, None, None, 1e-6, 2000);
    let mut parity_solve = s1.converged;
    for threads in [2usize, 4] {
        let pt = PinnedOp { op: &op, threads };
        let (xt, st) = pcg_batch_warm(&pt, &b, None, None, 1e-6, 2000);
        let ok = st.iters == s1.iters
            && xt.iter().zip(&x1).all(|(a, c)| a.to_bits() == c.to_bits());
        parity_solve &= ok;
        table.row(vec![
            "solve_parity".into(),
            threads.to_string(),
            "-".into(),
            if ok { "bitwise==T1".into() } else { "DIVERGED".into() },
        ]);
    }

    // ---- (c) batched-MVM speedup: 1 thread vs 4 --------------------------
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let speedup_measured = cores >= 4;
    let (t1_us, t4_us, speedup) = {
        let mut out = vec![0.0; batch * nm];
        let s1 = bench(
            || op.apply_batch_with_threads(&x, &mut out, batch, 1),
            3,
            Duration::from_millis(300),
        );
        let s4 = bench(
            || op.apply_batch_with_threads(&x, &mut out, batch, 4),
            3,
            Duration::from_millis(300),
        );
        (
            s1.median_secs() * 1e6,
            s4.median_secs() * 1e6,
            s1.median_secs() / s4.median_secs().max(1e-12),
        )
    };
    table.row(vec![
        "mvm_batched".into(),
        "1".into(),
        format!("{t1_us:.1}"),
        format!("batch={batch}"),
    ]);
    table.row(vec![
        "mvm_batched".into(),
        "4".into(),
        format!("{t4_us:.1}"),
        format!("speedup={speedup:.2}x"),
    ]);
    let speedup_ok = if speedup_measured {
        speedup >= 1.5
    } else {
        eprintln!(
            "warning: only {cores} core(s) available — the 4-thread speedup floor cannot be \
             measured here; BENCH_simd.json records speedup_measured=false and the gate \
             passes vacuously (run on a >=4-core box for a real measurement)"
        );
        true
    };

    // ---- (d) f32 + iterative refinement vs the f64 oracle ----------------
    let fast = MaskedKronOpF32::from_op(&op);
    let rb = rng.normal_vec(2 * nm);
    let (oracle, os) = pcg_batch_warm(&op, &rb, None, None, 1e-10, 4000);
    let (xr, rs) = refined_solve(&op, &fast, &rb, None, None, 1e-8, 1e-4, 10, 2000);
    let scale = oracle.iter().fold(1.0f64, |a, &v| a.max(v.abs()));
    let max_err = xr
        .iter()
        .zip(&oracle)
        .map(|(a, c)| (a - c).abs())
        .fold(0.0f64, f64::max);
    let refine_ok = os.converged && rs.converged && max_err < 1e-5 * scale;
    table.row(vec![
        "f32_refined".into(),
        "-".into(),
        "-".into(),
        format!(
            "outer={} inner={} max_err={max_err:.2e}",
            rs.outer_iters, rs.inner_iters
        ),
    ]);

    // ---- PAR_CHECKSUM: ambient-thread-count result digest ----------------
    // ci.sh compares this line across LKGP_THREADS=1 / =4 runs.
    let mut amb = vec![0.0; batch * nm];
    op.apply_batch_with_threads(&x, &mut amb, batch, ambient);
    let pamb = PinnedOp { op: &op, threads: ambient };
    let (xa, _) = pcg_batch_warm(&pamb, &b, None, None, 1e-6, 2000);
    let checksum = fnv_bits(&xa, fnv_bits(&amb, FNV_OFFSET));
    println!("PAR_CHECKSUM {checksum:016x}");

    table.write_csv("results/simd.csv")?;
    println!("\nwrote results/simd.csv");

    let summary = Json::obj(vec![
        ("bench", Json::Str("simd".into())),
        ("n", Json::Num(nn as f64)),
        ("batch", Json::Num(batch as f64)),
        ("cores", Json::Num(cores as f64)),
        ("ambient_threads", Json::Num(ambient as f64)),
        ("mvm_t1_us", Json::Num(t1_us)),
        ("mvm_t4_us", Json::Num(t4_us)),
        ("mvm_speedup_4t", Json::Num(speedup)),
        ("speedup_measured", Json::Bool(speedup_measured)),
        ("refine_outer_iters", Json::Num(rs.outer_iters as f64)),
        ("refine_inner_iters", Json::Num(rs.inner_iters as f64)),
        ("refine_max_err", Json::Num(max_err)),
        ("par_checksum", Json::Str(format!("{checksum:016x}"))),
        ("assert_par_parity_mvm", Json::Bool(parity_mvm)),
        ("assert_par_parity_solve", Json::Bool(parity_solve)),
        ("assert_simd_speedup", Json::Bool(speedup_ok)),
        ("assert_f32_refine_parity", Json::Bool(refine_ok)),
    ]);
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap_or_else(|| std::path::Path::new("."))
        .to_path_buf();
    std::fs::write(root.join("BENCH_simd.json"), summary.pretty())?;
    println!("wrote {}", root.join("BENCH_simd.json").display());
    Ok(())
}
