//! Hot-path micro benches for the §Perf optimization loop:
//!
//! * masked-Kronecker MVM (the paper's core op) across sizes — rust
//!   engine and (optionally) the Pallas-backed XLA artifact
//! * batched CG per-iteration cost
//! * panel-parallel matmul GFLOP/s (the rust roofline anchor)
//! * Matheron sampling end-to-end
//!
//! Output: results/hotpath.csv. Flags: --quick, --xla.

use lkgp::bench_util::{bench, Table};
use lkgp::gp::kernels;
use lkgp::gp::operator::MaskedKronOp;
use lkgp::gp::Theta;
use lkgp::lcbench::fig3_dataset;
use lkgp::linalg::{LinOp, Matrix};
use lkgp::rng::Pcg64;
use lkgp::util::Args;

fn main() -> lkgp::Result<()> {
    let args = Args::from_env();
    let quick = lkgp::bench_util::is_quick();
    let sizes: Vec<usize> = if quick {
        vec![64, 128]
    } else {
        vec![64, 128, 256, 512]
    };
    let with_xla = args.has("xla");
    let mut table = Table::new(&["op", "size", "median_us", "gflops"]);

    // ---- raw matmul roofline anchor ----
    for &nn in &sizes {
        let mut rng = Pcg64::new(nn as u64);
        let a = Matrix::from_vec(nn, nn, rng.normal_vec(nn * nn));
        let b = Matrix::from_vec(nn, nn, rng.normal_vec(nn * nn));
        let mut out = Matrix::zeros(nn, nn);
        let stats = bench(
            || a.matmul_into(&b, &mut out),
            5,
            std::time::Duration::from_millis(200),
        );
        let flops = 2.0 * (nn as f64).powi(3);
        table.row(vec![
            "matmul".into(),
            nn.to_string(),
            format!("{:.1}", stats.median_secs() * 1e6),
            format!("{:.2}", flops / stats.median_secs() / 1e9),
        ]);
    }

    // ---- masked Kronecker MVM ----
    for &nn in &sizes {
        let mut rng = Pcg64::new(nn as u64);
        let data = fig3_dataset(nn, &mut rng);
        let theta = Theta::unpack(&Theta::default_packed(10));
        let k1 = kernels::rbf(&data.x, &data.x, &theta.lengthscales);
        let k2 = kernels::matern12(&data.t, &data.t, theta.t_lengthscale, theta.outputscale);
        let op = MaskedKronOp::new(&k1, &k2, &data.mask, theta.sigma2);
        let v = rng.normal_vec(nn * nn);
        let mut out = vec![0.0; nn * nn];
        let stats = bench(
            || op.apply_batch(&v, &mut out, 1),
            5,
            std::time::Duration::from_millis(200),
        );
        let flops = 4.0 * (nn as f64).powi(3); // two n^2 m + n m^2 matmuls, n=m
        table.row(vec![
            "kron_mvm".into(),
            nn.to_string(),
            format!("{:.1}", stats.median_secs() * 1e6),
            format!("{:.2}", flops / stats.median_secs() / 1e9),
        ]);
    }

    // ---- MVM through the Pallas-backed artifact ----
    if with_xla {
        if let Ok(mut eng) =
            lkgp::runtime::XlaEngine::load(&lkgp::runtime::XlaEngine::default_dir())
        {
            for &nn in &sizes {
                let mut rng = Pcg64::new(nn as u64);
                let data = fig3_dataset(nn, &mut rng);
                if eng.manifest().pick("mvm", nn, nn, 10).is_err() {
                    continue;
                }
                let theta = Theta::default_packed(10);
                let v = Matrix::from_vec(nn, nn, rng.normal_vec(nn * nn));
                let stats = bench(
                    || {
                        let _ = eng.mvm(&theta, &data, &v).unwrap();
                    },
                    3,
                    std::time::Duration::from_millis(200),
                );
                let flops = 4.0 * (nn as f64).powi(3);
                table.row(vec![
                    "kron_mvm_xla".into(),
                    nn.to_string(),
                    format!("{:.1}", stats.median_secs() * 1e6),
                    format!("{:.2}", flops / stats.median_secs() / 1e9),
                ]);
            }
        }
    }

    // ---- one batched CG solve (17 RHS like training) ----
    for &nn in &sizes {
        if nn > 256 {
            continue; // keep bench wall time bounded
        }
        let mut rng = Pcg64::new(nn as u64);
        let data = fig3_dataset(nn, &mut rng);
        let theta = Theta::unpack(&Theta::default_packed(10));
        let k1 = kernels::rbf(&data.x, &data.x, &theta.lengthscales);
        let k2 = kernels::matern12(&data.t, &data.t, theta.t_lengthscale, theta.outputscale);
        let op = MaskedKronOp::new(&k1, &k2, &data.mask, theta.sigma2);
        let rhs = rng.normal_vec(17 * nn * nn);
        let stats = bench(
            || {
                let _ = op.solve(&rhs, 1e-2, 10_000);
            },
            2,
            std::time::Duration::from_millis(200),
        );
        table.row(vec![
            "cg_solve_b17".into(),
            nn.to_string(),
            format!("{:.1}", stats.median_secs() * 1e6),
            "-".into(),
        ]);
    }

    table.write_csv("results/hotpath.csv")?;
    println!("\nwrote results/hotpath.csv");
    Ok(())
}
