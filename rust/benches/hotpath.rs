//! Hot-path micro benches for the §Perf optimization loop:
//!
//! * masked-Kronecker MVM (the paper's core op) across sizes — rust
//!   engine and (optionally) the Pallas-backed XLA artifact
//! * batched CG per-iteration cost
//! * panel-parallel matmul GFLOP/s (the rust roofline anchor)
//! * warm-started vs cold CG on an incremental-mask refit (the
//!   scheduler's generation-to-generation workload)
//! * 4-shard ServicePool vs 4 isolated single-task services on the same
//!   worker-thread budget (aggregate PredictFinal throughput)
//!
//! Output: results/hotpath.csv + BENCH_hotpath.json at the repo root (the
//! perf-trajectory record). Flags: --quick, --xla.

use std::sync::mpsc::channel;
use std::time::Instant;

use lkgp::bench_util::{bench, Table};
use lkgp::coordinator::{
    CurveStore, PoolCfg, PredictionService, Registry, Request, ServicePool, Snapshot,
};
use lkgp::gp::kernels;
use lkgp::gp::Theta;
use lkgp::json::Json;
use lkgp::lcbench::{fig3_dataset, toy_dataset};
use lkgp::linalg::{LinOp, Matrix};
use lkgp::rng::Pcg64;
use lkgp::runtime::{Engine, RustEngine};
use lkgp::util::Args;

fn main() -> lkgp::Result<()> {
    let args = Args::from_env();
    let quick = lkgp::bench_util::is_quick();
    let sizes: Vec<usize> = if quick {
        vec![64, 128]
    } else {
        vec![64, 128, 256, 512]
    };
    let mut table = Table::new(&["op", "size", "median_us", "gflops"]);

    // ---- raw matmul roofline anchor ----
    for &nn in &sizes {
        let mut rng = Pcg64::new(nn as u64);
        let a = Matrix::from_vec(nn, nn, rng.normal_vec(nn * nn));
        let b = Matrix::from_vec(nn, nn, rng.normal_vec(nn * nn));
        let mut out = Matrix::zeros(nn, nn);
        let stats = bench(
            || a.matmul_into(&b, &mut out),
            5,
            std::time::Duration::from_millis(200),
        );
        let flops = 2.0 * (nn as f64).powi(3);
        table.row(vec![
            "matmul".into(),
            nn.to_string(),
            format!("{:.1}", stats.median_secs() * 1e6),
            format!("{:.2}", flops / stats.median_secs() / 1e9),
        ]);
    }

    // ---- masked Kronecker MVM ----
    for &nn in &sizes {
        let mut rng = Pcg64::new(nn as u64);
        let data = fig3_dataset(nn, &mut rng);
        let theta = Theta::unpack(&Theta::default_packed(10));
        let k1 = kernels::rbf(&data.x, &data.x, &theta.lengthscales);
        let k2 = kernels::matern12(&data.t, &data.t, theta.t_lengthscale, theta.outputscale);
        let op = lkgp::gp::operator::MaskedKronOp::new(&k1, &k2, &data.mask, theta.sigma2);
        let v = rng.normal_vec(nn * nn);
        let mut out = vec![0.0; nn * nn];
        let stats = bench(
            || op.apply_batch(&v, &mut out, 1),
            5,
            std::time::Duration::from_millis(200),
        );
        let flops = 4.0 * (nn as f64).powi(3); // two n^2 m + n m^2 matmuls, n=m
        table.row(vec![
            "kron_mvm".into(),
            nn.to_string(),
            format!("{:.1}", stats.median_secs() * 1e6),
            format!("{:.2}", flops / stats.median_secs() / 1e9),
        ]);
    }

    // ---- MVM through the Pallas-backed artifact ----
    #[cfg(feature = "xla")]
    if args.has("xla") {
        if let Ok(mut eng) = lkgp::runtime::XlaEngine::load(&lkgp::runtime::artifacts_dir()) {
            for &nn in &sizes {
                let mut rng = Pcg64::new(nn as u64);
                let data = fig3_dataset(nn, &mut rng);
                if eng.manifest().pick("mvm", nn, nn, 10).is_err() {
                    continue;
                }
                let theta = Theta::default_packed(10);
                let v = Matrix::from_vec(nn, nn, rng.normal_vec(nn * nn));
                let stats = bench(
                    || {
                        let _ = eng.mvm(&theta, &data, &v).unwrap();
                    },
                    3,
                    std::time::Duration::from_millis(200),
                );
                let flops = 4.0 * (nn as f64).powi(3);
                table.row(vec![
                    "kron_mvm_xla".into(),
                    nn.to_string(),
                    format!("{:.1}", stats.median_secs() * 1e6),
                    format!("{:.2}", flops / stats.median_secs() / 1e9),
                ]);
            }
        }
    }
    #[cfg(not(feature = "xla"))]
    let _ = &args;

    // ---- one batched CG solve (17 RHS like training) ----
    for &nn in &sizes {
        if nn > 256 {
            continue; // keep bench wall time bounded
        }
        let mut rng = Pcg64::new(nn as u64);
        let data = fig3_dataset(nn, &mut rng);
        let theta = Theta::unpack(&Theta::default_packed(10));
        let k1 = kernels::rbf(&data.x, &data.x, &theta.lengthscales);
        let k2 = kernels::matern12(&data.t, &data.t, theta.t_lengthscale, theta.outputscale);
        let op = lkgp::gp::operator::MaskedKronOp::new(&k1, &k2, &data.mask, theta.sigma2);
        let rhs = rng.normal_vec(17 * nn * nn);
        let stats = bench(
            || {
                let _ = op.solve(&rhs, 1e-2, 10_000);
            },
            2,
            std::time::Duration::from_millis(200),
        );
        table.row(vec![
            "cg_solve_b17".into(),
            nn.to_string(),
            format!("{:.1}", stats.median_secs() * 1e6),
            "-".into(),
        ]);
    }

    // ---- warm vs cold CG on an incremental-mask refit ----
    let (cold_iters, warm_iters, cold_total, warm_total) = warm_vs_cold_refit(&mut table);

    // ---- preconditioned vs plain CG at two condition regimes ----
    let pcg_json = pcg_vs_plain(&mut table);

    // ---- multi-query amortization through the session API ----
    let queries_json = queries_amortization(&mut table);

    // ---- read-only replica shards vs the serialized single-shard path ----
    let replicas_json = replica_burst(&mut table);

    // ---- corpus data plane: many-task admission + replay throughput ----
    let ingest_json = ingest_scale(&mut table, quick);

    // ---- seeded chaos soak: faults in, typed errors out, zero hangs ----
    let chaos_json = chaos_soak(&mut table, quick);

    // ---- 4-shard pool vs 4 isolated services, same thread budget ----
    let (pool_rps, isolated_rps) = pool_vs_isolated(&mut table, quick);

    table.write_csv("results/hotpath.csv")?;
    println!("\nwrote results/hotpath.csv");

    // ---- perf-trajectory record ----
    let summary = Json::obj(vec![
        ("bench", Json::Str("hotpath".into())),
        (
            "warm_cg",
            Json::obj(vec![
                ("n", Json::Num(64.0)),
                ("cold_iters_max", Json::Num(cold_iters as f64)),
                ("warm_iters_max", Json::Num(warm_iters as f64)),
                ("cold_iters_total", Json::Num(cold_total as f64)),
                ("warm_iters_total", Json::Num(warm_total as f64)),
            ]),
        ),
        (
            "serving",
            Json::obj(vec![
                ("tasks", Json::Num(4.0)),
                ("pool_rps", Json::Num(pool_rps)),
                ("isolated_rps", Json::Num(isolated_rps)),
                ("speedup", Json::Num(pool_rps / isolated_rps.max(1e-9))),
            ]),
        ),
    ]);
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap_or_else(|| std::path::Path::new("."))
        .to_path_buf();
    std::fs::write(root.join("BENCH_hotpath.json"), summary.pretty())?;
    println!("wrote {}", root.join("BENCH_hotpath.json").display());
    std::fs::write(root.join("BENCH_pcg.json"), pcg_json.pretty())?;
    println!("wrote {}", root.join("BENCH_pcg.json").display());
    std::fs::write(root.join("BENCH_queries.json"), queries_json.pretty())?;
    println!("wrote {}", root.join("BENCH_queries.json").display());
    std::fs::write(root.join("BENCH_replicas.json"), replicas_json.pretty())?;
    println!("wrote {}", root.join("BENCH_replicas.json").display());
    std::fs::write(root.join("BENCH_ingest.json"), ingest_json.pretty())?;
    println!("wrote {}", root.join("BENCH_ingest.json").display());
    std::fs::write(root.join("BENCH_chaos.json"), chaos_json.pretty())?;
    println!("wrote {}", root.join("BENCH_chaos.json").display());
    Ok(())
}

/// Seeded chaos soak over the sharded pool (the robustness tentpole):
/// shard 0 runs a clean engine, the remaining shards run `ChaosEngine`s
/// injecting panics, forced CG divergence, and slow solves from a fixed
/// `FaultPlan` seed, plus a leg of near-expired deadline requests. The
/// soak drives a mixed query/refit stream at every shard with a bounded
/// receive timeout and checks the contract the robustness layer promises.
/// The returned JSON carries the gates ci.sh enforces:
///
/// * `assert_chaos_no_lost_requests` — every submitted request resolved
///   (answer, typed error, or typed submit rejection) within the bound:
///   zero hangs, zero lost replies
/// * `assert_chaos_typed_errors_only` — every failure surfaced as a typed
///   `LkgpError` (Quarantined/Timeout/Solver/Io/Coordinator) and every
///   successful answer was finite — no NaN ever escaped
/// * `assert_chaos_healthy_parity`   — the clean shard's answers are
///   bit-identical to a chaos-free pool on the same queries
/// * `assert_chaos_recovered`        — faults actually fired and the
///   recovery machinery visibly engaged (panics recovered or solves
///   escalated): a soak that injects nothing proves nothing
fn chaos_soak(table: &mut Table, quick: bool) -> Json {
    use lkgp::coordinator::{Answer, PredictClient, Query};
    use lkgp::runtime::chaos::{ChaosEngine, ChaosStats, FaultPlan};
    use lkgp::LkgpError;
    use std::sync::atomic::Ordering;
    use std::sync::Arc;
    use std::time::Duration;

    let shards = 4usize;
    let reqs_per_shard = if quick { 6 } else { 14 };
    let recv_bound = Duration::from_secs(120);
    let plan = FaultPlan {
        seed: 7,
        panic_rate: 0.15,
        diverge_rate: 0.25,
        slow_rate: 0.10,
        slow_ms: 2,
        ..Default::default()
    };

    let chaos_stats = Arc::new(ChaosStats::default());
    let engines: Vec<Box<dyn Engine>> = (0..shards)
        .map(|s| {
            if s == 0 {
                Box::<RustEngine>::default() as Box<dyn Engine>
            } else {
                Box::new(ChaosEngine::new(
                    RustEngine::default(),
                    plan,
                    s as u64,
                    chaos_stats.clone(),
                )) as Box<dyn Engine>
            }
        })
        .collect();
    let pool = ServicePool::spawn(
        engines,
        PoolCfg { workers: shards, warm_start: false, ..Default::default() },
    );

    // one small generation per shard
    let snaps: Vec<Snapshot> = (0..shards)
        .map(|s| {
            let mut rng = Pcg64::new(90 + s as u64);
            let task =
                lkgp::lcbench::Task::generate(lkgp::lcbench::Preset::Airlines, 8, &mut rng);
            let mut reg = Registry::new();
            for i in 0..task.n() {
                let id = reg.add(task.configs.row(i).to_vec());
                for j in 0..3 + i % 3 {
                    reg.observe(id, task.curves[(i, j)], task.m()).unwrap();
                }
            }
            CurveStore::new(task.m()).snapshot(&reg).unwrap()
        })
        .collect();
    let theta = Theta::default_packed(lkgp::lcbench::DIMS);
    let query_for = |snap: &Snapshot, r: usize| Query::MeanAtFinal {
        xq: Matrix::from_vec(
            1,
            lkgp::lcbench::DIMS,
            snap.all_x.row(r % snap.all_x.rows()).to_vec(),
        ),
    };
    let finite_answer = |answers: &[Answer]| {
        answers.iter().all(|a| match a {
            Answer::Final(preds) => preds.iter().all(|(m, v)| m.is_finite() && v.is_finite()),
            _ => true,
        })
    };

    let t0 = Instant::now();
    let mut submitted = 0u64;
    let mut resolved = 0u64;
    let mut answered = 0u64;
    let mut typed_errors = 0u64;
    let mut untyped = 0u64;
    let mut nonfinite = 0u64;
    let mut receivers = Vec::new();
    for s in 0..shards {
        for r in 0..reqs_per_shard {
            submitted += 1;
            let (rtx, rrx) = channel();
            let query = Request::Query {
                snapshot: snaps[s].clone(),
                theta: theta.clone(),
                queries: vec![query_for(&snaps[s], r)],
                resp: rtx,
            };
            // every third request on a chaotic shard rides a tight deadline
            let req = if s > 0 && r % 3 == 2 {
                Request::Deadline {
                    deadline: Instant::now() + Duration::from_micros(200),
                    inner: Box::new(query),
                }
            } else {
                query
            };
            match pool.submit(s, req) {
                Ok(()) => receivers.push(rrx),
                Err(LkgpError::Quarantined { .. }) | Err(LkgpError::Coordinator(_)) => {
                    // typed fail-fast rejection IS a resolution
                    resolved += 1;
                    typed_errors += 1;
                }
                Err(_) => {
                    resolved += 1;
                    untyped += 1;
                }
            }
        }
    }
    for rrx in receivers {
        match rrx.recv_timeout(recv_bound) {
            Ok(Ok(answers)) => {
                resolved += 1;
                answered += 1;
                if !finite_answer(&answers) {
                    nonfinite += 1;
                }
            }
            Ok(Err(
                LkgpError::Solver { .. }
                | LkgpError::Timeout { .. }
                | LkgpError::Quarantined { .. }
                | LkgpError::Io(_)
                | LkgpError::Coordinator(_),
            )) => {
                resolved += 1;
                typed_errors += 1;
            }
            Ok(Err(_)) => {
                resolved += 1;
                untyped += 1;
            }
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                // reply channel dropped by a recovered panic: typed at the
                // client as a Coordinator "pool dropped request" error
                resolved += 1;
                typed_errors += 1;
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {} // a hang: unresolved
        }
    }
    let soak_secs = t0.elapsed().as_secs_f64();

    // healthy-shard parity against a chaos-free pool, cold solves
    let clean = ServicePool::spawn(
        vec![Box::<RustEngine>::default() as Box<dyn Engine>],
        PoolCfg { workers: 1, warm_start: false, ..Default::default() },
    );
    let parity_queries: Vec<Query> = (0..3).map(|r| query_for(&snaps[0], r)).collect();
    let want = clean
        .handle(0)
        .query(snaps[0].clone(), theta.clone(), parity_queries.clone())
        .ok();
    let got = pool
        .handle(0)
        .query(snaps[0].clone(), theta.clone(), parity_queries)
        .ok();
    let parity = match (&got, &want) {
        (Some(g), Some(w)) => {
            g.len() == w.len()
                && g.iter().zip(w).all(|(x, y)| match (x, y) {
                    (Answer::Final(a), Answer::Final(b)) => {
                        a.len() == b.len()
                            && a.iter().zip(b).all(|(p, q)| {
                                p.0.to_bits() == q.0.to_bits() && p.1.to_bits() == q.1.to_bits()
                            })
                    }
                    _ => false,
                })
        }
        _ => false,
    };

    let mut panics_recovered = 0u64;
    let mut escalations = 0u64;
    let mut timeouts = 0u64;
    let mut trips = 0u64;
    for s in 0..shards {
        let st = pool.stats(s);
        panics_recovered += st.panics_recovered.load(Ordering::Relaxed);
        escalations += st.escalations.load(Ordering::Relaxed);
        timeouts += st.timeouts.load(Ordering::Relaxed);
        trips += st.quarantine_trips.load(Ordering::Relaxed);
    }
    let injected = chaos_stats.total();
    let recovered = injected > 0 && (panics_recovered > 0 || escalations > 0);

    println!(
        "\nchaos soak: {submitted} requests over {shards} shards in {soak_secs:.2}s — \
         {answered} answered, {typed_errors} typed errors, {untyped} untyped, \
         {} unresolved; injected={injected} (panics={} diverges={} slows={}), \
         recovered: panics={panics_recovered} escalations={escalations} \
         timeouts={timeouts} trips={trips}, healthy parity={parity}",
        submitted - resolved,
        chaos_stats.panics.load(Ordering::Relaxed),
        chaos_stats.diverges.load(Ordering::Relaxed),
        chaos_stats.slows.load(Ordering::Relaxed),
    );
    table.row(vec![
        "chaos_soak".into(),
        submitted.to_string(),
        format!("{:.0}", soak_secs * 1e6),
        format!("{answered}ok/{typed_errors}err"),
    ]);

    Json::obj(vec![
        ("bench", Json::Str("chaos".into())),
        ("shards", Json::Num(shards as f64)),
        ("requests", Json::Num(submitted as f64)),
        ("answered", Json::Num(answered as f64)),
        ("typed_errors", Json::Num(typed_errors as f64)),
        ("injected_faults", Json::Num(injected as f64)),
        ("panics_recovered", Json::Num(panics_recovered as f64)),
        ("escalations", Json::Num(escalations as f64)),
        ("timeouts", Json::Num(timeouts as f64)),
        ("quarantine_trips", Json::Num(trips as f64)),
        ("soak_secs", Json::Num(soak_secs)),
        ("assert_chaos_no_lost_requests", Json::Bool(resolved == submitted)),
        (
            "assert_chaos_typed_errors_only",
            Json::Bool(untyped == 0 && nonfinite == 0),
        ),
        ("assert_chaos_healthy_parity", Json::Bool(parity)),
        ("assert_chaos_recovered", Json::Bool(recovered)),
    ])
}

/// Corpus data plane at scale (the ingestion tentpole): admit a many-task
/// corpus through `ServicePool::from_corpus` and measure (a) cold
/// admission throughput — one `PredictFinal` per task, every shard
/// materializing lazily on first touch — (b) lazy materialization +
/// idle eviction bookkeeping, (c) fixture-corpus ingestion
/// (`data/lcbench_mini`, real-shaped ragged dumps through the hardened
/// `Task::load_json`), and (d) sequential replay throughput of
/// `traces/smoke.jsonl` through the library replayer. The returned JSON
/// carries the gates ci.sh enforces:
///
/// * `assert_ingest_zero_errors`    — every admission answer and every
///   fixture task parse succeeded, and the smoke replay reported zero
///   errors/violations
/// * `assert_ingest_lazy`           — a pool that only touches half its
///   corpus materializes exactly that half, and an `evict_idle` sweep
///   frees it once quiet
/// * `assert_ingest_admission_floor` — cold admission sustains >= 2
///   tasks/s (deliberately conservative: admission = engine build + first
///   full GP solve per task)
/// * `assert_ingest_replay_floor`   — sequential smoke replay sustains
///   >= 10 req/s
fn ingest_scale(table: &mut Table, quick: bool) -> Json {
    use lkgp::coordinator::trace::run_replay;
    use lkgp::coordinator::EngineFactory;
    use lkgp::lcbench::corpus::{Corpus, JsonDirCorpus, SimCorpus};

    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap_or_else(|| std::path::Path::new("."))
        .to_path_buf();
    let mut zero_errors = true;

    // ---- (a) many-task cold admission ------------------------------------
    let tasks = if quick { 16 } else { 48 };
    let corpus = SimCorpus::new(tasks, 8, 5);
    let factory: EngineFactory =
        Box::new(|_| Box::<RustEngine>::default() as Box<dyn Engine>);
    let workers = lkgp::util::num_threads().clamp(2, 8);
    let pool = ServicePool::from_corpus(
        &corpus,
        factory,
        PoolCfg { workers, ..Default::default() },
    );
    // one tiny snapshot per task, derived from the corpus curves
    let snaps: Vec<Snapshot> = (0..tasks)
        .map(|t| {
            let task = corpus.task(t).expect("sim task");
            let mut reg = Registry::new();
            for i in 0..task.n() {
                let id = reg.add(task.configs.row(i).to_vec());
                for j in 0..3 + i % 3 {
                    reg.observe(id, task.curves[(i, j)], 8).unwrap();
                }
            }
            CurveStore::new(8).snapshot(&reg).unwrap()
        })
        .collect();
    let theta = Theta::default_packed(lkgp::lcbench::DIMS);
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    for (t, snap) in snaps.iter().enumerate() {
        let (rtx, rrx) = channel();
        pool.submit(
            t,
            Request::PredictFinal {
                snapshot: snap.clone(),
                theta: theta.clone(),
                xq: Matrix::from_vec(1, lkgp::lcbench::DIMS, snap.all_x.row(0).to_vec()),
                resp: rtx,
            },
        )
        .unwrap();
        rxs.push(rrx);
    }
    for r in rxs {
        match r.recv() {
            Ok(Ok(_)) => {}
            _ => zero_errors = false,
        }
    }
    let admit_secs = t0.elapsed().as_secs_f64();
    let admission_rps = tasks as f64 / admit_secs.max(1e-9);
    let all_materialized = pool.materialized() == tasks as u64;
    drop(pool);

    // ---- (b) lazy materialization + idle eviction ------------------------
    let corpus2 = SimCorpus::new(tasks, 8, 6);
    let factory2: EngineFactory =
        Box::new(|_| Box::<RustEngine>::default() as Box<dyn Engine>);
    let pool2 = ServicePool::from_corpus(
        &corpus2,
        factory2,
        PoolCfg { workers: 2, ..Default::default() },
    );
    let touched = tasks / 2;
    for (t, snap) in snaps.iter().take(touched).enumerate() {
        let (rtx, rrx) = channel();
        pool2
            .submit(
                t,
                Request::PredictFinal {
                    snapshot: snap.clone(),
                    theta: theta.clone(),
                    xq: Matrix::from_vec(1, lkgp::lcbench::DIMS, snap.all_x.row(0).to_vec()),
                    resp: rtx,
                },
            )
            .unwrap();
        if r_recv_ok(rrx).is_none() {
            zero_errors = false;
        }
    }
    let lazily_materialized = pool2.materialized() == touched as u64
        && pool2.live_shards() == touched;
    // first sweep records the enqueued watermark; later sweeps find the
    // shards quiet and free them (loop: a worker may still be clearing
    // its busy flag right after the last response)
    let mut evicted = pool2.evict_idle();
    let deadline = Instant::now() + std::time::Duration::from_secs(5);
    while evicted < touched && Instant::now() < deadline {
        std::thread::yield_now();
        evicted += pool2.evict_idle();
    }
    let evicted_ok = evicted == touched && pool2.live_shards() == 0;
    // an evicted shard re-materializes transparently
    let (rtx, rrx) = channel();
    pool2
        .submit(
            0,
            Request::PredictFinal {
                snapshot: snaps[0].clone(),
                theta: theta.clone(),
                xq: Matrix::from_vec(1, lkgp::lcbench::DIMS, snaps[0].all_x.row(0).to_vec()),
                resp: rtx,
            },
        )
        .unwrap();
    let rematerialized = r_recv_ok(rrx).is_some() && pool2.live_shards() == 1;
    drop(pool2);
    let lazy_ok = lazily_materialized && evicted_ok && rematerialized;

    // ---- (c) fixture-corpus ingestion (ragged real-shaped dumps) ---------
    let fixture_dir = root.join("data/lcbench_mini");
    let t1 = Instant::now();
    let (fixture_tasks, fixture_ragged, fixture_ok) = match JsonDirCorpus::open(&fixture_dir) {
        Ok(fixture) => {
            let mut ragged = 0usize;
            let mut ok = true;
            let n = fixture.len();
            for (id, task) in fixture.tasks() {
                match task {
                    Ok(t) => {
                        if t.mask_density() < 1.0 {
                            ragged += 1;
                        }
                    }
                    Err(e) => {
                        eprintln!("fixture task {id}: {e}");
                        ok = false;
                    }
                }
            }
            (n, ragged, ok && ragged > 0)
        }
        Err(e) => {
            eprintln!("fixture corpus: {e}");
            (0, 0, false)
        }
    };
    let fixture_secs = t1.elapsed().as_secs_f64();
    zero_errors &= fixture_ok;

    // ---- (d) sequential replay throughput --------------------------------
    let smoke = root.join("traces/smoke.jsonl");
    let (replay_rps, replay_requests) = match run_replay(smoke.to_str().unwrap(), false, None) {
        Ok(summary) => {
            if summary.errors > 0 || !summary.violations.is_empty() {
                zero_errors = false;
            }
            (
                summary.requests as f64 / summary.wall.as_secs_f64().max(1e-9),
                summary.requests,
            )
        }
        Err(e) => {
            eprintln!("smoke replay: {e}");
            zero_errors = false;
            (0.0, 0)
        }
    };

    println!(
        "\ningest scale: {tasks}-task cold admission {admission_rps:.1} tasks/s \
         ({admit_secs:.2}s), lazy={lazy_ok} (touched {touched}, evicted {evicted}), \
         fixture {fixture_tasks} tasks ({fixture_ragged} ragged) in {fixture_secs:.3}s, \
         replay {replay_requests} reqs at {replay_rps:.0} req/s"
    );
    table.row(vec![
        "ingest_admission".into(),
        tasks.to_string(),
        format!("{:.0}", admit_secs * 1e6),
        format!("{admission_rps:.1}tasks/s"),
    ]);
    table.row(vec![
        "ingest_replay".into(),
        replay_requests.to_string(),
        "-".into(),
        format!("{replay_rps:.0}rps"),
    ]);

    Json::obj(vec![
        ("bench", Json::Str("ingest".into())),
        ("tasks", Json::Num(tasks as f64)),
        ("admission_tasks_per_s", Json::Num(admission_rps)),
        ("all_materialized", Json::Bool(all_materialized)),
        ("touched", Json::Num(touched as f64)),
        ("evicted", Json::Num(evicted as f64)),
        ("fixture_tasks", Json::Num(fixture_tasks as f64)),
        ("fixture_ragged_tasks", Json::Num(fixture_ragged as f64)),
        ("replay_requests", Json::Num(replay_requests as f64)),
        ("replay_req_per_s", Json::Num(replay_rps)),
        ("assert_ingest_zero_errors", Json::Bool(zero_errors && all_materialized)),
        ("assert_ingest_lazy", Json::Bool(lazy_ok)),
        ("assert_ingest_admission_floor", Json::Bool(admission_rps >= 2.0)),
        ("assert_ingest_replay_floor", Json::Bool(replay_rps >= 10.0)),
    ])
}

/// recv a PredictFinal response, flattening the double Result.
fn r_recv_ok(
    rrx: std::sync::mpsc::Receiver<lkgp::Result<Vec<(f64, f64)>>>,
) -> Option<Vec<(f64, f64)>> {
    rrx.recv().ok().and_then(|r| r.ok())
}

/// Read-only replica shards on a single-task read burst (the tentpole of
/// the replica redesign): one shard, four workers, the writer pinned on a
/// refit, then a burst of concurrent typed-query batches against the
/// already-fitted generation. With `max_replicas = 0` the burst serializes
/// behind the refit (the historical behavior); with replicas enabled,
/// spare workers answer it from the shard's cached `WarmStart` lineage via
/// forked `Posterior`s. The returned JSON carries the gates ci.sh
/// enforces:
///
/// * `assert_replica_speedup`         — the replica burst finishes >= 2x
///   faster than the serialized burst (and replicas actually served it)
/// * `assert_replica_no_extra_solves` — the replica burst adds ZERO
///   underlying solves, and total solves never exceed the serialized run
/// * `assert_replica_parity`          — every replica answer is
///   bit-identical to the writer's answers for the same
///   (generation, theta, query)
fn replica_burst(table: &mut Table) -> Json {
    use lkgp::coordinator::PredictClient;
    use lkgp::gp::session::Answer;
    use lkgp::gp::session::Query;
    use std::sync::atomic::Ordering;

    const BURST: usize = 6;

    struct Variant {
        burst_us: u128,
        total_us: u128,
        burst_solves: u64,
        total_solves: u64,
        replica_hits: u64,
        replica_solves: u64,
        retires: u64,
        parity: bool,
    }

    fn answers_bits_equal(a: &[Answer], b: &[Answer]) -> bool {
        if a.len() != b.len() {
            return false;
        }
        a.iter().zip(b).all(|(x, y)| match (x, y) {
            (Answer::Final(u), Answer::Final(v)) => {
                u.len() == v.len()
                    && u.iter().zip(v).all(|(p, q)| {
                        p.0.to_bits() == q.0.to_bits() && p.1.to_bits() == q.1.to_bits()
                    })
            }
            (Answer::Variance(u), Answer::Variance(v)) => {
                u.len() == v.len()
                    && u.iter().zip(v).all(|(p, q)| p.to_bits() == q.to_bits())
            }
            (Answer::Quantiles(u), Answer::Quantiles(v))
            | (Answer::Steps(u), Answer::Steps(v)) => {
                u.rows() == v.rows()
                    && u.cols() == v.cols()
                    && u.data()
                        .iter()
                        .zip(v.data())
                        .all(|(p, q)| p.to_bits() == q.to_bits())
            }
            _ => false,
        })
    }

    fn run(max_replicas: usize) -> Variant {
        let snap = serving_snapshot(7);
        let theta = Theta::default_packed(3);
        let mut rng = Pcg64::new(8);
        let xq = Matrix::from_vec(8, 3, rng.uniform_vec(24, 0.0, 1.0));
        let queries = vec![
            Query::MeanAtFinal { xq: xq.clone() },
            Query::Variance { xq: xq.clone() },
            Query::Quantiles { xq: xq.clone(), ps: vec![0.1, 0.5, 0.9] },
        ];
        let engines: Vec<Box<dyn Engine>> =
            vec![Box::<RustEngine>::default() as Box<dyn Engine>];
        let pool = ServicePool::spawn(
            engines,
            PoolCfg {
                workers: 4,
                warm_start: true,
                max_replicas,
                ..Default::default()
            },
        );
        let handle = pool.handle(0);
        let t_all = Instant::now();
        // 1. fit the generation once on the writer (this also caches the
        //    WarmStart lineage replicas fork from) — the parity reference
        let reference = handle
            .query(snap.clone(), theta.clone(), queries.clone())
            .expect("reference query");
        let solves_before = pool.stats(0).engine_solves.load(Ordering::Relaxed);
        // 2. pin the writer on a refit (a write: strictly ordered on the
        //    writer) and wait until a worker has claimed it
        let (ftx, frx) = channel();
        pool.submit(
            0,
            Request::Refit {
                snapshot: snap.clone(),
                theta0: theta.clone(),
                seed: 1,
                resp: ftx,
            },
        )
        .expect("submit refit");
        while pool.queue_depth(0) > 0 {
            std::thread::yield_now();
        }
        // 3. concurrent read burst against the already-fitted generation
        let t0 = Instant::now();
        let mut rxs = Vec::new();
        for _ in 0..BURST {
            let (rtx, rrx) = channel();
            pool.submit(
                0,
                Request::Query {
                    snapshot: snap.clone(),
                    theta: theta.clone(),
                    queries: queries.clone(),
                    resp: rtx,
                },
            )
            .expect("submit burst");
            rxs.push(rrx);
        }
        let answers: Vec<Vec<Answer>> = rxs
            .into_iter()
            .map(|r| r.recv().expect("burst recv").expect("burst answers"))
            .collect();
        let burst_us = t0.elapsed().as_micros();
        let burst_solves =
            pool.stats(0).engine_solves.load(Ordering::Relaxed) - solves_before;
        frx.recv().expect("refit recv").expect("refit theta");
        let total_us = t_all.elapsed().as_micros();
        let stats = pool.stats(0);
        Variant {
            burst_us,
            total_us,
            burst_solves,
            total_solves: stats.engine_solves.load(Ordering::Relaxed),
            replica_hits: stats.replica_hits.load(Ordering::Relaxed),
            replica_solves: stats.replica_solves.load(Ordering::Relaxed),
            retires: stats.stale_replica_retires.load(Ordering::Relaxed),
            parity: answers.iter().all(|a| answers_bits_equal(a, &reference)),
        }
    }

    let serialized = run(0);
    let replicas = run(3);

    println!(
        "\nreplica burst (1 task, 4 workers, {BURST} concurrent batches, writer pinned on a \
         refit): serialized {}us vs replicas {}us ({} replica-served groups, {} replica \
         solves, {} retires)",
        serialized.burst_us,
        replicas.burst_us,
        replicas.replica_hits,
        replicas.replica_solves,
        replicas.retires,
    );
    for (name, v) in [("serialized", &serialized), ("replicas", &replicas)] {
        table.row(vec![
            format!("replica_burst_{name}"),
            BURST.to_string(),
            v.burst_us.to_string(),
            format!("solves={} hits={}", v.total_solves, v.replica_hits),
        ]);
    }

    let speedup = serialized.burst_us >= replicas.burst_us.saturating_mul(2)
        && replicas.replica_hits >= 1;
    let no_extra =
        replicas.burst_solves == 0 && replicas.total_solves <= serialized.total_solves;
    let parity = replicas.parity && serialized.parity;
    let variant_json = |v: &Variant| {
        Json::obj(vec![
            ("burst_us", Json::Num(v.burst_us as f64)),
            ("total_us", Json::Num(v.total_us as f64)),
            ("burst_solves", Json::Num(v.burst_solves as f64)),
            ("engine_solves", Json::Num(v.total_solves as f64)),
            ("replica_hits", Json::Num(v.replica_hits as f64)),
            ("replica_solves", Json::Num(v.replica_solves as f64)),
            ("stale_replica_retires", Json::Num(v.retires as f64)),
            ("parity", Json::Bool(v.parity)),
        ])
    };
    Json::obj(vec![
        ("bench", Json::Str("replicas".into())),
        ("tasks", Json::Num(1.0)),
        ("workers", Json::Num(4.0)),
        ("burst", Json::Num(BURST as f64)),
        ("serialized", variant_json(&serialized)),
        ("replicas", variant_json(&replicas)),
        ("assert_replica_speedup", Json::Bool(speedup)),
        ("assert_replica_no_extra_solves", Json::Bool(no_extra)),
        ("assert_replica_parity", Json::Bool(parity)),
    ])
}

/// Multi-query amortization through the session API (the tentpole of the
/// typed-query redesign): answering `MeanAtFinal` + `Variance` +
/// `Quantiles` + `MeanAtSteps` over the same configs costs ONE batched
/// solve through `Posterior::answer_batch`, vs one solve per statistic the
/// pre-session serving path paid. The returned JSON carries the gates
/// ci.sh enforces:
///
/// * `assert_shared_single_solve` — the 4-variant batch ran exactly one
///   underlying batched CG solve
/// * `assert_shared_fewer_rows`   — the shared batch applied strictly
///   fewer operator rows (`CgStats::mvm_rows`) than the per-query path
fn queries_amortization(table: &mut Table) -> Json {
    use lkgp::gp::session::{Posterior, Query};
    use lkgp::gp::SolverCfg;

    let (n, m, d) = (96usize, 32usize, 3usize);
    let data = std::sync::Arc::new(toy_dataset(n, m, d, 21));
    let packed = Theta::default_packed(d);
    let mut rng = Pcg64::new(22);
    let xq = Matrix::from_vec(8, d, rng.uniform_vec(8 * d, 0.0, 1.0));
    let ps = vec![0.1, 0.5, 0.9];
    let steps = vec![m / 2, m - 1];
    let cfg = SolverCfg::default();
    let batch = [
        Query::MeanAtFinal { xq: xq.clone() },
        Query::Variance { xq: xq.clone() },
        Query::Quantiles { xq: xq.clone(), ps: ps.clone() },
        Query::MeanAtSteps { xq: xq.clone(), steps: steps.clone() },
    ];

    // separate: one posterior per query — every statistic cold-solves
    let t0 = Instant::now();
    let mut separate_rows = 0usize;
    let mut separate_solves = 0usize;
    for q in &batch {
        let mut post = Posterior::new(data.clone(), packed.clone(), cfg.clone());
        post.answer(q).expect("separate query");
        separate_rows += post.cg_mvm_rows();
        separate_solves += post.solve_calls();
    }
    let separate_us = t0.elapsed().as_micros();

    // shared: one posterior answers the whole batch
    let t1 = Instant::now();
    let mut post = Posterior::new(data.clone(), packed.clone(), cfg.clone());
    let answers = post.answer_batch(&batch).expect("shared batch");
    let shared_us = t1.elapsed().as_micros();
    assert_eq!(answers.len(), batch.len());
    let shared_rows = post.cg_mvm_rows();
    let shared_solves = post.solve_calls();

    println!(
        "\nquery amortization (n={n}, m={m}, 8 configs, 4 variants): \
         shared {shared_solves} solve / {shared_rows} rows ({shared_us}us) vs \
         separate {separate_solves} solves / {separate_rows} rows ({separate_us}us)"
    );
    table.row(vec![
        "queries_shared".into(),
        n.to_string(),
        shared_us.to_string(),
        format!("solves={shared_solves} rows={shared_rows}"),
    ]);
    table.row(vec![
        "queries_separate".into(),
        n.to_string(),
        separate_us.to_string(),
        format!("solves={separate_solves} rows={separate_rows}"),
    ]);

    Json::obj(vec![
        ("bench", Json::Str("queries".into())),
        ("n", Json::Num(n as f64)),
        ("m", Json::Num(m as f64)),
        ("configs", Json::Num(8.0)),
        ("variants", Json::Num(batch.len() as f64)),
        (
            "shared",
            Json::obj(vec![
                ("solves", Json::Num(shared_solves as f64)),
                ("mvm_rows", Json::Num(shared_rows as f64)),
                ("us", Json::Num(shared_us as f64)),
            ]),
        ),
        (
            "separate",
            Json::obj(vec![
                ("solves", Json::Num(separate_solves as f64)),
                ("mvm_rows", Json::Num(separate_rows as f64)),
                ("us", Json::Num(separate_us as f64)),
            ]),
        ),
        (
            "assert_shared_single_solve",
            Json::Bool(shared_solves == 1),
        ),
        (
            "assert_shared_fewer_rows",
            Json::Bool(shared_rows < separate_rows),
        ),
    ])
}

/// One (iterations, mvm_rows, wall-µs) measurement of a batched solve.
struct SolveCost {
    iters: usize,
    mvm_rows: usize,
    us: u128,
}

impl SolveCost {
    fn json(&self) -> Json {
        Json::obj(vec![
            ("iters", Json::Num(self.iters as f64)),
            ("mvm_rows", Json::Num(self.mvm_rows as f64)),
            ("us", Json::Num(self.us as f64)),
        ])
    }
}

/// Preconditioned vs plain CG on the training system `[y, probes]` at two
/// condition regimes (n=128, m=48, prefix masks):
///
/// * `benign` — default theta (σ² = e⁻⁴)
/// * `ill`    — long lengthscales + σ² = 1e-4, the regime where plain CG
///   grinds for hundreds of iterations
///
/// For each regime: cold plain CG, cold PCG (Auto strategy), then a
/// generation-2 system (one more observed epoch per curve + a small theta
/// drift) solved warm-only and warm+PCG. The returned JSON carries the
/// acceptance booleans ci.sh gates on:
///
/// * `assert_pcg_2x_ill`       — PCG cuts iterations ≥ 2x on `ill`
/// * `assert_warm_pcg_below`   — warm+PCG mvm_rows strictly below
///   warm-only on the ill regime (benign is covered by never-worse)
/// * `assert_pcg_never_worse`  — PCG never exceeds plain CG's or
///   warm-only's mvm_rows on any measured system
fn pcg_vs_plain(table: &mut Table) -> Json {
    use lkgp::gp::{PrecondCfg, PrecondFactors};

    let (n, m, d, probes_cnt) = (128usize, 48usize, 3usize, 8usize);
    let nm = n * m;
    let tol = 1e-2;
    let cap = 10_000;

    let ill_packed = {
        let mut p = Theta::default_packed(d);
        for v in p.iter_mut().take(d) {
            *v = 3.0f64.ln(); // long lengthscales -> numerically low-rank K1
        }
        p[d] = 0.0; // t lengthscale 1.0
        p[d + 1] = 0.0; // outputscale 1.0
        p[d + 2] = 1e-4f64.ln(); // near-interpolation noise
        p
    };
    let regimes = [("benign", Theta::default_packed(d)), ("ill", ill_packed)];

    let mut regime_json = Vec::new();
    let mut two_x_ill = false;
    let mut warm_below = true;
    let mut never_worse = true;

    for (name, packed) in regimes {
        let gen1 = toy_dataset(n, m, d, 1);
        let mut gen2 = gen1.clone();
        for i in 0..n {
            let len = (0..m).take_while(|&j| gen1.mask[(i, j)] > 0.0).count();
            if len < m {
                let prev = gen2.y[(i, len.saturating_sub(1))];
                gen2.mask[(i, len)] = 1.0;
                gen2.y[(i, len)] = prev;
            }
        }
        let theta = Theta::unpack(&packed);
        let k1 = kernels::rbf(&gen1.x, &gen1.x, &theta.lengthscales);
        let k2 = kernels::matern12(&gen1.t, &gen1.t, theta.t_lengthscale, theta.outputscale);
        let op1 = lkgp::gp::operator::MaskedKronOp::new(&k1, &k2, &gen1.mask, theta.sigma2);

        let probes = Pcg64::new(2).rademacher_vec(probes_cnt * nm);
        let mut rhs1 = Vec::with_capacity((probes_cnt + 1) * nm);
        rhs1.extend_from_slice(gen1.y.data());
        rhs1.extend_from_slice(&probes);
        let mut rhs2 = Vec::with_capacity((probes_cnt + 1) * nm);
        rhs2.extend_from_slice(gen2.y.data());
        rhs2.extend_from_slice(&probes);

        // generation 1, cold: plain vs preconditioned. PCG timings START
        // BEFORE the factorization so BENCH_pcg.json carries the full cost
        // the serving path pays when factors must be (re)built.
        let t0 = Instant::now();
        let (sol_plain, st_plain) = op1.solve(&rhs1, tol, cap);
        let plain = SolveCost { iters: st_plain.iters, mvm_rows: st_plain.mvm_rows, us: t0.elapsed().as_micros() };
        let t1 = Instant::now();
        let factors1 = PrecondFactors::build(PrecondCfg::Auto, &k1, &k2, &gen1.mask, &packed)
            .expect("preconditioner factors");
        let (sol_pcg, st_pcg) = op1.solve_precond(&rhs1, None, Some(&factors1), tol, cap);
        let pcg = SolveCost { iters: st_pcg.iters, mvm_rows: st_pcg.mvm_rows, us: t1.elapsed().as_micros() };

        // generation 2: theta drifts slightly, masks grow one epoch
        let mut packed2 = packed.clone();
        for v in packed2.iter_mut().take(d) {
            *v += 0.02;
        }
        let theta2 = Theta::unpack(&packed2);
        let k1b = kernels::rbf(&gen2.x, &gen2.x, &theta2.lengthscales);
        let op2 = lkgp::gp::operator::MaskedKronOp::new(&k1b, &k2, &gen2.mask, theta2.sigma2);
        let t2 = Instant::now();
        let (_, st_warm) = op2.solve_warm(&rhs2, Some(&sol_plain), tol, cap);
        let warm = SolveCost { iters: st_warm.iters, mvm_rows: st_warm.mvm_rows, us: t2.elapsed().as_micros() };
        // the cached factors are stale (mask grew) -> rebuild, as the
        // serving layer's compatibility check would; the rebuild is
        // inside the warm+PCG timing for the same reason as above
        assert!(!factors1.compatible(&packed2, n, m, &gen2.mask));
        let t3 = Instant::now();
        let factors2 = PrecondFactors::build(PrecondCfg::Auto, &k1b, &k2, &gen2.mask, &packed2)
            .expect("gen2 factors");
        let (_, st_wp) = op2.solve_precond(&rhs2, Some(&sol_pcg), Some(&factors2), tol, cap);
        let warm_pcg = SolveCost { iters: st_wp.iters, mvm_rows: st_wp.mvm_rows, us: t3.elapsed().as_micros() };

        assert!(
            st_plain.converged && st_pcg.converged && st_warm.converged && st_wp.converged,
            "pcg bench solve did not converge ({name})"
        );
        println!(
            "pcg [{name}] ({} rank {}): cold plain {} iters / {} rows vs pcg {} iters / {} rows; \
             warm {} rows vs warm+pcg {} rows",
            factors1.strategy(),
            factors1.rank(),
            plain.iters,
            plain.mvm_rows,
            pcg.iters,
            pcg.mvm_rows,
            warm.mvm_rows,
            warm_pcg.mvm_rows,
        );
        for (variant, cost) in [("plain", &plain), ("pcg", &pcg), ("warm", &warm), ("warm_pcg", &warm_pcg)] {
            table.row(vec![
                format!("pcg_{name}_{variant}"),
                n.to_string(),
                cost.us.to_string(),
                format!("iters={} rows={}", cost.iters, cost.mvm_rows),
            ]);
        }

        if name == "ill" {
            two_x_ill = pcg.iters * 2 <= plain.iters;
            // strict gate only where warm starts leave real work behind;
            // on the benign regime a perfect warm guess can tie at
            // exactly `batch` residual rows, which is not a regression
            warm_below &= warm_pcg.mvm_rows < warm.mvm_rows;
        }
        never_worse &= pcg.mvm_rows <= plain.mvm_rows && warm_pcg.mvm_rows <= warm.mvm_rows;

        regime_json.push(Json::obj(vec![
            ("regime", Json::Str(name.into())),
            ("strategy", Json::Str(factors1.strategy().into())),
            ("rank", Json::Num(factors1.rank() as f64)),
            ("plain", plain.json()),
            ("pcg", pcg.json()),
            ("warm", warm.json()),
            ("warm_pcg", warm_pcg.json()),
        ]));
    }

    Json::obj(vec![
        ("bench", Json::Str("pcg".into())),
        ("n", Json::Num(n as f64)),
        ("m", Json::Num(m as f64)),
        ("probes", Json::Num(probes_cnt as f64)),
        ("regimes", Json::Arr(regime_json)),
        ("assert_pcg_2x_ill", Json::Bool(two_x_ill)),
        ("assert_warm_pcg_below", Json::Bool(warm_below)),
        ("assert_pcg_never_worse", Json::Bool(never_worse)),
    ])
}

/// The scheduler's generation-to-generation workload: re-solve the refit
/// system `[y, probes]` after every curve gains one more observed epoch.
/// Cold starts from zero; warm starts from the previous generation's
/// solves (acceptance: measurably fewer iterations at n >= 64).
fn warm_vs_cold_refit(table: &mut Table) -> (usize, usize, usize, usize) {
    let (n, m, d, probes_cnt) = (64usize, 48usize, 3usize, 8usize);
    let gen1 = toy_dataset(n, m, d, 1);
    // generation 2: every unfinished curve trains one more epoch
    let mut gen2 = gen1.clone();
    for i in 0..n {
        let len = (0..m).take_while(|&j| gen1.mask[(i, j)] > 0.0).count();
        if len < m {
            let prev = gen2.y[(i, len.saturating_sub(1))];
            gen2.mask[(i, len)] = 1.0;
            gen2.y[(i, len)] = prev;
        }
    }
    let theta = Theta::unpack(&Theta::default_packed(d));
    let k1 = kernels::rbf(&gen1.x, &gen1.x, &theta.lengthscales);
    let k2 = kernels::matern12(&gen1.t, &gen1.t, theta.t_lengthscale, theta.outputscale);
    let op1 = lkgp::gp::operator::MaskedKronOp::new(&k1, &k2, &gen1.mask, theta.sigma2);
    let op2 = lkgp::gp::operator::MaskedKronOp::new(&k1, &k2, &gen2.mask, theta.sigma2);

    let nm = n * m;
    let probes = Pcg64::new(2).rademacher_vec(probes_cnt * nm);
    let mut rhs1 = Vec::with_capacity((probes_cnt + 1) * nm);
    rhs1.extend_from_slice(gen1.y.data());
    rhs1.extend_from_slice(&probes);
    let mut rhs2 = Vec::with_capacity((probes_cnt + 1) * nm);
    rhs2.extend_from_slice(gen2.y.data());
    rhs2.extend_from_slice(&probes);

    let (solves1, _) = op1.solve(&rhs1, 1e-2, 10_000);

    let t0 = Instant::now();
    let (_, cold) = op2.solve(&rhs2, 1e-2, 10_000);
    let cold_us = t0.elapsed().as_micros();
    let t1 = Instant::now();
    let (_, warm) = op2.solve_warm(&rhs2, Some(&solves1), 1e-2, 10_000);
    let warm_us = t1.elapsed().as_micros();

    let cold_total: usize = cold.iters_per_rhs.iter().sum();
    let warm_total: usize = warm.iters_per_rhs.iter().sum();
    println!(
        "\nincremental-mask refit (n={n}, m={m}, {} RHS): \
         cold {} iters ({cold_us}us) vs warm {} iters ({warm_us}us)",
        probes_cnt + 1,
        cold.iters,
        warm.iters,
    );
    table.row(vec![
        "cg_refit_cold".into(),
        n.to_string(),
        cold_us.to_string(),
        format!("iters={}", cold.iters),
    ]);
    table.row(vec![
        "cg_refit_warm".into(),
        n.to_string(),
        warm_us.to_string(),
        format!("iters={}", warm.iters),
    ]);
    (cold.iters, warm.iters, cold_total, warm_total)
}

fn serving_snapshot(seed: u64) -> Snapshot {
    let mut rng = Pcg64::new(seed);
    let mut reg = Registry::new();
    for _ in 0..24 {
        let id = reg.add(vec![rng.uniform(), rng.uniform(), rng.uniform()]);
        for j in 0..4 + rng.below(8) {
            reg.observe(id, 0.4 + 0.03 * j as f64 + 0.05 * rng.uniform(), 16)
                .unwrap();
        }
    }
    CurveStore::new(16).snapshot(&reg).unwrap()
}

/// Aggregate PredictFinal throughput: a 4-shard pool with 4 shared workers
/// vs 4 isolated single-task services (one worker each — the same thread
/// budget). The pool's per-shard warm cache makes every round after the
/// first start its training solve from the previous solution; the
/// isolated seed-style services solve cold every time.
fn pool_vs_isolated(table: &mut Table, quick: bool) -> (f64, f64) {
    const TASKS: usize = 4;
    let rounds = if quick { 6 } else { 12 };
    let callers = 8;
    let snaps: Vec<Snapshot> = (0..TASKS as u64).map(|t| serving_snapshot(100 + t)).collect();
    // Each round models one scheduler generation: the refit nudges theta,
    // the active query set stays put. The pool's warm cache turns every
    // round after the first into a near-converged solve; the isolated
    // services solve cold each time.
    let thetas: Vec<Vec<f64>> = (0..rounds)
        .map(|r| {
            let mut t = Theta::default_packed(3);
            t[0] += 0.02 * r as f64;
            t
        })
        .collect();
    let total = (TASKS * rounds * callers) as f64;

    // --- isolated: one PredictionService (one worker thread) per task ---
    let services: Vec<PredictionService> = (0..TASKS)
        .map(|_| PredictionService::spawn(Box::<RustEngine>::default()))
        .collect();
    let t0 = Instant::now();
    for round in 0..rounds {
        let mut receivers = Vec::new();
        for (t, service) in services.iter().enumerate() {
            for c in 0..callers {
                let (rtx, rrx) = channel();
                service
                    .sender()
                    .send(Request::PredictFinal {
                        snapshot: snaps[t].clone(),
                        theta: thetas[round].clone(),
                        xq: Matrix::from_vec(1, 3, vec![0.1 * c as f64, 0.5, 0.5]),
                        resp: rtx,
                    })
                    .unwrap();
                receivers.push(rrx);
            }
        }
        for r in receivers {
            r.recv().unwrap().unwrap();
        }
    }
    let isolated_secs = t0.elapsed().as_secs_f64();
    drop(services);

    // --- pooled: 4 shards behind 4 shared workers, warm starts on ---
    let engines: Vec<Box<dyn Engine>> = (0..TASKS)
        .map(|_| Box::<RustEngine>::default() as Box<dyn Engine>)
        .collect();
    let pool = ServicePool::spawn(
        engines,
        PoolCfg { workers: TASKS, warm_start: true, ..Default::default() },
    );
    let t1 = Instant::now();
    for round in 0..rounds {
        let mut receivers = Vec::new();
        for (t, snap) in snaps.iter().enumerate() {
            for c in 0..callers {
                let (rtx, rrx) = channel();
                pool.submit(
                    t,
                    Request::PredictFinal {
                        snapshot: snap.clone(),
                        theta: thetas[round].clone(),
                        xq: Matrix::from_vec(1, 3, vec![0.1 * c as f64, 0.5, 0.5]),
                        resp: rtx,
                    },
                )
                .unwrap();
                receivers.push(rrx);
            }
        }
        for r in receivers {
            r.recv().unwrap().unwrap();
        }
    }
    let pool_secs = t1.elapsed().as_secs_f64();
    let warm_hits: u64 = (0..TASKS)
        .map(|t| pool.stats(t).warm_hits.load(std::sync::atomic::Ordering::Relaxed))
        .sum();
    drop(pool);

    let pool_rps = total / pool_secs.max(1e-9);
    let isolated_rps = total / isolated_secs.max(1e-9);
    println!(
        "\nserving throughput ({TASKS} tasks x {rounds} rounds x {callers} callers): \
         pool {pool_rps:.0} req/s vs isolated {isolated_rps:.0} req/s \
         ({warm_hits} warm engine calls)"
    );
    table.row(vec![
        "serve_pool_4shard".into(),
        (TASKS * rounds * callers).to_string(),
        format!("{:.0}", pool_secs * 1e6),
        format!("{pool_rps:.0}rps"),
    ]);
    table.row(vec![
        "serve_isolated_4x1".into(),
        (TASKS * rounds * callers).to_string(),
        format!("{:.0}", isolated_secs * 1e6),
        format!("{isolated_rps:.0}rps"),
    ]);
    (pool_rps, isolated_rps)
}
