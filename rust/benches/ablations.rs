//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! * CG tolerance sweep — the paper uses 0.01; how do looser/tighter
//!   tolerances trade solve time vs prediction error?
//! * probe count sweep — SLQ/Hutchinson variance vs cost.
//! * padding overhead — what does bucket padding cost the XLA engine?
//! * dynamic batching — service throughput with/without coalescing.
//!
//! Output: results/ablations_*.csv. Flags: --quick.

#![allow(deprecated)] // exercises the deprecated free-function shims by design

use std::sync::mpsc::channel;

use lkgp::bench_util::{bench, time_once, Table};
use lkgp::coordinator::{CurveStore, PredictionService, Registry, Request};
use lkgp::gp::lkgp::SolverCfg;
use lkgp::gp::Theta;
use lkgp::lcbench::toy_dataset;
use lkgp::linalg::Matrix;
use lkgp::rng::Pcg64;
use lkgp::runtime::RustEngine;
use lkgp::util::Args;

fn main() -> lkgp::Result<()> {
    let args = Args::from_env();
    let quick = lkgp::bench_util::is_quick();
    let n = args.get_usize("n", if quick { 32 } else { 64 });
    let m = args.get_usize("m", 52);

    cg_tolerance_sweep(n, m)?;
    probe_count_sweep(n, m)?;
    padding_overhead()?;
    batching_throughput()?;
    Ok(())
}

/// CG tolerance vs time and vs agreement with a tight solve.
fn cg_tolerance_sweep(n: usize, m: usize) -> lkgp::Result<()> {
    println!("\n== ablation: CG tolerance (paper uses 1e-2) ==");
    let data = toy_dataset(n, m, 7, 1);
    let theta = Theta::default_packed(7);
    let mut rng = Pcg64::new(2);
    let xq = Matrix::from_vec(8, 7, rng.uniform_vec(56, 0.0, 1.0));

    // reference: tight solve
    let tight = SolverCfg { cg_tol: 1e-10, ..Default::default() };
    let refp = lkgp::gp::lkgp::predict_final(&theta, &data, &xq, &tight)?;

    let mut table = Table::new(&["cg_tol", "iters", "time_ms", "max_pred_err"]);
    for tol in [1e-1, 3e-2, 1e-2, 1e-3, 1e-5] {
        let cfg = SolverCfg { cg_tol: tol, ..Default::default() };
        let stats = bench(
            || {
                let _ = lkgp::gp::lkgp::predict_final(&theta, &data, &xq, &cfg).unwrap();
            },
            3,
            std::time::Duration::from_millis(300),
        );
        let preds = lkgp::gp::lkgp::predict_final(&theta, &data, &xq, &cfg)?;
        let err = preds
            .iter()
            .zip(&refp)
            .map(|(a, b)| (a.0 - b.0).abs())
            .fold(0.0, f64::max);
        // measure iterations via a single mll pass
        let probes = Pcg64::new(3).rademacher_vec(8 * n * m);
        let eval = lkgp::gp::lkgp::mll_value_grad(&theta, &data, &probes, &cfg)?;
        table.row(vec![
            format!("{tol:.0e}"),
            eval.cg.iters.to_string(),
            format!("{:.2}", stats.median_secs() * 1e3),
            format!("{err:.2e}"),
        ]);
    }
    table.write_csv("results/ablations_cg_tol.csv")?;
    Ok(())
}

/// Probe count vs MLL value spread (SLQ variance) and gradient time.
fn probe_count_sweep(n: usize, m: usize) -> lkgp::Result<()> {
    println!("\n== ablation: Hutchinson/SLQ probe count ==");
    let data = toy_dataset(n, m, 7, 4);
    let theta = Theta::default_packed(7);
    let exact = lkgp::gp::lkgp::mll_exact(&theta, &data)?;

    let mut table = Table::new(&["probes", "time_ms", "value_std", "value_bias"]);
    for p in [2usize, 4, 8, 16, 32] {
        let cfg = SolverCfg { probes: p, ..Default::default() };
        let mut values = Vec::new();
        let (_, t) = time_once(|| {
            for s in 0..6 {
                let probes = Pcg64::new(100 + s).rademacher_vec(p * n * m);
                let eval = lkgp::gp::lkgp::mll_value_grad(&theta, &data, &probes, &cfg).unwrap();
                values.push(eval.value);
            }
        });
        let (mean, _) = lkgp::metrics::mean_stderr(&values);
        let std = (values.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>()
            / values.len() as f64)
            .sqrt();
        table.row(vec![
            p.to_string(),
            format!("{:.1}", t.as_secs_f64() * 1e3 / 6.0),
            format!("{std:.3}"),
            format!("{:.3}", mean - exact),
        ]);
    }
    table.write_csv("results/ablations_probes.csv")?;
    Ok(())
}

/// XLA bucket padding: same logical problem executed at its natural size
/// vs padded into a larger bucket. Needs the `xla` feature.
fn padding_overhead() -> lkgp::Result<()> {
    println!("\n== ablation: artifact bucket padding overhead ==");
    #[cfg(not(feature = "xla"))]
    println!("(xla feature disabled; skipped)");
    #[cfg(feature = "xla")]
    {
        let dir = lkgp::runtime::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            println!("(artifacts not built; skipped)");
            return Ok(());
        }
        let mut eng = lkgp::runtime::XlaEngine::load(&dir)?;
        let theta = Theta::default_packed(7);
        let mut table = Table::new(&["n", "bucket_n", "mll_grad_ms"]);
        // 52-epoch, d=7 quality buckets: n in {16, 32, 64}
        for n in [12usize, 16, 24, 32, 48, 64] {
            let data = toy_dataset(n, 52, 7, n as u64);
            let Ok(spec) = eng.manifest().pick("mll_grad", n, 52, 7) else {
                continue;
            };
            let bucket_n = spec.n;
            let stats = bench(
                || {
                    let _ = eng.mll_grad(&theta, &data, 1).unwrap();
                },
                3,
                std::time::Duration::from_millis(300),
            );
            table.row(vec![
                n.to_string(),
                bucket_n.to_string(),
                format!("{:.1}", stats.median_secs() * 1e3),
            ]);
        }
        table.write_csv("results/ablations_padding.csv")?;
    }
    Ok(())
}

/// Dynamic batching: burst of single-query requests vs one batched call.
fn batching_throughput() -> lkgp::Result<()> {
    println!("\n== ablation: prediction-service dynamic batching ==");
    let mut reg = Registry::new();
    let mut rng = Pcg64::new(7);
    for _ in 0..24 {
        let id = reg.add(vec![rng.uniform(), rng.uniform(), rng.uniform()]);
        for j in 0..4 + rng.below(8) {
            reg.observe(id, 0.5 + 0.03 * j as f64, 16).unwrap();
        }
    }
    let snap = CurveStore::new(16).snapshot(&reg)?;
    let theta = Theta::default_packed(3);

    let mut table = Table::new(&["mode", "requests", "wall_ms", "batch_factor"]);
    for &burst in &[8usize, 32, 64] {
        let service = PredictionService::spawn(Box::<RustEngine>::default());
        let (_, wall) = time_once(|| {
            let mut receivers = Vec::new();
            for i in 0..burst {
                let (rtx, rrx) = channel();
                service
                    .sender()
                    .send(Request::PredictFinal {
                        snapshot: snap.clone(),
                        theta: theta.clone(),
                        xq: Matrix::from_vec(1, 3, vec![0.1 * (i % 10) as f64, 0.5, 0.5]),
                        resp: rtx,
                    })
                    .unwrap();
                receivers.push(rrx);
            }
            for r in receivers {
                r.recv().unwrap().unwrap();
            }
        });
        table.row(vec![
            "batched".into(),
            burst.to_string(),
            format!("{:.2}", wall.as_secs_f64() * 1e3),
            format!("{:.1}", service.stats.batch_factor()),
        ]);

        // sequential: one at a time (no queue depth to coalesce)
        let service = PredictionService::spawn(Box::<RustEngine>::default());
        let (_, wall) = time_once(|| {
            for i in 0..burst {
                let _ = service
                    .predict_final(
                        snap.clone(),
                        theta.clone(),
                        Matrix::from_vec(1, 3, vec![0.1 * (i % 10) as f64, 0.5, 0.5]),
                    )
                    .unwrap();
            }
        });
        table.row(vec![
            "sequential".into(),
            burst.to_string(),
            format!("{:.2}", wall.as_secs_f64() * 1e3),
            format!("{:.1}", service.stats.batch_factor()),
        ]);
    }
    table.write_csv("results/ablations_batching.csv")?;
    Ok(())
}
