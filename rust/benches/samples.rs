//! Pathwise posterior-sampling bench (`ci.sh` `samples` gate):
//!
//! * zero-solve warm sampling — a `CurveSamples` draw against a warm
//!   pathwise lineage must run **zero** CG solves (counter-asserted via
//!   `Posterior::{solve_calls, pathwise_hits, sample_mvms}`)
//! * marginal cost — the incremental cost of one extra sample on a warm
//!   lineage must stay within a small multiple of one masked-Kronecker
//!   MVM (one factored apply + the prior draw + the correction matmuls),
//!   far below a CG solve
//! * throughput — drawing all samples through the warm pathwise lineage
//!   must clear a 5x floor over the per-sample-solve baseline (one full
//!   legacy sampling call per sample) at the full sample count
//! * writer/replica parity — a replica posterior seeded with the writer's
//!   `(alpha, PathLineage)` must reproduce the writer's draws bit for bit
//!
//! Besides BENCH_samples.json / results/samples.csv, the bench prints one
//! `SAMPLES_CHECKSUM <hex>` line: an FNV-1a digest over the bits of every
//! warm-path sample drawn at the *ambient* `util::num_threads()`. ci.sh
//! runs the bench twice (LKGP_THREADS=1 and =4) and compares the lines —
//! the cross-process half of the sampling determinism contract
//! (docs/sampling.md, docs/parallelism.md).

use std::sync::Arc;
use std::time::Duration;

use lkgp::bench_util::{bench, Table};
use lkgp::gp::kernels;
use lkgp::gp::operator::MaskedKronOp;
use lkgp::gp::lkgp::posterior_samples;
use lkgp::gp::session::{Answer, Posterior, Query};
use lkgp::gp::{SolverCfg, Theta};
use lkgp::json::Json;
use lkgp::lcbench::fig3_dataset;
use lkgp::linalg::Matrix;
use lkgp::rng::Pcg64;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv_bits(values: &[f64], mut h: u64) -> u64 {
    for v in values {
        for b in v.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    }
    h
}

fn curves(a: &Answer) -> &Vec<Matrix> {
    match a {
        Answer::Curves(c) => c,
        other => panic!("expected Curves, got {other:?}"),
    }
}

fn main() -> lkgp::Result<()> {
    let quick = lkgp::bench_util::is_quick();
    let n = if quick { 48 } else { 96 };
    let s = if quick { 16 } else { 64 };
    let q = 8usize;
    let seed = 1234u64;

    let mut rng = Pcg64::new(7);
    let data = Arc::new(fig3_dataset(n, &mut rng));
    let (nn, m, d) = (data.n(), data.m(), data.d());
    let theta = Theta::default_packed(d);
    let cfg = SolverCfg::default();
    let xq = Matrix::from_vec(q, d, rng.uniform_vec(q * d, 0.0, 1.0));
    let query = |count: usize, seed: u64| Query::CurveSamples { xq: xq.clone(), n: count, seed };
    let mut table = Table::new(&["op", "samples", "median_us", "note"]);

    // ---- writer: cold pathwise call pays exactly the training solve ------
    let mut writer = Posterior::new(data.clone(), theta.clone(), cfg.clone());
    let writer_draw = writer.answer(&query(s, seed))?;
    assert_eq!(writer.solve_calls(), 1, "cold pathwise pays only the training solve");
    let lineage = writer.path_state().expect("pathwise base cached on the writer");
    let alpha = writer.alpha().expect("training solve cached").to_vec();

    // ---- zero-solve warm sampling (the hard gate) ------------------------
    let mut probe = writer.fork();
    let probe_draw = probe.answer(&query(s, seed))?;
    let zero_solve_ok = probe.solve_calls() == 0
        && probe.pathwise_hits() == 1
        && probe.sample_mvms() == s
        && probe_draw.bits_eq(&writer_draw);
    table.row(vec![
        "warm_draw".into(),
        s.to_string(),
        "-".into(),
        format!(
            "solves={} hits={} mvms={}",
            probe.solve_calls(),
            probe.pathwise_hits(),
            probe.sample_mvms()
        ),
    ]);

    // ---- marginal cost: (t_s - t_1) / (s - 1) vs one masked-Kron MVM -----
    let t1_us = {
        let stats = bench(
            || {
                let mut f = writer.fork();
                let _ = f.answer(&query(1, seed)).unwrap();
            },
            3,
            Duration::from_millis(300),
        );
        stats.median_secs() * 1e6
    };
    let ts_us = {
        let stats = bench(
            || {
                let mut f = writer.fork();
                let _ = f.answer(&query(s, seed)).unwrap();
            },
            3,
            Duration::from_millis(300),
        );
        stats.median_secs() * 1e6
    };
    let marginal_us = ((ts_us - t1_us) / (s - 1) as f64).max(0.0);

    let th = Theta::unpack(&theta);
    let k1 = kernels::rbf(&data.x, &data.x, &th.lengthscales);
    let k2 = kernels::matern12(&data.t, &data.t, th.t_lengthscale, th.outputscale);
    let op = MaskedKronOp::new(&k1, &k2, &data.mask, th.sigma2);
    let mvm_us = {
        let x = rng.normal_vec(nn * m);
        let mut out = vec![0.0; nn * m];
        let stats = bench(|| op.apply_batch(&x, &mut out, 1), 5, Duration::from_millis(200));
        stats.median_secs() * 1e6
    };
    // One extra sample = prior draw + one factored apply + the correction
    // matmuls: a handful of MVM-equivalents, never a solve (tens to
    // hundreds of MVMs). The 16x headroom absorbs timer noise while still
    // separating the two regimes by an order of magnitude.
    let marginal_ok = marginal_us <= 16.0 * mvm_us.max(1e-3);
    table.row(vec![
        "warm_marginal".into(),
        format!("{}->{s}", 1),
        format!("{marginal_us:.1}"),
        format!("one_mvm={mvm_us:.1}us"),
    ]);

    // ---- throughput vs the per-sample-solve baseline ---------------------
    let legacy_cfg = SolverCfg { pathwise: false, ..cfg.clone() };
    let base_us = {
        let stats = bench(
            || {
                // the historical serving shape: every sample request pays
                // its own training + sampling solve
                for i in 0..s {
                    let mut r = Pcg64::new(seed ^ i as u64);
                    let _ = posterior_samples(&theta, &data, &xq, 1, &legacy_cfg, &mut r).unwrap();
                }
            },
            1,
            Duration::from_millis(100),
        );
        stats.median_secs() * 1e6
    };
    let speedup = base_us / ts_us.max(1e-9);
    let speedup_ok = speedup >= 5.0;
    table.row(vec![
        "per_sample_solve".into(),
        s.to_string(),
        format!("{base_us:.1}"),
        format!("speedup={speedup:.1}x"),
    ]);

    // ---- writer/replica parity -------------------------------------------
    // The replica_serve shape: fresh posterior + the writer's converged
    // (alpha, lineage); must reproduce the writer's draws bit for bit.
    let mut replica = Posterior::new(data.clone(), theta.clone(), cfg.clone())
        .with_solves(alpha, None, Vec::new())
        .with_path(Some(lineage));
    let replica_draw = replica.answer(&query(s, seed))?;
    let parity_ok = replica.solve_calls() == 0 && replica_draw.bits_eq(&writer_draw);
    table.row(vec![
        "replica_parity".into(),
        s.to_string(),
        "-".into(),
        if parity_ok { "bitwise==writer".into() } else { "DIVERGED".into() },
    ]);

    // ---- SAMPLES_CHECKSUM: ambient-thread-count sample digest ------------
    // ci.sh compares this line across LKGP_THREADS=1 / =4 runs.
    let mut checksum = FNV_OFFSET;
    for smp in curves(&writer_draw) {
        checksum = fnv_bits(smp.data(), checksum);
    }
    println!("SAMPLES_CHECKSUM {checksum:016x}");

    table.write_csv("results/samples.csv")?;
    println!("\nwrote results/samples.csv");

    let summary = Json::obj(vec![
        ("bench", Json::Str("samples".into())),
        ("n", Json::Num(nn as f64)),
        ("m", Json::Num(m as f64)),
        ("q", Json::Num(q as f64)),
        ("samples", Json::Num(s as f64)),
        ("ambient_threads", Json::Num(lkgp::util::num_threads() as f64)),
        ("warm_t1_us", Json::Num(t1_us)),
        ("warm_ts_us", Json::Num(ts_us)),
        ("marginal_us", Json::Num(marginal_us)),
        ("one_mvm_us", Json::Num(mvm_us)),
        ("per_sample_solve_us", Json::Num(base_us)),
        ("speedup_vs_per_sample_solve", Json::Num(speedup)),
        ("samples_checksum", Json::Str(format!("{checksum:016x}"))),
        ("assert_samples_zero_solve_warm", Json::Bool(zero_solve_ok)),
        ("assert_samples_marginal_mvm", Json::Bool(marginal_ok)),
        ("assert_samples_speedup", Json::Bool(speedup_ok)),
        ("assert_samples_replica_parity", Json::Bool(parity_ok)),
    ]);
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap_or_else(|| std::path::Path::new("."))
        .to_path_buf();
    std::fs::write(root.join("BENCH_samples.json"), summary.pretty())?;
    println!("wrote {}", root.join("BENCH_samples.json").display());
    Ok(())
}
