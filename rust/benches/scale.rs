//! Online-ingestion scale bench (`ci.sh` `scale` gate): the steady
//! epoch-arrival serving regime at 10k+ simulated tasks, exercising the
//! hash-bucketed shard routing and the `Request::Observe` warm re-solve
//! path end to end (docs/serving.md).
//!
//! Floors recorded in `BENCH_scale.json`:
//!
//! * admission — a 10k-task corpus admits (lazily, no engines built) at
//!   >= 2 tasks/s
//! * steady-state throughput — a hot working set streaming epoch
//!   arrivals through observe + query sustains >= 10 ops/s
//! * bounded residency — live engines never exceed the bucket count,
//!   bucket count stays below the task count, and the idle-eviction
//!   sweep frees at least one quiet shard between waves
//! * observe is cheap — an `Observe` performs zero MLL evals (counter
//!   proof: `engine_solves` does not move during an observe-only run)
//!   and costs >= 10x fewer operator MVM rows than an equivalent `Refit`

use std::sync::atomic::Ordering::Relaxed;
use std::sync::Arc;
use std::time::Instant;

use lkgp::bench_util::Table;
use lkgp::coordinator::{
    CurveStore, EngineFactory, PoolCfg, PredictClient, Registry, ServicePool, TrialId,
};
use lkgp::json::Json;
use lkgp::lcbench::corpus::{Corpus, SimCorpus};
use lkgp::lcbench::Task;
use lkgp::linalg::Matrix;
use lkgp::runtime::RustEngine;

/// One hot task's client-side state: its registry grows by one epoch per
/// arrival, exactly like a live trainer reporting progress.
struct Hot {
    id: usize,
    task: Arc<Task>,
    reg: Registry,
    store: CurveStore,
    epoch: usize,
    theta: Vec<f64>,
}

fn admit(corpus: &SimCorpus, id: usize, warmup_epochs: usize) -> lkgp::Result<Hot> {
    let task = corpus.task(id)?;
    let mut reg = Registry::new();
    for i in 0..task.n() {
        let tid = reg.add(task.configs.row(i).to_vec());
        for j in 0..warmup_epochs {
            reg.observe(tid, task.curves[(i, j.min(task.m() - 1))], task.m())?;
        }
    }
    let store = CurveStore::new(task.m());
    Ok(Hot { id, task, reg, store, epoch: warmup_epochs, theta: Vec::new() })
}

impl Hot {
    /// One epoch arrives for every trial of this task.
    fn extend(&mut self) -> lkgp::Result<()> {
        let j = self.epoch.min(self.task.m() - 1);
        for i in 0..self.task.n() {
            self.reg.observe(TrialId(i), self.task.curves[(i, j)], self.task.m())?;
        }
        self.epoch += 1;
        Ok(())
    }
}

/// Establish each hot task's generation-1 lineage with a real refit, then
/// stream `rounds` epoch arrivals through observe + query. Returns the
/// ops count of the streamed (post-refit) phase.
fn run_wave(pool: &ServicePool, hots: &mut [Hot], rounds: usize, seed: u64) -> lkgp::Result<usize> {
    std::thread::scope(|scope| -> lkgp::Result<()> {
        let mut joins = Vec::new();
        for hot in hots.iter_mut() {
            joins.push(scope.spawn(move || -> lkgp::Result<()> {
                let snap = hot.store.snapshot(&hot.reg)?;
                hot.theta =
                    pool.handle(hot.id).refit(snap, Vec::new(), seed + hot.id as u64)?;
                Ok(())
            }));
        }
        for j in joins {
            j.join().expect("refit thread panicked")?;
        }
        Ok(())
    })?;
    let mut ops = 0usize;
    std::thread::scope(|scope| -> lkgp::Result<()> {
        let mut joins = Vec::new();
        for hot in hots.iter_mut() {
            joins.push(scope.spawn(move || -> lkgp::Result<usize> {
                let mut ops = 0usize;
                for r in 0..rounds {
                    hot.extend()?;
                    let snap = hot.store.snapshot(&hot.reg)?;
                    let report = pool.handle(hot.id).observe(snap.clone(), Vec::new())?;
                    ops += 1;
                    if report.refit_due {
                        // the policy judged theta stale — pay a real refit
                        hot.theta = pool
                            .handle(hot.id)
                            .refit(snap.clone(), Vec::new(), seed + hot.id as u64)?;
                        ops += 1;
                    }
                    let d = snap.all_x.cols();
                    let row = r % snap.all_x.rows();
                    let xq = Matrix::from_vec(1, d, snap.all_x.row(row).to_vec());
                    let preds = pool.handle(hot.id).predict_final(snap, hot.theta.clone(), xq)?;
                    assert!(preds[0].0.is_finite(), "query after observe must be finite");
                    ops += 1;
                }
                Ok(ops)
            }));
        }
        for j in joins {
            ops += j.join().expect("storm thread panicked")?;
        }
        Ok(())
    })?;
    Ok(ops)
}

fn main() -> lkgp::Result<()> {
    let quick = lkgp::bench_util::is_quick();
    let tasks = if quick { 1_000 } else { 10_000 };
    let buckets = 16usize;
    let wave = if quick { 6 } else { 16 };
    let rounds = if quick { 3 } else { 5 };
    let n_configs = 6usize;
    let seed = 42u64;
    let mut table = Table::new(&["phase", "value", "note"]);

    // ---- admission: 10k tasks folded onto a fixed bucket set -------------
    let t0 = Instant::now();
    let corpus = SimCorpus::new(tasks, n_configs, seed);
    let factory: EngineFactory = Box::new(|_| Box::new(RustEngine::default()));
    let pool = ServicePool::from_corpus(
        &corpus,
        factory,
        PoolCfg { workers: 4, buckets, ..Default::default() },
    );
    let admit_secs = t0.elapsed().as_secs_f64().max(1e-9);
    let admission_rate = tasks as f64 / admit_secs;
    assert_eq!(pool.shards(), tasks, "every task stays addressable");
    assert_eq!(pool.buckets(), buckets, "tasks fold onto the bucket set");
    let admission_ok = admission_rate >= 2.0;
    table.row(vec![
        "admission".into(),
        format!("{admission_rate:.0}/s"),
        format!("{tasks} tasks, {buckets} buckets"),
    ]);

    // ---- wave 1: hot working set streams observe + query -----------------
    let mut hots: Vec<Hot> = (0..wave)
        .map(|k| admit(&corpus, k * (tasks / wave), 3))
        .collect::<lkgp::Result<Vec<_>>>()?;
    let t1 = Instant::now();
    let ops = run_wave(&pool, &mut hots, rounds, seed)?;
    let storm_secs = t1.elapsed().as_secs_f64().max(1e-9);
    let ops_per_sec = ops as f64 / storm_secs;
    let throughput_ok = ops_per_sec >= 10.0;
    let live_wave1 = pool.live_shards();
    table.row(vec![
        "steady_state".into(),
        format!("{ops_per_sec:.1} ops/s"),
        format!("{ops} observe+query ops, {wave} hot tasks"),
    ]);

    // ---- eviction between waves: the resident set follows the hot set ----
    // First sweep baselines the traffic counters, second finds everything
    // quiet and tears the engines down.
    pool.evict_idle();
    let freed = pool.evict_idle();
    let live_after_evict = pool.live_shards();
    // wave 2: a disjoint hot set re-materializes shards transparently
    let mut hots2: Vec<Hot> = (0..wave)
        .map(|k| admit(&corpus, k * (tasks / wave) + tasks / (2 * wave), 3))
        .collect::<lkgp::Result<Vec<_>>>()?;
    run_wave(&pool, &mut hots2, 1, seed ^ 0x9e37)?;
    let live_wave2 = pool.live_shards();
    let max_live = live_wave1.max(live_wave2);
    let resident_ok = max_live <= buckets && buckets < tasks && freed >= 1;
    table.row(vec![
        "residency".into(),
        format!("{max_live} live"),
        format!(
            "{buckets} buckets, {} materialized, {} evicted ({} after sweep)",
            pool.materialized(),
            pool.evicted(),
            live_after_evict
        ),
    ]);

    // ---- observe vs refit cost in operator MVM rows ----------------------
    // Observe-only window first: `engine_solves` must not move (an Observe
    // performs no MLL evaluations and no query solves), then a lone refit
    // for the per-op comparison.
    let probe = &mut hots[0];
    let stats = pool.stats(probe.id).clone();
    let k_obs = 3usize;
    let solves_before = stats.engine_solves.load(Relaxed);
    let obs_rows_before = stats.observe_solve_mvm_rows.load(Relaxed);
    let obs_before = stats.observes.load(Relaxed);
    for _ in 0..k_obs {
        probe.extend()?;
        let snap = probe.store.snapshot(&probe.reg)?;
        pool.handle(probe.id).observe(snap, Vec::new())?;
    }
    let zero_fit_ok = stats.engine_solves.load(Relaxed) == solves_before
        && stats.observes.load(Relaxed) == obs_before + k_obs as u64;
    let observe_rows_per_op = (stats.observe_solve_mvm_rows.load(Relaxed) - obs_rows_before)
        as f64
        / k_obs as f64;

    let cg_rows_before = stats.cg_mvm_rows.load(Relaxed);
    probe.extend()?;
    let snap = probe.store.snapshot(&probe.reg)?;
    pool.handle(probe.id).refit(snap, Vec::new(), seed + 7)?;
    let refit_rows = (stats.cg_mvm_rows.load(Relaxed) - cg_rows_before) as f64;
    let ratio = refit_rows / observe_rows_per_op.max(1e-9);
    let observe_cheap_ok = observe_rows_per_op > 0.0 && ratio >= 10.0;
    table.row(vec![
        "observe_cost".into(),
        format!("{observe_rows_per_op:.0} rows/op"),
        format!("refit={refit_rows:.0} rows, ratio={ratio:.1}x"),
    ]);

    let (total_observes, total_refits_triggered) = pool
        .all_stats()
        .iter()
        .fold((0u64, 0u64), |(o, r), s| {
            (o + s.observes.load(Relaxed), r + s.refits_triggered.load(Relaxed))
        });

    table.write_csv("results/scale.csv")?;
    println!("\nwrote results/scale.csv");

    let summary = Json::obj(vec![
        ("bench", Json::Str("scale".into())),
        ("tasks", Json::Num(tasks as f64)),
        ("buckets", Json::Num(buckets as f64)),
        ("hot_tasks", Json::Num(wave as f64)),
        ("rounds", Json::Num(rounds as f64)),
        ("admission_tasks_per_sec", Json::Num(admission_rate)),
        ("steady_ops_per_sec", Json::Num(ops_per_sec)),
        ("max_live_shards", Json::Num(max_live as f64)),
        ("evicted_between_waves", Json::Num(freed as f64)),
        ("observe_rows_per_op", Json::Num(observe_rows_per_op)),
        ("refit_rows_per_op", Json::Num(refit_rows)),
        ("refit_over_observe_rows", Json::Num(ratio)),
        ("observes_total", Json::Num(total_observes as f64)),
        ("refits_triggered_total", Json::Num(total_refits_triggered as f64)),
        ("assert_scale_admission", Json::Bool(admission_ok)),
        ("assert_scale_throughput", Json::Bool(throughput_ok)),
        ("assert_scale_resident_bounded", Json::Bool(resident_ok)),
        ("assert_scale_observe_zero_fit", Json::Bool(zero_fit_ok)),
        ("assert_scale_observe_cheap", Json::Bool(observe_cheap_ok)),
    ]);
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap_or_else(|| std::path::Path::new("."))
        .to_path_buf();
    std::fs::write(root.join("BENCH_scale.json"), summary.pretty())?;
    println!("wrote {}", root.join("BENCH_scale.json").display());
    Ok(())
}
