//! Figure 3 reproduction: time & memory vs training-set size for LKGP
//! (iterative, latent Kronecker) vs naive Cholesky of the joint covariance.
//!
//! Protocol (paper §C): X ~ U[0,1]^{n x 10}, Y ~ N(0,1)^{n x m}, t linear
//! on [0,1], n = m in {16, 32, ..., 512}, no missing data. "Training"
//! optimizes noise + kernel parameters (a fixed number of optimizer steps,
//! identical for both engines); "prediction" samples full learning curves
//! for query configurations.
//!
//! Differences vs the paper's measurement (documented in EXPERIMENTS.md):
//! CPU instead of V100, so absolute numbers differ; the *shape* of the
//! curves — near-cubic-in-n wall for naive vs gentle growth for LKGP, OOM
//! vs easily-scaling memory — is the reproduced claim. Memory is reported
//! as exact noted-allocation pressure (both engines share the same
//! containers) plus RSS growth.
//!
//! Output: results/fig3_scaling.csv + a table on stdout.
//! Flags: --quick (CI sizes), --max-size N, --naive-max N, --steps K,
//!        --xla (adds the AOT-artifact engine series where buckets exist).

#![allow(deprecated)] // exercises the deprecated free-function shims by design

use std::time::Duration;

use lkgp::bench_util::{time_once, Table};
use lkgp::gp::lkgp::SolverCfg;
use lkgp::gp::{naive, trainer, Theta};
use lkgp::lcbench::fig3_dataset;
use lkgp::linalg::Matrix;
use lkgp::metrics::alloc::AllocTracker;
use lkgp::rng::Pcg64;
#[cfg(feature = "xla")]
use lkgp::runtime::Engine;
use lkgp::util::Args;

fn main() -> lkgp::Result<()> {
    let args = Args::from_env();
    let quick = lkgp::bench_util::is_quick();
    // Defaults bounded for the single-core CI box; pass --max-size 512
    // --naive-max 128 for the paper's full sweep on real hardware.
    let max_size = args.get_usize("max-size", if quick { 64 } else { 256 });
    let naive_max = args.get_usize("naive-max", if quick { 32 } else { 64 });
    let steps = args.get_usize("steps", 2);
    // Fig-3 protocol data (random N(0,1) targets, noise starting at e^-4)
    // is maximally ill-conditioned for CG; the paper notes its solver
    // "converges in fewer iterations than mathematically required". We cap
    // iterations per solve (documented in EXPERIMENTS.md) — the sweep
    // measures scaling, not solution accuracy on random targets.
    let cg_cap = args.get_usize("cg-cap", 100);
    let queries = 16; // predict: sample curves for query configs
    let samples = 4;

    let mut table = Table::new(&[
        "size", "engine", "train_s", "predict_s", "peak_alloc_mb", "rss_mb",
    ]);

    let mut size = 16;
    while size <= max_size {
        let mut rng = Pcg64::new(size as u64);
        let data = fig3_dataset(size, &mut rng);
        let theta0 = Theta::default_packed(10);
        let xq = Matrix::from_vec(queries, 10, rng.uniform_vec(queries * 10, 0.0, 1.0));

        // ---- LKGP (iterative, rust engine) ----
        {
            let cfg = SolverCfg { cg_max_iters: cg_cap, ..Default::default() };
            let tracker = AllocTracker::start();
            let probes = Pcg64::new(1).rademacher_vec(cfg.probes * size * size);
            let (theta, train_t) = time_once(|| {
                let mut obj = |p: &[f64]| {
                    lkgp::gp::lkgp::mll_value_grad(p, &data, &probes, &cfg)
                        .map(|e| (e.value, e.grad))
                };
                trainer::adam(
                    &mut obj,
                    &theta0,
                    &trainer::AdamCfg { steps, ..Default::default() },
                )
                .map(|t| t.theta)
            });
            let theta = theta?;
            let (_, pred_t) = time_once(|| {
                let mut prng = Pcg64::new(2);
                lkgp::gp::lkgp::posterior_samples(&theta, &data, &xq, samples, &cfg, &mut prng)
            });
            table.row(vec![
                size.to_string(),
                "lkgp".into(),
                format!("{:.3}", train_t.as_secs_f64()),
                format!("{:.3}", pred_t.as_secs_f64()),
                format!("{:.1}", tracker.peak_noted() as f64 / 1e6),
                format!("{:.1}", tracker.rss_growth() as f64 / 1e6),
            ]);
        }

        // ---- LKGP through the AOT artifacts (optional series) ----
        #[cfg(feature = "xla")]
        if args.has("xla") {
            if let Ok(mut eng) =
                lkgp::runtime::XlaEngine::load(&lkgp::runtime::artifacts_dir())
            {
                if eng.manifest().pick("fit_adam", size, size, 10).is_ok() {
                    let tracker = AllocTracker::start();
                    let (theta, train_t) = time_once(|| eng.fit(&theta0, &data, 1));
                    let theta = theta?;
                    let (res, pred_t) =
                        time_once(|| eng.sample_curves(&theta, &data, &xq, samples, 2));
                    res?;
                    table.row(vec![
                        size.to_string(),
                        "lkgp_xla".into(),
                        format!("{:.3}", train_t.as_secs_f64()),
                        format!("{:.3}", pred_t.as_secs_f64()),
                        format!("{:.1}", tracker.peak_noted() as f64 / 1e6),
                        format!("{:.1}", tracker.rss_growth() as f64 / 1e6),
                    ]);
                }
            }
        }

        // ---- naive Cholesky (the paper's baseline) ----
        if size <= naive_max {
            let tracker = AllocTracker::start();
            let (theta, train_t) = time_once(|| {
                let mut obj = |p: &[f64]| naive::mll_value_grad_exact(p, &data);
                trainer::adam(
                    &mut obj,
                    &theta0,
                    &trainer::AdamCfg { steps, ..Default::default() },
                )
                .map(|t| t.theta)
            });
            let theta = theta?;
            let (res, pred_t) = time_once(|| {
                let mut prng = Pcg64::new(2);
                naive::sample_curves_exact(&theta, &data, &xq, samples, &mut prng)
            });
            res?;
            table.row(vec![
                size.to_string(),
                "naive".into(),
                format!("{:.3}", train_t.as_secs_f64()),
                format!("{:.3}", pred_t.as_secs_f64()),
                format!("{:.1}", tracker.peak_noted() as f64 / 1e6),
                format!("{:.1}", tracker.rss_growth() as f64 / 1e6),
            ]);
        } else {
            // project the O(n^3 m^3) cost so the table still tells the story
            table.row(vec![
                size.to_string(),
                "naive".into(),
                "skipped(O(n^6) wall)".into(),
                "-".into(),
                format!("{:.1}", (size * size) as f64 * (size * size) as f64 * 8.0 / 1e6),
                "-".into(),
            ]);
        }

        size *= 2;
        // keep total bench time bounded
        let _ = Duration::from_secs(0);
    }

    table.write_csv("results/fig3_scaling.csv")?;
    println!("\nwrote results/fig3_scaling.csv");
    Ok(())
}
