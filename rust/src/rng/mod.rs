//! Deterministic random number generation.
//!
//! The offline crate set has no usable RNG crates, so this module is fully
//! self-contained: a PCG64 (XSL-RR 128/64) engine plus Gaussian /
//! Rademacher / uniform helpers, with `next_u64`/`next_u32`/`fill_bytes`
//! as inherent methods (no `rand_core` trait plumbing).
//!
//! Everything randomized in the system — probe vectors for Hutchinson/SLQ,
//! Matheron prior draws, synthetic benchmark data, scheduler tie-breaking —
//! flows through [`Pcg64`] seeded from a `u64`, which makes artifact
//! executions bitwise reproducible (randomness is an *input* to the AOT
//! graphs, never generated inside them).

/// PCG XSL-RR 128/64 generator (O'Neill 2014), the same parameterization
/// rand's `Pcg64` uses. 128-bit LCG state, 64-bit xor-shift/rotate output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed (stream constant fixed).
    pub fn new(seed: u64) -> Self {
        let mut rng = Self {
            state: 0,
            inc: (0xda3e_39cb_94b9_5bdb_u128 << 1) | 1,
        };
        rng.state = rng.inc.wrapping_add(seed as u128);
        rng.step();
        rng
    }

    /// Derive an independent child generator (used to give each worker /
    /// trial / probe batch its own stream without coordination).
    pub fn fork(&mut self, tag: u64) -> Self {
        let s = self.next_u64() ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        Self::new(s)
    }

    #[inline]
    fn step(&mut self) {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
    }

    /// Next 64 uniformly random bits (XSL-RR output function).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Next 32 uniformly random bits (upper half of `next_u64`).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Fill a byte buffer with random bytes.
    pub fn fill_bytes(&mut self, dest: &mut [u8]) {
        let mut chunks = dest.chunks_exact_mut(8);
        for chunk in &mut chunks {
            chunk.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let bytes = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&bytes[..rem.len()]);
        }
    }

    /// Build from an 8-byte little-endian seed.
    pub fn from_seed(seed: [u8; 8]) -> Self {
        Self::new(u64::from_le_bytes(seed))
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard normal via Box-Muller (polar-free, deterministic pairing).
    pub fn normal(&mut self) -> f64 {
        // Box-Muller with cached second value would introduce state
        // dependence on call parity across forks; recompute instead —
        // normals are not the hot path.
        let u1 = (1.0 - self.uniform()).max(f64::MIN_POSITIVE);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Rademacher sample in {-1.0, +1.0}.
    #[inline]
    pub fn rademacher(&mut self) -> f64 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Multiply-shift rejection-free mapping is fine for our uses.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, len: usize) -> Vec<f64> {
        (0..len).map(|_| self.normal()).collect()
    }

    /// Vector of Rademacher +-1.
    pub fn rademacher_vec(&mut self, len: usize) -> Vec<f64> {
        (0..len).map(|_| self.rademacher()).collect()
    }

    /// Vector of uniforms in [lo, hi).
    pub fn uniform_vec(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.uniform_in(lo, hi)).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k.min(n));
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fill_bytes_and_from_seed() {
        let mut a = Pcg64::from_seed(42u64.to_le_bytes());
        let mut b = Pcg64::new(42);
        assert_eq!(a.next_u64(), b.next_u64());
        let mut buf = [0u8; 13];
        a.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&x| x != 0));
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_bounds_and_moments() {
        let mut rng = Pcg64::new(7);
        let n = 20000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(9);
        let n = 40000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn rademacher_is_pm_one_and_balanced() {
        let mut rng = Pcg64::new(3);
        let n = 10000;
        let mut pos = 0;
        for _ in 0..n {
            let r = rng.rademacher();
            assert!(r == 1.0 || r == -1.0);
            if r > 0.0 {
                pos += 1;
            }
        }
        assert!((pos as f64 / n as f64 - 0.5).abs() < 0.03);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg64::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let i = rng.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(11);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Pcg64::new(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg64::new(2);
        let idx = rng.sample_indices(20, 8);
        assert_eq!(idx.len(), 8);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 8);
    }
}
