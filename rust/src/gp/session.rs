//! Session-scoped inference API: [`FitSession`], [`Posterior`], and typed
//! [`Query`]s.
//!
//! The engine's math is one thing — latent-Kronecker MVMs plus iterative
//! solvers — but the crate historically exposed it as three parallel
//! families of free functions (`mll_value_grad{,_warm,_cached}`,
//! `predict_final{,_warm,_cached}`, `predict_mean`, `posterior_samples`)
//! whose warm-start buffers and preconditioner factors every caller had to
//! hand-thread. This module folds that lineage into two session objects:
//!
//! * [`FitSession`] owns the dataset, the probe set, the warm solve buffer
//!   and the factored preconditioner across optimizer steps. Warm vs cold
//!   vs cached is a lifecycle state of the session, not a choice of
//!   function name.
//! * [`Posterior`] freezes one `(dataset, theta)` pair and answers typed
//!   [`Query`] values. Queries submitted together share one underlying
//!   batched solve (`[y, c_1..c_q]` with deduplicated cross-covariance
//!   columns), and the converged `alpha` is reused across every later
//!   query against the same session.
//!
//! The historical free functions survive as `#[deprecated]` thin shims
//! over this API (bit-exact: they build a one-shot session and delegate),
//! and the serving layer routes `coordinator::Request::Query` batches here
//! through `runtime::Engine::answer_batch`. See `docs/api.md` for the
//! lifecycle and the migration table.

use std::sync::Arc;

use crate::error::{LkgpError, Result};
use crate::gp::kernels;
use crate::gp::params::Theta;
use crate::gp::trainer::{self, FitTrace};
use crate::linalg::{CgStats, Matrix};
use crate::rng::Pcg64;

use super::lkgp::{self, Dataset, MllEval, SolverCfg};
use super::operator::PrecondFactors;
use super::pathwise::{self, PathBase, PathLineage, PathQuery};

// ---------------------------------------------------------------------------
// Typed queries

/// A typed posterior query. Queries carry their own query-config matrices
/// so a heterogeneous batch can be answered by one session; final-step
/// queries (`MeanAtFinal`, `Variance`, `Quantiles`) against identical
/// configs share cross-covariance solve columns.
#[derive(Clone, Debug)]
pub enum Query {
    /// Exact Gaussian predictive of the final progression value:
    /// `(mean, variance-with-noise)` per query row.
    MeanAtFinal { xq: Matrix },
    /// Posterior mean at specific progression-grid steps: a
    /// `(xq.rows(), steps.len())` matrix. Needs only the training solve.
    MeanAtSteps { xq: Matrix, steps: Vec<usize> },
    /// Predictive variance (with noise) of the final value per query row.
    Variance { xq: Matrix },
    /// Gaussian predictive quantiles of the final value: a
    /// `(xq.rows(), ps.len())` matrix, levels strictly inside (0, 1).
    Quantiles { xq: Matrix, ps: Vec<f64> },
    /// `n` posterior curve samples over `[X; xq] x grid` via Matheron's
    /// rule, drawn from a fresh `Pcg64::new(seed)` stream.
    CurveSamples { xq: Matrix, n: usize, seed: u64 },
    /// MAP objective (value + gradient) under the session's theta, with a
    /// fresh Rademacher probe set from `seed`.
    Mll { seed: u64 },
}

/// The answer to one [`Query`], in the same order as submitted.
#[derive(Clone, Debug)]
pub enum Answer {
    /// `MeanAtFinal`: `(mean, variance-with-noise)` per query row.
    Final(Vec<(f64, f64)>),
    /// `MeanAtSteps`: `(q, steps.len())` posterior means.
    Steps(Matrix),
    /// `Variance`: final-step predictive variance per query row.
    Variance(Vec<f64>),
    /// `Quantiles`: `(q, ps.len())` predictive quantiles.
    Quantiles(Matrix),
    /// `CurveSamples`: one `(n + q, m)` matrix per sample.
    Curves(Vec<Matrix>),
    /// `Mll`: objective value, gradient and solve stats.
    Mll(MllEval),
}

impl Answer {
    /// Bitwise equality of two answers — the parity predicate the replica
    /// gates and the concurrent trace replay share. Stronger than
    /// `PartialEq` on floats: every value must match bit for bit (NaNs
    /// included), and differing answer kinds never compare equal.
    pub fn bits_eq(&self, other: &Answer) -> bool {
        fn mat_eq(a: &Matrix, b: &Matrix) -> bool {
            a.rows() == b.rows()
                && a.cols() == b.cols()
                && a.data()
                    .iter()
                    .zip(b.data())
                    .all(|(x, y)| x.to_bits() == y.to_bits())
        }
        match (self, other) {
            (Answer::Final(a), Answer::Final(b)) => {
                a.len() == b.len()
                    && a.iter().zip(b).all(|(x, y)| {
                        x.0.to_bits() == y.0.to_bits() && x.1.to_bits() == y.1.to_bits()
                    })
            }
            (Answer::Variance(a), Answer::Variance(b)) => {
                a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
            }
            (Answer::Quantiles(a), Answer::Quantiles(b))
            | (Answer::Steps(a), Answer::Steps(b)) => mat_eq(a, b),
            (Answer::Curves(a), Answer::Curves(b)) => {
                a.len() == b.len() && a.iter().zip(b).all(|(x, y)| mat_eq(x, y))
            }
            (Answer::Mll(a), Answer::Mll(b)) => {
                a.value.to_bits() == b.value.to_bits()
                    && a.grad.len() == b.grad.len()
                    && a.grad
                        .iter()
                        .zip(&b.grad)
                        .all(|(x, y)| x.to_bits() == y.to_bits())
            }
            _ => false,
        }
    }
}

/// Stack the final-step query matrices of a batch into the layout the
/// shared `[y, c_1..c_q]` solve uses, deduplicating bitwise-identical
/// blocks (a `MeanAtFinal` + `Variance` + `Quantiles` trio over the same
/// configs costs one set of cross columns, not three). Returns the stacked
/// matrix and, per query, the `(row_offset, rows)` slice it reads.
/// Blocks whose width disagrees with the first block are skipped (the
/// session rejects such batches during validation; the serving layer only
/// uses the stacked matrix for warm-start embedding).
fn stack_final_queries(queries: &[Query]) -> (Option<Matrix>, Vec<Option<(usize, usize)>>) {
    let mut blocks: Vec<&Matrix> = Vec::new();
    let mut offsets: Vec<usize> = Vec::new();
    let mut total = 0usize;
    let mut slices: Vec<Option<(usize, usize)>> = Vec::with_capacity(queries.len());
    for q in queries {
        let xq = match q {
            Query::MeanAtFinal { xq } | Query::Variance { xq } | Query::Quantiles { xq, .. } => {
                Some(xq)
            }
            _ => None,
        };
        let Some(xq) = xq else {
            slices.push(None);
            continue;
        };
        if let Some(first) = blocks.first() {
            if first.cols() != xq.cols() {
                slices.push(None);
                continue;
            }
        }
        let found = blocks
            .iter()
            .position(|b| b.rows() == xq.rows() && b.cols() == xq.cols() && b.data() == xq.data());
        let off = match found {
            Some(i) => offsets[i],
            None => {
                let off = total;
                blocks.push(xq);
                offsets.push(off);
                total += xq.rows();
                off
            }
        };
        slices.push(Some((off, xq.rows())));
    }
    if blocks.is_empty() {
        return (None, slices);
    }
    let cols = blocks[0].cols();
    let mut stacked = Matrix::zeros(total, cols);
    let mut row = 0;
    for b in &blocks {
        for r in 0..b.rows() {
            stacked.row_mut(row).copy_from_slice(b.row(r));
            row += 1;
        }
    }
    (Some(stacked), slices)
}

/// The deduplicated stacked final-step query matrix of a batch — the
/// layout [`Posterior::answer_batch`] solves cross-covariance columns for,
/// shared with the serving layer's warm-start embedding
/// (`coordinator::store::WarmStart::embed_predict`). `None` when the
/// batch has no final-step queries.
pub fn stacked_final_xq(queries: &[Query]) -> Option<Matrix> {
    stack_final_queries(queries).0
}

/// Stacked-solve row weight of one query — the cost proxy the serving
/// layer's intra-batch splitter uses. Final-step queries contribute their
/// cross-covariance rows, `CurveSamples` is weighted by its Matheron solve
/// count, and `Mll` counts as one row (its probe solves are fixed-cost and
/// never split).
pub fn query_weight(q: &Query) -> usize {
    match q {
        Query::MeanAtFinal { xq } | Query::Variance { xq } | Query::Quantiles { xq, .. } => {
            xq.rows()
        }
        Query::MeanAtSteps { xq, .. } => xq.rows(),
        Query::CurveSamples { xq, n, .. } => (xq.rows() + 1) * (*n).max(1),
        Query::Mll { .. } => 1,
    }
}

/// Split one query batch into ordered chunks whose summed row weight stays
/// at or below `max_rows`, so the serving layer can fan a single oversized
/// stacked batch across pool workers and read replicas instead of
/// serializing it on one shard writer; concatenating the per-chunk answers
/// restores the original batch order. A single query heavier than
/// `max_rows` gets its own chunk — queries are never split internally —
/// and `max_rows == 0` (splitting disabled) or a batch that already fits
/// returns one chunk. Chunking never reorders queries, and because every
/// RHS of the shared batched solve iterates under its own convergence
/// criterion, per-query answers match the unsplit batch bit for bit when
/// the chunks run under the same warm-start lineage.
pub fn split_queries(queries: &[Query], max_rows: usize) -> Vec<Vec<Query>> {
    if queries.is_empty() {
        return Vec::new();
    }
    let total: usize = queries.iter().map(query_weight).sum();
    if max_rows == 0 || total <= max_rows {
        return vec![queries.to_vec()];
    }
    let mut chunks = Vec::new();
    let mut cur: Vec<Query> = Vec::new();
    let mut w = 0usize;
    for q in queries {
        let qw = query_weight(q);
        if !cur.is_empty() && w + qw > max_rows {
            chunks.push(std::mem::take(&mut cur));
            w = 0;
        }
        w += qw;
        cur.push(q.clone());
    }
    if !cur.is_empty() {
        chunks.push(cur);
    }
    chunks
}

/// Validate one query against a dataset's shape. Shared by
/// [`Posterior::answer_batch`], the default `Engine::answer_batch`
/// mapping, and the serving layer (which fails malformed requests
/// individually *before* coalescing them with healthy same-generation
/// traffic).
pub fn validate_query(data: &Dataset, q: &Query) -> Result<()> {
    let (m, d) = (data.m(), data.d());
    let check_xq = |xq: &Matrix| -> Result<()> {
        if xq.cols() != d {
            return Err(LkgpError::Shape(format!(
                "query configs are {}-dim, dataset is {d}-dim",
                xq.cols()
            )));
        }
        if xq.rows() == 0 {
            return Err(LkgpError::Shape("query needs at least one config row".into()));
        }
        Ok(())
    };
    match q {
        Query::MeanAtFinal { xq } | Query::Variance { xq } => check_xq(xq),
        Query::Quantiles { xq, ps } => {
            check_xq(xq)?;
            if ps.is_empty() {
                return Err(LkgpError::Shape("Quantiles needs at least one level".into()));
            }
            if ps.iter().any(|&p| !(p > 0.0 && p < 1.0)) {
                return Err(LkgpError::Shape(
                    "quantile levels must lie strictly inside (0, 1)".into(),
                ));
            }
            Ok(())
        }
        Query::MeanAtSteps { xq, steps } => {
            check_xq(xq)?;
            if steps.is_empty() {
                return Err(LkgpError::Shape("MeanAtSteps needs at least one step".into()));
            }
            if steps.iter().any(|&j| j >= m) {
                return Err(LkgpError::Shape(format!(
                    "step index out of range (grid has {m} steps)"
                )));
            }
            Ok(())
        }
        Query::CurveSamples { xq, n, .. } => {
            check_xq(xq)?;
            if *n == 0 {
                return Err(LkgpError::Shape("CurveSamples needs n >= 1".into()));
            }
            Ok(())
        }
        Query::Mll { .. } => Ok(()),
    }
}

/// Gaussian predictive quantiles from `(mean, variance-with-noise)`
/// pairs: a `(preds.len(), ps.len())` matrix with entries
/// `mean + Φ⁻¹(p)·sd`. Shared by [`Posterior::answer_batch`] and the
/// default `Engine::answer_batch` mapping so session-capable and
/// legacy-mapped engines can never diverge on the same query.
pub fn quantiles_from_preds(preds: &[(f64, f64)], ps: &[f64]) -> Matrix {
    let mut qm = Matrix::zeros(preds.len(), ps.len());
    for (r, &(mu, var)) in preds.iter().enumerate() {
        let sd = var.max(0.0).sqrt();
        for (c, &p) in ps.iter().enumerate() {
            qm[(r, c)] = mu + sd * normal_quantile(p);
        }
    }
    qm
}

/// Select grid-step columns out of a full `(q, m)` posterior-mean matrix
/// (the `MeanAtSteps` answer shape). Shared like [`quantiles_from_preds`].
pub fn select_steps(full: &Matrix, steps: &[usize]) -> Matrix {
    let mut sm = Matrix::zeros(full.rows(), steps.len());
    for r in 0..full.rows() {
        for (c, &j) in steps.iter().enumerate() {
            sm[(r, c)] = full[(r, j)];
        }
    }
    sm
}

/// Standard-normal quantile function Φ⁻¹(p) (Acklam's rational
/// approximation, absolute error < 1.2e-9 on (0, 1)). Used to turn the
/// exact Gaussian predictive `(mean, variance)` into `Quantiles` answers.
pub fn normal_quantile(p: f64) -> f64 {
    debug_assert!(p > 0.0 && p < 1.0, "quantile level must be in (0, 1)");
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;
    if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p > 1.0 - P_LOW {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    }
}

// ---------------------------------------------------------------------------
// FitSession

/// Hyper-parameter optimizer choice for [`FitSession::fit`].
#[derive(Clone, Debug)]
pub enum FitMethod {
    /// First-order default — robust to the stochastic log-det gradient.
    Adam(trainer::AdamCfg),
    /// Quasi-Newton, the paper's §B choice.
    Lbfgs(trainer::LbfgsCfg),
}

/// A hyper-parameter fitting session: owns the dataset, the Rademacher
/// probe set (so the probe-conditioned objective is deterministic), the
/// warm CG solve buffer and the factored preconditioner. Every
/// [`FitSession::eval`] warm-starts from the previous evaluation and
/// rebuilds the preconditioner only when theta drifts past the
/// compatibility window — the threading `RustEngine::fit` used to do by
/// hand.
pub struct FitSession {
    data: Arc<Dataset>,
    cfg: SolverCfg,
    probes: Vec<f64>,
    warm: Option<Vec<f64>>,
    precond: Option<Arc<PrecondFactors>>,
    evals: usize,
}

impl FitSession {
    /// New session with `cfg.probes` Rademacher probes drawn from `seed`.
    pub fn new(data: Arc<Dataset>, cfg: SolverCfg, seed: u64) -> Result<Self> {
        let nm = data.n() * data.m();
        let mut rng = Pcg64::new(seed);
        let probes = rng.rademacher_vec(cfg.probes * nm);
        Self::with_probes(data, cfg, probes)
    }

    /// New session over an explicit `(p, n*m)` row-major probe buffer
    /// (deterministic parity with pre-session callers that draw their own).
    pub fn with_probes(data: Arc<Dataset>, cfg: SolverCfg, probes: Vec<f64>) -> Result<Self> {
        data.check()?;
        Ok(FitSession {
            data,
            cfg,
            probes,
            warm: None,
            precond: None,
            evals: 0,
        })
    }

    /// Inject previously-converged state (a warm solve buffer in the
    /// `[y, probes]` layout and/or factored preconditioner), e.g. from a
    /// prior session's lineage.
    pub fn seed_state(&mut self, warm: Option<Vec<f64>>, precond: Option<Arc<PrecondFactors>>) {
        if warm.is_some() {
            self.warm = warm;
        }
        if precond.is_some() {
            self.precond = precond;
        }
    }

    /// Evaluate the MAP objective and gradient at `packed`, warm-starting
    /// the batched `[y, probes]` solve from the previous evaluation.
    pub fn eval(&mut self, packed: &[f64]) -> Result<MllEval> {
        let (eval, solves) = lkgp::mll_impl(
            packed,
            &self.data,
            &self.probes,
            &self.cfg,
            self.warm.as_deref(),
            &mut self.precond,
        )?;
        self.warm = Some(solves);
        self.evals += 1;
        Ok(eval)
    }

    /// Optimize from `theta0` with the given method; every objective
    /// evaluation flows through [`FitSession::eval`] (warm + cached).
    pub fn fit(&mut self, theta0: &[f64], method: &FitMethod) -> Result<FitTrace> {
        let mut obj = |p: &[f64]| self.eval(p).map(|e| (e.value, e.grad));
        match method {
            FitMethod::Adam(cfg) => trainer::adam(&mut obj, theta0, cfg),
            FitMethod::Lbfgs(cfg) => trainer::lbfgs(&mut obj, theta0, cfg),
        }
    }

    /// Freeze a [`Posterior`] at `theta`, carrying the preconditioner
    /// lineage forward (the factors were built under nearby
    /// hyper-parameters, so prediction solves reuse them).
    pub fn posterior(&self, theta: Vec<f64>) -> Posterior {
        Posterior::new(self.data.clone(), theta, self.cfg.clone())
            .with_precond(self.precond.clone())
    }

    /// The converged `[y, probes]` solve buffer of the last evaluation.
    pub fn warm_buffer(&self) -> Option<&[f64]> {
        self.warm.as_deref()
    }

    /// The factored preconditioner currently cached by the session.
    pub fn precond(&self) -> Option<Arc<PrecondFactors>> {
        self.precond.clone()
    }

    /// Objective evaluations performed so far.
    pub fn evals(&self) -> usize {
        self.evals
    }

    /// The session's dataset.
    pub fn data(&self) -> &Arc<Dataset> {
        &self.data
    }

    /// The session's solver configuration.
    pub fn cfg(&self) -> &SolverCfg {
        &self.cfg
    }
}

// ---------------------------------------------------------------------------
// Posterior

/// A posterior session: one `(dataset, theta, solver config)` triple plus
/// every piece of converged solver state — the training solve `alpha`, the
/// cross-covariance solves for the last final-step query matrix, and the
/// factored preconditioner. [`Posterior::answer_batch`] shares one
/// underlying batched solve across a query batch and reuses `alpha` for
/// every later query against the same session.
pub struct Posterior {
    data: Arc<Dataset>,
    theta: Vec<f64>,
    cfg: SolverCfg,
    /// Converged flattened `(n, m)` training solve, once any query ran.
    alpha: Option<Vec<f64>>,
    /// The stacked final-step query matrix the cached cross solves (and
    /// predictions) were computed for.
    cross_xq: Option<Matrix>,
    /// Flattened `(cross_xq.rows(), n*m)` cross-covariance solves.
    cross: Vec<f64>,
    /// `(mean, variance-with-noise)` per `cross_xq` row.
    preds: Vec<(f64, f64)>,
    precond: Option<Arc<PrecondFactors>>,
    /// External warm-start guess (lineage) consumed by the first solve:
    /// either a flattened `(n, m)` alpha or a full `(q+1)*n*m` buffer.
    guess: Option<Vec<f64>>,
    /// Pathwise sampling state for this `(dataset, theta)` pair
    /// (docs/sampling.md) — lineage-injected or built on first use.
    path_base: Option<Arc<PathBase>>,
    /// Last query-keyed pathwise factorization (Thompson storms repeat
    /// the same candidate matrix).
    path_query: Option<Arc<PathQuery>>,
    cg_iters: usize,
    cg_mvm_rows: usize,
    solve_calls: usize,
    escalations: usize,
    dense_fallbacks: usize,
    /// `CurveSamples` queries answered pathwise with ZERO solves in the
    /// call (the lineage-warm fast path).
    pathwise_hits: usize,
    /// Factored `B⁻¹` applies performed by pathwise sampling (one per
    /// drawn sample — the marginal cost the bench gate pins).
    sample_mvms: usize,
    last_cg: Option<CgStats>,
}

impl Posterior {
    /// New posterior session; no solve runs until the first query.
    pub fn new(data: Arc<Dataset>, theta: Vec<f64>, cfg: SolverCfg) -> Self {
        Posterior {
            data,
            theta,
            cfg,
            alpha: None,
            cross_xq: None,
            cross: Vec::new(),
            preds: Vec::new(),
            precond: None,
            guess: None,
            path_base: None,
            path_query: None,
            cg_iters: 0,
            cg_mvm_rows: 0,
            solve_calls: 0,
            escalations: 0,
            dense_fallbacks: 0,
            pathwise_hits: 0,
            sample_mvms: 0,
            last_cg: None,
        }
    }

    /// Inject a warm-start guess from external lineage: a flattened
    /// `(n, m)` alpha, or a full `(q+1)*n*m` buffer matching the stacked
    /// final-step layout of the first query batch.
    pub fn with_guess(mut self, guess: Option<Vec<f64>>) -> Self {
        self.guess = guess;
        self
    }

    /// Inject cached preconditioner factors (staleness is re-checked
    /// against theta and the mask before use, so old factors are safe).
    pub fn with_precond(mut self, precond: Option<Arc<PrecondFactors>>) -> Self {
        self.precond = precond;
        self
    }

    /// Inject pathwise sampling lineage (docs/sampling.md). Compatibility
    /// is re-checked bitwise against theta and the mask before use, so
    /// stale lineage is safe to pass — it is simply rebuilt on demand.
    pub fn with_path(mut self, path: Option<PathLineage>) -> Self {
        if let Some(p) = path {
            self.path_base = Some(p.base);
            self.path_query = p.query;
        }
        self
    }

    /// Seed the session with an already-converged solve: `alpha` is the
    /// flattened `(n, m)` training solve and, when `cross_xq` is given,
    /// `cross` holds the matching flattened `(cross_xq.rows(), n*m)`
    /// cross-covariance solves. The predictions for `cross_xq` are
    /// recomputed from the seeded buffers with the exact arithmetic of the
    /// original solve (no CG runs), so a query batch whose stacked
    /// final-step matrix equals `cross_xq` answers with **zero** solves and
    /// bit-identical results.
    ///
    /// The seeded state must come from a solve of the SAME `(dataset,
    /// theta)` pair — a solve under different hyper-parameters is a warm
    /// *guess*, not converged state; use [`Posterior::with_guess`] for
    /// that. Mismatched buffer shapes are ignored (the session simply
    /// solves on demand), so stale lineage is safe to pass.
    pub fn with_solves(
        mut self,
        alpha: Vec<f64>,
        cross_xq: Option<Matrix>,
        cross: Vec<f64>,
    ) -> Self {
        let nm = self.data.n() * self.data.m();
        if alpha.len() != nm {
            return self;
        }
        if let Some(xq) = cross_xq {
            let preds = lkgp::preds_from_solves(&self.theta, &self.data, &xq, &alpha, &cross);
            if let Some(preds) = preds {
                self.preds = preds;
                self.cross = cross;
                self.cross_xq = Some(xq);
            }
        }
        self.alpha = Some(alpha);
        self
    }

    /// Cheap read-only fork: shares the dataset `Arc` and copies every
    /// piece of converged solver state (training solve, cross solves,
    /// predictions, preconditioner, pending lineage guess) so the fork
    /// answers already-covered queries without re-solving — and answers
    /// new ones independently of the parent. Solve telemetry
    /// (`cg_iters`/`cg_mvm_rows`/`solve_calls`) restarts at zero so the
    /// fork reports only its own work. This is the primitive behind the
    /// `ServicePool`'s read-only replica shards (docs/serving.md).
    pub fn fork(&self) -> Posterior {
        Posterior {
            data: self.data.clone(),
            theta: self.theta.clone(),
            cfg: self.cfg.clone(),
            alpha: self.alpha.clone(),
            cross_xq: self.cross_xq.clone(),
            cross: self.cross.clone(),
            preds: self.preds.clone(),
            precond: self.precond.clone(),
            guess: self.guess.clone(),
            path_base: self.path_base.clone(),
            path_query: self.path_query.clone(),
            cg_iters: 0,
            cg_mvm_rows: 0,
            solve_calls: 0,
            escalations: 0,
            dense_fallbacks: 0,
            pathwise_hits: 0,
            sample_mvms: 0,
            last_cg: None,
        }
    }

    /// Run the training solve now (or reuse it) without answering any
    /// query — the pre-warm hook: after a refit, the serving layer calls
    /// this on the writer so the fresh generation's lineage carries a
    /// converged `alpha` (replica-ready) before the first read arrives
    /// (docs/serving.md "pre-warm on refit completion"). An injected
    /// [`Posterior::with_guess`] lineage warms the solve like any other.
    pub fn prewarm(&mut self) -> Result<()> {
        self.ensure_alpha()
    }

    /// Answer one query (see [`Posterior::answer_batch`]).
    pub fn answer(&mut self, query: &Query) -> Result<Answer> {
        let mut answers = self.answer_batch(std::slice::from_ref(query))?;
        answers.pop().ok_or_else(|| {
            crate::LkgpError::Coordinator("answer_batch returned no answer for a query".into())
        })
    }

    /// Answer a batch of typed queries. All final-step queries share one
    /// batched `[y, c_1..c_q]` solve (duplicate query matrices share
    /// columns); `MeanAtSteps` reuses the same converged `alpha`. Answers
    /// are returned in submission order.
    pub fn answer_batch(&mut self, queries: &[Query]) -> Result<Vec<Answer>> {
        for q in queries {
            self.validate(q)?;
        }
        let (stacked, slices) = stack_final_queries(queries);
        if let Some(xq) = &stacked {
            self.ensure_final_solve(xq)?;
        }
        // Every final-step query was assigned a slice by
        // `stack_final_queries`; a missing one means the stacking logic
        // drifted from the query taxonomy, surfaced as a typed error.
        fn final_span(slice: Option<(usize, usize)>) -> Result<(usize, usize)> {
            slice.ok_or_else(|| {
                crate::LkgpError::Shape(
                    "final-step query was not assigned a stacked slice".into(),
                )
            })
        }
        let mut out = Vec::with_capacity(queries.len());
        for (q, slice) in queries.iter().zip(slices) {
            let ans = match q {
                Query::MeanAtFinal { .. } => {
                    let (off, rows) = final_span(slice)?;
                    Answer::Final(self.preds[off..off + rows].to_vec())
                }
                Query::Variance { .. } => {
                    let (off, rows) = final_span(slice)?;
                    Answer::Variance(self.preds[off..off + rows].iter().map(|p| p.1).collect())
                }
                Query::Quantiles { ps, .. } => {
                    let (off, rows) = final_span(slice)?;
                    Answer::Quantiles(quantiles_from_preds(&self.preds[off..off + rows], ps))
                }
                Query::MeanAtSteps { xq, steps } => {
                    let full = self.mean_rows(xq)?;
                    Answer::Steps(select_steps(&full, steps))
                }
                Query::CurveSamples { xq, n: s, seed } => {
                    let mut rng = Pcg64::new(*seed);
                    Answer::Curves(self.sample_curves_with(xq, *s, &mut rng)?)
                }
                Query::Mll { seed } => Answer::Mll(self.mll(*seed)?),
            };
            out.push(ans);
        }
        Ok(out)
    }

    /// Posterior curve samples via Matheron's rule using an external RNG
    /// stream (the `Query::CurveSamples` path seeds its own).
    ///
    /// With `cfg.pathwise` (the default) the samples are served through
    /// pathwise conditioning (docs/sampling.md): the cached training
    /// solve supplies the data half of the Matheron correction and the
    /// sample half is one exact factored apply per sample — ZERO CG
    /// solves when the lineage already carries a converged `alpha`
    /// (counted in [`Posterior::pathwise_hits`]). When the deterministic
    /// probe check rejects the factored apply (or `cfg.pathwise` is
    /// off), the historical batched-CG sampler answers instead — each
    /// path is bitwise stable per seed, and the probe decision is a pure
    /// function of `(theta, dataset)`, so writer, replicas, and replays
    /// always take the same path.
    pub fn sample_curves_with(
        &mut self,
        xq: &Matrix,
        s: usize,
        rng: &mut Pcg64,
    ) -> Result<Vec<Matrix>> {
        if self.cfg.pathwise {
            if let Some(samples) = self.sample_pathwise(xq, s, rng)? {
                return Ok(samples);
            }
        }
        let (samples, cg) = lkgp::posterior_samples_impl(
            &self.theta,
            &self.data,
            xq,
            s,
            &self.cfg,
            rng,
            &mut self.precond,
        )?;
        self.record_cg(cg);
        Ok(samples)
    }

    /// Pathwise sampling attempt: `Ok(None)` means the factored apply
    /// failed its probe check and the caller should fall back to the
    /// batched-CG sampler (no RNG state was consumed).
    fn sample_pathwise(
        &mut self,
        xq: &Matrix,
        s: usize,
        rng: &mut Pcg64,
    ) -> Result<Option<Vec<Matrix>>> {
        let solves_before = self.solve_calls;
        // Query-independent state: reuse bitwise-compatible lineage,
        // build (deterministically) otherwise.
        let base = match &self.path_base {
            Some(b) if b.compatible(&self.theta, &self.data) => b.clone(),
            _ => {
                let b = Arc::new(PathBase::build(&self.theta, &self.data, &self.cfg)?);
                self.path_base = Some(b.clone());
                b
            }
        };
        if !base.exact() {
            return Ok(None);
        }
        // The data half of the correction: the converged training solve
        // (free when the lineage is warm, one solve when cold).
        self.ensure_alpha()?;
        let query = match &self.path_query {
            Some(q) if q.matches(xq) => q.clone(),
            _ => {
                let q = Arc::new(PathQuery::build(&base, &self.data, xq, &self.cfg)?);
                self.path_query = Some(q.clone());
                q
            }
        };
        let alpha = match &self.alpha {
            Some(a) => a.clone(),
            None => {
                return Err(crate::LkgpError::Coordinator(
                    "training solve left no alpha cached".into(),
                ))
            }
        };
        let samples = pathwise::sample_paths(&base, &query, &self.data, &alpha, s, rng)?;
        self.sample_mvms += s;
        if self.solve_calls == solves_before {
            self.pathwise_hits += 1;
        }
        Ok(Some(samples))
    }

    /// MAP objective value + gradient at the session's theta with a fresh
    /// probe set from `seed`. The cached `alpha` warm-starts the `y`
    /// column of the `[y, probes]` solve.
    pub fn mll(&mut self, seed: u64) -> Result<MllEval> {
        let nm = self.data.n() * self.data.m();
        let mut rng = Pcg64::new(seed);
        let probes = rng.rademacher_vec(self.cfg.probes.max(1) * nm);
        let x0: Option<Vec<f64>> = self.alpha.as_ref().map(|a| {
            let p = probes.len() / nm;
            let mut buf = vec![0.0; (p + 1) * nm];
            buf[..nm].copy_from_slice(a);
            buf
        });
        let (eval, _solves) = lkgp::mll_impl(
            &self.theta,
            &self.data,
            &probes,
            &self.cfg,
            x0.as_deref(),
            &mut self.precond,
        )?;
        self.record_cg(eval.cg.clone());
        Ok(eval)
    }

    fn validate(&self, q: &Query) -> Result<()> {
        validate_query(&self.data, q)
    }

    /// Run (or reuse) the shared `[y, c_1..c_q]` solve for a stacked
    /// final-step query matrix. A bitwise-identical repeat is free; a new
    /// matrix warm-starts from the converged `alpha` (or the injected
    /// lineage guess on the very first solve).
    fn ensure_final_solve(&mut self, xq: &Matrix) -> Result<()> {
        if self.alpha.is_some() {
            if let Some(cached) = &self.cross_xq {
                if cached.rows() == xq.rows()
                    && cached.cols() == xq.cols()
                    && cached.data() == xq.data()
                {
                    return Ok(());
                }
            }
        }
        let nm = self.data.n() * self.data.m();
        let guess: Option<Vec<f64>> = match &self.alpha {
            Some(a) => Some(a.clone()),
            None => self.guess.clone(),
        };
        let (preds, solves, cg) = lkgp::predict_final_impl(
            &self.theta,
            &self.data,
            xq,
            &self.cfg,
            guess.as_deref(),
            &mut self.precond,
        )?;
        self.alpha = Some(solves[..nm].to_vec());
        self.cross = solves[nm..].to_vec();
        self.cross_xq = Some(xq.clone());
        self.preds = preds;
        self.record_cg(cg);
        Ok(())
    }

    /// Solve (or reuse) the single-RHS training system `A alpha = vec(Y)`.
    fn ensure_alpha(&mut self) -> Result<()> {
        if self.alpha.is_some() {
            return Ok(());
        }
        self.data.check()?;
        let theta = Theta::unpack(&self.theta);
        let nm = self.data.n() * self.data.m();
        let k1 = kernels::rbf(&self.data.x, &self.data.x, &theta.lengthscales);
        let k2 = kernels::matern12(
            &self.data.t,
            &self.data.t,
            theta.t_lengthscale,
            theta.outputscale,
        );
        let op = super::operator::MaskedKronOp::new(&k1, &k2, &self.data.mask, theta.sigma2);
        let factors = lkgp::resolve_precond(
            &self.cfg,
            &self.theta,
            &k1,
            &k2,
            &self.data.mask,
            self.precond.as_ref(),
        );
        // the alpha slice of an injected lineage guess warms the y column
        let g0: Option<Vec<f64>> = self.guess.as_ref().and_then(|g| {
            if g.len() >= nm && g.len() % nm == 0 {
                Some(g[..nm].to_vec())
            } else {
                None
            }
        });
        let (sol, cg) = lkgp::solve_healthy(
            &op,
            &self.cfg,
            self.data.y.data(),
            g0.as_deref(),
            factors.as_deref(),
            &k1,
            &k2,
            &self.data.mask,
            &self.theta,
            theta.sigma2,
        )?;
        self.precond = factors;
        self.alpha = Some(sol);
        self.record_cg(cg);
        Ok(())
    }

    /// Full-grid posterior mean rows `k1(xq, X) (M ∘ A) K2` from the
    /// cached training solve.
    fn mean_rows(&mut self, xq: &Matrix) -> Result<Matrix> {
        self.ensure_alpha()?;
        let theta = Theta::unpack(&self.theta);
        let (n, m) = (self.data.n(), self.data.m());
        let Some(alpha) = self.alpha.as_ref() else {
            return Err(crate::LkgpError::Coordinator(
                "training solve left no alpha cached".into(),
            ));
        };
        let am = lkgp::mask_product(&self.data.mask, alpha, n, m);
        let k1q = kernels::rbf(xq, &self.data.x, &theta.lengthscales);
        let k2 = kernels::matern12(
            &self.data.t,
            &self.data.t,
            theta.t_lengthscale,
            theta.outputscale,
        );
        Ok(k1q.matmul(&am).matmul(&k2))
    }

    fn record_cg(&mut self, cg: CgStats) {
        self.cg_iters += cg.iters_per_rhs.iter().sum::<usize>();
        self.cg_mvm_rows += cg.mvm_rows;
        self.solve_calls += 1;
        self.escalations += cg.escalations;
        if cg.fallback_dense {
            self.dense_fallbacks += 1;
        }
        self.last_cg = Some(cg);
    }

    // -- accessors (serving-layer lineage + telemetry) ---------------------

    /// The converged training solve, once any query ran.
    pub fn alpha(&self) -> Option<&[f64]> {
        self.alpha.as_deref()
    }

    /// The stacked query matrix the cached cross solves correspond to.
    pub fn cross_xq(&self) -> Option<&Matrix> {
        self.cross_xq.as_ref()
    }

    /// The cached cross-covariance solves (flattened `(q, n*m)`).
    pub fn cross_solves(&self) -> Option<&[f64]> {
        if self.cross_xq.is_some() {
            Some(&self.cross)
        } else {
            None
        }
    }

    /// The full converged `[alpha, c_1.., c_q]` buffer of the last
    /// final-step solve (the historical `predict_final_warm` return).
    pub fn solve_buffer(&self) -> Option<Vec<f64>> {
        let alpha = self.alpha.as_ref()?;
        let mut buf = Vec::with_capacity(alpha.len() + self.cross.len());
        buf.extend_from_slice(alpha);
        buf.extend_from_slice(&self.cross);
        Some(buf)
    }

    /// Factored preconditioner state after the last solve.
    pub fn precond(&self) -> Option<Arc<PrecondFactors>> {
        self.precond.clone()
    }

    /// Pathwise sampling lineage after the last `CurveSamples` query
    /// (`Arc`-shared; the serving layer caches it in `WarmStart` so later
    /// sampling traffic against the same `(generation, theta)` is
    /// solve-free — docs/sampling.md).
    pub fn path_state(&self) -> Option<PathLineage> {
        self.path_base.as_ref().map(|b| PathLineage {
            base: b.clone(),
            query: self.path_query.clone(),
        })
    }

    /// `CurveSamples` queries answered pathwise with zero solves in the
    /// call (the lineage-warm fast path; docs/sampling.md).
    pub fn pathwise_hits(&self) -> usize {
        self.pathwise_hits
    }

    /// Factored `B⁻¹` applies performed by pathwise sampling (one per
    /// drawn sample).
    pub fn sample_mvms(&self) -> usize {
        self.sample_mvms
    }

    /// Stats of the most recent underlying solve.
    pub fn last_cg(&self) -> Option<&CgStats> {
        self.last_cg.as_ref()
    }

    /// Total per-RHS CG iterations across the session's solves.
    pub fn cg_iters(&self) -> usize {
        self.cg_iters
    }

    /// Total operator rows applied across the session's solves
    /// (`CgStats::mvm_rows` — the true MVM work).
    pub fn cg_mvm_rows(&self) -> usize {
        self.cg_mvm_rows
    }

    /// Underlying batched solves run so far (query batches amortize many
    /// queries into one).
    pub fn solve_calls(&self) -> usize {
        self.solve_calls
    }

    /// Escalation-ladder rungs climbed across the session's solves
    /// (0 on the healthy path; docs/robustness.md).
    pub fn escalations(&self) -> usize {
        self.escalations
    }

    /// Solves answered by the dense-Cholesky fallback rung.
    pub fn dense_fallbacks(&self) -> usize {
        self.dense_fallbacks
    }

    /// The session's packed hyper-parameters.
    pub fn theta(&self) -> &[f64] {
        &self.theta
    }

    /// The session's dataset.
    pub fn data(&self) -> &Arc<Dataset> {
        &self.data
    }

    /// The session's solver configuration.
    pub fn cfg(&self) -> &SolverCfg {
        &self.cfg
    }
}

// ---------------------------------------------------------------------------
// Online epoch ingestion (Observe)

/// Result of an [`observe`] warm re-solve: the converged training solve on
/// the extended mask plus the telemetry the serving layer reports
/// (`ServiceStats::observe_solve_mvm_rows`) and the drift statistic the
/// refit policy consumes. No MLL evaluation happens anywhere on this path.
#[derive(Clone, Debug)]
pub struct ObserveSolve {
    /// Converged flattened `(n, m)` training solve on the extended mask.
    pub alpha: Vec<f64>,
    /// Data-fit term `yᵀ alpha` — the half of the MLL that moves when new
    /// epochs arrive under a frozen theta. The refit policy watches its
    /// relative drift; it is free given `alpha` (one dot product), so the
    /// observe path stays at zero MLL evaluations.
    pub data_fit: f64,
    /// Per-RHS CG iterations of the warm re-solve.
    pub cg_iters: usize,
    /// Operator rows applied (the true MVM work — the 10x-vs-refit claim
    /// in `BENCH_scale.json` is measured in these units).
    pub mvm_rows: usize,
    /// Escalation-ladder rungs climbed (0 on the healthy warm path).
    pub escalations: usize,
    /// Whether the dense-Cholesky fallback rung answered.
    pub dense_fallbacks: usize,
    /// Preconditioner factors used (reused from the lineage when the
    /// mask-staleness check passed, rebuilt otherwise) — cached back into
    /// the task's `WarmStart` for the next observe/query.
    pub precond: Option<Arc<PrecondFactors>>,
}

/// Warm training re-solve for online epoch ingestion: solve
/// `A alpha = vec(Y)` on `data`'s (extended) mask under a FROZEN theta,
/// seeded from the previous generation's converged `alpha` (embedded onto
/// the new grid by the caller) and reusing cached preconditioner factors
/// when the mask-staleness check passes (`lkgp::resolve_precond`; the
/// latent-Kronecker factors survive mask growth, observed-Gram factors are
/// rebuilt). This is the `Request::Observe` engine: adding an epoch only
/// grows the observed mask of the fixed latent grid (PAPER.md), so the
/// old solve is an excellent guess and the re-solve converges in a few
/// iterations — zero MLL evaluations, an order of magnitude fewer MVM rows
/// than a `Refit` generation.
///
/// Bit-consistency: the solve is `lkgp::solve_healthy` with the same
/// operator, RHS, tolerance, and preconditioner a from-scratch solve on
/// the same `(data, theta)` would use; only the initial guess differs, and
/// CG measures convergence against `‖b‖` regardless of the guess, so an
/// observe-then-query answer equals a fresh lineage-warm solve on the
/// extended snapshot bit for bit (see `tests/service_pool.rs`).
pub fn observe(
    data: &Arc<Dataset>,
    theta: &[f64],
    cfg: &SolverCfg,
    guess: Option<&[f64]>,
    precond: Option<&Arc<PrecondFactors>>,
) -> Result<ObserveSolve> {
    data.check()?;
    let th = Theta::unpack(theta);
    let nm = data.n() * data.m();
    let k1 = kernels::rbf(&data.x, &data.x, &th.lengthscales);
    let k2 = kernels::matern12(&data.t, &data.t, th.t_lengthscale, th.outputscale);
    let op = super::operator::MaskedKronOp::new(&k1, &k2, &data.mask, th.sigma2);
    let factors = lkgp::resolve_precond(cfg, theta, &k1, &k2, &data.mask, precond);
    // The embedded previous-generation alpha warms the single y column;
    // a shape mismatch (caller embedded against a stale grid) degrades to
    // a cold solve rather than poisoning the warm start.
    let g0 = guess.filter(|g| g.len() == nm);
    let (alpha, cg) = lkgp::solve_healthy(
        &op,
        cfg,
        data.y.data(),
        g0,
        factors.as_deref(),
        &k1,
        &k2,
        &data.mask,
        theta,
        th.sigma2,
    )?;
    let data_fit = crate::linalg::matrix::dot(data.y.data(), &alpha);
    Ok(ObserveSolve {
        data_fit,
        cg_iters: cg.iters_per_rhs.iter().sum::<usize>(),
        mvm_rows: cg.mvm_rows,
        escalations: cg.escalations,
        dense_fallbacks: if cg.fallback_dense { 1 } else { 0 },
        precond: factors,
        alpha,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(n: usize, m: usize, d: usize, seed: u64) -> Arc<Dataset> {
        let mut rng = Pcg64::new(seed);
        let x = Matrix::from_vec(n, d, rng.uniform_vec(n * d, 0.0, 1.0));
        let t: Vec<f64> = (0..m).map(|i| i as f64 / (m - 1).max(1) as f64).collect();
        let mut mask = Matrix::zeros(n, m);
        for i in 0..n {
            let len = 2 + rng.below(m - 1);
            for j in 0..len {
                mask[(i, j)] = 1.0;
            }
        }
        let mut y = Matrix::zeros(n, m);
        for i in 0..n {
            for j in 0..m {
                if mask[(i, j)] > 0.0 {
                    y[(i, j)] = -0.5 + 0.1 * j as f64 + 0.02 * rng.normal();
                }
            }
        }
        Arc::new(Dataset { x, t, y, mask })
    }

    #[test]
    fn split_queries_respects_weight_budget_and_order() {
        let xq = |rows: usize, tag: f64| Matrix::from_vec(rows, 2, vec![tag; rows * 2]);
        let queries = vec![
            Query::MeanAtFinal { xq: xq(3, 0.1) },
            Query::Variance { xq: xq(2, 0.2) },
            Query::Quantiles { xq: xq(4, 0.3), ps: vec![0.5] },
            Query::Mll { seed: 7 },
            Query::MeanAtSteps { xq: xq(5, 0.4), steps: vec![0, 1] },
        ];
        // weights: 3, 2, 4, 1, 5 (total 15)
        let chunks = split_queries(&queries, 5);
        let sizes: Vec<usize> = chunks.iter().map(|c| c.len()).collect();
        assert_eq!(sizes, vec![2, 2, 1], "greedy packing: [3+2][4+1][5]");
        let flat: Vec<Query> = chunks.into_iter().flatten().collect();
        assert_eq!(flat.len(), queries.len());
        for (a, b) in flat.iter().zip(&queries) {
            assert_eq!(query_weight(a), query_weight(b), "order preserved");
        }
    }

    #[test]
    fn split_queries_edge_cases() {
        let xq = Matrix::from_vec(8, 2, vec![0.5; 16]);
        let big = vec![Query::MeanAtFinal { xq: xq.clone() }];
        // an oversized single query still gets exactly one chunk
        assert_eq!(split_queries(&big, 3).len(), 1);
        // disabled splitting and already-fitting batches stay whole
        assert_eq!(split_queries(&big, 0).len(), 1);
        assert_eq!(split_queries(&big, 100).len(), 1);
        assert!(split_queries(&[], 4).is_empty());
        // CurveSamples weight scales with the sample count
        let cs = Query::CurveSamples { xq: Matrix::from_vec(2, 2, vec![0.1; 4]), n: 3, seed: 1 };
        assert_eq!(query_weight(&cs), 9);
    }

    #[test]
    fn split_batch_answers_match_unsplit_bitwise() {
        let data = toy(7, 6, 2, 31);
        let mut rng = Pcg64::new(32);
        let xq1 = Matrix::from_vec(2, 2, rng.uniform_vec(4, 0.0, 1.0));
        let xq2 = Matrix::from_vec(3, 2, rng.uniform_vec(6, 0.0, 1.0));
        let queries = vec![
            Query::MeanAtFinal { xq: xq1.clone() },
            Query::Variance { xq: xq2.clone() },
            Query::Quantiles { xq: xq1.clone(), ps: vec![0.25, 0.75] },
        ];
        let theta = Theta::default_packed(2);
        let cfg = SolverCfg::default();
        let mut whole = Posterior::new(data.clone(), theta.clone(), cfg.clone());
        let want = whole.answer_batch(&queries).unwrap();
        let mut got: Vec<Answer> = Vec::new();
        for chunk in split_queries(&queries, 3) {
            // fresh cold session per chunk — the serving layer's split path
            let mut part = Posterior::new(data.clone(), theta.clone(), cfg.clone());
            got.extend(part.answer_batch(&chunk).unwrap());
        }
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert!(g.bits_eq(w), "split answers must match unsplit bitwise");
        }
    }

    #[test]
    fn normal_quantile_known_values() {
        assert!(normal_quantile(0.5).abs() < 1e-12);
        assert!((normal_quantile(0.975) - 1.959963985).abs() < 1e-7);
        assert!((normal_quantile(0.025) + 1.959963985).abs() < 1e-7);
        // tail branch + symmetry
        assert!((normal_quantile(0.001) + normal_quantile(0.999)).abs() < 1e-7);
        assert!((normal_quantile(0.001) + 3.090232306).abs() < 1e-6);
    }

    #[test]
    fn stacking_dedupes_identical_query_blocks() {
        let xq = Matrix::from_vec(2, 2, vec![0.1, 0.2, 0.3, 0.4]);
        let other = Matrix::from_vec(1, 2, vec![0.9, 0.9]);
        let queries = vec![
            Query::MeanAtFinal { xq: xq.clone() },
            Query::Variance { xq: xq.clone() },
            Query::MeanAtSteps { xq: xq.clone(), steps: vec![0] },
            Query::Quantiles { xq: other.clone(), ps: vec![0.5] },
        ];
        let (stacked, slices) = stack_final_queries(&queries);
        let stacked = stacked.expect("final-step queries present");
        // identical blocks share rows: 2 (xq) + 1 (other), not 5
        assert_eq!(stacked.rows(), 3);
        assert_eq!(slices[0], Some((0, 2)));
        assert_eq!(slices[1], Some((0, 2)));
        assert_eq!(slices[2], None); // MeanAtSteps adds no cross columns
        assert_eq!(slices[3], Some((2, 1)));
        assert_eq!(stacked.row(2), other.row(0));
    }

    #[test]
    fn batch_shares_one_solve_across_variants() {
        let data = toy(6, 5, 2, 3);
        let theta = Theta::default_packed(2);
        let mut rng = Pcg64::new(4);
        let xq = Matrix::from_vec(2, 2, rng.uniform_vec(4, 0.0, 1.0));
        let mut post = Posterior::new(data, theta, SolverCfg::default());
        let answers = post
            .answer_batch(&[
                Query::MeanAtFinal { xq: xq.clone() },
                Query::Variance { xq: xq.clone() },
                Query::Quantiles { xq: xq.clone(), ps: vec![0.25, 0.75] },
                Query::MeanAtSteps { xq: xq.clone(), steps: vec![0, 4] },
            ])
            .unwrap();
        assert_eq!(post.solve_calls(), 1, "four variants, one solve");
        // internal consistency: Variance == Final.1, quantile order
        let finals = match &answers[0] {
            Answer::Final(v) => v.clone(),
            other => panic!("want Final, got {other:?}"),
        };
        match &answers[1] {
            Answer::Variance(v) => {
                for (a, b) in v.iter().zip(&finals) {
                    assert_eq!(a.to_bits(), b.1.to_bits());
                }
            }
            other => panic!("want Variance, got {other:?}"),
        }
        match &answers[2] {
            Answer::Quantiles(q) => {
                for r in 0..2 {
                    assert!(q[(r, 0)] < q[(r, 1)], "quantiles must be ordered");
                }
            }
            other => panic!("want Quantiles, got {other:?}"),
        }
        // an identical follow-up batch answers from cache: still one solve
        let again = post.answer(&Query::MeanAtFinal { xq: xq.clone() }).unwrap();
        assert_eq!(post.solve_calls(), 1);
        match again {
            Answer::Final(v) => {
                for (a, b) in v.iter().zip(&finals) {
                    assert_eq!(a.0.to_bits(), b.0.to_bits());
                    assert_eq!(a.1.to_bits(), b.1.to_bits());
                }
            }
            other => panic!("want Final, got {other:?}"),
        }
    }

    #[test]
    fn steps_only_batch_solves_single_rhs_then_warms_finals() {
        let data = toy(6, 5, 2, 7);
        let theta = Theta::default_packed(2);
        let mut rng = Pcg64::new(8);
        let xq = Matrix::from_vec(2, 2, rng.uniform_vec(4, 0.0, 1.0));
        let mut post = Posterior::new(data, theta, SolverCfg::default());
        let ans = post
            .answer(&Query::MeanAtSteps { xq: xq.clone(), steps: vec![4] })
            .unwrap();
        match ans {
            Answer::Steps(s) => assert_eq!((s.rows(), s.cols()), (2, 1)),
            other => panic!("want Steps, got {other:?}"),
        }
        assert_eq!(post.solve_calls(), 1);
        let rows_alpha_only = post.cg_mvm_rows();
        // a later final-step query warm-starts its y column from alpha
        let _ = post.answer(&Query::MeanAtFinal { xq }).unwrap();
        assert_eq!(post.solve_calls(), 2);
        let cg = post.last_cg().unwrap();
        assert!(
            cg.iters_per_rhs[0] <= 2,
            "y column should be warm: {:?}",
            cg.iters_per_rhs
        );
        assert!(rows_alpha_only > 0);
    }

    #[test]
    fn invalid_queries_are_rejected() {
        let data = toy(5, 4, 2, 9);
        let theta = Theta::default_packed(2);
        let mut post = Posterior::new(data, theta, SolverCfg::default());
        let xq = Matrix::from_vec(1, 2, vec![0.5, 0.5]);
        let wrong_d = Matrix::from_vec(1, 3, vec![0.5, 0.5, 0.5]);
        assert!(post.answer(&Query::MeanAtFinal { xq: wrong_d }).is_err());
        assert!(post
            .answer(&Query::MeanAtSteps { xq: xq.clone(), steps: vec![4] })
            .is_err());
        assert!(post
            .answer(&Query::Quantiles { xq: xq.clone(), ps: vec![0.0] })
            .is_err());
        assert!(post
            .answer(&Query::Quantiles { xq: xq.clone(), ps: vec![] })
            .is_err());
        assert!(post
            .answer(&Query::CurveSamples { xq, n: 0, seed: 1 })
            .is_err());
        // nothing solved on the error paths
        assert_eq!(post.solve_calls(), 0);
    }

    #[test]
    fn fork_answers_cached_queries_without_solving() {
        let data = toy(6, 5, 2, 13);
        let theta = Theta::default_packed(2);
        let mut rng = Pcg64::new(14);
        let xq = Matrix::from_vec(3, 2, rng.uniform_vec(6, 0.0, 1.0));
        let mut parent = Posterior::new(data, theta, SolverCfg::default());
        let batch = [
            Query::MeanAtFinal { xq: xq.clone() },
            Query::Quantiles { xq: xq.clone(), ps: vec![0.2, 0.8] },
        ];
        let want = parent.answer_batch(&batch).unwrap();
        assert_eq!(parent.solve_calls(), 1);

        // the fork serves the covered batch from copied state: zero solves
        let mut fork = parent.fork();
        assert_eq!(fork.solve_calls(), 0);
        let got = fork.answer_batch(&batch).unwrap();
        assert_eq!(fork.solve_calls(), 0, "fork must not re-solve cached state");
        match (&want[0], &got[0]) {
            (Answer::Final(a), Answer::Final(b)) => {
                for (x, y) in a.iter().zip(b) {
                    assert_eq!(x.0.to_bits(), y.0.to_bits());
                    assert_eq!(x.1.to_bits(), y.1.to_bits());
                }
            }
            other => panic!("unexpected answers {other:?}"),
        }
        // a new query matrix solves on the fork alone; the parent's cache
        // is untouched (MeanAtSteps on the parent still reuses alpha)
        let other = Matrix::from_vec(1, 2, vec![0.9, 0.1]);
        let _ = fork.answer(&Query::MeanAtFinal { xq: other }).unwrap();
        assert_eq!(fork.solve_calls(), 1);
        assert_eq!(parent.solve_calls(), 1);
        let _ = parent
            .answer(&Query::MeanAtSteps { xq: xq.clone(), steps: vec![0] })
            .unwrap();
        assert_eq!(parent.solve_calls(), 1);
    }

    #[test]
    fn with_solves_seeds_converged_state_bit_exactly() {
        let data = toy(7, 4, 2, 15);
        let theta = Theta::default_packed(2);
        let mut rng = Pcg64::new(16);
        let xq = Matrix::from_vec(2, 2, rng.uniform_vec(4, 0.0, 1.0));
        let mut parent = Posterior::new(data.clone(), theta.clone(), SolverCfg::default());
        let want = parent.answer(&Query::MeanAtFinal { xq: xq.clone() }).unwrap();
        let alpha = parent.alpha().unwrap().to_vec();
        let cross = parent.cross_solves().unwrap().to_vec();

        // rebuild a posterior from the raw lineage buffers (the serving
        // layer's WarmStart shape): zero solves, bit-identical answers
        let mut seeded = Posterior::new(data.clone(), theta.clone(), SolverCfg::default())
            .with_solves(alpha.clone(), Some(xq.clone()), cross.clone());
        let got = seeded.answer(&Query::MeanAtFinal { xq: xq.clone() }).unwrap();
        assert_eq!(seeded.solve_calls(), 0);
        match (&want, &got) {
            (Answer::Final(a), Answer::Final(b)) => {
                for (x, y) in a.iter().zip(b) {
                    assert_eq!(x.0.to_bits(), y.0.to_bits());
                    assert_eq!(x.1.to_bits(), y.1.to_bits());
                }
            }
            other => panic!("unexpected answers {other:?}"),
        }
        // steps-only queries reuse the seeded alpha without a solve
        let _ = seeded
            .answer(&Query::MeanAtSteps { xq: xq.clone(), steps: vec![0, 3] })
            .unwrap();
        assert_eq!(seeded.solve_calls(), 0);

        // mismatched lineage is ignored, not trusted
        let mut bad = Posterior::new(data, theta, SolverCfg::default())
            .with_solves(vec![1.0; 3], Some(xq.clone()), cross);
        assert!(bad.alpha().is_none());
        let _ = bad.answer(&Query::MeanAtFinal { xq }).unwrap();
        assert_eq!(bad.solve_calls(), 1);
    }

    #[test]
    fn fit_session_matches_hand_threaded_eval() {
        let data = toy(6, 5, 2, 11);
        let cfg = SolverCfg::default();
        let nm = 30;
        let probes = Pcg64::new(12).rademacher_vec(cfg.probes * nm);
        let theta = Theta::default_packed(2);
        let mut session =
            FitSession::with_probes(data.clone(), cfg.clone(), probes.clone()).unwrap();
        let eval = session.eval(&theta).unwrap();
        assert_eq!(session.evals(), 1);
        // hand-threaded reference through the internal impl
        let mut cache = None;
        let (want, solves) =
            lkgp::mll_impl(&theta, &data, &probes, &cfg, None, &mut cache).unwrap();
        assert_eq!(eval.value.to_bits(), want.value.to_bits());
        for (a, b) in eval.grad.iter().zip(&want.grad) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let warm = session.warm_buffer().unwrap();
        assert_eq!(warm.len(), solves.len());
        for (a, b) in warm.iter().zip(&solves) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn pathwise_samples_zero_solves_when_lineage_warm() {
        let data = toy(6, 5, 2, 41);
        let theta = Theta::default_packed(2);
        let cfg = SolverCfg::default();
        let mut rng = Pcg64::new(42);
        let xq = Matrix::from_vec(2, 2, rng.uniform_vec(4, 0.0, 1.0));
        let q = Query::CurveSamples { xq: xq.clone(), n: 3, seed: 7 };

        // cold writer: exactly one (training) solve, never a per-sample one
        let mut parent = Posterior::new(data.clone(), theta.clone(), cfg.clone());
        let want = parent.answer(&q).unwrap();
        assert_eq!(parent.solve_calls(), 1, "cold pathwise pays only the training solve");
        assert_eq!(parent.pathwise_hits(), 0, "a cold call is not a hit");
        assert_eq!(parent.sample_mvms(), 3, "one factored apply per sample");
        let lineage = parent.path_state().expect("pathwise state cached");

        // seeded from raw lineage buffers (the WarmStart shape): ZERO solves
        let mut warm = Posterior::new(data.clone(), theta.clone(), cfg.clone())
            .with_solves(parent.alpha().unwrap().to_vec(), None, Vec::new())
            .with_path(Some(lineage));
        let got = warm.answer(&q).unwrap();
        assert_eq!(warm.solve_calls(), 0, "warm lineage sampling must be solve-free");
        assert_eq!(warm.pathwise_hits(), 1);
        assert_eq!(warm.sample_mvms(), 3);
        assert!(got.bits_eq(&want), "same seed must be bitwise identical");

        // a fork (the replica primitive) is solve-free and bit-identical too
        let mut fork = parent.fork();
        let got2 = fork.answer(&q).unwrap();
        assert_eq!(fork.solve_calls(), 0, "fork must reuse pathwise lineage");
        assert_eq!(fork.pathwise_hits(), 1);
        assert!(got2.bits_eq(&want));

        // further draws (new seeds) stay solve-free; counters accumulate
        let _ = fork
            .answer(&Query::CurveSamples { xq: xq.clone(), n: 5, seed: 99 })
            .unwrap();
        assert_eq!(fork.solve_calls(), 0);
        assert_eq!(fork.pathwise_hits(), 2);
        assert_eq!(fork.sample_mvms(), 8);
    }

    #[test]
    fn pathwise_off_pins_historical_sampler() {
        let data = toy(7, 5, 2, 43);
        let theta = Theta::default_packed(2);
        let cfg = SolverCfg { pathwise: false, ..Default::default() };
        let mut rng = Pcg64::new(44);
        let xq = Matrix::from_vec(2, 2, rng.uniform_vec(4, 0.0, 1.0));
        let seed = 17u64;
        let mut post = Posterior::new(data.clone(), theta.clone(), cfg.clone());
        let got = post
            .answer(&Query::CurveSamples { xq: xq.clone(), n: 2, seed })
            .unwrap();
        assert_eq!(post.pathwise_hits(), 0);
        assert_eq!(post.sample_mvms(), 0);
        assert_eq!(post.solve_calls(), 1, "historical path solves per batch");
        // bit-exact with the historical impl under the same RNG stream
        let mut hist_rng = Pcg64::new(seed);
        let mut cache = None;
        let (want, _) = lkgp::posterior_samples_impl(
            &theta, &data, &xq, 2, &cfg, &mut hist_rng, &mut cache,
        )
        .unwrap();
        assert!(got.bits_eq(&Answer::Curves(want)));
    }

    #[test]
    fn pathwise_lineage_stales_on_theta_drift() {
        let data = toy(6, 5, 2, 45);
        let theta = Theta::default_packed(2);
        let cfg = SolverCfg::default();
        let mut rng = Pcg64::new(46);
        let xq = Matrix::from_vec(2, 2, rng.uniform_vec(4, 0.0, 1.0));
        let q = Query::CurveSamples { xq, n: 2, seed: 5 };
        let mut parent = Posterior::new(data.clone(), theta.clone(), cfg.clone());
        let _ = parent.answer(&q).unwrap();
        let lineage = parent.path_state().expect("state cached");

        // drifted theta: stale lineage is rebuilt, not trusted
        let mut drifted_theta = theta.clone();
        drifted_theta[0] += 0.3;
        let mut drifted = Posterior::new(data, drifted_theta, cfg).with_path(Some(lineage));
        let _ = drifted.answer(&q).unwrap();
        assert_eq!(drifted.solve_calls(), 1, "drifted theta must re-solve alpha");
        assert_eq!(drifted.pathwise_hits(), 0, "a rebuilt+resolved call is not a hit");
    }

    /// Extend a toy dataset's mask by one epoch per row (where room
    /// remains), filling the newly observed cells with synthetic values.
    fn extend_one_epoch(data: &Dataset, seed: u64) -> Arc<Dataset> {
        let (n, m) = (data.n(), data.m());
        let mut rng = Pcg64::new(seed);
        let mut mask = data.mask.clone();
        let mut y = data.y.clone();
        for i in 0..n {
            let len = (0..m).take_while(|&j| mask[(i, j)] > 0.0).count();
            if len < m {
                mask[(i, len)] = 1.0;
                y[(i, len)] = -0.5 + 0.1 * len as f64 + 0.02 * rng.normal();
            }
        }
        Arc::new(Dataset { x: data.x.clone(), t: data.t.clone(), y, mask })
    }

    #[test]
    fn observe_cold_matches_posterior_alpha_bitwise() {
        // observe() with no guess is exactly the ensure_alpha solve.
        let data = toy(6, 5, 2, 51);
        let theta = Theta::default_packed(2);
        let cfg = SolverCfg::default();
        let mut post = Posterior::new(data.clone(), theta.clone(), cfg.clone());
        post.prewarm().unwrap();
        let obs = observe(&data, &theta, &cfg, None, None).unwrap();
        let want = post.alpha().unwrap();
        assert_eq!(obs.alpha.len(), want.len());
        for (a, b) in obs.alpha.iter().zip(want) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let dot: f64 = data.y.data().iter().zip(want).map(|(y, a)| y * a).sum();
        assert_eq!(obs.data_fit.to_bits(), crate::linalg::matrix::dot(data.y.data(), want).to_bits());
        assert!((obs.data_fit - dot).abs() < 1e-9);
    }

    #[test]
    fn observe_warm_resolve_is_cheap_and_bit_consistent() {
        let data = toy(7, 6, 2, 52);
        let theta = Theta::default_packed(2);
        let cfg = SolverCfg::default();
        // generation 1: converged solve on the base mask
        let gen1 = observe(&data, &theta, &cfg, None, None).unwrap();
        // generation 2: one new epoch per row, warm re-solve from alpha1
        let data2 = extend_one_epoch(&data, 53);
        let warm =
            observe(&data2, &theta, &cfg, Some(&gen1.alpha), gen1.precond.as_ref()).unwrap();
        let cold = observe(&data2, &theta, &cfg, None, None).unwrap();
        // the warm start changes the iterate path but not the solution
        // quality; both must satisfy the same residual bound (checked by
        // solve_healthy), and the warm one must be strictly cheaper
        assert!(
            warm.mvm_rows < cold.mvm_rows,
            "warm {} vs cold {} MVM rows",
            warm.mvm_rows,
            cold.mvm_rows
        );
        // re-observing the SAME data from its own converged alpha is free
        // modulo the single warm-residual MVM, and returns the alpha bits
        // unchanged (the CG active set is empty on arrival)
        let re = observe(&data2, &theta, &cfg, Some(&warm.alpha), warm.precond.as_ref()).unwrap();
        assert_eq!(re.cg_iters, 0, "converged guess must 0-iterate");
        for (a, b) in re.alpha.iter().zip(&warm.alpha) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn observe_mismatched_guess_degrades_to_cold() {
        let data = toy(5, 5, 2, 54);
        let theta = Theta::default_packed(2);
        let cfg = SolverCfg::default();
        let cold = observe(&data, &theta, &cfg, None, None).unwrap();
        let short = vec![1.0; 7];
        let got = observe(&data, &theta, &cfg, Some(&short), None).unwrap();
        for (a, b) in got.alpha.iter().zip(&cold.alpha) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(got.mvm_rows, cold.mvm_rows);
    }
}
