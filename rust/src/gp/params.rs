//! Model parameters: packing, constraints, priors (paper §B).
//!
//! The unconstrained vector layout matches the L2 jax graphs exactly
//! (python/compile/model.py), so the same theta can be fed to either
//! engine:
//!
//! ```text
//! theta = [ log ls_1 .. log ls_d, log ls_t, log outputscale, log sigma2 ]
//! ```
//!
//! d + 3 free parameters — 10 for LCBench's d = 7, as the paper highlights.

/// Log-normal prior std for RBF lengthscales (Hvarfner et al., 2024).
pub const LS_PRIOR_STD: f64 = 1.732_050_807_568_877_2; // sqrt(3)
/// Log-normal prior on the noise variance: logN(-4, 1).
pub const NOISE_PRIOR_MEAN: f64 = -4.0;
pub const NOISE_PRIOR_STD: f64 = 1.0;

/// Unpacked, positively-constrained view of the parameter vector.
#[derive(Clone, Debug)]
pub struct Theta {
    /// ARD lengthscales over hyper-parameters, length d.
    pub lengthscales: Vec<f64>,
    /// Matern-1/2 lengthscale over progression.
    pub t_lengthscale: f64,
    /// Matern-1/2 outputscale (signal variance of the product kernel).
    pub outputscale: f64,
    /// Homoskedastic noise variance.
    pub sigma2: f64,
}

impl Theta {
    /// Number of hyper-parameter dimensions for a packed vector length.
    pub fn dim_of(packed_len: usize) -> usize {
        packed_len
            .checked_sub(3)
            .expect("theta vector must have at least 3 entries")
    }

    /// Unpack an unconstrained vector (exp constraint).
    pub fn unpack(packed: &[f64]) -> Theta {
        let d = Self::dim_of(packed.len());
        Theta {
            lengthscales: packed[..d].iter().map(|v| v.exp()).collect(),
            t_lengthscale: packed[d].exp(),
            outputscale: packed[d + 1].exp(),
            sigma2: packed[d + 2].exp(),
        }
    }

    /// Pack back to the unconstrained layout.
    pub fn pack(&self) -> Vec<f64> {
        let mut out: Vec<f64> = self.lengthscales.iter().map(|v| v.ln()).collect();
        out.push(self.t_lengthscale.ln());
        out.push(self.outputscale.ln());
        out.push(self.sigma2.ln());
        out
    }

    /// Prior-mean initialization (matches `model.default_theta`).
    pub fn default_packed(d: usize) -> Vec<f64> {
        let mu_ls = 2f64.sqrt() + 0.5 * (d as f64).ln();
        let mut out = vec![mu_ls; d];
        out.push(0.3f64.ln());
        out.push(0.0);
        out.push(NOISE_PRIOR_MEAN);
        out
    }
}

/// Lengthscale prior mean for dimension count d.
pub fn ls_prior_mean(d: usize) -> f64 {
    2f64.sqrt() + 0.5 * (d as f64).ln()
}

/// MAP penalty: log p(lengthscales) + log p(noise) (log-normal densities,
/// paper §B; t-lengthscale and outputscale carry no prior).
pub fn log_prior(packed: &[f64]) -> f64 {
    let d = Theta::dim_of(packed.len());
    let mu = ls_prior_mean(d);
    let mut lp = 0.0;
    for &log_ls in &packed[..d] {
        let z = (log_ls - mu) / LS_PRIOR_STD;
        lp += -log_ls - 0.5 * z * z;
    }
    let log_s2 = packed[d + 2];
    let zn = (log_s2 - NOISE_PRIOR_MEAN) / NOISE_PRIOR_STD;
    lp += -log_s2 - 0.5 * zn * zn;
    lp
}

/// Gradient of [`log_prior`] w.r.t. the packed (log-space) parameters.
pub fn log_prior_grad(packed: &[f64]) -> Vec<f64> {
    let d = Theta::dim_of(packed.len());
    let mu = ls_prior_mean(d);
    let mut g = vec![0.0; packed.len()];
    for (i, &log_ls) in packed[..d].iter().enumerate() {
        g[i] = -1.0 - (log_ls - mu) / (LS_PRIOR_STD * LS_PRIOR_STD);
    }
    let log_s2 = packed[d + 2];
    g[d + 2] = -1.0 - (log_s2 - NOISE_PRIOR_MEAN) / (NOISE_PRIOR_STD * NOISE_PRIOR_STD);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_unpack_roundtrip() {
        let packed = vec![0.1, -0.5, 1.2, 0.3, -0.2, -3.5];
        let theta = Theta::unpack(&packed);
        assert_eq!(theta.lengthscales.len(), 3);
        let back = theta.pack();
        for (a, b) in packed.iter().zip(&back) {
            assert!((a - b).abs() < 1e-14);
        }
    }

    #[test]
    fn default_has_ten_params_for_lcbench() {
        assert_eq!(Theta::default_packed(7).len(), 10);
    }

    #[test]
    fn prior_grad_matches_fd() {
        let packed = vec![0.3, -0.1, 0.7, 0.2, 0.4, -3.0];
        let g = log_prior_grad(&packed);
        let h = 1e-6;
        for i in 0..packed.len() {
            let mut p1 = packed.clone();
            let mut p2 = packed.clone();
            p1[i] += h;
            p2[i] -= h;
            let fd = (log_prior(&p1) - log_prior(&p2)) / (2.0 * h);
            assert!((g[i] - fd).abs() < 1e-6, "i={i} g={} fd={}", g[i], fd);
        }
    }

    #[test]
    fn prior_peaks_at_mean() {
        let d = 4;
        // with the -log ls Jacobian term the mode of logN in log-space is
        // mu - sigma^2, so just check finite + decreasing away from mode.
        let mu = ls_prior_mean(d) - LS_PRIOR_STD * LS_PRIOR_STD;
        let mut at_mode = Theta::default_packed(d);
        for v in at_mode.iter_mut().take(d) {
            *v = mu;
        }
        let mut away = at_mode.clone();
        away[0] += 5.0;
        assert!(log_prior(&at_mode) > log_prior(&away));
    }
}
