//! The Latent Kronecker GP engine (pure-rust mirror of the L2 jax graphs).
//!
//! Training and inference never materialize the joint covariance: every
//! operation is expressed through the masked Kronecker operator and
//! iterative methods (paper §2):
//!
//! * MAP objective value: batched CG for alpha + stochastic Lanczos
//!   quadrature for the log determinant
//! * gradient: Hutchinson trace estimator with the same CG solves and the
//!   analytic kernel derivatives (`gp::kernels`)
//! * posterior mean / final-value prediction: CG solves against masked
//!   cross-covariance vectors (exact Gaussian predictive)
//! * posterior samples: Matheron's rule with Kronecker-factored prior
//!   Cholesky — O((n+q)^3 + m^3 ) as the paper quotes
//!
//! This engine is the correctness oracle for the AOT artifacts (they mirror
//! each other's math), the fallback when no artifact bucket fits, and the
//! subject of the Figure-3 LKGP series.
//!
//! The preferred entry point is the session API in [`crate::gp::session`]
//! ([`crate::gp::session::FitSession`] / [`crate::gp::session::Posterior`]
//! with typed queries); the free functions in this module remain as
//! `#[deprecated]` bit-exact shims over it. The `*_impl` internals here
//! are the pure computations the sessions drive.

use std::sync::Arc;

use crate::error::Result;
use crate::gp::kernels;
use crate::gp::params::{self, Theta};
use crate::linalg::{self, CgStats, LinOp, Matrix};
use crate::rng::Pcg64;

use super::operator::{dense_masked_kron, MaskedKronOp, PrecondCfg, PrecondFactors};

/// A learning-curve training set in *model* space (already transformed).
#[derive(Clone, Debug)]
pub struct Dataset {
    /// (n, d) configs in the unit hypercube.
    pub x: Matrix,
    /// (m,) progression grid in the log-spaced unit interval.
    pub t: Vec<f64>,
    /// (n, m) standardized targets; missing entries are exactly 0.
    pub y: Matrix,
    /// (n, m) observation mask in {0, 1}.
    pub mask: Matrix,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.x.rows()
    }

    pub fn m(&self) -> usize {
        self.t.len()
    }

    pub fn d(&self) -> usize {
        self.x.cols()
    }

    pub fn n_obs(&self) -> f64 {
        self.mask.data().iter().sum()
    }

    /// Validate shape consistency.
    pub fn check(&self) -> Result<()> {
        use crate::error::LkgpError::Shape;
        if self.y.rows() != self.n() || self.y.cols() != self.m() {
            return Err(Shape(format!(
                "y is {}x{}, want {}x{}",
                self.y.rows(),
                self.y.cols(),
                self.n(),
                self.m()
            )));
        }
        if self.mask.rows() != self.n() || self.mask.cols() != self.m() {
            return Err(Shape("mask shape mismatch".into()));
        }
        Ok(())
    }
}

/// Numeric precision mode for the masked-Kronecker CG solves.
///
/// `F64` is the historical bit-exact path. `F32` stores the Kronecker
/// factors in f32 (halving the hot working set), accumulates in f64, and
/// wraps the inner solves in an iterative-refinement outer loop whose
/// convergence is measured against the exact f64 operator — so returned
/// residuals are f64-grade even though the heavy matmuls run on rounded
/// storage (cf. arXiv 2312.15305).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Precision {
    /// Pure f64 compute, bit-exact with the historical solver.
    #[default]
    F64,
    /// f32-storage factors + f64 accumulation + iterative refinement.
    F32,
}

impl Precision {
    /// Parse a CLI/config token (`"f64"` / `"f32"`).
    pub fn parse(s: &str) -> Option<Precision> {
        match s.trim().to_ascii_lowercase().as_str() {
            "f64" | "double" => Some(Precision::F64),
            "f32" | "single" | "mixed" => Some(Precision::F32),
            _ => None,
        }
    }

    /// Stable token for logs and bench artifacts.
    pub fn tag(self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::F32 => "f32",
        }
    }
}

/// Solver configuration (paper §B defaults).
#[derive(Clone, Debug)]
pub struct SolverCfg {
    /// CG relative-residual tolerance (paper: 0.01).
    pub cg_tol: f64,
    /// CG iteration cap (paper: 10000).
    pub cg_max_iters: usize,
    /// Hutchinson/SLQ probe count.
    pub probes: usize,
    /// Lanczos (Krylov) iterations for SLQ.
    pub lanczos_iters: usize,
    /// Jitter added to Kronecker-factor Choleskys in Matheron sampling.
    pub jitter: f64,
    /// Preconditioner policy for the masked-Kronecker CG solves (fit,
    /// predict, posterior samples). SLQ's Lanczos quadrature stays on the
    /// raw operator — preconditioning it changes the estimated quantity
    /// (it would need a logdet(P) correction; see docs/solvers.md).
    pub precond: PrecondCfg,
    /// Precision mode for the CG solves (fit, predict, posterior samples,
    /// session training solve). SLQ always runs f64 on the exact operator.
    pub precision: Precision,
    /// Serve `CurveSamples` through pathwise conditioning when the probe
    /// check certifies the full-rank factored apply (docs/sampling.md):
    /// each extra sample costs one factored apply instead of a CG solve.
    /// `false` pins the historical batched-CG sampler.
    pub pathwise: bool,
}

impl Default for SolverCfg {
    fn default() -> Self {
        SolverCfg {
            cg_tol: 1e-2,
            cg_max_iters: 10_000,
            probes: 8,
            lanczos_iters: 16,
            jitter: 1e-6,
            precond: PrecondCfg::Off,
            precision: Precision::F64,
            pathwise: true,
        }
    }
}

/// Run one batched solve through the configured precision mode.
///
/// `F64` is a transparent pass-through to [`MaskedKronOp::solve_precond`]
/// (bit-identical to calling it directly); `F32` routes through the
/// iterative-refinement path and folds the refinement stats into the same
/// [`CgStats`] shape every caller already reports.
pub(crate) fn solve_cfg(
    op: &MaskedKronOp,
    cfg: &SolverCfg,
    rhs: &[f64],
    x0: Option<&[f64]>,
    factors: Option<&PrecondFactors>,
) -> (Vec<f64>, CgStats) {
    match cfg.precision {
        Precision::F64 => op.solve_precond(rhs, x0, factors, cfg.cg_tol, cfg.cg_max_iters),
        Precision::F32 => {
            let (x, st) = op.solve_refined(rhs, x0, factors, cfg.cg_tol, cfg.cg_max_iters);
            (x, st.to_cg_stats())
        }
    }
}

/// Maximum joint dimension (n·m) the dense-Cholesky fallback rung will
/// materialize. 1024 → an 8 MiB dense operator and an O((nm)³) ≈ 1e9-flop
/// factorization — acceptable as a last resort, never as a fast path.
const DENSE_FALLBACK_MAX: usize = 1024;

/// Escalate a preconditioner policy one step for the retry ladder:
/// switched on if it was off, strategy kept but rank pushed up otherwise
/// (`PrecondFactors::build` clamps to the factored dimension).
fn escalate_precond(cfg: PrecondCfg) -> PrecondCfg {
    match cfg {
        PrecondCfg::Off => PrecondCfg::Auto,
        // Auto caps at rank 32 latent / 64 observed-Gram; jump past both.
        PrecondCfg::Auto => PrecondCfg::Rank(128),
        PrecondCfg::Rank(r) => PrecondCfg::Rank(r.saturating_mul(2).max(r + 1)),
    }
}

/// Run one batched solve through the escalation ladder
/// (docs/robustness.md): rung 0 is exactly [`solve_cfg`] — bit-identical
/// to the pre-ladder behavior whenever the solve reports healthy — and
/// each further rung only runs after the previous one failed:
///
/// 1. doubled iteration budget, warm-started from the stalled iterate;
/// 2. a stronger (or switched-on) preconditioner, rebuilt one rank step up;
/// 3. full-f64 retry when the f32 refined path was the failure;
/// 4. dense Cholesky on the materialized operator for small systems.
///
/// Exhaustion surfaces [`crate::LkgpError::Solver`] instead of handing the
/// caller unconverged numbers. The returned [`CgStats`] carry the rung
/// count in `escalations` so the serving layer can count ladder traffic.
#[allow(clippy::too_many_arguments)]
pub(crate) fn solve_healthy(
    op: &MaskedKronOp,
    cfg: &SolverCfg,
    rhs: &[f64],
    x0: Option<&[f64]>,
    factors: Option<&PrecondFactors>,
    k1: &Matrix,
    k2: &Matrix,
    mask: &Matrix,
    packed: &[f64],
    sigma2: f64,
) -> Result<(Vec<f64>, CgStats)> {
    let (x, stats) = solve_cfg(op, cfg, rhs, x0, factors);
    if stats.health().is_healthy() {
        return Ok((x, stats));
    }

    // Severity-then-residual ordering for keeping the best failed attempt
    // (its iterate seeds the next rung's warm start; its health names the
    // terminal error if every rung fails).
    fn better(a: &CgStats, b: &CgStats) -> bool {
        let (ha, hb) = (a.health(), b.health());
        ha < hb || (ha == hb && a.worst_rel_residual() < b.worst_rel_residual())
    }
    // Warm each retry from the best finite iterate so far; a poisoned
    // buffer would re-poison the next attempt.
    fn warm_of(best: &[f64], fallback: Option<&[f64]>) -> Option<Vec<f64>> {
        // lint: allow(float_eq) — all-zero is the cold-start sentinel for
        // a warm-guess buffer (same contract as pcg's warm path).
        if best.iter().all(|v| v.is_finite()) && best.iter().any(|&v| v != 0.0) {
            Some(best.to_vec())
        } else {
            fallback
                .filter(|g| g.iter().all(|v| v.is_finite()))
                .map(|g| g.to_vec())
        }
    }

    let mut rungs = 0usize;
    let mut best_x = x;
    let mut best = stats;
    let bigger_budget = cfg.cg_max_iters.saturating_mul(2).max(cfg.cg_max_iters + 16);

    // Rung 1: doubled iteration budget (the plain ill-conditioned stall).
    {
        rungs += 1;
        let c = SolverCfg { cg_max_iters: bigger_budget, ..cfg.clone() };
        let guess = warm_of(&best_x, x0);
        let (x, mut st) = solve_cfg(op, &c, rhs, guess.as_deref(), factors);
        if st.health().is_healthy() {
            st.escalations = rungs;
            return Ok((x, st));
        }
        if better(&st, &best) {
            best_x = x;
            best = st;
        }
    }

    // Rung 2: stronger / switched preconditioner (still doubled budget).
    {
        rungs += 1;
        let esc = escalate_precond(cfg.precond);
        if let Some(f) = PrecondFactors::build(esc, k1, k2, mask, packed) {
            let c = SolverCfg {
                cg_max_iters: bigger_budget,
                precond: esc,
                ..cfg.clone()
            };
            let guess = warm_of(&best_x, x0);
            let (x, mut st) = solve_cfg(op, &c, rhs, guess.as_deref(), Some(&f));
            if st.health().is_healthy() {
                st.escalations = rungs;
                return Ok((x, st));
            }
            if better(&st, &best) {
                best_x = x;
                best = st;
            }
        }
    }

    // Rung 3: the refined f32 path failed — promote to full f64.
    if cfg.precision == Precision::F32 {
        rungs += 1;
        let c = SolverCfg {
            cg_max_iters: bigger_budget,
            precision: Precision::F64,
            ..cfg.clone()
        };
        let guess = warm_of(&best_x, x0);
        let (x, mut st) = solve_cfg(op, &c, rhs, guess.as_deref(), factors);
        if st.health().is_healthy() {
            st.escalations = rungs;
            return Ok((x, st));
        }
        if better(&st, &best) {
            best_x = x;
            best = st;
        }
    }

    // Rung 4: dense Cholesky for small systems — O((nm)³) but exact, and
    // its answer is verified against the true operator residual below.
    let nm = k1.rows() * k2.rows();
    if nm > 0 && nm <= DENSE_FALLBACK_MAX && rhs.len() % nm == 0 {
        rungs += 1;
        let batch = rhs.len() / nm;
        let dense = dense_masked_kron(k1, k2, mask, sigma2);
        if let Ok(l) = linalg::cholesky(&dense) {
            let mut x = Vec::with_capacity(rhs.len());
            for b in 0..batch {
                x.extend_from_slice(&linalg::chol_solve(&l, &rhs[b * nm..(b + 1) * nm]));
            }
            // Honest report: measure the true relative residual of the
            // dense answer against the iterative operator.
            let mut ax = vec![0.0; rhs.len()];
            op.apply_batch(&x, &mut ax, batch);
            let rel: Vec<f64> = (0..batch)
                .map(|b| {
                    let (rb, xb) = (&rhs[b * nm..(b + 1) * nm], &ax[b * nm..(b + 1) * nm]);
                    let bn = linalg::matrix::dot(rb, rb).sqrt().max(1e-300);
                    let rn = rb
                        .iter()
                        .zip(xb)
                        .map(|(bi, ai)| (bi - ai) * (bi - ai))
                        .sum::<f64>()
                        .sqrt();
                    rn / bn
                })
                .collect();
            let non_finite = rel.iter().any(|v| !v.is_finite())
                || x.iter().any(|v| !v.is_finite());
            let converged =
                !non_finite && rel.iter().all(|&r| r <= cfg.cg_tol * 1.0001);
            let st = CgStats {
                iters: 0,
                iters_per_rhs: vec![0; batch],
                rel_residual: rel,
                converged,
                mvms: 1,
                mvm_rows: batch,
                breakdowns: 0,
                non_finite,
                escalations: rungs,
                fallback_dense: true,
            };
            if st.health().is_healthy() {
                return Ok((x, st));
            }
            if better(&st, &best) {
                best = st;
            }
        }
    }

    Err(crate::error::LkgpError::Solver {
        health: best.health().tag().to_string(),
        rungs,
        rel_residual: best.worst_rel_residual(),
    })
}

/// Resolve the preconditioner for one solve: reuse compatible cached
/// factors (hyper-parameters drift slowly across optimizer steps and
/// scheduler generations), rebuild otherwise.
pub(crate) fn resolve_precond(
    cfg: &SolverCfg,
    packed: &[f64],
    k1: &Matrix,
    k2: &Matrix,
    mask: &Matrix,
    cached: Option<&Arc<PrecondFactors>>,
) -> Option<Arc<PrecondFactors>> {
    if !cfg.precond.enabled() {
        return None;
    }
    let (n, m) = (k1.rows(), k2.rows());
    if let Some(f) = cached {
        if f.compatible(packed, n, m, mask) {
            return Some(f.clone());
        }
    }
    PrecondFactors::build(cfg.precond, k1, k2, mask, packed).map(Arc::new)
}

/// MAP objective evaluation output.
#[derive(Clone, Debug)]
pub struct MllEval {
    /// MAP objective (marginal log-likelihood + log prior).
    pub value: f64,
    /// Gradient w.r.t. packed (log-space) parameters.
    pub grad: Vec<f64>,
    /// CG convergence stats for the batched solve.
    pub cg: CgStats,
}

/// Evaluate the MAP objective and its gradient at `packed` parameters.
///
/// `probes` is a (p, n*m) row-major Rademacher buffer; passing the same
/// probes across optimizer steps gives a deterministic (probe-conditioned)
/// objective, which is what both trainers rely on.
#[deprecated(note = "use gp::session::FitSession::eval — see docs/api.md")]
pub fn mll_value_grad(
    packed: &[f64],
    data: &Dataset,
    probes: &[f64],
    cfg: &SolverCfg,
) -> Result<MllEval> {
    Ok(mll_value_grad_warm(packed, data, probes, cfg, None)?.0)
}

/// [`mll_value_grad`] with an optional warm start for the batched CG solve
/// and the raw solve buffer returned for reuse.
///
/// `x0` is a previous `(p + 1, n*m)` solve buffer (as returned by this
/// function). A [`crate::gp::session::FitSession`] owns this buffer for
/// you — this shim exists for callers that still thread it by hand.
#[deprecated(note = "use gp::session::FitSession (warm state is owned by the session) — see docs/api.md")]
pub fn mll_value_grad_warm(
    packed: &[f64],
    data: &Dataset,
    probes: &[f64],
    cfg: &SolverCfg,
    x0: Option<&[f64]>,
) -> Result<(MllEval, Vec<f64>)> {
    let mut precond_cache = None;
    mll_value_grad_cached(packed, data, probes, cfg, x0, &mut precond_cache)
}

/// [`mll_value_grad_warm`] with persistent preconditioner state. Thin
/// shim: builds a one-shot [`crate::gp::session::FitSession`], seeds it
/// with the caller's state, evaluates, and copies the state back out —
/// bit-exact with the historical free function (see tests/session.rs).
#[deprecated(note = "use gp::session::FitSession (eval/fit) — see docs/api.md")]
pub fn mll_value_grad_cached(
    packed: &[f64],
    data: &Dataset,
    probes: &[f64],
    cfg: &SolverCfg,
    x0: Option<&[f64]>,
    precond_cache: &mut Option<Arc<PrecondFactors>>,
) -> Result<(MllEval, Vec<f64>)> {
    // NOTE: a one-shot session copies the dataset and probe buffer —
    // another reason to migrate; a real FitSession pays this once, not
    // per evaluation. The caller's factor cache is cloned (cheap Arc),
    // not taken, so an error leaves it intact like the historical code.
    let mut session = crate::gp::session::FitSession::with_probes(
        Arc::new(data.clone()),
        cfg.clone(),
        probes.to_vec(),
    )?;
    session.seed_state(x0.map(|g| g.to_vec()), precond_cache.clone());
    let eval = session.eval(packed)?;
    *precond_cache = session.precond();
    let solves = session
        .warm_buffer()
        .map(|w| w.to_vec())
        .unwrap_or_default();
    Ok((eval, solves))
}

/// MAP objective + gradient core: one batched `[y, probes]` (P)CG solve,
/// SLQ log-det, Hutchinson trace gradients. State threading (warm buffer,
/// preconditioner cache) is owned by `gp::session`; this is the pure
/// computation.
pub(crate) fn mll_impl(
    packed: &[f64],
    data: &Dataset,
    probes: &[f64],
    cfg: &SolverCfg,
    x0: Option<&[f64]>,
    precond_cache: &mut Option<Arc<PrecondFactors>>,
) -> Result<(MllEval, Vec<f64>)> {
    data.check()?;
    let (n, m) = (data.n(), data.m());
    let nm = n * m;
    let d = data.d();
    assert_eq!(packed.len(), d + 3, "theta length");
    let p = probes.len() / nm;
    assert!(p > 0, "need probes");

    let theta = Theta::unpack(packed);
    let k1 = kernels::rbf(&data.x, &data.x, &theta.lengthscales);
    let k2 = kernels::matern12(&data.t, &data.t, theta.t_lengthscale, theta.outputscale);
    let op = MaskedKronOp::new(&k1, &k2, &data.mask, theta.sigma2);

    // --- batched (P)CG: [y, z_1 .. z_p] ---
    let mut rhs = Vec::with_capacity((p + 1) * nm);
    rhs.extend_from_slice(data.y.data());
    rhs.extend_from_slice(&probes[..p * nm]);
    let factors = resolve_precond(cfg, packed, &k1, &k2, &data.mask, precond_cache.as_ref());
    let (solves, cg) = solve_healthy(
        &op,
        cfg,
        &rhs,
        x0,
        factors.as_deref(),
        &k1,
        &k2,
        &data.mask,
        packed,
        theta.sigma2,
    )?;
    *precond_cache = factors;
    let alpha = &solves[..nm];
    let us = &solves[nm..];

    // --- value ---
    let n_obs = data.n_obs();
    let logdet_full = linalg::slq_logdet(&op, &probes[..p * nm], cfg.lanczos_iters);
    let logdet_obs = logdet_full - (nm as f64 - n_obs) * theta.sigma2.ln();
    let fit = -0.5 * linalg::matrix::dot(data.y.data(), alpha);
    let value = fit - 0.5 * logdet_obs - 0.5 * n_obs * (2.0 * std::f64::consts::PI).ln()
        + params::log_prior(packed);

    // --- gradient ---
    // For each kernel parameter k: grad_k = 1/2 a^T dA_k a
    //   - 1/2 mean_i z_i^T dA_k u_i, with dA_k = M (dK1 (x) K2) M etc.
    let mut grad = params::log_prior_grad(packed);

    // Quadratic forms against a substituted factor pair (ka, kb):
    // q(v, w) = (M v)^T reshape^-1( ka (M w) kb ) accumulated per pair.
    let quad = |ka: &Matrix, kb: &Matrix, v: &[f64], w: &[f64]| -> f64 {
        let mv = mask_product(&data.mask, w, n, m);
        let tmp = mv.matmul(kb);
        let full = ka.matmul(&tmp);
        let mut s = 0.0;
        let mk = data.mask.data();
        let fd = full.data();
        for i in 0..nm {
            s += v[i] * mk[i] * fd[i];
        }
        s
    };

    // RBF lengthscales.
    for dim in 0..d {
        let dk1 = kernels::rbf_grad_log_ls(&data.x, &data.x, &theta.lengthscales, &k1, dim);
        let mut g = 0.5 * quad(&dk1, &k2, alpha, alpha);
        let mut tr = 0.0;
        for i in 0..p {
            tr += quad(&dk1, &k2, &probes[i * nm..(i + 1) * nm], &us[i * nm..(i + 1) * nm]);
        }
        g -= 0.5 * tr / p as f64;
        grad[dim] += g;
    }
    // t lengthscale and outputscale act through K2.
    let dk2_ls = kernels::matern12_grad_log_ls(&data.t, &data.t, theta.t_lengthscale, &k2);
    for (pi, dk2) in [(d, &dk2_ls), (d + 1, &k2)] {
        let mut g = 0.5 * quad(&k1, dk2, alpha, alpha);
        let mut tr = 0.0;
        for i in 0..p {
            tr += quad(&k1, dk2, &probes[i * nm..(i + 1) * nm], &us[i * nm..(i + 1) * nm]);
        }
        g -= 0.5 * tr / p as f64;
        grad[pi] += g;
    }
    // Noise: dA/dlog s2 = s2 I (full space) + padding correction.
    {
        let s2 = theta.sigma2;
        let a_dot = linalg::matrix::dot(alpha, alpha);
        let mut tr = 0.0;
        for i in 0..p {
            tr += linalg::matrix::dot(&probes[i * nm..(i + 1) * nm], &us[i * nm..(i + 1) * nm]);
        }
        grad[d + 2] += 0.5 * s2 * a_dot - 0.5 * s2 * tr / p as f64 + 0.5 * (nm as f64 - n_obs);
    }

    Ok((MllEval { value, grad, cg }, solves))
}

pub(crate) fn mask_product(mask: &Matrix, v: &[f64], n: usize, m: usize) -> Matrix {
    let mut out = Matrix::zeros(n, m);
    for (dst, (a, b)) in out
        .data_mut()
        .iter_mut()
        .zip(v.iter().zip(mask.data()))
    {
        *dst = a * b;
    }
    out
}

/// Exact MAP objective via dense Cholesky on the observed block
/// (O(n_obs^3); test oracle shared with the naive engine).
pub fn mll_exact(packed: &[f64], data: &Dataset) -> Result<f64> {
    let theta = Theta::unpack(packed);
    let k1 = kernels::rbf(&data.x, &data.x, &theta.lengthscales);
    let k2 = kernels::matern12(&data.t, &data.t, theta.t_lengthscale, theta.outputscale);
    let (n, m) = (data.n(), data.m());
    let idx: Vec<usize> = data
        .mask
        .data()
        .iter()
        .enumerate()
        .filter(|(_, &mv)| mv > 0.0)
        .map(|(i, _)| i)
        .collect();
    let no = idx.len();
    let mut kobs = Matrix::zeros(no, no);
    for (a, &ia) in idx.iter().enumerate() {
        let (i1, j1) = (ia / m, ia % m);
        for (b, &ib) in idx.iter().enumerate() {
            let (i2, j2) = (ib / m, ib % m);
            kobs[(a, b)] = k1[(i1, i2)] * k2[(j1, j2)];
        }
    }
    kobs.add_diag(theta.sigma2);
    let l = linalg::cholesky(&kobs)?;
    let yobs: Vec<f64> = idx.iter().map(|&i| data.y.data()[i]).collect();
    let alpha = linalg::chol_solve(&l, &yobs);
    let _ = n;
    Ok(
        -0.5 * linalg::matrix::dot(&yobs, &alpha) - 0.5 * linalg::chol_logdet(&l)
            - 0.5 * no as f64 * (2.0 * std::f64::consts::PI).ln()
            + params::log_prior(packed),
    )
}

/// Posterior mean over the full grid for query configs.
///
/// mean(xq, .) = k1(xq, X) (M . A) K2 with A = reshape(CG(A, vec(Y))).
/// Thin shim: a one-shot [`crate::gp::session::Posterior`] answering
/// `Query::MeanAtSteps` over the whole grid.
#[deprecated(note = "use gp::session::Posterior with Query::MeanAtSteps — see docs/api.md")]
pub fn predict_mean(packed: &[f64], data: &Dataset, xq: &Matrix, cfg: &SolverCfg) -> Result<(Matrix, CgStats)> {
    let mut post = crate::gp::session::Posterior::new(
        Arc::new(data.clone()),
        packed.to_vec(),
        cfg.clone(),
    );
    let steps: Vec<usize> = (0..data.m()).collect();
    let answer = post.answer(&crate::gp::session::Query::MeanAtSteps { xq: xq.clone(), steps })?;
    let mean = match answer {
        crate::gp::session::Answer::Steps(mat) => mat,
        _ => unreachable!("MeanAtSteps answers Steps"),
    };
    let cg = post.last_cg().cloned().expect("mean query ran a solve");
    Ok((mean, cg))
}

/// Exact Gaussian predictive for the *final* progression value of each
/// query config: returns (mean, variance-with-noise) pairs.
///
/// Each query needs one extra CG solve against its masked cross-covariance
/// vector; the q solves are batched into a single CG call.
#[deprecated(note = "use gp::session::Posterior with Query::MeanAtFinal — see docs/api.md")]
pub fn predict_final(
    packed: &[f64],
    data: &Dataset,
    xq: &Matrix,
    cfg: &SolverCfg,
) -> Result<Vec<(f64, f64)>> {
    Ok(predict_final_warm(packed, data, xq, cfg, None)?.0)
}

/// [`predict_final`] with an optional warm start for the batched solve.
///
/// `guess` is either a flattened `(n, m)` initial guess for the
/// `A^{-1} vec(Y)` column alone, or a full `(q + 1) * n * m` buffer
/// covering the cross-covariance columns too (e.g. a previous
/// generation's solves, embedded by trial row — see
/// `coordinator::store::WarmStart`). It is ignored when the length
/// matches neither. Returns the predictions, the full converged solve
/// buffer (`[alpha, w_1 .. w_q]`, for caching by the serving layer), and
/// the CG stats.
#[deprecated(note = "use gp::session::Posterior::with_guess + Query::MeanAtFinal — see docs/api.md")]
pub fn predict_final_warm(
    packed: &[f64],
    data: &Dataset,
    xq: &Matrix,
    cfg: &SolverCfg,
    guess: Option<&[f64]>,
) -> Result<(Vec<(f64, f64)>, Vec<f64>, CgStats)> {
    let mut precond_cache = None;
    predict_final_cached(packed, data, xq, cfg, guess, &mut precond_cache)
}

/// [`predict_final_warm`] with persistent preconditioner state. Thin
/// shim: builds a one-shot [`crate::gp::session::Posterior`] seeded with
/// the caller's guess and factors, answers `Query::MeanAtFinal`, and
/// copies the converged state back out — bit-exact with the historical
/// free function (see tests/session.rs).
#[deprecated(note = "use gp::session::Posterior (guess/precond lineage is owned by the session) — see docs/api.md")]
pub fn predict_final_cached(
    packed: &[f64],
    data: &Dataset,
    xq: &Matrix,
    cfg: &SolverCfg,
    guess: Option<&[f64]>,
    precond_cache: &mut Option<Arc<PrecondFactors>>,
) -> Result<(Vec<(f64, f64)>, Vec<f64>, CgStats)> {
    // The caller's factor cache is cloned (cheap Arc), not taken, so an
    // error path leaves it intact like the historical code did.
    let mut post = crate::gp::session::Posterior::new(
        Arc::new(data.clone()),
        packed.to_vec(),
        cfg.clone(),
    )
    .with_guess(guess.map(|g| g.to_vec()))
    .with_precond(precond_cache.clone());
    let answer = post.answer(&crate::gp::session::Query::MeanAtFinal { xq: xq.clone() })?;
    *precond_cache = post.precond();
    let preds = match answer {
        crate::gp::session::Answer::Final(v) => v,
        _ => unreachable!("MeanAtFinal answers Final"),
    };
    let solves = post.solve_buffer().expect("predict ran a solve");
    let cg = post.last_cg().cloned().expect("predict ran a solve");
    Ok((preds, solves, cg))
}

/// Final-value predictive core: one batched `[y, c_1..c_q]` (P)CG solve
/// against the masked cross-covariance columns. State threading is owned
/// by `gp::session`; this is the pure computation.
pub(crate) fn predict_final_impl(
    packed: &[f64],
    data: &Dataset,
    xq: &Matrix,
    cfg: &SolverCfg,
    guess: Option<&[f64]>,
    precond_cache: &mut Option<Arc<PrecondFactors>>,
) -> Result<(Vec<(f64, f64)>, Vec<f64>, CgStats)> {
    data.check()?;
    let theta = Theta::unpack(packed);
    let (n, m) = (data.n(), data.m());
    let nm = n * m;
    let q = xq.rows();
    let k1 = kernels::rbf(&data.x, &data.x, &theta.lengthscales);
    let k2 = kernels::matern12(&data.t, &data.t, theta.t_lengthscale, theta.outputscale);
    let op = MaskedKronOp::new(&k1, &k2, &data.mask, theta.sigma2);

    // Cross-covariance columns c_j = M . (k1(X, xq_j) (x) k2(t, t_last)).
    let k1qx = kernels::rbf(&data.x, xq, &theta.lengthscales); // (n, q)
    let t_last = [data.t[m - 1]];
    let k2t = kernels::matern12(&data.t, &t_last, theta.t_lengthscale, theta.outputscale); // (m, 1)

    let mut rhs = Vec::with_capacity((q + 1) * nm);
    rhs.extend_from_slice(data.y.data());
    for j in 0..q {
        for i in 0..n {
            for jj in 0..m {
                rhs.push(data.mask[(i, jj)] * k1qx[(i, j)] * k2t[(jj, 0)]);
            }
        }
    }
    // Embed the guess into the full batched buffer: an alpha-only guess
    // leaves the cross-covariance columns cold; a full buffer warms them
    // all (the serving layer caches both).
    let x0: Option<Vec<f64>> = guess.and_then(|g| {
        if g.len() == rhs.len() {
            return Some(g.to_vec());
        }
        if g.len() != nm {
            return None;
        }
        let mut x = vec![0.0; rhs.len()];
        x[..nm].copy_from_slice(g);
        Some(x)
    });
    let factors = resolve_precond(cfg, packed, &k1, &k2, &data.mask, precond_cache.as_ref());
    let (solves, cg) = solve_healthy(
        &op,
        cfg,
        &rhs,
        x0.as_deref(),
        factors.as_deref(),
        &k1,
        &k2,
        &data.mask,
        packed,
        theta.sigma2,
    )?;
    *precond_cache = factors;

    let prior_var = theta.outputscale; // k1(xq,xq)=1, k2(t*,t*)=outputscale
    let mut out = Vec::with_capacity(q);
    {
        let alpha = &solves[..nm];
        for j in 0..q {
            let c = &rhs[(j + 1) * nm..(j + 2) * nm];
            let w = &solves[(j + 1) * nm..(j + 2) * nm];
            let mean = linalg::matrix::dot(c, alpha);
            let var = (prior_var - linalg::matrix::dot(c, w)).max(1e-12) + theta.sigma2;
            out.push((mean, var));
        }
    }
    Ok((out, solves, cg))
}

/// Final-value predictions from an already-converged `[alpha, w_1..w_q]`
/// solve buffer, with NO solver involvement: rebuilds the cross-covariance
/// columns and applies the same mean/variance arithmetic as
/// [`predict_final_impl`], so the result is bit-identical to the solve
/// that produced the buffer. This is how a forked read-only `Posterior`
/// (replica shards, `docs/serving.md`) serves cached lineage without
/// paying a CG solve. Returns `None` when the buffer shapes do not match
/// the problem.
pub(crate) fn preds_from_solves(
    packed: &[f64],
    data: &Dataset,
    xq: &Matrix,
    alpha: &[f64],
    cross_solves: &[f64],
) -> Option<Vec<(f64, f64)>> {
    let theta = Theta::unpack(packed);
    let (n, m) = (data.n(), data.m());
    let nm = n * m;
    let q = xq.rows();
    if alpha.len() != nm || cross_solves.len() != q * nm || xq.cols() != data.d() {
        return None;
    }
    let k1qx = kernels::rbf(&data.x, xq, &theta.lengthscales); // (n, q)
    let t_last = [data.t[m - 1]];
    let k2t = kernels::matern12(&data.t, &t_last, theta.t_lengthscale, theta.outputscale);
    let prior_var = theta.outputscale;
    let mut out = Vec::with_capacity(q);
    // c_j is materialized row-by-row with the exact expression
    // predict_final_impl uses to build its RHS, so the dot products see
    // bitwise-identical inputs.
    let mut c = vec![0.0; nm];
    for j in 0..q {
        for i in 0..n {
            for jj in 0..m {
                c[i * m + jj] = data.mask[(i, jj)] * k1qx[(i, j)] * k2t[(jj, 0)];
            }
        }
        let w = &cross_solves[j * nm..(j + 1) * nm];
        let mean = linalg::matrix::dot(&c, alpha);
        let var = (prior_var - linalg::matrix::dot(&c, w)).max(1e-12) + theta.sigma2;
        out.push((mean, var));
    }
    Some(out)
}

/// Posterior samples over [X; Xq] x grid via Matheron's rule.
///
/// Returns `s` samples, each an (n+q, m) matrix. Thin shim over
/// [`crate::gp::session::Posterior::sample_curves_with`] (bit-exact given
/// the same RNG stream; `Query::CurveSamples { seed }` seeds its own).
#[deprecated(note = "use gp::session::Posterior with Query::CurveSamples — see docs/api.md")]
pub fn posterior_samples(
    packed: &[f64],
    data: &Dataset,
    xq: &Matrix,
    s: usize,
    cfg: &SolverCfg,
    rng: &mut Pcg64,
) -> Result<Vec<Matrix>> {
    let mut post = crate::gp::session::Posterior::new(
        Arc::new(data.clone()),
        packed.to_vec(),
        cfg.clone(),
    );
    post.sample_curves_with(xq, s, rng)
}

/// Matheron-sampling core: Kronecker-factored prior draws plus one
/// batched pathwise (P)CG solve. `precond_cache` lets a session amortize
/// the factorization across calls; the converged stats are returned for
/// the session's telemetry.
pub(crate) fn posterior_samples_impl(
    packed: &[f64],
    data: &Dataset,
    xq: &Matrix,
    s: usize,
    cfg: &SolverCfg,
    rng: &mut Pcg64,
    precond_cache: &mut Option<Arc<PrecondFactors>>,
) -> Result<(Vec<Matrix>, CgStats)> {
    data.check()?;
    let theta = Theta::unpack(packed);
    let (n, m) = (data.n(), data.m());
    let nm = n * m;
    let q = xq.rows();
    let nj = n + q;

    let k1 = kernels::rbf(&data.x, &data.x, &theta.lengthscales);
    let k2 = kernels::matern12(&data.t, &data.t, theta.t_lengthscale, theta.outputscale);
    let op = MaskedKronOp::new(&k1, &k2, &data.mask, theta.sigma2);

    // Joint config kernel and its Cholesky factors.
    let mut xj = Matrix::zeros(nj, data.d());
    for i in 0..n {
        xj.row_mut(i).copy_from_slice(data.x.row(i));
    }
    for i in 0..q {
        xj.row_mut(n + i).copy_from_slice(xq.row(i));
    }
    let mut k1j = kernels::rbf(&xj, &xj, &theta.lengthscales);
    k1j.add_diag(cfg.jitter);
    let l1 = linalg::cholesky(&k1j)?;
    let mut k2j = k2.clone();
    k2j.add_diag(cfg.jitter);
    let l2 = linalg::cholesky(&k2j)?;
    let l2t = l2.transpose();

    // Prior samples f_s = L1 Z_s L2^T, batched RHS for the pathwise update.
    let mut priors: Vec<Matrix> = Vec::with_capacity(s);
    let mut rhs = Vec::with_capacity(s * nm);
    let sigma = theta.sigma2.sqrt();
    for _ in 0..s {
        let z = Matrix::from_vec(nj, m, rng.normal_vec(nj * m));
        let f = l1.matmul(&z).matmul(&l2t);
        for i in 0..n {
            for j in 0..m {
                let noise = sigma * rng.normal();
                rhs.push(data.mask[(i, j)] * (data.y[(i, j)] - f[(i, j)] - noise));
            }
        }
        priors.push(f);
    }
    let factors = resolve_precond(cfg, packed, &k1, &k2, &data.mask, precond_cache.as_ref());
    let (ws, cg) = solve_healthy(
        &op,
        cfg,
        &rhs,
        None,
        factors.as_deref(),
        &k1,
        &k2,
        &data.mask,
        packed,
        theta.sigma2,
    )?;
    *precond_cache = factors;

    // k1([X; Xq], X) is the left block of k1j (jitter only touched diag).
    let k1cross = {
        let mut c = Matrix::zeros(nj, n);
        for i in 0..nj {
            for j in 0..n {
                c[(i, j)] = if i == j { k1j[(i, j)] - cfg.jitter } else { k1j[(i, j)] };
            }
        }
        c
    };

    let mut out = Vec::with_capacity(s);
    for (si, mut f) in priors.into_iter().enumerate() {
        let w = mask_product(&data.mask, &ws[si * nm..(si + 1) * nm], n, m);
        let update = k1cross.matmul(&w).matmul(&k2);
        f.add_assign(&update);
        out.push(f);
    }
    Ok((out, cg))
}

#[cfg(test)]
#[allow(deprecated)] // unit tests double as coverage for the deprecated shims
mod tests {
    use super::*;

    pub(crate) fn toy_dataset(n: usize, m: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Pcg64::new(seed);
        let x = Matrix::from_vec(n, d, rng.uniform_vec(n * d, 0.0, 1.0));
        let t: Vec<f64> = (0..m).map(|i| i as f64 / (m - 1).max(1) as f64).collect();
        // prefix masks (early stopping pattern)
        let mut mask = Matrix::zeros(n, m);
        for i in 0..n {
            let len = 2 + rng.below(m - 1);
            for j in 0..len {
                mask[(i, j)] = 1.0;
            }
        }
        let mut y = Matrix::zeros(n, m);
        for i in 0..n {
            let a = rng.uniform_in(0.5, 1.0);
            for j in 0..m {
                if mask[(i, j)] > 0.0 {
                    y[(i, j)] = -a * (-3.0 * t[j]).exp() + 0.02 * rng.normal();
                }
            }
        }
        Dataset { x, t, y, mask }
    }

    #[test]
    fn mll_value_close_to_exact() {
        // SLQ value noise is ~N/sqrt(p); with p=256 probes the std on this
        // problem is ~0.5 nats (measured), so a 2-nat budget is ~4 sigma.
        let data = toy_dataset(10, 8, 3, 1);
        let packed = Theta::default_packed(3);
        let mut rng = Pcg64::new(2);
        let probes = rng.rademacher_vec(256 * 80);
        let cfg = SolverCfg { probes: 256, lanczos_iters: 16, ..Default::default() };
        let eval = mll_value_grad(&packed, &data, &probes, &cfg).unwrap();
        let exact = mll_exact(&packed, &data).unwrap();
        assert!(
            (eval.value - exact).abs() < 2.0,
            "iter={} exact={exact}",
            eval.value
        );
    }

    #[test]
    fn mll_grad_matches_exact_fd() {
        let data = toy_dataset(9, 7, 2, 3);
        let mut packed = Theta::default_packed(2);
        packed[0] -= 0.7; // move off the prior mean
        let mut rng = Pcg64::new(4);
        let probes = rng.rademacher_vec(64 * 63);
        let cfg = SolverCfg { probes: 64, cg_tol: 1e-10, ..Default::default() };
        let eval = mll_value_grad(&packed, &data, &probes, &cfg).unwrap();
        let h = 1e-5;
        let mut fd = vec![0.0; packed.len()];
        for i in 0..packed.len() {
            let mut p1 = packed.clone();
            let mut p2 = packed.clone();
            p1[i] += h;
            p2[i] -= h;
            fd[i] = (mll_exact(&p1, &data).unwrap() - mll_exact(&p2, &data).unwrap()) / (2.0 * h);
        }
        let nf = fd.iter().map(|g| g * g).sum::<f64>().sqrt();
        let diff = eval
            .grad
            .iter()
            .zip(&fd)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        assert!(diff / nf < 0.1, "grad={:?} fd={:?}", eval.grad, fd);
    }

    #[test]
    fn predict_mean_matches_dense() {
        let data = toy_dataset(8, 6, 2, 5);
        let packed = Theta::default_packed(2);
        let mut rng = Pcg64::new(6);
        let xq = Matrix::from_vec(3, 2, rng.uniform_vec(6, 0.0, 1.0));
        let cfg = SolverCfg { cg_tol: 1e-11, ..Default::default() };
        let (mean, _) = predict_mean(&packed, &data, &xq, &cfg).unwrap();

        // dense oracle
        let theta = Theta::unpack(&packed);
        let k1 = kernels::rbf(&data.x, &data.x, &theta.lengthscales);
        let k2 = kernels::matern12(&data.t, &data.t, theta.t_lengthscale, theta.outputscale);
        let (n, m) = (8, 6);
        let idx: Vec<usize> = data
            .mask
            .data()
            .iter()
            .enumerate()
            .filter(|(_, &mv)| mv > 0.0)
            .map(|(i, _)| i)
            .collect();
        let no = idx.len();
        let mut kobs = Matrix::zeros(no, no);
        for (a, &ia) in idx.iter().enumerate() {
            for (b, &ib) in idx.iter().enumerate() {
                kobs[(a, b)] = k1[(ia / m, ib / m)] * k2[(ia % m, ib % m)];
            }
        }
        kobs.add_diag(theta.sigma2);
        let l = linalg::cholesky(&kobs).unwrap();
        let yobs: Vec<f64> = idx.iter().map(|&i| data.y.data()[i]).collect();
        let alpha = linalg::chol_solve(&l, &yobs);
        let k1q = kernels::rbf(&xq, &data.x, &theta.lengthscales);
        for qi in 0..3 {
            for j in 0..m {
                let mut want = 0.0;
                for (a, &ia) in idx.iter().enumerate() {
                    want += k1q[(qi, ia / m)] * k2[(j, ia % m)] * alpha[a];
                }
                assert!((mean[(qi, j)] - want).abs() < 1e-6, "q={qi} j={j}");
            }
        }
        let _ = n;
    }

    #[test]
    fn predict_final_matches_dense_variance() {
        let data = toy_dataset(7, 5, 2, 7);
        let packed = Theta::default_packed(2);
        let mut rng = Pcg64::new(8);
        let xq = Matrix::from_vec(2, 2, rng.uniform_vec(4, 0.0, 1.0));
        let cfg = SolverCfg { cg_tol: 1e-11, ..Default::default() };
        let preds = predict_final(&packed, &data, &xq, &cfg).unwrap();

        let theta = Theta::unpack(&packed);
        let k1 = kernels::rbf(&data.x, &data.x, &theta.lengthscales);
        let k2 = kernels::matern12(&data.t, &data.t, theta.t_lengthscale, theta.outputscale);
        let m = 5;
        let idx: Vec<usize> = data
            .mask
            .data()
            .iter()
            .enumerate()
            .filter(|(_, &mv)| mv > 0.0)
            .map(|(i, _)| i)
            .collect();
        let no = idx.len();
        let mut kobs = Matrix::zeros(no, no);
        for (a, &ia) in idx.iter().enumerate() {
            for (b, &ib) in idx.iter().enumerate() {
                kobs[(a, b)] = k1[(ia / m, ib / m)] * k2[(ia % m, ib % m)];
            }
        }
        kobs.add_diag(theta.sigma2);
        let l = linalg::cholesky(&kobs).unwrap();
        let yobs: Vec<f64> = idx.iter().map(|&i| data.y.data()[i]).collect();
        let alpha = linalg::chol_solve(&l, &yobs);
        let k1q = kernels::rbf(&xq, &data.x, &theta.lengthscales);
        for qi in 0..2 {
            let c: Vec<f64> = idx
                .iter()
                .map(|&ia| k1q[(qi, ia / m)] * k2[(m - 1, ia % m)])
                .collect();
            let mean = linalg::matrix::dot(&c, &alpha);
            let w = linalg::chol_solve(&l, &c);
            let var = theta.outputscale - linalg::matrix::dot(&c, &w) + theta.sigma2;
            assert!((preds[qi].0 - mean).abs() < 1e-6);
            assert!((preds[qi].1 - var).abs() < 1e-6);
        }
    }

    #[test]
    fn predict_final_warm_matches_cold() {
        let data = toy_dataset(8, 6, 2, 13);
        let nm = 8 * 6;
        let packed = Theta::default_packed(2);
        let mut rng = Pcg64::new(14);
        let xq = Matrix::from_vec(2, 2, rng.uniform_vec(4, 0.0, 1.0));
        let cfg = SolverCfg { cg_tol: 1e-10, ..Default::default() };
        let cold = predict_final(&packed, &data, &xq, &cfg).unwrap();
        let (preds, solves, _) = predict_final_warm(&packed, &data, &xq, &cfg, None).unwrap();
        assert_eq!(preds, cold);
        assert_eq!(solves.len(), 3 * nm); // alpha + one column per query
        // alpha-only guess: the y column is ~free, cross columns run cold
        let (warm, _, stats) =
            predict_final_warm(&packed, &data, &xq, &cfg, Some(&solves[..nm])).unwrap();
        assert!(
            stats.iters_per_rhs[0] <= 2,
            "y column should be warm: {:?}",
            stats.iters_per_rhs
        );
        for (a, b) in warm.iter().zip(&cold) {
            assert!((a.0 - b.0).abs() < 1e-6 && (a.1 - b.1).abs() < 1e-6);
        }
        // full-buffer guess: every column is ~free
        let (full, _, full_stats) =
            predict_final_warm(&packed, &data, &xq, &cfg, Some(&solves)).unwrap();
        assert!(
            full_stats.iters_per_rhs.iter().all(|&it| it <= 2),
            "all columns should be warm: {:?}",
            full_stats.iters_per_rhs
        );
        for (a, b) in full.iter().zip(&cold) {
            assert!((a.0 - b.0).abs() < 1e-6 && (a.1 - b.1).abs() < 1e-6);
        }
    }

    #[test]
    fn preconditioned_predictions_match_plain() {
        // Preconditioning changes the iteration path, never the answer:
        // at tight tolerance predictions and the MAP objective agree with
        // the plain-CG path on both prefix-masked and full-mask data.
        for (seed, densify) in [(19u64, false), (20u64, true)] {
            let mut data = toy_dataset(12, 10, 2, seed);
            if densify {
                for v in data.mask.data_mut().iter_mut() {
                    *v = 1.0;
                }
            }
            let packed = Theta::default_packed(2);
            let mut rng = Pcg64::new(seed + 100);
            let xq = Matrix::from_vec(3, 2, rng.uniform_vec(6, 0.0, 1.0));
            let plain_cfg = SolverCfg { cg_tol: 1e-10, ..Default::default() };
            let pcg_cfg = SolverCfg {
                cg_tol: 1e-10,
                precond: PrecondCfg::Auto,
                ..Default::default()
            };
            let plain = predict_final(&packed, &data, &xq, &plain_cfg).unwrap();
            let pcg = predict_final(&packed, &data, &xq, &pcg_cfg).unwrap();
            for (a, b) in plain.iter().zip(&pcg) {
                assert!(
                    (a.0 - b.0).abs() < 1e-6 && (a.1 - b.1).abs() < 1e-6,
                    "densify={densify}: {a:?} vs {b:?}"
                );
            }

            let probes = rng.rademacher_vec(16 * 120);
            let pc = SolverCfg { probes: 16, ..plain_cfg.clone() };
            let qc = SolverCfg { probes: 16, ..pcg_cfg.clone() };
            let ev_plain = mll_value_grad(&packed, &data, &probes, &pc).unwrap();
            let ev_pcg = mll_value_grad(&packed, &data, &probes, &qc).unwrap();
            assert!(
                (ev_plain.value - ev_pcg.value).abs() < 1e-5,
                "densify={densify}: {} vs {}",
                ev_plain.value,
                ev_pcg.value
            );
            for (g1, g2) in ev_plain.grad.iter().zip(&ev_pcg.grad) {
                assert!((g1 - g2).abs() < 1e-4, "densify={densify}");
            }
        }
    }

    #[test]
    fn precond_cache_reused_across_calls() {
        let data = toy_dataset(10, 8, 2, 23);
        let packed = Theta::default_packed(2);
        let mut rng = Pcg64::new(24);
        let xq = Matrix::from_vec(2, 2, rng.uniform_vec(4, 0.0, 1.0));
        let cfg = SolverCfg { precond: PrecondCfg::Auto, ..Default::default() };
        let mut cache = None;
        let _ = predict_final_cached(&packed, &data, &xq, &cfg, None, &mut cache).unwrap();
        let first = cache.clone().expect("factors built");
        let _ = predict_final_cached(&packed, &data, &xq, &cfg, None, &mut cache).unwrap();
        let second = cache.expect("factors kept");
        assert!(Arc::ptr_eq(&first, &second), "cache should be reused");
        // a drifted theta stales the cache
        let mut drifted = packed.clone();
        drifted[0] += 1.0;
        let mut cache2 = Some(first.clone());
        let _ = predict_final_cached(&drifted, &data, &xq, &cfg, None, &mut cache2).unwrap();
        assert!(!Arc::ptr_eq(&first, &cache2.unwrap()), "drift must rebuild");
    }

    #[test]
    fn matheron_moments_match_dense_posterior() {
        let data = toy_dataset(5, 4, 2, 9);
        let packed = Theta::default_packed(2);
        let mut rng = Pcg64::new(10);
        let xq = Matrix::from_vec(2, 2, rng.uniform_vec(4, 0.0, 1.0));
        let cfg = SolverCfg { cg_tol: 1e-10, jitter: 1e-10, ..Default::default() };
        let s = 4000;
        let samples = posterior_samples(&packed, &data, &xq, s, &cfg, &mut rng).unwrap();

        // dense posterior mean at the query block
        let theta = Theta::unpack(&packed);
        let (n, m, q) = (5usize, 4usize, 2usize);
        let mut xj = Matrix::zeros(n + q, 2);
        for i in 0..n {
            xj.row_mut(i).copy_from_slice(data.x.row(i));
        }
        for i in 0..q {
            xj.row_mut(n + i).copy_from_slice(xq.row(i));
        }
        let k1j = kernels::rbf(&xj, &xj, &theta.lengthscales);
        let k2 = kernels::matern12(&data.t, &data.t, theta.t_lengthscale, theta.outputscale);
        let idx: Vec<usize> = data
            .mask
            .data()
            .iter()
            .enumerate()
            .filter(|(_, &mv)| mv > 0.0)
            .map(|(i, _)| i)
            .collect();
        let no = idx.len();
        let mut kobs = Matrix::zeros(no, no);
        for (a, &ia) in idx.iter().enumerate() {
            for (b, &ib) in idx.iter().enumerate() {
                kobs[(a, b)] = k1j[(ia / m, ib / m)] * k2[(ia % m, ib % m)];
            }
        }
        kobs.add_diag(theta.sigma2);
        let l = linalg::cholesky(&kobs).unwrap();
        let yobs: Vec<f64> = idx.iter().map(|&i| data.y.data()[i]).collect();
        let alpha = linalg::chol_solve(&l, &yobs);

        for qi in 0..q {
            for j in 0..m {
                let mut want = 0.0;
                for (a, &ia) in idx.iter().enumerate() {
                    want += k1j[(n + qi, ia / m)] * k2[(j, ia % m)] * alpha[a];
                }
                let emp: f64 =
                    samples.iter().map(|smp| smp[(n + qi, j)]).sum::<f64>() / s as f64;
                assert!(
                    (emp - want).abs() < 0.08,
                    "qi={qi} j={j} emp={emp} want={want}"
                );
            }
        }
    }

    #[test]
    fn precision_parse_roundtrip() {
        assert_eq!(Precision::parse("f64"), Some(Precision::F64));
        assert_eq!(Precision::parse(" F32 "), Some(Precision::F32));
        assert_eq!(Precision::parse("mixed"), Some(Precision::F32));
        assert_eq!(Precision::parse("half"), None);
        assert_eq!(Precision::default(), Precision::F64);
        assert_eq!(Precision::F32.tag(), "f32");
        assert_eq!(Precision::parse(Precision::F64.tag()), Some(Precision::F64));
    }

    #[test]
    fn f32_precision_predictions_match_f64() {
        // The refinement loop measures convergence on the exact operator, so
        // a tight tol must carry through to predictions even though the heavy
        // matmuls run on f32-rounded factors.
        let data = toy_dataset(9, 7, 2, 21);
        let packed = Theta::default_packed(2);
        let mut rng = Pcg64::new(22);
        let xq = Matrix::from_vec(3, 2, rng.uniform_vec(6, 0.0, 1.0));
        let exact_cfg = SolverCfg { cg_tol: 1e-10, ..Default::default() };
        let fast_cfg = SolverCfg {
            cg_tol: 1e-8,
            precision: Precision::F32,
            ..Default::default()
        };
        let (want, _) = predict_mean(&packed, &data, &xq, &exact_cfg).unwrap();
        let (got, cg) = predict_mean(&packed, &data, &xq, &fast_cfg).unwrap();
        assert!(cg.converged, "refined solve must converge: {cg:?}");
        for (a, b) in got.data().iter().zip(want.data()) {
            assert!((a - b).abs() < 1e-5, "got={a} want={b}");
        }
    }

    #[test]
    fn posterior_samples_interpolate_observations() {
        // With tiny noise, samples at observed entries track the data.
        let mut data = toy_dataset(6, 5, 2, 11);
        // densify mask
        for v in data.mask.data_mut().iter_mut() {
            *v = 1.0;
        }
        // Unit lengthscales keep K1 well-conditioned so the small-noise
        // interpolation identity is numerically clean; jitter must be well
        // below sigma2 (Matheron assumes exact prior covariance).
        let mut packed = Theta::default_packed(2);
        for v in packed.iter_mut().take(3) {
            *v = 0.0; // ls = 1
        }
        let dlen = packed.len();
        packed[dlen - 1] = (1e-4f64).ln();
        let mut rng = Pcg64::new(12);
        let xq = Matrix::from_vec(1, 2, vec![0.5, 0.5]);
        let cfg = SolverCfg { cg_tol: 1e-10, jitter: 1e-10, ..Default::default() };
        let samples = posterior_samples(&packed, &data, &xq, 20, &cfg, &mut rng).unwrap();
        for smp in &samples {
            for i in 0..6 {
                for j in 0..5 {
                    assert!(
                        (smp[(i, j)] - data.y[(i, j)]).abs() < 0.05,
                        "i={i} j={j} smp={} y={}",
                        smp[(i, j)],
                        data.y[(i, j)]
                    );
                }
            }
        }
    }
}
