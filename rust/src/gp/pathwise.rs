//! Pathwise-conditioned posterior sampling (docs/sampling.md).
//!
//! Matheron's rule writes a posterior sample as a *prior* path plus a
//! data-dependent correction:
//!
//! ```text
//! f_post = f_prior + K_*x (K_xx + σ²I)⁻¹ (y − f_prior − ε)
//! ```
//!
//! The historical `posterior_samples_impl` pays one batched CG solve per
//! sample batch for that correction. But the training targets are exactly
//! zero off-mask, so the correction splits into a *cached* half and a
//! *sample* half:
//!
//! ```text
//! v_s = B⁻¹ vec(Y) − B⁻¹ (M ∘ (f_s + ε_s))  =  α − B⁻¹ (M ∘ (f_s + ε_s))
//! ```
//!
//! with `B = M ∘ (K1 ⊗ K2) ∘ M + σ²I` and `α` the training solve every
//! warm [`crate::gp::session::Posterior`] lineage already carries. The
//! remaining `B⁻¹` is applied *directly* through full-rank
//! [`PrecondFactors`]: at rank `n·m` both factored strategies are exact
//! inverses of the operator (latent-Kronecker eigendecomposition on full
//! masks, observed-Gram Woodbury on partial masks — see
//! `operator::precond_matches_dense_inverse_at_full_rank`), so each extra
//! sample costs one masked-Kron-shaped apply instead of a CG solve.
//!
//! Exactness is *verified, not assumed*: [`PathBase::build`] runs a
//! deterministic probe residual check (fixed seed, `‖B·B⁻¹p − p‖/‖p‖`)
//! and only flags the state `exact` below [`PROBE_TOL`]. A failed probe
//! falls back to the historical batched-CG path in the session layer —
//! still correct, just not solve-free.
//!
//! Determinism contract (docs/sampling.md): for a fixed seed the RNG
//! consumption order is identical to the historical sampler (one
//! `normal_vec(nj·m)` prior draw then `n·m` noise normals per sample), and
//! every matmul / factored apply in this module is bit-identical across
//! worker-thread counts, so `Query::CurveSamples { seed }` answers are
//! bitwise stable across threads, replicas, and repeat calls *within* the
//! pathwise path. The pathwise and CG paths are each deterministic but
//! not bit-equal to each other (different correction arithmetic), which
//! is why the probe decision is itself deterministic.

use std::sync::Arc;

use crate::error::Result;
use crate::gp::kernels;
use crate::gp::params::Theta;
use crate::linalg::pcg::Preconditioner;
use crate::linalg::{self, Matrix};
use crate::rng::Pcg64;

use super::lkgp::{mask_product, Dataset, SolverCfg};
use super::operator::{MaskedKronOp, PrecondCfg, PrecondFactors};

/// Fixed seed for the probe residual check. A *constant* (never caller
/// data) so the exact-vs-fallback decision is a pure function of
/// `(theta, dataset)` — the same on the writer, every replica, and every
/// replay of a recorded trace.
const PROBE_SEED: u64 = 0x5eed_9a27_317b_f00d;

/// Probe relative-residual ceiling for the exact path. Far tighter than
/// the default CG tolerance (1e-2), so pathwise corrections are *more*
/// converged than the solver path they replace.
const PROBE_TOL: f64 = 1e-6;

/// Query-independent pathwise state for one `(dataset, theta)` pair: the
/// grid-kernel Cholesky for prior draws, and full-rank factored state
/// applying `B⁻¹` exactly. Built once per `(generation, theta)` and
/// carried through the `WarmStart` lineage (`Arc`-shared across the
/// writer, its forks, and the read replicas).
#[derive(Clone, Debug)]
pub struct PathBase {
    /// Packed theta the state was built under (bitwise reuse check).
    theta: Vec<f64>,
    n: usize,
    m: usize,
    sigma2: f64,
    /// (m, m) progression kernel (no jitter) for the correction term.
    k2: Matrix,
    /// Transposed Cholesky of `K2 + jitter·I` for prior draws.
    l2t: Matrix,
    /// Full-rank factored inverse of `B`; `None` when the mask is empty.
    factors: Option<Arc<PrecondFactors>>,
    /// Measured probe relative residual `‖B·B⁻¹p − p‖ / ‖p‖`.
    probe_rel: f64,
    /// Whether the factored apply passed the probe check.
    exact: bool,
}

impl PathBase {
    /// Factor the pathwise state for `(packed, data)`. Deterministic: the
    /// probe RNG is a fixed constant, so two builds from identical inputs
    /// agree bit for bit — including the `exact` decision.
    pub fn build(packed: &[f64], data: &Dataset, cfg: &SolverCfg) -> Result<PathBase> {
        data.check()?;
        let theta = Theta::unpack(packed);
        let (n, m) = (data.n(), data.m());
        let k1 = kernels::rbf(&data.x, &data.x, &theta.lengthscales);
        let k2 = kernels::matern12(&data.t, &data.t, theta.t_lengthscale, theta.outputscale);
        let mut k2j = k2.clone();
        k2j.add_diag(cfg.jitter);
        let l2t = linalg::cholesky(&k2j)?.transpose();
        // Rank n·m clamps to the factored dimension of whichever strategy
        // the mask selects (n latent / n_obs observed-Gram) — full rank,
        // i.e. the exact inverse up to factorization roundoff.
        let factors =
            PrecondFactors::build(PrecondCfg::Rank(n * m), &k1, &k2, &data.mask, packed)
                .map(Arc::new);
        let (probe_rel, exact) = match &factors {
            Some(f) => {
                let nm = n * m;
                let op = MaskedKronOp::new(&k1, &k2, &data.mask, theta.sigma2);
                let mut probe_rng = Pcg64::new(PROBE_SEED);
                let p = probe_rng.normal_vec(nm);
                let mut z = vec![0.0; nm];
                f.apply_state(&data.mask, theta.sigma2).apply_batch(&p, &mut z, 1);
                let mut az = vec![0.0; nm];
                op.apply_batch(&z, &mut az, 1);
                let pn = linalg::matrix::dot(&p, &p).sqrt().max(1e-300);
                let rn = az
                    .iter()
                    .zip(&p)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f64>()
                    .sqrt();
                let rel = rn / pn;
                (rel, rel.is_finite() && rel <= PROBE_TOL)
            }
            None => (f64::INFINITY, false),
        };
        Ok(PathBase {
            theta: packed.to_vec(),
            n,
            m,
            sigma2: theta.sigma2,
            k2,
            l2t,
            factors,
            probe_rel,
            exact,
        })
    }

    /// Whether this state serves `(packed, data)`: exact shape match,
    /// *bitwise* theta equality (sampling reuses the cached training
    /// solve, which is only valid at the exact theta it converged under),
    /// and factored state still bound to this exact mask.
    pub fn compatible(&self, packed: &[f64], data: &Dataset) -> bool {
        self.n == data.n()
            && self.m == data.m()
            && self.theta.len() == packed.len()
            && self
                .theta
                .iter()
                .zip(packed)
                .all(|(a, b)| a.to_bits() == b.to_bits())
            && self
                .factors
                .as_ref()
                .map_or(false, |f| f.compatible(packed, self.n, self.m, &data.mask))
    }

    /// Whether the factored apply passed the probe residual check (the
    /// solve-free path is only taken when this holds).
    pub fn exact(&self) -> bool {
        self.exact
    }

    /// The measured probe relative residual (telemetry).
    pub fn probe_rel(&self) -> f64 {
        self.probe_rel
    }

    /// Training-config count the state was built for.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Grid length the state was built for.
    pub fn m(&self) -> usize {
        self.m
    }
}

/// Query-dependent pathwise state: the joint config-kernel Cholesky over
/// `[X; xq]` for prior draws and the cross block for the correction.
/// Keyed bitwise on `xq`, so a Thompson-sampling storm re-drawing the same
/// candidate set pays the O(nj³) factorization once.
#[derive(Clone, Debug)]
pub struct PathQuery {
    /// The query-config matrix this state was factored for (bitwise key).
    xq: Matrix,
    /// (nj, nj) Cholesky of `K1([X; xq], [X; xq]) + jitter·I`.
    l1j: Matrix,
    /// (nj, n) cross block `K1([X; xq], X)` (diagonal jitter removed).
    k1cross: Matrix,
}

impl PathQuery {
    /// Factor the joint config kernel for `xq` against `data`'s configs.
    pub fn build(base: &PathBase, data: &Dataset, xq: &Matrix, cfg: &SolverCfg) -> Result<PathQuery> {
        let theta = Theta::unpack(&base.theta);
        let (n, q) = (data.n(), xq.rows());
        let nj = n + q;
        let mut xj = Matrix::zeros(nj, data.d());
        for i in 0..n {
            xj.row_mut(i).copy_from_slice(data.x.row(i));
        }
        for i in 0..q {
            xj.row_mut(n + i).copy_from_slice(xq.row(i));
        }
        let mut k1j = kernels::rbf(&xj, &xj, &theta.lengthscales);
        k1j.add_diag(cfg.jitter);
        let l1j = linalg::cholesky(&k1j)?;
        // k1([X; xq], X) is the left block of k1j; the jitter only touched
        // the diagonal (same materialization as the historical sampler).
        let mut k1cross = Matrix::zeros(nj, n);
        for i in 0..nj {
            for j in 0..n {
                k1cross[(i, j)] = if i == j { k1j[(i, j)] - cfg.jitter } else { k1j[(i, j)] };
            }
        }
        Ok(PathQuery { xq: xq.clone(), l1j, k1cross })
    }

    /// Bitwise key check against a query matrix.
    pub fn matches(&self, xq: &Matrix) -> bool {
        self.xq.rows() == xq.rows()
            && self.xq.cols() == xq.cols()
            && self
                .xq
                .data()
                .iter()
                .zip(xq.data())
                .all(|(a, b)| a.to_bits() == b.to_bits())
    }

    /// Joint dimension `n + q` of the factored config kernel.
    pub fn nj(&self) -> usize {
        self.l1j.rows()
    }
}

/// The pathwise lineage handle carried by `coordinator::store::WarmStart`
/// and `runtime::QueryOutcome`: the per-`(generation, theta)` base plus
/// the last query factorization (both `Arc`-shared, so threading it
/// through the pool costs pointer copies).
#[derive(Clone, Debug)]
pub struct PathLineage {
    /// Query-independent factored state.
    pub base: Arc<PathBase>,
    /// Last query-keyed factorization, if any.
    pub query: Option<Arc<PathQuery>>,
}

/// Draw `s` posterior curve samples pathwise: prior paths
/// `f_s = L1j Z_s L2ᵀ`, then the Matheron correction
/// `f_s + K1cross (M ∘ (α − B⁻¹(M ∘ (f_s + ε_s)))) K2` with `B⁻¹` applied
/// through the full-rank factors — one factored apply per sample, zero
/// solves. RNG consumption order matches the historical sampler exactly.
///
/// The caller guarantees `base.exact()` and passes the converged training
/// solve `alpha` (flattened `(n, m)`).
pub(crate) fn sample_paths(
    base: &PathBase,
    query: &PathQuery,
    data: &Dataset,
    alpha: &[f64],
    s: usize,
    rng: &mut Pcg64,
) -> Result<Vec<Matrix>> {
    let (n, m) = (base.n, base.m);
    let nm = n * m;
    debug_assert_eq!(alpha.len(), nm, "alpha must be the flattened training solve");
    let factors = base.factors.as_ref().ok_or_else(|| {
        crate::LkgpError::Coordinator("pathwise sampling without factored state".into())
    })?;
    let nj = query.nj();
    let sigma = base.sigma2.sqrt();

    // Prior paths + the masked sample-half RHS, in the historical RNG
    // order: one nj·m prior draw, then one noise normal per grid cell.
    let mut priors: Vec<Matrix> = Vec::with_capacity(s);
    let mut rhs = Vec::with_capacity(s * nm);
    for _ in 0..s {
        let z = Matrix::from_vec(nj, m, rng.normal_vec(nj * m));
        let f = query.l1j.matmul(&z).matmul(&base.l2t);
        for i in 0..n {
            for j in 0..m {
                let noise = sigma * rng.normal();
                rhs.push(data.mask[(i, j)] * (f[(i, j)] + noise));
            }
        }
        priors.push(f);
    }

    // One batched exact apply: ws_s = B⁻¹ (M ∘ (f_s + ε_s)).
    let mut ws = vec![0.0; s * nm];
    factors.apply_state(&data.mask, base.sigma2).apply_batch(&rhs, &mut ws, s);

    let mut out = Vec::with_capacity(s);
    for (si, mut f) in priors.into_iter().enumerate() {
        // v_s = α − ws_s, then the correction K1cross (M ∘ v_s) K2.
        let v: Vec<f64> = alpha
            .iter()
            .zip(&ws[si * nm..(si + 1) * nm])
            .map(|(a, w)| a - w)
            .collect();
        let corr = mask_product(&data.mask, &v, n, m);
        let update = query.k1cross.matmul(&corr).matmul(&base.k2);
        f.add_assign(&update);
        out.push(f);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(n: usize, m: usize, d: usize, seed: u64, full_mask: bool) -> Dataset {
        let mut rng = Pcg64::new(seed);
        let x = Matrix::from_vec(n, d, rng.uniform_vec(n * d, 0.0, 1.0));
        let t: Vec<f64> = (0..m).map(|i| i as f64 / (m - 1).max(1) as f64).collect();
        let mut mask = Matrix::zeros(n, m);
        for i in 0..n {
            let len = if full_mask { m } else { 2 + rng.below(m - 1) };
            for j in 0..len {
                mask[(i, j)] = 1.0;
            }
        }
        let mut y = Matrix::zeros(n, m);
        for i in 0..n {
            for j in 0..m {
                if mask[(i, j)] > 0.0 {
                    y[(i, j)] = -0.5 + 0.1 * j as f64 + 0.02 * rng.normal();
                }
            }
        }
        Dataset { x, t, y, mask }
    }

    #[test]
    fn base_passes_probe_on_both_strategies() {
        let packed = Theta::default_packed(2);
        let cfg = SolverCfg::default();
        for full in [true, false] {
            let data = toy(7, 5, 2, 91, full);
            let base = PathBase::build(&packed, &data, &cfg).unwrap();
            assert!(
                base.exact(),
                "full_mask={full}: probe_rel={} should clear {PROBE_TOL}",
                base.probe_rel()
            );
            assert!(base.compatible(&packed, &data));
        }
    }

    #[test]
    fn base_reuse_is_bitwise_on_theta() {
        let packed = Theta::default_packed(2);
        let data = toy(6, 5, 2, 92, false);
        let base = PathBase::build(&packed, &data, &SolverCfg::default()).unwrap();
        let mut drifted = packed.clone();
        drifted[0] += 1e-12; // tiny, but not bit-equal
        assert!(!base.compatible(&drifted, &data));
        // a mask change stales the observed-Gram binding
        let mut grown = data.clone();
        if let Some(i) = grown.mask.data().iter().position(|&v| v <= 0.0) {
            grown.mask.data_mut()[i] = 1.0;
            assert!(!base.compatible(&packed, &grown));
        }
    }

    #[test]
    fn query_key_is_bitwise() {
        let packed = Theta::default_packed(2);
        let data = toy(6, 5, 2, 93, false);
        let cfg = SolverCfg::default();
        let base = PathBase::build(&packed, &data, &cfg).unwrap();
        let mut rng = Pcg64::new(94);
        let xq = Matrix::from_vec(2, 2, rng.uniform_vec(4, 0.0, 1.0));
        let pq = PathQuery::build(&base, &data, &xq, &cfg).unwrap();
        assert!(pq.matches(&xq));
        assert_eq!(pq.nj(), 8);
        let mut other = xq.clone();
        other[(0, 0)] += 1e-13;
        assert!(!pq.matches(&other));
        assert!(!pq.matches(&Matrix::zeros(3, 2)));
    }

    #[test]
    fn pathwise_matches_tight_cg_sampler() {
        // Same seed, same RNG order: the pathwise correction differs from
        // the CG correction only by solver accuracy, so at a tight CG
        // tolerance the two samplers agree to solver precision.
        let packed = Theta::default_packed(2);
        for full in [true, false] {
            let data = toy(6, 5, 2, 95, full);
            let cfg = SolverCfg { cg_tol: 1e-12, ..Default::default() };
            let mut rng = Pcg64::new(96);
            let xq = Matrix::from_vec(2, 2, rng.uniform_vec(4, 0.0, 1.0));
            let base = PathBase::build(&packed, &data, &cfg).unwrap();
            assert!(base.exact(), "full_mask={full}");
            let query = PathQuery::build(&base, &data, &xq, &cfg).unwrap();

            // converged training solve
            let theta = Theta::unpack(&packed);
            let k1 = kernels::rbf(&data.x, &data.x, &theta.lengthscales);
            let k2 =
                kernels::matern12(&data.t, &data.t, theta.t_lengthscale, theta.outputscale);
            let op = MaskedKronOp::new(&k1, &k2, &data.mask, theta.sigma2);
            let (alpha, st) = op.solve(data.y.data(), 1e-12, 10_000);
            assert!(st.converged);

            let s = 3;
            let seed = 4242;
            let mut rng_a = Pcg64::new(seed);
            let got = sample_paths(&base, &query, &data, &alpha, s, &mut rng_a).unwrap();
            let mut rng_b = Pcg64::new(seed);
            let mut cache = None;
            let (want, _) = super::super::lkgp::posterior_samples_impl(
                &packed, &data, &xq, s, &cfg, &mut rng_b, &mut cache,
            )
            .unwrap();
            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(&want) {
                for (a, b) in g.data().iter().zip(w.data()) {
                    assert!(
                        (a - b).abs() < 1e-7,
                        "full_mask={full}: pathwise={a} cg={b}"
                    );
                }
            }
        }
    }

    #[test]
    fn pathwise_is_deterministic_per_seed() {
        let packed = Theta::default_packed(2);
        let data = toy(7, 6, 2, 97, false);
        let cfg = SolverCfg::default();
        let mut rng = Pcg64::new(98);
        let xq = Matrix::from_vec(2, 2, rng.uniform_vec(4, 0.0, 1.0));
        let base = PathBase::build(&packed, &data, &cfg).unwrap();
        let query = PathQuery::build(&base, &data, &xq, &cfg).unwrap();
        let theta = Theta::unpack(&packed);
        let k1 = kernels::rbf(&data.x, &data.x, &theta.lengthscales);
        let k2 = kernels::matern12(&data.t, &data.t, theta.t_lengthscale, theta.outputscale);
        let op = MaskedKronOp::new(&k1, &k2, &data.mask, theta.sigma2);
        let (alpha, _) = op.solve(data.y.data(), 1e-10, 10_000);

        let mut r1 = Pcg64::new(777);
        let a = sample_paths(&base, &query, &data, &alpha, 4, &mut r1).unwrap();
        let mut r2 = Pcg64::new(777);
        let b = sample_paths(&base, &query, &data, &alpha, 4, &mut r2).unwrap();
        for (x, y) in a.iter().zip(&b) {
            for (u, v) in x.data().iter().zip(y.data()) {
                assert_eq!(u.to_bits(), v.to_bits(), "same seed must be bitwise stable");
            }
        }
        // a rebuilt base/query (same inputs) reproduces the same bits
        let base2 = PathBase::build(&packed, &data, &cfg).unwrap();
        let query2 = PathQuery::build(&base2, &data, &xq, &cfg).unwrap();
        let mut r3 = Pcg64::new(777);
        let c = sample_paths(&base2, &query2, &data, &alpha, 4, &mut r3).unwrap();
        for (x, y) in a.iter().zip(&c) {
            for (u, v) in x.data().iter().zip(y.data()) {
                assert_eq!(u.to_bits(), v.to_bits(), "rebuild must be bitwise stable");
            }
        }
    }
}
