//! Hyper-parameter optimizers: Adam and L-BFGS over the MAP objective.
//!
//! The paper trains by maximizing marginal likelihood + priors with L-BFGS
//! (§B). With iterative inference, the objective/gradient are conditioned
//! on a fixed probe set (deterministic given the seed), so both a
//! first-order (Adam, robust default) and a quasi-Newton (L-BFGS with
//! backtracking line search, paper-faithful) trainer are provided.
//! Either can drive the rust engine or any `Objective` (e.g. the naive
//! engine, or the XLA `mll_grad` artifact through the runtime).

use crate::error::Result;

/// An objective to MAXIMIZE: value and gradient at packed parameters.
pub trait Objective {
    fn eval(&mut self, packed: &[f64]) -> Result<(f64, Vec<f64>)>;
}

impl<F> Objective for F
where
    F: FnMut(&[f64]) -> Result<(f64, Vec<f64>)>,
{
    fn eval(&mut self, packed: &[f64]) -> Result<(f64, Vec<f64>)> {
        self(packed)
    }
}

/// Record of one training run.
#[derive(Clone, Debug)]
pub struct FitTrace {
    /// Objective value after each step.
    pub values: Vec<f64>,
    /// Final parameters.
    pub theta: Vec<f64>,
    /// Steps actually taken.
    pub steps: usize,
    /// Objective evaluations, including rejected line-search probes. Each
    /// evaluation is one batched (P)CG solve + SLQ pass, so this is the
    /// fit's solver-work denominator (pairs with `CgStats::mvm_rows`).
    pub evals: usize,
}

/// Adam configuration.
#[derive(Clone, Debug)]
pub struct AdamCfg {
    pub steps: usize,
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
}

impl Default for AdamCfg {
    fn default() -> Self {
        AdamCfg {
            steps: 150,
            lr: 0.05,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
        }
    }
}

/// Maximize with Adam (gradient ascent form).
pub fn adam(obj: &mut dyn Objective, theta0: &[f64], cfg: &AdamCfg) -> Result<FitTrace> {
    let mut theta = theta0.to_vec();
    let mut mu = vec![0.0; theta.len()];
    let mut nu = vec![0.0; theta.len()];
    let mut values = Vec::with_capacity(cfg.steps);
    let mut evals = 0;
    for step in 0..cfg.steps {
        let (value, grad) = obj.eval(&theta)?;
        evals += 1;
        values.push(value);
        let t = (step + 1) as f64;
        for i in 0..theta.len() {
            let g = grad[i];
            mu[i] = cfg.beta1 * mu[i] + (1.0 - cfg.beta1) * g;
            nu[i] = cfg.beta2 * nu[i] + (1.0 - cfg.beta2) * g * g;
            let mu_hat = mu[i] / (1.0 - cfg.beta1.powf(t));
            let nu_hat = nu[i] / (1.0 - cfg.beta2.powf(t));
            theta[i] += cfg.lr * mu_hat / (nu_hat.sqrt() + cfg.eps);
        }
    }
    Ok(FitTrace {
        steps: values.len(),
        values,
        theta,
        evals,
    })
}

/// L-BFGS configuration.
#[derive(Clone, Debug)]
pub struct LbfgsCfg {
    pub max_iters: usize,
    /// History pairs kept for the two-loop recursion.
    pub history: usize,
    /// Gradient-norm stopping tolerance.
    pub gtol: f64,
    /// Armijo parameter for backtracking.
    pub armijo_c: f64,
    /// Max backtracking halvings per iteration.
    pub max_backtracks: usize,
}

impl Default for LbfgsCfg {
    fn default() -> Self {
        LbfgsCfg {
            max_iters: 60,
            history: 10,
            gtol: 1e-5,
            armijo_c: 1e-4,
            max_backtracks: 25,
        }
    }
}

/// Maximize with L-BFGS (two-loop recursion + backtracking Armijo search).
///
/// Internally minimizes -f. A failed line search or a non-PD objective
/// evaluation ends the run gracefully with the best iterate so far.
pub fn lbfgs(obj: &mut dyn Objective, theta0: &[f64], cfg: &LbfgsCfg) -> Result<FitTrace> {
    let n = theta0.len();
    let mut theta = theta0.to_vec();
    let (mut fval, mut grad) = neg(obj.eval(&theta)?);
    let mut evals = 1;
    let mut values = vec![-fval];

    let mut s_hist: Vec<Vec<f64>> = Vec::new();
    let mut y_hist: Vec<Vec<f64>> = Vec::new();
    let mut rho: Vec<f64> = Vec::new();

    for _iter in 0..cfg.max_iters {
        let gnorm = norm(&grad);
        if gnorm < cfg.gtol {
            break;
        }
        // Two-loop recursion for direction = -H g.
        let mut q = grad.clone();
        let k = s_hist.len();
        let mut alphas = vec![0.0; k];
        for i in (0..k).rev() {
            alphas[i] = rho[i] * dot(&s_hist[i], &q);
            axpy(-alphas[i], &y_hist[i], &mut q);
        }
        // Initial scaling gamma = s.y / y.y of the most recent pair.
        if k > 0 {
            let gamma = dot(&s_hist[k - 1], &y_hist[k - 1]) / dot(&y_hist[k - 1], &y_hist[k - 1]);
            for qi in q.iter_mut() {
                *qi *= gamma.max(1e-12);
            }
        }
        for i in 0..k {
            let beta = rho[i] * dot(&y_hist[i], &q);
            axpy(alphas[i] - beta, &s_hist[i], &mut q);
        }
        let dir: Vec<f64> = q.iter().map(|v| -v).collect();
        let slope = dot(&grad, &dir);
        if slope >= 0.0 {
            // Not a descent direction (stale curvature); reset history.
            s_hist.clear();
            y_hist.clear();
            rho.clear();
            continue;
        }

        // Backtracking Armijo.
        let mut step = 1.0;
        let mut accepted = false;
        let mut new_theta = theta.clone();
        let mut new_f = fval;
        let mut new_g = grad.clone();
        for _ in 0..cfg.max_backtracks {
            for i in 0..n {
                new_theta[i] = theta[i] + step * dir[i];
            }
            evals += 1;
            match obj.eval(&new_theta) {
                Ok(vg) => {
                    let (f2, g2) = neg(vg);
                    if f2 <= fval + cfg.armijo_c * step * slope {
                        new_f = f2;
                        new_g = g2;
                        accepted = true;
                        break;
                    }
                }
                Err(_) => { /* non-PD region: shrink */ }
            }
            step *= 0.5;
        }
        if !accepted {
            break;
        }

        let s: Vec<f64> = (0..n).map(|i| new_theta[i] - theta[i]).collect();
        let yv: Vec<f64> = (0..n).map(|i| new_g[i] - grad[i]).collect();
        let sy = dot(&s, &yv);
        if sy > 1e-12 {
            s_hist.push(s);
            y_hist.push(yv);
            rho.push(1.0 / sy);
            if s_hist.len() > cfg.history {
                s_hist.remove(0);
                y_hist.remove(0);
                rho.remove(0);
            }
        }
        theta = new_theta;
        fval = new_f;
        grad = new_g;
        values.push(-fval);
    }

    Ok(FitTrace {
        steps: values.len(),
        values,
        theta,
        evals,
    })
}

fn neg((v, g): (f64, Vec<f64>)) -> (f64, Vec<f64>) {
    (-v, g.into_iter().map(|x| -x).collect())
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    crate::linalg::matrix::dot(a, b)
}

fn norm(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    crate::linalg::matrix::axpy(alpha, x, y)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Concave quadratic: f(x) = -1/2 (x-c)^T D (x-c); max at c.
    struct Quad {
        c: Vec<f64>,
        d: Vec<f64>,
    }

    impl Objective for Quad {
        fn eval(&mut self, x: &[f64]) -> Result<(f64, Vec<f64>)> {
            let mut f = 0.0;
            let mut g = vec![0.0; x.len()];
            for i in 0..x.len() {
                let z = x[i] - self.c[i];
                f -= 0.5 * self.d[i] * z * z;
                g[i] = -self.d[i] * z;
            }
            Ok((f, g))
        }
    }

    #[test]
    fn adam_reaches_quadratic_max() {
        let mut q = Quad {
            c: vec![1.0, -2.0, 0.5],
            d: vec![2.0, 0.5, 4.0],
        };
        let trace = adam(
            &mut q,
            &[0.0, 0.0, 0.0],
            &AdamCfg {
                steps: 800,
                lr: 0.05,
                ..Default::default()
            },
        )
        .unwrap();
        for (a, b) in trace.theta.iter().zip(&q.c) {
            assert!((a - b).abs() < 0.02, "{a} vs {b}");
        }
    }

    #[test]
    fn lbfgs_reaches_quadratic_max_fast() {
        let mut q = Quad {
            c: vec![3.0, -1.0],
            d: vec![10.0, 0.1],
        };
        let trace = lbfgs(&mut q, &[0.0, 0.0], &LbfgsCfg::default()).unwrap();
        assert!(trace.steps < 40);
        for (a, b) in trace.theta.iter().zip(&q.c) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn lbfgs_beats_adam_on_ill_conditioned() {
        let c = vec![1.0, 1.0, 1.0, 1.0];
        let d = vec![100.0, 1.0, 0.01, 10.0];
        let mut q1 = Quad { c: c.clone(), d: d.clone() };
        let mut q2 = Quad { c: c.clone(), d };
        let tr_l = lbfgs(&mut q1, &[0.0; 4], &LbfgsCfg::default()).unwrap();
        let tr_a = adam(
            &mut q2,
            &[0.0; 4],
            &AdamCfg { steps: tr_l.steps, ..Default::default() },
        )
        .unwrap();
        assert!(tr_l.values.last().unwrap() >= tr_a.values.last().unwrap());
    }

    #[test]
    fn rosenbrock_maximization() {
        // max of -rosenbrock at (1, 1)
        struct Rb;
        impl Objective for Rb {
            fn eval(&mut self, x: &[f64]) -> Result<(f64, Vec<f64>)> {
                let (a, b) = (x[0], x[1]);
                let f = -((1.0 - a).powi(2) + 100.0 * (b - a * a).powi(2));
                let g = vec![
                    -(-2.0 * (1.0 - a) - 400.0 * a * (b - a * a)),
                    -(200.0 * (b - a * a)),
                ];
                Ok((f, g))
            }
        }
        let trace = lbfgs(
            &mut Rb,
            &[-1.2, 1.0],
            &LbfgsCfg { max_iters: 2000, history: 20, ..Default::default() },
        )
        .unwrap();
        assert!((trace.theta[0] - 1.0).abs() < 1e-2, "{:?}", trace.theta);
        assert!((trace.theta[1] - 1.0).abs() < 2e-2);
    }

    #[test]
    fn trainers_count_objective_evaluations() {
        let mut q = Quad { c: vec![1.0, 2.0], d: vec![1.0, 2.0] };
        let tr = adam(
            &mut q,
            &[0.0, 0.0],
            &AdamCfg { steps: 7, ..Default::default() },
        )
        .unwrap();
        assert_eq!(tr.evals, 7);
        let mut q2 = Quad { c: vec![1.0, 2.0], d: vec![1.0, 2.0] };
        let tr2 = lbfgs(&mut q2, &[0.0, 0.0], &LbfgsCfg::default()).unwrap();
        // line searches may probe more than once per accepted step
        assert!(tr2.evals >= tr2.steps, "{} < {}", tr2.evals, tr2.steps);
    }

    #[test]
    fn values_monotone_for_lbfgs() {
        let mut q = Quad {
            c: vec![0.3, 0.7],
            d: vec![1.0, 2.0],
        };
        let trace = lbfgs(&mut q, &[5.0, -5.0], &LbfgsCfg::default()).unwrap();
        for w in trace.values.windows(2) {
            assert!(w[1] >= w[0] - 1e-12);
        }
    }

    #[test]
    fn objective_error_is_propagated_gracefully() {
        struct Bad(usize);
        impl Objective for Bad {
            fn eval(&mut self, x: &[f64]) -> Result<(f64, Vec<f64>)> {
                self.0 += 1;
                if self.0 > 3 {
                    Err(crate::error::LkgpError::NotPd { index: 0, value: -1.0 })
                } else {
                    Ok((-x[0] * x[0], vec![-2.0 * x[0]]))
                }
            }
        }
        // L-BFGS treats eval failure inside line search as a shrink signal
        // and ends with the best iterate instead of erroring out.
        let trace = lbfgs(&mut Bad(0), &[2.0], &LbfgsCfg::default()).unwrap();
        assert!(!trace.values.is_empty());
    }
}
