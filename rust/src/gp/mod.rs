//! Gaussian-process engines: the paper's Latent Kronecker GP and the naive
//! dense baseline, plus kernels, transforms, parameters and trainers.
//!
//! Two interchangeable compute paths exist for the LKGP math:
//! * this module's pure-rust engine ([`lkgp`]), and
//! * the AOT-compiled XLA artifacts driven by [`crate::runtime`].
//!
//! Both implement the same equations (they are tested against each other),
//! so the coordinator can run self-contained or artifact-accelerated.

pub mod kernels;
pub mod lkgp;
pub mod naive;
pub mod operator;
pub mod params;
pub mod pathwise;
pub mod session;
pub mod trainer;
pub mod transforms;

pub use lkgp::{Dataset, MllEval, Precision, SolverCfg};
pub use pathwise::{PathBase, PathLineage, PathQuery};
pub use session::{split_queries, Answer, FitMethod, FitSession, Posterior, Query};
pub use operator::{
    KronPrecondFactors, LatentKronPrecond, MaskedKronOp, MaskedKronOpF32, ObsGramPrecond,
    ObsGramPrecondFactors, PrecondApply, PrecondCfg, PrecondFactors,
};
pub use params::Theta;
