//! The naive joint-covariance engine — the paper's Figure-3 baseline.
//!
//! Materializes the dense joint covariance over *observed* entries
//! (`P (K1 (x) K2) P^T + sigma2 I`, n_obs x n_obs), factorizes it with
//! Cholesky, and computes exact MLL, gradients, predictions and samples.
//! Complexity O(n^3 m^3) time / O(n^2 m^2) space — the scaling wall the
//! paper contrasts against. Shares kernels/transforms with the LKGP engine
//! so Figure 3 compares inference strategy, not implementation details.

use crate::error::Result;
use crate::gp::kernels;
use crate::gp::lkgp::Dataset;
use crate::gp::params::{self, Theta};
use crate::linalg::{self, Matrix};
use crate::rng::Pcg64;

/// Index map of observed entries (row-major over the (n, m) grid).
fn observed_indices(data: &Dataset) -> Vec<usize> {
    data.mask
        .data()
        .iter()
        .enumerate()
        .filter(|(_, &mv)| mv > 0.0)
        .map(|(i, _)| i)
        .collect()
}

/// Dense observed-block covariance (no noise).
fn joint_cov(data: &Dataset, theta: &Theta, idx: &[usize]) -> Matrix {
    let m = data.m();
    let k1 = kernels::rbf(&data.x, &data.x, &theta.lengthscales);
    let k2 = kernels::matern12(&data.t, &data.t, theta.t_lengthscale, theta.outputscale);
    let no = idx.len();
    let mut k = Matrix::zeros(no, no);
    for (a, &ia) in idx.iter().enumerate() {
        let (i1, j1) = (ia / m, ia % m);
        for (b, &ib) in idx.iter().enumerate().skip(a) {
            let (i2, j2) = (ib / m, ib % m);
            let v = k1[(i1, i2)] * k2[(j1, j2)];
            k[(a, b)] = v;
            k[(b, a)] = v;
        }
    }
    k
}

/// Exact MAP objective and gradient via dense Cholesky + explicit inverse.
///
/// grad_k = 1/2 a^T dK_k a - 1/2 tr(K^{-1} dK_k) (+ prior grad), all exact.
/// The O(n_obs^3) inverse dominates — this cost *is* the baseline's story.
pub fn mll_value_grad_exact(packed: &[f64], data: &Dataset) -> Result<(f64, Vec<f64>)> {
    data.check()?;
    let theta = Theta::unpack(packed);
    let d = data.d();
    let m = data.m();
    let idx = observed_indices(data);
    let no = idx.len();

    let mut kn = joint_cov(data, &theta, &idx);
    kn.add_diag(theta.sigma2);
    let l = linalg::cholesky(&kn)?;
    let yobs: Vec<f64> = idx.iter().map(|&i| data.y.data()[i]).collect();
    let alpha = linalg::chol_solve(&l, &yobs);
    let value = -0.5 * linalg::matrix::dot(&yobs, &alpha)
        - 0.5 * linalg::chol_logdet(&l)
        - 0.5 * no as f64 * (2.0 * std::f64::consts::PI).ln()
        + params::log_prior(packed);

    // Explicit inverse via column solves (parallel over column panels).
    let kinv = chol_inverse(&l);

    let k1 = kernels::rbf(&data.x, &data.x, &theta.lengthscales);
    let k2 = kernels::matern12(&data.t, &data.t, theta.t_lengthscale, theta.outputscale);
    let mut grad = params::log_prior_grad(packed);

    // helper: accumulate grad for dK defined by factor matrices (da, db)
    // where dK[a,b] = da[i1,i2] * db[j1,j2].
    let accum = |da: &Matrix, db: &Matrix, out: &mut f64| {
        let mut quad = 0.0;
        let mut tr = 0.0;
        for (a, &ia) in idx.iter().enumerate() {
            let (i1, j1) = (ia / m, ia % m);
            for (b, &ib) in idx.iter().enumerate() {
                let (i2, j2) = (ib / m, ib % m);
                let dk = da[(i1, i2)] * db[(j1, j2)];
                quad += alpha[a] * dk * alpha[b];
                tr += kinv[(a, b)] * dk;
            }
        }
        *out += 0.5 * quad - 0.5 * tr;
    };

    for dim in 0..d {
        let dk1 = kernels::rbf_grad_log_ls(&data.x, &data.x, &theta.lengthscales, &k1, dim);
        accum(&dk1, &k2, &mut grad[dim]);
    }
    let dk2_ls = kernels::matern12_grad_log_ls(&data.t, &data.t, theta.t_lengthscale, &k2);
    accum(&k1, &dk2_ls, &mut grad[d]);
    accum(&k1, &k2, &mut grad[d + 1]);
    // noise: dK = s2 I on the observed block
    let s2 = theta.sigma2;
    let mut trace_inv = 0.0;
    for a in 0..no {
        trace_inv += kinv[(a, a)];
    }
    grad[d + 2] += 0.5 * s2 * linalg::matrix::dot(&alpha, &alpha) - 0.5 * s2 * trace_inv;

    Ok((value, grad))
}

/// Explicit inverse from a Cholesky factor (thread-parallel column solves).
fn chol_inverse(l: &Matrix) -> Matrix {
    let n = l.rows();
    let mut inv = Matrix::zeros(n, n);
    let threads = crate::util::num_threads().min(n.max(1));
    let chunk = n.div_ceil(threads);
    let cols: Vec<(usize, &mut [f64])> = inv
        .data_mut()
        .chunks_mut(chunk * n)
        .enumerate()
        .map(|(ci, c)| (ci * chunk, c))
        .collect();
    // We compute rows of the inverse (symmetric, so rows == cols).
    std::thread::scope(|scope| {
        for (row0, buf) in cols {
            scope.spawn(move || {
                let rows = buf.len() / n;
                for r in 0..rows {
                    let i = row0 + r;
                    let mut e = vec![0.0; n];
                    e[i] = 1.0;
                    let x = linalg::chol_solve(l, &e);
                    buf[r * n..(r + 1) * n].copy_from_slice(&x);
                }
            });
        }
    });
    inv
}

/// Exact predictive (mean, variance-with-noise) of the final value for
/// each query config.
pub fn predict_final_exact(packed: &[f64], data: &Dataset, xq: &Matrix) -> Result<Vec<(f64, f64)>> {
    data.check()?;
    let theta = Theta::unpack(packed);
    let m = data.m();
    let idx = observed_indices(data);
    let mut kn = joint_cov(data, &theta, &idx);
    kn.add_diag(theta.sigma2);
    let l = linalg::cholesky(&kn)?;
    let yobs: Vec<f64> = idx.iter().map(|&i| data.y.data()[i]).collect();
    let alpha = linalg::chol_solve(&l, &yobs);

    let k1q = kernels::rbf(&data.x, xq, &theta.lengthscales);
    let k2 = kernels::matern12(&data.t, &data.t, theta.t_lengthscale, theta.outputscale);
    let mut out = Vec::with_capacity(xq.rows());
    for qi in 0..xq.rows() {
        let c: Vec<f64> = idx
            .iter()
            .map(|&ia| k1q[(ia / m, qi)] * k2[(m - 1, ia % m)])
            .collect();
        let mean = linalg::matrix::dot(&c, &alpha);
        let w = linalg::chol_solve(&l, &c);
        let var = (theta.outputscale - linalg::matrix::dot(&c, &w)).max(1e-12) + theta.sigma2;
        out.push((mean, var));
    }
    Ok(out)
}

/// Exact posterior samples of full curves for query configs (dense joint
/// Cholesky over observed + query entries) — Figure-3 "prediction" phase
/// of the naive baseline.
pub fn sample_curves_exact(
    packed: &[f64],
    data: &Dataset,
    xq: &Matrix,
    s: usize,
    rng: &mut Pcg64,
) -> Result<Vec<Matrix>> {
    data.check()?;
    let theta = Theta::unpack(packed);
    let m = data.m();
    let q = xq.rows();
    let idx = observed_indices(data);
    let no = idx.len();

    let mut kn = joint_cov(data, &theta, &idx);
    kn.add_diag(theta.sigma2);
    let l = linalg::cholesky(&kn)?;
    let yobs: Vec<f64> = idx.iter().map(|&i| data.y.data()[i]).collect();
    let alpha = linalg::chol_solve(&l, &yobs);

    // Cross-covariance (q*m, n_obs) and query prior (q*m, q*m).
    let k1q = kernels::rbf(xq, &data.x, &theta.lengthscales);
    let k1qq = kernels::rbf(xq, xq, &theta.lengthscales);
    let k2 = kernels::matern12(&data.t, &data.t, theta.t_lengthscale, theta.outputscale);
    let qm = q * m;
    let mut kcross = Matrix::zeros(qm, no);
    for r in 0..qm {
        let (qi, j) = (r / m, r % m);
        for (b, &ib) in idx.iter().enumerate() {
            kcross[(r, b)] = k1q[(qi, ib / m)] * k2[(j, ib % m)];
        }
    }
    let mut kqq = Matrix::zeros(qm, qm);
    for r in 0..qm {
        for c in 0..qm {
            kqq[(r, c)] = k1qq[(r / m, c / m)] * k2[(r % m, c % m)];
        }
    }

    // Posterior mean and covariance, then dense sampling.
    let mean: Vec<f64> = (0..qm)
        .map(|r| linalg::matrix::dot(kcross.row(r), &alpha))
        .collect();
    // cov = Kqq - Kcross Kn^{-1} Kcross^T
    let mut kninv_kc = Matrix::zeros(no, qm);
    for c in 0..qm {
        let col: Vec<f64> = (0..no).map(|r| kcross[(c, r)]).collect();
        let sol = linalg::chol_solve(&l, &col);
        for r in 0..no {
            kninv_kc[(r, c)] = sol[r];
        }
    }
    let mut cov = kcross.matmul(&kninv_kc);
    for r in 0..qm {
        for c in 0..qm {
            cov[(r, c)] = kqq[(r, c)] - cov[(r, c)];
        }
    }
    cov.add_diag(1e-8);
    let lc = linalg::cholesky(&cov)?;

    let mut out = Vec::with_capacity(s);
    for _ in 0..s {
        let z = rng.normal_vec(qm);
        let dev = linalg::chol_sample(&lc, &z);
        let mut smp = Matrix::zeros(q, m);
        for r in 0..qm {
            smp[(r / m, r % m)] = mean[r] + dev[r];
        }
        out.push(smp);
    }
    Ok(out)
}

#[cfg(test)]
#[allow(deprecated)] // compares against the deprecated shims on purpose
mod tests {
    use super::*;
    use crate::gp::lkgp::{self, SolverCfg};

    fn toy(n: usize, m: usize, d: usize, seed: u64) -> Dataset {
        // reuse lkgp's toy generator through a tiny local copy
        let mut rng = Pcg64::new(seed);
        let x = Matrix::from_vec(n, d, rng.uniform_vec(n * d, 0.0, 1.0));
        let t: Vec<f64> = (0..m).map(|i| i as f64 / (m - 1).max(1) as f64).collect();
        let mut mask = Matrix::zeros(n, m);
        for i in 0..n {
            let len = 2 + rng.below(m - 1);
            for j in 0..len {
                mask[(i, j)] = 1.0;
            }
        }
        let mut y = Matrix::zeros(n, m);
        for i in 0..n {
            let a = rng.uniform_in(0.5, 1.0);
            for j in 0..m {
                if mask[(i, j)] > 0.0 {
                    y[(i, j)] = -a * (-3.0 * t[j]).exp() + 0.02 * rng.normal();
                }
            }
        }
        Dataset { x, t, y, mask }
    }

    #[test]
    fn exact_value_matches_lkgp_oracle() {
        let data = toy(8, 6, 2, 1);
        let packed = Theta::default_packed(2);
        let (v, _) = mll_value_grad_exact(&packed, &data).unwrap();
        let want = lkgp::mll_exact(&packed, &data).unwrap();
        assert!((v - want).abs() < 1e-9);
    }

    #[test]
    fn exact_grad_matches_fd() {
        let data = toy(7, 5, 2, 2);
        let mut packed = Theta::default_packed(2);
        packed[1] += 0.4;
        let (_, grad) = mll_value_grad_exact(&packed, &data).unwrap();
        let h = 1e-5;
        for i in 0..packed.len() {
            let mut p1 = packed.clone();
            let mut p2 = packed.clone();
            p1[i] += h;
            p2[i] -= h;
            let fd = (lkgp::mll_exact(&p1, &data).unwrap()
                - lkgp::mll_exact(&p2, &data).unwrap())
                / (2.0 * h);
            assert!((grad[i] - fd).abs() < 1e-5 * (1.0 + fd.abs()), "i={i}");
        }
    }

    #[test]
    fn naive_and_lkgp_predict_final_agree() {
        let data = toy(9, 6, 3, 3);
        let packed = Theta::default_packed(3);
        let mut rng = Pcg64::new(4);
        let xq = Matrix::from_vec(3, 3, rng.uniform_vec(9, 0.0, 1.0));
        let naive = predict_final_exact(&packed, &data, &xq).unwrap();
        let cfg = SolverCfg { cg_tol: 1e-11, ..Default::default() };
        let iter = lkgp::predict_final(&packed, &data, &xq, &cfg).unwrap();
        for (a, b) in naive.iter().zip(&iter) {
            assert!((a.0 - b.0).abs() < 1e-6, "mean {} vs {}", a.0, b.0);
            assert!((a.1 - b.1).abs() < 1e-6, "var {} vs {}", a.1, b.1);
        }
    }

    #[test]
    fn sample_curves_mean_matches_predictive() {
        let data = toy(6, 5, 2, 5);
        let packed = Theta::default_packed(2);
        let mut rng = Pcg64::new(6);
        let xq = Matrix::from_vec(2, 2, rng.uniform_vec(4, 0.0, 1.0));
        let samples = sample_curves_exact(&packed, &data, &xq, 3000, &mut rng).unwrap();
        let preds = predict_final_exact(&packed, &data, &xq).unwrap();
        let m = data.m();
        for qi in 0..2 {
            let emp: f64 = samples.iter().map(|s| s[(qi, m - 1)]).sum::<f64>() / 3000.0;
            assert!((emp - preds[qi].0).abs() < 0.06, "emp={emp} want={}", preds[qi].0);
        }
    }

    #[test]
    fn chol_inverse_is_inverse() {
        let mut rng = Pcg64::new(7);
        let a = Matrix::from_vec(12, 12, rng.normal_vec(144));
        let mut spd = a.matmul(&a.transpose());
        spd.add_diag(12.0);
        let l = linalg::cholesky(&spd).unwrap();
        let inv = chol_inverse(&l);
        let prod = spd.matmul(&inv);
        assert!(prod.max_abs_diff(&Matrix::eye(12)) < 1e-9);
    }
}
