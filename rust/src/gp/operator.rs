//! The masked latent-Kronecker operator (the paper's core contribution).
//!
//! Implements `A v = M . (K1 (M . V) K2) + sigma2 * v` as a [`LinOp`]:
//! the full-space embedding of `P (K1 (x) K2) P^T + sigma2 I` where P
//! selects observed learning-curve entries. The Kronecker identity
//! `(A (x) B) vec(C) = vec(B C A^T)` turns the O(n^2 m^2) dense MVM into
//! two dense matmuls — O(n^2 m + n m^2) time, O(nm) space — and the mask
//! plays the role of the zero-pad / slice-index projections (paper §2).

use crate::linalg::matrix::{matmul_mixed_a32b, matmul_mixed_ab32, MatrixF32};
use crate::linalg::pcg::Preconditioner;
use crate::linalg::{
    cg_batch, jacobi_eigh, pivoted_cholesky, refined_solve, CgStats, LinOp, Matrix, RefineStats,
};

/// Masked Kronecker operator over the (n x m) learning-curve grid.
pub struct MaskedKronOp<'a> {
    /// (n, n) config kernel matrix.
    pub k1: &'a Matrix,
    /// (m, m) progression kernel matrix.
    pub k2: &'a Matrix,
    /// (n, m) observation mask in {0, 1}.
    pub mask: &'a Matrix,
    /// Noise variance added on the diagonal.
    pub sigma2: f64,
}

impl<'a> MaskedKronOp<'a> {
    pub fn new(k1: &'a Matrix, k2: &'a Matrix, mask: &'a Matrix, sigma2: f64) -> Self {
        assert_eq!(k1.rows(), k1.cols());
        assert_eq!(k2.rows(), k2.cols());
        assert_eq!(mask.rows(), k1.rows());
        assert_eq!(mask.cols(), k2.rows());
        MaskedKronOp { k1, k2, mask, sigma2 }
    }

    pub fn n(&self) -> usize {
        self.k1.rows()
    }

    pub fn m(&self) -> usize {
        self.k2.rows()
    }

    /// Apply to a single (n, m) matrix in-place-free form.
    pub fn apply_mat(&self, v: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(self.n(), self.m());
        let mut ws = Workspace::new(self.n(), self.m());
        self.apply_into(v.data(), out.data_mut(), &mut ws);
        out
    }

    /// Core kernel: out = M.(K1 (M.v) K2) + sigma2 v for one flattened v.
    fn apply_into(&self, v: &[f64], out: &mut [f64], ws: &mut Workspace) {
        let (n, m) = (self.n(), self.m());
        // mv = M . V
        for (dst, (a, b)) in ws.mv.data_mut().iter_mut().zip(v.iter().zip(self.mask.data())) {
            *dst = a * b;
        }
        // w = (M.V) K2   (n x m) (m x m)
        ws.mv.matmul_into(self.k2, &mut ws.w);
        // out_mat = K1 w  (n x n) (n x m)
        self.k1.matmul_into(&ws.w, &mut ws.out_mat);
        // epilogue: mask + sigma2 shift
        let om = ws.out_mat.data();
        let mk = self.mask.data();
        debug_assert_eq!(out.len(), n * m);
        for i in 0..n * m {
            out[i] = mk[i] * om[i] + self.sigma2 * v[i];
        }
    }

    /// Convenience: batched CG solve against this operator.
    pub fn solve(&self, rhs: &[f64], tol: f64, max_iters: usize) -> (Vec<f64>, CgStats) {
        cg_batch(self, rhs, tol, max_iters)
    }

    /// Batched CG solve warm-started from `x0` (same layout as `rhs`).
    /// Scheduler rounds re-solve near-identical masked systems every
    /// generation; starting from the previous solution instead of zero cuts
    /// iterations sharply (see benches/hotpath.rs).
    pub fn solve_warm(
        &self,
        rhs: &[f64],
        x0: Option<&[f64]>,
        tol: f64,
        max_iters: usize,
    ) -> (Vec<f64>, CgStats) {
        crate::linalg::cg_batch_warm(self, rhs, x0, tol, max_iters)
    }

    /// Batched *preconditioned* CG solve, optionally warm-started.
    /// `factors` is the factored preconditioner state (cacheable across
    /// scheduler generations / repeated predicts — see
    /// [`PrecondFactors`]); the mask and σ² are bound live so slightly
    /// stale factors remain a valid SPD preconditioner.
    pub fn solve_precond(
        &self,
        rhs: &[f64],
        x0: Option<&[f64]>,
        factors: Option<&PrecondFactors>,
        tol: f64,
        max_iters: usize,
    ) -> (Vec<f64>, CgStats) {
        match factors {
            Some(f) => {
                let pc = f.apply_state(self.mask, self.sigma2);
                crate::linalg::pcg_batch_warm(self, rhs, x0, Some(&pc), tol, max_iters)
            }
            None => crate::linalg::cg_batch_warm(self, rhs, x0, tol, max_iters),
        }
    }
}

/// Reusable buffers for one apply (avoids per-iteration allocation in CG).
struct Workspace {
    mv: Matrix,
    w: Matrix,
    out_mat: Matrix,
}

impl Workspace {
    fn new(n: usize, m: usize) -> Self {
        Workspace {
            mv: Matrix::zeros(n, m),
            w: Matrix::zeros(n, m),
            out_mat: Matrix::zeros(n, m),
        }
    }
}

/// Shared scaffold for row-independent batched kernels (the operator,
/// its mixed-precision twin, and both preconditioners): split the batch
/// into RHS-column chunks keyed by the *logical* thread count, give each
/// chunk its own workspace, and hand the chunks to the persistent
/// [`crate::util::team::WorkerTeam`] (nested matmul parallelism is
/// disabled inside the parts). Batched CG feeds 9-33 independent RHS per
/// iteration; distributing them across threads is the engine's main
/// parallelism lever (§Perf: 3.4x on the 17-RHS training solve at size
/// 128). Results are bit-identical for every thread count — and for
/// every *team size* — because the chunk split depends only on `threads`
/// and each row's arithmetic is independent of where it runs.
fn apply_rows_threaded<WS>(
    x: &[f64],
    out: &mut [f64],
    batch: usize,
    nm: usize,
    threads: usize,
    make_ws: &(impl Fn() -> WS + Sync),
    row: &(impl Fn(&[f64], &mut [f64], &mut WS) + Sync),
) {
    debug_assert_eq!(x.len(), batch * nm);
    let threads = threads.min(batch.max(1));
    if threads <= 1 || batch <= 1 {
        let mut ws = make_ws();
        for b in 0..batch {
            row(&x[b * nm..(b + 1) * nm], &mut out[b * nm..(b + 1) * nm], &mut ws);
        }
        return;
    }
    let chunk = batch.div_ceil(threads);
    let parts = batch.div_ceil(chunk);
    let base = crate::linalg::matrix::SendMutPtr(out.as_mut_ptr());
    crate::util::team::WorkerTeam::global().run(parts, &|p| {
        crate::linalg::matrix::without_nested_parallelism(|| {
            let b0 = p * chunk;
            let local = chunk.min(batch - b0);
            // SAFETY: RHS blocks [b0, b0 + local) are disjoint across part
            // indices, and the team's completion barrier keeps the `out`
            // borrow live while any part runs.
            let out_chunk =
                unsafe { std::slice::from_raw_parts_mut(base.0.add(b0 * nm), local * nm) };
            let x_chunk = &x[b0 * nm..(b0 + local) * nm];
            let mut ws = make_ws();
            for b in 0..local {
                row(
                    &x_chunk[b * nm..(b + 1) * nm],
                    &mut out_chunk[b * nm..(b + 1) * nm],
                    &mut ws,
                );
            }
        });
    });
}

impl MaskedKronOp<'_> {
    /// [`LinOp::apply_batch`] with an explicit worker-thread count
    /// (`apply_batch` resolves it from `util::num_threads`). Exposed so
    /// tests can pin the threaded split deterministically; results are
    /// bit-identical for every thread count.
    pub fn apply_batch_with_threads(&self, x: &[f64], out: &mut [f64], batch: usize, threads: usize) {
        apply_rows_threaded(
            x,
            out,
            batch,
            self.len(),
            threads,
            &|| Workspace::new(self.n(), self.m()),
            &|xi, oi, ws| self.apply_into(xi, oi, ws),
        );
    }
}

impl LinOp for MaskedKronOp<'_> {
    fn len(&self) -> usize {
        self.n() * self.m()
    }

    fn apply_batch(&self, x: &[f64], out: &mut [f64], batch: usize) {
        self.apply_batch_with_threads(x, out, batch, crate::util::num_threads());
    }
}

// ---------------------------------------------------------------------------
// Mixed-precision operator (f32 storage, f64 accumulation)

/// The mixed-precision twin of [`MaskedKronOp`]: the Kronecker factors K1
/// and K2 are stored rounded to f32 (halving the memory traffic that
/// bounds the MVM), while the mask, the vectors, σ², and every product
/// accumulation stay f64. It is the *fast* operator inside the
/// [`refined_solve`] outer loop (`SolverCfg::precision = F32`); the exact
/// f64 operator still measures the residual, so final answers carry
/// f64-grade residual guarantees (docs/parallelism.md).
pub struct MaskedKronOpF32<'a> {
    k1: MatrixF32,
    k2: MatrixF32,
    mask: &'a Matrix,
    sigma2: f64,
}

impl<'a> MaskedKronOpF32<'a> {
    /// Round an exact operator's factors down to f32 storage (O(n² + m²)
    /// one-off cast, trivial next to one O(n²m) apply).
    pub fn from_op(op: &MaskedKronOp<'a>) -> Self {
        MaskedKronOpF32 {
            k1: MatrixF32::from_f64(op.k1),
            k2: MatrixF32::from_f64(op.k2),
            mask: op.mask,
            sigma2: op.sigma2,
        }
    }

    pub fn n(&self) -> usize {
        self.k1.rows()
    }

    pub fn m(&self) -> usize {
        self.k2.rows()
    }

    /// Core kernel: same structure as [`MaskedKronOp::apply_into`], with
    /// the two matmuls running against f32-storage factors.
    fn apply_into(&self, v: &[f64], out: &mut [f64], ws: &mut Workspace) {
        let (n, m) = (self.n(), self.m());
        for (dst, (a, b)) in ws.mv.data_mut().iter_mut().zip(v.iter().zip(self.mask.data())) {
            *dst = a * b;
        }
        matmul_mixed_ab32(&ws.mv, &self.k2, &mut ws.w);
        matmul_mixed_a32b(&self.k1, &ws.w, &mut ws.out_mat);
        let om = ws.out_mat.data();
        let mk = self.mask.data();
        debug_assert_eq!(out.len(), n * m);
        for i in 0..n * m {
            out[i] = mk[i] * om[i] + self.sigma2 * v[i];
        }
    }

    /// [`LinOp::apply_batch`] with an explicit worker-thread count; same
    /// determinism contract as the exact operator (bit-identical for
    /// every thread count at fixed precision mode).
    pub fn apply_batch_with_threads(&self, x: &[f64], out: &mut [f64], batch: usize, threads: usize) {
        apply_rows_threaded(
            x,
            out,
            batch,
            self.n() * self.m(),
            threads,
            &|| Workspace::new(self.n(), self.m()),
            &|xi, oi, ws| self.apply_into(xi, oi, ws),
        );
    }
}

impl LinOp for MaskedKronOpF32<'_> {
    fn len(&self) -> usize {
        self.n() * self.m()
    }

    fn apply_batch(&self, x: &[f64], out: &mut [f64], batch: usize) {
        self.apply_batch_with_threads(x, out, batch, crate::util::num_threads());
    }
}

impl MaskedKronOp<'_> {
    /// Mixed-precision batched solve: inner PCG iterations run against
    /// the f32-storage twin, an iterative-refinement outer loop measures
    /// residuals against `self` (exact f64) until they clear `tol` — see
    /// [`refined_solve`]. `factors` precondition the inner solves exactly
    /// as in [`solve_precond`](Self::solve_precond).
    pub fn solve_refined(
        &self,
        rhs: &[f64],
        x0: Option<&[f64]>,
        factors: Option<&PrecondFactors>,
        tol: f64,
        max_iters: usize,
    ) -> (Vec<f64>, RefineStats) {
        // Inner solves only need enough reduction for the outer loop to
        // contract; far-below-tol inner targets would fight f32 rounding.
        let inner_tol = (tol * 0.1).max(1e-6).min(0.1);
        let max_outer = 8;
        let fast = MaskedKronOpF32::from_op(self);
        match factors {
            Some(f) => {
                let pc = f.apply_state(self.mask, self.sigma2);
                refined_solve(self, &fast, rhs, x0, Some(&pc), tol, inner_tol, max_outer, max_iters)
            }
            None => refined_solve(self, &fast, rhs, x0, None, tol, inner_tol, max_outer, max_iters),
        }
    }
}

// ---------------------------------------------------------------------------
// Latent-Kronecker preconditioner

/// Preconditioner policy for the masked-Kronecker CG solves.
///
/// `Auto` and `Rank` choose the *strategy* by mask shape (measured in
/// benches/hotpath.rs, BENCH_pcg.json):
///
/// * **full mask** → [`KronPrecondFactors`] (latent-Kronecker): K1 is
///   factored at low rank, K2 exactly, and `(L1L1ᵀ ⊗ K2 + σ²I)⁻¹` is the
///   near-exact inverse of the operator — CG converges in O(1) iterations.
/// * **partial mask** → [`ObsGramPrecondFactors`] (observed-Gram): the
///   GPyTorch-style rank-r pivoted Cholesky of the observed covariance
///   P K Pᵀ itself, inverted by Woodbury. Masking couples the latent
///   factors' observed/unobserved blocks, which caps their win at ~1.8x
///   on ill-conditioned prefix-mask systems; factoring the observed Gram
///   directly sidesteps the coupling entirely (8-14x measured).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PrecondCfg {
    /// Plain CG (bit-exact with the historical solver).
    #[default]
    Off,
    /// Strategy by mask shape; rank is ADAPTIVE — the pivoted Cholesky
    /// stops when the residual trace of its diagonal has decayed below
    /// [`PrecondCfg::rank_tol`] times the starting trace, capped at
    /// min(n, 64) latent / min(n_obs, 128) observed-Gram. Smooth kernels
    /// compress to single-digit ranks; ill-conditioned spectra spend the
    /// budget where it actually buys iterations.
    Auto,
    /// Explicit pivoted-Cholesky rank (clamped to the factored dimension;
    /// no residual-trace early stop beyond numerical exhaustion).
    Rank(usize),
}

impl PrecondCfg {
    /// Whether preconditioning is requested at all.
    pub fn enabled(&self) -> bool {
        !matches!(self, PrecondCfg::Off)
    }

    /// Rank CAP for the latent-Kronecker strategy (K1 is n×n); None when
    /// off. Under `Auto` the factorization may stop earlier (see
    /// [`PrecondCfg::rank_tol`]).
    pub fn latent_rank(&self, n: usize) -> Option<usize> {
        match self {
            PrecondCfg::Off => None,
            PrecondCfg::Auto => Some(n.min(64).max(1)),
            PrecondCfg::Rank(r) => Some((*r).clamp(1, n.max(1))),
        }
    }

    /// Rank CAP for the observed-Gram strategy; None when off.
    pub fn obs_rank(&self, n_obs: usize) -> Option<usize> {
        match self {
            PrecondCfg::Off => None,
            PrecondCfg::Auto => Some(n_obs.min(128).max(1)),
            PrecondCfg::Rank(r) => Some((*r).clamp(1, n_obs.max(1))),
        }
    }

    /// Relative residual-trace stopping tolerance handed to the pivoted
    /// Cholesky: the factorization stops at the first rank whose residual
    /// diagonal trace falls below `rank_tol * trace(A)`. `Auto` trades
    /// factor size against iteration count at 1e-3 (the residual spectrum
    /// the factors fail to capture is what PCG still has to iterate
    /// through, so deeper decay buys nothing once CG converges in a
    /// handful of steps); explicit `Rank` keeps the historical
    /// numerical-exhaustion-only threshold so requested ranks are honored.
    pub fn rank_tol(&self) -> f64 {
        match self {
            PrecondCfg::Auto => 1e-3,
            PrecondCfg::Off | PrecondCfg::Rank(_) => 1e-12,
        }
    }

    /// Parse a CLI spec: `off`, `auto`, or `rank=R` (R >= 1). Whitespace
    /// around the spec and around the `=` is tolerated (`" rank = 8 "`);
    /// `rank=0` is rejected as None so callers surface a proper error
    /// instead of driving the factorization down a degenerate path.
    pub fn parse(s: &str) -> Option<PrecondCfg> {
        let s = s.trim();
        match s {
            "off" => Some(PrecondCfg::Off),
            "auto" => Some(PrecondCfg::Auto),
            _ => {
                let rest = s.strip_prefix("rank")?.trim_start().strip_prefix('=')?.trim();
                match rest.parse::<usize>() {
                    Ok(0) | Err(_) => None,
                    Ok(r) => Some(PrecondCfg::Rank(r)),
                }
            }
        }
    }
}

/// Mask-free factored state of the latent-Kronecker preconditioner:
/// K1 ≈ L1 L1ᵀ (rank-r pivoted Cholesky) and K2 = V2 D2 V2ᵀ (exact Jacobi
/// eigendecomposition; m ≤ ~52 in this workload). The preconditioner
/// applies
///
/// ```text
/// (L1 L1ᵀ ⊗ K2 + σ² I)⁻¹
///   = (I ⊗ V2) · blockdiag_j (σ² I + d_j L1 L1ᵀ)⁻¹ · (I ⊗ V2ᵀ)
/// ```
///
/// with each n×n block inverted by Woodbury through the r×r
/// eigendecomposition L1ᵀL1 = U S Uᵀ:
///
/// ```text
/// (σ² I + d L1L1ᵀ)⁻¹ = (1/σ²) [ I − L1 U diag(d / (σ² + d s_k)) Uᵀ L1ᵀ ]
/// ```
///
/// Per-apply cost is O(n m² + n m r + m r²) — two V2 rotations, two L1
/// products, two U rotations — against the operator's O(n² m + n m²) MVM.
/// σ² and the mask are NOT baked in: they are supplied at apply time, so
/// the factors stay valid while hyper-parameters drift slowly and can be
/// cached in the `coordinator::store::WarmStart` lineage across scheduler
/// generations.
#[derive(Clone, Debug)]
pub struct KronPrecondFactors {
    n: usize,
    m: usize,
    rank: usize,
    /// Packed theta the factors were built under (drift check).
    theta: Vec<f64>,
    /// (n, r) pivoted-Cholesky factor of K1 and its transpose.
    l1: Matrix,
    l1t: Matrix,
    /// (r, r) eigenvectors of L1ᵀL1 and transpose; eigenvalues s.
    u: Matrix,
    ut: Matrix,
    s: Vec<f64>,
    /// (m, m) eigenvectors of K2 and transpose; eigenvalues d2.
    v2: Matrix,
    v2t: Matrix,
    d2: Vec<f64>,
}

impl KronPrecondFactors {
    /// Factor K1 at `rank` and K2 exactly. `theta` is the packed
    /// hyper-parameter vector the kernels were evaluated at (recorded for
    /// the staleness check; the noise entry is excluded there because σ²
    /// is applied live).
    pub fn build(k1: &Matrix, k2: &Matrix, rank: usize, theta: &[f64]) -> Self {
        Self::build_with_tol(k1, k2, rank, 1e-12, theta)
    }

    /// [`KronPrecondFactors::build`] with an explicit residual-trace
    /// stopping tolerance for the pivoted Cholesky of K1 — `rank` becomes
    /// a cap and the factorization stops early once the residual diagonal
    /// trace decays below `rel_tol * trace(K1)` (the adaptive-rank policy
    /// behind [`PrecondCfg::Auto`]).
    pub fn build_with_tol(
        k1: &Matrix,
        k2: &Matrix,
        rank: usize,
        rel_tol: f64,
        theta: &[f64],
    ) -> Self {
        let (n, m) = (k1.rows(), k2.rows());
        let pc = pivoted_cholesky(k1, rank.min(n), rel_tol);
        let l1 = pc.l;
        let l1t = l1.transpose();
        let c = l1t.matmul(&l1); // (r, r)
        let (mut s, u) = jacobi_eigh(&c, 30);
        for v in s.iter_mut() {
            *v = v.max(0.0);
        }
        let ut = u.transpose();
        let (mut d2, v2) = jacobi_eigh(k2, 30);
        for v in d2.iter_mut() {
            *v = v.max(0.0);
        }
        let v2t = v2.transpose();
        KronPrecondFactors {
            n,
            m,
            rank: l1.cols(),
            theta: theta.to_vec(),
            l1,
            l1t,
            u,
            ut,
            s,
            v2,
            v2t,
            d2,
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn m(&self) -> usize {
        self.m
    }

    /// Rank actually factored (≤ requested when K1 compresses early).
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Whether these factors are still a useful preconditioner for a
    /// problem of shape (n, m) at `theta`: same grid, same config count,
    /// and kernel hyper-parameters within a log-space drift budget. The
    /// noise entry (last packed slot) is excluded — σ² enters the apply
    /// live, so noise drift never stales the factors. Any SPD factors are
    /// *correct* (PCG converges on the true residual regardless); this
    /// check only guards iteration-count quality.
    pub fn compatible(&self, theta: &[f64], n: usize, m: usize) -> bool {
        if self.n != n || self.m != m || self.theta.len() != theta.len() {
            return false;
        }
        let kernel_dims = theta.len().saturating_sub(1);
        self.theta[..kernel_dims]
            .iter()
            .zip(&theta[..kernel_dims])
            .all(|(a, b)| (a - b).abs() < 0.25)
    }
}

/// The masked latent-Kronecker preconditioner: block-diagonal across the
/// observed/unobserved split, matching the operator's structure.
///
/// ```text
/// z = M ∘ P⁻¹ (M ∘ r)  +  (1/σ²) (1 − M) ∘ r
/// ```
///
/// where P = L1L1ᵀ ⊗ K2 + σ²I (see [`KronPrecondFactors`]). On the
/// unobserved complement the operator is exactly σ²I, so the second term
/// is its exact inverse; on the observed block the masked restriction of
/// P⁻¹ is SPD (vᵀ M P⁻¹ M v = (Mv)ᵀ P⁻¹ (Mv) > 0 for mask-supported v).
pub struct LatentKronPrecond<'a> {
    pub factors: &'a KronPrecondFactors,
    /// (n, m) observation mask in {0, 1} (applied live).
    pub mask: &'a Matrix,
    /// Current noise variance (applied live; may differ from build time).
    pub sigma2: f64,
}

/// Reusable buffers for one preconditioner apply.
struct PrecondWorkspace {
    w: Matrix,    // (n, m) rotated residual
    t: Matrix,    // (r, m)
    t2: Matrix,   // (r, m)
    t3: Matrix,   // (r, m)
    corr: Matrix, // (n, m)
    zm: Matrix,   // (n, m) back-rotated output
}

impl PrecondWorkspace {
    fn new(n: usize, m: usize, r: usize) -> Self {
        PrecondWorkspace {
            w: Matrix::zeros(n, m),
            t: Matrix::zeros(r, m),
            t2: Matrix::zeros(r, m),
            t3: Matrix::zeros(r, m),
            corr: Matrix::zeros(n, m),
            zm: Matrix::zeros(n, m),
        }
    }
}

impl LatentKronPrecond<'_> {
    fn apply_one(&self, v: &[f64], out: &mut [f64], ws: &mut PrecondWorkspace) {
        let f = self.factors;
        let (n, m, r) = (f.n, f.m, f.rank);
        let nm = n * m;
        debug_assert_eq!(v.len(), nm);
        let mk = self.mask.data();
        let inv_s2 = 1.0 / self.sigma2;

        // rm = M ∘ v, staged into the w-input slot via corr as scratch.
        for i in 0..nm {
            ws.corr.data_mut()[i] = mk[i] * v[i];
        }
        // W = (M ∘ v) V2   — into the D2 eigenbasis on the grid axis.
        ws.corr.matmul_into(&f.v2, &mut ws.w);
        // T = L1ᵀ W, T2 = Uᵀ T  — into the r-dim eigenbasis on configs.
        f.l1t.matmul_into(&ws.w, &mut ws.t);
        f.ut.matmul_into(&ws.t, &mut ws.t2);
        // Woodbury scaling per (k, j): d_j / (σ² + d_j s_k); a zero grid
        // eigenvalue contributes no correction (block is exactly σ²I).
        for k in 0..r {
            let sk = f.s[k];
            let row = ws.t2.row_mut(k);
            for (j, val) in row.iter_mut().enumerate() {
                let dj = f.d2[j];
                if dj > 0.0 {
                    *val *= dj / (self.sigma2 + dj * sk);
                } else {
                    *val = 0.0;
                }
            }
        }
        // T3 = U T2, corr = L1 T3, W' = (W − corr) / σ².
        f.u.matmul_into(&ws.t2, &mut ws.t3);
        f.l1.matmul_into(&ws.t3, &mut ws.corr);
        {
            let wd = ws.w.data_mut();
            let cd = ws.corr.data();
            for i in 0..nm {
                wd[i] = (wd[i] - cd[i]) * inv_s2;
            }
        }
        // Z = W' V2ᵀ, then the masked epilogue.
        ws.w.matmul_into(&f.v2t, &mut ws.zm);
        let zd = ws.zm.data();
        for i in 0..nm {
            // lint: allow(float_eq) — the mask is exactly 0.0/1.0 by
            // construction; 0.0 marks a structurally missing entry, not a
            // small value.
            out[i] = if mk[i] != 0.0 { zd[i] } else { v[i] * inv_s2 };
        }
    }

    /// Batched apply with an explicit thread count (shares the operator's
    /// scaffold; results are bit-identical for every thread count because
    /// rows are independent).
    pub fn apply_batch_with_threads(&self, r: &[f64], z: &mut [f64], batch: usize, threads: usize) {
        let f = self.factors;
        apply_rows_threaded(
            r,
            z,
            batch,
            f.n * f.m,
            threads,
            &|| PrecondWorkspace::new(f.n, f.m, f.rank),
            &|ri, zi, ws| self.apply_one(ri, zi, ws),
        );
    }
}

impl Preconditioner for LatentKronPrecond<'_> {
    fn apply_batch(&self, r: &[f64], z: &mut [f64], batch: usize) {
        self.apply_batch_with_threads(r, z, batch, crate::util::num_threads());
    }
}

/// Observed-Gram preconditioner factors: rank-r pivoted Cholesky of the
/// observed covariance (P (K1 ⊗ K2) Pᵀ) itself — the machinery GPyTorch
/// uses (Gardner et al. 2018). Entries of the observed Gram are kernel
/// products `k1[i1,i2]·k2[j1,j2]`, so the factorization touches O(n_obs·r)
/// entries through `pivoted_cholesky_fn` without materializing the
/// n_obs × n_obs matrix. The preconditioner is
///
/// ```text
/// z_obs  = (L Lᵀ + σ² I)⁻¹ r_obs
///        = (1/σ²) [ r_obs − L (σ² I + LᵀL)⁻¹ Lᵀ r_obs ]   (Woodbury)
/// z_miss = r_miss / σ²
/// ```
///
/// O(n_obs · r) per apply. σ² enters only the r×r capacitance, which is
/// re-factored per solve, so the factors survive noise drift; the mask is
/// baked in (the factorization lives on the observed index set `idx`), so
/// a mask change stales them — `compatible` checks the observed set
/// exactly against `idx`, which together with (n, m) fully determines the
/// {0,1} mask.
#[derive(Clone, Debug)]
pub struct ObsGramPrecondFactors {
    n: usize,
    m: usize,
    /// Packed theta the factors were built under (drift check).
    theta: Vec<f64>,
    /// Flat grid indices of the observed entries, row-major ascending.
    idx: Vec<usize>,
    /// (n_obs, r) pivoted-Cholesky factor of the observed Gram.
    l: Matrix,
    /// (r, r) Gram LᵀL, precomputed for the capacitance.
    ltl: Matrix,
}

impl ObsGramPrecondFactors {
    /// Factor the observed covariance at `rank` (≤ n_obs).
    pub fn build(k1: &Matrix, k2: &Matrix, mask: &Matrix, rank: usize, theta: &[f64]) -> Self {
        Self::build_with_tol(k1, k2, mask, rank, 1e-12, theta)
    }

    /// [`ObsGramPrecondFactors::build`] with an explicit residual-trace
    /// stopping tolerance — `rank` becomes a cap and the factorization
    /// stops early once the residual diagonal trace of the observed Gram
    /// decays below `rel_tol` times its starting trace (the adaptive-rank
    /// policy behind [`PrecondCfg::Auto`]).
    pub fn build_with_tol(
        k1: &Matrix,
        k2: &Matrix,
        mask: &Matrix,
        rank: usize,
        rel_tol: f64,
        theta: &[f64],
    ) -> Self {
        let (n, m) = (k1.rows(), k2.rows());
        debug_assert_eq!((mask.rows(), mask.cols()), (n, m));
        let idx: Vec<usize> = mask
            .data()
            .iter()
            .enumerate()
            .filter(|(_, &mv)| mv > 0.0)
            .map(|(i, _)| i)
            .collect();
        let diag: Vec<f64> = idx.iter().map(|&i| k1[(i / m, i / m)] * k2[(i % m, i % m)]).collect();
        let pc = crate::linalg::pivoted_cholesky_fn(
            &diag,
            &mut |piv, out| {
                let (pi, pj) = (idx[piv] / m, idx[piv] % m);
                for (a, o) in out.iter_mut().enumerate() {
                    let (i, j) = (idx[a] / m, idx[a] % m);
                    *o = k1[(i, pi)] * k2[(j, pj)];
                }
            },
            rank.min(idx.len()),
            rel_tol,
        );
        let l = pc.l;
        let ltl = l.transpose().matmul(&l);
        ObsGramPrecondFactors {
            n,
            m,
            theta: theta.to_vec(),
            idx,
            l,
            ltl,
        }
    }

    pub fn rank(&self) -> usize {
        self.l.cols()
    }

    /// Valid for a problem at `theta` with this exact mask: kernel
    /// hyper-parameters within the drift window (noise excluded — σ² only
    /// enters the per-solve capacitance) and an unchanged observed set
    /// (streamed against the stored `idx`, no mask copy kept).
    pub fn compatible(&self, theta: &[f64], n: usize, m: usize, mask: &Matrix) -> bool {
        if self.n != n || self.m != m || self.theta.len() != theta.len() {
            return false;
        }
        let mut stored = self.idx.iter();
        let same_observed = mask
            .data()
            .iter()
            .enumerate()
            .all(|(i, &mv)| mv <= 0.0 || stored.next() == Some(&i))
            && stored.next().is_none();
        if !same_observed {
            return false;
        }
        let kernel_dims = theta.len().saturating_sub(1);
        self.theta[..kernel_dims]
            .iter()
            .zip(&theta[..kernel_dims])
            .all(|(a, b)| (a - b).abs() < 0.25)
    }
}

/// Live apply state for [`ObsGramPrecondFactors`]: the σ²-dependent
/// capacitance Cholesky is built once per solve.
pub struct ObsGramPrecond<'a> {
    factors: &'a ObsGramPrecondFactors,
    sigma2: f64,
    /// Cholesky factor of (σ² I + LᵀL).
    cap_l: Matrix,
}

impl<'a> ObsGramPrecond<'a> {
    pub fn new(factors: &'a ObsGramPrecondFactors, sigma2: f64) -> Self {
        let mut cap = factors.ltl.clone();
        cap.add_diag(sigma2);
        // σ² I + LᵀL is SPD by construction; cholesky cannot fail for
        // sigma2 > 0 barring catastrophic roundoff, in which case we
        // neutralize the low-rank correction (capacitance inverse → 0)
        // so the preconditioner degrades to the SPD 1/σ² scaling.
        let cap_l = crate::linalg::cholesky(&cap).unwrap_or_else(|_| {
            let mut eye = Matrix::eye(factors.rank());
            eye.scale(1e150);
            eye
        });
        ObsGramPrecond { factors, sigma2, cap_l }
    }

    fn apply_one(&self, v: &[f64], out: &mut [f64], robs: &mut [f64], t: &mut [f64]) {
        let f = self.factors;
        let inv_s2 = 1.0 / self.sigma2;
        for (o, vi) in out.iter_mut().zip(v.iter()) {
            *o = vi * inv_s2;
        }
        let no = f.idx.len();
        let r = f.rank();
        if no == 0 || r == 0 {
            return;
        }
        for (a, &i) in f.idx.iter().enumerate() {
            robs[a] = v[i];
        }
        // t = Lᵀ r_obs (row-wise accumulation keeps L accesses contiguous)
        t.fill(0.0);
        for (a, &ra) in robs.iter().enumerate() {
            crate::linalg::matrix::axpy(ra, f.l.row(a), t);
        }
        // t ← (σ²I + LᵀL)⁻¹ t via the capacitance Cholesky
        let t2 = crate::linalg::chol_solve(&self.cap_l, t);
        // z_obs = (r_obs − L t2) / σ²
        for (a, &i) in f.idx.iter().enumerate() {
            let corr = crate::linalg::matrix::dot(f.l.row(a), &t2);
            out[i] = (robs[a] - corr) * inv_s2;
        }
    }

    /// Batched apply with an explicit thread count (shares the operator's
    /// scaffold; rows independent, so results are bit-identical for every
    /// thread count).
    pub fn apply_batch_with_threads(&self, r: &[f64], z: &mut [f64], batch: usize, threads: usize) {
        let f = self.factors;
        apply_rows_threaded(
            r,
            z,
            batch,
            f.n * f.m,
            threads,
            &|| (vec![0.0; f.idx.len()], vec![0.0; f.rank()]),
            &|ri, zi, ws: &mut (Vec<f64>, Vec<f64>)| self.apply_one(ri, zi, &mut ws.0, &mut ws.1),
        );
    }
}

impl Preconditioner for ObsGramPrecond<'_> {
    fn apply_batch(&self, r: &[f64], z: &mut [f64], batch: usize) {
        self.apply_batch_with_threads(r, z, batch, crate::util::num_threads());
    }
}

/// The factored preconditioner state threaded through the solve stack and
/// cached in the `coordinator::store::WarmStart` lineage. Strategy is
/// chosen by mask shape at build time (see [`PrecondCfg`]).
#[derive(Clone, Debug)]
pub enum PrecondFactors {
    /// Mask-free latent-Kronecker factors (full-mask problems; reusable
    /// across generations even as the mask would change — it is applied
    /// live).
    LatentKron(KronPrecondFactors),
    /// Observed-Gram factors (partial masks; reusable while the observed
    /// set is unchanged, e.g. repeated predicts against one snapshot).
    ObservedGram(ObsGramPrecondFactors),
}

impl PrecondFactors {
    /// Build factors for a masked-Kronecker system under `cfg`. Returns
    /// None when preconditioning is off (or the mask is empty).
    pub fn build(
        cfg: PrecondCfg,
        k1: &Matrix,
        k2: &Matrix,
        mask: &Matrix,
        theta: &[f64],
    ) -> Option<PrecondFactors> {
        if !cfg.enabled() {
            return None;
        }
        let n = k1.rows();
        let full_mask = mask.data().iter().all(|&mv| mv > 0.0);
        if full_mask {
            let rank = cfg.latent_rank(n)?;
            Some(PrecondFactors::LatentKron(KronPrecondFactors::build_with_tol(
                k1,
                k2,
                rank,
                cfg.rank_tol(),
                theta,
            )))
        } else {
            let n_obs = mask.data().iter().filter(|&&mv| mv > 0.0).count();
            if n_obs == 0 {
                return None;
            }
            let rank = cfg.obs_rank(n_obs)?;
            Some(PrecondFactors::ObservedGram(ObsGramPrecondFactors::build_with_tol(
                k1,
                k2,
                mask,
                rank,
                cfg.rank_tol(),
                theta,
            )))
        }
    }

    /// Whether cached factors still fit a problem of shape (n, m) at
    /// `theta` with `mask` (see the per-strategy `compatible` docs).
    pub fn compatible(&self, theta: &[f64], n: usize, m: usize, mask: &Matrix) -> bool {
        match self {
            PrecondFactors::LatentKron(f) => {
                f.compatible(theta, n, m) && mask.data().iter().all(|&mv| mv > 0.0)
            }
            PrecondFactors::ObservedGram(f) => f.compatible(theta, n, m, mask),
        }
    }

    /// Bind the factors to a live (mask, σ²) pair for one solve.
    pub fn apply_state<'a>(&'a self, mask: &'a Matrix, sigma2: f64) -> PrecondApply<'a> {
        match self {
            PrecondFactors::LatentKron(f) => PrecondApply::LatentKron(LatentKronPrecond {
                factors: f,
                mask,
                sigma2,
            }),
            PrecondFactors::ObservedGram(f) => {
                PrecondApply::ObservedGram(ObsGramPrecond::new(f, sigma2))
            }
        }
    }

    /// Rank of the underlying factor (observability / reports).
    pub fn rank(&self) -> usize {
        match self {
            PrecondFactors::LatentKron(f) => f.rank(),
            PrecondFactors::ObservedGram(f) => f.rank(),
        }
    }

    /// Short strategy tag for logs.
    pub fn strategy(&self) -> &'static str {
        match self {
            PrecondFactors::LatentKron(_) => "latent-kron",
            PrecondFactors::ObservedGram(_) => "obs-gram",
        }
    }
}

/// Per-solve apply state for [`PrecondFactors`] (implements
/// [`Preconditioner`] uniformly over both strategies).
pub enum PrecondApply<'a> {
    LatentKron(LatentKronPrecond<'a>),
    ObservedGram(ObsGramPrecond<'a>),
}

impl Preconditioner for PrecondApply<'_> {
    fn apply_batch(&self, r: &[f64], z: &mut [f64], batch: usize) {
        match self {
            PrecondApply::LatentKron(p) => p.apply_batch(r, z, batch),
            PrecondApply::ObservedGram(p) => p.apply_batch(r, z, batch),
        }
    }
}

/// Dense materialization of the same operator (oracle for tests and the
/// naive engine's building block): diag(m) (K1 (x) K2) diag(m) + s2 I.
pub fn dense_masked_kron(k1: &Matrix, k2: &Matrix, mask: &Matrix, sigma2: f64) -> Matrix {
    let (n, m) = (k1.rows(), k2.rows());
    let nm = n * m;
    let mut out = Matrix::zeros(nm, nm);
    let mk = mask.data();
    for i1 in 0..n {
        for j1 in 0..m {
            let r = i1 * m + j1;
            for i2 in 0..n {
                for j2 in 0..m {
                    let c = i2 * m + j2;
                    out[(r, c)] = mk[r] * k1[(i1, i2)] * k2[(j1, j2)] * mk[c];
                }
            }
        }
    }
    out.add_diag(sigma2);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::kernels;
    use crate::rng::Pcg64;

    #[test]
    fn precond_cfg_parse_accepts_whitespace_and_rejects_zero() {
        assert_eq!(PrecondCfg::parse("off"), Some(PrecondCfg::Off));
        assert_eq!(PrecondCfg::parse(" auto "), Some(PrecondCfg::Auto));
        assert_eq!(PrecondCfg::parse("rank=12"), Some(PrecondCfg::Rank(12)));
        assert_eq!(PrecondCfg::parse("  rank=8  "), Some(PrecondCfg::Rank(8)));
        assert_eq!(PrecondCfg::parse("rank = 3"), Some(PrecondCfg::Rank(3)));
        assert_eq!(PrecondCfg::parse("rank =7"), Some(PrecondCfg::Rank(7)));
        // rank=0 must surface as a parse error, not a degenerate config
        assert_eq!(PrecondCfg::parse("rank=0"), None);
        assert_eq!(PrecondCfg::parse("rank = 0"), None);
        assert_eq!(PrecondCfg::parse("rank="), None);
        assert_eq!(PrecondCfg::parse("rank=abc"), None);
        assert_eq!(PrecondCfg::parse("bogus"), None);
        assert_eq!(PrecondCfg::parse(""), None);
    }

    fn setup(n: usize, m: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
        let mut rng = Pcg64::new(seed);
        let x = Matrix::from_vec(n, 3, rng.uniform_vec(n * 3, 0.0, 1.0));
        let k1 = kernels::rbf(&x, &x, &[0.8, 1.1, 0.6]);
        let t: Vec<f64> = (0..m).map(|i| i as f64 / (m.max(2) - 1) as f64).collect();
        let k2 = kernels::matern12(&t, &t, 0.4, 1.3);
        let mask = Matrix::from_fn(n, m, |_, _| if rng.uniform() < 0.7 { 1.0 } else { 0.0 });
        (k1, k2, mask)
    }

    #[test]
    fn matches_dense_operator() {
        let (k1, k2, mask) = setup(6, 5, 1);
        let op = MaskedKronOp::new(&k1, &k2, &mask, 0.09);
        let dense = dense_masked_kron(&k1, &k2, &mask, 0.09);
        let mut rng = Pcg64::new(2);
        let v = rng.normal_vec(30);
        let mut got = vec![0.0; 30];
        op.apply_batch(&v, &mut got, 1);
        let want = dense.matvec(&v);
        for i in 0..30 {
            assert!((got[i] - want[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn batched_apply_matches_sequential() {
        let (k1, k2, mask) = setup(8, 7, 3);
        let op = MaskedKronOp::new(&k1, &k2, &mask, 0.2);
        let mut rng = Pcg64::new(4);
        let batch = 5;
        let v = rng.normal_vec(batch * 56);
        let mut got = vec![0.0; batch * 56];
        op.apply_batch(&v, &mut got, batch);
        for b in 0..batch {
            let mut one = vec![0.0; 56];
            op.apply_batch(&v[b * 56..(b + 1) * 56], &mut one, 1);
            assert_eq!(&got[b * 56..(b + 1) * 56], &one[..]);
        }
    }

    #[test]
    fn preserves_observed_subspace() {
        let (k1, k2, mask) = setup(7, 6, 5);
        let op = MaskedKronOp::new(&k1, &k2, &mask, 0.15);
        let mut rng = Pcg64::new(6);
        // observed-supported input
        let v: Vec<f64> = mask.data().iter().map(|&m| m * rng.normal()).collect();
        let mut out = vec![0.0; 42];
        op.apply_batch(&v, &mut out, 1);
        for (o, m) in out.iter().zip(mask.data()) {
            if *m == 0.0 {
                assert_eq!(*o, 0.0);
            }
        }
    }

    #[test]
    fn full_mask_is_plain_kronecker() {
        let (k1, k2, _) = setup(5, 4, 7);
        let mask = Matrix::from_fn(5, 4, |_, _| 1.0);
        let op = MaskedKronOp::new(&k1, &k2, &mask, 0.0);
        // (K1 x K2) vec(V) == K1 V K2 (row-major, symmetric K2)
        let mut rng = Pcg64::new(8);
        let v = Matrix::from_vec(5, 4, rng.normal_vec(20));
        let want = k1.matmul(&v).matmul(&k2);
        let got = op.apply_mat(&v);
        assert!(got.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn solve_restricted_equals_projected_system() {
        // CG on the full-space masked operator must equal the dense solve
        // of the projected (observed-only) system (paper's P K P^T).
        let (k1, k2, mask) = setup(6, 5, 9);
        let s2 = 0.3;
        let op = MaskedKronOp::new(&k1, &k2, &mask, s2);
        let mut rng = Pcg64::new(10);
        let rhs: Vec<f64> = mask.data().iter().map(|&m| m * rng.normal()).collect();
        let (x, stats) = op.solve(&rhs, 1e-12, 2000);
        assert!(stats.converged);

        // dense projected system
        let idx: Vec<usize> = mask
            .data()
            .iter()
            .enumerate()
            .filter(|(_, &m)| m > 0.0)
            .map(|(i, _)| i)
            .collect();
        let dense = dense_masked_kron(&k1, &k2, &mask, s2);
        let no = idx.len();
        let mut proj = Matrix::zeros(no, no);
        for (a, &ia) in idx.iter().enumerate() {
            for (b, &ib) in idx.iter().enumerate() {
                proj[(a, b)] = dense[(ia, ib)];
            }
        }
        let l = crate::linalg::cholesky(&proj).unwrap();
        let rhs_obs: Vec<f64> = idx.iter().map(|&i| rhs[i]).collect();
        let want = crate::linalg::chol_solve(&l, &rhs_obs);
        for (a, &ia) in idx.iter().enumerate() {
            assert!((x[ia] - want[a]).abs() < 1e-8, "obs {a}");
        }
        // missing entries stay exactly zero
        for (i, &m) in mask.data().iter().enumerate() {
            if m == 0.0 {
                assert_eq!(x[i], 0.0);
            }
        }
    }

    #[test]
    fn precond_matches_dense_inverse_at_full_rank() {
        // Full mask + full rank: the preconditioner IS (K1 ⊗ K2 + σ²I)⁻¹.
        let (k1, k2, _) = setup(6, 5, 21);
        let mask = Matrix::from_fn(6, 5, |_, _| 1.0);
        let s2 = 0.17;
        let theta = vec![0.0; 6];
        let f = KronPrecondFactors::build(&k1, &k2, 6, &theta);
        let pc = LatentKronPrecond { factors: &f, mask: &mask, sigma2: s2 };

        let dense = dense_masked_kron(&k1, &k2, &mask, s2);
        let l = crate::linalg::cholesky(&dense).unwrap();
        let mut rng = Pcg64::new(22);
        let v = rng.normal_vec(30);
        let mut z = vec![0.0; 30];
        pc.apply_batch(&v, &mut z, 1);
        let want = crate::linalg::chol_solve(&l, &v);
        for i in 0..30 {
            assert!((z[i] - want[i]).abs() < 1e-7, "i={i}: {} vs {}", z[i], want[i]);
        }
    }

    #[test]
    fn precond_is_exact_noise_inverse_off_mask() {
        let (k1, k2, mask) = setup(7, 6, 23);
        let s2 = 0.4;
        let theta = vec![0.0; 6];
        let f = KronPrecondFactors::build(&k1, &k2, 4, &theta);
        let pc = LatentKronPrecond { factors: &f, mask: &mask, sigma2: s2 };
        let mut rng = Pcg64::new(24);
        let v = rng.normal_vec(42);
        let mut z = vec![0.0; 42];
        pc.apply_batch(&v, &mut z, 1);
        for (i, &mk) in mask.data().iter().enumerate() {
            if mk == 0.0 {
                assert!((z[i] - v[i] / s2).abs() < 1e-12, "i={i}");
            }
        }
    }

    #[test]
    fn precond_is_symmetric_positive_definite() {
        let (k1, k2, mask) = setup(6, 5, 25);
        let theta = vec![0.0; 6];
        let f = KronPrecondFactors::build(&k1, &k2, 3, &theta);
        let pc = LatentKronPrecond { factors: &f, mask: &mask, sigma2: 0.09 };
        let mut rng = Pcg64::new(26);
        let u = rng.normal_vec(30);
        let v = rng.normal_vec(30);
        let mut mu = vec![0.0; 30];
        let mut mv = vec![0.0; 30];
        pc.apply_batch(&u, &mut mu, 1);
        pc.apply_batch(&v, &mut mv, 1);
        let umv = crate::linalg::matrix::dot(&u, &mv);
        let vmu = crate::linalg::matrix::dot(&v, &mu);
        assert!((umv - vmu).abs() < 1e-8 * (1.0 + umv.abs()), "not symmetric");
        let umu = crate::linalg::matrix::dot(&u, &mu);
        assert!(umu > 0.0, "u M⁻¹ u = {umu}");
    }

    #[test]
    fn precond_batch_parallel_bit_identical() {
        let (k1, k2, mask) = setup(8, 6, 27);
        let theta = vec![0.0; 6];
        let f = KronPrecondFactors::build(&k1, &k2, 5, &theta);
        let pc = LatentKronPrecond { factors: &f, mask: &mask, sigma2: 0.2 };
        let nm = 48;
        let batch = 5;
        let mut rng = Pcg64::new(28);
        let v = rng.normal_vec(batch * nm);
        let mut seq = vec![0.0; batch * nm];
        for b in 0..batch {
            pc.apply_batch_with_threads(&v[b * nm..(b + 1) * nm], &mut seq[b * nm..(b + 1) * nm], 1, 1);
        }
        for threads in [2, 3, 4] {
            let mut got = vec![0.0; batch * nm];
            pc.apply_batch_with_threads(&v, &mut got, batch, threads);
            assert_eq!(got, seq, "threads={threads}");
        }
    }

    /// Ill-conditioned test system: small noise + smooth kernels.
    fn ill_system(n: usize, m: usize, seed: u64) -> (Matrix, Matrix) {
        let mut rng = Pcg64::new(seed);
        let x = Matrix::from_vec(n, 2, rng.uniform_vec(n * 2, 0.0, 1.0));
        let k1 = kernels::rbf(&x, &x, &[2.0, 2.0]);
        let t: Vec<f64> = (0..m).map(|i| i as f64 / (m - 1) as f64).collect();
        let k2 = kernels::matern12(&t, &t, 1.5, 1.0);
        (k1, k2)
    }

    fn assert_pcg_beats_plain(
        op: &MaskedKronOp,
        factors: &PrecondFactors,
        rhs: &[f64],
        min_ratio: usize,
    ) {
        let (_, plain) = op.solve(rhs, 1e-2, 10_000);
        let (pcg_x, pcg) = op.solve_precond(rhs, None, Some(factors), 1e-2, 10_000);
        assert!(plain.converged && pcg.converged);
        assert!(
            pcg.iters * min_ratio <= plain.iters,
            "[{}] pcg {} vs plain {}",
            factors.strategy(),
            pcg.iters,
            plain.iters
        );
        assert!(pcg.mvm_rows <= plain.mvm_rows);
        // the preconditioned solve lands on the same system solution
        let nm = op.len();
        let mut back = vec![0.0; nm];
        op.apply_batch(&pcg_x, &mut back, 1);
        let bnorm = crate::linalg::matrix::dot(rhs, rhs).sqrt();
        let mut err = 0.0f64;
        for i in 0..nm {
            err += (back[i] - rhs[i]) * (back[i] - rhs[i]);
        }
        assert!(err.sqrt() <= 1.1e-2 * bnorm, "pcg residual too large");
    }

    #[test]
    fn latent_kron_precond_crushes_full_mask_ill_conditioned() {
        // Full mask -> Auto picks the latent-Kronecker factors, which are
        // the near-exact inverse: expect O(1) iterations vs hundreds.
        let (n, m) = (24, 16);
        let (k1, k2) = ill_system(n, m, 29);
        let mask = Matrix::from_fn(n, m, |_, _| 1.0);
        let s2 = 1e-4;
        let op = MaskedKronOp::new(&k1, &k2, &mask, s2);
        let mut rng = Pcg64::new(30);
        let rhs = rng.normal_vec(n * m);
        let theta = vec![0.0; 5];
        let f = PrecondFactors::build(PrecondCfg::Auto, &k1, &k2, &mask, &theta).unwrap();
        assert_eq!(f.strategy(), "latent-kron");
        assert_pcg_beats_plain(&op, &f, &rhs, 4);
    }

    #[test]
    fn obs_gram_precond_cuts_masked_ill_conditioned() {
        // Partial mask -> Auto picks the observed-Gram factors (the
        // latent factors' observed/unobserved coupling caps their win).
        let (n, m) = (24, 16);
        let (k1, k2) = ill_system(n, m, 31);
        let mut rng = Pcg64::new(32);
        let mask = Matrix::from_fn(n, m, |_, _| if rng.uniform() < 0.8 { 1.0 } else { 0.0 });
        let s2 = 1e-4;
        let op = MaskedKronOp::new(&k1, &k2, &mask, s2);
        let rhs: Vec<f64> = mask.data().iter().map(|&mk| mk * rng.normal()).collect();
        let theta = vec![0.0; 5];
        let f = PrecondFactors::build(PrecondCfg::Auto, &k1, &k2, &mask, &theta).unwrap();
        assert_eq!(f.strategy(), "obs-gram");
        assert_pcg_beats_plain(&op, &f, &rhs, 2);
    }

    #[test]
    fn auto_rank_adapts_to_spectrum_decay() {
        // Smooth RBF kernel with long lengthscales: the spectrum decays
        // fast, so Auto's residual-trace stop should settle far below the
        // cap. Shorter lengthscales flatten the spectrum and force a
        // larger rank. Explicit Rank(r) must keep honoring r exactly.
        let (n, m) = (40, 10);
        let mut rng = Pcg64::new(61);
        let x = Matrix::from_vec(n, 2, rng.uniform_vec(n * 2, 0.0, 1.0));
        let t: Vec<f64> = (0..m).map(|i| i as f64 / (m - 1) as f64).collect();
        let k2 = kernels::matern12(&t, &t, 1.5, 1.0);
        let mask = Matrix::from_fn(n, m, |_, _| 1.0);
        let theta = vec![0.0; 5];

        let smooth = kernels::rbf(&x, &x, &[3.0, 3.0]);
        let f_smooth = PrecondFactors::build(PrecondCfg::Auto, &smooth, &k2, &mask, &theta).unwrap();
        assert!(
            f_smooth.rank() < 16,
            "fast-decay spectrum must compress: rank={}",
            f_smooth.rank()
        );

        let rough = kernels::rbf(&x, &x, &[0.08, 0.08]);
        let f_rough = PrecondFactors::build(PrecondCfg::Auto, &rough, &k2, &mask, &theta).unwrap();
        assert!(
            f_rough.rank() > f_smooth.rank(),
            "flat spectrum must spend more rank: rough={} smooth={}",
            f_rough.rank(),
            f_smooth.rank()
        );

        // Rank(r) is pinned regardless of decay (no 1e-3 early stop).
        let f_pin = PrecondFactors::build(PrecondCfg::Rank(12), &smooth, &k2, &mask, &theta).unwrap();
        assert_eq!(f_pin.rank(), 12);
    }

    #[test]
    fn auto_rank_still_beats_plain_on_ill_conditioned_system() {
        // The adaptive stop must not under-rank an ill-conditioned
        // partial-mask system into losing its PCG win.
        let (n, m) = (24, 16);
        let (k1, k2) = ill_system(n, m, 63);
        let mut rng = Pcg64::new(64);
        let mask = Matrix::from_fn(n, m, |_, _| if rng.uniform() < 0.8 { 1.0 } else { 0.0 });
        let op = MaskedKronOp::new(&k1, &k2, &mask, 1e-4);
        let rhs: Vec<f64> = mask.data().iter().map(|&mk| mk * rng.normal()).collect();
        let theta = vec![0.0; 5];
        let f = PrecondFactors::build(PrecondCfg::Auto, &k1, &k2, &mask, &theta).unwrap();
        assert_eq!(f.strategy(), "obs-gram");
        assert_pcg_beats_plain(&op, &f, &rhs, 2);
    }

    #[test]
    fn obs_gram_precond_matches_dense_inverse_at_full_rank() {
        // At rank = n_obs the Woodbury apply is the exact inverse of the
        // observed block (K_obs + σ²I) and 1/σ² off-mask.
        let (k1, k2, mask) = setup(6, 5, 33);
        let s2 = 0.21;
        let theta = vec![0.0; 6];
        let n_obs = mask.data().iter().filter(|&&mv| mv > 0.0).count();
        let f = ObsGramPrecondFactors::build(&k1, &k2, &mask, n_obs, &theta);
        let pc = ObsGramPrecond::new(&f, s2);
        let dense = dense_masked_kron(&k1, &k2, &mask, s2);
        let l = crate::linalg::cholesky(&dense).unwrap();
        let mut rng = Pcg64::new(34);
        let v = rng.normal_vec(30);
        let mut z = vec![0.0; 30];
        pc.apply_batch(&v, &mut z, 1);
        let want = crate::linalg::chol_solve(&l, &v);
        for i in 0..30 {
            assert!((z[i] - want[i]).abs() < 1e-7, "i={i}: {} vs {}", z[i], want[i]);
        }
    }

    #[test]
    fn obs_gram_factors_stale_on_mask_change() {
        let (k1, k2, mask) = setup(6, 5, 35);
        let theta = vec![0.0; 6];
        let f = PrecondFactors::build(PrecondCfg::Rank(8), &k1, &k2, &mask, &theta).unwrap();
        assert!(f.compatible(&theta, 6, 5, &mask));
        let mut grown = mask.clone();
        let flip = grown.data().iter().position(|&mv| mv == 0.0);
        if let Some(i) = flip {
            grown.data_mut()[i] = 1.0;
            assert!(!f.compatible(&theta, 6, 5, &grown));
        }
    }

    #[test]
    fn precond_factors_compatibility_window() {
        let (k1, k2, _) = setup(6, 5, 31);
        let theta = vec![0.1, 0.2, 0.3, -0.5, 0.0, -2.0];
        let f = KronPrecondFactors::build(&k1, &k2, 4, &theta);
        assert!(f.compatible(&theta, 6, 5));
        // noise drift is free (σ² applied live)
        let mut noise_shift = theta.clone();
        noise_shift[5] -= 3.0;
        assert!(f.compatible(&noise_shift, 6, 5));
        // kernel drift beyond the window stales the factors
        let mut ls_shift = theta.clone();
        ls_shift[0] += 0.5;
        assert!(!f.compatible(&ls_shift, 6, 5));
        // shape changes always stale
        assert!(!f.compatible(&theta, 7, 5));
        assert!(!f.compatible(&theta, 6, 4));
    }

    #[test]
    fn operator_is_symmetric() {
        let (k1, k2, mask) = setup(5, 6, 11);
        let op = MaskedKronOp::new(&k1, &k2, &mask, 0.05);
        let mut rng = Pcg64::new(12);
        let u = rng.normal_vec(30);
        let v = rng.normal_vec(30);
        let mut au = vec![0.0; 30];
        let mut av = vec![0.0; 30];
        op.apply_batch(&u, &mut au, 1);
        op.apply_batch(&v, &mut av, 1);
        let uav = crate::linalg::matrix::dot(&u, &av);
        let vau = crate::linalg::matrix::dot(&v, &au);
        assert!((uav - vau).abs() < 1e-9);
    }

    #[test]
    fn f32_operator_matches_exact_within_rounding() {
        let (k1, k2, mask) = setup(10, 8, 41);
        let s2 = 0.15;
        let op = MaskedKronOp::new(&k1, &k2, &mask, s2);
        let fast = MaskedKronOpF32::from_op(&op);
        let mut rng = Pcg64::new(42);
        let v = rng.normal_vec(80);
        let mut exact = vec![0.0; 80];
        let mut approx = vec![0.0; 80];
        op.apply_batch(&v, &mut exact, 1);
        fast.apply_batch(&v, &mut approx, 1);
        // Storage rounding only: error scales with f32 eps times the
        // operator norm, far below f64 but far above zero.
        let scale = k1.fro_norm() * k2.fro_norm();
        for i in 0..80 {
            assert!(
                (exact[i] - approx[i]).abs() < 1e-4 * scale.max(1.0),
                "i={i}: {} vs {}",
                exact[i],
                approx[i]
            );
        }
        // And the sigma2 diagonal is applied in full precision: off-mask
        // rows are exactly sigma2 * v in both.
        for (i, &mk) in mask.data().iter().enumerate() {
            if mk == 0.0 {
                assert_eq!(exact[i].to_bits(), approx[i].to_bits(), "off-mask i={i}");
            }
        }
    }

    #[test]
    fn f32_batched_apply_bit_identical_across_threads() {
        let (k1, k2, mask) = setup(8, 6, 43);
        let op = MaskedKronOp::new(&k1, &k2, &mask, 0.1);
        let fast = MaskedKronOpF32::from_op(&op);
        let nm = 48;
        let batch = 5;
        let mut rng = Pcg64::new(44);
        let v = rng.normal_vec(batch * nm);
        let mut seq = vec![0.0; batch * nm];
        fast.apply_batch_with_threads(&v, &mut seq, batch, 1);
        for threads in [2, 3, 8] {
            let mut got = vec![0.0; batch * nm];
            fast.apply_batch_with_threads(&v, &mut got, batch, threads);
            assert_eq!(got, seq, "threads={threads}");
        }
    }

    #[test]
    fn solve_refined_reaches_f64_grade_residual() {
        let (k1, k2, mask) = setup(12, 9, 45);
        let s2 = 0.2;
        let op = MaskedKronOp::new(&k1, &k2, &mask, s2);
        let mut rng = Pcg64::new(46);
        let rhs: Vec<f64> = mask.data().iter().map(|&mk| mk * rng.normal()).collect();
        let tol = 1e-8;
        let (x, st) = op.solve_refined(&rhs, None, None, tol, 10_000);
        assert!(st.converged, "stats={st:?}");
        // residual measured against the exact operator
        let mut back = vec![0.0; rhs.len()];
        op.apply_batch(&x, &mut back, 1);
        let bn = crate::linalg::matrix::dot(&rhs, &rhs).sqrt();
        let rn = back
            .iter()
            .zip(&rhs)
            .map(|(a, b)| (b - a) * (b - a))
            .sum::<f64>()
            .sqrt();
        assert!(rn <= tol * 1.001 * bn, "rel={}", rn / bn);
        // and the solution matches the pure-f64 solve well beyond f32
        let (oracle, os) = op.solve_warm(&rhs, None, 1e-10, 10_000);
        assert!(os.converged);
        for (a, o) in x.iter().zip(&oracle) {
            assert!((a - o).abs() < 1e-6, "{a} vs {o}");
        }
    }

    #[test]
    fn solve_refined_with_precond_and_warm_start() {
        let (n, m) = (16, 10);
        let (k1, k2) = ill_system(n, m, 47);
        let mask = Matrix::from_fn(n, m, |_, _| 1.0);
        let s2 = 1e-3;
        let op = MaskedKronOp::new(&k1, &k2, &mask, s2);
        let mut rng = Pcg64::new(48);
        let rhs = rng.normal_vec(n * m);
        let theta = vec![0.0; 4];
        let f = PrecondFactors::build(PrecondCfg::Auto, &k1, &k2, &mask, &theta).unwrap();
        let tol = 1e-6;
        let (x, st) = op.solve_refined(&rhs, None, Some(&f), tol, 10_000);
        assert!(st.converged, "stats={st:?}");
        // warm re-solve from the converged answer: zero inner iterations
        let (x2, st2) = op.solve_refined(&rhs, Some(&x), Some(&f), tol, 10_000);
        assert!(st2.converged);
        assert_eq!(st2.inner_iters, 0, "stats={st2:?}");
        assert_eq!(x, x2, "already-converged warm start must be a no-op");
    }
}
