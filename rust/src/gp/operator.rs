//! The masked latent-Kronecker operator (the paper's core contribution).
//!
//! Implements `A v = M . (K1 (M . V) K2) + sigma2 * v` as a [`LinOp`]:
//! the full-space embedding of `P (K1 (x) K2) P^T + sigma2 I` where P
//! selects observed learning-curve entries. The Kronecker identity
//! `(A (x) B) vec(C) = vec(B C A^T)` turns the O(n^2 m^2) dense MVM into
//! two dense matmuls — O(n^2 m + n m^2) time, O(nm) space — and the mask
//! plays the role of the zero-pad / slice-index projections (paper §2).

use crate::linalg::{cg_batch, CgStats, LinOp, Matrix};

/// Masked Kronecker operator over the (n x m) learning-curve grid.
pub struct MaskedKronOp<'a> {
    /// (n, n) config kernel matrix.
    pub k1: &'a Matrix,
    /// (m, m) progression kernel matrix.
    pub k2: &'a Matrix,
    /// (n, m) observation mask in {0, 1}.
    pub mask: &'a Matrix,
    /// Noise variance added on the diagonal.
    pub sigma2: f64,
}

impl<'a> MaskedKronOp<'a> {
    pub fn new(k1: &'a Matrix, k2: &'a Matrix, mask: &'a Matrix, sigma2: f64) -> Self {
        assert_eq!(k1.rows(), k1.cols());
        assert_eq!(k2.rows(), k2.cols());
        assert_eq!(mask.rows(), k1.rows());
        assert_eq!(mask.cols(), k2.rows());
        MaskedKronOp { k1, k2, mask, sigma2 }
    }

    pub fn n(&self) -> usize {
        self.k1.rows()
    }

    pub fn m(&self) -> usize {
        self.k2.rows()
    }

    /// Apply to a single (n, m) matrix in-place-free form.
    pub fn apply_mat(&self, v: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(self.n(), self.m());
        let mut ws = Workspace::new(self.n(), self.m());
        self.apply_into(v.data(), out.data_mut(), &mut ws);
        out
    }

    /// Core kernel: out = M.(K1 (M.v) K2) + sigma2 v for one flattened v.
    fn apply_into(&self, v: &[f64], out: &mut [f64], ws: &mut Workspace) {
        let (n, m) = (self.n(), self.m());
        // mv = M . V
        for (dst, (a, b)) in ws.mv.data_mut().iter_mut().zip(v.iter().zip(self.mask.data())) {
            *dst = a * b;
        }
        // w = (M.V) K2   (n x m) (m x m)
        ws.mv.matmul_into(self.k2, &mut ws.w);
        // out_mat = K1 w  (n x n) (n x m)
        self.k1.matmul_into(&ws.w, &mut ws.out_mat);
        // epilogue: mask + sigma2 shift
        let om = ws.out_mat.data();
        let mk = self.mask.data();
        debug_assert_eq!(out.len(), n * m);
        for i in 0..n * m {
            out[i] = mk[i] * om[i] + self.sigma2 * v[i];
        }
    }

    /// Convenience: batched CG solve against this operator.
    pub fn solve(&self, rhs: &[f64], tol: f64, max_iters: usize) -> (Vec<f64>, CgStats) {
        cg_batch(self, rhs, tol, max_iters)
    }

    /// Batched CG solve warm-started from `x0` (same layout as `rhs`).
    /// Scheduler rounds re-solve near-identical masked systems every
    /// generation; starting from the previous solution instead of zero cuts
    /// iterations sharply (see benches/hotpath.rs).
    pub fn solve_warm(
        &self,
        rhs: &[f64],
        x0: Option<&[f64]>,
        tol: f64,
        max_iters: usize,
    ) -> (Vec<f64>, CgStats) {
        crate::linalg::cg_batch_warm(self, rhs, x0, tol, max_iters)
    }
}

/// Reusable buffers for one apply (avoids per-iteration allocation in CG).
struct Workspace {
    mv: Matrix,
    w: Matrix,
    out_mat: Matrix,
}

impl Workspace {
    fn new(n: usize, m: usize) -> Self {
        Workspace {
            mv: Matrix::zeros(n, m),
            w: Matrix::zeros(n, m),
            out_mat: Matrix::zeros(n, m),
        }
    }
}

impl MaskedKronOp<'_> {
    /// [`LinOp::apply_batch`] with an explicit worker-thread count
    /// (`apply_batch` resolves it from `util::num_threads`). Exposed so
    /// tests can pin the threaded split deterministically; results are
    /// bit-identical for every thread count.
    pub fn apply_batch_with_threads(&self, x: &[f64], out: &mut [f64], batch: usize, threads: usize) {
        let nm = self.len();
        debug_assert_eq!(x.len(), batch * nm);
        let threads = threads.min(batch.max(1));
        // Batched CG feeds 9-33 independent RHS per iteration; distributing
        // them across threads is the engine's main parallelism lever
        // (§Perf: 3.4x on the 17-RHS training solve at size 128).
        if threads <= 1 || batch <= 1 {
            let mut ws = Workspace::new(self.n(), self.m());
            for b in 0..batch {
                self.apply_into(&x[b * nm..(b + 1) * nm], &mut out[b * nm..(b + 1) * nm], &mut ws);
            }
            return;
        }
        let chunk = batch.div_ceil(threads);
        std::thread::scope(|scope| {
            for (ci, out_chunk) in out.chunks_mut(chunk * nm).enumerate() {
                let x_chunk = &x[ci * chunk * nm..(ci * chunk * nm + out_chunk.len())];
                scope.spawn(move || {
                    crate::linalg::matrix::without_nested_parallelism(|| {
                        let mut ws = Workspace::new(self.n(), self.m());
                        let local = out_chunk.len() / nm;
                        for b in 0..local {
                            self.apply_into(
                                &x_chunk[b * nm..(b + 1) * nm],
                                &mut out_chunk[b * nm..(b + 1) * nm],
                                &mut ws,
                            );
                        }
                    });
                });
            }
        });
    }
}

impl LinOp for MaskedKronOp<'_> {
    fn len(&self) -> usize {
        self.n() * self.m()
    }

    fn apply_batch(&self, x: &[f64], out: &mut [f64], batch: usize) {
        self.apply_batch_with_threads(x, out, batch, crate::util::num_threads());
    }
}

/// Dense materialization of the same operator (oracle for tests and the
/// naive engine's building block): diag(m) (K1 (x) K2) diag(m) + s2 I.
pub fn dense_masked_kron(k1: &Matrix, k2: &Matrix, mask: &Matrix, sigma2: f64) -> Matrix {
    let (n, m) = (k1.rows(), k2.rows());
    let nm = n * m;
    let mut out = Matrix::zeros(nm, nm);
    let mk = mask.data();
    for i1 in 0..n {
        for j1 in 0..m {
            let r = i1 * m + j1;
            for i2 in 0..n {
                for j2 in 0..m {
                    let c = i2 * m + j2;
                    out[(r, c)] = mk[r] * k1[(i1, i2)] * k2[(j1, j2)] * mk[c];
                }
            }
        }
    }
    out.add_diag(sigma2);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::kernels;
    use crate::rng::Pcg64;

    fn setup(n: usize, m: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
        let mut rng = Pcg64::new(seed);
        let x = Matrix::from_vec(n, 3, rng.uniform_vec(n * 3, 0.0, 1.0));
        let k1 = kernels::rbf(&x, &x, &[0.8, 1.1, 0.6]);
        let t: Vec<f64> = (0..m).map(|i| i as f64 / (m.max(2) - 1) as f64).collect();
        let k2 = kernels::matern12(&t, &t, 0.4, 1.3);
        let mask = Matrix::from_fn(n, m, |_, _| if rng.uniform() < 0.7 { 1.0 } else { 0.0 });
        (k1, k2, mask)
    }

    #[test]
    fn matches_dense_operator() {
        let (k1, k2, mask) = setup(6, 5, 1);
        let op = MaskedKronOp::new(&k1, &k2, &mask, 0.09);
        let dense = dense_masked_kron(&k1, &k2, &mask, 0.09);
        let mut rng = Pcg64::new(2);
        let v = rng.normal_vec(30);
        let mut got = vec![0.0; 30];
        op.apply_batch(&v, &mut got, 1);
        let want = dense.matvec(&v);
        for i in 0..30 {
            assert!((got[i] - want[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn batched_apply_matches_sequential() {
        let (k1, k2, mask) = setup(8, 7, 3);
        let op = MaskedKronOp::new(&k1, &k2, &mask, 0.2);
        let mut rng = Pcg64::new(4);
        let batch = 5;
        let v = rng.normal_vec(batch * 56);
        let mut got = vec![0.0; batch * 56];
        op.apply_batch(&v, &mut got, batch);
        for b in 0..batch {
            let mut one = vec![0.0; 56];
            op.apply_batch(&v[b * 56..(b + 1) * 56], &mut one, 1);
            assert_eq!(&got[b * 56..(b + 1) * 56], &one[..]);
        }
    }

    #[test]
    fn preserves_observed_subspace() {
        let (k1, k2, mask) = setup(7, 6, 5);
        let op = MaskedKronOp::new(&k1, &k2, &mask, 0.15);
        let mut rng = Pcg64::new(6);
        // observed-supported input
        let v: Vec<f64> = mask.data().iter().map(|&m| m * rng.normal()).collect();
        let mut out = vec![0.0; 42];
        op.apply_batch(&v, &mut out, 1);
        for (o, m) in out.iter().zip(mask.data()) {
            if *m == 0.0 {
                assert_eq!(*o, 0.0);
            }
        }
    }

    #[test]
    fn full_mask_is_plain_kronecker() {
        let (k1, k2, _) = setup(5, 4, 7);
        let mask = Matrix::from_fn(5, 4, |_, _| 1.0);
        let op = MaskedKronOp::new(&k1, &k2, &mask, 0.0);
        // (K1 x K2) vec(V) == K1 V K2 (row-major, symmetric K2)
        let mut rng = Pcg64::new(8);
        let v = Matrix::from_vec(5, 4, rng.normal_vec(20));
        let want = k1.matmul(&v).matmul(&k2);
        let got = op.apply_mat(&v);
        assert!(got.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn solve_restricted_equals_projected_system() {
        // CG on the full-space masked operator must equal the dense solve
        // of the projected (observed-only) system (paper's P K P^T).
        let (k1, k2, mask) = setup(6, 5, 9);
        let s2 = 0.3;
        let op = MaskedKronOp::new(&k1, &k2, &mask, s2);
        let mut rng = Pcg64::new(10);
        let rhs: Vec<f64> = mask.data().iter().map(|&m| m * rng.normal()).collect();
        let (x, stats) = op.solve(&rhs, 1e-12, 2000);
        assert!(stats.converged);

        // dense projected system
        let idx: Vec<usize> = mask
            .data()
            .iter()
            .enumerate()
            .filter(|(_, &m)| m > 0.0)
            .map(|(i, _)| i)
            .collect();
        let dense = dense_masked_kron(&k1, &k2, &mask, s2);
        let no = idx.len();
        let mut proj = Matrix::zeros(no, no);
        for (a, &ia) in idx.iter().enumerate() {
            for (b, &ib) in idx.iter().enumerate() {
                proj[(a, b)] = dense[(ia, ib)];
            }
        }
        let l = crate::linalg::cholesky(&proj).unwrap();
        let rhs_obs: Vec<f64> = idx.iter().map(|&i| rhs[i]).collect();
        let want = crate::linalg::chol_solve(&l, &rhs_obs);
        for (a, &ia) in idx.iter().enumerate() {
            assert!((x[ia] - want[a]).abs() < 1e-8, "obs {a}");
        }
        // missing entries stay exactly zero
        for (i, &m) in mask.data().iter().enumerate() {
            if m == 0.0 {
                assert_eq!(x[i], 0.0);
            }
        }
    }

    #[test]
    fn operator_is_symmetric() {
        let (k1, k2, mask) = setup(5, 6, 11);
        let op = MaskedKronOp::new(&k1, &k2, &mask, 0.05);
        let mut rng = Pcg64::new(12);
        let u = rng.normal_vec(30);
        let v = rng.normal_vec(30);
        let mut au = vec![0.0; 30];
        let mut av = vec![0.0; 30];
        op.apply_batch(&u, &mut au, 1);
        op.apply_batch(&v, &mut av, 1);
        let uav = crate::linalg::matrix::dot(&u, &av);
        let vau = crate::linalg::matrix::dot(&v, &au);
        assert!((uav - vau).abs() < 1e-9);
    }
}
