//! Kernel matrices and their parameter derivatives.
//!
//! The factor kernels of the latent Kronecker product (paper §2):
//! an ARD RBF over hyper-parameter configurations and a Matern-1/2
//! (exponential) over learning-curve progression, with the outputscale
//! attached to the progression factor (paper §B).
//!
//! Derivatives are taken w.r.t. *log* parameters (the unconstrained space
//! the trainers walk in), so dK/dlog ls = dK/dls * ls.

use crate::linalg::Matrix;

/// ARD RBF kernel matrix: k(x, x') = exp(-1/2 sum_k ((x_k - x'_k)/ls_k)^2).
pub fn rbf(x1: &Matrix, x2: &Matrix, lengthscales: &[f64]) -> Matrix {
    let (n1, d) = (x1.rows(), x1.cols());
    let n2 = x2.rows();
    assert_eq!(x2.cols(), d, "rbf dims mismatch");
    assert_eq!(lengthscales.len(), d, "rbf lengthscale count");
    let mut k = Matrix::zeros(n1, n2);
    for i in 0..n1 {
        let xi = x1.row(i);
        for j in 0..n2 {
            let xj = x2.row(j);
            let mut s = 0.0;
            for kk in 0..d {
                let z = (xi[kk] - xj[kk]) / lengthscales[kk];
                s += z * z;
            }
            k[(i, j)] = (-0.5 * s).exp();
        }
    }
    k
}

/// d RBF / d log ls_dim, given the kernel matrix (reuses K: dK = K .* z^2).
pub fn rbf_grad_log_ls(x1: &Matrix, x2: &Matrix, lengthscales: &[f64], k: &Matrix, dim: usize) -> Matrix {
    let (n1, n2) = (x1.rows(), x2.rows());
    let ls = lengthscales[dim];
    let mut dk = Matrix::zeros(n1, n2);
    for i in 0..n1 {
        for j in 0..n2 {
            let z = (x1[(i, dim)] - x2[(j, dim)]) / ls;
            // dk/dls = k * z^2 / ls; dk/dlog ls = k * z^2.
            dk[(i, j)] = k[(i, j)] * z * z;
        }
    }
    dk
}

/// Matern-1/2 kernel matrix: k(t, t') = os * exp(-|t - t'| / ls).
pub fn matern12(t1: &[f64], t2: &[f64], lengthscale: f64, outputscale: f64) -> Matrix {
    let (m1, m2) = (t1.len(), t2.len());
    let mut k = Matrix::zeros(m1, m2);
    for i in 0..m1 {
        for j in 0..m2 {
            k[(i, j)] = outputscale * (-(t1[i] - t2[j]).abs() / lengthscale).exp();
        }
    }
    k
}

/// d Matern12 / d log ls = K .* (|dt| / ls).
pub fn matern12_grad_log_ls(t1: &[f64], t2: &[f64], lengthscale: f64, k: &Matrix) -> Matrix {
    let (m1, m2) = (t1.len(), t2.len());
    let mut dk = Matrix::zeros(m1, m2);
    for i in 0..m1 {
        for j in 0..m2 {
            dk[(i, j)] = k[(i, j)] * (t1[i] - t2[j]).abs() / lengthscale;
        }
    }
    dk
}

// d Matern12 / d log outputscale = K itself (no helper needed).

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn fd_check(dim: usize) {
        let mut rng = Pcg64::new(dim as u64 + 1);
        let (n, d) = (7, 3);
        let x = Matrix::from_vec(n, d, rng.uniform_vec(n * d, 0.0, 1.0));
        let ls = vec![0.7, 1.3, 0.4];
        let k = rbf(&x, &x, &ls);
        let dk = rbf_grad_log_ls(&x, &x, &ls, &k, dim);
        let h = 1e-6f64;
        let mut ls_p = ls.clone();
        let mut ls_m = ls.clone();
        ls_p[dim] *= (h as f64).exp();
        ls_m[dim] *= (-h as f64).exp();
        let kp = rbf(&x, &x, &ls_p);
        let km = rbf(&x, &x, &ls_m);
        for i in 0..n {
            for j in 0..n {
                let fd = (kp[(i, j)] - km[(i, j)]) / (2.0 * h);
                assert!(
                    (dk[(i, j)] - fd).abs() < 1e-6,
                    "dim={dim} i={i} j={j} dk={} fd={fd}",
                    dk[(i, j)]
                );
            }
        }
    }

    #[test]
    fn rbf_diag_is_one() {
        let mut rng = Pcg64::new(0);
        let x = Matrix::from_vec(5, 4, rng.normal_vec(20));
        let k = rbf(&x, &x, &[1.0, 2.0, 0.5, 1.5]);
        for i in 0..5 {
            assert!((k[(i, i)] - 1.0).abs() < 1e-14);
        }
    }

    #[test]
    fn rbf_symmetric_and_bounded() {
        let mut rng = Pcg64::new(1);
        let x = Matrix::from_vec(8, 3, rng.normal_vec(24));
        let k = rbf(&x, &x, &[1.0, 1.0, 1.0]);
        for i in 0..8 {
            for j in 0..8 {
                assert!((k[(i, j)] - k[(j, i)]).abs() < 1e-15);
                assert!(k[(i, j)] > 0.0 && k[(i, j)] <= 1.0);
            }
        }
    }

    #[test]
    fn rbf_grad_matches_fd_all_dims() {
        for dim in 0..3 {
            fd_check(dim);
        }
    }

    #[test]
    fn matern_matches_closed_form() {
        let t = [0.0, 0.5, 1.0];
        let k = matern12(&t, &t, 0.5, 2.0);
        assert!((k[(0, 0)] - 2.0).abs() < 1e-14);
        assert!((k[(0, 1)] - 2.0 * (-1.0f64).exp()).abs() < 1e-14);
        assert!((k[(0, 2)] - 2.0 * (-2.0f64).exp()).abs() < 1e-14);
    }

    #[test]
    fn matern_grad_matches_fd() {
        let mut rng = Pcg64::new(2);
        let t: Vec<f64> = (0..9).map(|_| rng.uniform()).collect();
        let (ls, os) = (0.37f64, 1.42);
        let k = matern12(&t, &t, ls, os);
        let dk = matern12_grad_log_ls(&t, &t, ls, &k);
        let h = 1e-6f64;
        let kp = matern12(&t, &t, ls * h.exp(), os);
        let km = matern12(&t, &t, ls * (-h).exp(), os);
        for i in 0..9 {
            for j in 0..9 {
                let fd = (kp[(i, j)] - km[(i, j)]) / (2.0 * h);
                assert!((dk[(i, j)] - fd).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn kernels_match_python_reference_values() {
        // Golden values computed with python/compile/kernels/ref.py.
        let x = Matrix::from_vec(2, 2, vec![0.1, 0.2, 0.4, 0.9]);
        let k = rbf(&x, &x, &[0.5, 1.0]);
        let want01 = (-0.5f64 * ((0.3f64 / 0.5).powi(2) + 0.7f64.powi(2))).exp();
        assert!((k[(0, 1)] - want01).abs() < 1e-12);
        let k2 = matern12(&[0.0, 1.0], &[0.0, 1.0], 0.25, 3.0);
        assert!((k2[(0, 1)] - 3.0 * (-4.0f64).exp()).abs() < 1e-12);
    }
}
