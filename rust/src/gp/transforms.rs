//! Input/output transforms (paper §B).
//!
//! * hyper-parameters x -> unit hypercube (per-dimension min/max from the
//!   training configs)
//! * progression t -> log-spaced unit interval: (log t - log t_1) /
//!   (log t_m - log t_1)
//! * outputs Y -> subtract max over observed values, divide by their std
//!
//! The transforms are fit on training data and applied consistently at
//! prediction time; `YTransform::undo_*` maps predictions and variances
//! back to original units (needed for the paper's MSE/LLH metrics).

use crate::linalg::Matrix;

/// Per-dimension min/max normalizer to the unit hypercube.
#[derive(Clone, Debug)]
pub struct XTransform {
    pub lo: Vec<f64>,
    pub hi: Vec<f64>,
}

impl XTransform {
    /// Fit on training configs (rows = configs).
    pub fn fit(x: &Matrix) -> Self {
        let d = x.cols();
        let mut lo = vec![f64::INFINITY; d];
        let mut hi = vec![f64::NEG_INFINITY; d];
        for i in 0..x.rows() {
            for j in 0..d {
                lo[j] = lo[j].min(x[(i, j)]);
                hi[j] = hi[j].max(x[(i, j)]);
            }
        }
        XTransform { lo, hi }
    }

    /// Apply: constant dimensions map to 0.5 (paper normalizes by range;
    /// zero range would divide by zero).
    pub fn apply(&self, x: &Matrix) -> Matrix {
        let (n, d) = (x.rows(), x.cols());
        assert_eq!(d, self.lo.len());
        let mut out = Matrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                let range = self.hi[j] - self.lo[j];
                out[(i, j)] = if range > 0.0 {
                    ((x[(i, j)] - self.lo[j]) / range).clamp(-1.0, 2.0)
                } else {
                    0.5
                };
            }
        }
        out
    }
}

/// Progression transform: log-spaced unit interval.
#[derive(Clone, Debug)]
pub struct TTransform {
    log_t1: f64,
    log_tm: f64,
}

impl TTransform {
    /// Fit on the epoch grid (t must be positive and increasing).
    pub fn fit(t: &[f64]) -> Self {
        assert!(!t.is_empty());
        assert!(t[0] > 0.0, "progression grid must be positive");
        TTransform {
            log_t1: t[0].ln(),
            log_tm: t[t.len() - 1].ln(),
        }
    }

    /// Apply to a grid.
    pub fn apply(&self, t: &[f64]) -> Vec<f64> {
        let denom = (self.log_tm - self.log_t1).max(1e-12);
        t.iter().map(|&v| (v.ln() - self.log_t1) / denom).collect()
    }
}

/// Output standardization: y' = (y - max) / std over observed entries.
#[derive(Clone, Debug)]
pub struct YTransform {
    pub max: f64,
    pub std: f64,
}

impl YTransform {
    /// Fit over observed entries only (mask > 0).
    pub fn fit(y: &Matrix, mask: &Matrix) -> Self {
        let mut count = 0.0;
        let mut sum = 0.0;
        let mut max = f64::NEG_INFINITY;
        for (v, m) in y.data().iter().zip(mask.data()) {
            if *m > 0.0 {
                count += 1.0;
                sum += v;
                max = max.max(*v);
            }
        }
        let mean = if count > 0.0 { sum / count } else { 0.0 };
        let mut var = 0.0;
        for (v, m) in y.data().iter().zip(mask.data()) {
            if *m > 0.0 {
                var += (v - mean) * (v - mean);
            }
        }
        let std = if count > 1.0 {
            (var / count).sqrt().max(1e-12)
        } else {
            1.0
        };
        YTransform {
            max: if max.is_finite() { max } else { 0.0 },
            std,
        }
    }

    /// Standardize (missing entries forced to exactly 0 so they're inert
    /// in the masked operator).
    pub fn apply(&self, y: &Matrix, mask: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(y.rows(), y.cols());
        for i in 0..y.rows() {
            for j in 0..y.cols() {
                out[(i, j)] = if mask[(i, j)] > 0.0 {
                    (y[(i, j)] - self.max) / self.std
                } else {
                    0.0
                };
            }
        }
        out
    }

    /// Map a standardized prediction back to original units.
    pub fn undo_mean(&self, v: f64) -> f64 {
        v * self.std + self.max
    }

    /// Map a standardized variance back to original units.
    pub fn undo_var(&self, v: f64) -> f64 {
        v * self.std * self.std
    }

    /// Log-likelihood correction: log p_orig(y) = log p_std(y') - log std.
    pub fn llh_correction(&self) -> f64 {
        -self.std.ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn x_maps_to_unit_cube() {
        let x = Matrix::from_vec(3, 2, vec![1.0, 10.0, 3.0, 20.0, 2.0, 15.0]);
        let tf = XTransform::fit(&x);
        let z = tf.apply(&x);
        assert_eq!(z[(0, 0)], 0.0);
        assert_eq!(z[(1, 0)], 1.0);
        assert_eq!(z[(2, 0)], 0.5);
        assert_eq!(z[(0, 1)], 0.0);
        assert_eq!(z[(1, 1)], 1.0);
    }

    #[test]
    fn x_constant_dim_maps_to_half() {
        let x = Matrix::from_vec(2, 1, vec![5.0, 5.0]);
        let tf = XTransform::fit(&x);
        let z = tf.apply(&x);
        assert_eq!(z[(0, 0)], 0.5);
        assert_eq!(z[(1, 0)], 0.5);
    }

    #[test]
    fn t_log_spacing() {
        let t: Vec<f64> = (1..=52).map(|v| v as f64).collect();
        let tf = TTransform::fit(&t);
        let z = tf.apply(&t);
        assert_eq!(z[0], 0.0);
        assert!((z[51] - 1.0).abs() < 1e-14);
        // log spacing: early epochs spread wider than late ones
        assert!(z[1] - z[0] > z[51] - z[50]);
    }

    #[test]
    fn y_standardization_properties() {
        let y = Matrix::from_vec(2, 3, vec![0.5, 0.7, 0.9, 0.2, 0.4, 0.0]);
        let mask = Matrix::from_vec(2, 3, vec![1.0, 1.0, 1.0, 1.0, 1.0, 0.0]);
        let tf = YTransform::fit(&y, &mask);
        let z = tf.apply(&y, &mask);
        // max maps to 0, everything else negative
        let mut max_seen = f64::NEG_INFINITY;
        for (v, m) in z.data().iter().zip(mask.data()) {
            if *m > 0.0 {
                max_seen = max_seen.max(*v);
                assert!(*v <= 1e-12);
            }
        }
        assert!(max_seen.abs() < 1e-12);
        // masked entry exactly zero
        assert_eq!(z[(1, 2)], 0.0);
        // roundtrip
        assert!((tf.undo_mean(z[(0, 1)]) - 0.7).abs() < 1e-12);
    }

    #[test]
    fn y_degenerate_single_observation() {
        let y = Matrix::from_vec(1, 2, vec![0.3, 0.0]);
        let mask = Matrix::from_vec(1, 2, vec![1.0, 0.0]);
        let tf = YTransform::fit(&y, &mask);
        let z = tf.apply(&y, &mask);
        assert!(z[(0, 0)].is_finite());
    }

    #[test]
    fn llh_correction_is_neg_log_std() {
        let y = Matrix::from_vec(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        let mask = Matrix::from_vec(1, 4, vec![1.0; 4]);
        let tf = YTransform::fit(&y, &mask);
        assert!((tf.llh_correction() + tf.std.ln()).abs() < 1e-14);
    }
}
