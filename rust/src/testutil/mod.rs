//! Lightweight property-testing helper (proptest is not in the offline
//! crate set).
//!
//! [`property`] runs a closure over `cases` deterministic random seeds; on
//! failure it reports the failing seed so the case can be replayed as a
//! unit test. Generators are plain functions over [`crate::rng::Pcg64`].

use crate::rng::Pcg64;

/// Run `f` over `cases` seeded RNGs; panic with the failing seed.
///
/// ```ignore
/// property(100, |rng| {
///     let n = 1 + rng.below(20);
///     assert!(my_invariant(n));
/// });
/// ```
pub fn property(cases: u64, mut f: impl FnMut(&mut Pcg64)) {
    for seed in 0..cases {
        let mut rng = Pcg64::new(0x5eed_0000 + seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "panic".to_string());
            panic!("property failed at seed {seed}: {msg}");
        }
    }
}

/// Uniform usize in [lo, hi].
pub fn gen_usize(rng: &mut Pcg64, lo: usize, hi: usize) -> usize {
    lo + rng.below(hi - lo + 1)
}

/// Random prefix-observation mask (early-stopping pattern): each row
/// observes a prefix of length in [min_len, m].
pub fn gen_prefix_mask(rng: &mut Pcg64, n: usize, m: usize, min_len: usize) -> crate::linalg::Matrix {
    let mut mask = crate::linalg::Matrix::zeros(n, m);
    for i in 0..n {
        let len = gen_usize(rng, min_len.min(m), m);
        for j in 0..len {
            mask[(i, j)] = 1.0;
        }
    }
    mask
}

/// Random SPD matrix with controlled conditioning.
pub fn gen_spd(rng: &mut Pcg64, n: usize, diag_boost: f64) -> crate::linalg::Matrix {
    let a = crate::linalg::Matrix::from_vec(n, n, rng.normal_vec(n * n));
    let mut spd = a.matmul(&a.transpose());
    spd.add_diag(diag_boost * n as f64);
    spd
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn property_runs_all_cases() {
        let mut count = 0;
        property(25, |_| {
            count += 1;
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property failed at seed")]
    fn property_reports_seed() {
        property(10, |rng| {
            assert!(rng.uniform() < 2.0); // always true
            assert!(gen_usize(rng, 0, 5) != 3); // eventually false
        });
    }

    #[test]
    fn prefix_mask_is_prefix() {
        property(20, |rng| {
            let n = gen_usize(rng, 1, 10);
            let m = gen_usize(rng, 2, 12);
            let mask = gen_prefix_mask(rng, n, m, 1);
            for i in 0..n {
                let mut seen_zero = false;
                for j in 0..m {
                    if mask[(i, j)] == 0.0 {
                        seen_zero = true;
                    } else {
                        assert!(!seen_zero, "non-prefix mask");
                    }
                }
            }
        });
    }

    #[test]
    fn spd_is_spd() {
        property(10, |rng| {
            let n = gen_usize(rng, 1, 15);
            let spd = gen_spd(rng, n, 1.0);
            assert!(crate::linalg::cholesky(&spd).is_ok());
        });
    }
}
