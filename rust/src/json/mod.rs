//! Minimal JSON parser/serializer.
//!
//! serde is not in the offline crate set, so this module provides the small
//! JSON surface the library needs: parsing `artifacts/manifest.json` and
//! (de)serializing experiment results. It supports the full JSON grammar
//! minus exotic number forms; strings handle the standard escapes.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value tree.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    /// Serialize with 1-space indentation (stable, diff-friendly).
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    /// Serialize compactly.
    pub fn compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, depth: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                // lint: allow(float_eq) — fract()==0.0 is the exact
                // integer-valued test: print `3` not `3.0`; any rounding
                // noise correctly falls through to the float formatter.
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&" ".repeat(depth + 1));
                    }
                    item.write(out, depth + 1, pretty);
                }
                if pretty && !items.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(depth));
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&" ".repeat(depth + 1));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, depth + 1, pretty);
                }
                if pretty && !map.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(depth));
                }
                out.push('}');
            }
        }
    }

    // ----- typed accessors -----

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    // ----- builders -----

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse failure with byte offset context.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are not needed for our documents.
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // UTF-8 passthrough: copy the whole code point.
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.bytes.len() && (self.bytes[self.pos] & 0xC0) == 0x80 {
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-12").unwrap(), Json::Num(-12.0));
        assert_eq!(Json::parse("1e-3").unwrap(), Json::Num(1e-3));
        assert_eq!(
            Json::parse("\"hi\\nthere\"").unwrap(),
            Json::Str("hi\nthere".into())
        );
    }

    #[test]
    fn parse_nested() {
        let doc = r#"{"a": [1, 2, {"b": "c"}], "d": null, "e": {"f": true}}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
        assert_eq!(v.get("e").unwrap().get("f").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let doc = r#"{"x": [1.5, -2, true, "s\"q"], "y": {"z": []}}"#;
        let v = Json::parse(doc).unwrap();
        let v2 = Json::parse(&v.pretty()).unwrap();
        let v3 = Json::parse(&v.compact()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v, v3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn parses_real_manifest_shape() {
        let doc = r#"{"format": 1, "dtype": "f64", "artifacts": [
            {"entry": "mvm", "file": "mvm_n16_m16_d3.hlo.txt", "n": 16,
             "m": 16, "d": 3, "inputs": [{"name": "theta", "shape": [6]}],
             "outputs": ["out"]}]}"#;
        let v = Json::parse(doc).unwrap();
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("n").unwrap().as_usize(), Some(16));
        assert_eq!(
            arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
                .get("shape")
                .unwrap()
                .as_arr()
                .unwrap()[0]
                .as_usize(),
            Some(6)
        );
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo → ∞\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → ∞"));
    }
}
