//! Library-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls: `thiserror` is not in the offline
//! crate set.

/// Result alias used across the crate.
pub type Result<T> = std::result::Result<T, LkgpError>;

/// Errors surfaced by the LKGP library.
#[derive(Debug)]
pub enum LkgpError {
    /// Shape mismatch in a linear-algebra or engine call.
    Shape(String),

    /// Matrix not positive definite during factorization.
    NotPd { index: usize, value: f64 },

    /// No AOT artifact bucket can hold the requested problem.
    NoBucket { n: usize, m: usize, d: usize },

    /// Artifact manifest missing or malformed.
    Manifest(String),

    /// PJRT/XLA runtime failure.
    Xla(String),

    /// Coordinator protocol violation (e.g. observation for unknown trial).
    Coordinator(String),

    /// I/O failure.
    Io(std::io::Error),

    /// JSON parse failure.
    Json(crate::json::JsonError),

    /// Iterative solver failed even after the escalation ladder was
    /// exhausted (docs/robustness.md). Carries the terminal health and
    /// how many rungs were attempted so callers can log root cause.
    Solver {
        /// Human-readable terminal solve health (e.g. "max_iters",
        /// "non_finite", "breakdown").
        health: String,
        /// Number of escalation rungs attempted before giving up.
        rungs: usize,
        /// Worst relative residual observed on the final attempt.
        rel_residual: f64,
    },

    /// Request deadline expired before (or while) the work was served.
    Timeout {
        /// Shard the request was bound for.
        shard: usize,
        /// How far past the deadline the request was when dropped, in
        /// microseconds (0 if shed at submit time).
        late_micros: u64,
    },

    /// The in-tree static analyzer (`lkgp lint`, docs/static_analysis.md)
    /// found invariant violations with no justifying pragma.
    Lint {
        /// Number of unjustified findings.
        findings: usize,
    },

    /// Shard is quarantined by the circuit breaker; fail-fast reply.
    Quarantined {
        /// The quarantined shard.
        shard: usize,
        /// Consecutive failures that tripped the breaker.
        failures: u32,
        /// Remaining cool-down at reply time, in milliseconds.
        cooldown_ms: u64,
    },
}

impl std::fmt::Display for LkgpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LkgpError::Shape(msg) => write!(f, "shape error: {msg}"),
            LkgpError::NotPd { index, value } => write!(
                f,
                "matrix not positive definite at pivot {index} (value {value})"
            ),
            LkgpError::NoBucket { n, m, d } => write!(
                f,
                "no artifact bucket fits problem (n={n}, m={m}, d={d}); \
                 rebuild artifacts or use the rust engine"
            ),
            LkgpError::Manifest(msg) => write!(f, "manifest error: {msg}"),
            LkgpError::Xla(msg) => write!(f, "xla runtime error: {msg}"),
            LkgpError::Coordinator(msg) => write!(f, "coordinator error: {msg}"),
            LkgpError::Io(e) => write!(f, "io error: {e}"),
            LkgpError::Json(e) => write!(f, "{e}"),
            LkgpError::Solver {
                health,
                rungs,
                rel_residual,
            } => write!(
                f,
                "solver failed ({health}) after {rungs} escalation rung(s); \
                 worst rel residual {rel_residual:.3e}"
            ),
            LkgpError::Timeout { shard, late_micros } => write!(
                f,
                "request deadline expired on shard {shard} ({late_micros}us late)"
            ),
            LkgpError::Lint { findings } => write!(
                f,
                "lint failed: {findings} unjustified finding(s) \
                 (see docs/static_analysis.md for the rule catalog and pragma syntax)"
            ),
            LkgpError::Quarantined {
                shard,
                failures,
                cooldown_ms,
            } => write!(
                f,
                "shard {shard} quarantined after {failures} consecutive failure(s); \
                 retry after ~{cooldown_ms}ms"
            ),
        }
    }
}

impl std::error::Error for LkgpError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            LkgpError::Io(e) => Some(e),
            LkgpError::Json(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for LkgpError {
    fn from(e: std::io::Error) -> Self {
        LkgpError::Io(e)
    }
}

impl From<crate::json::JsonError> for LkgpError {
    fn from(e: crate::json::JsonError) -> Self {
        LkgpError::Json(e)
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for LkgpError {
    fn from(e: xla::Error) -> Self {
        LkgpError::Xla(e.to_string())
    }
}
