//! Library-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls: `thiserror` is not in the offline
//! crate set.

/// Result alias used across the crate.
pub type Result<T> = std::result::Result<T, LkgpError>;

/// Errors surfaced by the LKGP library.
#[derive(Debug)]
pub enum LkgpError {
    /// Shape mismatch in a linear-algebra or engine call.
    Shape(String),

    /// Matrix not positive definite during factorization.
    NotPd { index: usize, value: f64 },

    /// No AOT artifact bucket can hold the requested problem.
    NoBucket { n: usize, m: usize, d: usize },

    /// Artifact manifest missing or malformed.
    Manifest(String),

    /// PJRT/XLA runtime failure.
    Xla(String),

    /// Coordinator protocol violation (e.g. observation for unknown trial).
    Coordinator(String),

    /// I/O failure.
    Io(std::io::Error),

    /// JSON parse failure.
    Json(crate::json::JsonError),
}

impl std::fmt::Display for LkgpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LkgpError::Shape(msg) => write!(f, "shape error: {msg}"),
            LkgpError::NotPd { index, value } => write!(
                f,
                "matrix not positive definite at pivot {index} (value {value})"
            ),
            LkgpError::NoBucket { n, m, d } => write!(
                f,
                "no artifact bucket fits problem (n={n}, m={m}, d={d}); \
                 rebuild artifacts or use the rust engine"
            ),
            LkgpError::Manifest(msg) => write!(f, "manifest error: {msg}"),
            LkgpError::Xla(msg) => write!(f, "xla runtime error: {msg}"),
            LkgpError::Coordinator(msg) => write!(f, "coordinator error: {msg}"),
            LkgpError::Io(e) => write!(f, "io error: {e}"),
            LkgpError::Json(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for LkgpError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            LkgpError::Io(e) => Some(e),
            LkgpError::Json(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for LkgpError {
    fn from(e: std::io::Error) -> Self {
        LkgpError::Io(e)
    }
}

impl From<crate::json::JsonError> for LkgpError {
    fn from(e: crate::json::JsonError) -> Self {
        LkgpError::Json(e)
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for LkgpError {
    fn from(e: xla::Error) -> Self {
        LkgpError::Xla(e.to_string())
    }
}
