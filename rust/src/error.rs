//! Library-wide error type.

/// Result alias used across the crate.
pub type Result<T> = std::result::Result<T, LkgpError>;

/// Errors surfaced by the LKGP library.
#[derive(Debug, thiserror::Error)]
pub enum LkgpError {
    /// Shape mismatch in a linear-algebra or engine call.
    #[error("shape error: {0}")]
    Shape(String),

    /// Matrix not positive definite during factorization.
    #[error("matrix not positive definite at pivot {index} (value {value})")]
    NotPd { index: usize, value: f64 },

    /// No AOT artifact bucket can hold the requested problem.
    #[error("no artifact bucket fits problem (n={n}, m={m}, d={d}); rebuild artifacts or use the rust engine")]
    NoBucket { n: usize, m: usize, d: usize },

    /// Artifact manifest missing or malformed.
    #[error("manifest error: {0}")]
    Manifest(String),

    /// PJRT/XLA runtime failure.
    #[error("xla runtime error: {0}")]
    Xla(String),

    /// Coordinator protocol violation (e.g. observation for unknown trial).
    #[error("coordinator error: {0}")]
    Coordinator(String),

    /// I/O failure.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    /// JSON parse failure.
    #[error(transparent)]
    Json(#[from] crate::json::JsonError),
}

impl From<xla::Error> for LkgpError {
    fn from(e: xla::Error) -> Self {
        LkgpError::Xla(e.to_string())
    }
}
