//! Baseline final-value predictors for the Figure-4 comparison.
//!
//! The paper compares LKGP against DPL (power-law ensemble), DyHPO
//! (deep-kernel GP), FT-PFN (pretrained Transformer) and FT-PFN (no HPs).
//! FT-PFN cannot be re-pretrained offline (14.69M params, millions of
//! synthetic curves); per DESIGN.md §Substitutions we populate the
//! comparison axes with from-scratch stand-ins:
//!
//! * [`PowerLawEnsemble`] — DPL-like: per-curve power-law fits, ensembled
//!   over random restarts + bootstrap, predictive moments from the
//!   ensemble spread.
//! * [`PerCurveGp`] — conditional-independence GP (Swersky-style; plays
//!   the "no cross-config correlation" role of FT-PFN (no HPs) / DyHPO's
//!   curve-local behaviour): an exact Matern-1/2 GP per curve over t only.
//! * [`LastValue`] — carry-forward with a random-walk variance, the
//!   canonical sanity baseline.
//!
//! All baselines consume raw (untransformed) prefixes and predict the
//! final-epoch value in original units, like the LKGP pipeline does after
//! undoing its transforms.

use crate::linalg::{self, Matrix};
use crate::rng::Pcg64;

/// A predictor of final learning-curve values from observed prefixes.
pub trait FinalPredictor {
    /// `curves` is (k, m) raw values with `lengths[i]` observed entries per
    /// row; `epochs` the raw grid. Returns (mean, var) per curve.
    fn predict(
        &mut self,
        curves: &Matrix,
        lengths: &[usize],
        epochs: &[f64],
    ) -> Vec<(f64, f64)>;

    fn name(&self) -> &'static str;
}

// ---------------------------------------------------------------------------
// Last value

/// Carry the last observation forward; variance from a random-walk model
/// on the observed increments.
pub struct LastValue;

impl FinalPredictor for LastValue {
    fn predict(&mut self, curves: &Matrix, lengths: &[usize], epochs: &[f64]) -> Vec<(f64, f64)> {
        let m = epochs.len();
        lengths
            .iter()
            .enumerate()
            .map(|(i, &len)| {
                let len = len.max(1).min(m);
                let last = curves[(i, len - 1)];
                // increment variance over the prefix
                let mut iv = 0.0;
                for j in 1..len {
                    let d = curves[(i, j)] - curves[(i, j - 1)];
                    iv += d * d;
                }
                let iv = if len > 1 { iv / (len - 1) as f64 } else { 1e-4 };
                let remaining = (m - len) as f64;
                (last, (iv * remaining).max(1e-6))
            })
            .collect()
    }

    fn name(&self) -> &'static str {
        "last_value"
    }
}

// ---------------------------------------------------------------------------
// Power-law ensemble (DPL-like)

/// Fit `y(t) = a - b * t^(-c)` per curve by Gauss-Newton over random
/// restarts and bootstrap subsamples; predict with ensemble moments.
pub struct PowerLawEnsemble {
    pub members: usize,
    pub seed: u64,
}

impl Default for PowerLawEnsemble {
    fn default() -> Self {
        PowerLawEnsemble { members: 8, seed: 0 }
    }
}

/// One power-law fit on (t, y) pairs; returns (a, b, c).
fn fit_power_law(ts: &[f64], ys: &[f64], init: (f64, f64, f64)) -> (f64, f64, f64) {
    // parameters: a, log b, log c for positivity of b, c
    let (mut a, mut lb, mut lc) = (init.0, init.1.max(1e-9).ln(), init.2.clamp(0.05, 5.0).ln());
    let n = ts.len();
    let mut lambda = 1e-3f64; // Levenberg damping
    let mut last_sse = f64::INFINITY;
    for _ in 0..60 {
        let (b, c) = (lb.exp(), lc.exp());
        // residuals + Jacobian (3 cols)
        let mut jtj = [[0.0f64; 3]; 3];
        let mut jtr = [0.0f64; 3];
        let mut sse = 0.0;
        for i in 0..n {
            let tc = ts[i].powf(-c);
            let pred = a - b * tc;
            let r = ys[i] - pred;
            sse += r * r;
            // d pred / d a = 1; d/d lb = -b t^-c; d/d lc = b c ln(t) t^-c
            let j = [1.0, -b * tc, b * c * ts[i].ln() * tc];
            for p in 0..3 {
                jtr[p] += j[p] * r;
                for q in 0..3 {
                    jtj[p][q] += j[p] * j[q];
                }
            }
        }
        if sse > last_sse {
            lambda *= 4.0;
        } else {
            lambda = (lambda * 0.5).max(1e-9);
            last_sse = sse;
        }
        // solve (JtJ + lambda I) d = Jtr (3x3)
        let mut mtx = Matrix::zeros(3, 3);
        for p in 0..3 {
            for q in 0..3 {
                mtx[(p, q)] = jtj[p][q];
            }
            mtx[(p, p)] += lambda + 1e-10;
        }
        let Ok(l) = linalg::cholesky(&mtx) else { break };
        let step = linalg::chol_solve(&l, &jtr);
        a += step[0];
        lb = (lb + step[1]).clamp(-12.0, 4.0);
        lc = (lc + step[2]).clamp(-3.0, 2.0);
        if step.iter().map(|s| s.abs()).fold(0.0, f64::max) < 1e-10 {
            break;
        }
    }
    (a, lb.exp(), lc.exp())
}

impl FinalPredictor for PowerLawEnsemble {
    fn predict(&mut self, curves: &Matrix, lengths: &[usize], epochs: &[f64]) -> Vec<(f64, f64)> {
        let m = epochs.len();
        let mut rng = Pcg64::new(self.seed);
        let t_final = epochs[m - 1];
        lengths
            .iter()
            .enumerate()
            .map(|(i, &len)| {
                let len = len.max(1).min(m);
                if len < 3 {
                    // not enough points for a 3-parameter fit: carry last
                    // value with a wide random-walk variance
                    let last = curves[(i, len - 1)];
                    return (last, 0.01 * (m - len) as f64 + 1e-4);
                }
                let ts: Vec<f64> = epochs[..len].to_vec();
                let ys: Vec<f64> = (0..len).map(|j| curves[(i, j)]).collect();
                let last = ys[len - 1];
                let mut preds = Vec::with_capacity(self.members);
                for _ in 0..self.members {
                    // bootstrap subsample (keep at least 3 points, always
                    // include the last point — it anchors the asymptote)
                    let keep: Vec<usize> = (0..len)
                        .filter(|&j| j + 1 == len || rng.uniform() < 0.8)
                        .collect();
                    let tsb: Vec<f64> = keep.iter().map(|&j| ts[j]).collect();
                    let ysb: Vec<f64> = keep.iter().map(|&j| ys[j]).collect();
                    let init = (
                        last + rng.uniform_in(0.0, 0.1),
                        (last - ys[0]).abs().max(0.01) * rng.uniform_in(0.5, 2.0),
                        rng.uniform_in(0.3, 1.5),
                    );
                    let (a, b, c) = fit_power_law(&tsb, &ysb, init);
                    let p = a - b * t_final.powf(-c);
                    // keep sane: clamp to a broad band around observations
                    preds.push(p.clamp(ys[0] - 0.5, 1.2));
                }
                let (mean, _) = crate::metrics::mean_stderr(&preds);
                let var = preds.iter().map(|p| (p - mean) * (p - mean)).sum::<f64>()
                    / (preds.len() - 1).max(1) as f64;
                (mean, (var + 1e-6).max(1e-6))
            })
            .collect()
    }

    fn name(&self) -> &'static str {
        "power_law"
    }
}

// ---------------------------------------------------------------------------
// Per-curve GP (conditional independence across configs)

/// Exact Matern-1/2 GP per curve over progression only. Hyper-parameters
/// (lengthscale, outputscale, noise) are chosen per curve by grid search
/// on the exact marginal likelihood (m <= 52, Cholesky is trivial).
pub struct PerCurveGp {
    /// Grid sizes for (lengthscale, outputscale, noise).
    pub grid: usize,
}

impl Default for PerCurveGp {
    fn default() -> Self {
        PerCurveGp { grid: 5 }
    }
}

impl FinalPredictor for PerCurveGp {
    fn predict(&mut self, curves: &Matrix, lengths: &[usize], epochs: &[f64]) -> Vec<(f64, f64)> {
        let m = epochs.len();
        // log-normalized grid like the main model
        let lt: Vec<f64> = epochs.iter().map(|e| e.ln()).collect();
        let denom = (lt[m - 1] - lt[0]).max(1e-12);
        let tn: Vec<f64> = lt.iter().map(|v| (v - lt[0]) / denom).collect();

        lengths
            .iter()
            .enumerate()
            .map(|(i, &len)| {
                let len = len.max(1).min(m);
                if len == 1 {
                    return (curves[(i, 0)], 0.05);
                }
                let ys_raw: Vec<f64> = (0..len).map(|j| curves[(i, j)]).collect();
                let mean_y = ys_raw.iter().sum::<f64>() / len as f64;
                let ys: Vec<f64> = ys_raw.iter().map(|v| v - mean_y).collect();
                let ts = &tn[..len];

                let mut best = (f64::NEG_INFINITY, 0.3, 0.1, 1e-3);
                for li in 0..self.grid {
                    let ls = 0.05 * 4f64.powf(li as f64 / (self.grid - 1).max(1) as f64 * 2.0);
                    for oi in 0..self.grid {
                        let os = 0.003 * 10f64.powf(oi as f64 / (self.grid - 1).max(1) as f64 * 2.5);
                        for ni in 0..self.grid {
                            let s2 = 1e-6 * 10f64.powf(ni as f64 / (self.grid - 1).max(1) as f64 * 4.0);
                            if let Some(mll) = curve_mll(ts, &ys, ls, os, s2) {
                                if mll > best.0 {
                                    best = (mll, ls, os, s2);
                                }
                            }
                        }
                    }
                }
                let (_, ls, os, s2) = best;
                // predictive at the final grid point
                let mut k = crate::gp::kernels::matern12(ts, ts, ls, os);
                k.add_diag(s2);
                let Ok(l) = linalg::cholesky(&k) else {
                    return (mean_y, os + s2);
                };
                let alpha = linalg::chol_solve(&l, &ys);
                let kstar: Vec<f64> = ts
                    .iter()
                    .map(|&t| os * (-(tn[m - 1] - t).abs() / ls).exp())
                    .collect();
                let mean = linalg::matrix::dot(&kstar, &alpha) + mean_y;
                let w = linalg::chol_solve(&l, &kstar);
                let var = (os - linalg::matrix::dot(&kstar, &w)).max(1e-9) + s2;
                (mean, var)
            })
            .collect()
    }

    fn name(&self) -> &'static str {
        "percurve_gp"
    }
}

/// Exact log marginal likelihood of a 1-d Matern-1/2 GP (None if not PD).
fn curve_mll(ts: &[f64], ys: &[f64], ls: f64, os: f64, s2: f64) -> Option<f64> {
    let mut k = crate::gp::kernels::matern12(ts, ts, ls, os);
    k.add_diag(s2);
    let l = linalg::cholesky(&k).ok()?;
    let alpha = linalg::chol_solve(&l, ys);
    Some(
        -0.5 * linalg::matrix::dot(ys, &alpha)
            - 0.5 * linalg::chol_logdet(&l)
            - 0.5 * ys.len() as f64 * (2.0 * std::f64::consts::PI).ln(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Curves following an exact power law (easy mode for all baselines).
    fn powerlaw_curves(k: usize, m: usize, seed: u64) -> (Matrix, Vec<usize>, Vec<f64>, Vec<f64>) {
        let mut rng = Pcg64::new(seed);
        let epochs: Vec<f64> = (1..=m).map(|e| e as f64).collect();
        let mut curves = Matrix::zeros(k, m);
        let mut lengths = Vec::with_capacity(k);
        let mut finals = Vec::with_capacity(k);
        for i in 0..k {
            let a = rng.uniform_in(0.7, 0.9);
            let b = rng.uniform_in(0.2, 0.4);
            let c = rng.uniform_in(0.5, 1.2);
            for (j, &t) in epochs.iter().enumerate() {
                curves[(i, j)] = a - b * t.powf(-c) + 0.001 * rng.normal();
            }
            lengths.push(m / 2 + rng.below(m / 3));
            finals.push(curves[(i, m - 1)]);
        }
        (curves, lengths, epochs, finals)
    }

    #[test]
    fn last_value_basics() {
        let curves = Matrix::from_vec(1, 4, vec![0.1, 0.2, 0.3, 0.9]);
        let preds = LastValue.predict(&curves, &[3], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(preds[0].0, 0.3);
        assert!(preds[0].1 > 0.0);
    }

    #[test]
    fn power_law_fit_recovers_parameters() {
        let ts: Vec<f64> = (1..=30).map(|t| t as f64).collect();
        let (a0, b0, c0) = (0.85, 0.3, 0.8);
        let ys: Vec<f64> = ts.iter().map(|&t| a0 - b0 * t.powf(-c0)).collect();
        let (a, b, c) = fit_power_law(&ts, &ys, (0.7, 0.2, 0.5));
        assert!((a - a0).abs() < 1e-3, "a={a}");
        assert!((b - b0).abs() < 1e-2, "b={b}");
        assert!((c - c0).abs() < 1e-2, "c={c}");
    }

    #[test]
    fn power_law_ensemble_beats_last_value_on_power_laws() {
        let (curves, lengths, epochs, finals) = powerlaw_curves(20, 50, 1);
        let pl = PowerLawEnsemble::default().predict(&curves, &lengths, &epochs);
        let lv = LastValue.predict(&curves, &lengths, &epochs);
        let mse = |preds: &[(f64, f64)]| -> f64 {
            crate::metrics::mse(
                &preds.iter().map(|p| p.0).collect::<Vec<_>>(),
                &finals,
            )
        };
        assert!(mse(&pl) < mse(&lv), "pl={} lv={}", mse(&pl), mse(&lv));
    }

    #[test]
    fn per_curve_gp_reasonable_on_saturating_curves() {
        let (curves, lengths, epochs, finals) = powerlaw_curves(10, 50, 2);
        let preds = PerCurveGp::default().predict(&curves, &lengths, &epochs);
        for (p, f) in preds.iter().zip(&finals) {
            assert!((p.0 - f).abs() < 0.2, "pred={} truth={f}", p.0);
            assert!(p.1.is_finite() && p.1 > 0.0);
        }
    }

    #[test]
    fn short_prefixes_dont_panic() {
        let curves = Matrix::from_vec(2, 5, vec![0.5, 0.0, 0.0, 0.0, 0.0, 0.4, 0.5, 0.0, 0.0, 0.0]);
        let epochs = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        for lens in [[1usize, 2], [2, 1]] {
            let p1 = PowerLawEnsemble::default().predict(&curves, &lens, &epochs);
            let p2 = PerCurveGp::default().predict(&curves, &lens, &epochs);
            let p3 = LastValue.predict(&curves, &lens, &epochs);
            for p in [p1, p2, p3] {
                assert_eq!(p.len(), 2);
                for (mu, var) in p {
                    assert!(mu.is_finite() && var > 0.0);
                }
            }
        }
    }
}
