//! Rule family `stats_drift` / `bench_gate`: observability drift.
//!
//! Counters and bench artifacts only help if someone looks at them. The
//! stats rule fails when a `ServiceStats` counter is incremented but never
//! observed (`.load(..)` / `.lock(..)` on the field) in non-test code —
//! dead telemetry that silently stops meaning anything. The bench rule
//! fails when a bench source names a `BENCH_*.json` artifact that `ci.sh`
//! never gates on — a benchmark whose regression no one would catch.

use super::tokenizer::Kind;
use super::{AnalysisConfig, AnalysisInput, FileTokens, Finding, Rule};

/// Rule `stats_drift`: every field of the configured stats struct must be
/// observed somewhere in non-test code. "Observed" means a `.field.load(`
/// or `.field.lock(` chain — the shapes every print/serialize path in
/// this crate goes through (counters are atomics, histograms sit behind a
/// mutex). Increment-only fields (`fetch_add` with no reader) are flagged
/// at their declaration.
pub(crate) fn stats_drift(
    files: &[FileTokens],
    cfg: &AnalysisConfig,
    findings: &mut Vec<Finding>,
) {
    // ---- locate the struct and parse its field names -----------------
    let mut fields: Vec<(String, String, u32)> = Vec::new(); // (field, file, decl line)
    for ft in files {
        for ci in 0..ft.code.len() {
            if ft.ctext(ci) != "struct" || ft.ctext(ci + 1) != cfg.stats_struct {
                continue;
            }
            // First `{` after the name opens the body.
            let mut j = ci + 2;
            while j < ft.code.len() && ft.ctext(j) != "{" && ft.ctext(j) != ";" {
                j += 1;
            }
            if ft.ctext(j) != "{" {
                continue;
            }
            let Some(&close) = ft.brace_match.get(&j) else { continue };
            let mut depth = 0i64;
            for k in j..close {
                match ft.ctext(k) {
                    "{" => depth += 1,
                    "}" => depth -= 1,
                    _ => {}
                }
                // A field is `ident :` at body depth 1, introduced by the
                // open brace, a comma, or `pub`.
                if depth == 1
                    && ft.ct(k).kind == Kind::Ident
                    && ft.ctext(k + 1) == ":"
                    && matches!(ft.ctext(k.wrapping_sub(1)), "{" | "," | "pub")
                {
                    fields.push((ft.ctext(k).to_string(), ft.name.clone(), ft.ct(k).line));
                }
            }
        }
    }

    // ---- scan for observations ---------------------------------------
    for (field, file, line) in fields {
        let mut observed = false;
        'files: for ft in files {
            for ci in 0..ft.code.len() {
                if ft.ctext(ci) == "."
                    && ft.ctext(ci + 1) == field
                    && ft.ctext(ci + 2) == "."
                    && matches!(ft.ctext(ci + 3), "load" | "lock")
                    && ft.ctext(ci + 4) == "("
                    && !ft.in_test(ft.ct(ci + 1).line)
                {
                    observed = true;
                    break 'files;
                }
            }
        }
        if !observed {
            findings.push(Finding {
                rule: Rule::StatsDrift,
                file,
                line,
                message: format!(
                    "`{}::{field}` is never observed (`.{field}.load(..)`) in \
                     non-test code — print or serialize it, or remove the counter",
                    cfg.stats_struct
                ),
                justified: None,
            });
        }
    }
}

/// Rule `bench_gate`: every `BENCH_*.json` artifact named in a bench
/// source's string literals must appear in `ci.sh` (which is where the
/// assert gates live). Skipped when no ci.sh text was provided (fixture
/// runs) — absence of the script is not absence of the gate.
pub(crate) fn bench_gate(input: &AnalysisInput, findings: &mut Vec<Finding>) {
    let Some(ci_script) = input.ci_script.as_deref() else {
        return;
    };
    for sf in &input.benches {
        let toks = super::tokenizer::tokenize(&sf.text);
        for t in &toks {
            if t.kind != Kind::Str {
                continue;
            }
            for name in bench_artifact_names(&t.text) {
                if !ci_script.contains(&name) {
                    findings.push(Finding {
                        rule: Rule::BenchGate,
                        file: sf.name.clone(),
                        line: t.line,
                        message: format!(
                            "bench artifact `{name}` has no ci.sh gate — add an \
                             assert on it or the benchmark can regress silently"
                        ),
                        justified: None,
                    });
                }
            }
        }
    }
}

/// Extract `BENCH_<word>.json` names from a string-literal token's text.
fn bench_artifact_names(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let bytes = s.as_bytes();
    let mut i = 0usize;
    while let Some(at) = s[i..].find("BENCH_") {
        let start = i + at;
        let mut end = start + "BENCH_".len();
        while end < bytes.len()
            && (bytes[end].is_ascii_alphanumeric() || bytes[end] == b'_')
        {
            end += 1;
        }
        if s[end..].starts_with(".json") {
            out.push(s[start..end + ".json".len()].to_string());
            i = end + ".json".len();
        } else {
            i = end;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::{analyze, AnalysisConfig, AnalysisInput, Rule, SourceFile};
    use super::bench_artifact_names;

    fn cfg() -> AnalysisConfig {
        let mut c = AnalysisConfig::crate_default();
        c.stats_struct = "MiniStats".into();
        c
    }

    #[test]
    fn unread_counter_is_flagged_and_read_counter_is_not() {
        let src = "\
pub struct MiniStats {\n\
    pub seen: AtomicU64,\n\
    pub lost: AtomicU64,\n\
}\n\
fn report(s: &MiniStats) -> u64 { s.seen.load(Ordering::Relaxed) }\n";
        let input = AnalysisInput {
            src: vec![SourceFile { name: "stats.rs".into(), text: src.into() }],
            benches: Vec::new(),
            ci_script: None,
        };
        let a = analyze(&input, &cfg());
        let drift: Vec<_> = a
            .findings
            .iter()
            .filter(|f| f.rule == Rule::StatsDrift)
            .collect();
        assert_eq!(drift.len(), 1, "{:?}", a.findings);
        assert!(drift[0].message.contains("lost"));
        assert_eq!(drift[0].line, 3);
    }

    #[test]
    fn bench_artifact_without_gate_is_flagged() {
        let bench = "fn main() { write(\"BENCH_NEW.json\"); write(\"BENCH_OLD.json\"); }\n";
        let input = AnalysisInput {
            src: Vec::new(),
            benches: vec![SourceFile { name: "b.rs".into(), text: bench.into() }],
            ci_script: Some("assert BENCH_OLD.json".into()),
        };
        let a = analyze(&input, &cfg());
        let gate: Vec<_> = a
            .findings
            .iter()
            .filter(|f| f.rule == Rule::BenchGate)
            .collect();
        assert_eq!(gate.len(), 1, "{:?}", a.findings);
        assert!(gate[0].message.contains("BENCH_NEW.json"));
    }

    #[test]
    fn artifact_name_extraction() {
        assert_eq!(
            bench_artifact_names("\"out/BENCH_PCG.json and BENCH_A_B.json\""),
            vec!["BENCH_PCG.json".to_string(), "BENCH_A_B.json".to_string()]
        );
        assert!(bench_artifact_names("\"BENCH_ pcg\"").is_empty());
    }
}
