//! Rule family `stats_drift` / `bench_gate` / `doc_drift`: drift between
//! what the code does and what anyone can observe or read about it.
//!
//! Counters and bench artifacts only help if someone looks at them. The
//! stats rule fails when a `ServiceStats` counter is incremented but never
//! observed (`.load(..)` / `.lock(..)` on the field) in non-test code —
//! dead telemetry that silently stops meaning anything. The bench rule
//! fails when a bench source names a `BENCH_*.json` artifact that `ci.sh`
//! never gates on — a benchmark whose regression no one would catch. The
//! doc rule fails when the prose contract breaks: a source file points a
//! reader at a `docs/*.md` note that does not exist, a bench emits an
//! artifact that docs/ci.md's inventory omits, or the `lkgp` usage
//! string advertises a `--flag` no doc explains (docs/index.md).

use super::tokenizer::Kind;
use super::{AnalysisConfig, AnalysisInput, FileTokens, Finding, Rule};

/// Rule `stats_drift`: every field of the configured stats struct must be
/// observed somewhere in non-test code. "Observed" means a `.field.load(`
/// or `.field.lock(` chain — the shapes every print/serialize path in
/// this crate goes through (counters are atomics, histograms sit behind a
/// mutex). Increment-only fields (`fetch_add` with no reader) are flagged
/// at their declaration.
pub(crate) fn stats_drift(
    files: &[FileTokens],
    cfg: &AnalysisConfig,
    findings: &mut Vec<Finding>,
) {
    // ---- locate the struct and parse its field names -----------------
    let mut fields: Vec<(String, String, u32)> = Vec::new(); // (field, file, decl line)
    for ft in files {
        for ci in 0..ft.code.len() {
            if ft.ctext(ci) != "struct" || ft.ctext(ci + 1) != cfg.stats_struct {
                continue;
            }
            // First `{` after the name opens the body.
            let mut j = ci + 2;
            while j < ft.code.len() && ft.ctext(j) != "{" && ft.ctext(j) != ";" {
                j += 1;
            }
            if ft.ctext(j) != "{" {
                continue;
            }
            let Some(&close) = ft.brace_match.get(&j) else { continue };
            let mut depth = 0i64;
            for k in j..close {
                match ft.ctext(k) {
                    "{" => depth += 1,
                    "}" => depth -= 1,
                    _ => {}
                }
                // A field is `ident :` at body depth 1, introduced by the
                // open brace, a comma, or `pub`.
                if depth == 1
                    && ft.ct(k).kind == Kind::Ident
                    && ft.ctext(k + 1) == ":"
                    && matches!(ft.ctext(k.wrapping_sub(1)), "{" | "," | "pub")
                {
                    fields.push((ft.ctext(k).to_string(), ft.name.clone(), ft.ct(k).line));
                }
            }
        }
    }

    // ---- scan for observations ---------------------------------------
    for (field, file, line) in fields {
        let mut observed = false;
        'files: for ft in files {
            for ci in 0..ft.code.len() {
                if ft.ctext(ci) == "."
                    && ft.ctext(ci + 1) == field
                    && ft.ctext(ci + 2) == "."
                    && matches!(ft.ctext(ci + 3), "load" | "lock")
                    && ft.ctext(ci + 4) == "("
                    && !ft.in_test(ft.ct(ci + 1).line)
                {
                    observed = true;
                    break 'files;
                }
            }
        }
        if !observed {
            findings.push(Finding {
                rule: Rule::StatsDrift,
                file,
                line,
                message: format!(
                    "`{}::{field}` is never observed (`.{field}.load(..)`) in \
                     non-test code — print or serialize it, or remove the counter",
                    cfg.stats_struct
                ),
                justified: None,
            });
        }
    }
}

/// Rule `bench_gate`: every `BENCH_*.json` artifact named in a bench
/// source's string literals must appear in `ci.sh` (which is where the
/// assert gates live). Skipped when no ci.sh text was provided (fixture
/// runs) — absence of the script is not absence of the gate.
pub(crate) fn bench_gate(input: &AnalysisInput, findings: &mut Vec<Finding>) {
    let Some(ci_script) = input.ci_script.as_deref() else {
        return;
    };
    for sf in &input.benches {
        let toks = super::tokenizer::tokenize(&sf.text);
        for t in &toks {
            if t.kind != Kind::Str {
                continue;
            }
            for name in bench_artifact_names(&t.text) {
                if !ci_script.contains(&name) {
                    findings.push(Finding {
                        rule: Rule::BenchGate,
                        file: sf.name.clone(),
                        line: t.line,
                        message: format!(
                            "bench artifact `{name}` has no ci.sh gate — add an \
                             assert on it or the benchmark can regress silently"
                        ),
                        justified: None,
                    });
                }
            }
        }
    }
}

/// Rule `doc_drift`: the docs tree and the code must not drift apart.
/// Three checks, all anchored at the offending source line so the usual
/// `// lint: allow(doc_drift) — <why>` pragma applies:
///
/// (a) every `docs/<name>.md` path written in a crate or bench source —
///     module docs, error messages, comments — must exist under `docs/`
///     (a dangling pointer sends the reader nowhere);
/// (b) every `BENCH_*.json` artifact a bench source names must be
///     mentioned in `docs/ci.md`, the artifact inventory;
/// (c) every `--flag` in `main.rs`'s string literals (the CLI usage
///     surface) must appear in at least one doc.
///
/// Skipped entirely when no docs were provided (fixture runs — absence
/// of the docs tree is not absence of the contract); check (b) is
/// skipped when the provided docs lack a `ci.md`. Crate sources are
/// scanned through their token view so `#[cfg(test)]` regions are
/// exempt — fixtures and unit tests cite fictional docs on purpose.
pub(crate) fn doc_drift(
    files: &[FileTokens],
    input: &AnalysisInput,
    findings: &mut Vec<Finding>,
) {
    if input.docs.is_empty() {
        return;
    }
    let doc_names: Vec<&str> = input.docs.iter().map(|d| d.name.as_str()).collect();
    let dangling = |file: &str, line: u32, name: String, reported: &mut Vec<String>| {
        if doc_names.contains(&name.as_str()) || reported.contains(&name) {
            return None;
        }
        reported.push(name.clone());
        Some(Finding {
            rule: Rule::DocDrift,
            file: file.to_string(),
            line,
            message: format!(
                "source references `docs/{name}`, which does not exist — \
                 write the doc or fix the pointer"
            ),
            justified: None,
        })
    };

    // (a) dangling docs/*.md references, one finding per (file, name).
    // Doc paths live in comments and string literals; both are tokens.
    for ft in files {
        let mut reported: Vec<String> = Vec::new();
        for t in &ft.toks {
            if !matches!(t.kind, Kind::Comment | Kind::Str) || ft.in_test(t.line) {
                continue;
            }
            for name in doc_refs(&t.text) {
                findings.extend(dangling(&ft.name, t.line, name, &mut reported));
            }
        }
    }
    for sf in &input.benches {
        let mut reported: Vec<String> = Vec::new();
        for (i, line) in sf.text.lines().enumerate() {
            for name in doc_refs(line) {
                findings.extend(dangling(&sf.name, (i + 1) as u32, name, &mut reported));
            }
        }
    }

    // (b) bench artifacts missing from docs/ci.md's inventory
    if let Some(ci_md) = input.docs.iter().find(|d| d.name == "ci.md") {
        for sf in &input.benches {
            let mut reported: Vec<String> = Vec::new();
            for (i, line) in sf.text.lines().enumerate() {
                for name in bench_artifact_names(line) {
                    if ci_md.text.contains(&name) || reported.contains(&name) {
                        continue;
                    }
                    findings.push(Finding {
                        rule: Rule::DocDrift,
                        file: sf.name.clone(),
                        line: (i + 1) as u32,
                        message: format!(
                            "bench artifact `{name}` is not inventoried in \
                             docs/ci.md — add it to the artifact table"
                        ),
                        justified: None,
                    });
                    reported.push(name);
                }
            }
        }
    }

    // (c) usage-surface flags nobody documents. String literals only:
    // the usage string is the advertised surface; prose comments that
    // mention `--key value` syntax are not.
    for ft in files.iter().filter(|f| f.name == "main.rs") {
        let mut reported: Vec<String> = Vec::new();
        for t in &ft.toks {
            if t.kind != Kind::Str || ft.in_test(t.line) {
                continue;
            }
            for flag in cli_flags(&t.text) {
                if reported.contains(&flag)
                    || input.docs.iter().any(|d| d.text.contains(&flag))
                {
                    continue;
                }
                findings.push(Finding {
                    rule: Rule::DocDrift,
                    file: ft.name.clone(),
                    line: t.line,
                    message: format!(
                        "CLI flag `{flag}` is advertised in the usage string but \
                         documented in no docs/*.md — add it to a doc (the flag \
                         table in docs/index.md, if nowhere better)"
                    ),
                    justified: None,
                });
                reported.push(flag);
            }
        }
    }
}

/// Extract the `<name>.md` parts of `docs/<name>.md` references in `s`.
fn doc_refs(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let bytes = s.as_bytes();
    let mut i = 0usize;
    while let Some(at) = s[i..].find("docs/") {
        let start = i + at + "docs/".len();
        let mut end = start;
        while end < bytes.len()
            && (bytes[end].is_ascii_alphanumeric() || bytes[end] == b'_')
        {
            end += 1;
        }
        if end > start && s[end..].starts_with(".md") {
            out.push(format!("{}.md", &s[start..end]));
            i = end + ".md".len();
        } else {
            i = start;
        }
    }
    out
}

/// Extract `--flag` names (`--` plus a lowercase kebab-case word) from a
/// string-literal token's text, including the leading dashes.
fn cli_flags(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let bytes = s.as_bytes();
    let mut i = 0usize;
    while let Some(at) = s[i..].find("--") {
        let start = i + at;
        let mut end = start + 2;
        while end < bytes.len()
            && (bytes[end].is_ascii_lowercase()
                || bytes[end].is_ascii_digit()
                || bytes[end] == b'-')
        {
            end += 1;
        }
        if end > start + 2 {
            out.push(s[start..end].to_string());
        }
        i = end;
    }
    out
}

/// Extract `BENCH_<word>.json` names from a string-literal token's text.
fn bench_artifact_names(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let bytes = s.as_bytes();
    let mut i = 0usize;
    while let Some(at) = s[i..].find("BENCH_") {
        let start = i + at;
        let mut end = start + "BENCH_".len();
        while end < bytes.len()
            && (bytes[end].is_ascii_alphanumeric() || bytes[end] == b'_')
        {
            end += 1;
        }
        if s[end..].starts_with(".json") {
            out.push(s[start..end + ".json".len()].to_string());
            i = end + ".json".len();
        } else {
            i = end;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::{analyze, AnalysisConfig, AnalysisInput, Rule, SourceFile};
    use super::bench_artifact_names;

    fn cfg() -> AnalysisConfig {
        let mut c = AnalysisConfig::crate_default();
        c.stats_struct = "MiniStats".into();
        c
    }

    #[test]
    fn unread_counter_is_flagged_and_read_counter_is_not() {
        let src = "\
pub struct MiniStats {\n\
    pub seen: AtomicU64,\n\
    pub lost: AtomicU64,\n\
}\n\
fn report(s: &MiniStats) -> u64 { s.seen.load(Ordering::Relaxed) }\n";
        let input = AnalysisInput {
            src: vec![SourceFile { name: "stats.rs".into(), text: src.into() }],
            benches: Vec::new(),
            ci_script: None,
            docs: Vec::new(),
        };
        let a = analyze(&input, &cfg());
        let drift: Vec<_> = a
            .findings
            .iter()
            .filter(|f| f.rule == Rule::StatsDrift)
            .collect();
        assert_eq!(drift.len(), 1, "{:?}", a.findings);
        assert!(drift[0].message.contains("lost"));
        assert_eq!(drift[0].line, 3);
    }

    #[test]
    fn bench_artifact_without_gate_is_flagged() {
        let bench = "fn main() { write(\"BENCH_NEW.json\"); write(\"BENCH_OLD.json\"); }\n";
        let input = AnalysisInput {
            src: Vec::new(),
            benches: vec![SourceFile { name: "b.rs".into(), text: bench.into() }],
            ci_script: Some("assert BENCH_OLD.json".into()),
            docs: Vec::new(),
        };
        let a = analyze(&input, &cfg());
        let gate: Vec<_> = a
            .findings
            .iter()
            .filter(|f| f.rule == Rule::BenchGate)
            .collect();
        assert_eq!(gate.len(), 1, "{:?}", a.findings);
        assert!(gate[0].message.contains("BENCH_NEW.json"));
    }

    #[test]
    fn doc_drift_fires_on_all_three_checks_and_skips_without_docs() {
        let src = SourceFile {
            name: "main.rs".into(),
            text: "//! See docs/real.md and docs/ghost.md.\nfn main() { \
                   eprintln!(\"usage: x [--known N] [--rogue N]\"); }\n"
                .into(),
        };
        let bench = SourceFile {
            name: "b.rs".into(),
            text: "fn main() { out(\"BENCH_listed.json\"); out(\"BENCH_orphan.json\"); }\n".into(),
        };
        let docs = vec![
            SourceFile { name: "real.md".into(), text: "covers `--known` too".into() },
            SourceFile { name: "ci.md".into(), text: "artifacts: BENCH_listed.json".into() },
        ];
        let input = AnalysisInput {
            src: vec![src],
            benches: vec![bench],
            ci_script: Some("gate BENCH_listed.json BENCH_orphan.json".into()),
            docs,
        };
        let a = analyze(&input, &cfg());
        let drift: Vec<_> = a
            .findings
            .iter()
            .filter(|f| f.rule == Rule::DocDrift)
            .collect();
        assert_eq!(drift.len(), 3, "{:?}", a.findings);
        assert!(drift.iter().any(|f| f.message.contains("docs/ghost.md")));
        assert!(drift.iter().any(|f| f.message.contains("BENCH_orphan.json")));
        assert!(drift.iter().any(|f| f.message.contains("`--rogue`")));
        // `docs/real.md`, BENCH_listed.json, and `--known` are all clean.

        // No docs provided (fixture shape): the rule stays silent.
        let quiet = AnalysisInput {
            src: vec![SourceFile {
                name: "main.rs".into(),
                text: "//! docs/ghost.md\nfn main() { out(\"--rogue\"); }\n".into(),
            }],
            benches: Vec::new(),
            ci_script: None,
            docs: Vec::new(),
        };
        let a = analyze(&quiet, &cfg());
        assert!(
            a.findings.iter().all(|f| f.rule != Rule::DocDrift),
            "{:?}",
            a.findings
        );
    }

    #[test]
    fn doc_and_flag_extraction() {
        use super::{cli_flags, doc_refs};
        assert_eq!(
            doc_refs("see docs/api.md, docs/static_analysis.md; not docs/<name>.md or docs/x.rs"),
            vec!["api.md".to_string(), "static_analysis.md".to_string()]
        );
        assert_eq!(
            cli_flags("\"[--deadline-ms N] [--chaos panic=P] -- not a flag\""),
            vec!["--deadline-ms".to_string(), "--chaos".to_string()]
        );
    }

    #[test]
    fn artifact_name_extraction() {
        assert_eq!(
            bench_artifact_names("\"out/BENCH_PCG.json and BENCH_A_B.json\""),
            vec!["BENCH_PCG.json".to_string(), "BENCH_A_B.json".to_string()]
        );
        assert!(bench_artifact_names("\"BENCH_ pcg\"").is_empty());
    }
}
