//! Comment/string-aware Rust tokenizer for the in-tree analyzer.
//!
//! This is not a full Rust lexer — it covers exactly what the invariant
//! rules need: code tokens (identifiers, numbers, strings, chars,
//! lifetimes, punctuation) with 1-based line numbers, plus comments kept
//! as first-class tokens so the rules can find `// SAFETY:` justifications
//! and `// lint: allow(...)` pragmas. Nested block comments, raw strings
//! (`r#"…"#`, `br"…"`), byte strings/chars, and the lifetime-vs-char
//! ambiguity (`'a` vs `'a'`) are handled so that quote and brace
//! characters inside literals never confuse the rule scanners.

/// Token class. Keywords are plain `Ident`s — the rules match on text.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Kind {
    /// Identifier or keyword.
    Ident,
    /// Numeric literal (int or float; text kept for float detection).
    Num,
    /// String literal, including raw and byte strings (delimiters kept).
    Str,
    /// Char or byte-char literal.
    Char,
    /// Lifetime (`'a`, `'static`, `'_`).
    Lifetime,
    /// Line or block comment, delimiters kept. Line = the comment's
    /// first line for block comments; `//` comments are one token each.
    Comment,
    /// Operator / punctuation; multi-char operators (`==`, `::`, `->`,
    /// `..=`) are single tokens.
    Punct,
}

/// One token with its 1-based source line.
#[derive(Clone, Debug)]
pub struct Token {
    pub kind: Kind,
    pub text: String,
    pub line: u32,
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// True when a `Num` token's text denotes a float (`1.0`, `1e-3`,
/// `2f64`), as opposed to an integer in any base.
pub fn is_float_literal(text: &str) -> bool {
    if text.starts_with("0x") || text.starts_with("0X")
        || text.starts_with("0b") || text.starts_with("0B")
        || text.starts_with("0o") || text.starts_with("0O")
    {
        return false;
    }
    if text.contains('.') {
        return true;
    }
    if text.ends_with("f32") || text.ends_with("f64") {
        return true;
    }
    // `1e9` / `1E-3`: an exponent marker followed by digits or a sign.
    let bytes = text.as_bytes();
    for (i, &c) in bytes.iter().enumerate() {
        if (c == b'e' || c == b'E') && i > 0 {
            if let Some(&next) = bytes.get(i + 1) {
                if next.is_ascii_digit() || next == b'+' || next == b'-' {
                    return true;
                }
            }
        }
    }
    false
}

/// Tokenize one source file. Unterminated constructs (string to EOF) are
/// tolerated — the token simply runs to the end of input; the analyzer
/// lints the crate's own compiling sources, so this never fires in anger.
pub fn tokenize(src: &str) -> Vec<Token> {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut out: Vec<Token> = Vec::new();
    let mut i = 0usize;
    let mut line: u32 = 1;

    // Two-char (and `..=`) operators that the rules care to see whole.
    const TWO: &[&str] = &[
        "::", "->", "=>", "==", "!=", "<=", ">=", "&&", "||", "+=", "-=",
        "*=", "/=", "%=", "^=", "&=", "|=", "..", "<<", ">>",
    ];

    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment (incl. doc comments).
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let start = i;
            while i < n && chars[i] != '\n' {
                i += 1;
            }
            out.push(Token {
                kind: Kind::Comment,
                text: chars[start..i].iter().collect(),
                line,
            });
            continue;
        }
        // Block comment, nesting per Rust rules.
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let start = i;
            let start_line = line;
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if chars[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            out.push(Token {
                kind: Kind::Comment,
                text: chars[start..i].iter().collect(),
                line: start_line,
            });
            continue;
        }
        // Raw / byte strings: r"…", r#"…"#, br"…", b"…".
        if c == 'r' || c == 'b' {
            let mut j = i + 1;
            if c == 'b' && j < n && chars[j] == 'r' {
                j += 1;
            }
            let mut hashes = 0usize;
            while j < n && chars[j] == '#' {
                hashes += 1;
                j += 1;
            }
            let is_raw = j > i + 1 || c == 'r';
            if j < n && chars[j] == '"' && (is_raw || c == 'b') {
                // Raw string (possibly byte-raw) or plain byte string.
                let start = i;
                let start_line = line;
                if hashes == 0 && (c == 'b' && chars[i + 1] == '"') {
                    // b"…" — ordinary escaped string body.
                    i += 2;
                    while i < n {
                        if chars[i] == '\\' {
                            i += 2;
                        } else if chars[i] == '"' {
                            i += 1;
                            break;
                        } else {
                            if chars[i] == '\n' {
                                line += 1;
                            }
                            i += 1;
                        }
                    }
                } else if is_raw {
                    // r…"body"… — ends at `"` followed by `hashes` #'s.
                    i = j + 1;
                    while i < n {
                        if chars[i] == '\n' {
                            line += 1;
                            i += 1;
                            continue;
                        }
                        if chars[i] == '"' {
                            let mut k = 0usize;
                            while k < hashes && i + 1 + k < n && chars[i + 1 + k] == '#' {
                                k += 1;
                            }
                            if k == hashes {
                                i += 1 + hashes;
                                break;
                            }
                        }
                        i += 1;
                    }
                }
                out.push(Token {
                    kind: Kind::Str,
                    text: chars[start..i].iter().collect(),
                    line: start_line,
                });
                continue;
            }
            // Byte char b'…'.
            if c == 'b' && i + 1 < n && chars[i + 1] == '\'' {
                let start = i;
                i += 2;
                if i < n && chars[i] == '\\' {
                    i += 2;
                } else if i < n {
                    i += 1;
                }
                if i < n && chars[i] == '\'' {
                    i += 1;
                }
                out.push(Token {
                    kind: Kind::Char,
                    text: chars[start..i].iter().collect(),
                    line,
                });
                continue;
            }
            // Fall through: ordinary identifier starting with r/b.
        }
        // Plain string literal.
        if c == '"' {
            let start = i;
            let start_line = line;
            i += 1;
            while i < n {
                if chars[i] == '\\' {
                    i += 2;
                } else if chars[i] == '"' {
                    i += 1;
                    break;
                } else {
                    if chars[i] == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            out.push(Token {
                kind: Kind::Str,
                text: chars[start..i].iter().collect(),
                line: start_line,
            });
            continue;
        }
        // Lifetime or char literal.
        if c == '\'' {
            // Lifetime: 'ident NOT followed by a closing quote ('a', 'x').
            if i + 1 < n && is_ident_start(chars[i + 1]) {
                let mut j = i + 1;
                while j < n && is_ident_continue(chars[j]) {
                    j += 1;
                }
                if j >= n || chars[j] != '\'' {
                    out.push(Token {
                        kind: Kind::Lifetime,
                        text: chars[i..j].iter().collect(),
                        line,
                    });
                    i = j;
                    continue;
                }
            }
            // Char literal, with escapes ('\n', '\'', '\u{1F600}').
            let start = i;
            i += 1;
            if i < n && chars[i] == '\\' {
                i += 1;
                if i < n && chars[i] == 'u' {
                    while i < n && chars[i] != '}' {
                        i += 1;
                    }
                    i += 1;
                } else {
                    i += 1;
                }
            } else if i < n {
                i += 1;
            }
            if i < n && chars[i] == '\'' {
                i += 1;
            }
            out.push(Token {
                kind: Kind::Char,
                text: chars[start..i].iter().collect(),
                line,
            });
            continue;
        }
        // Number.
        if c.is_ascii_digit() {
            let start = i;
            i += 1;
            if c == '0' && i < n && (chars[i] == 'x' || chars[i] == 'b' || chars[i] == 'o') {
                i += 1;
                while i < n && (chars[i].is_ascii_alphanumeric() || chars[i] == '_') {
                    i += 1;
                }
            } else {
                while i < n && (chars[i].is_ascii_digit() || chars[i] == '_') {
                    i += 1;
                }
                // Fraction: a dot consumed only when a digit follows, so
                // ranges (`0..n`) and method calls (`1.max(x)`) survive.
                if i + 1 < n && chars[i] == '.' && chars[i + 1].is_ascii_digit() {
                    i += 1;
                    while i < n && (chars[i].is_ascii_digit() || chars[i] == '_') {
                        i += 1;
                    }
                }
                // Exponent.
                if i < n && (chars[i] == 'e' || chars[i] == 'E') {
                    let mut j = i + 1;
                    if j < n && (chars[j] == '+' || chars[j] == '-') {
                        j += 1;
                    }
                    if j < n && chars[j].is_ascii_digit() {
                        i = j;
                        while i < n && (chars[i].is_ascii_digit() || chars[i] == '_') {
                            i += 1;
                        }
                    }
                }
                // Type suffix (f64, u32, usize, …).
                while i < n && is_ident_continue(chars[i]) {
                    i += 1;
                }
            }
            out.push(Token {
                kind: Kind::Num,
                text: chars[start..i].iter().collect(),
                line,
            });
            continue;
        }
        // Identifier / keyword.
        if is_ident_start(c) {
            let start = i;
            while i < n && is_ident_continue(chars[i]) {
                i += 1;
            }
            out.push(Token {
                kind: Kind::Ident,
                text: chars[start..i].iter().collect(),
                line,
            });
            continue;
        }
        // Punctuation: `..=` first, then two-char operators, then single.
        if i + 2 < n && chars[i] == '.' && chars[i + 1] == '.' && chars[i + 2] == '=' {
            out.push(Token { kind: Kind::Punct, text: "..=".into(), line });
            i += 3;
            continue;
        }
        if i + 1 < n {
            let pair: String = chars[i..i + 2].iter().collect();
            if TWO.contains(&pair.as_str()) {
                out.push(Token { kind: Kind::Punct, text: pair, line });
                i += 2;
                continue;
            }
        }
        out.push(Token {
            kind: Kind::Punct,
            text: c.to_string(),
            line,
        });
        i += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(Kind, String)> {
        tokenize(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn comments_and_strings_do_not_leak_code() {
        let toks = kinds("let x = \"a == b\"; // y == 0.0\n/* z != 1.0 */ x");
        let eqs: Vec<_> = toks
            .iter()
            .filter(|(k, t)| *k == Kind::Punct && (t == "==" || t == "!="))
            .collect();
        assert!(eqs.is_empty(), "operators inside literals/comments leaked: {eqs:?}");
        let comments: Vec<_> = toks.iter().filter(|(k, _)| *k == Kind::Comment).collect();
        assert_eq!(comments.len(), 2);
    }

    #[test]
    fn lifetime_vs_char() {
        let toks = kinds("fn f<'a>(x: &'a str) { let c = 'x'; let nl = '\\n'; }");
        assert!(toks.iter().any(|(k, t)| *k == Kind::Lifetime && t == "'a"));
        assert!(toks.iter().any(|(k, t)| *k == Kind::Char && t == "'x'"));
        assert!(toks.iter().any(|(k, t)| *k == Kind::Char && t == "'\\n'"));
    }

    #[test]
    fn raw_strings_and_nesting() {
        let toks = kinds("let s = r#\"quote \" inside\"#; /* outer /* inner */ still */ done");
        assert!(toks.iter().any(|(k, t)| *k == Kind::Str && t.contains("quote")));
        assert!(toks.iter().any(|(k, t)| *k == Kind::Ident && t == "done"));
        assert_eq!(toks.iter().filter(|(k, _)| *k == Kind::Comment).count(), 1);
    }

    #[test]
    fn float_detection() {
        assert!(is_float_literal("1.0"));
        assert!(is_float_literal("0.5f64"));
        assert!(is_float_literal("1e9"));
        assert!(is_float_literal("2f32"));
        assert!(!is_float_literal("1"));
        assert!(!is_float_literal("0x1f"));
        assert!(!is_float_literal("100_000"));
        assert!(!is_float_literal("3usize"));
    }

    #[test]
    fn ranges_are_not_floats() {
        let toks = kinds("for i in 0..10 { a[i] = 1.5; }");
        let nums: Vec<_> = toks
            .iter()
            .filter(|(k, _)| *k == Kind::Num)
            .map(|(_, t)| t.clone())
            .collect();
        assert_eq!(nums, vec!["0", "10", "1.5"]);
    }

    #[test]
    fn line_numbers_advance_through_literals() {
        let toks = tokenize("a\n\"two\nline\"\nb");
        let b = toks.iter().find(|t| t.text == "b").unwrap();
        assert_eq!(b.line, 4);
    }
}
