//! The unsafe-audit, panic-discipline, and float-discipline rules.
//!
//! All three are local token-pattern rules; the lock rule (graph-based)
//! lives in [`super::locks`] and the drift rules in [`super::drift`].

use super::tokenizer::{is_float_literal, Kind};
use super::{AnalysisConfig, FileTokens, Finding, Rule, UnsafeSite};
use std::collections::{BTreeMap, BTreeSet};

/// Rule `unsafe_safety`: every `unsafe` occurrence (block, fn, impl,
/// extern) needs an adjacent `// SAFETY:` comment — trailing on the same
/// line, or anywhere in the contiguous comment/attribute block directly
/// above (so a multi-line argument with a `#[cfg(..)]` between it and the
/// item still counts; a line of real code breaks the block). Test code is
/// audited too: a test's aliasing argument is as load-bearing as
/// production's. The full inventory is returned for `ANALYSIS.json`.
pub(crate) fn unsafe_audit(
    files: &[FileTokens],
    findings: &mut Vec<Finding>,
    sites: &mut Vec<UnsafeSite>,
) {
    for ft in files {
        // Per-line view: comment text, and whether the line has real
        // (non-attribute) code. `#[...]` tokens don't break a SAFETY
        // block hanging above a `#[cfg(feature)] unsafe impl`.
        let mut comment_text: BTreeMap<u32, String> = BTreeMap::new();
        for t in &ft.toks {
            if t.kind == Kind::Comment {
                comment_text.entry(t.line).or_default().push_str(&t.text);
            }
        }
        let mut attr_tok: Vec<bool> = vec![false; ft.code.len()];
        let mut ci = 0usize;
        while ci + 1 < ft.code.len() {
            if ft.ctext(ci) == "#" && ft.ctext(ci + 1) == "[" {
                attr_tok[ci] = true;
                let mut depth = 0i64;
                let mut j = ci + 1;
                while j < ft.code.len() {
                    attr_tok[j] = true;
                    match ft.ctext(j) {
                        "[" => depth += 1,
                        "]" => {
                            depth -= 1;
                            if depth == 0 {
                                break;
                            }
                        }
                        _ => {}
                    }
                    j += 1;
                }
                ci = j + 1;
            } else {
                ci += 1;
            }
        }
        let mut real_code_lines: BTreeSet<u32> = BTreeSet::new();
        for (k, &is_attr) in attr_tok.iter().enumerate() {
            if !is_attr {
                real_code_lines.insert(ft.ct(k).line);
            }
        }

        for ci in 0..ft.code.len() {
            if ft.ctext(ci) != "unsafe" || ft.ct(ci).kind != Kind::Ident {
                continue;
            }
            let line = ft.ct(ci).line;
            let kind = match ft.ctext(ci + 1) {
                "{" => "block",
                "fn" => "fn",
                "impl" => "impl",
                "extern" => "extern",
                _ => "other",
            };
            // Trailing comment on the site's own line, else the nearest
            // SAFETY in the contiguous comment/attribute block above.
            let mut safety = comment_text.get(&line).and_then(|c| safety_snippet(c));
            if safety.is_none() {
                let mut l = line.saturating_sub(1);
                while l >= 1 && line - l <= 24 {
                    if let Some(s) = comment_text.get(&l).and_then(|c| safety_snippet(c)) {
                        safety = Some(s);
                        break;
                    }
                    if real_code_lines.contains(&l) && line - l > 6 {
                        // Within 6 lines, intervening code is tolerated
                        // (the comment sits above a multi-line statement);
                        // beyond that the block must be contiguous.
                        break;
                    }
                    l -= 1;
                }
            }
            if safety.is_none() {
                findings.push(Finding {
                    rule: Rule::UnsafeSafety,
                    file: ft.name.clone(),
                    line,
                    message: format!(
                        "`unsafe` {kind} without an adjacent `// SAFETY:` comment"
                    ),
                    justified: None,
                });
            }
            sites.push(UnsafeSite {
                file: ft.name.clone(),
                line,
                kind: kind.into(),
                safety,
                in_test: ft.in_test(line),
            });
        }
    }
}

/// Extract the justification text after `SAFETY:` from a comment line,
/// capped for the `ANALYSIS.json` inventory.
fn safety_snippet(comment: &str) -> Option<String> {
    let at = comment.find("SAFETY:")?;
    Some(
        comment[at + "SAFETY:".len()..]
            .trim_start()
            .trim_end_matches("*/")
            .trim()
            .chars()
            .take(160)
            .collect(),
    )
}

/// Methods whose trailing `.unwrap()` / `.expect(..)` expresses the
/// mutex-poison protocol, not a panic shortcut: the poison-policy rule
/// owns those sites (a fail-loud queue lock *must* unwrap), so the panic
/// rule exempts them instead of contradicting it.
const POISON_METHODS: &[&str] = &["lock", "try_lock", "wait", "wait_timeout", "into_inner"];

/// True when the `.` before an `unwrap`/`expect` at `dot_ci` closes a
/// call to one of `POISON_METHODS` (e.g. `q.lock().unwrap()`,
/// `cv.wait(g).unwrap()`, `m.into_inner().unwrap()`).
fn poison_exempt(ft: &FileTokens, dot_ci: usize) -> bool {
    if dot_ci == 0 || ft.ctext(dot_ci - 1) != ")" {
        return false;
    }
    let Some(open) = ft.match_paren_back(dot_ci - 1) else {
        return false;
    };
    open >= 1 && POISON_METHODS.contains(&ft.ctext(open - 1))
}

/// Rule `panic`: no `unwrap()` / `expect(..)` / `panic!`-family macros in
/// the serving hot path outside `#[cfg(test)]`. `assert!` is deliberately
/// out of scope (contract checks are policy), as are poison unwraps (see
/// [`POISON_METHODS`]). Surviving sites carry `// lint: allow(panic)`
/// pragmas with the fail-loud justification.
pub(crate) fn panic_discipline(
    files: &[FileTokens],
    cfg: &AnalysisConfig,
    findings: &mut Vec<Finding>,
) {
    const MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];
    for ft in files {
        if !cfg.hot_paths.iter().any(|h| ft.name.contains(h.as_str())) {
            continue;
        }
        for ci in 0..ft.code.len() {
            let t = ft.ct(ci);
            if t.kind != Kind::Ident || ft.in_test(t.line) {
                continue;
            }
            let text = t.text.as_str();
            if (text == "unwrap" || text == "expect")
                && ci > 0
                && ft.ctext(ci - 1) == "."
                && ft.ctext(ci + 1) == "("
            {
                if poison_exempt(ft, ci - 1) {
                    continue;
                }
                findings.push(Finding {
                    rule: Rule::Panic,
                    file: ft.name.clone(),
                    line: t.line,
                    message: format!(
                        "`.{text}(..)` in the serving hot path — return a typed \
                         `LkgpError` the caller can act on, or pragma-justify"
                    ),
                    justified: None,
                });
            } else if MACROS.contains(&text) && ft.ctext(ci + 1) == "!" {
                findings.push(Finding {
                    rule: Rule::Panic,
                    file: ft.name.clone(),
                    line: t.line,
                    message: format!(
                        "`{text}!` in the serving hot path — return a typed \
                         `LkgpError`, or pragma-justify why failing loud is right"
                    ),
                    justified: None,
                });
            }
        }
    }
}

/// Rule `float_eq` / `float_cmp`: no `==`/`!=` against float literals and
/// no NaN-unsafe `partial_cmp(..).unwrap()` orderings outside the
/// approved parity modules. Exact comparisons go through `.to_bits()`
/// (which the analyzer never flags — the operands are integers there);
/// orderings through `total_cmp`.
pub(crate) fn float_discipline(
    files: &[FileTokens],
    cfg: &AnalysisConfig,
    findings: &mut Vec<Finding>,
) {
    for ft in files {
        if cfg.float_exempt.iter().any(|m| ft.name.contains(m.as_str())) {
            continue;
        }
        for ci in 0..ft.code.len() {
            let t = ft.ct(ci);
            if ft.in_test(t.line) {
                continue;
            }
            if t.kind == Kind::Punct && (t.text == "==" || t.text == "!=") {
                let prev_float = ci > 0
                    && ft.ct(ci - 1).kind == Kind::Num
                    && is_float_literal(ft.ctext(ci - 1));
                // `x == 1.0` and `x == -1.0` both count.
                let mut rhs = ci + 1;
                if ft.ctext(rhs) == "-" {
                    rhs += 1;
                }
                let next_float = rhs < ft.code.len()
                    && ft.ct(rhs).kind == Kind::Num
                    && is_float_literal(ft.ctext(rhs));
                if prev_float || next_float {
                    findings.push(Finding {
                        rule: Rule::FloatEq,
                        file: ft.name.clone(),
                        line: t.line,
                        message: format!(
                            "float `{}` comparison — use `.to_bits()` for exact \
                             identity or an explicit tolerance, or pragma-justify \
                             the exact-zero/sentinel check",
                            t.text
                        ),
                        justified: None,
                    });
                }
            } else if t.kind == Kind::Ident
                && t.text == "partial_cmp"
                && ci > 0
                && ft.ctext(ci - 1) == "."
                && ft.ctext(ci + 1) == "("
            {
                if let Some(close) = ft.match_paren_fwd(ci + 1) {
                    if ft.ctext(close + 1) == "."
                        && (ft.ctext(close + 2) == "unwrap" || ft.ctext(close + 2) == "expect")
                    {
                        findings.push(Finding {
                            rule: Rule::FloatCmp,
                            file: ft.name.clone(),
                            line: t.line,
                            message: "NaN-unsafe `partial_cmp(..).unwrap()` — use \
                                      `total_cmp` for float orderings"
                                .into(),
                            justified: None,
                        });
                    }
                }
            }
        }
    }
}
