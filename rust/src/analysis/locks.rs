//! Rule family `lock_order` / `lock_class` / `poison_policy`: classify
//! every `Mutex`/`RwLock` acquisition site, check each class's poison
//! policy, and build the acquisition-order graph (intra-function guard
//! extents plus call-graph edges), failing on cycles.
//!
//! ## Model
//!
//! A **lock class** is the field or binding name of a declared
//! `Mutex`/`RwLock` (`queues`, `warm`, …) — the unit the crate's ordering
//! comments reason about ("queues before warm"). Classes are discovered
//! from declarations; every discovered class must appear in the
//! [`AnalysisConfig::lock_policies`] table, so a new lock cannot land
//! unclassified.
//!
//! A guard bound by a plain `let` whose initializer is exactly the
//! acquisition chain (`let mut q = shared.queues.lock().unwrap();` or
//! `let w = lock_clean(&slot.warm);`) is modeled as **held to the end of
//! its enclosing block**. Any longer chain (`.peek(g)`, `.take()`,
//! let-else patterns) is a **temporary** with expression extent — the
//! guard drops at the end of the statement, so it contributes no ordering
//! edges. This deliberately under-approximates a few same-statement holds
//! (an `if let` scrutinee temporary) and never invents a hold that isn't
//! there; the crate's idioms keep real multi-lock extents `let`-bound.
//!
//! Within a held extent, edges come from (a) further direct acquisitions
//! and (b) bare crate-function calls (`try_steal_reads(..)`), whose
//! transitive lock sets are computed by fixpoint over the call graph.
//! Method calls and `Path::qualified()` calls are not traversed — the
//! former can't be resolved without types, and both would smear unrelated
//! `fn new`-style names together. Test code is excluded throughout.

use super::tokenizer::Kind;
use super::{AnalysisConfig, FileTokens, Finding, LockEdge, LockPolicy, LockSite, Rule};
use std::collections::{BTreeMap, BTreeSet};

/// Poison-handling shape observed at a site.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Shape {
    /// `.unwrap()` — fail-loud.
    Unwrap,
    /// `.expect(..)` — fail-loud.
    Expect,
    /// `.unwrap_or_else(|p| p.into_inner())` — recover.
    Recover,
    /// `lock_clean(..)` — recover via the shared helper.
    LockClean,
    /// Poison-tolerant read (`.map(..).unwrap_or(..)`, `.ok()`, …).
    Tolerant,
    /// `try_lock()` — the match on the result handles poison explicitly;
    /// exempt from the policy check, still an acquisition for ordering.
    TryLock,
    /// Anything else — flagged: poison handling must be recognizable.
    Raw,
}

impl Shape {
    fn name(self) -> &'static str {
        match self {
            Shape::Unwrap => "unwrap",
            Shape::Expect => "expect",
            Shape::Recover => "recover",
            Shape::LockClean => "lock_clean",
            Shape::Tolerant => "tolerant",
            Shape::TryLock => "try_lock",
            Shape::Raw => "raw",
        }
    }
}

/// One acquisition site in code coordinates, before extent analysis.
struct Site {
    /// Code index of the method/helper ident (`lock` / `lock_clean`).
    ci: usize,
    class: String,
    shape: Shape,
    /// End of the full acquisition expression (code index of its last
    /// token), used for guard-binding detection.
    expr_end: usize,
    /// Code index where the receiver chain starts (for `let` detection).
    chain_start: usize,
    line: u32,
}

/// A function's body span in one file, in code coordinates.
struct FnBody {
    name: String,
    file: usize,
    open: usize,
    close: usize,
}

const KEYWORDS: &[&str] = &[
    "if", "else", "while", "match", "for", "loop", "return", "fn", "let", "move", "unsafe",
    "in", "as", "break", "continue", "ref", "impl", "pub", "use", "where", "struct", "enum",
    "trait", "type", "mod", "const", "static", "crate", "super", "Self", "self", "dyn",
    "mut", "async", "await",
];

pub(crate) fn lock_discipline(
    files: &[FileTokens],
    cfg: &AnalysisConfig,
    findings: &mut Vec<Finding>,
) -> (Vec<LockSite>, Vec<LockEdge>) {
    // ---- pass 1: declared lock classes -------------------------------
    // `name: …Mutex<…>` fields / typed lets, and `let name = …Mutex::new`
    // bindings. Function parameter lists are skipped (`m: &Mutex<T>` in a
    // helper is a borrow, not a new class).
    let mut declared: BTreeMap<String, (String, u32, &'static str)> = BTreeMap::new();
    let fns = collect_fns(files);
    for (fi, ft) in files.iter().enumerate() {
        let params = param_ranges(ft);
        for ci in 0..ft.code.len() {
            let kind_name = match ft.ctext(ci) {
                "Mutex" => "Mutex",
                "RwLock" => "RwLock",
                _ => continue,
            };
            if ft.ct(ci).kind != Kind::Ident
                || ft.in_test(ft.ct(ci).line)
                || params.iter().any(|&(a, b)| ci > a && ci < b)
            {
                continue;
            }
            // Walk back over type-position tokens to the `:` or `=`.
            let mut j = ci as i64 - 1;
            while j >= 0 {
                let t = ft.ctext(j as usize);
                if ft.ct(j as usize).kind == Kind::Ident || t == "::" || t == "<" {
                    j -= 1;
                } else {
                    break;
                }
            }
            if j < 1 {
                continue;
            }
            let j = j as usize;
            let name = match ft.ctext(j) {
                // `name: Mutex<..>` (struct field, typed let, struct-literal
                // init of a lock field — all register the same class name).
                ":" => {
                    let cand = ft.ct(j - 1);
                    if cand.kind == Kind::Ident && !KEYWORDS.contains(&cand.text.as_str()) {
                        Some(cand.text.clone())
                    } else {
                        None
                    }
                }
                // `let [mut] name = …Mutex::new(..)`.
                "=" => {
                    let mut k = j as i64 - 1;
                    let cand = if k >= 0 && ft.ct(k as usize).kind == Kind::Ident {
                        let c = ft.ct(k as usize).text.clone();
                        k -= 1;
                        Some(c)
                    } else {
                        None
                    };
                    if k >= 0 && ft.ctext(k as usize) == "mut" {
                        k -= 1;
                    }
                    if k >= 0 && ft.ctext(k as usize) == "let" {
                        cand
                    } else {
                        None
                    }
                }
                _ => None,
            };
            if let Some(name) = name {
                declared
                    .entry(name)
                    .or_insert((ft.name.clone(), ft.ct(ci).line, kind_name));
            }
        }
        let _ = fi;
    }
    // Every discovered class must be registered in the policy table.
    for (class, (file, line, _)) in &declared {
        if cfg.policy_of(class).is_none() {
            findings.push(Finding {
                rule: Rule::LockClass,
                file: file.clone(),
                line: *line,
                message: format!(
                    "lock class `{class}` is not registered in the poison-policy \
                     table (analysis::AnalysisConfig::crate_default)"
                ),
                justified: None,
            });
        }
    }

    // ---- pass 2: acquisition sites per function ----------------------
    let mut all_sites: Vec<LockSite> = Vec::new();
    let mut edges: Vec<LockEdge> = Vec::new();
    // fn name -> classes directly acquired anywhere in (any) body.
    let mut direct: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
    // fn name -> bare crate functions called anywhere in body.
    let mut calls: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
    let fn_names: BTreeSet<String> = fns.iter().map(|f| f.name.clone()).collect();
    // Held-extent call sites to expand after the fixpoint:
    // (held class, callee, file name, line).
    let mut held_calls: Vec<(String, String, String, u32)> = Vec::new();

    for f in &fns {
        // The recover helper's own `m.lock()` is the implementation of
        // the recover shape, not a classifiable site.
        if f.name == "lock_clean" {
            continue;
        }
        let ft = &files[f.file];
        let mut sites: Vec<Site> = Vec::new();
        for ci in f.open..f.close {
            if let Some(site) = acquisition_at(ft, ci, f, &declared) {
                sites.push(site);
            }
        }
        for s in &sites {
            let policy = cfg.policy_of(&s.class);
            if declared.contains_key(&s.class) && policy.is_none() {
                // Already reported at the declaration; skip per-site noise.
            } else if !declared.contains_key(&s.class) {
                findings.push(Finding {
                    rule: Rule::LockClass,
                    file: ft.name.clone(),
                    line: s.line,
                    message: format!(
                        "cannot classify lock acquisition (receiver `{}` is not a \
                         declared lock class)",
                        s.class
                    ),
                    justified: None,
                });
            }
            if let Some(policy) = policy {
                check_policy(ft, s, policy, findings);
            }
            direct
                .entry(f.name.clone())
                .or_default()
                .insert(s.class.clone());
        }
        // Bare calls anywhere in the body feed the call graph.
        for ci in f.open..f.close {
            if let Some(callee) = bare_call_at(ft, ci, &fn_names) {
                calls.entry(f.name.clone()).or_default().insert(callee);
            }
        }
        // Guard extents: direct edges + held calls.
        for (i, s) in sites.iter().enumerate() {
            let held = guard_extent(ft, s, f);
            all_sites.push(LockSite {
                file: ft.name.clone(),
                line: s.line,
                class: s.class.clone(),
                shape: s.shape.name().into(),
                held: held.is_some(),
            });
            let Some(extent_end) = held else { continue };
            for other in sites.iter().skip(i + 1) {
                if other.ci < extent_end && other.class != s.class {
                    edges.push(LockEdge {
                        from: s.class.clone(),
                        to: other.class.clone(),
                        file: ft.name.clone(),
                        line: other.line,
                        via: "direct".into(),
                    });
                }
            }
            for ci in s.expr_end + 1..extent_end {
                if let Some(callee) = bare_call_at(ft, ci, &fn_names) {
                    held_calls.push((
                        s.class.clone(),
                        callee,
                        ft.name.clone(),
                        ft.ct(ci).line,
                    ));
                }
            }
        }
    }

    // ---- pass 3: transitive lock sets (fixpoint) ---------------------
    let mut locks_in: BTreeMap<String, BTreeSet<String>> = direct.clone();
    loop {
        let mut changed = false;
        for (fname, callees) in &calls {
            let mut add: BTreeSet<String> = BTreeSet::new();
            for callee in callees {
                if let Some(set) = locks_in.get(callee) {
                    add.extend(set.iter().cloned());
                }
            }
            let entry = locks_in.entry(fname.clone()).or_default();
            for c in add {
                if entry.insert(c) {
                    changed = true;
                }
            }
        }
        if !changed {
            break;
        }
    }
    for (held, callee, file, line) in held_calls {
        if let Some(set) = locks_in.get(&callee) {
            for cls in set {
                if *cls != held {
                    edges.push(LockEdge {
                        from: held.clone(),
                        to: cls.clone(),
                        file: file.clone(),
                        line,
                        via: callee.clone(),
                    });
                }
            }
        }
    }

    // ---- pass 4: cycle detection over the class graph ----------------
    // Self-edges (same class re-acquired under its own guard) are direct
    // deadlocks with std's non-reentrant Mutex; A→…→A cycles are the
    // classic two-thread deadlock. Either fails the build.
    let mut adj: BTreeMap<&str, BTreeSet<&str>> = BTreeMap::new();
    for e in &edges {
        adj.entry(e.from.as_str()).or_default().insert(e.to.as_str());
    }
    if let Some(cycle) = find_cycle(&adj) {
        let path = cycle.join(" -> ");
        // Witness: the edge closing the cycle.
        let last = cycle.len().saturating_sub(1);
        let witness = edges
            .iter()
            .find(|e| last > 0 && e.from == cycle[last - 1] && e.to == cycle[last]);
        let (file, line, via) = match witness {
            Some(e) => (e.file.clone(), e.line, e.via.clone()),
            None => ("<graph>".into(), 0, "?".into()),
        };
        findings.push(Finding {
            rule: Rule::LockOrder,
            file,
            line,
            message: format!(
                "lock-order cycle: {path} (closing edge via `{via}`) — two threads \
                 interleaving these acquisitions deadlock"
            ),
            justified: None,
        });
    }

    (all_sites, edges)
}

/// Recognize an acquisition at code index `ci`; returns its site record.
fn acquisition_at(
    ft: &FileTokens,
    ci: usize,
    f: &FnBody,
    declared: &BTreeMap<String, (String, u32, &'static str)>,
) -> Option<Site> {
    let t = ft.ct(ci);
    if t.kind != Kind::Ident || ft.in_test(t.line) {
        return None;
    }
    match t.text.as_str() {
        "lock" | "try_lock" | "read" | "write" => {
            if ci == 0 || ft.ctext(ci - 1) != "." || ft.ctext(ci + 1) != "(" {
                return None;
            }
            let (class, chain_start) = resolve_receiver(ft, ci - 1, f, declared)?;
            // `.read()`/`.write()` are lock ops only on a declared RwLock
            // (otherwise they're io calls and no class will match).
            if (t.text == "read" || t.text == "write")
                && declared.get(&class).map(|d| d.2) != Some("RwLock")
            {
                return None;
            }
            let close = ft.match_paren_fwd(ci + 1)?;
            let (shape, expr_end) = if t.text == "try_lock" {
                (Shape::TryLock, close)
            } else {
                classify_shape(ft, close)
            };
            Some(Site { ci, class, shape, expr_end, chain_start, line: t.line })
        }
        "lock_clean" => {
            if ft.ctext(ci + 1) != "(" || (ci > 0 && ft.ctext(ci - 1) == ".") {
                return None;
            }
            // Skip the declaration itself (`fn lock_clean…`) and imports.
            if ci > 0 && (ft.ctext(ci - 1) == "fn" || ft.ctext(ci - 1) == "::") {
                return None;
            }
            let close = ft.match_paren_fwd(ci + 1)?;
            // Class = last field ident of the argument chain, skipping a
            // trailing index group: `lock_clean(&shared.warm[si])` → warm.
            let mut k = close as i64 - 1;
            if k >= 0 && ft.ctext(k as usize) == "]" {
                let open = ft.match_bracket_back(k as usize)?;
                k = open as i64 - 1;
            }
            if k < 0 || ft.ct(k as usize).kind != Kind::Ident {
                return None;
            }
            let class = ft.ct(k as usize).text.clone();
            Some(Site {
                ci,
                class,
                shape: Shape::LockClean,
                expr_end: close,
                chain_start: ci,
                line: t.line,
            })
        }
        _ => None,
    }
}

/// Resolve the receiver chain before the `.` at `dot_ci` to a lock class:
/// the nearest field ident (`shared.queues.lock` → `queues`), skipping a
/// trailing index group (`shards[si].lock` → `shards`). Falls back to the
/// enclosing statement when the nearest ident is an opaque local (closure
/// parameter): if exactly one declared class appears in the statement,
/// that's the class.
fn resolve_receiver(
    ft: &FileTokens,
    dot_ci: usize,
    f: &FnBody,
    declared: &BTreeMap<String, (String, u32, &'static str)>,
) -> Option<(String, usize)> {
    let mut p = dot_ci as i64 - 1;
    if p >= 0 && ft.ctext(p as usize) == "]" {
        let open = ft.match_bracket_back(p as usize)?;
        p = open as i64 - 1;
    }
    if p < 0 || ft.ct(p as usize).kind != Kind::Ident {
        return None;
    }
    let cand = ft.ct(p as usize).text.clone();
    // Chain start: walk further back over `a.b.c` / index groups / `&`.
    let mut start = p as usize;
    let mut q = p - 1;
    while q >= 1 {
        let txt = ft.ctext(q as usize);
        if txt == "." && ft.ct(q as usize - 1).kind == Kind::Ident {
            start = q as usize - 1;
            q -= 2;
        } else if txt == "]" {
            match ft.match_bracket_back(q as usize) {
                Some(open) if open >= 1 => {
                    q = open as i64 - 1;
                }
                _ => break,
            }
        } else if txt == "&" {
            start = q as usize;
            break;
        } else {
            break;
        }
    }
    if declared.contains_key(&cand) {
        return Some((cand, start));
    }
    // Statement fallback for opaque locals: `|s| s.lock()…` inside an
    // iterator chain over a declared class.
    let mut lo = dot_ci;
    while lo > f.open {
        let t = ft.ctext(lo - 1);
        if t == ";" || t == "{" || t == "}" {
            break;
        }
        lo -= 1;
    }
    let mut hi = dot_ci;
    while hi < f.close {
        let t = ft.ctext(hi);
        if t == ";" || t == "}" {
            break;
        }
        hi += 1;
    }
    let mut seen: BTreeSet<&str> = BTreeSet::new();
    for k in lo..hi {
        let t = ft.ct(k);
        if t.kind == Kind::Ident && declared.contains_key(&t.text) {
            seen.insert(t.text.as_str());
        }
    }
    if seen.len() == 1 {
        let class = seen.iter().next().map(|s| s.to_string())?;
        return Some((class, start));
    }
    Some((cand, start))
}

/// Classify the poison-handling shape following `lock()`'s close paren.
/// Returns the shape and the code index of the shape's last token.
fn classify_shape(ft: &FileTokens, close: usize) -> (Shape, usize) {
    if ft.ctext(close + 1) != "." {
        return (Shape::Raw, close);
    }
    let m = close + 2;
    match ft.ctext(m) {
        "unwrap" if ft.ctext(m + 1) == "(" => {
            let end = ft.match_paren_fwd(m + 1).unwrap_or(m + 1);
            (Shape::Unwrap, end)
        }
        "expect" if ft.ctext(m + 1) == "(" => {
            let end = ft.match_paren_fwd(m + 1).unwrap_or(m + 1);
            (Shape::Expect, end)
        }
        "unwrap_or_else" if ft.ctext(m + 1) == "(" => {
            let end = ft.match_paren_fwd(m + 1).unwrap_or(m + 1);
            let recovers = (m + 1..end).any(|k| ft.ctext(k) == "into_inner");
            (if recovers { Shape::Recover } else { Shape::Raw }, end)
        }
        "map" if ft.ctext(m + 1) == "(" => {
            // `.map(..).unwrap_or(..)` / `.map_or(..)`-style tolerant reads.
            let map_end = ft.match_paren_fwd(m + 1).unwrap_or(m + 1);
            if ft.ctext(map_end + 1) == "."
                && (ft.ctext(map_end + 2).starts_with("unwrap_or")
                    || ft.ctext(map_end + 2) == "ok")
            {
                let end = ft
                    .match_paren_fwd(map_end + 3)
                    .unwrap_or(map_end + 2);
                (Shape::Tolerant, end)
            } else {
                (Shape::Raw, map_end)
            }
        }
        "ok" | "unwrap_or" | "unwrap_or_default" | "map_or" if ft.ctext(m + 1) == "(" => {
            let end = ft.match_paren_fwd(m + 1).unwrap_or(m + 1);
            (Shape::Tolerant, end)
        }
        _ => (Shape::Raw, close),
    }
}

/// Policy compliance per site.
fn check_policy(ft: &FileTokens, s: &Site, policy: LockPolicy, findings: &mut Vec<Finding>) {
    let violation = match (policy, s.shape) {
        (_, Shape::TryLock) => None,
        (LockPolicy::FailLoud, Shape::Unwrap | Shape::Expect) => None,
        (LockPolicy::FailLoud, Shape::Recover | Shape::LockClean | Shape::Tolerant) => {
            Some(format!(
                "fail-loud lock class `{}` must propagate poison \
                 (`.lock().unwrap()`), found a recover shape — a dead peer's \
                 state would be silently reused",
                s.class
            ))
        }
        (LockPolicy::Recover, Shape::Recover | Shape::LockClean | Shape::Tolerant) => None,
        (LockPolicy::Recover, Shape::Unwrap | Shape::Expect) => Some(format!(
            "recover lock class `{}` must tolerate poison \
             (`unwrap_or_else(|p| p.into_inner())` or `lock_clean`) — a recovered \
             engine panic must not poison this state for every later request",
            s.class
        )),
        (_, Shape::Raw) => Some(format!(
            "unrecognized poison handling on lock class `{}` — use the \
             registered fail-loud or recover shape",
            s.class
        )),
    };
    if let Some(message) = violation {
        findings.push(Finding {
            rule: Rule::PoisonPolicy,
            file: ft.name.clone(),
            line: s.line,
            message,
            justified: None,
        });
    }
}

/// A site is a held guard when it is the entire initializer of a plain
/// `let` binding: `let [mut] name = <acquisition chain> ;`. Returns the
/// code index of the enclosing block's close brace (the extent end).
fn guard_extent(ft: &FileTokens, s: &Site, f: &FnBody) -> Option<usize> {
    if !matches!(
        s.shape,
        Shape::Unwrap | Shape::Expect | Shape::Recover | Shape::LockClean
    ) {
        return None;
    }
    if ft.ctext(s.expr_end + 1) != ";" {
        return None;
    }
    // `let [mut] name =` directly before the chain.
    let mut p = s.chain_start as i64 - 1;
    if p < 0 || ft.ctext(p as usize) != "=" {
        return None;
    }
    p -= 1;
    if p < 0 || ft.ct(p as usize).kind != Kind::Ident {
        return None;
    }
    p -= 1;
    if p >= 0 && ft.ctext(p as usize) == "mut" {
        p -= 1;
    }
    if p < 0 || ft.ctext(p as usize) != "let" {
        return None;
    }
    // Extent: walk forward to the close of the innermost enclosing block.
    let mut depth = 0i64;
    let mut ci = s.expr_end + 1;
    while ci < f.close {
        match ft.ctext(ci) {
            "{" => depth += 1,
            "}" => {
                depth -= 1;
                if depth < 0 {
                    return Some(ci);
                }
            }
            _ => {}
        }
        ci += 1;
    }
    Some(f.close)
}

/// Bare crate-function call at `ci`: `ident(` with no leading `.`/`::`
/// (method and qualified calls are excluded — see module docs) where the
/// ident names a crate `fn`.
fn bare_call_at(ft: &FileTokens, ci: usize, fn_names: &BTreeSet<String>) -> Option<String> {
    let t = ft.ct(ci);
    if t.kind != Kind::Ident || ft.ctext(ci + 1) != "(" {
        return None;
    }
    if ci > 0 {
        let prev = ft.ctext(ci - 1);
        if prev == "." || prev == "::" || prev == "fn" {
            return None;
        }
    }
    if KEYWORDS.contains(&t.text.as_str()) || !fn_names.contains(&t.text) {
        return None;
    }
    Some(t.text.clone())
}

/// All non-test function bodies across the files.
fn collect_fns(files: &[FileTokens]) -> Vec<FnBody> {
    let mut out = Vec::new();
    for (fi, ft) in files.iter().enumerate() {
        let n = ft.code.len();
        for ci in 0..n {
            if ft.ctext(ci) != "fn" || ft.ct(ci).kind != Kind::Ident {
                continue;
            }
            if ft.in_test(ft.ct(ci).line) {
                continue;
            }
            if ci + 1 >= n {
                continue;
            }
            // `fn(usize) -> T` pointer types have no name ident.
            if ft.ct(ci + 1).kind != Kind::Ident {
                continue;
            }
            let name = ft.ctext(ci + 1).to_string();
            // Param list: first `(` outside the generic brackets.
            let mut j = ci + 2;
            let mut angle = 0i64;
            let mut params_open: Option<usize> = None;
            while j < n {
                match ft.ctext(j) {
                    "<" => angle += 1,
                    ">" => angle -= 1,
                    ">>" => angle -= 2,
                    "(" if angle <= 0 => {
                        params_open = Some(j);
                        break;
                    }
                    "{" | ";" => break,
                    _ => {}
                }
                j += 1;
            }
            let Some(po) = params_open else { continue };
            let Some(pc) = ft.match_paren_fwd(po) else { continue };
            // Body: first `{` before any `;` (trait method decls have none).
            let mut k = pc + 1;
            let mut open = None;
            while k < n {
                match ft.ctext(k) {
                    "{" => {
                        open = Some(k);
                        break;
                    }
                    ";" => break,
                    _ => {}
                }
                k += 1;
            }
            let Some(open) = open else { continue };
            let Some(&close) = ft.brace_match.get(&open) else { continue };
            out.push(FnBody { name, file: fi, open, close });
        }
    }
    out
}

/// Parameter-list spans of every `fn` in the file (used to skip
/// `m: &Mutex<T>` parameters during class discovery).
fn param_ranges(ft: &FileTokens) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let n = ft.code.len();
    for ci in 0..n {
        if ft.ctext(ci) != "fn" || ft.ct(ci).kind != Kind::Ident {
            continue;
        }
        let mut j = ci + 1;
        let mut angle = 0i64;
        while j < n {
            match ft.ctext(j) {
                "<" => angle += 1,
                ">" => angle -= 1,
                ">>" => angle -= 2,
                "(" if angle <= 0 => {
                    if let Some(close) = ft.match_paren_fwd(j) {
                        out.push((j, close));
                    }
                    break;
                }
                "{" | ";" => break,
                _ => {}
            }
            j += 1;
        }
    }
    out
}

/// First cycle in the class digraph (DFS coloring), as the node path
/// `[a, b, …, a]`.
fn find_cycle<'a>(adj: &BTreeMap<&'a str, BTreeSet<&'a str>>) -> Option<Vec<String>> {
    #[derive(Clone, Copy, PartialEq)]
    enum Color {
        White,
        Gray,
        Black,
    }
    let nodes: Vec<&str> = adj.keys().copied().collect();
    let mut color: BTreeMap<&str, Color> = BTreeMap::new();
    for e in adj.values().flatten() {
        color.insert(e, Color::White);
    }
    for n in &nodes {
        color.insert(n, Color::White);
    }
    fn dfs<'a>(
        node: &'a str,
        adj: &BTreeMap<&'a str, BTreeSet<&'a str>>,
        color: &mut BTreeMap<&'a str, Color>,
        stack: &mut Vec<&'a str>,
    ) -> Option<Vec<String>> {
        color.insert(node, Color::Gray);
        stack.push(node);
        if let Some(nexts) = adj.get(node) {
            for &next in nexts {
                match color.get(next).copied().unwrap_or(Color::White) {
                    Color::Gray => {
                        // Cycle: slice the stack from `next` onward.
                        let start = stack.iter().position(|&s| s == next).unwrap_or(0);
                        let mut path: Vec<String> =
                            stack[start..].iter().map(|s| s.to_string()).collect();
                        path.push(next.to_string());
                        return Some(path);
                    }
                    Color::White => {
                        if let Some(c) = dfs(next, adj, color, stack) {
                            return Some(c);
                        }
                    }
                    Color::Black => {}
                }
            }
        }
        stack.pop();
        color.insert(node, Color::Black);
        None
    }
    for n in nodes {
        if color.get(n).copied().unwrap_or(Color::White) == Color::White {
            let mut stack = Vec::new();
            if let Some(c) = dfs(n, adj, &mut color, &mut stack) {
                return Some(c);
            }
        }
    }
    None
}
