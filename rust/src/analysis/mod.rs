//! In-tree static analyzer (`lkgp lint`): the crate's concurrency,
//! unsafety, panic, float, and observability invariants enforced as
//! machine-checked rules over its own sources.
//!
//! Seven PRs of guarantees — bit-identical parity under every thread
//! count, a typed-error serving surface with a deliberate mutex-poison
//! policy, replica answers never stale — were previously enforced by
//! convention and reviewer memory. This module re-derives them on every
//! `cargo test` / `./ci.sh` run instead:
//!
//! 1. **lock discipline** (`lock_order` / `lock_class` / `poison_policy`)
//!    — every `Mutex` acquisition site is classified against a registered
//!    lock class, an intra-function + call-edge acquisition-order graph
//!    is built, cycles fail the build, and each class's poison policy
//!    (fail-loud `.unwrap()` vs recover `into_inner()`) is checked at
//!    every site. See [`locks`].
//! 2. **unsafe audit** (`unsafe_safety`) — every `unsafe` occurrence
//!    needs an adjacent `// SAFETY:` comment; the full inventory lands in
//!    `ANALYSIS.json`.
//! 3. **panic discipline** (`panic`) — no `unwrap()` / `expect()` /
//!    `panic!`-family macros in the serving hot path outside
//!    `#[cfg(test)]`. Lock/condvar poison unwraps are exempt here (the
//!    poison-policy rule owns them — a fail-loud queue lock *must*
//!    unwrap).
//! 4. **float discipline** (`float_eq` / `float_cmp`) — no `==`/`!=`
//!    against float literals and no NaN-unsafe `partial_cmp().unwrap()`
//!    outside approved parity modules; exact comparisons go through
//!    `.to_bits()`, orderings through `total_cmp`.
//! 5. **drift lints** (`stats_drift` / `bench_gate` / `doc_drift`) —
//!    every `ServiceStats` counter must be printed or serialized
//!    somewhere in non-test code, every `BENCH_*.json` a bench emits
//!    must have a ci.sh gate, and the prose contract holds: every
//!    `docs/*.md` path named in a source file exists, every bench
//!    artifact is inventoried in docs/ci.md, and every CLI flag in the
//!    `lkgp` usage surface is documented somewhere under docs/.
//!
//! Surviving sites carry an inline pragma — `// lint: allow(<rule>) —
//! <reason>` on the offending line or the line above — and every pragma
//! is inventoried in `ANALYSIS.json` with its justification. The same
//! analyzer runs as the `lkgp lint` subcommand and as
//! `tests/lint.rs` under plain `cargo test`, so the tier-1 gate carries
//! it even where `cargo bench` is skipped. See `docs/static_analysis.md`
//! for the rule catalog.

pub mod tokenizer;

mod drift;
mod locks;
mod rules;

use crate::json::Json;
use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::path::{Path, PathBuf};
use tokenizer::{tokenize, Kind, Token};

/// How a lock class must handle a poisoned mutex (docs/robustness.md).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum LockPolicy {
    /// Poison means a peer worker died mid-protocol: propagate the panic
    /// (`.lock().unwrap()` / `.expect(..)`). Queue and handshake locks.
    FailLoud,
    /// Poison must not take the shard down: reclaim the inner state
    /// (`unwrap_or_else(|p| p.into_inner())`, `lock_clean`, or another
    /// poison-tolerant shape). Cache and telemetry locks.
    Recover,
}

/// Analyzer configuration: the lock-class policy table plus the scopes
/// the panic/float/drift rules apply to. [`AnalysisConfig::crate_default`]
/// is the shipped tree's contract; fixtures build their own.
#[derive(Clone)]
pub struct AnalysisConfig {
    /// Registered lock classes (field / binding names of `Mutex`es) and
    /// their poison policy. A declared `Mutex` whose name is missing here
    /// is a `lock_class` finding — new locks must be classified.
    pub lock_policies: Vec<(String, LockPolicy)>,
    /// Hot-path scopes for the panic rule (substring match on the
    /// src-relative file name; `"linalg/"` covers the directory).
    pub hot_paths: Vec<String>,
    /// Modules exempt from the float rule (parity/test-support code that
    /// legitimately compares exact float values).
    pub float_exempt: Vec<String>,
    /// Name of the stats struct whose counters must all be observable.
    pub stats_struct: String,
}

impl AnalysisConfig {
    /// The shipped tree's invariants. The policy table is the
    /// authoritative registry: adding a `Mutex` to the crate without
    /// adding its class here fails `lkgp lint`.
    pub fn crate_default() -> Self {
        use LockPolicy::{FailLoud, Recover};
        let policies: &[(&str, LockPolicy)] = &[
            // Fail-loud: poison means a worker died mid-handshake; waiters
            // would otherwise hang forever on state no one will repair.
            ("queues", FailLoud),   // coordinator/service.rs pool queues
            ("slot", FailLoud),     // util/team.rs job hand-off slot
            ("done", FailLoud),     // util/team.rs completion latch
            ("submit", FailLoud),   // util/team.rs leader election
            ("rec", FailLoud),      // coordinator/trace.rs recorder (a torn trace must not pass)
            ("recorder", FailLoud), // coordinator/mod.rs recorder binding
            ("partials", FailLoud), // linalg/lanczos.rs scoped-thread partial sums
            // Recover: worst case a stale cache entry or a lost histogram
            // sample, which every consumer tolerates; a recovered engine
            // panic must not poison the shard for all later requests.
            ("warm", Recover),     // warm-start lineage LRU
            ("latency", Recover),  // latency histograms
            ("breakers", Recover), // circuit breakers
            ("state", Recover),    // refit-policy cadence/baseline map
            ("shards", Recover),   // engine slots (guarded by the busy flag)
            ("cache", Recover),    // lcbench task cache
            ("digests", Recover),  // lcbench fingerprint digests
            ("rng", Recover),      // chaos fault-plan RNG
        ];
        AnalysisConfig {
            lock_policies: policies
                .iter()
                .map(|(n, p)| (n.to_string(), *p))
                .collect(),
            hot_paths: vec![
                "coordinator/service.rs".into(),
                "gp/session.rs".into(),
                "linalg/".into(),
            ],
            float_exempt: vec!["testutil/".into()],
            stats_struct: "ServiceStats".into(),
        }
    }

    pub(crate) fn policy_of(&self, class: &str) -> Option<LockPolicy> {
        self.lock_policies
            .iter()
            .find(|(n, _)| n == class)
            .map(|(_, p)| *p)
    }
}

/// Rule families. `name()` is the pragma identifier.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Rule {
    LockOrder,
    LockClass,
    PoisonPolicy,
    UnsafeSafety,
    Panic,
    FloatEq,
    FloatCmp,
    StatsDrift,
    BenchGate,
    DocDrift,
    /// Malformed `// lint:` pragma (unknown rule, missing reason).
    Pragma,
}

impl Rule {
    pub fn name(self) -> &'static str {
        match self {
            Rule::LockOrder => "lock_order",
            Rule::LockClass => "lock_class",
            Rule::PoisonPolicy => "poison_policy",
            Rule::UnsafeSafety => "unsafe_safety",
            Rule::Panic => "panic",
            Rule::FloatEq => "float_eq",
            Rule::FloatCmp => "float_cmp",
            Rule::StatsDrift => "stats_drift",
            Rule::BenchGate => "bench_gate",
            Rule::DocDrift => "doc_drift",
            Rule::Pragma => "pragma",
        }
    }
}

/// One rule violation. `justified` carries the pragma reason when an
/// inline `// lint: allow(...)` covers the site; unjustified findings
/// fail the lint gate.
#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: Rule,
    pub file: String,
    pub line: u32,
    pub message: String,
    pub justified: Option<String>,
}

/// Inventory entry for one `unsafe` occurrence.
#[derive(Clone, Debug)]
pub struct UnsafeSite {
    pub file: String,
    pub line: u32,
    /// `block` / `fn` / `impl` / `extern`.
    pub kind: String,
    /// The adjacent `// SAFETY:` text, when present.
    pub safety: Option<String>,
    pub in_test: bool,
}

/// One parsed `// lint: allow(<rule>) — <reason>` pragma.
#[derive(Clone, Debug)]
pub struct Pragma {
    pub file: String,
    pub line: u32,
    pub rule: String,
    pub reason: String,
    /// The code line the pragma covers (its own line when it trails code,
    /// else the next code line below it).
    pub target_line: u32,
}

/// One classified lock acquisition site.
#[derive(Clone, Debug)]
pub struct LockSite {
    pub file: String,
    pub line: u32,
    pub class: String,
    /// Poison-handling shape observed: `unwrap`, `expect`, `recover`,
    /// `tolerant`, `lock_clean`, `try_lock`, or `raw`.
    pub shape: String,
    /// True when the guard is `let`-bound and held to end of block (the
    /// extent used for ordering edges).
    pub held: bool,
}

/// One acquisition-order edge: `from` was held while `to` was acquired
/// (`via` names the called function for call-graph edges, or `direct`).
#[derive(Clone, Debug)]
pub struct LockEdge {
    pub from: String,
    pub to: String,
    pub file: String,
    pub line: u32,
    pub via: String,
}

/// Full analysis result: findings plus the machine-readable inventories
/// serialized to `ANALYSIS.json`.
pub struct Analysis {
    pub findings: Vec<Finding>,
    pub unsafe_sites: Vec<UnsafeSite>,
    pub pragmas: Vec<Pragma>,
    pub lock_sites: Vec<LockSite>,
    pub lock_edges: Vec<LockEdge>,
    pub files_scanned: usize,
}

impl Analysis {
    /// Findings not covered by an inline pragma — these fail the gate.
    pub fn unjustified(&self) -> Vec<&Finding> {
        self.findings
            .iter()
            .filter(|f| f.justified.is_none())
            .collect()
    }

    /// Serialize the full inventory (docs/static_analysis.md documents
    /// the schema).
    pub fn to_json(&self) -> Json {
        let findings = self
            .findings
            .iter()
            .map(|f| {
                Json::obj(vec![
                    ("rule", Json::Str(f.rule.name().into())),
                    ("file", Json::Str(f.file.clone())),
                    ("line", Json::Num(f.line as f64)),
                    ("message", Json::Str(f.message.clone())),
                    (
                        "justified",
                        match &f.justified {
                            Some(r) => Json::Str(r.clone()),
                            None => Json::Null,
                        },
                    ),
                ])
            })
            .collect();
        let unsafes = self
            .unsafe_sites
            .iter()
            .map(|u| {
                Json::obj(vec![
                    ("file", Json::Str(u.file.clone())),
                    ("line", Json::Num(u.line as f64)),
                    ("kind", Json::Str(u.kind.clone())),
                    (
                        "safety",
                        match &u.safety {
                            Some(s) => Json::Str(s.clone()),
                            None => Json::Null,
                        },
                    ),
                    ("in_test", Json::Bool(u.in_test)),
                ])
            })
            .collect();
        let pragmas = self
            .pragmas
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("file", Json::Str(p.file.clone())),
                    ("line", Json::Num(p.line as f64)),
                    ("rule", Json::Str(p.rule.clone())),
                    ("reason", Json::Str(p.reason.clone())),
                ])
            })
            .collect();
        let sites = self
            .lock_sites
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("file", Json::Str(s.file.clone())),
                    ("line", Json::Num(s.line as f64)),
                    ("class", Json::Str(s.class.clone())),
                    ("shape", Json::Str(s.shape.clone())),
                    ("held", Json::Bool(s.held)),
                ])
            })
            .collect();
        let edges = self
            .lock_edges
            .iter()
            .map(|e| {
                Json::obj(vec![
                    ("from", Json::Str(e.from.clone())),
                    ("to", Json::Str(e.to.clone())),
                    ("file", Json::Str(e.file.clone())),
                    ("line", Json::Num(e.line as f64)),
                    ("via", Json::Str(e.via.clone())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("analysis", Json::Str("lkgp.lint".into())),
            ("version", Json::Num(1.0)),
            ("files_scanned", Json::Num(self.files_scanned as f64)),
            (
                "unjustified_findings",
                Json::Num(self.unjustified().len() as f64),
            ),
            ("findings", Json::Arr(findings)),
            ("unsafe_sites", Json::Arr(unsafes)),
            ("pragmas", Json::Arr(pragmas)),
            ("lock_sites", Json::Arr(sites)),
            ("lock_edges", Json::Arr(edges)),
        ])
    }
}

/// One source file handed to the analyzer (name is src-relative, with
/// forward slashes: `coordinator/service.rs`).
pub struct SourceFile {
    pub name: String,
    pub text: String,
}

/// Everything the rules scan: crate sources, bench sources (for the
/// bench-gate drift rule), the ci.sh script text, and the repo's
/// `docs/*.md` prose (for the doc-drift rule; `name` is the bare file
/// name, `ci.md`).
pub struct AnalysisInput {
    pub src: Vec<SourceFile>,
    pub benches: Vec<SourceFile>,
    pub ci_script: Option<String>,
    pub docs: Vec<SourceFile>,
}

impl AnalysisInput {
    /// Load from a crate root (the directory holding `src/`): walks
    /// `src/**/*.rs` and `benches/*.rs`, and reads `../ci.sh` and
    /// `../docs/*.md` when present (the repo layout used by `lkgp lint`
    /// and `tests/lint.rs`).
    pub fn load(crate_root: &Path) -> crate::Result<Self> {
        let src_dir = crate_root.join("src");
        let mut src = Vec::new();
        walk_rs(&src_dir, &src_dir, &mut src)?;
        let mut benches = Vec::new();
        let bench_dir = crate_root.join("benches");
        if bench_dir.is_dir() {
            walk_rs(&bench_dir, &bench_dir, &mut benches)?;
        }
        let ci_script = crate_root
            .parent()
            .map(|p| p.join("ci.sh"))
            .and_then(|p| std::fs::read_to_string(p).ok());
        let mut docs = Vec::new();
        if let Some(docs_dir) = crate_root.parent().map(|p| p.join("docs")) {
            if docs_dir.is_dir() {
                let mut entries: Vec<PathBuf> = std::fs::read_dir(&docs_dir)?
                    .filter_map(|e| e.ok().map(|e| e.path()))
                    .collect();
                entries.sort();
                for path in entries {
                    if path.extension().map_or(false, |e| e == "md") {
                        let name = path
                            .file_name()
                            .map(|n| n.to_string_lossy().into_owned())
                            .unwrap_or_default();
                        let text = std::fs::read_to_string(&path)?;
                        docs.push(SourceFile { name, text });
                    }
                }
            }
        }
        Ok(AnalysisInput { src, benches, ci_script, docs })
    }
}

fn walk_rs(root: &Path, dir: &Path, out: &mut Vec<SourceFile>) -> crate::Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            walk_rs(root, &path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .components()
                .map(|c| c.as_os_str().to_string_lossy().into_owned())
                .collect::<Vec<_>>()
                .join("/");
            let text = std::fs::read_to_string(&path)?;
            out.push(SourceFile { name: rel, text });
        }
    }
    Ok(())
}

/// Tokenized file plus the structural indexes the rules share: the
/// code-token view, brace matching, `#[cfg(test)]` line ranges, and
/// parsed pragmas.
pub(crate) struct FileTokens {
    pub name: String,
    pub toks: Vec<Token>,
    /// Indices into `toks` of non-comment tokens.
    pub code: Vec<usize>,
    /// `{` position -> matching `}` position, both in `code` coordinates.
    pub brace_match: HashMap<usize, usize>,
    /// Inclusive line ranges under `#[cfg(test)]` / `#[test]` items.
    pub test_ranges: Vec<(u32, u32)>,
    pub pragmas: Vec<Pragma>,
}

impl FileTokens {
    /// Code token at code-coordinate `ci`.
    pub fn ct(&self, ci: usize) -> &Token {
        &self.toks[self.code[ci]]
    }

    /// Code token text at `ci`, or `""` past the end.
    pub fn ctext(&self, ci: usize) -> &str {
        if ci < self.code.len() {
            &self.toks[self.code[ci]].text
        } else {
            ""
        }
    }

    pub fn in_test(&self, line: u32) -> bool {
        self.test_ranges
            .iter()
            .any(|&(a, b)| line >= a && line <= b)
    }

    /// Matching `)` for the `(` at code-coordinate `open_ci`.
    pub fn match_paren_fwd(&self, open_ci: usize) -> Option<usize> {
        let mut depth = 0i64;
        for ci in open_ci..self.code.len() {
            match self.ctext(ci) {
                "(" => depth += 1,
                ")" => {
                    depth -= 1;
                    if depth == 0 {
                        return Some(ci);
                    }
                }
                _ => {}
            }
        }
        None
    }

    /// Matching `(` for the `)` at code-coordinate `close_ci`.
    pub fn match_paren_back(&self, close_ci: usize) -> Option<usize> {
        let mut depth = 0i64;
        let mut ci = close_ci as i64;
        while ci >= 0 {
            match self.ctext(ci as usize) {
                ")" => depth += 1,
                "(" => {
                    depth -= 1;
                    if depth == 0 {
                        return Some(ci as usize);
                    }
                }
                _ => {}
            }
            ci -= 1;
        }
        None
    }

    /// Matching `[` for the `]` at code-coordinate `close_ci`.
    pub fn match_bracket_back(&self, close_ci: usize) -> Option<usize> {
        let mut depth = 0i64;
        let mut ci = close_ci as i64;
        while ci >= 0 {
            match self.ctext(ci as usize) {
                "]" => depth += 1,
                "[" => {
                    depth -= 1;
                    if depth == 0 {
                        return Some(ci as usize);
                    }
                }
                _ => {}
            }
            ci -= 1;
        }
        None
    }

    pub(crate) fn build(name: &str, text: &str) -> (FileTokens, Vec<Finding>) {
        let toks = tokenize(text);
        let code: Vec<usize> = toks
            .iter()
            .enumerate()
            .filter(|(_, t)| t.kind != Kind::Comment)
            .map(|(i, _)| i)
            .collect();
        let mut ft = FileTokens {
            name: name.to_string(),
            toks,
            code,
            brace_match: HashMap::new(),
            test_ranges: Vec::new(),
            pragmas: Vec::new(),
        };
        // Brace matching over the code view (string/char tokens can't
        // confuse it — the tokenizer already swallowed their contents).
        let mut stack: Vec<usize> = Vec::new();
        for ci in 0..ft.code.len() {
            match ft.ctext(ci) {
                "{" => stack.push(ci),
                "}" => {
                    if let Some(open) = stack.pop() {
                        ft.brace_match.insert(open, ci);
                    }
                }
                _ => {}
            }
        }
        ft.find_test_ranges();
        let findings = ft.parse_pragmas();
        (ft, findings)
    }

    /// Mark `#[cfg(test)] mod … { … }` / `#[test] fn … { … }` line
    /// ranges. The attribute's following brace group is the region; a
    /// `test` identifier anywhere inside the attribute counts (covers
    /// `cfg(test)` and `cfg(all(test, …))`).
    fn find_test_ranges(&mut self) {
        let n = self.code.len();
        let mut ranges = Vec::new();
        let mut ci = 0usize;
        while ci + 1 < n {
            if self.ctext(ci) == "#" && self.ctext(ci + 1) == "[" {
                let mut j = ci + 2;
                let mut depth = 1usize;
                let mut is_test = false;
                while j < n && depth > 0 {
                    match self.ctext(j) {
                        "[" => depth += 1,
                        "]" => depth -= 1,
                        "test" => is_test = true,
                        _ => {}
                    }
                    j += 1;
                }
                if is_test {
                    // First `{` or `;` after the attribute opens the item.
                    let mut k = j;
                    while k < n && self.ctext(k) != "{" && self.ctext(k) != ";" {
                        k += 1;
                    }
                    if k < n && self.ctext(k) == "{" {
                        if let Some(&close) = self.brace_match.get(&k) {
                            ranges.push((self.ct(ci).line, self.ct(close).line));
                            ci = j;
                            continue;
                        }
                    }
                }
                ci = j;
                continue;
            }
            ci += 1;
        }
        self.test_ranges = ranges;
    }

    /// Parse `// lint: allow(<rule>) — <reason>` pragmas out of comment
    /// tokens. Only comments that *begin* with `lint:` (after the
    /// comment markers) count — prose that merely mentions the pragma
    /// syntax, like this doc comment, is not a pragma. Malformed pragmas
    /// (unknown rule / missing reason) are findings — a justification
    /// that doesn't parse must not silently stop justifying.
    fn parse_pragmas(&mut self) -> Vec<Finding> {
        const KNOWN: &[&str] = &[
            "lock_order",
            "lock_class",
            "poison_policy",
            "unsafe_safety",
            "panic",
            "float_eq",
            "float_cmp",
            "stats_drift",
            "bench_gate",
            "doc_drift",
        ];
        let mut findings = Vec::new();
        let mut pragmas = Vec::new();
        // For reason wrapping: stripped comment text per line, and the set
        // of lines holding code (a wrapped reason stops at either).
        let mut comment_body: BTreeMap<u32, String> = BTreeMap::new();
        for t in &self.toks {
            if t.kind == Kind::Comment {
                let body = t
                    .text
                    .trim_start_matches('/')
                    .trim_start_matches(['!', '*'])
                    .trim_start();
                comment_body.entry(t.line).or_default().push_str(body);
            }
        }
        let code_lines: BTreeSet<u32> =
            self.code.iter().map(|&i| self.toks[i].line).collect();
        for t in &self.toks {
            if t.kind != Kind::Comment {
                continue;
            }
            let body = t
                .text
                .trim_start_matches('/')
                .trim_start_matches(['!', '*'])
                .trim_start();
            let Some(rest) = body.strip_prefix("lint:") else { continue };
            let rest = rest.trim_start();
            let Some(rest) = rest.strip_prefix("allow(") else {
                findings.push(Finding {
                    rule: Rule::Pragma,
                    file: self.name.clone(),
                    line: t.line,
                    message: "malformed lint pragma: expected `lint: allow(<rule>) — <reason>`"
                        .into(),
                    justified: None,
                });
                continue;
            };
            let Some(close) = rest.find(')') else {
                findings.push(Finding {
                    rule: Rule::Pragma,
                    file: self.name.clone(),
                    line: t.line,
                    message: "malformed lint pragma: unclosed allow(...)".into(),
                    justified: None,
                });
                continue;
            };
            let rule = rest[..close].trim().to_string();
            if !KNOWN.contains(&rule.as_str()) {
                findings.push(Finding {
                    rule: Rule::Pragma,
                    file: self.name.clone(),
                    line: t.line,
                    message: format!("lint pragma names unknown rule `{rule}`"),
                    justified: None,
                });
                continue;
            }
            let reason = rest[close + 1..]
                .trim_start()
                .trim_start_matches(['—', '-', ':'])
                .trim()
                .to_string();
            if reason.is_empty() {
                findings.push(Finding {
                    rule: Rule::Pragma,
                    file: self.name.clone(),
                    line: t.line,
                    message: format!(
                        "lint pragma for `{rule}` is missing a reason (allow(..) — <why>)"
                    ),
                    justified: None,
                });
                continue;
            }
            // An own-line pragma's reason may wrap onto the contiguous
            // comment lines below it; stop at code, an empty comment, or
            // another pragma. Trailing pragmas never wrap (the next line's
            // comment belongs to the next statement).
            let mut reason = reason;
            if !code_lines.contains(&t.line) {
                let mut l = t.line + 1;
                while !code_lines.contains(&l) {
                    let Some(next) = comment_body.get(&l) else { break };
                    let next = next.trim();
                    if next.is_empty() || next.starts_with("lint:") {
                        break;
                    }
                    reason.push(' ');
                    reason.push_str(next);
                    l += 1;
                }
            }
            pragmas.push(Pragma {
                file: self.name.clone(),
                line: t.line,
                rule,
                reason,
                target_line: 0,
            });
        }
        // Resolve each pragma's target: its own line when code shares the
        // line (trailing pragma), else the next code line below.
        for p in &mut pragmas {
            let mut target = p.line;
            let mut next_code: Option<u32> = None;
            let mut same_line = false;
            for &i in &self.code {
                let l = self.toks[i].line;
                if l == p.line {
                    same_line = true;
                    break;
                }
                if l > p.line {
                    next_code = Some(l);
                    break;
                }
            }
            if !same_line {
                if let Some(l) = next_code {
                    target = l;
                }
            }
            p.target_line = target;
        }
        self.pragmas = pragmas;
        findings
    }
}

/// Run every rule over the input. This is the single entry point shared
/// by the CLI, the integration test, and the fixtures.
pub fn analyze(input: &AnalysisInput, cfg: &AnalysisConfig) -> Analysis {
    let mut findings: Vec<Finding> = Vec::new();
    let mut files: Vec<FileTokens> = Vec::new();
    for sf in &input.src {
        let (ft, mut pf) = FileTokens::build(&sf.name, &sf.text);
        findings.append(&mut pf);
        files.push(ft);
    }
    let mut unsafe_sites = Vec::new();
    rules::unsafe_audit(&files, &mut findings, &mut unsafe_sites);
    rules::panic_discipline(&files, cfg, &mut findings);
    rules::float_discipline(&files, cfg, &mut findings);
    let (lock_sites, lock_edges) = locks::lock_discipline(&files, cfg, &mut findings);
    drift::stats_drift(&files, cfg, &mut findings);
    drift::bench_gate(input, &mut findings);
    drift::doc_drift(&files, input, &mut findings);
    // Apply pragmas: a finding is justified when a same-rule pragma
    // targets its line.
    let mut pragmas: Vec<Pragma> = Vec::new();
    for ft in &files {
        pragmas.extend(ft.pragmas.iter().cloned());
    }
    for f in &mut findings {
        if let Some(p) = pragmas.iter().find(|p| {
            p.file == f.file && p.rule == f.rule.name() && p.target_line == f.line
        }) {
            f.justified = Some(p.reason.clone());
        }
    }
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule.name()).cmp(&(b.file.as_str(), b.line, b.rule.name()))
    });
    Analysis {
        findings,
        unsafe_sites,
        pragmas,
        lock_sites,
        lock_edges,
        files_scanned: files.len(),
    }
}

/// Analyze a single in-memory source (the fixture entry point).
pub fn analyze_source(name: &str, text: &str, cfg: &AnalysisConfig) -> Analysis {
    let input = AnalysisInput {
        src: vec![SourceFile { name: name.into(), text: text.into() }],
        benches: Vec::new(),
        ci_script: None,
        docs: Vec::new(),
    };
    analyze(&input, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_ranges_cover_cfg_test_mods() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\n";
        let (ft, _) = FileTokens::build("a.rs", src);
        assert!(!ft.in_test(1));
        assert!(ft.in_test(4));
    }

    #[test]
    fn pragma_targets_next_code_line() {
        let src = "// lint: allow(panic) — justified here\nfoo.unwrap();\nbar.unwrap(); // lint: allow(panic) — trailing\n";
        let (ft, findings) = FileTokens::build("a.rs", src);
        assert!(findings.is_empty(), "{findings:?}");
        assert_eq!(ft.pragmas.len(), 2);
        assert_eq!(ft.pragmas[0].target_line, 2);
        assert_eq!(ft.pragmas[1].target_line, 3);
        assert_eq!(ft.pragmas[0].reason, "justified here");
    }

    #[test]
    fn pragma_reason_wraps_across_comment_lines() {
        let src = "// lint: allow(panic) — first half\n// second half.\nfoo.unwrap();\n// unrelated comment\nbar();\n";
        let (ft, findings) = FileTokens::build("a.rs", src);
        assert!(findings.is_empty(), "{findings:?}");
        assert_eq!(ft.pragmas.len(), 1);
        assert_eq!(ft.pragmas[0].reason, "first half second half.");
        assert_eq!(ft.pragmas[0].target_line, 3);
    }

    #[test]
    fn malformed_pragmas_are_findings() {
        let src = "// lint: allow(no_such_rule) — x\n// lint: allow(panic)\n";
        let (_, findings) = FileTokens::build("a.rs", src);
        assert_eq!(findings.len(), 2);
        assert!(findings.iter().all(|f| f.rule == Rule::Pragma));
    }
}
