//! Micro bench harness (criterion is not in the offline crate set).
//!
//! `harness = false` bench binaries use [`Bench`] for warmup + repeated
//! timing with median/mean/stddev reporting, and [`Table`] for the
//! paper-figure tables the benches print and dump to `results/*.csv`.

use std::time::{Duration, Instant};

/// Timing statistics over repetitions.
#[derive(Clone, Debug)]
pub struct Stats {
    pub median: Duration,
    pub mean: Duration,
    pub stddev: Duration,
    pub reps: usize,
}

impl Stats {
    pub fn median_secs(&self) -> f64 {
        self.median.as_secs_f64()
    }
}

/// Run `f` with warmup and repetitions; returns stats.
///
/// `min_reps` runs are always performed; more are added until
/// `min_total` wall time is accumulated (like criterion's target time,
/// scaled down for CI).
pub fn bench(mut f: impl FnMut(), min_reps: usize, min_total: Duration) -> Stats {
    // warmup
    f();
    let mut samples: Vec<Duration> = Vec::new();
    let start = Instant::now();
    while samples.len() < min_reps || (start.elapsed() < min_total && samples.len() < 1000) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort();
    let median = samples[samples.len() / 2];
    let mean_ns = samples.iter().map(|d| d.as_nanos() as f64).sum::<f64>() / samples.len() as f64;
    let var = samples
        .iter()
        .map(|d| {
            let x = d.as_nanos() as f64 - mean_ns;
            x * x
        })
        .sum::<f64>()
        / samples.len() as f64;
    Stats {
        median,
        mean: Duration::from_nanos(mean_ns as u64),
        stddev: Duration::from_nanos(var.sqrt() as u64),
        reps: samples.len(),
    }
}

/// Time a single run (for expensive cases where repetition is infeasible,
/// e.g. the naive Cholesky wall at large sizes).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Column-aligned table printer that also accumulates CSV rows.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        println!("{}", header.join(" | "));
        println!("{}", vec!["---"; header.len()].join("-|-"));
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        println!("{}", cells.join(" | "));
        self.rows.push(cells);
    }

    /// Write accumulated rows to CSV under results/.
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        let header: Vec<&str> = self.header.iter().map(String::as_str).collect();
        crate::util::write_csv(path, &header, &self.rows)
    }
}

/// Parse common bench CLI flags: `--quick` shrinks workloads for CI.
///
/// Also auto-engages on boxes with <= 2 cores (this repo's CI runs on a
/// single core where the paper-scale sweeps take tens of minutes); pass
/// `--full` to force the full workload anyway. The paper-scale runs used
/// for EXPERIMENTS.md pass explicit `--max-size`/`--seeds` flags.
pub fn is_quick() -> bool {
    if std::env::args().any(|a| a == "--full") {
        return false;
    }
    if std::env::args().any(|a| a == "--quick") || std::env::var("LKGP_BENCH_QUICK").is_ok() {
        return true;
    }
    std::thread::available_parallelism().map(|n| n.get() <= 2).unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        let stats = bench(|| std::thread::sleep(Duration::from_micros(100)),
                          5, Duration::from_millis(2));
        assert!(stats.reps >= 5);
        assert!(stats.median >= Duration::from_micros(80));
        assert!(stats.mean >= Duration::from_micros(80));
    }

    #[test]
    fn table_accumulates_and_writes() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.write_csv("/tmp/lkgp_bench_table.csv").unwrap();
        let text = std::fs::read_to_string("/tmp/lkgp_bench_table.csv").unwrap();
        assert!(text.contains("a,b"));
        assert!(text.contains("1,2"));
    }
}
