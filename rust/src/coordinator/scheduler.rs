//! Freeze-thaw scheduler: the round-based AutoML control loop.
//!
//! Each round the scheduler (1) steps every running trial one epoch on the
//! workload, (2) records observations, (3) periodically refits the LKGP
//! through the prediction service, (4) queries batched final-value
//! predictions for every known config, and (5) re-allocates compute:
//! promote the most promising paused/pending trials, pause the rest,
//! early-stop hopeless ones per the configured policy.
//!
//! The "workload" is abstract ([`EpochRunner`]) — the simulated LCBench
//! task in examples/benches, a real training farm behind an RPC in
//! production.

use crate::gp::session::{Answer, Query};
use crate::gp::Theta;
use crate::linalg::Matrix;

use super::policy::{Decision, Policy, TrialForecast};
use super::service::PredictClient;
use super::store::{CurveStore, WarmStart};
use super::trial::{Registry, TrialId, TrialStatus};

/// Executes one training epoch of a trial and returns the metric value.
pub trait EpochRunner {
    fn run_epoch(&mut self, trial: TrialId, config: &[f64], epoch: usize) -> f64;
}

impl<F> EpochRunner for F
where
    F: FnMut(TrialId, &[f64], usize) -> f64,
{
    fn run_epoch(&mut self, trial: TrialId, config: &[f64], epoch: usize) -> f64 {
        self(trial, config, epoch)
    }
}

/// [`EpochRunner`] over one corpus task: trial `i` replays config `i`'s
/// recorded curve (trials are registered in config order; a trial id
/// beyond the task's configs is a caller bug and panics, like the
/// historical `SimRunner` indexing did). Requests past a config's
/// observed prefix repeat its last recorded value — an early-stopped dump
/// has nothing later to reveal, and a constant tail is the conservative
/// stand-in. For full-length tasks (every simulated one) this is exactly
/// the historical `SimRunner` clamp, value for value.
pub struct CorpusRunner {
    pub task: std::sync::Arc<crate::lcbench::Task>,
}

impl EpochRunner for CorpusRunner {
    fn run_epoch(&mut self, trial: TrialId, _config: &[f64], epoch: usize) -> f64 {
        let i = trial.0;
        let last = self.task.lengths[i].max(1) - 1;
        self.task.curves[(i, epoch.min(last).min(self.task.m() - 1))]
    }
}

/// Scheduler configuration.
#[derive(Clone, Debug)]
pub struct SchedulerCfg {
    /// Max trials training concurrently per round.
    pub max_concurrent: usize,
    /// Refit hyper-parameters every this many rounds.
    pub refit_every: usize,
    /// Between refits, push freshly trained epochs through the service
    /// every this many rounds as a `Request::Observe` — a warm re-solve
    /// under the standing theta with zero MLL evaluations (0 = off, the
    /// historical cadence where new epochs only reach the model at the
    /// next refit). When the backend's refit policy reports drift, the
    /// scheduler refits immediately instead of waiting out `refit_every`.
    pub observe_every: usize,
    /// Total epoch budget across all trials.
    pub epoch_budget: usize,
    /// Early-stop policy.
    pub policy: Policy,
    /// RNG seed for refits/sampling.
    pub seed: u64,
}

impl Default for SchedulerCfg {
    fn default() -> Self {
        SchedulerCfg {
            max_concurrent: 4,
            refit_every: 5,
            observe_every: 0,
            epoch_budget: 200,
            policy: Policy::PredictedFinal { delta: 0.0, threshold: 0.95 },
            seed: 0,
        }
    }
}

/// Outcome of a scheduling run.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Rounds executed.
    pub rounds: usize,
    /// Total epochs spent.
    pub epochs_spent: usize,
    /// Best observed value and its trial.
    pub best_value: f64,
    pub best_trial: Option<TrialId>,
    /// Trials early-stopped.
    pub stopped: usize,
    /// Trials completed to the final epoch.
    pub completed: usize,
    /// Mean GP-prediction batch factor (queries per engine call).
    pub batch_factor: f64,
    /// History of (round, epochs_spent, best_so_far).
    pub trace: Vec<(usize, usize, f64)>,
}

/// The freeze-thaw coordinator loop.
pub struct Scheduler {
    pub registry: Registry,
    pub store: CurveStore,
    pub cfg: SchedulerCfg,
    theta: Vec<f64>,
}

impl Scheduler {
    pub fn new(max_epochs: usize, cfg: SchedulerCfg) -> Self {
        Scheduler {
            registry: Registry::new(),
            store: CurveStore::new(max_epochs),
            cfg,
            theta: Vec::new(),
        }
    }

    /// Register candidate configurations.
    pub fn add_candidates(&mut self, configs: &[Vec<f64>]) -> Vec<TrialId> {
        configs.iter().map(|c| self.registry.add(c.clone())).collect()
    }

    /// Run the loop until the epoch budget is exhausted or nothing is left
    /// to train. `service` is any [`PredictClient`]: the single-task
    /// [`super::service::PredictionService`] or a [`super::service::ShardHandle`]
    /// of a multi-task pool.
    pub fn run(
        &mut self,
        runner: &mut dyn EpochRunner,
        service: &dyn PredictClient,
    ) -> crate::Result<RunReport> {
        let max_epochs = self.store.max_epochs();
        let mut rounds = 0;
        let mut trace = Vec::new();

        // bootstrap: start the first max_concurrent trials
        self.promote_pending();

        while self.registry.total_epochs() < self.cfg.epoch_budget {
            let running = self.registry.by_status(TrialStatus::Running);
            if running.is_empty() {
                break;
            }
            rounds += 1;

            // 1-2. train one epoch per running trial, record observations
            for id in &running {
                let trial = self.registry.get(*id);
                let epoch = trial.epochs_trained();
                let config = trial.config.clone();
                let value = runner.run_epoch(*id, &config, epoch);
                self.registry.observe(*id, value, max_epochs)?;
                if self.registry.total_epochs() >= self.cfg.epoch_budget {
                    break;
                }
            }

            // 3-5. periodically refit + re-allocate
            if rounds % self.cfg.refit_every == 0 {
                self.replan(service, rounds)?;
            } else if self.cfg.observe_every > 0
                && rounds % self.cfg.observe_every == 0
                && !self.theta.is_empty()
            {
                // O(warm-solve) ingestion between refits: extend the
                // model with this round's epochs under the standing theta
                // (zero MLL evals). An early refit happens only when the
                // service's refit policy flags cadence/drift.
                if let Ok(snapshot) = self.store.snapshot(&self.registry) {
                    if service.observe(snapshot, self.theta.clone())?.refit_due {
                        self.replan(service, rounds)?;
                    }
                }
            }
            self.promote_pending();

            let best = self.registry.best_observed().map(|(_, v)| v).unwrap_or(0.0);
            trace.push((rounds, self.registry.total_epochs(), best));
        }

        let (best_trial, best_value) = self
            .registry
            .best_observed()
            .map(|(id, v)| (Some(id), v))
            .unwrap_or((None, 0.0));
        Ok(RunReport {
            rounds,
            epochs_spent: self.registry.total_epochs(),
            best_value,
            best_trial,
            stopped: self.registry.by_status(TrialStatus::Stopped).len(),
            completed: self.registry.by_status(TrialStatus::Completed).len(),
            batch_factor: service.batch_factor(),
            trace,
        })
    }

    /// Refit + forecast + promote/pause/stop.
    fn replan(&mut self, service: &dyn PredictClient, round: usize) -> crate::Result<()> {
        let snapshot = match self.store.snapshot(&self.registry) {
            Ok(s) => s,
            Err(_) => return Ok(()), // nothing observed yet
        };

        // refit hyper-parameters (warm start from previous theta)
        let theta0 = if self.theta.is_empty() {
            Theta::default_packed(snapshot.data.d())
        } else {
            self.theta.clone()
        };
        self.theta = service.refit(snapshot.clone(), theta0, self.cfg.seed + round as u64)?;
        // Record the fitted theta as warm-start lineage: future snapshots
        // carry it, so any solver downstream (including a fresh service
        // shard) can start from it instead of the prior mean.
        self.store.record_warm(WarmStart {
            generation: snapshot.generation,
            theta: self.theta.clone(),
            row_ids: (*snapshot.row_ids).clone(),
            m: snapshot.data.m(),
            alpha: Vec::new(),
            xq: None,
            cross: Vec::new(),
            precond: None,
            path: None,
        });

        // forecast finals for every active (non-terminal) config
        let active: Vec<TrialId> = snapshot
            .all_ids
            .iter()
            .copied()
            .filter(|&id| {
                matches!(
                    self.registry.get(id).status,
                    TrialStatus::Running | TrialStatus::Paused | TrialStatus::Pending
                )
            })
            .collect();
        if active.is_empty() {
            return Ok(());
        }
        let d = snapshot.all_x.cols();
        let mut xq = Matrix::zeros(active.len(), d);
        let id_to_row: std::collections::HashMap<TrialId, usize> = snapshot
            .all_ids
            .iter()
            .enumerate()
            .map(|(r, &id)| (id, r))
            .collect();
        for (row, id) in active.iter().enumerate() {
            let src = id_to_row[id];
            let src_row: Vec<f64> = snapshot.all_x.row(src).to_vec();
            xq.row_mut(row).copy_from_slice(&src_row);
        }
        // one typed query through the service; coalesces with any other
        // same-generation traffic into a single shared solve
        let answers = service.query(
            snapshot.clone(),
            self.theta.clone(),
            vec![Query::MeanAtFinal { xq }],
        )?;
        let preds = match answers.into_iter().next() {
            Some(Answer::Final(v)) => v,
            _ => {
                return Err(crate::LkgpError::Coordinator(
                    "prediction service answered MeanAtFinal with an unexpected shape".into(),
                ))
            }
        };

        // undo standardization for decisions in original units
        let preds: Vec<(f64, f64)> = preds
            .iter()
            .map(|&(mu, var)| (snapshot.ytf.undo_mean(mu), snapshot.ytf.undo_var(var)))
            .collect();

        let best = self.registry.best_observed().map(|(_, v)| v).unwrap_or(0.0);
        let mut lasts: Vec<f64> = self
            .registry
            .by_status(TrialStatus::Running)
            .iter()
            .filter_map(|&id| self.registry.get(id).last_value())
            .collect();
        lasts.sort_by(f64::total_cmp);
        let median_last = lasts.get(lasts.len() / 2).copied().unwrap_or(0.0);

        // early-stop per policy, then promote the top-q by optimistic value
        let mut ranked: Vec<(TrialId, f64)> = Vec::new();
        for (id, &(mean, var)) in active.iter().zip(&preds) {
            let trial = self.registry.get(*id);
            let fc = TrialForecast {
                mean,
                var,
                last: trial.last_value().unwrap_or(0.0),
                epochs: trial.epochs_trained(),
            };
            // never stop untouched configs — they carry prior uncertainty
            if fc.epochs > 0 {
                match self.cfg.policy.decide(&fc, best, median_last) {
                    Decision::Stop => {
                        self.registry.set_status(*id, TrialStatus::Stopped);
                        continue;
                    }
                    Decision::Pause => {
                        self.registry.set_status(*id, TrialStatus::Paused);
                    }
                    Decision::Continue => {}
                }
            }
            // acquisition: optimistic final value (UCB with kappa = 1)
            ranked.push((*id, mean + var.sqrt()));
        }
        ranked.sort_by(|a, b| b.1.total_cmp(&a.1));

        // top-q run, the rest pause (pending stay pending until promoted)
        for (rank, (id, _)) in ranked.iter().enumerate() {
            let status = self.registry.get(*id).status;
            if rank < self.cfg.max_concurrent {
                if matches!(status, TrialStatus::Paused | TrialStatus::Pending | TrialStatus::Running) {
                    self.registry.set_status(*id, TrialStatus::Running);
                }
            } else if status == TrialStatus::Running {
                self.registry.set_status(*id, TrialStatus::Paused);
            }
        }
        Ok(())
    }

    /// Fill free slots with pending trials (exploration bootstrap).
    fn promote_pending(&mut self) {
        let running = self.registry.by_status(TrialStatus::Running).len();
        let mut free = self.cfg.max_concurrent.saturating_sub(running);
        for id in self.registry.by_status(TrialStatus::Pending) {
            if free == 0 {
                break;
            }
            self.registry.set_status(id, TrialStatus::Running);
            free -= 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::PredictionService;
    use crate::lcbench::{Preset, Task};
    use crate::rng::Pcg64;
    use crate::runtime::RustEngine;

    /// Runner backed by a simulated task.
    struct SimRunner {
        task: Task,
        map: Vec<usize>, // trial row -> task config index
    }

    impl EpochRunner for SimRunner {
        fn run_epoch(&mut self, trial: TrialId, _config: &[f64], epoch: usize) -> f64 {
            self.task.curves[(self.map[trial.0], epoch.min(self.task.m() - 1))]
        }
    }

    fn build(n: usize, seed: u64) -> (Scheduler, SimRunner) {
        let mut rng = Pcg64::new(seed);
        let task = Task::generate(Preset::FashionMnist, n, &mut rng);
        let cfg = SchedulerCfg {
            max_concurrent: 3,
            refit_every: 4,
            epoch_budget: 120,
            ..Default::default()
        };
        let mut sched = Scheduler::new(task.m(), cfg);
        let configs: Vec<Vec<f64>> = (0..n).map(|i| task.configs.row(i).to_vec()).collect();
        sched.add_candidates(&configs);
        let map = (0..n).collect();
        (sched, SimRunner { task, map })
    }

    #[test]
    fn run_respects_budget_and_concurrency() {
        let (mut sched, mut runner) = build(10, 1);
        let service = PredictionService::spawn(Box::<RustEngine>::default());
        let report = sched.run(&mut runner, &service).unwrap();
        assert!(report.epochs_spent <= 120 + 3);
        assert!(report.rounds > 0);
        assert!(report.best_value > 0.5, "best={}", report.best_value);
        // trace is monotone in best value
        for w in report.trace.windows(2) {
            assert!(w[1].2 >= w[0].2);
        }
    }

    #[test]
    fn freeze_thaw_saves_epochs_vs_full_training() {
        // With 10 configs x 52 epochs = 520 full epochs; the scheduler
        // must find a near-best config within a 120-epoch budget.
        let (mut sched, mut runner) = build(10, 2);
        let oracle_best = (0..10)
            .map(|i| runner.task.curves[(i, runner.task.m() - 1)])
            .fold(f64::NEG_INFINITY, f64::max);
        let service = PredictionService::spawn(Box::<RustEngine>::default());
        let report = sched.run(&mut runner, &service).unwrap();
        assert!(report.epochs_spent < 130);
        assert!(
            report.best_value > oracle_best - 0.08,
            "best={} oracle={oracle_best}",
            report.best_value
        );
    }

    #[test]
    fn policy_stops_bad_trials() {
        let (mut sched, mut runner) = build(12, 3);
        sched.cfg.policy = Policy::PredictedFinal { delta: 0.0, threshold: 0.9 };
        sched.cfg.epoch_budget = 200;
        let service = PredictionService::spawn(Box::<RustEngine>::default());
        let report = sched.run(&mut runner, &service).unwrap();
        // the simulator creates clearly-bad configs; some must be stopped
        // or paused rather than trained to completion
        assert!(report.stopped + sched.registry.by_status(TrialStatus::Paused).len() > 0);
    }
}
