//! Early-stopping / promotion policies for freeze-thaw scheduling.
//!
//! Policies consume the GP's final-value predictions — this is exactly the
//! AutoML use the paper motivates: "predict learning curves accurately
//! based on results from partial training [to decide] whether to continue
//! training or to stop early".

/// A trial's prediction context at decision time.
#[derive(Clone, Copy, Debug)]
pub struct TrialForecast {
    /// Predicted final value (original units).
    pub mean: f64,
    /// Predictive variance (original units).
    pub var: f64,
    /// Last observed value.
    pub last: f64,
    /// Epochs trained so far.
    pub epochs: usize,
}

/// Decision for one trial.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Decision {
    Continue,
    Pause,
    Stop,
}

/// Early-stop policy.
#[derive(Clone, Copy, Debug)]
pub enum Policy {
    /// Stop when P(final < best - delta) > threshold (paper-motivated:
    /// uses the GP's probabilistic extrapolation).
    PredictedFinal { delta: f64, threshold: f64 },
    /// Classic median rule on the *current* value (no GP; ablation).
    MedianRule,
    /// Pause when the optimistic bound mean + kappa*sigma trails the best.
    UcbRule { kappa: f64 },
}

impl Policy {
    /// Decide for one trial given the incumbent best final value and the
    /// median of last-observed values across running trials.
    pub fn decide(&self, f: &TrialForecast, best: f64, median_last: f64) -> Decision {
        match *self {
            Policy::PredictedFinal { delta, threshold } => {
                let sigma = f.var.sqrt().max(1e-9);
                // P(final < best - delta)
                let z = (best - delta - f.mean) / sigma;
                if phi(z) > threshold {
                    Decision::Stop
                } else {
                    Decision::Continue
                }
            }
            Policy::MedianRule => {
                if f.epochs >= 4 && f.last < median_last {
                    Decision::Stop
                } else {
                    Decision::Continue
                }
            }
            Policy::UcbRule { kappa } => {
                let ucb = f.mean + kappa * f.var.sqrt();
                if ucb < best {
                    Decision::Pause
                } else {
                    Decision::Continue
                }
            }
        }
    }
}

/// Standard normal CDF (Abramowitz-Stegun erf approximation, |err|<1.5e-7).
pub fn phi(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.327_591_1 * x);
    let y = 1.0
        - (((((1.061_405_429 * t - 1.453_152_027) * t) + 1.421_413_741) * t - 0.284_496_736)
            * t
            + 0.254_829_592)
            * t
            * (-x * x).exp();
    sign * y
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phi_matches_known_values() {
        assert!((phi(0.0) - 0.5).abs() < 1e-7);
        assert!((phi(1.959_964) - 0.975).abs() < 1e-4);
        assert!((phi(-1.959_964) - 0.025).abs() < 1e-4);
        assert!(phi(8.0) > 0.999999);
        assert!(phi(-8.0) < 1e-6);
    }

    #[test]
    fn predicted_final_stops_hopeless_trials() {
        let p = Policy::PredictedFinal { delta: 0.01, threshold: 0.95 };
        // confident bad trial
        let bad = TrialForecast { mean: 0.5, var: 1e-4, last: 0.48, epochs: 10 };
        assert_eq!(p.decide(&bad, 0.9, 0.6), Decision::Stop);
        // promising trial
        let good = TrialForecast { mean: 0.92, var: 1e-4, last: 0.8, epochs: 10 };
        assert_eq!(p.decide(&good, 0.9, 0.6), Decision::Continue);
        // uncertain trial is spared
        let unsure = TrialForecast { mean: 0.5, var: 0.5, last: 0.4, epochs: 2 };
        assert_eq!(p.decide(&unsure, 0.9, 0.6), Decision::Continue);
    }

    #[test]
    fn median_rule_spares_young_trials() {
        let p = Policy::MedianRule;
        let young = TrialForecast { mean: 0.0, var: 1.0, last: 0.1, epochs: 2 };
        assert_eq!(p.decide(&young, 0.9, 0.5), Decision::Continue);
        let old_bad = TrialForecast { mean: 0.0, var: 1.0, last: 0.1, epochs: 6 };
        assert_eq!(p.decide(&old_bad, 0.9, 0.5), Decision::Stop);
    }

    #[test]
    fn ucb_rule_pauses_not_stops() {
        let p = Policy::UcbRule { kappa: 2.0 };
        let trailing = TrialForecast { mean: 0.6, var: 0.001, last: 0.55, epochs: 5 };
        assert_eq!(p.decide(&trailing, 0.9, 0.5), Decision::Pause);
        let contender = TrialForecast { mean: 0.85, var: 0.01, last: 0.8, epochs: 5 };
        assert_eq!(p.decide(&contender, 0.9, 0.5), Decision::Continue);
    }
}
