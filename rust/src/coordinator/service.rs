//! Prediction serving: single-task worker services and the multi-task
//! sharded [`ServicePool`].
//!
//! This is the vLLM-router pattern scaled to this workload: many
//! concurrent callers (scheduler rounds, UI, benches) enqueue typed
//! [`Query`] batches (`MeanAtFinal`, `Variance`, `Quantiles`,
//! `MeanAtSteps`, ... — `PredictFinal` remains as a compatibility front);
//! a worker drains the queue and coalesces all queries that target the
//! same model generation into a single `Engine::answer_batch` call (one
//! artifact execution / one batched CG shared across every variant), then
//! scatters the per-caller responses. Refits and sampling requests pass
//! through the same queue, preserving order within a generation.
//!
//! Two front-ends share the same batching core:
//!
//! * [`PredictionService`] — the original single-task service: one worker
//!   thread owning one engine, fed through an mpsc channel. Cold solves
//!   only (stable baseline).
//! * [`ServicePool`] — the multi-task serving layer: engine shard
//!   *buckets* behind a shared worker pool. Requests are addressed by
//!   task id and routed through a deterministic hash table
//!   (`PoolCfg::buckets`; the default 0 keeps the historical 1:1
//!   task-per-bucket layout), so a 10k-task corpus materializes at most
//!   `buckets` engines instead of 10k. Same-generation `PredictFinal`
//!   batches coalesce *across* concurrent callers per bucket, submission
//!   applies backpressure (bounded per-bucket queues), and every bucket
//!   tracks latency/queue-depth/warm-start metrics. Each bucket caches
//!   converged `alpha` (and fitted theta) lineage per `(task, generation)`
//!   as a [`WarmStart`] so the next generation's near-identical
//!   masked-Kronecker solve starts from the prior solution instead of
//!   zero (see `linalg::cg_batch_warm`). The replica generation fence is
//!   per TASK: one task's write never retires another task's reads.
//!
//! Online ingestion rides [`Request::Observe`]: extending a learning
//! curve by an epoch only grows the observed mask of the fixed latent
//! grid (PAPER.md), so the worker re-solves the training system warm from
//! the task's converged lineage — zero MLL evaluations — and a refit
//! policy (`PoolCfg::{refit_every_epochs, refit_drift}`) decides when
//! theta is actually stale and a real `Refit` is worth enqueueing
//! (docs/serving.md).
//!
//! Schedulers drive either front-end through the [`PredictClient`] trait.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::gp::session::{self, Answer, Posterior, Query};
use crate::gp::{SolverCfg, Theta};
use crate::linalg::Matrix;
use crate::metrics::LatencyHist;
use crate::runtime::Engine;
use crate::util::lock_clean;

use super::store::{Snapshot, WarmStart};

/// A request to the prediction service.
pub enum Request {
    /// Re-fit hyper-parameters on a snapshot.
    Refit {
        snapshot: Snapshot,
        theta0: Vec<f64>,
        seed: u64,
        resp: Sender<crate::Result<Vec<f64>>>,
    },
    /// Extend a task's curve in place. The caller has already appended
    /// the new epoch(s) to its registry and built the extended
    /// `snapshot`; the worker re-solves the training system warm from
    /// the task's converged lineage alpha (`gp::session::observe` — zero
    /// MLL evaluations, preconditioner factors reused while their own
    /// staleness check passes) and refreshes the task's `WarmStart`
    /// lineage at the new generation. A write for fencing purposes: the
    /// task's generation fence advances at enqueue, so replicas never
    /// serve a pre-`Observe` generation for this task. The reply carries
    /// the refit policy's verdict ([`ObserveReport::refit_due`]); the
    /// caller decides whether to enqueue the actual `Refit`.
    Observe {
        snapshot: Snapshot,
        /// Packed theta to solve under; empty = the task's lineage theta
        /// (falling back to the prior mean).
        theta: Vec<f64>,
        resp: Sender<crate::Result<ObserveReport>>,
    },
    /// Final-value prediction for query rows (standardized units).
    /// Compatibility front for `Query` with a single
    /// [`Query::MeanAtFinal`]; coalesces with typed-query traffic.
    PredictFinal {
        snapshot: Snapshot,
        theta: Vec<f64>,
        /// Normalized query configs.
        xq: Matrix,
        resp: Sender<crate::Result<Vec<(f64, f64)>>>,
    },
    /// A batch of typed posterior queries against one snapshot + theta.
    /// All queries in the batch — and any same-generation queries
    /// coalesced from concurrent callers — share one underlying solve
    /// (see `gp::session::Posterior::answer_batch`).
    Query {
        snapshot: Snapshot,
        theta: Vec<f64>,
        queries: Vec<Query>,
        resp: Sender<crate::Result<Vec<Answer>>>,
    },
    /// Posterior curve samples over [train; query] x grid.
    SampleCurves {
        snapshot: Snapshot,
        theta: Vec<f64>,
        xq: Matrix,
        samples: usize,
        seed: u64,
        resp: Sender<crate::Result<Vec<Matrix>>>,
    },
    /// Any request wrapped with an absolute deadline. Workers unwrap the
    /// envelope when they pick the request up and drop expired work with a
    /// typed [`crate::LkgpError::Timeout`] reply instead of spending solver
    /// time on an answer nobody is waiting for. Nested envelopes keep the
    /// tightest deadline. `ServicePool`s built with a `PoolCfg::deadline`
    /// wrap submissions automatically; requests arriving pre-wrapped keep
    /// their own deadline.
    Deadline {
        deadline: Instant,
        inner: Box<Request>,
    },
    /// Stop the worker.
    Shutdown,
}

/// Reply to a [`Request::Observe`]: the generation whose lineage now
/// carries the refreshed alpha, the warm re-solve's cost, and the refit
/// policy's verdict.
#[derive(Clone, Debug)]
pub struct ObserveReport {
    /// Generation of the extended snapshot the lineage was refreshed at.
    pub generation: u64,
    /// CG iterations the warm re-solve spent (0 when the previous alpha
    /// already satisfied the extended system's tolerance).
    pub cg_iters: usize,
    /// Operator rows the re-solve applied (`CgStats::mvm_rows`) — the
    /// number `BENCH_scale.json` compares against a full `Refit`'s MVM
    /// work for the >= 10x online-ingestion saving.
    pub mvm_rows: usize,
    /// True when the refit policy (`PoolCfg::{refit_every_epochs,
    /// refit_drift}`) judged theta stale: the caller should enqueue a
    /// real `Refit` for this task.
    pub refit_due: bool,
}

/// Generation a (possibly deadline-wrapped) WRITE targets, for the
/// per-task replica generation fence. Refits and observes both move a
/// task's model state forward; reads return None.
fn write_generation(req: &Request) -> Option<u64> {
    match req {
        Request::Refit { snapshot, .. } | Request::Observe { snapshot, .. } => {
            Some(snapshot.generation)
        }
        Request::Deadline { inner, .. } => write_generation(inner),
        _ => None,
    }
}

/// Terminally fail a request with a typed error, whatever its reply
/// channel flavor (deadline expiry, quarantine fail-fast).
fn fail_request(req: Request, err: crate::LkgpError) {
    match req {
        Request::Refit { resp, .. } => {
            let _ = resp.send(Err(err));
        }
        Request::Observe { resp, .. } => {
            let _ = resp.send(Err(err));
        }
        Request::PredictFinal { resp, .. } => {
            let _ = resp.send(Err(err));
        }
        Request::Query { resp, .. } => {
            let _ = resp.send(Err(err));
        }
        Request::SampleCurves { resp, .. } => {
            let _ = resp.send(Err(err));
        }
        Request::Deadline { inner, .. } => fail_request(*inner, err),
        Request::Shutdown => {}
    }
}

/// Shared service statistics (one instance per service / per pool shard).
#[derive(Default)]
pub struct ServiceStats {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub batched_queries: AtomicU64,
    pub latency: Mutex<LatencyHist>,
    /// Requests enqueued through a pool shard (submit path).
    pub enqueued: AtomicU64,
    /// Highest per-shard queue depth observed at enqueue time.
    pub peak_queue_depth: AtomicU64,
    /// Engine calls that ran with a warm-start guess.
    pub warm_hits: AtomicU64,
    /// Total per-RHS CG iterations reported by warm-capable engines.
    pub cg_iters: AtomicU64,
    /// Total per-RHS operator rows applied (`CgStats::mvm_rows`) — the
    /// true MVM work after warm starts, preconditioning, and active-set
    /// compaction.
    pub cg_mvm_rows: AtomicU64,
    /// Exact-generation hits in the keyed warm-start LRU (the queried
    /// generation's own lineage was cached).
    pub warm_cache_hits: AtomicU64,
    /// Keyed warm-cache misses (fell back to the most-recent lineage or
    /// the snapshot's own, or started cold).
    pub warm_cache_misses: AtomicU64,
    /// Underlying batched solves reported by the engine
    /// (`QueryOutcome::solves`) — plus, for pool shards, the solves run by
    /// read-only replicas: with coalescing, the session layer, and replica
    /// lineage reuse, many queries amortize into few solves.
    pub engine_solves: AtomicU64,
    /// Coalesced query groups answered by a read-only replica instead of
    /// the writer shard (replica fast path + replica solves).
    pub replica_hits: AtomicU64,
    /// Underlying solves replicas actually paid (0 when the cached lineage
    /// covered the queries; also counted into `engine_solves`).
    pub replica_solves: AtomicU64,
    /// Replica batches retired because a writer advanced the shard's
    /// generation fence mid-serve: the replica's answers were discarded
    /// and the requests were handed back to the writer, so no stale
    /// replica answer is ever delivered.
    pub stale_replica_retires: AtomicU64,
    /// Generations pre-warmed on refit completion: the writer ran the
    /// fresh generation's training solve right after fitting and cached a
    /// replica-ready lineage, so the first read burst against it forks
    /// instead of serializing on a cold solve. Pre-warm solves are counted
    /// here (plus `cg_iters`/`cg_mvm_rows`), NOT in `engine_solves`, which
    /// stays a query-path counter (the replay equalities and the
    /// `BENCH_replicas.json` gates depend on that).
    pub prewarmed: AtomicU64,
    /// Rank of the factored CG preconditioner used by this shard's most
    /// recent solve (0 = unpreconditioned). Makes the adaptive rank
    /// `PrecondCfg::Auto` picks by residual-trace decay of the pivoted
    /// Cholesky (`gp::operator`) observable in the pool report.
    pub precond_rank: AtomicU64,
    /// `Request::Observe` warm re-solves served — each one extended a
    /// task's curve with ZERO MLL evaluations (the refit path is the only
    /// MLL consumer by construction; see docs/serving.md).
    pub observes: AtomicU64,
    /// Operator rows applied by `Observe` re-solves alone (also counted
    /// into `cg_mvm_rows`). Against the refit path's MVM work this makes
    /// the >= 10x online-ingestion saving observable (`BENCH_scale.json`).
    pub observe_solve_mvm_rows: AtomicU64,
    /// Observes whose refit-policy verdict was "theta is stale"
    /// (`ObserveReport::refit_due` handed to the caller). Edge-triggered:
    /// firing re-arms the task's cadence, so an ignored verdict does not
    /// re-fire every epoch.
    pub refits_triggered: AtomicU64,
    /// Oversized stacked query batches the shard handle split into chunks
    /// before enqueueing (`PoolCfg::split_rows`), so a single giant batch
    /// fans across pool workers / read replicas instead of serializing on
    /// one shard writer. Counts batches split, not chunks produced.
    pub split_batches: AtomicU64,
    /// Engine panics caught and recovered by pool workers (writer or
    /// replica path). The shard survives; consecutive recoveries feed the
    /// circuit breaker (docs/robustness.md).
    pub panics_recovered: AtomicU64,
    /// Requests dropped at pick-up because their deadline had expired
    /// (typed `LkgpError::Timeout` reply; see `Request::Deadline`).
    pub timeouts: AtomicU64,
    /// Requests shed at submission because the shard queue stayed full for
    /// the whole bounded wait (`PoolCfg::submit_wait` / `try_submit`).
    pub shed: AtomicU64,
    /// Escalation-ladder rungs climbed by this shard's solves (0 on the
    /// healthy path; see `gp::lkgp` and docs/robustness.md).
    pub escalations: AtomicU64,
    /// Solves answered by the dense-Cholesky fallback rung.
    pub dense_fallbacks: AtomicU64,
    /// Typed engine failures delivered to callers from the writer path
    /// (ladder exhaustion, fit failures). Feeds the circuit breaker.
    pub solver_failures: AtomicU64,
    /// Times this shard's circuit breaker tripped into quarantine.
    pub quarantine_trips: AtomicU64,
    /// Submissions rejected fail-fast while the shard was quarantined
    /// (typed `LkgpError::Quarantined` reply).
    pub quarantine_rejects: AtomicU64,
    /// `CurveSamples` engine calls served pathwise with ZERO new CG
    /// solves (the lineage-warm fast path; docs/sampling.md). Writer and
    /// replica paths both count here.
    pub pathwise_hits: AtomicU64,
    /// Factored `B⁻¹` applies spent drawing pathwise samples (one per
    /// sample — the marginal per-sample cost `BENCH_samples.json` gates).
    pub sample_mvms: AtomicU64,
}

impl ServiceStats {
    /// Mean queries per engine call (batching factor).
    pub fn batch_factor(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed).max(1);
        self.batched_queries.load(Ordering::Relaxed) as f64 / b as f64
    }
}

/// Synchronous client interface to a prediction backend: the single-task
/// [`PredictionService`] or one shard of a [`ServicePool`]. The scheduler
/// is written against this trait, so it runs unchanged on either.
pub trait PredictClient {
    /// Re-fit hyper-parameters on a snapshot (blocking).
    fn refit(&self, snapshot: Snapshot, theta0: Vec<f64>, seed: u64) -> crate::Result<Vec<f64>>;

    /// Extend a task's curve in place (blocking): warm re-solve of the
    /// training system on the extended snapshot under the existing theta
    /// — no hyper-parameter refit, zero MLL evaluations. The report says
    /// when the backend's refit policy wants a real [`Self::refit`].
    fn observe(&self, snapshot: Snapshot, theta: Vec<f64>) -> crate::Result<ObserveReport>;

    /// Answer a batch of typed posterior queries (blocking). The batch —
    /// plus any coalesced same-generation traffic — shares one underlying
    /// solve on session-capable engines.
    fn query(
        &self,
        snapshot: Snapshot,
        theta: Vec<f64>,
        queries: Vec<Query>,
    ) -> crate::Result<Vec<Answer>>;

    /// Final-value predictions for query rows (blocking).
    fn predict_final(
        &self,
        snapshot: Snapshot,
        theta: Vec<f64>,
        xq: Matrix,
    ) -> crate::Result<Vec<(f64, f64)>>;

    /// Posterior curve samples (blocking).
    fn sample_curves(
        &self,
        snapshot: Snapshot,
        theta: Vec<f64>,
        xq: Matrix,
        samples: usize,
        seed: u64,
    ) -> crate::Result<Vec<Matrix>>;

    /// Mean queries per engine call (batching factor), for run reports.
    fn batch_factor(&self) -> f64;
}

// ---------------------------------------------------------------------------
// Shared batching core

/// Small keyed warm-start cache, most-recently-used first, keyed by
/// `(task, generation)` (ROADMAP "warm-cache LRU"). Buckets mix many
/// tasks behind one engine, and generation counters are per task, so the
/// task id is part of the key — a bare generation key would let task A's
/// generation-3 lineage answer task B's generation-3 queries. The
/// capacity is per TASK (the historical per-shard cap, now that a shard
/// serves many tasks): mixed-generation traffic — dashboards re-reading
/// old generations while the scheduler advances — hits the exact lineage
/// it solved under instead of cold-solving or cross-embedding from the
/// newest generation, and a wide bucket cannot thrash one hot task's
/// lineage out with another task's.
struct WarmLru {
    entries: Vec<((u64, u64), Arc<WarmStart>)>,
    /// Max entries kept per task (>= 1).
    cap: usize,
}

impl WarmLru {
    fn new(cap: usize) -> Self {
        WarmLru { entries: Vec::new(), cap: cap.max(1) }
    }

    /// Exact `(task, generation)` lookup; refreshes the entry's recency.
    fn get(&mut self, task: u64, generation: u64) -> Option<Arc<WarmStart>> {
        let i = self
            .entries
            .iter()
            .position(|(k, _)| *k == (task, generation))?;
        let e = self.entries.remove(i);
        let w = e.1.clone();
        self.entries.insert(0, e);
        Some(w)
    }

    /// Exact `(task, generation)` lookup without touching recency — the
    /// read-only replica path, so replica traffic never perturbs the
    /// writer's eviction order.
    fn peek(&self, task: u64, generation: u64) -> Option<Arc<WarmStart>> {
        self.entries
            .iter()
            .find(|(k, _)| *k == (task, generation))
            .map(|(_, w)| w.clone())
    }

    /// Most-recently-used lineage OF ONE TASK (the historical single-slot
    /// semantics, task-scoped).
    fn latest_for(&self, task: u64) -> Option<Arc<WarmStart>> {
        self.entries
            .iter()
            .find(|((t, _), _)| *t == task)
            .map(|(_, w)| w.clone())
    }

    /// Insert/replace the lineage for `(task, w.generation)`; evicts the
    /// task's LRU entries beyond the per-task cap (other tasks' entries
    /// are never touched).
    fn put(&mut self, task: u64, w: Arc<WarmStart>) {
        let key = (task, w.generation);
        if let Some(i) = self.entries.iter().position(|(k, _)| *k == key) {
            self.entries.remove(i);
        }
        self.entries.insert(0, (key, w));
        let mut kept = 0usize;
        self.entries.retain(|((t, _), _)| {
            if *t != task {
                return true;
            }
            kept += 1;
            kept <= self.cap
        });
    }

    /// Drop every cached lineage (bucket eviction).
    fn clear(&mut self) {
        self.entries.clear();
    }
}

/// An engine plus its keyed warm-start cache; the engine is exclusive to
/// one worker at a time, while the cache sits behind its own short-lived
/// lock so read-only replicas can peek lineage while the writer computes
/// (the writer never holds the cache lock across an engine call).
struct EngineSlot {
    engine: Box<dyn Engine>,
    warm: Arc<Mutex<WarmLru>>,
}

/// How a pending query batch's answers are delivered: raw typed answers,
/// unwrapped to the legacy `PredictFinal` shape, or unwrapped to the
/// legacy `SampleCurves` sample-matrix shape.
enum PendingReply {
    Preds(Sender<crate::Result<Vec<(f64, f64)>>>),
    Answers(Sender<crate::Result<Vec<Answer>>>),
    Curves(Sender<crate::Result<Vec<Matrix>>>),
}

/// A queued query batch awaiting coalescing. `task` scopes the warm
/// cache and the coalescing key — buckets mix tasks, and two tasks'
/// same-numbered generations are unrelated model states.
struct PendingQuery {
    task: u64,
    snapshot: Snapshot,
    theta: Vec<f64>,
    queries: Vec<Query>,
    reply: PendingReply,
}

/// Writer-path outcome summary for one processed batch, fed to the shard
/// circuit breaker: engine-level failures delivered to callers vs engine
/// calls that produced answers. Per-request validation rejections count as
/// neither (a caller's malformed query says nothing about shard health).
#[derive(Default)]
struct BatchReport {
    engine_failures: u64,
    engine_successes: u64,
    /// A `Shutdown` request was seen.
    shutdown: bool,
}

/// Per-bucket refit-policy state for [`Request::Observe`]: decides when a
/// task's theta is stale enough that the caller should enqueue a real
/// `Refit` (docs/serving.md). Two triggers, either sufficient: a cadence
/// (`every` observes per task) and a drift threshold on the data-fit term
/// `y'alpha` the warm re-solve computes for free — when the quadratic
/// form under the FROZEN theta moves relatively more than `drift`, the
/// new epochs disagree with the old hyper-parameters. The mutex nests
/// inside nothing: never held across an engine call or while the
/// queues/warm locks are taken.
struct RefitPolicy {
    /// Observes per task between refit verdicts; 0 disables the cadence.
    every: usize,
    /// Relative `y'alpha` drift that flags theta stale; 0 disables.
    drift: f64,
    /// Per-task cadence/baseline state, keyed by task id. Entries are
    /// few (tasks active in this bucket since its last refit), so a
    /// linear map beats a hash table here.
    state: Mutex<Vec<(u64, PolicyEntry)>>,
}

#[derive(Clone, Copy, Default)]
struct PolicyEntry {
    /// Observes since the last refit (or the last fired verdict).
    observes: usize,
    /// Data-fit `y'alpha` at the last refit/verdict; None until the first
    /// observe after one (its data-fit becomes the baseline).
    baseline: Option<f64>,
}

impl RefitPolicy {
    fn new(every: usize, drift: f64) -> Self {
        RefitPolicy { every, drift, state: Mutex::new(Vec::new()) }
    }

    /// Feed one observe's data-fit; returns whether a refit is due.
    /// Edge-triggered: firing resets the task's cadence and re-baselines
    /// the drift, so an ignored verdict re-arms instead of firing on
    /// every subsequent epoch.
    fn feed_observe(&self, task: u64, data_fit: f64) -> bool {
        let mut st = lock_clean(&self.state);
        let i = match st.iter().position(|(t, _)| *t == task) {
            Some(i) => i,
            None => {
                st.push((task, PolicyEntry::default()));
                st.len() - 1
            }
        };
        let e = &mut st[i].1;
        e.observes += 1;
        let drifted = match e.baseline {
            Some(b) if self.drift > 0.0 => {
                (data_fit - b).abs() / b.abs().max(1e-12) > self.drift
            }
            _ => false,
        };
        if e.baseline.is_none() {
            e.baseline = Some(data_fit);
        }
        let due = drifted || (self.every > 0 && e.observes >= self.every);
        if due {
            *e = PolicyEntry { observes: 0, baseline: Some(data_fit) };
        }
        due
    }

    /// A real refit ran for this task: reset its cadence and baseline
    /// (the next observe under the fresh theta re-baselines).
    fn note_refit(&self, task: u64) {
        lock_clean(&self.state).retain(|(t, _)| *t != task);
    }
}

/// Flush queued query batches: group by (task, generation, theta),
/// concatenate each group's typed queries into one `Engine::answer_batch`
/// call (one underlying solve for session-capable engines), scatter the
/// responses. With `warm_enabled`, solves start from the bucket's keyed
/// warm cache (the task's exact generation first, its most-recent lineage
/// as fallback, then the snapshot's own) and the converged state is
/// cached back under `(task, generation)`.
fn flush_queries(
    slot: &mut EngineSlot,
    pending: &mut Vec<PendingQuery>,
    stats: &ServiceStats,
    warm_enabled: bool,
    report: &mut BatchReport,
) {
    while !pending.is_empty() {
        let task0 = pending[0].task;
        let gen0 = pending[0].snapshot.generation;
        let theta0 = pending[0].theta.clone();
        // Bitwise theta comparison so the head request always matches its
        // own group even if a caller passed NaN.
        let same_theta = |t: &[f64]| {
            t.len() == theta0.len()
                && t.iter().zip(&theta0).all(|(a, b)| a.to_bits() == b.to_bits())
        };
        let group: Vec<PendingQuery> = {
            let (take, keep): (Vec<PendingQuery>, Vec<PendingQuery>) =
                pending.drain(..).partition(|p| {
                    p.task == task0
                        && p.snapshot.generation == gen0
                        && same_theta(&p.theta)
                });
            *pending = keep;
            take
        };
        // flatten the typed queries, remembering each request's span
        let mut snap: Option<Snapshot> = None;
        let mut replies: Vec<(PendingReply, usize)> = Vec::with_capacity(group.len());
        let mut all: Vec<Query> = Vec::new();
        for p in group {
            if snap.is_none() {
                snap = Some(p.snapshot);
            }
            replies.push((p.reply, p.queries.len()));
            all.extend(p.queries);
        }
        // lint: allow(panic) — the caller only forms groups from a
        // non-empty pending list, and a silent skip here would leave the
        // group's reply channels dangling (callers hang forever).
        let snap = snap.expect("non-empty group");
        // Warm lineage: the task's exact generation from the keyed LRU,
        // else the task's most-recent entry (cross-generation embed by
        // trial id), else the snapshot's own lineage.
        let lineage: Option<Arc<WarmStart>> = {
            let mut warm = lock_clean(&slot.warm);
            match warm.get(task0, gen0) {
                Some(w) => {
                    stats.warm_cache_hits.fetch_add(1, Ordering::Relaxed);
                    Some(w)
                }
                None => {
                    stats.warm_cache_misses.fetch_add(1, Ordering::Relaxed);
                    warm.latest_for(task0).or_else(|| snap.warm.clone())
                }
            }
        };
        // The guess targets the batch's stacked final-step layout (the
        // same stacking the session solves); batches with no final-step
        // queries embed the alpha alone. The factored preconditioner is
        // NOT gated by `warm_enabled` — the flags are independent (a
        // `--warm off` shard still amortizes the factorization), and the
        // engine checks factor staleness itself, so old factors are safe.
        let stacked = session::stacked_final_xq(&all);
        let guess: Option<Vec<f64>> = if warm_enabled {
            lineage.as_ref().and_then(|w| match &stacked {
                Some(xq) => w.embed_predict(&snap.row_ids, snap.data.m(), xq),
                None => w.embed_alpha(&snap.row_ids, snap.data.m()),
            })
        } else {
            None
        };
        let precond = lineage.as_ref().and_then(|w| w.precond.clone());
        // Pathwise lineage is staleness-checked by the sampler itself
        // (bitwise theta), so carrying it is always safe — like `precond`,
        // it is deliberately NOT gated by `warm_enabled`.
        let path = lineage.as_ref().and_then(|w| w.path.clone());
        let t0 = Instant::now();
        let result = slot.engine.answer_batch(
            &theta0,
            &snap.data,
            &all,
            guess.as_deref(),
            precond.clone(),
            path.clone(),
        );
        stats.batches.fetch_add(1, Ordering::Relaxed);
        stats
            .batched_queries
            .fetch_add(replies.len() as u64, Ordering::Relaxed);
        if guess.is_some() {
            stats.warm_hits.fetch_add(1, Ordering::Relaxed);
        }
        lock_clean(&stats.latency).record(t0.elapsed().as_micros() as u64);
        match result {
            Ok(outcome) => {
                report.engine_successes += 1;
                let crate::runtime::QueryOutcome {
                    answers,
                    alpha,
                    xq,
                    cross,
                    cg_iters,
                    cg_mvm_rows,
                    solves,
                    precond: out_precond,
                    escalations,
                    dense_fallbacks,
                    pathwise_hits,
                    sample_mvms,
                    path: out_path,
                } = outcome;
                stats
                    .escalations
                    .fetch_add(escalations as u64, Ordering::Relaxed);
                stats
                    .dense_fallbacks
                    .fetch_add(dense_fallbacks as u64, Ordering::Relaxed);
                stats
                    .pathwise_hits
                    .fetch_add(pathwise_hits as u64, Ordering::Relaxed);
                stats
                    .sample_mvms
                    .fetch_add(sample_mvms as u64, Ordering::Relaxed);
                stats.cg_iters.fetch_add(cg_iters as u64, Ordering::Relaxed);
                stats
                    .cg_mvm_rows
                    .fetch_add(cg_mvm_rows as u64, Ordering::Relaxed);
                stats
                    .engine_solves
                    .fetch_add(solves as u64, Ordering::Relaxed);
                if let Some(f) = &out_precond {
                    stats.precond_rank.store(f.rank() as u64, Ordering::Relaxed);
                }
                match (warm_enabled, alpha) {
                    (true, Some(alpha)) => {
                        lock_clean(&slot.warm).put(task0, Arc::new(WarmStart {
                            generation: snap.generation,
                            theta: theta0.clone(),
                            row_ids: (*snap.row_ids).clone(),
                            m: snap.data.m(),
                            alpha,
                            xq,
                            cross: cross.unwrap_or_default(),
                            precond: out_precond,
                            path: out_path,
                        }));
                    }
                    _ => {
                        // warm starts off (or no alpha exposed): cache
                        // ONLY the amortizable factorizations (empty alpha
                        // means nothing embeds as a guess, so solves stay
                        // cold as requested).
                        if out_precond.is_some() || out_path.is_some() {
                            lock_clean(&slot.warm).put(task0, Arc::new(WarmStart {
                                generation: snap.generation,
                                theta: theta0.clone(),
                                row_ids: (*snap.row_ids).clone(),
                                m: snap.data.m(),
                                alpha: Vec::new(),
                                xq: None,
                                cross: Vec::new(),
                                precond: out_precond,
                                path: out_path,
                            }));
                        }
                    }
                }
                scatter_answers(replies, answers);
            }
            Err(e) if replies.len() == 1 => {
                report.engine_failures += 1;
                stats.solver_failures.fetch_add(1, Ordering::Relaxed);
                if let Some((reply, _)) = replies.into_iter().next() {
                    send_error(reply, e);
                }
            }
            Err(_) => {
                // Failure isolation for coalesced groups: shape errors are
                // already rejected per-request at enqueue time, but an
                // engine can still refuse a whole batch (e.g. the legacy
                // mapping has no Mll path) or fail numerically. Re-run
                // each request on its own so one caller's failure never
                // errors out its same-generation neighbors.
                let mut off = 0;
                for (reply, len) in replies {
                    let span = &all[off..off + len];
                    off += len;
                    let res = slot.engine.answer_batch(
                        &theta0,
                        &snap.data,
                        span,
                        None,
                        precond.clone(),
                        path.clone(),
                    );
                    match res {
                        Ok(outcome) => {
                            report.engine_successes += 1;
                            stats
                                .cg_iters
                                .fetch_add(outcome.cg_iters as u64, Ordering::Relaxed);
                            stats
                                .cg_mvm_rows
                                .fetch_add(outcome.cg_mvm_rows as u64, Ordering::Relaxed);
                            stats
                                .engine_solves
                                .fetch_add(outcome.solves as u64, Ordering::Relaxed);
                            stats
                                .escalations
                                .fetch_add(outcome.escalations as u64, Ordering::Relaxed);
                            stats
                                .dense_fallbacks
                                .fetch_add(outcome.dense_fallbacks as u64, Ordering::Relaxed);
                            stats
                                .pathwise_hits
                                .fetch_add(outcome.pathwise_hits as u64, Ordering::Relaxed);
                            stats
                                .sample_mvms
                                .fetch_add(outcome.sample_mvms as u64, Ordering::Relaxed);
                            let mut answers = outcome.answers.into_iter();
                            match reply {
                                PendingReply::Answers(tx) => {
                                    let _ = tx.send(Ok(answers.collect()));
                                }
                                PendingReply::Preds(tx) => {
                                    let send = match answers.next() {
                                        Some(Answer::Final(v)) => Ok(v),
                                        _ => Err(crate::LkgpError::Coordinator(
                                            "engine answered PredictFinal with a non-Final \
                                             answer"
                                                .into(),
                                        )),
                                    };
                                    let _ = tx.send(send);
                                }
                                PendingReply::Curves(tx) => {
                                    let send = match answers.next() {
                                        Some(Answer::Curves(v)) => Ok(v),
                                        _ => Err(crate::LkgpError::Coordinator(
                                            "engine answered SampleCurves with a non-Curves \
                                             answer"
                                                .into(),
                                        )),
                                    };
                                    let _ = tx.send(send);
                                }
                            }
                        }
                        Err(e) => {
                            report.engine_failures += 1;
                            stats.solver_failures.fetch_add(1, Ordering::Relaxed);
                            send_error(reply, e);
                        }
                    }
                }
            }
        }
    }
}

/// Scatter a flat answer vector back to per-caller replies (each reply
/// consumes `len` answers, in submission order). Shared by the writer's
/// coalesced flush and the replica serving path so the two can never
/// disagree on response framing.
fn scatter_answers(replies: Vec<(PendingReply, usize)>, answers: Vec<Answer>) {
    let mut answers = answers.into_iter();
    for (reply, len) in replies {
        let span: Vec<Answer> = answers.by_ref().take(len).collect();
        match reply {
            PendingReply::Answers(tx) => {
                let _ = tx.send(Ok(span));
            }
            PendingReply::Preds(tx) => {
                let send = match span.into_iter().next() {
                    Some(Answer::Final(v)) => Ok(v),
                    _ => Err(crate::LkgpError::Coordinator(
                        "engine answered PredictFinal with a non-Final answer".into(),
                    )),
                };
                let _ = tx.send(send);
            }
            PendingReply::Curves(tx) => {
                let send = match span.into_iter().next() {
                    Some(Answer::Curves(v)) => Ok(v),
                    _ => Err(crate::LkgpError::Coordinator(
                        "engine answered SampleCurves with a non-Curves answer".into(),
                    )),
                };
                let _ = tx.send(send);
            }
        }
    }
}

/// Deliver a typed error to either reply flavor. Callers keep the original
/// `LkgpError` (e.g. `Solver` from ladder exhaustion, `Timeout`) instead
/// of a stringly `Coordinator` wrapper, so they can match on the failure
/// kind.
fn send_error(reply: PendingReply, err: crate::LkgpError) {
    match reply {
        PendingReply::Preds(tx) => {
            let _ = tx.send(Err(err));
        }
        PendingReply::Answers(tx) => {
            let _ = tx.send(Err(err));
        }
        PendingReply::Curves(tx) => {
            let _ = tx.send(Err(err));
        }
    }
}

/// Warm theta for an empty-`theta0` refit/observe: the task's
/// exact-generation lineage, then its most-recent cache entry, then the
/// snapshot lineage, then the prior mean.
fn warm_theta(slot: &mut EngineSlot, task: u64, snapshot: &Snapshot, d: usize) -> Vec<f64> {
    let lineage = {
        let mut warm = lock_clean(&slot.warm);
        warm.get(task, snapshot.generation)
            .or_else(|| warm.latest_for(task))
    }
    .or_else(|| snapshot.warm.clone());
    if let Some(w) = lineage {
        if w.theta.len() == d + 3 {
            return w.theta.clone();
        }
    }
    Theta::default_packed(d)
}

/// Pre-warm a freshly refitted generation on the writer: run the training
/// solve once under the fitted theta (warm-started from whatever lineage
/// exists) and cache the converged alpha as replica-ready `WarmStart`
/// lineage for `snapshot.generation`, so the first read burst against the
/// fresh generation forks off the cache instead of serializing on a cold
/// writer solve (docs/serving.md). Skipped when the generation already has
/// alpha-carrying lineage (nothing to warm — and clobbering it would
/// replace a richer entry, e.g. one with cached cross solves) or when the
/// engine has no session path. Pre-warm work lands in
/// `ServiceStats::{prewarmed, cg_iters, cg_mvm_rows}` but NOT in
/// `engine_solves` (see the field docs).
fn prewarm_generation(
    slot: &mut EngineSlot,
    task: u64,
    snapshot: &Snapshot,
    theta: Vec<f64>,
    cfg: SolverCfg,
    stats: &ServiceStats,
) {
    let (guess, precond) = {
        let mut warm = lock_clean(&slot.warm);
        if warm
            .peek(task, snapshot.generation)
            .map_or(false, |w| !w.alpha.is_empty())
        {
            return; // already replica-ready
        }
        match warm
            .get(task, snapshot.generation)
            .or_else(|| warm.latest_for(task))
        {
            Some(w) => (
                w.embed_alpha(&snapshot.row_ids, snapshot.data.m()),
                w.precond.clone(),
            ),
            None => (None, None),
        }
    };
    let mut post = Posterior::new(snapshot.data.clone(), theta.clone(), cfg)
        .with_guess(guess)
        .with_precond(precond);
    if post.prewarm().is_err() {
        return; // numeric failure: the read path simply stays cold
    }
    let Some(alpha) = post.alpha().map(|a| a.to_vec()) else {
        return;
    };
    let precond = post.precond();
    if let Some(f) = &precond {
        stats.precond_rank.store(f.rank() as u64, Ordering::Relaxed);
    }
    lock_clean(&slot.warm).put(task, Arc::new(WarmStart {
        generation: snapshot.generation,
        theta,
        row_ids: (*snapshot.row_ids).clone(),
        m: snapshot.data.m(),
        alpha,
        xq: None,
        cross: Vec::new(),
        precond,
        path: post.path_state(),
    }));
    stats.prewarmed.fetch_add(1, Ordering::Relaxed);
    stats
        .cg_iters
        .fetch_add(post.cg_iters() as u64, Ordering::Relaxed);
    stats
        .cg_mvm_rows
        .fetch_add(post.cg_mvm_rows() as u64, Ordering::Relaxed);
    stats
        .escalations
        .fetch_add(post.escalations() as u64, Ordering::Relaxed);
    stats
        .dense_fallbacks
        .fetch_add(post.dense_fallbacks() as u64, Ordering::Relaxed);
}

/// Cache the fitted theta in the shard lineage, preserving any cached
/// alpha and factored preconditioner (both solved under nearby
/// hyper-parameters, so both remain excellent across the refit).
fn record_fit_lineage(slot: &mut EngineSlot, task: u64, snapshot: &Snapshot, theta: Vec<f64>) {
    let mut warm = lock_clean(&slot.warm);
    let base = warm
        .get(task, snapshot.generation)
        .or_else(|| warm.latest_for(task));
    // Keep the base entry's own generation: the alpha/cross it carries
    // were solved under THAT generation, and re-keying it would make the
    // exact-generation hit counters lie about lineage provenance.
    let updated = match base {
        Some(w) => WarmStart { theta, ..(*w).clone() },
        None => WarmStart {
            generation: snapshot.generation,
            theta,
            row_ids: (*snapshot.row_ids).clone(),
            m: snapshot.data.m(),
            alpha: Vec::new(),
            xq: None,
            cross: Vec::new(),
            precond: None,
            path: None,
        },
    };
    warm.put(task, Arc::new(updated));
}

/// Process one drained batch of `(task, request)` pairs against an
/// engine slot. The report's `shutdown` flag is set when a `Shutdown` was
/// seen (remaining requests are dropped, like the original single-worker
/// loop); its engine failure/success counts feed the bucket circuit
/// breaker. `policy` is the bucket's refit-policy state for `Observe`.
fn process_batch(
    slot: &mut EngineSlot,
    batch: Vec<(u64, Request)>,
    stats: &ServiceStats,
    warm_enabled: bool,
    prewarm: bool,
    shard: usize,
    policy: &RefitPolicy,
) -> BatchReport {
    let mut report = BatchReport::default();
    let mut pending: Vec<PendingQuery> = Vec::new();
    for (task, req) in batch {
        stats.requests.fetch_add(1, Ordering::Relaxed);
        // Unwrap deadline envelopes (nesting keeps the tightest deadline)
        // and drop expired work with a typed Timeout reply instead of
        // paying for a solve nobody is waiting for.
        let mut req = req;
        let mut deadline: Option<Instant> = None;
        while let Request::Deadline { deadline: d, inner } = req {
            deadline = Some(deadline.map_or(d, |cur| cur.min(d)));
            req = *inner;
        }
        if let Some(d) = deadline {
            let now = Instant::now();
            if now > d {
                stats.timeouts.fetch_add(1, Ordering::Relaxed);
                let late_micros = now.duration_since(d).as_micros() as u64;
                fail_request(req, crate::LkgpError::Timeout { shard, late_micros });
                continue;
            }
        }
        match req {
            // Malformed requests are failed individually BEFORE coalescing
            // so one caller's bad query can never error out a whole
            // same-generation group (the historical stack kept malformed
            // widths out of the group key for the same reason).
            Request::PredictFinal { snapshot, theta, xq, resp } => {
                let query = Query::MeanAtFinal { xq };
                if let Err(e) = session::validate_query(&snapshot.data, &query) {
                    let _ = resp.send(Err(e));
                    continue;
                }
                pending.push(PendingQuery {
                    task,
                    snapshot,
                    theta,
                    queries: vec![query],
                    reply: PendingReply::Preds(resp),
                });
            }
            Request::Query { snapshot, theta, queries, resp } => {
                if let Some(e) = queries
                    .iter()
                    .find_map(|q| session::validate_query(&snapshot.data, q).err())
                {
                    let _ = resp.send(Err(e));
                    continue;
                }
                pending.push(PendingQuery {
                    task,
                    snapshot,
                    theta,
                    queries,
                    reply: PendingReply::Answers(resp),
                });
            }
            Request::Refit { snapshot, theta0, seed, resp } => {
                // order barrier: flush batched queries first
                flush_queries(slot, &mut pending, stats, warm_enabled, &mut report);
                let d = snapshot.data.d();
                let theta0 = if theta0.is_empty() {
                    if warm_enabled {
                        warm_theta(slot, task, &snapshot, d)
                    } else {
                        Theta::default_packed(d)
                    }
                } else {
                    theta0
                };
                let result = slot.engine.fit(&theta0, &snapshot.data, seed);
                match &result {
                    Ok(_) => report.engine_successes += 1,
                    Err(_) => {
                        report.engine_failures += 1;
                        stats.solver_failures.fetch_add(1, Ordering::Relaxed);
                    }
                }
                if warm_enabled {
                    if let Ok(theta) = &result {
                        record_fit_lineage(slot, task, &snapshot, theta.clone());
                        // Pre-warm BEFORE acknowledging the refit, so the
                        // lineage is replica-ready the moment the caller
                        // can start issuing reads against the fresh fit.
                        if prewarm {
                            if let Some(cfg) = slot.engine.session_cfg() {
                                prewarm_generation(
                                    slot,
                                    task,
                                    &snapshot,
                                    theta.clone(),
                                    cfg,
                                    stats,
                                );
                            }
                        }
                    }
                }
                if result.is_ok() {
                    policy.note_refit(task);
                }
                let _ = resp.send(result);
            }
            Request::Observe { snapshot, theta, resp } => {
                // A write like Refit: order-barrier the queued reads so
                // older-generation queries flush before the task's
                // lineage moves forward.
                flush_queries(slot, &mut pending, stats, warm_enabled, &mut report);
                let Some(cfg) = slot.engine.session_cfg() else {
                    report.engine_failures += 1;
                    stats.solver_failures.fetch_add(1, Ordering::Relaxed);
                    let _ = resp.send(Err(crate::LkgpError::Coordinator(
                        "Observe needs a session-capable engine (gp::session warm re-solve)"
                            .into(),
                    )));
                    continue;
                };
                let d = snapshot.data.d();
                let theta = if theta.is_empty() {
                    warm_theta(slot, task, &snapshot, d)
                } else {
                    theta
                };
                // Seed from the task's converged lineage: the extended
                // snapshot's own generation is new, so this lands on the
                // task's most-recent entry in practice.
                let lineage = {
                    let mut warm = lock_clean(&slot.warm);
                    warm.get(task, snapshot.generation)
                        .or_else(|| warm.latest_for(task))
                }
                .or_else(|| snapshot.warm.clone());
                let guess = lineage
                    .as_ref()
                    .and_then(|w| w.embed_alpha(&snapshot.row_ids, snapshot.data.m()));
                let precond = lineage.as_ref().and_then(|w| w.precond.as_ref().cloned());
                let path = lineage.as_ref().and_then(|w| w.path.clone());
                let t0 = Instant::now();
                let result = session::observe(
                    &snapshot.data,
                    &theta,
                    &cfg,
                    guess.as_deref(),
                    precond.as_ref(),
                );
                lock_clean(&stats.latency).record(t0.elapsed().as_micros() as u64);
                match result {
                    Ok(solve) => {
                        report.engine_successes += 1;
                        stats.observes.fetch_add(1, Ordering::Relaxed);
                        stats
                            .observe_solve_mvm_rows
                            .fetch_add(solve.mvm_rows as u64, Ordering::Relaxed);
                        stats
                            .cg_iters
                            .fetch_add(solve.cg_iters as u64, Ordering::Relaxed);
                        stats
                            .cg_mvm_rows
                            .fetch_add(solve.mvm_rows as u64, Ordering::Relaxed);
                        stats
                            .escalations
                            .fetch_add(solve.escalations as u64, Ordering::Relaxed);
                        stats
                            .dense_fallbacks
                            .fetch_add(solve.dense_fallbacks as u64, Ordering::Relaxed);
                        if guess.is_some() {
                            stats.warm_hits.fetch_add(1, Ordering::Relaxed);
                        }
                        if let Some(f) = &solve.precond {
                            stats.precond_rank.store(f.rank() as u64, Ordering::Relaxed);
                        }
                        // Cache the refreshed lineage even with `--warm
                        // off`: "the next solve starts converged" IS the
                        // Observe contract, not an optimization. The
                        // pathwise lineage rides along — the sampler
                        // staleness-checks it itself.
                        lock_clean(&slot.warm).put(
                            task,
                            Arc::new(WarmStart {
                                generation: snapshot.generation,
                                theta: theta.clone(),
                                row_ids: (*snapshot.row_ids).clone(),
                                m: snapshot.data.m(),
                                alpha: solve.alpha,
                                xq: None,
                                cross: Vec::new(),
                                precond: solve.precond,
                                path,
                            }),
                        );
                        let refit_due = policy.feed_observe(task, solve.data_fit);
                        if refit_due {
                            stats.refits_triggered.fetch_add(1, Ordering::Relaxed);
                        }
                        let _ = resp.send(Ok(ObserveReport {
                            generation: snapshot.generation,
                            cg_iters: solve.cg_iters,
                            mvm_rows: solve.mvm_rows,
                            refit_due,
                        }));
                    }
                    Err(e) => {
                        report.engine_failures += 1;
                        stats.solver_failures.fetch_add(1, Ordering::Relaxed);
                        let _ = resp.send(Err(e));
                    }
                }
            }
            Request::SampleCurves { snapshot, theta, xq, samples, seed, resp } => {
                // Sampling rides the coalesced query path as a seeded
                // `CurveSamples` (pathwise-capable, lineage-warm, replica
                // stealable) instead of the historical per-request
                // `Engine::sample_curves` solve (docs/sampling.md).
                let query = Query::CurveSamples { xq, n: samples, seed };
                if let Err(e) = session::validate_query(&snapshot.data, &query) {
                    let _ = resp.send(Err(e));
                    continue;
                }
                pending.push(PendingQuery {
                    task,
                    snapshot,
                    theta,
                    queries: vec![query],
                    reply: PendingReply::Curves(resp),
                });
            }
            // lint: allow(panic) — the dispatch loop unwraps Deadline
            // envelopes before this match; reaching here is memory-safe
            // but means the dispatcher was rewired wrong, which must fail
            // the run rather than silently drop the deadline.
            Request::Deadline { .. } => unreachable!("deadline envelopes unwrapped above"),
            Request::Shutdown => {
                flush_queries(slot, &mut pending, stats, warm_enabled, &mut report);
                report.shutdown = true;
                return report;
            }
        }
    }
    flush_queries(slot, &mut pending, stats, warm_enabled, &mut report);
    report
}

// ---------------------------------------------------------------------------
// Single-task service

/// Handle to the single-task service thread.
pub struct PredictionService {
    tx: Sender<Request>,
    pub stats: Arc<ServiceStats>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl PredictionService {
    /// Spawn the worker around an engine.
    pub fn spawn(engine: Box<dyn Engine>) -> Self {
        let (tx, rx) = channel::<Request>();
        let stats = Arc::new(ServiceStats::default());
        let worker_stats = stats.clone();
        let worker = std::thread::spawn(move || worker_loop(engine, rx, worker_stats));
        PredictionService {
            tx,
            stats,
            worker: Some(worker),
        }
    }

    pub fn sender(&self) -> Sender<Request> {
        self.tx.clone()
    }

    /// Synchronous refit helper.
    pub fn refit(&self, snapshot: Snapshot, theta0: Vec<f64>, seed: u64) -> crate::Result<Vec<f64>> {
        let (rtx, rrx) = channel();
        self.tx
            .send(Request::Refit { snapshot, theta0, seed, resp: rtx })
            .map_err(|_| crate::LkgpError::Coordinator("service down".into()))?;
        rrx.recv()
            .map_err(|_| crate::LkgpError::Coordinator("service dropped request".into()))?
    }

    /// Synchronous observe helper: warm re-solve on an extended snapshot
    /// under an existing theta (see [`Request::Observe`]).
    pub fn observe(&self, snapshot: Snapshot, theta: Vec<f64>) -> crate::Result<ObserveReport> {
        let (rtx, rrx) = channel();
        self.tx
            .send(Request::Observe { snapshot, theta, resp: rtx })
            .map_err(|_| crate::LkgpError::Coordinator("service down".into()))?;
        rrx.recv()
            .map_err(|_| crate::LkgpError::Coordinator("service dropped request".into()))?
    }

    /// Synchronous predict helper.
    pub fn predict_final(
        &self,
        snapshot: Snapshot,
        theta: Vec<f64>,
        xq: Matrix,
    ) -> crate::Result<Vec<(f64, f64)>> {
        let (rtx, rrx) = channel();
        self.tx
            .send(Request::PredictFinal { snapshot, theta, xq, resp: rtx })
            .map_err(|_| crate::LkgpError::Coordinator("service down".into()))?;
        rrx.recv()
            .map_err(|_| crate::LkgpError::Coordinator("service dropped request".into()))?
    }

    /// Synchronous typed-query helper.
    pub fn query(
        &self,
        snapshot: Snapshot,
        theta: Vec<f64>,
        queries: Vec<Query>,
    ) -> crate::Result<Vec<Answer>> {
        let (rtx, rrx) = channel();
        self.tx
            .send(Request::Query { snapshot, theta, queries, resp: rtx })
            .map_err(|_| crate::LkgpError::Coordinator("service down".into()))?;
        rrx.recv()
            .map_err(|_| crate::LkgpError::Coordinator("service dropped request".into()))?
    }

    /// Synchronous sampling helper.
    pub fn sample_curves(
        &self,
        snapshot: Snapshot,
        theta: Vec<f64>,
        xq: Matrix,
        samples: usize,
        seed: u64,
    ) -> crate::Result<Vec<Matrix>> {
        let (rtx, rrx) = channel();
        self.tx
            .send(Request::SampleCurves { snapshot, theta, xq, samples, seed, resp: rtx })
            .map_err(|_| crate::LkgpError::Coordinator("service down".into()))?;
        rrx.recv()
            .map_err(|_| crate::LkgpError::Coordinator("service dropped request".into()))?
    }
}

impl PredictClient for PredictionService {
    fn refit(&self, snapshot: Snapshot, theta0: Vec<f64>, seed: u64) -> crate::Result<Vec<f64>> {
        PredictionService::refit(self, snapshot, theta0, seed)
    }

    fn observe(&self, snapshot: Snapshot, theta: Vec<f64>) -> crate::Result<ObserveReport> {
        PredictionService::observe(self, snapshot, theta)
    }

    fn query(
        &self,
        snapshot: Snapshot,
        theta: Vec<f64>,
        queries: Vec<Query>,
    ) -> crate::Result<Vec<Answer>> {
        PredictionService::query(self, snapshot, theta, queries)
    }

    fn predict_final(
        &self,
        snapshot: Snapshot,
        theta: Vec<f64>,
        xq: Matrix,
    ) -> crate::Result<Vec<(f64, f64)>> {
        PredictionService::predict_final(self, snapshot, theta, xq)
    }

    fn sample_curves(
        &self,
        snapshot: Snapshot,
        theta: Vec<f64>,
        xq: Matrix,
        samples: usize,
        seed: u64,
    ) -> crate::Result<Vec<Matrix>> {
        PredictionService::sample_curves(self, snapshot, theta, xq, samples, seed)
    }

    fn batch_factor(&self) -> f64 {
        self.stats.batch_factor()
    }
}

impl Drop for PredictionService {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(engine: Box<dyn Engine>, rx: Receiver<Request>, stats: Arc<ServiceStats>) {
    // single-task service: cold solves (warm_enabled = false below), so a
    // one-entry cache only carries preconditioner lineage
    let mut slot = EngineSlot {
        engine,
        warm: Arc::new(Mutex::new(WarmLru::new(1))),
    };
    // Single-task refit policy with the pool defaults; everything is
    // task 0 here.
    let defaults = PoolCfg::default();
    let policy = RefitPolicy::new(defaults.refit_every_epochs, defaults.refit_drift);
    loop {
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return,
        };
        // Drain whatever else is queued right now (dynamic batching window).
        let mut queue: Vec<(u64, Request)> = vec![(0, first)];
        while let Ok(r) = rx.try_recv() {
            queue.push((0, r));
        }
        if process_batch(&mut slot, queue, &stats, false, false, 0, &policy).shutdown {
            return;
        }
    }
}

// ---------------------------------------------------------------------------
// Multi-task sharded pool

/// Configuration for [`ServicePool`].
#[derive(Clone, Copy, Debug)]
pub struct PoolCfg {
    /// Worker threads shared across all shards.
    pub workers: usize,
    /// Per-shard pending-queue bound; `submit` blocks when a shard's queue
    /// is full (backpressure).
    pub max_queue: usize,
    /// Warm-start solves from each shard's cached alpha/theta lineage.
    pub warm_start: bool,
    /// Entries in each shard's keyed warm-start LRU (by generation).
    /// 1 reproduces the historical latest-only cache; a few entries let
    /// mixed-generation dashboard traffic warm-hit old generations.
    pub warm_cache: usize,
    /// Read-only replicas allowed per task shard (0 disables). While a
    /// writer shard is busy, spare workers may claim queued read-only
    /// `Request::Query`/`PredictFinal` traffic for an already-fitted
    /// generation and answer it from a `Posterior` forked off the shard's
    /// cached `WarmStart` lineage — writes (refits) stay strictly ordered
    /// on the writer, and a generation fence retires replicas whose
    /// generation a writer has advanced past (see docs/serving.md).
    pub max_replicas: usize,
    /// Pre-warm freshly refitted generations: after a successful `Refit`,
    /// the writer immediately runs the new generation's training solve and
    /// caches replica-ready lineage (`ServiceStats::prewarmed`), closing
    /// the "first read burst against a fresh fit serializes on the writer"
    /// gap. Requires `warm_start`; no-op for engines without a session
    /// path.
    pub prewarm: bool,
    /// Intra-batch split threshold in stacked solve rows
    /// (`gp::session::query_weight`): a `ShardHandle::query` batch heavier
    /// than this is split into `split_queries` chunks and enqueued as
    /// independent requests, so read replicas can steal pieces of one
    /// giant batch while the writer chews the rest. 0 disables splitting
    /// (the historical single-request behavior). Answers are concatenated
    /// back in batch order; the chunks remain eligible for same-generation
    /// coalescing downstream.
    pub split_rows: usize,
    /// Default per-request deadline stamped at submission (None = no
    /// deadline, the historical behavior). Requests arriving already
    /// wrapped in [`Request::Deadline`] keep their own (tighter) deadline.
    /// Workers drop expired work with a typed `LkgpError::Timeout` reply.
    pub deadline: Option<Duration>,
    /// Bound on how long `submit` blocks waiting for queue space before
    /// shedding the request with an error (None = block forever, the
    /// historical backpressure; `Duration::ZERO` = never wait, i.e.
    /// `try_submit` semantics for every submission).
    pub submit_wait: Option<Duration>,
    /// Consecutive writer-path engine failures (recovered panics or typed
    /// errors with no success in between) that trip a shard's circuit
    /// breaker into quarantine: submissions fail fast with a typed
    /// `LkgpError::Quarantined` until the cool-down elapses, then traffic
    /// probes the shard again (lazily admitted shards re-materialize from
    /// the corpus). 0 disables the breaker. See docs/robustness.md.
    pub breaker_threshold: u32,
    /// Base quarantine cool-down; doubles on every consecutive trip
    /// (capped at 64x).
    pub breaker_cooldown: Duration,
    /// Hash-bucketed shard routing for corpus pools: the number of shard
    /// buckets many tasks are folded into (FNV over the task id, stable
    /// across restarts). 0 = one bucket per task, the historical 1:1
    /// layout and the default; positive values are clamped to the task
    /// count. Queues, engines, warm caches, breakers, and stats become
    /// per-bucket; generation fences stay per-task so one task's write
    /// never retires a bucket-mate's replicas. Ignored by
    /// [`ServicePool::spawn`], which is always 1:1 by construction.
    pub buckets: usize,
    /// Refit policy: after this many `Request::Observe` extensions of a
    /// task without a refit, the observe report sets `refit_due` (0
    /// disables the cadence trigger; drift can still fire).
    pub refit_every_epochs: usize,
    /// Refit policy: relative drift of the observe solve's data-fit term
    /// against the task's post-refit baseline that flags theta as stale
    /// (`refit_due`). The baseline re-arms on every real refit.
    pub refit_drift: f64,
}

impl Default for PoolCfg {
    fn default() -> Self {
        PoolCfg {
            // Each engine call fans out its own batch-parallel threads
            // (MaskedKronOp::apply_batch), so budget roughly half the
            // cores for workers to avoid worker x inner-thread
            // oversubscription. Callers with known task counts should set
            // this explicitly (see benches/hotpath.rs).
            workers: (crate::util::num_threads() / 2).max(1),
            max_queue: 1024,
            warm_start: true,
            warm_cache: 4,
            max_replicas: 2,
            prewarm: true,
            // A 64-row stacked solve is where one batch starts dominating
            // a shard's writer occupancy on the bench datasets.
            split_rows: 64,
            deadline: None,
            submit_wait: None,
            // Three consecutive engine failures with zero successes in
            // between is a sick shard, not caller error (malformed queries
            // are rejected before they reach the engine and never count).
            breaker_threshold: 3,
            breaker_cooldown: Duration::from_millis(250),
            // 1:1 task->shard layout unless the caller opts into folding
            // (serving CLI: --buckets N|auto).
            buckets: 0,
            // Observe is a solve-only extension: let theta ride for a
            // curve's typical "nothing changed" stretch, and catch real
            // drift early via the data-fit term.
            refit_every_epochs: 8,
            refit_drift: 0.25,
        }
    }
}

struct PoolQueues {
    /// Per-bucket FIFO of `(task, request)` pairs. The task id rides
    /// along because a bucket may serve many tasks (hash routing): warm
    /// lineages, fences, and the refit policy all key on it.
    pending: Vec<VecDeque<(u64, Request)>>,
    /// A shard is busy while a worker processes its drained batch; the
    /// flag serializes engine access per shard and preserves per-shard
    /// request order for everything the writer runs. Read-only replica
    /// serving is the one deliberate exception (reads commute; see
    /// `try_steal_reads`).
    busy: Vec<bool>,
    /// Live read-only replicas per shard (capped by
    /// `PoolCfg::max_replicas`).
    replicas: Vec<usize>,
    /// Round-robin scan start so a continuously-loaded low-index shard
    /// cannot starve higher-index shards when workers are scarce.
    cursor: usize,
    shutdown: bool,
}

/// Builds one engine per shard id, on demand. Pools admitted from a
/// corpus materialize shards lazily through this (see
/// [`ServicePool::from_corpus`]).
pub type EngineFactory = Box<dyn Fn(usize) -> Box<dyn Engine> + Send + Sync>;

struct PoolShared {
    /// Task -> bucket routing table (`route[task]` indexes every
    /// bucket-sized vector below). Identity for `spawn` pools and for
    /// `from_corpus` with `PoolCfg::buckets == 0`; FNV-folded otherwise.
    /// Deterministic across restarts: the same task always lands in the
    /// same bucket for a given (task count, bucket count).
    route: Vec<usize>,
    queues: Mutex<PoolQueues>,
    /// Workers wait here for claimable work.
    work_cv: Condvar,
    /// Submitters wait here for queue space (backpressure).
    space_cv: Condvar,
    /// Per-shard engine slot. `None` = admitted but never touched: pools
    /// built by [`ServicePool::from_corpus`] materialize a slot through
    /// `factory` on a shard's first writer claim, so admitting a
    /// 1000-task corpus costs 1000 queue cells, not 1000 engines.
    /// `spawn` pre-materializes every slot (the historical behavior).
    shards: Vec<Mutex<Option<EngineSlot>>>,
    /// Engine builder for lazy shards (`None` for `spawn` pools, which
    /// also makes `evict_idle` a no-op — engines handed in by the caller
    /// cannot be rebuilt).
    factory: Option<EngineFactory>,
    /// Each shard's keyed warm-start cache, shared between the writer
    /// (same `Arc` lives in the shard's `EngineSlot`) and read-only
    /// replicas. Lock order where both are held: `queues` before `warm`;
    /// nothing ever takes `queues` while holding a `warm` lock.
    warm: Vec<Arc<Mutex<WarmLru>>>,
    /// Per-TASK generation fence (length = `route.len()`, task-indexed
    /// even when every other vector here is bucket-indexed): the newest
    /// generation any write (`Refit` or `Observe`) has been enqueued for
    /// that task. Replicas only serve a task's reads at or beyond its
    /// fence and re-check it immediately before delivering, so a replica
    /// never answers a generation a writer has advanced past — and one
    /// task's write never retires a bucket-mate's replica reads.
    fences: Vec<AtomicU64>,
    /// Per-shard solver config for replica `Posterior`s, captured from
    /// `Engine::session_cfg` at spawn or lazy materialization (`None`
    /// inside disables replicas for that shard — e.g. artifact engines
    /// whose answers don't come from `gp::session`; an unset cell means
    /// the shard never materialized, which also disables replicas — there
    /// is no lineage to fork anyway).
    session_cfgs: Vec<std::sync::OnceLock<Option<SolverCfg>>>,
    stats: Vec<Arc<ServiceStats>>,
    /// Shards materialized over the pool's lifetime (monotone; eviction
    /// does not decrement — see `live_shards`).
    materialized: AtomicU64,
    /// Shards evicted by `evict_idle` over the pool's lifetime.
    evicted: AtomicU64,
    /// Per-shard `enqueued` watermark at the previous `evict_idle` sweep.
    evict_seen: Vec<AtomicU64>,
    /// Fingerprint of the corpus this pool was admitted from, if any.
    corpus_fingerprint: Option<String>,
    /// Per-shard circuit-breaker state (docs/robustness.md). Its mutex
    /// nests inside nothing: never held across an engine call or while
    /// the queues lock is taken.
    breakers: Vec<Mutex<Breaker>>,
    /// Per-bucket refit policy driven by `Request::Observe` (per-task
    /// entries inside). Its mutex nests inside nothing: only touched from
    /// the writer path between engine calls.
    policy: Vec<RefitPolicy>,
    max_queue: usize,
    warm_start: bool,
    max_replicas: usize,
    prewarm: bool,
    split_rows: usize,
    deadline: Option<Duration>,
    submit_wait: Option<Duration>,
    breaker_threshold: u32,
    breaker_cooldown: Duration,
}

/// Per-shard circuit-breaker state. Consecutive writer-path engine
/// failures trip the shard into quarantine; `failures` is deliberately
/// NOT reset on a trip, so a failing post-cool-down probe re-trips
/// immediately with a doubled cool-down instead of needing another full
/// run of failures.
#[derive(Default)]
struct Breaker {
    /// Consecutive engine failures since the last success.
    failures: u32,
    /// Consecutive trips (scales the cool-down exponentially).
    trips: u32,
    /// While set and in the future, submissions fail fast.
    open_until: Option<Instant>,
}

/// Multi-task sharded prediction service: one engine shard per task id, a
/// shared worker pool, request routing by task id, per-shard coalescing
/// across concurrent callers, bounded queues, and warm-started solves.
pub struct ServicePool {
    shared: Arc<PoolShared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl ServicePool {
    /// Spawn a pool with one shard per engine and `cfg.workers` shared
    /// worker threads. Every shard is materialized up front (the
    /// historical behavior); see [`ServicePool::from_corpus`] for lazy
    /// admission.
    pub fn spawn(engines: Vec<Box<dyn Engine>>, cfg: PoolCfg) -> Self {
        let session_cfgs: Vec<std::sync::OnceLock<Option<SolverCfg>>> = engines
            .iter()
            .map(|e| {
                let cell = std::sync::OnceLock::new();
                let _ = cell.set(e.session_cfg());
                cell
            })
            .collect();
        let warm: Vec<Arc<Mutex<WarmLru>>> = (0..engines.len())
            .map(|_| Arc::new(Mutex::new(WarmLru::new(cfg.warm_cache))))
            .collect();
        let n = engines.len();
        let shards: Vec<Mutex<Option<EngineSlot>>> = engines
            .into_iter()
            .zip(&warm)
            .map(|(engine, w)| Mutex::new(Some(EngineSlot { engine, warm: w.clone() })))
            .collect();
        // Caller-supplied engines are task-specific: always 1:1.
        let route = (0..n).collect();
        Self::build(shards, None, warm, session_cfgs, None, n as u64, route, cfg)
    }

    /// Admit every task of a corpus as a shard, materializing engines
    /// lazily: a shard builds its engine through `factory` on the first
    /// request that reaches it, so a 1000-task corpus with a 5-task hot
    /// set pays for 5 engines. Idle shards can be torn back down with
    /// [`ServicePool::evict_idle`]. The pool records the corpus
    /// fingerprint for reports and trace headers.
    pub fn from_corpus(
        corpus: &dyn crate::lcbench::corpus::Corpus,
        factory: EngineFactory,
        cfg: PoolCfg,
    ) -> Self {
        let n = corpus.len();
        // Hash-bucketed routing: fold n tasks into `cfg.buckets` shard
        // buckets (0 or >= n keeps the historical 1:1 identity layout).
        // A 10k-task corpus with 32 buckets costs 32 queue cells and at
        // most 32 engines, not 10k.
        let buckets = if cfg.buckets == 0 { n } else { cfg.buckets.min(n) };
        let route: Vec<usize> = if buckets == n {
            (0..n).collect()
        } else {
            (0..n).map(|t| bucket_of_task(t, buckets)).collect()
        };
        let warm: Vec<Arc<Mutex<WarmLru>>> = (0..buckets)
            .map(|_| Arc::new(Mutex::new(WarmLru::new(cfg.warm_cache))))
            .collect();
        let shards: Vec<Mutex<Option<EngineSlot>>> =
            (0..buckets).map(|_| Mutex::new(None)).collect();
        let session_cfgs = (0..buckets).map(|_| std::sync::OnceLock::new()).collect();
        Self::build(
            shards,
            Some(factory),
            warm,
            session_cfgs,
            Some(corpus.fingerprint()),
            0,
            route,
            cfg,
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn build(
        shards: Vec<Mutex<Option<EngineSlot>>>,
        factory: Option<EngineFactory>,
        warm: Vec<Arc<Mutex<WarmLru>>>,
        session_cfgs: Vec<std::sync::OnceLock<Option<SolverCfg>>>,
        corpus_fingerprint: Option<String>,
        materialized: u64,
        route: Vec<usize>,
        cfg: PoolCfg,
    ) -> Self {
        // n = bucket count; route.len() = task count (== n when 1:1).
        let n = shards.len();
        let tasks = route.len();
        let shared = Arc::new(PoolShared {
            queues: Mutex::new(PoolQueues {
                pending: (0..n).map(|_| VecDeque::new()).collect(),
                busy: vec![false; n],
                replicas: vec![0; n],
                cursor: 0,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            space_cv: Condvar::new(),
            shards,
            factory,
            warm,
            fences: (0..tasks).map(|_| AtomicU64::new(0)).collect(),
            session_cfgs,
            stats: (0..n).map(|_| Arc::new(ServiceStats::default())).collect(),
            materialized: AtomicU64::new(materialized),
            evicted: AtomicU64::new(0),
            evict_seen: (0..n).map(|_| AtomicU64::new(0)).collect(),
            corpus_fingerprint,
            breakers: (0..n).map(|_| Mutex::new(Breaker::default())).collect(),
            policy: (0..n)
                .map(|_| RefitPolicy::new(cfg.refit_every_epochs, cfg.refit_drift))
                .collect(),
            route,
            max_queue: cfg.max_queue.max(1),
            warm_start: cfg.warm_start,
            max_replicas: cfg.max_replicas,
            prewarm: cfg.prewarm,
            split_rows: cfg.split_rows,
            deadline: cfg.deadline,
            submit_wait: cfg.submit_wait,
            breaker_threshold: cfg.breaker_threshold,
            breaker_cooldown: cfg.breaker_cooldown,
        });
        let workers = (0..cfg.workers.max(1))
            .map(|_| {
                let shared = shared.clone();
                std::thread::spawn(move || pool_worker(shared))
            })
            .collect();
        ServicePool { shared, workers }
    }

    /// Number of addressable task shards in the pool. This is the TASK
    /// count — the public addressing space of `submit`/`handle`/`stats`
    /// — regardless of how many physical buckets back it.
    pub fn shards(&self) -> usize {
        self.shared.route.len()
    }

    /// Number of physical shard buckets (== [`ServicePool::shards`] for
    /// the historical 1:1 layout; smaller under hash-bucketed routing).
    pub fn buckets(&self) -> usize {
        self.shared.shards.len()
    }

    /// The bucket a task routes to (deterministic across restarts).
    pub fn bucket_of(&self, task: usize) -> usize {
        self.shared.route[task]
    }

    /// Shards materialized over the pool's lifetime (monotone: re-warming
    /// an evicted shard counts again).
    pub fn materialized(&self) -> u64 {
        self.shared.materialized.load(Ordering::Relaxed)
    }

    /// Shards torn down by [`ServicePool::evict_idle`] so far.
    pub fn evicted(&self) -> u64 {
        self.shared.evicted.load(Ordering::Relaxed)
    }

    /// Shards currently holding a live engine.
    pub fn live_shards(&self) -> usize {
        self.shared
            .shards
            .iter()
            .filter(|s| s.lock().map(|g| g.is_some()).unwrap_or(false))
            .count()
    }

    /// Fingerprint of the corpus this pool was admitted from, if any.
    pub fn corpus_fingerprint(&self) -> Option<&str> {
        self.shared.corpus_fingerprint.as_deref()
    }

    /// Tear down shards that saw no traffic since the previous sweep:
    /// drop the engine and clear the warm cache for every quiet,
    /// unmaterialized-able shard (lazy pools only — `spawn` engines cannot
    /// be rebuilt, so the call is a no-op there). Returns the number of
    /// shards evicted this sweep. An evicted shard is re-materialized
    /// transparently by its next request; call this periodically (e.g.
    /// between scheduler rounds) to keep a wide corpus's resident set at
    /// its hot set.
    pub fn evict_idle(&self) -> usize {
        let shared = &self.shared;
        if shared.factory.is_none() {
            return 0;
        }
        let mut freed = 0usize;
        for si in 0..shared.shards.len() {
            // Claim the shard exactly like a writer would so the teardown
            // can never race an engine call or a replica claim.
            {
                let mut q = shared.queues.lock().unwrap();
                let seen = shared.stats[si].enqueued.load(Ordering::Relaxed);
                let quiet = seen == shared.evict_seen[si].swap(seen, Ordering::Relaxed);
                if !quiet
                    || q.busy[si]
                    || q.replicas[si] > 0
                    || !q.pending[si].is_empty()
                    || q.shutdown
                {
                    continue;
                }
                q.busy[si] = true;
            }
            let had_engine = shared.shards[si]
                .lock()
                .unwrap_or_else(|poisoned| poisoned.into_inner())
                .take()
                .is_some();
            if had_engine {
                lock_clean(&shared.warm[si]).clear();
                shared.evicted.fetch_add(1, Ordering::Relaxed);
                freed += 1;
            }
            {
                let mut q = shared.queues.lock().unwrap();
                q.busy[si] = false;
            }
            // a request may have queued while the shard was claimed
            shared.work_cv.notify_one();
        }
        freed
    }

    /// Enqueue a request for a task shard; blocks while the shard's queue
    /// is at `max_queue` (backpressure), bounded by `PoolCfg::submit_wait`
    /// when one is configured (the request is shed with an error once the
    /// wait expires).
    pub fn submit(&self, shard: usize, req: Request) -> crate::Result<()> {
        submit_to(&self.shared, shard, req)
    }

    /// Non-blocking submit: enqueue if the shard's queue has space, shed
    /// immediately with an error otherwise (`ServiceStats::shed`). Load
    /// shedding for callers that prefer a fast typed failure over waiting
    /// on backpressure.
    pub fn try_submit(&self, shard: usize, req: Request) -> crate::Result<()> {
        submit_with(&self.shared, shard, req, Some(Duration::ZERO))
    }

    /// A cloneable synchronous handle bound to one task shard.
    pub fn handle(&self, shard: usize) -> ShardHandle {
        assert!(shard < self.shards(), "shard {shard} out of range");
        ShardHandle {
            shared: self.shared.clone(),
            shard,
        }
    }

    /// Statistics of the bucket a task shard routes to (per-task under
    /// the 1:1 layout; shared between bucket-mates under hash routing).
    pub fn stats(&self, shard: usize) -> &Arc<ServiceStats> {
        &self.shared.stats[self.shared.route[shard]]
    }

    /// All per-bucket statistics blocks, bucket-indexed (one per physical
    /// bucket; see [`ServicePool::stats`] for task-indexed access). Lets
    /// pool-wide reports aggregate without walking every task.
    pub fn all_stats(&self) -> &[Arc<ServiceStats>] {
        &self.shared.stats
    }

    /// Current pending-queue depth of the bucket a task shard routes to.
    pub fn queue_depth(&self, shard: usize) -> usize {
        self.shared.queues.lock().unwrap().pending[self.shared.route[shard]].len()
    }
}

impl Drop for ServicePool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queues.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        self.shared.space_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Cloneable synchronous client bound to one shard of a [`ServicePool`].
/// Implements [`PredictClient`], so a `Scheduler` can drive it directly.
#[derive(Clone)]
pub struct ShardHandle {
    shared: Arc<PoolShared>,
    shard: usize,
}

impl ShardHandle {
    /// The shard this handle routes to.
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Enqueue a raw request (blocking on backpressure, bounded by
    /// `PoolCfg::submit_wait` when configured).
    pub fn submit(&self, req: Request) -> crate::Result<()> {
        submit_to(&self.shared, self.shard, req)
    }

    /// Non-blocking submit: shed immediately with an error instead of
    /// waiting when the shard queue is full.
    pub fn try_submit(&self, req: Request) -> crate::Result<()> {
        submit_with(&self.shared, self.shard, req, Some(Duration::ZERO))
    }

    /// This shard's statistics (the backing bucket's, under hash routing).
    pub fn stats(&self) -> &Arc<ServiceStats> {
        &self.shared.stats[self.shared.route[self.shard]]
    }

    /// Synchronous observe helper: extend this task's curve in place with
    /// a warm re-solve (no refit; see [`Request::Observe`]).
    pub fn observe(&self, snapshot: Snapshot, theta: Vec<f64>) -> crate::Result<ObserveReport> {
        let (rtx, rrx) = channel();
        self.submit(Request::Observe { snapshot, theta, resp: rtx })?;
        rrx.recv()
            .map_err(|_| crate::LkgpError::Coordinator("pool dropped request".into()))?
    }
}

impl PredictClient for ShardHandle {
    fn refit(&self, snapshot: Snapshot, theta0: Vec<f64>, seed: u64) -> crate::Result<Vec<f64>> {
        let (rtx, rrx) = channel();
        self.submit(Request::Refit { snapshot, theta0, seed, resp: rtx })?;
        rrx.recv()
            .map_err(|_| crate::LkgpError::Coordinator("pool dropped request".into()))?
    }

    fn observe(&self, snapshot: Snapshot, theta: Vec<f64>) -> crate::Result<ObserveReport> {
        ShardHandle::observe(self, snapshot, theta)
    }

    fn query(
        &self,
        snapshot: Snapshot,
        theta: Vec<f64>,
        queries: Vec<Query>,
    ) -> crate::Result<Vec<Answer>> {
        let mut chunks = crate::gp::session::split_queries(&queries, self.shared.split_rows);
        if chunks.len() <= 1 {
            let (rtx, rrx) = channel();
            self.submit(Request::Query { snapshot, theta, queries, resp: rtx })?;
            return rrx
                .recv()
                .map_err(|_| crate::LkgpError::Coordinator("pool dropped request".into()))?;
        }
        // Oversized batch: enqueue every chunk before collecting any
        // answer, so spare workers (and read replicas, which steal
        // same-generation reads from a busy shard) can serve chunks
        // concurrently while the writer chews the first one. Answers come
        // back in submission order, which restores the batch order.
        self.stats().split_batches.fetch_add(1, Ordering::Relaxed);
        let Some(last) = chunks.pop() else {
            return Err(crate::LkgpError::Coordinator(
                "split_queries produced no chunks for a non-empty batch".into(),
            ));
        };
        let mut rxs = Vec::with_capacity(chunks.len() + 1);
        for chunk in chunks {
            let (rtx, rrx) = channel();
            self.submit(Request::Query {
                snapshot: snapshot.clone(),
                theta: theta.clone(),
                queries: chunk,
                resp: rtx,
            })?;
            rxs.push(rrx);
        }
        let (rtx, rrx) = channel();
        self.submit(Request::Query { snapshot, theta, queries: last, resp: rtx })?;
        rxs.push(rrx);
        let mut out = Vec::new();
        for rrx in rxs {
            out.extend(
                rrx.recv()
                    .map_err(|_| crate::LkgpError::Coordinator("pool dropped request".into()))??,
            );
        }
        Ok(out)
    }

    fn predict_final(
        &self,
        snapshot: Snapshot,
        theta: Vec<f64>,
        xq: Matrix,
    ) -> crate::Result<Vec<(f64, f64)>> {
        let (rtx, rrx) = channel();
        self.submit(Request::PredictFinal { snapshot, theta, xq, resp: rtx })?;
        rrx.recv()
            .map_err(|_| crate::LkgpError::Coordinator("pool dropped request".into()))?
    }

    fn sample_curves(
        &self,
        snapshot: Snapshot,
        theta: Vec<f64>,
        xq: Matrix,
        samples: usize,
        seed: u64,
    ) -> crate::Result<Vec<Matrix>> {
        let (rtx, rrx) = channel();
        self.submit(Request::SampleCurves { snapshot, theta, xq, samples, seed, resp: rtx })?;
        rrx.recv()
            .map_err(|_| crate::LkgpError::Coordinator("pool dropped request".into()))?
    }

    fn batch_factor(&self) -> f64 {
        self.stats().batch_factor()
    }
}

/// The bucket a task folds into under hash routing: FNV-1a over the task
/// id's little-endian bytes, mod the bucket count. Pure function of
/// (task, buckets) — deterministic across restarts and processes, which
/// is what keeps warm lineage, traces, and eviction behavior reproducible
/// for a fixed pool shape.
fn bucket_of_task(task: usize, buckets: usize) -> usize {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in (task as u64).to_le_bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h % buckets.max(1) as u64) as usize
}

fn submit_to(shared: &PoolShared, shard: usize, req: Request) -> crate::Result<()> {
    submit_with(shared, shard, req, shared.submit_wait)
}

fn submit_with(
    shared: &PoolShared,
    shard: usize,
    req: Request,
    max_wait: Option<Duration>,
) -> crate::Result<()> {
    // `shard` is the public task index; everything queue/breaker/stats
    // below happens on the bucket it routes to.
    if shard >= shared.route.len() {
        return Err(crate::LkgpError::Coordinator(format!(
            "no shard {shard} (pool has {})",
            shared.route.len()
        )));
    }
    let bucket = shared.route[shard];
    if matches!(req, Request::Shutdown) {
        // Per-request shutdown belongs to the single-task service; the
        // pool's lifecycle is its Drop impl.
        return Err(crate::LkgpError::Coordinator(
            "Shutdown is not routable through the pool; drop the pool instead".into(),
        ));
    }
    // Quarantine fail-fast: a tripped shard rejects new work immediately
    // with a typed error until its cool-down elapses; the first
    // submission after the cool-down flows through as a probe (half-open
    // breaker — see `breaker_feed`).
    if shared.breaker_threshold > 0 {
        let mut b = lock_clean(&shared.breakers[bucket]);
        if let Some(until) = b.open_until {
            let now = Instant::now();
            if now < until {
                shared.stats[bucket]
                    .quarantine_rejects
                    .fetch_add(1, Ordering::Relaxed);
                return Err(crate::LkgpError::Quarantined {
                    shard,
                    failures: b.failures,
                    cooldown_ms: until.duration_since(now).as_millis() as u64,
                });
            }
            b.open_until = None;
        }
    }
    // Pool-wide default deadline; requests that arrive already wrapped
    // keep their own (the worker takes the tightest of nested envelopes).
    let req = match shared.deadline {
        Some(d) if !matches!(req, Request::Deadline { .. }) => Request::Deadline {
            deadline: Instant::now() + d,
            inner: Box::new(req),
        },
        _ => req,
    };
    // Writes advance the TASK's generation fence at enqueue time — the
    // earliest point a replica can learn that its generation is about to
    // be superseded. Per-task, so a bucket-mate's write never fences this
    // task's replica reads.
    if let Some(g) = write_generation(&req) {
        shared.fences[shard].fetch_max(g, Ordering::Relaxed);
    }
    let depth = {
        let mut q = shared.queues.lock().unwrap();
        let shed_at = max_wait.map(|w| Instant::now() + w);
        loop {
            if q.shutdown {
                return Err(crate::LkgpError::Coordinator("pool shutting down".into()));
            }
            if q.pending[bucket].len() < shared.max_queue {
                break;
            }
            match shed_at {
                // historical backpressure: block until space frees up
                None => q = shared.space_cv.wait(q).unwrap(),
                Some(t) => {
                    let now = Instant::now();
                    if now >= t {
                        shared.stats[bucket].shed.fetch_add(1, Ordering::Relaxed);
                        return Err(crate::LkgpError::Coordinator(format!(
                            "shard {shard} queue full ({} pending); request shed",
                            q.pending[bucket].len()
                        )));
                    }
                    let (guard, _) = shared
                        .space_cv
                        .wait_timeout(q, t.duration_since(now))
                        .unwrap();
                    q = guard;
                }
            }
        }
        q.pending[bucket].push_back((shard as u64, req));
        q.pending[bucket].len() as u64
    };
    let stats = &shared.stats[bucket];
    stats.enqueued.fetch_add(1, Ordering::Relaxed);
    stats.peak_queue_depth.fetch_max(depth, Ordering::Relaxed);
    shared.work_cv.notify_one();
    Ok(())
}

/// What a pool worker claimed: exclusive writer access to a shard's
/// drained queue, or a read-only replica group stolen from a busy shard.
enum PoolWork {
    Writer(usize, Vec<(u64, Request)>),
    Replica {
        shard: usize,
        task: u64,
        generation: u64,
        reads: Vec<PendingQuery>,
    },
}

/// Replica claim: from a busy bucket's queue, steal every read-only
/// request (`Query` / `PredictFinal`) of one *servable* (task,
/// generation) — a generation at or beyond that task's write fence whose
/// lineage (cached `WarmStart` with a converged alpha) already sits in
/// the bucket's warm cache. Writes and reads of other tasks/generations
/// stay queued in order for the writer. Returns None when nothing is
/// stealable.
fn try_steal_reads(
    q: &mut PoolQueues,
    shared: &PoolShared,
) -> Option<(usize, u64, u64, Vec<PendingQuery>)> {
    if shared.max_replicas == 0 {
        return None;
    }
    let k = q.pending.len();
    for si in 0..k {
        // An unset session_cfg cell means the shard never materialized:
        // no lineage exists, so there is nothing for a replica to fork.
        let session_capable = shared.session_cfgs[si]
            .get()
            .map_or(false, |c| c.is_some());
        if !q.busy[si]
            || q.pending[si].is_empty()
            || q.replicas[si] >= shared.max_replicas
            || !session_capable
        {
            continue;
        }
        // Find the first read whose generation passes its task's fence
        // and is already fitted (exact (task, generation) lineage with an
        // alpha). The warm lock nests inside the queues lock here; the
        // reverse order never occurs (see PoolShared::warm).
        let mut target: Option<(u64, u64)> = None;
        // Memoize the lineage check per distinct (task, generation): a
        // deep read backlog must not turn one scan into a warm-lock
        // acquisition per queued request (this whole scan runs under the
        // queues lock).
        let mut checked: Vec<(u64, u64, bool)> = Vec::new();
        for (task, req) in q.pending[si].iter() {
            // Deadline-wrapped reads fall through to the writer (which
            // enforces expiry at pick-up); replicas only steal bare reads.
            let g = match req {
                Request::Query { snapshot, .. }
                | Request::PredictFinal { snapshot, .. }
                | Request::SampleCurves { snapshot, .. } => snapshot.generation,
                _ => continue,
            };
            if g < shared.fences[*task as usize].load(Ordering::Relaxed) {
                continue;
            }
            let fitted = match checked.iter().find(|(ct, cg, _)| ct == task && *cg == g) {
                Some(&(_, _, fitted)) => fitted,
                None => {
                    let fitted = lock_clean(&shared.warm[si])
                        .peek(*task, g)
                        .map_or(false, |w| !w.alpha.is_empty());
                    checked.push((*task, g, fitted));
                    fitted
                }
            };
            if fitted {
                target = Some((*task, g));
                break;
            }
        }
        let Some((task0, g)) = target else { continue };
        let mut stolen = Vec::new();
        let mut keep = VecDeque::with_capacity(q.pending[si].len());
        for (task, req) in q.pending[si].drain(..) {
            if task != task0 {
                keep.push_back((task, req));
                continue;
            }
            match req {
                Request::Query { snapshot, theta, queries, resp }
                    if snapshot.generation == g =>
                {
                    stolen.push(PendingQuery {
                        task,
                        snapshot,
                        theta,
                        queries,
                        reply: PendingReply::Answers(resp),
                    });
                }
                Request::PredictFinal { snapshot, theta, xq, resp }
                    if snapshot.generation == g =>
                {
                    stolen.push(PendingQuery {
                        task,
                        snapshot,
                        theta,
                        queries: vec![Query::MeanAtFinal { xq }],
                        reply: PendingReply::Preds(resp),
                    });
                }
                Request::SampleCurves { snapshot, theta, xq, samples, seed, resp }
                    if snapshot.generation == g =>
                {
                    // Seeded samples are deterministic functions of
                    // (theta, data, xq, seed), so a replica's draws are
                    // bit-identical to the writer's (docs/sampling.md).
                    stolen.push(PendingQuery {
                        task,
                        snapshot,
                        theta,
                        queries: vec![Query::CurveSamples { xq, n: samples, seed }],
                        reply: PendingReply::Curves(resp),
                    });
                }
                other => keep.push_back((task, other)),
            }
        }
        q.pending[si] = keep;
        q.replicas[si] += 1;
        return Some((si, task0, g, stolen));
    }
    None
}

/// Hand a replica's unserved reads back to the writer queue (front,
/// original order preserved) — the retire path, and the fallback when the
/// lineage disappeared between claim and serve.
fn requeue_reads(shared: &PoolShared, shard: usize, reads: Vec<PendingQuery>) {
    {
        let mut q = shared.queues.lock().unwrap();
        for p in reads.into_iter().rev() {
            let task = p.task;
            let req = match p.reply {
                PendingReply::Answers(tx) => Request::Query {
                    snapshot: p.snapshot,
                    theta: p.theta,
                    queries: p.queries,
                    resp: tx,
                },
                PendingReply::Preds(tx) => {
                    let xq = match p.queries.into_iter().next() {
                        Some(Query::MeanAtFinal { xq }) => xq,
                        // lint: allow(panic) — enqueue constructs every
                        // Preds-reply entry with exactly one MeanAtFinal;
                        // any other shape is a protocol bug upstream.
                        _ => unreachable!("PredictFinal reads carry one MeanAtFinal"),
                    };
                    Request::PredictFinal {
                        snapshot: p.snapshot,
                        theta: p.theta,
                        xq,
                        resp: tx,
                    }
                }
                PendingReply::Curves(tx) => {
                    let (xq, samples, seed) = match p.queries.into_iter().next() {
                        Some(Query::CurveSamples { xq, n, seed }) => (xq, n, seed),
                        // lint: allow(panic) — enqueue constructs every
                        // Curves-reply entry with exactly one CurveSamples;
                        // any other shape is a protocol bug upstream.
                        _ => unreachable!("SampleCurves reads carry one CurveSamples"),
                    };
                    Request::SampleCurves {
                        snapshot: p.snapshot,
                        theta: p.theta,
                        xq,
                        samples,
                        seed,
                        resp: tx,
                    }
                }
            };
            q.pending[shard].push_front((task, req));
        }
    }
    shared.work_cv.notify_one();
}

/// Serve a stolen read group on a spare worker: group by theta (the
/// generation is fixed), fork a `Posterior` off the cached lineage —
/// covered queries answer with zero solves, anything else warm-starts
/// from the lineage exactly like the writer would — and deliver, unless
/// a writer advanced the shard's fence mid-serve, in which case the whole
/// group retires back to the writer unanswered.
fn replica_serve(shared: &PoolShared, si: usize, task: u64, g: u64, mut reads: Vec<PendingQuery>) {
    let stats = &shared.stats[si];
    let Some(cfg) = shared.session_cfgs[si].get().and_then(|c| c.as_ref()) else {
        // Eligibility is checked before stealing, but a lost race with a
        // shard teardown must retire the group to the writer, not panic.
        requeue_reads(shared, si, reads);
        return;
    };
    // Same per-request validation the writer applies before coalescing:
    // malformed queries fail alone and never poison a group. A request is
    // counted into `stats.requests` only when the replica terminally
    // responds to it — retired/requeued reads are counted by the writer
    // that eventually answers them, so nothing is double-counted.
    let mut valid = Vec::with_capacity(reads.len());
    for p in reads.drain(..) {
        if let Some(e) = p
            .queries
            .iter()
            .find_map(|qr| session::validate_query(&p.snapshot.data, qr).err())
        {
            stats.requests.fetch_add(1, Ordering::Relaxed);
            send_error(p.reply, e);
            continue;
        }
        valid.push(p);
    }
    let mut pending = valid;
    while !pending.is_empty() {
        let theta0 = pending[0].theta.clone();
        let same_theta = |t: &[f64]| {
            t.len() == theta0.len()
                && t.iter().zip(&theta0).all(|(a, b)| a.to_bits() == b.to_bits())
        };
        let group: Vec<PendingQuery> = {
            let (take, keep): (Vec<PendingQuery>, Vec<PendingQuery>) =
                pending.drain(..).partition(|p| same_theta(&p.theta));
            pending = keep;
            take
        };
        let Some(lineage) = lock_clean(&shared.warm[si]).peek(task, g) else {
            // Evicted between claim and serve (tiny window): not stale,
            // just unlucky — hand the group back to the writer.
            requeue_reads(shared, si, group);
            continue;
        };
        let snap = group[0].snapshot.clone();
        let mut replies: Vec<(PendingReply, usize)> = Vec::with_capacity(group.len());
        let mut all: Vec<Query> = Vec::new();
        for p in group {
            replies.push((p.reply, p.queries.len()));
            all.extend(p.queries);
        }
        let stacked = session::stacked_final_xq(&all);
        // The pathwise lineage checks its own staleness (bitwise theta),
        // so it rides along unconditionally — with a seeded alpha it makes
        // CurveSamples solve-free and bit-identical to the writer's
        // (docs/sampling.md).
        let mut post = Posterior::new(snap.data.clone(), theta0.clone(), cfg.clone())
            .with_precond(lineage.precond.clone())
            .with_path(lineage.path.clone());
        let seeded = same_theta(&lineage.theta)
            && lineage.m == snap.data.m()
            && lineage.row_ids == *snap.row_ids
            && !lineage.alpha.is_empty();
        if seeded {
            // Converged state of the SAME (generation, theta): covered
            // queries answer bit-identically with zero solves.
            post = post.with_solves(
                lineage.alpha.clone(),
                lineage.xq.clone(),
                lineage.cross.clone(),
            );
        } else if shared.warm_start {
            // Different theta: the lineage is only a warm *guess*, exactly
            // what the writer's flush would embed.
            let guess = match &stacked {
                Some(xq) => lineage.embed_predict(&snap.row_ids, snap.data.m(), xq),
                None => lineage.embed_alpha(&snap.row_ids, snap.data.m()),
            };
            post = post.with_guess(guess);
        }
        let t0 = Instant::now();
        let result = post.answer_batch(&all);
        // Generation fence: a writer advanced this TASK past g while we
        // computed — discard the answers and hand the requests back (they
        // carry their own snapshots, so the writer still answers them
        // correctly; the replica just must not). Bucket-mates' writes
        // don't touch this fence.
        if shared.fences[task as usize].load(Ordering::Relaxed) > g {
            stats.stale_replica_retires.fetch_add(1, Ordering::Relaxed);
            let rebuilt: Vec<PendingQuery> = {
                let mut offs = 0usize;
                replies
                    .into_iter()
                    .map(|(reply, len)| {
                        let queries = all[offs..offs + len].to_vec();
                        offs += len;
                        PendingQuery {
                            task,
                            snapshot: snap.clone(),
                            theta: theta0.clone(),
                            queries,
                            reply,
                        }
                    })
                    .collect()
            };
            requeue_reads(shared, si, rebuilt);
            continue;
        }
        stats.batches.fetch_add(1, Ordering::Relaxed);
        stats
            .batched_queries
            .fetch_add(replies.len() as u64, Ordering::Relaxed);
        stats.replica_hits.fetch_add(1, Ordering::Relaxed);
        lock_clean(&stats.latency).record(t0.elapsed().as_micros() as u64);
        let solves = post.solve_calls() as u64;
        stats.replica_solves.fetch_add(solves, Ordering::Relaxed);
        stats.engine_solves.fetch_add(solves, Ordering::Relaxed);
        stats
            .cg_iters
            .fetch_add(post.cg_iters() as u64, Ordering::Relaxed);
        stats
            .cg_mvm_rows
            .fetch_add(post.cg_mvm_rows() as u64, Ordering::Relaxed);
        stats
            .escalations
            .fetch_add(post.escalations() as u64, Ordering::Relaxed);
        stats
            .dense_fallbacks
            .fetch_add(post.dense_fallbacks() as u64, Ordering::Relaxed);
        stats
            .pathwise_hits
            .fetch_add(post.pathwise_hits() as u64, Ordering::Relaxed);
        stats
            .sample_mvms
            .fetch_add(post.sample_mvms() as u64, Ordering::Relaxed);
        if let Some(f) = post.precond() {
            stats.precond_rank.store(f.rank() as u64, Ordering::Relaxed);
        }
        match result {
            Ok(answers) => {
                stats
                    .requests
                    .fetch_add(replies.len() as u64, Ordering::Relaxed);
                scatter_answers(replies, answers);
            }
            Err(e) => {
                // Failure isolation, mirroring the writer: retry each
                // request on its own forked posterior so one caller's
                // numeric failure never errors out its neighbors. The
                // fence is re-checked before every solo delivery — the
                // stale-answer invariant holds on this path too, and
                // requests superseded mid-loop retire back to the writer.
                if replies.len() == 1 {
                    if let Some((reply, _)) = replies.into_iter().next() {
                        stats.requests.fetch_add(1, Ordering::Relaxed);
                        send_error(reply, e);
                    }
                } else {
                    let mut off = 0;
                    let mut retired: Vec<PendingQuery> = Vec::new();
                    for (reply, len) in replies {
                        let span_off = off;
                        off += len;
                        let span = &all[span_off..span_off + len];
                        let mut solo =
                            Posterior::new(snap.data.clone(), theta0.clone(), cfg.clone())
                                .with_precond(lineage.precond.clone())
                                .with_path(lineage.path.clone());
                        let res = solo.answer_batch(span);
                        let solves = solo.solve_calls() as u64;
                        stats.replica_solves.fetch_add(solves, Ordering::Relaxed);
                        stats.engine_solves.fetch_add(solves, Ordering::Relaxed);
                        stats
                            .pathwise_hits
                            .fetch_add(solo.pathwise_hits() as u64, Ordering::Relaxed);
                        stats
                            .sample_mvms
                            .fetch_add(solo.sample_mvms() as u64, Ordering::Relaxed);
                        if shared.fences[task as usize].load(Ordering::Relaxed) > g {
                            retired.push(PendingQuery {
                                task,
                                snapshot: snap.clone(),
                                theta: theta0.clone(),
                                queries: span.to_vec(),
                                reply,
                            });
                            continue;
                        }
                        stats.requests.fetch_add(1, Ordering::Relaxed);
                        match res {
                            Ok(answers) => scatter_answers(vec![(reply, len)], answers),
                            Err(e) => send_error(reply, e),
                        }
                    }
                    if !retired.is_empty() {
                        stats.stale_replica_retires.fetch_add(1, Ordering::Relaxed);
                        requeue_reads(shared, si, retired);
                    }
                }
            }
        }
    }
}

/// Feed one worker outcome into a shard's circuit breaker. A success with
/// no failure closes the breaker completely; a failure increments the
/// consecutive count and trips the shard into quarantine at the
/// threshold, with a cool-down that doubles on every consecutive trip
/// (capped at 64x the base). On a trip from the writer path of a lazily
/// admitted pool (`can_evict`), the engine and warm cache are torn down
/// so the post-cool-down probe transparently re-materializes the shard
/// from the corpus (`ServicePool::from_corpus`).
fn breaker_feed(shared: &PoolShared, si: usize, failed: bool, succeeded: bool, can_evict: bool) {
    if shared.breaker_threshold == 0 || (!failed && !succeeded) {
        return;
    }
    let tripped = {
        let mut b = lock_clean(&shared.breakers[si]);
        if !failed {
            b.failures = 0;
            b.trips = 0;
            b.open_until = None;
            false
        } else {
            b.failures = b.failures.saturating_add(1);
            if b.failures >= shared.breaker_threshold {
                b.trips = b.trips.saturating_add(1);
                let scale = 1u32 << (b.trips - 1).min(6);
                b.open_until = Some(Instant::now() + shared.breaker_cooldown * scale);
                true
            } else {
                false
            }
        }
    };
    if tripped {
        shared.stats[si].quarantine_trips.fetch_add(1, Ordering::Relaxed);
        eprintln!("lkgp: shard {si} quarantined after consecutive engine failures");
        if can_evict && shared.factory.is_some() {
            // The caller holds the shard's busy flag, so the teardown
            // cannot race an engine call; the next successful claim
            // rebuilds through the factory.
            lock_clean(&shared.shards[si]).take();
            lock_clean(&shared.warm[si]).clear();
        }
    }
}

fn pool_worker(shared: Arc<PoolShared>) {
    loop {
        // Claim work: an idle shard with pending requests (writer path,
        // round-robin from the shared cursor so no shard is starved), or
        // — when every pending shard is writer-busy — a read-only replica
        // group stolen from a busy shard's queue.
        let work = {
            let mut q = shared.queues.lock().unwrap();
            loop {
                let k = q.pending.len();
                let start = q.cursor;
                let claim = (0..k)
                    .map(|o| (start + o) % k.max(1))
                    .find(|&i| !q.busy[i] && !q.pending[i].is_empty());
                if let Some(si) = claim {
                    q.busy[si] = true;
                    q.cursor = (si + 1) % k;
                    let batch: Vec<(u64, Request)> = q.pending[si].drain(..).collect();
                    break PoolWork::Writer(si, batch);
                }
                if let Some((si, task, g, reads)) = try_steal_reads(&mut q, &shared) {
                    break PoolWork::Replica { shard: si, task, generation: g, reads };
                }
                if q.shutdown {
                    return;
                }
                q = shared.work_cv.wait(q).unwrap();
            }
        };
        shared.space_cv.notify_all();
        match work {
            PoolWork::Writer(si, batch) => {
                // The busy flag guarantees exclusivity, so the shard lock
                // is uncontended (it exists to satisfy Sync). A panic
                // inside an engine call must not wedge the shard: catch
                // it, shed the poisoned-lock state, and always clear the
                // busy flag below.
                let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    let mut guard = shared.shards[si]
                        .lock()
                        .unwrap_or_else(|poisoned| poisoned.into_inner());
                    // Lazy admission: a corpus shard materializes its
                    // engine on first writer claim (and after eviction).
                    if guard.is_none() {
                        if let Some(factory) = shared.factory.as_ref() {
                            let engine = factory(si);
                            let _ = shared.session_cfgs[si].set(engine.session_cfg());
                            shared.materialized.fetch_add(1, Ordering::Relaxed);
                            *guard = Some(EngineSlot {
                                engine,
                                warm: shared.warm[si].clone(),
                            });
                        }
                    }
                    let Some(slot) = guard.as_mut() else {
                        // An unmaterialized shard in a pool without a
                        // factory is a wiring bug; fail the batch with
                        // typed errors instead of taking the worker down.
                        let mut report = BatchReport::default();
                        for (_task, req) in batch {
                            if matches!(req, Request::Shutdown) {
                                report.shutdown = true;
                                continue;
                            }
                            report.engine_failures += 1;
                            fail_request(
                                req,
                                crate::LkgpError::Coordinator(format!(
                                    "shard {si} has no engine and the pool has no factory"
                                )),
                            );
                        }
                        return report;
                    };
                    process_batch(
                        slot,
                        batch,
                        &shared.stats[si],
                        shared.warm_start,
                        shared.prewarm,
                        si,
                        &shared.policy[si],
                    )
                }));
                let (failed, succeeded) = match &run {
                    Err(_) => {
                        shared.stats[si]
                            .panics_recovered
                            .fetch_add(1, Ordering::Relaxed);
                        eprintln!("lkgp: pool worker recovered from a panic on shard {si}");
                        (true, false)
                    }
                    Ok(report) => (
                        report.engine_failures > 0 && report.engine_successes == 0,
                        report.engine_successes > 0,
                    ),
                };
                // The busy flag is still held here, so a breaker trip can
                // tear the engine down without racing another worker.
                breaker_feed(&shared, si, failed, succeeded, true);
                let more = {
                    let mut q = shared.queues.lock().unwrap();
                    q.busy[si] = false;
                    !q.pending[si].is_empty()
                };
                if more {
                    shared.work_cv.notify_one();
                }
            }
            PoolWork::Replica { shard, task, generation, reads } => {
                let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    replica_serve(&shared, shard, task, generation, reads);
                }));
                if run.is_err() {
                    shared.stats[shard]
                        .panics_recovered
                        .fetch_add(1, Ordering::Relaxed);
                    eprintln!(
                        "lkgp: pool worker recovered from a panic on shard {shard} (replica)"
                    );
                    // A replica panic counts toward quarantine, but cannot
                    // tear the engine down (the writer may hold the shard).
                    breaker_feed(&shared, shard, true, false, false);
                }
                let more = {
                    let mut q = shared.queues.lock().unwrap();
                    q.replicas[shard] = q.replicas[shard].saturating_sub(1);
                    !q.pending[shard].is_empty()
                };
                if more {
                    shared.work_cv.notify_one();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::store::CurveStore;
    use crate::coordinator::trial::Registry;
    use crate::runtime::RustEngine;

    fn tiny_snapshot() -> Snapshot {
        let mut reg = Registry::new();
        for i in 0..6 {
            let id = reg.add(vec![i as f64 * 0.1, 0.5 - i as f64 * 0.05]);
            for j in 0..3 + i % 3 {
                reg.observe(id, 0.4 + 0.05 * j as f64 + 0.01 * i as f64, 8).unwrap();
            }
        }
        CurveStore::new(8).snapshot(&reg).unwrap()
    }

    #[test]
    fn refit_and_predict_roundtrip() {
        let service = PredictionService::spawn(Box::<RustEngine>::default());
        let snap = tiny_snapshot();
        let theta = service.refit(snap.clone(), vec![], 1).unwrap();
        assert_eq!(theta.len(), 2 + 3);
        let xq = Matrix::from_vec(2, 2, vec![0.2, 0.3, 0.8, 0.1]);
        let preds = service.predict_final(snap, theta, xq).unwrap();
        assert_eq!(preds.len(), 2);
        for (mu, var) in preds {
            assert!(mu.is_finite() && var > 0.0);
        }
    }

    #[test]
    fn concurrent_predictions_are_batched() {
        let service = PredictionService::spawn(Box::<RustEngine>::default());
        let snap = tiny_snapshot();
        let theta = Theta::default_packed(2);
        // enqueue many requests before the worker drains them
        let mut receivers = Vec::new();
        for i in 0..12 {
            let (rtx, rrx) = channel();
            service
                .sender()
                .send(Request::PredictFinal {
                    snapshot: snap.clone(),
                    theta: theta.clone(),
                    xq: Matrix::from_vec(1, 2, vec![0.1 * i as f64 % 1.0, 0.4]),
                    resp: rtx,
                })
                .unwrap();
            receivers.push(rrx);
        }
        for rrx in receivers {
            let preds = rrx.recv().unwrap().unwrap();
            assert_eq!(preds.len(), 1);
        }
        let reqs = service.stats.requests.load(Ordering::Relaxed);
        let batches = service.stats.batches.load(Ordering::Relaxed);
        assert_eq!(reqs, 12);
        assert!(batches <= reqs, "batches={batches} reqs={reqs}");
        // batching factor must be >= 1; with the pre-enqueued burst it is
        // typically well above 1 (the first recv may run solo).
        assert!(service.stats.batch_factor() >= 1.0);
    }

    #[test]
    fn sample_curves_via_service() {
        let service = PredictionService::spawn(Box::<RustEngine>::default());
        let snap = tiny_snapshot();
        let theta = Theta::default_packed(2);
        let xq = Matrix::from_vec(1, 2, vec![0.5, 0.5]);
        let samples = service.sample_curves(snap, theta, xq, 4, 9).unwrap();
        assert_eq!(samples.len(), 4);
        assert_eq!(samples[0].rows(), 6 + 1);
        assert_eq!(samples[0].cols(), 8);
    }

    #[test]
    fn shutdown_on_drop_joins_worker() {
        let service = PredictionService::spawn(Box::<RustEngine>::default());
        drop(service); // must not hang
    }

    fn pool_of(n: usize, cfg: PoolCfg) -> ServicePool {
        let engines: Vec<Box<dyn Engine>> = (0..n)
            .map(|_| Box::<RustEngine>::default() as Box<dyn Engine>)
            .collect();
        ServicePool::spawn(engines, cfg)
    }

    #[test]
    fn pool_roundtrip_and_routing() {
        let pool = pool_of(2, PoolCfg { workers: 2, ..Default::default() });
        let snap = tiny_snapshot();
        let theta = Theta::default_packed(2);
        for shard in 0..2 {
            let handle = pool.handle(shard);
            let xq = Matrix::from_vec(1, 2, vec![0.3, 0.6]);
            let preds = handle.predict_final(snap.clone(), theta.clone(), xq).unwrap();
            assert_eq!(preds.len(), 1);
            assert!(preds[0].0.is_finite() && preds[0].1 > 0.0);
            assert_eq!(pool.stats(shard).requests.load(Ordering::Relaxed), 1);
        }
        // shard 1's traffic never hit shard 0's engine
        assert_eq!(pool.stats(0).batches.load(Ordering::Relaxed), 1);
        assert_eq!(pool.stats(1).batches.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn pool_warm_cache_populates_and_hits() {
        let pool = pool_of(1, PoolCfg { workers: 1, ..Default::default() });
        let snap = tiny_snapshot();
        let theta = Theta::default_packed(2);
        let handle = pool.handle(0);
        let xq = Matrix::from_vec(1, 2, vec![0.4, 0.4]);
        let a = handle
            .predict_final(snap.clone(), theta.clone(), xq.clone())
            .unwrap();
        // second call hits the cached alpha (same generation -> exact guess)
        let b = handle.predict_final(snap, theta, xq).unwrap();
        assert_eq!(pool.stats(0).warm_hits.load(Ordering::Relaxed), 1);
        for (x, y) in a.iter().zip(&b) {
            assert!((x.0 - y.0).abs() < 1e-6 && (x.1 - y.1).abs() < 1e-6);
        }
    }

    #[test]
    fn warm_lru_keys_by_task_and_generation_and_evicts() {
        fn entry(generation: u64) -> Arc<WarmStart> {
            Arc::new(WarmStart {
                generation,
                theta: vec![generation as f64],
                row_ids: Vec::new(),
                m: 1,
                alpha: Vec::new(),
                xq: None,
                cross: Vec::new(),
                precond: None,
                path: None,
            })
        }
        let mut lru = WarmLru::new(2);
        assert!(lru.get(0, 1).is_none());
        lru.put(0, entry(1));
        lru.put(0, entry(2));
        // exact (task, generation) hits, MRU refresh
        assert_eq!(lru.get(0, 1).unwrap().generation, 1);
        assert_eq!(lru.latest_for(0).unwrap().generation, 1);
        // inserting a third evicts the task's least recently used (gen 2)
        lru.put(0, entry(3));
        assert!(lru.get(0, 2).is_none());
        assert_eq!(lru.get(0, 1).unwrap().generation, 1);
        assert_eq!(lru.get(0, 3).unwrap().generation, 3);
        // replacing a generation keeps one entry
        lru.put(0, entry(3));
        assert_eq!(lru.latest_for(0).unwrap().generation, 3);
        // bucket-mates are isolated: another task's lineage neither
        // shadows nor evicts task 0's, and the per-task cap applies
        // independently
        lru.put(7, entry(3));
        lru.put(7, entry(4));
        lru.put(7, entry(5));
        assert_eq!(lru.latest_for(0).unwrap().generation, 3);
        assert_eq!(lru.get(0, 3).unwrap().theta, vec![3.0]);
        assert!(lru.get(7, 3).is_none());
        assert_eq!(lru.get(7, 4).unwrap().generation, 4);
        assert_eq!(lru.get(7, 5).unwrap().generation, 5);
        assert!(lru.get(0, 1).is_some(), "task 0 keeps its own two entries");
    }

    #[test]
    fn typed_query_batch_through_pool_shares_one_solve() {
        let pool = pool_of(1, PoolCfg { workers: 1, ..Default::default() });
        let snap = tiny_snapshot();
        let theta = Theta::default_packed(2);
        let handle = pool.handle(0);
        let xq = Matrix::from_vec(2, 2, vec![0.2, 0.3, 0.7, 0.6]);
        let queries = vec![
            Query::MeanAtFinal { xq: xq.clone() },
            Query::Variance { xq: xq.clone() },
            Query::Quantiles { xq: xq.clone(), ps: vec![0.1, 0.9] },
            Query::MeanAtSteps { xq: xq.clone(), steps: vec![0, 7] },
        ];
        let answers = handle.query(snap.clone(), theta.clone(), queries).unwrap();
        assert_eq!(answers.len(), 4);
        assert_eq!(
            pool.stats(0).engine_solves.load(Ordering::Relaxed),
            1,
            "four variants must share one underlying solve"
        );
        match (&answers[0], &answers[1]) {
            (Answer::Final(f), Answer::Variance(v)) => {
                for (a, b) in f.iter().zip(v) {
                    assert_eq!(a.1.to_bits(), b.to_bits());
                }
            }
            other => panic!("unexpected answers {other:?}"),
        }
        // the first batch was a keyed-cache miss, a same-generation
        // repeat is an exact hit
        assert_eq!(pool.stats(0).warm_cache_misses.load(Ordering::Relaxed), 1);
        let again = handle
            .query(snap, theta, vec![Query::MeanAtFinal { xq }])
            .unwrap();
        assert_eq!(again.len(), 1);
        assert_eq!(pool.stats(0).warm_cache_hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn malformed_query_fails_alone_without_engine_call() {
        let pool = pool_of(1, PoolCfg { workers: 1, ..Default::default() });
        let snap = tiny_snapshot();
        let theta = Theta::default_packed(2);
        let handle = pool.handle(0);
        // wrong width: rejected per-request, never reaches the engine
        let bad = Matrix::from_vec(1, 3, vec![0.1, 0.2, 0.3]);
        let err = handle.query(
            snap.clone(),
            theta.clone(),
            vec![Query::MeanAtFinal { xq: bad }],
        );
        assert!(err.is_err());
        assert_eq!(pool.stats(0).batches.load(Ordering::Relaxed), 0);
        // a healthy same-generation query still succeeds afterwards
        let good = Matrix::from_vec(1, 2, vec![0.4, 0.4]);
        let ok = handle
            .query(snap, theta, vec![Query::MeanAtFinal { xq: good }])
            .unwrap();
        assert_eq!(ok.len(), 1);
    }

    #[test]
    fn pool_rejects_unknown_shard_and_drops_cleanly() {
        let pool = pool_of(1, PoolCfg { workers: 1, ..Default::default() });
        let (rtx, _rrx) = channel();
        let err = pool.submit(
            5,
            Request::PredictFinal {
                snapshot: tiny_snapshot(),
                theta: Theta::default_packed(2),
                xq: Matrix::from_vec(1, 2, vec![0.5, 0.5]),
                resp: rtx,
            },
        );
        assert!(err.is_err());
        drop(pool); // must not hang
    }
}
