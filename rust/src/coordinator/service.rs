//! Prediction service: a worker thread owning the GP engine, fed through
//! an mpsc channel with dynamic request batching.
//!
//! This is the vLLM-router pattern scaled to this workload: many
//! concurrent callers (scheduler rounds, UI, benches) enqueue
//! `PredictFinal` queries; the worker drains the queue and coalesces all
//! queries that target the same model generation into a single engine
//! call (one artifact execution / one batched CG), then scatters the
//! per-caller responses. Refits and sampling requests pass through the
//! same queue, preserving order within a generation.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::gp::Theta;
use crate::linalg::Matrix;
use crate::metrics::LatencyHist;
use crate::runtime::Engine;

use super::store::Snapshot;

/// A request to the prediction service.
pub enum Request {
    /// Re-fit hyper-parameters on a snapshot.
    Refit {
        snapshot: Snapshot,
        theta0: Vec<f64>,
        seed: u64,
        resp: Sender<crate::Result<Vec<f64>>>,
    },
    /// Final-value prediction for query rows (standardized units).
    PredictFinal {
        snapshot: Snapshot,
        theta: Vec<f64>,
        /// Normalized query configs.
        xq: Matrix,
        resp: Sender<crate::Result<Vec<(f64, f64)>>>,
    },
    /// Posterior curve samples over [train; query] x grid.
    SampleCurves {
        snapshot: Snapshot,
        theta: Vec<f64>,
        xq: Matrix,
        samples: usize,
        seed: u64,
        resp: Sender<crate::Result<Vec<Matrix>>>,
    },
    /// Stop the worker.
    Shutdown,
}

/// Shared service statistics.
#[derive(Default)]
pub struct ServiceStats {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub batched_queries: AtomicU64,
    pub latency: Mutex<LatencyHist>,
}

impl ServiceStats {
    /// Mean queries per engine call (batching factor).
    pub fn batch_factor(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed).max(1);
        self.batched_queries.load(Ordering::Relaxed) as f64 / b as f64
    }
}

/// Handle to the service thread.
pub struct PredictionService {
    tx: Sender<Request>,
    pub stats: Arc<ServiceStats>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl PredictionService {
    /// Spawn the worker around an engine.
    pub fn spawn(engine: Box<dyn Engine>) -> Self {
        let (tx, rx) = channel::<Request>();
        let stats = Arc::new(ServiceStats::default());
        let worker_stats = stats.clone();
        let worker = std::thread::spawn(move || worker_loop(engine, rx, worker_stats));
        PredictionService {
            tx,
            stats,
            worker: Some(worker),
        }
    }

    pub fn sender(&self) -> Sender<Request> {
        self.tx.clone()
    }

    /// Synchronous refit helper.
    pub fn refit(&self, snapshot: Snapshot, theta0: Vec<f64>, seed: u64) -> crate::Result<Vec<f64>> {
        let (rtx, rrx) = channel();
        self.tx
            .send(Request::Refit { snapshot, theta0, seed, resp: rtx })
            .map_err(|_| crate::LkgpError::Coordinator("service down".into()))?;
        rrx.recv()
            .map_err(|_| crate::LkgpError::Coordinator("service dropped request".into()))?
    }

    /// Synchronous predict helper.
    pub fn predict_final(
        &self,
        snapshot: Snapshot,
        theta: Vec<f64>,
        xq: Matrix,
    ) -> crate::Result<Vec<(f64, f64)>> {
        let (rtx, rrx) = channel();
        self.tx
            .send(Request::PredictFinal { snapshot, theta, xq, resp: rtx })
            .map_err(|_| crate::LkgpError::Coordinator("service down".into()))?;
        rrx.recv()
            .map_err(|_| crate::LkgpError::Coordinator("service dropped request".into()))?
    }

    /// Synchronous sampling helper.
    pub fn sample_curves(
        &self,
        snapshot: Snapshot,
        theta: Vec<f64>,
        xq: Matrix,
        samples: usize,
        seed: u64,
    ) -> crate::Result<Vec<Matrix>> {
        let (rtx, rrx) = channel();
        self.tx
            .send(Request::SampleCurves { snapshot, theta, xq, samples, seed, resp: rtx })
            .map_err(|_| crate::LkgpError::Coordinator("service down".into()))?;
        rrx.recv()
            .map_err(|_| crate::LkgpError::Coordinator("service dropped request".into()))?
    }
}

impl Drop for PredictionService {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(mut engine: Box<dyn Engine>, rx: Receiver<Request>, stats: Arc<ServiceStats>) {
    // Pending predict-final queries grouped by generation.
    struct Pending {
        snapshot: Snapshot,
        theta: Vec<f64>,
        xq: Matrix,
        resp: Sender<crate::Result<Vec<(f64, f64)>>>,
    }

    loop {
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return,
        };
        // Drain whatever else is queued right now (dynamic batching window).
        let mut queue: Vec<Request> = vec![first];
        while let Ok(r) = rx.try_recv() {
            queue.push(r);
        }

        let mut predicts: Vec<Pending> = Vec::new();
        let flush =
            |engine: &mut Box<dyn Engine>, predicts: &mut Vec<Pending>, stats: &ServiceStats| {
                if predicts.is_empty() {
                    return;
                }
                // group by (generation, theta bits)
                while !predicts.is_empty() {
                    let gen0 = predicts[0].snapshot.generation;
                    let theta0 = predicts[0].theta.clone();
                    let group: Vec<Pending> = {
                        let (take, keep): (Vec<Pending>, Vec<Pending>) = predicts
                            .drain(..)
                            .partition(|p| p.snapshot.generation == gen0 && p.theta == theta0);
                        *predicts = keep;
                        take
                    };
                    // stack queries
                    let total: usize = group.iter().map(|p| p.xq.rows()).sum();
                    let d = group[0].xq.cols();
                    let mut xq = Matrix::zeros(total, d);
                    let mut row = 0;
                    for p in &group {
                        for r in 0..p.xq.rows() {
                            xq.row_mut(row).copy_from_slice(p.xq.row(r));
                            row += 1;
                        }
                    }
                    let t0 = Instant::now();
                    let result = engine.predict_final(&theta0, &group[0].snapshot.data, &xq);
                    stats.batches.fetch_add(1, Ordering::Relaxed);
                    stats
                        .batched_queries
                        .fetch_add(group.len() as u64, Ordering::Relaxed);
                    stats
                        .latency
                        .lock()
                        .unwrap()
                        .record(t0.elapsed().as_micros() as u64);
                    match result {
                        Ok(all) => {
                            let mut off = 0;
                            for p in group {
                                let k = p.xq.rows();
                                let _ = p.resp.send(Ok(all[off..off + k].to_vec()));
                                off += k;
                            }
                        }
                        Err(e) => {
                            let msg = e.to_string();
                            for p in group {
                                let _ = p
                                    .resp
                                    .send(Err(crate::LkgpError::Coordinator(msg.clone())));
                            }
                        }
                    }
                }
            };

        for req in queue {
            stats.requests.fetch_add(1, Ordering::Relaxed);
            match req {
                Request::PredictFinal { snapshot, theta, xq, resp } => {
                    predicts.push(Pending { snapshot, theta, xq, resp });
                }
                Request::Refit { snapshot, theta0, seed, resp } => {
                    // order barrier: flush batched predictions first
                    flush(&mut engine, &mut predicts, &stats);
                    let theta0 = if theta0.is_empty() {
                        Theta::default_packed(snapshot.data.d())
                    } else {
                        theta0
                    };
                    let _ = resp.send(engine.fit(&theta0, &snapshot.data, seed));
                }
                Request::SampleCurves { snapshot, theta, xq, samples, seed, resp } => {
                    flush(&mut engine, &mut predicts, &stats);
                    let _ =
                        resp.send(engine.sample_curves(&theta, &snapshot.data, &xq, samples, seed));
                }
                Request::Shutdown => {
                    flush(&mut engine, &mut predicts, &stats);
                    return;
                }
            }
        }
        flush(&mut engine, &mut predicts, &stats);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::store::CurveStore;
    use crate::coordinator::trial::Registry;
    use crate::runtime::RustEngine;

    fn tiny_snapshot() -> Snapshot {
        let mut reg = Registry::new();
        for i in 0..6 {
            let id = reg.add(vec![i as f64 * 0.1, 0.5 - i as f64 * 0.05]);
            for j in 0..3 + i % 3 {
                reg.observe(id, 0.4 + 0.05 * j as f64 + 0.01 * i as f64, 8).unwrap();
            }
        }
        CurveStore::new(8).snapshot(&reg).unwrap()
    }

    #[test]
    fn refit_and_predict_roundtrip() {
        let service = PredictionService::spawn(Box::<RustEngine>::default());
        let snap = tiny_snapshot();
        let theta = service.refit(snap.clone(), vec![], 1).unwrap();
        assert_eq!(theta.len(), 2 + 3);
        let xq = Matrix::from_vec(2, 2, vec![0.2, 0.3, 0.8, 0.1]);
        let preds = service.predict_final(snap, theta, xq).unwrap();
        assert_eq!(preds.len(), 2);
        for (mu, var) in preds {
            assert!(mu.is_finite() && var > 0.0);
        }
    }

    #[test]
    fn concurrent_predictions_are_batched() {
        let service = PredictionService::spawn(Box::<RustEngine>::default());
        let snap = tiny_snapshot();
        let theta = Theta::default_packed(2);
        // enqueue many requests before the worker drains them
        let mut receivers = Vec::new();
        for i in 0..12 {
            let (rtx, rrx) = channel();
            service
                .sender()
                .send(Request::PredictFinal {
                    snapshot: snap.clone(),
                    theta: theta.clone(),
                    xq: Matrix::from_vec(1, 2, vec![0.1 * i as f64 % 1.0, 0.4]),
                    resp: rtx,
                })
                .unwrap();
            receivers.push(rrx);
        }
        for rrx in receivers {
            let preds = rrx.recv().unwrap().unwrap();
            assert_eq!(preds.len(), 1);
        }
        let reqs = service.stats.requests.load(Ordering::Relaxed);
        let batches = service.stats.batches.load(Ordering::Relaxed);
        assert_eq!(reqs, 12);
        assert!(batches <= reqs, "batches={batches} reqs={reqs}");
        // batching factor must be >= 1; with the pre-enqueued burst it is
        // typically well above 1 (the first recv may run solo).
        assert!(service.stats.batch_factor() >= 1.0);
    }

    #[test]
    fn sample_curves_via_service() {
        let service = PredictionService::spawn(Box::<RustEngine>::default());
        let snap = tiny_snapshot();
        let theta = Theta::default_packed(2);
        let xq = Matrix::from_vec(1, 2, vec![0.5, 0.5]);
        let samples = service.sample_curves(snap, theta, xq, 4, 9).unwrap();
        assert_eq!(samples.len(), 4);
        assert_eq!(samples[0].rows(), 6 + 1);
        assert_eq!(samples[0].cols(), 8);
    }

    #[test]
    fn shutdown_on_drop_joins_worker() {
        let service = PredictionService::spawn(Box::<RustEngine>::default());
        drop(service); // must not hang
    }
}
