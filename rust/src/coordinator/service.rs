//! Prediction serving: single-task worker services and the multi-task
//! sharded [`ServicePool`].
//!
//! This is the vLLM-router pattern scaled to this workload: many
//! concurrent callers (scheduler rounds, UI, benches) enqueue
//! `PredictFinal` queries; a worker drains the queue and coalesces all
//! queries that target the same model generation into a single engine
//! call (one artifact execution / one batched CG), then scatters the
//! per-caller responses. Refits and sampling requests pass through the
//! same queue, preserving order within a generation.
//!
//! Two front-ends share the same batching core:
//!
//! * [`PredictionService`] — the original single-task service: one worker
//!   thread owning one engine, fed through an mpsc channel. Cold solves
//!   only (stable baseline).
//! * [`ServicePool`] — the multi-task serving layer: per-task engine
//!   shards behind a shared worker pool. Requests are routed by task id,
//!   same-generation `PredictFinal` batches coalesce *across* concurrent
//!   callers per shard, submission applies backpressure (bounded per-shard
//!   queues), and every shard tracks latency/queue-depth/warm-start
//!   metrics. Each shard caches the previous generation's converged
//!   `alpha` (and fitted theta) as a [`WarmStart`] so the next
//!   generation's near-identical masked-Kronecker solve starts from the
//!   prior solution instead of zero (see `linalg::cg_batch_warm`).
//!
//! Schedulers drive either front-end through the [`PredictClient`] trait.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::gp::Theta;
use crate::linalg::Matrix;
use crate::metrics::LatencyHist;
use crate::runtime::Engine;

use super::store::{Snapshot, WarmStart};

/// A request to the prediction service.
pub enum Request {
    /// Re-fit hyper-parameters on a snapshot.
    Refit {
        snapshot: Snapshot,
        theta0: Vec<f64>,
        seed: u64,
        resp: Sender<crate::Result<Vec<f64>>>,
    },
    /// Final-value prediction for query rows (standardized units).
    PredictFinal {
        snapshot: Snapshot,
        theta: Vec<f64>,
        /// Normalized query configs.
        xq: Matrix,
        resp: Sender<crate::Result<Vec<(f64, f64)>>>,
    },
    /// Posterior curve samples over [train; query] x grid.
    SampleCurves {
        snapshot: Snapshot,
        theta: Vec<f64>,
        xq: Matrix,
        samples: usize,
        seed: u64,
        resp: Sender<crate::Result<Vec<Matrix>>>,
    },
    /// Stop the worker.
    Shutdown,
}

/// Shared service statistics (one instance per service / per pool shard).
#[derive(Default)]
pub struct ServiceStats {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub batched_queries: AtomicU64,
    pub latency: Mutex<LatencyHist>,
    /// Requests enqueued through a pool shard (submit path).
    pub enqueued: AtomicU64,
    /// Highest per-shard queue depth observed at enqueue time.
    pub peak_queue_depth: AtomicU64,
    /// Engine calls that ran with a warm-start guess.
    pub warm_hits: AtomicU64,
    /// Total per-RHS CG iterations reported by warm-capable engines.
    pub cg_iters: AtomicU64,
    /// Total per-RHS operator rows applied (`CgStats::mvm_rows`) — the
    /// true MVM work after warm starts, preconditioning, and active-set
    /// compaction.
    pub cg_mvm_rows: AtomicU64,
}

impl ServiceStats {
    /// Mean queries per engine call (batching factor).
    pub fn batch_factor(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed).max(1);
        self.batched_queries.load(Ordering::Relaxed) as f64 / b as f64
    }
}

/// Synchronous client interface to a prediction backend: the single-task
/// [`PredictionService`] or one shard of a [`ServicePool`]. The scheduler
/// is written against this trait, so it runs unchanged on either.
pub trait PredictClient {
    /// Re-fit hyper-parameters on a snapshot (blocking).
    fn refit(&self, snapshot: Snapshot, theta0: Vec<f64>, seed: u64) -> crate::Result<Vec<f64>>;

    /// Final-value predictions for query rows (blocking).
    fn predict_final(
        &self,
        snapshot: Snapshot,
        theta: Vec<f64>,
        xq: Matrix,
    ) -> crate::Result<Vec<(f64, f64)>>;

    /// Posterior curve samples (blocking).
    fn sample_curves(
        &self,
        snapshot: Snapshot,
        theta: Vec<f64>,
        xq: Matrix,
        samples: usize,
        seed: u64,
    ) -> crate::Result<Vec<Matrix>>;

    /// Mean queries per engine call (batching factor), for run reports.
    fn batch_factor(&self) -> f64;
}

// ---------------------------------------------------------------------------
// Shared batching core

/// An engine plus its warm-start cache; exclusive to one worker at a time.
struct EngineSlot {
    engine: Box<dyn Engine>,
    warm: Option<Arc<WarmStart>>,
}

/// A queued `PredictFinal` awaiting coalescing.
struct PendingPredict {
    snapshot: Snapshot,
    theta: Vec<f64>,
    xq: Matrix,
    resp: Sender<crate::Result<Vec<(f64, f64)>>>,
}

/// Flush queued predictions: group by (generation, theta), stack each
/// group's queries into one engine call, scatter the responses. With
/// `warm_enabled`, solves start from the shard's cached alpha (or the
/// snapshot's lineage) and the converged alpha is cached back.
fn flush_predicts(
    slot: &mut EngineSlot,
    predicts: &mut Vec<PendingPredict>,
    stats: &ServiceStats,
    warm_enabled: bool,
) {
    while !predicts.is_empty() {
        let gen0 = predicts[0].snapshot.generation;
        let theta0 = predicts[0].theta.clone();
        let cols0 = predicts[0].xq.cols();
        // Bitwise theta comparison so the head request always matches its
        // own group even if a caller passed NaN; query width is part of
        // the key so heterogeneous requests can never corrupt the stack.
        let same_theta = |t: &[f64]| {
            t.len() == theta0.len()
                && t.iter().zip(&theta0).all(|(a, b)| a.to_bits() == b.to_bits())
        };
        let group: Vec<PendingPredict> = {
            let (take, keep): (Vec<PendingPredict>, Vec<PendingPredict>) =
                predicts.drain(..).partition(|p| {
                    p.snapshot.generation == gen0
                        && p.xq.cols() == cols0
                        && same_theta(&p.theta)
                });
            *predicts = keep;
            take
        };
        let snap = group[0].snapshot.clone();
        // stack queries
        let total: usize = group.iter().map(|p| p.xq.rows()).sum();
        let d = group[0].xq.cols();
        let mut xq = Matrix::zeros(total, d);
        let mut row = 0;
        for p in &group {
            for r in 0..p.xq.rows() {
                xq.row_mut(row).copy_from_slice(p.xq.row(r));
                row += 1;
            }
        }
        // warm-start guess: shard cache first, then snapshot lineage. The
        // full batched guess (alpha + cross columns) applies when the same
        // queries repeat; otherwise the alpha alone is embedded. The
        // factored preconditioner rides the same lineage but is NOT gated
        // by `warm_enabled` — the flags are independent (a `--warm off`
        // shard still amortizes the factorization), and the engine checks
        // factor staleness itself, so passing old factors is always safe.
        let lineage = slot.warm.as_ref().or(snap.warm.as_ref());
        let guess: Option<Vec<f64>> = if warm_enabled {
            lineage.and_then(|w| w.embed_predict(&snap.row_ids, snap.data.m(), &xq))
        } else {
            None
        };
        let precond = lineage.and_then(|w| w.precond.clone());
        let t0 = Instant::now();
        let result =
            slot.engine
                .predict_final_cached(&theta0, &snap.data, &xq, guess.as_deref(), precond);
        stats.batches.fetch_add(1, Ordering::Relaxed);
        stats
            .batched_queries
            .fetch_add(group.len() as u64, Ordering::Relaxed);
        if guess.is_some() {
            stats.warm_hits.fetch_add(1, Ordering::Relaxed);
        }
        stats
            .latency
            .lock()
            .unwrap()
            .record(t0.elapsed().as_micros() as u64);
        match result {
            Ok(outcome) => {
                stats
                    .cg_iters
                    .fetch_add(outcome.cg_iters as u64, Ordering::Relaxed);
                stats
                    .cg_mvm_rows
                    .fetch_add(outcome.cg_mvm_rows as u64, Ordering::Relaxed);
                if warm_enabled {
                    if let Some(alpha) = outcome.alpha {
                        slot.warm = Some(Arc::new(WarmStart {
                            generation: snap.generation,
                            theta: theta0.clone(),
                            row_ids: (*snap.row_ids).clone(),
                            m: snap.data.m(),
                            alpha,
                            xq: Some(xq.clone()),
                            cross: outcome.cross.unwrap_or_default(),
                            precond: outcome.precond,
                        }));
                    }
                } else if let Some(factors) = outcome.precond {
                    // warm starts off: cache ONLY the factored
                    // preconditioner (empty alpha means nothing embeds as
                    // a guess, so solves stay cold as requested).
                    slot.warm = Some(Arc::new(WarmStart {
                        generation: snap.generation,
                        theta: theta0.clone(),
                        row_ids: (*snap.row_ids).clone(),
                        m: snap.data.m(),
                        alpha: Vec::new(),
                        xq: None,
                        cross: Vec::new(),
                        precond: Some(factors),
                    }));
                }
                let mut off = 0;
                for p in group {
                    let k = p.xq.rows();
                    let _ = p.resp.send(Ok(outcome.preds[off..off + k].to_vec()));
                    off += k;
                }
            }
            Err(e) => {
                let msg = e.to_string();
                for p in group {
                    let _ = p
                        .resp
                        .send(Err(crate::LkgpError::Coordinator(msg.clone())));
                }
            }
        }
    }
}

/// Warm theta for an empty-`theta0` refit: shard cache, then snapshot
/// lineage, then the prior mean.
fn warm_theta(slot: &EngineSlot, snapshot: &Snapshot, d: usize) -> Vec<f64> {
    if let Some(w) = slot.warm.as_ref().or(snapshot.warm.as_ref()) {
        if w.theta.len() == d + 3 {
            return w.theta.clone();
        }
    }
    Theta::default_packed(d)
}

/// Cache the fitted theta in the shard lineage, preserving any cached
/// alpha and factored preconditioner (both solved under nearby
/// hyper-parameters, so both remain excellent across the refit).
fn record_fit_lineage(slot: &mut EngineSlot, snapshot: &Snapshot, theta: Vec<f64>) {
    let updated = match slot.warm.take() {
        Some(w) => WarmStart { theta, ..(*w).clone() },
        None => WarmStart {
            generation: snapshot.generation,
            theta,
            row_ids: (*snapshot.row_ids).clone(),
            m: snapshot.data.m(),
            alpha: Vec::new(),
            xq: None,
            cross: Vec::new(),
            precond: None,
        },
    };
    slot.warm = Some(Arc::new(updated));
}

/// Process one drained batch of requests against an engine slot. Returns
/// false when a `Shutdown` was seen (remaining requests are dropped, like
/// the original single-worker loop).
fn process_batch(
    slot: &mut EngineSlot,
    batch: Vec<Request>,
    stats: &ServiceStats,
    warm_enabled: bool,
) -> bool {
    let mut predicts: Vec<PendingPredict> = Vec::new();
    for req in batch {
        stats.requests.fetch_add(1, Ordering::Relaxed);
        match req {
            Request::PredictFinal { snapshot, theta, xq, resp } => {
                predicts.push(PendingPredict { snapshot, theta, xq, resp });
            }
            Request::Refit { snapshot, theta0, seed, resp } => {
                // order barrier: flush batched predictions first
                flush_predicts(slot, &mut predicts, stats, warm_enabled);
                let d = snapshot.data.d();
                let theta0 = if theta0.is_empty() {
                    if warm_enabled {
                        warm_theta(slot, &snapshot, d)
                    } else {
                        Theta::default_packed(d)
                    }
                } else {
                    theta0
                };
                let result = slot.engine.fit(&theta0, &snapshot.data, seed);
                if warm_enabled {
                    if let Ok(theta) = &result {
                        record_fit_lineage(slot, &snapshot, theta.clone());
                    }
                }
                let _ = resp.send(result);
            }
            Request::SampleCurves { snapshot, theta, xq, samples, seed, resp } => {
                flush_predicts(slot, &mut predicts, stats, warm_enabled);
                let _ = resp.send(slot.engine.sample_curves(
                    &theta,
                    &snapshot.data,
                    &xq,
                    samples,
                    seed,
                ));
            }
            Request::Shutdown => {
                flush_predicts(slot, &mut predicts, stats, warm_enabled);
                return false;
            }
        }
    }
    flush_predicts(slot, &mut predicts, stats, warm_enabled);
    true
}

// ---------------------------------------------------------------------------
// Single-task service

/// Handle to the single-task service thread.
pub struct PredictionService {
    tx: Sender<Request>,
    pub stats: Arc<ServiceStats>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl PredictionService {
    /// Spawn the worker around an engine.
    pub fn spawn(engine: Box<dyn Engine>) -> Self {
        let (tx, rx) = channel::<Request>();
        let stats = Arc::new(ServiceStats::default());
        let worker_stats = stats.clone();
        let worker = std::thread::spawn(move || worker_loop(engine, rx, worker_stats));
        PredictionService {
            tx,
            stats,
            worker: Some(worker),
        }
    }

    pub fn sender(&self) -> Sender<Request> {
        self.tx.clone()
    }

    /// Synchronous refit helper.
    pub fn refit(&self, snapshot: Snapshot, theta0: Vec<f64>, seed: u64) -> crate::Result<Vec<f64>> {
        let (rtx, rrx) = channel();
        self.tx
            .send(Request::Refit { snapshot, theta0, seed, resp: rtx })
            .map_err(|_| crate::LkgpError::Coordinator("service down".into()))?;
        rrx.recv()
            .map_err(|_| crate::LkgpError::Coordinator("service dropped request".into()))?
    }

    /// Synchronous predict helper.
    pub fn predict_final(
        &self,
        snapshot: Snapshot,
        theta: Vec<f64>,
        xq: Matrix,
    ) -> crate::Result<Vec<(f64, f64)>> {
        let (rtx, rrx) = channel();
        self.tx
            .send(Request::PredictFinal { snapshot, theta, xq, resp: rtx })
            .map_err(|_| crate::LkgpError::Coordinator("service down".into()))?;
        rrx.recv()
            .map_err(|_| crate::LkgpError::Coordinator("service dropped request".into()))?
    }

    /// Synchronous sampling helper.
    pub fn sample_curves(
        &self,
        snapshot: Snapshot,
        theta: Vec<f64>,
        xq: Matrix,
        samples: usize,
        seed: u64,
    ) -> crate::Result<Vec<Matrix>> {
        let (rtx, rrx) = channel();
        self.tx
            .send(Request::SampleCurves { snapshot, theta, xq, samples, seed, resp: rtx })
            .map_err(|_| crate::LkgpError::Coordinator("service down".into()))?;
        rrx.recv()
            .map_err(|_| crate::LkgpError::Coordinator("service dropped request".into()))?
    }
}

impl PredictClient for PredictionService {
    fn refit(&self, snapshot: Snapshot, theta0: Vec<f64>, seed: u64) -> crate::Result<Vec<f64>> {
        PredictionService::refit(self, snapshot, theta0, seed)
    }

    fn predict_final(
        &self,
        snapshot: Snapshot,
        theta: Vec<f64>,
        xq: Matrix,
    ) -> crate::Result<Vec<(f64, f64)>> {
        PredictionService::predict_final(self, snapshot, theta, xq)
    }

    fn sample_curves(
        &self,
        snapshot: Snapshot,
        theta: Vec<f64>,
        xq: Matrix,
        samples: usize,
        seed: u64,
    ) -> crate::Result<Vec<Matrix>> {
        PredictionService::sample_curves(self, snapshot, theta, xq, samples, seed)
    }

    fn batch_factor(&self) -> f64 {
        self.stats.batch_factor()
    }
}

impl Drop for PredictionService {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(engine: Box<dyn Engine>, rx: Receiver<Request>, stats: Arc<ServiceStats>) {
    let mut slot = EngineSlot { engine, warm: None };
    loop {
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return,
        };
        // Drain whatever else is queued right now (dynamic batching window).
        let mut queue: Vec<Request> = vec![first];
        while let Ok(r) = rx.try_recv() {
            queue.push(r);
        }
        if !process_batch(&mut slot, queue, &stats, false) {
            return;
        }
    }
}

// ---------------------------------------------------------------------------
// Multi-task sharded pool

/// Configuration for [`ServicePool`].
#[derive(Clone, Copy, Debug)]
pub struct PoolCfg {
    /// Worker threads shared across all shards.
    pub workers: usize,
    /// Per-shard pending-queue bound; `submit` blocks when a shard's queue
    /// is full (backpressure).
    pub max_queue: usize,
    /// Warm-start solves from each shard's cached alpha/theta lineage.
    pub warm_start: bool,
}

impl Default for PoolCfg {
    fn default() -> Self {
        PoolCfg {
            // Each engine call fans out its own batch-parallel threads
            // (MaskedKronOp::apply_batch), so budget roughly half the
            // cores for workers to avoid worker x inner-thread
            // oversubscription. Callers with known task counts should set
            // this explicitly (see benches/hotpath.rs).
            workers: (crate::util::num_threads() / 2).max(1),
            max_queue: 1024,
            warm_start: true,
        }
    }
}

struct PoolQueues {
    pending: Vec<VecDeque<Request>>,
    /// A shard is busy while a worker processes its drained batch; the
    /// flag serializes engine access per shard and preserves per-shard
    /// request order.
    busy: Vec<bool>,
    /// Round-robin scan start so a continuously-loaded low-index shard
    /// cannot starve higher-index shards when workers are scarce.
    cursor: usize,
    shutdown: bool,
}

struct PoolShared {
    queues: Mutex<PoolQueues>,
    /// Workers wait here for claimable work.
    work_cv: Condvar,
    /// Submitters wait here for queue space (backpressure).
    space_cv: Condvar,
    shards: Vec<Mutex<EngineSlot>>,
    stats: Vec<Arc<ServiceStats>>,
    max_queue: usize,
    warm_start: bool,
}

/// Multi-task sharded prediction service: one engine shard per task id, a
/// shared worker pool, request routing by task id, per-shard coalescing
/// across concurrent callers, bounded queues, and warm-started solves.
pub struct ServicePool {
    shared: Arc<PoolShared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl ServicePool {
    /// Spawn a pool with one shard per engine and `cfg.workers` shared
    /// worker threads.
    pub fn spawn(engines: Vec<Box<dyn Engine>>, cfg: PoolCfg) -> Self {
        let shards: Vec<Mutex<EngineSlot>> = engines
            .into_iter()
            .map(|engine| Mutex::new(EngineSlot { engine, warm: None }))
            .collect();
        let n = shards.len();
        let shared = Arc::new(PoolShared {
            queues: Mutex::new(PoolQueues {
                pending: (0..n).map(|_| VecDeque::new()).collect(),
                busy: vec![false; n],
                cursor: 0,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            space_cv: Condvar::new(),
            shards,
            stats: (0..n).map(|_| Arc::new(ServiceStats::default())).collect(),
            max_queue: cfg.max_queue.max(1),
            warm_start: cfg.warm_start,
        });
        let workers = (0..cfg.workers.max(1))
            .map(|_| {
                let shared = shared.clone();
                std::thread::spawn(move || pool_worker(shared))
            })
            .collect();
        ServicePool { shared, workers }
    }

    /// Number of shards (tasks) in the pool.
    pub fn shards(&self) -> usize {
        self.shared.shards.len()
    }

    /// Enqueue a request for a task shard; blocks while the shard's queue
    /// is at `max_queue` (backpressure).
    pub fn submit(&self, shard: usize, req: Request) -> crate::Result<()> {
        submit_to(&self.shared, shard, req)
    }

    /// A cloneable synchronous handle bound to one task shard.
    pub fn handle(&self, shard: usize) -> ShardHandle {
        assert!(shard < self.shards(), "shard {shard} out of range");
        ShardHandle {
            shared: self.shared.clone(),
            shard,
        }
    }

    /// Per-shard statistics.
    pub fn stats(&self, shard: usize) -> &Arc<ServiceStats> {
        &self.shared.stats[shard]
    }

    /// Current pending-queue depth of a shard.
    pub fn queue_depth(&self, shard: usize) -> usize {
        self.shared.queues.lock().unwrap().pending[shard].len()
    }
}

impl Drop for ServicePool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queues.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        self.shared.space_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Cloneable synchronous client bound to one shard of a [`ServicePool`].
/// Implements [`PredictClient`], so a `Scheduler` can drive it directly.
#[derive(Clone)]
pub struct ShardHandle {
    shared: Arc<PoolShared>,
    shard: usize,
}

impl ShardHandle {
    /// The shard this handle routes to.
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Enqueue a raw request (blocking on backpressure).
    pub fn submit(&self, req: Request) -> crate::Result<()> {
        submit_to(&self.shared, self.shard, req)
    }

    /// This shard's statistics.
    pub fn stats(&self) -> &Arc<ServiceStats> {
        &self.shared.stats[self.shard]
    }
}

impl PredictClient for ShardHandle {
    fn refit(&self, snapshot: Snapshot, theta0: Vec<f64>, seed: u64) -> crate::Result<Vec<f64>> {
        let (rtx, rrx) = channel();
        self.submit(Request::Refit { snapshot, theta0, seed, resp: rtx })?;
        rrx.recv()
            .map_err(|_| crate::LkgpError::Coordinator("pool dropped request".into()))?
    }

    fn predict_final(
        &self,
        snapshot: Snapshot,
        theta: Vec<f64>,
        xq: Matrix,
    ) -> crate::Result<Vec<(f64, f64)>> {
        let (rtx, rrx) = channel();
        self.submit(Request::PredictFinal { snapshot, theta, xq, resp: rtx })?;
        rrx.recv()
            .map_err(|_| crate::LkgpError::Coordinator("pool dropped request".into()))?
    }

    fn sample_curves(
        &self,
        snapshot: Snapshot,
        theta: Vec<f64>,
        xq: Matrix,
        samples: usize,
        seed: u64,
    ) -> crate::Result<Vec<Matrix>> {
        let (rtx, rrx) = channel();
        self.submit(Request::SampleCurves { snapshot, theta, xq, samples, seed, resp: rtx })?;
        rrx.recv()
            .map_err(|_| crate::LkgpError::Coordinator("pool dropped request".into()))?
    }

    fn batch_factor(&self) -> f64 {
        self.stats().batch_factor()
    }
}

fn submit_to(shared: &PoolShared, shard: usize, req: Request) -> crate::Result<()> {
    if shard >= shared.shards.len() {
        return Err(crate::LkgpError::Coordinator(format!(
            "no shard {shard} (pool has {})",
            shared.shards.len()
        )));
    }
    if matches!(req, Request::Shutdown) {
        // Per-request shutdown belongs to the single-task service; the
        // pool's lifecycle is its Drop impl.
        return Err(crate::LkgpError::Coordinator(
            "Shutdown is not routable through the pool; drop the pool instead".into(),
        ));
    }
    let depth = {
        let mut q = shared.queues.lock().unwrap();
        loop {
            if q.shutdown {
                return Err(crate::LkgpError::Coordinator("pool shutting down".into()));
            }
            if q.pending[shard].len() < shared.max_queue {
                break;
            }
            q = shared.space_cv.wait(q).unwrap();
        }
        q.pending[shard].push_back(req);
        q.pending[shard].len() as u64
    };
    let stats = &shared.stats[shard];
    stats.enqueued.fetch_add(1, Ordering::Relaxed);
    stats.peak_queue_depth.fetch_max(depth, Ordering::Relaxed);
    shared.work_cv.notify_one();
    Ok(())
}

fn pool_worker(shared: Arc<PoolShared>) {
    loop {
        // Claim an idle shard with pending work (round-robin from the
        // shared cursor so no shard is starved); drain its queue.
        let (si, batch) = {
            let mut q = shared.queues.lock().unwrap();
            loop {
                let k = q.pending.len();
                let start = q.cursor;
                let claim = (0..k)
                    .map(|o| (start + o) % k.max(1))
                    .find(|&i| !q.busy[i] && !q.pending[i].is_empty());
                if let Some(si) = claim {
                    q.busy[si] = true;
                    q.cursor = (si + 1) % k;
                    let batch: Vec<Request> = q.pending[si].drain(..).collect();
                    break (si, batch);
                }
                if q.shutdown {
                    return;
                }
                q = shared.work_cv.wait(q).unwrap();
            }
        };
        shared.space_cv.notify_all();
        // The busy flag guarantees exclusivity, so the shard lock is
        // uncontended (it exists to satisfy Sync). A panic inside an
        // engine call must not wedge the shard: catch it, shed the
        // poisoned-lock state, and always clear the busy flag below.
        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut slot = shared.shards[si]
                .lock()
                .unwrap_or_else(|poisoned| poisoned.into_inner());
            process_batch(&mut slot, batch, &shared.stats[si], shared.warm_start);
        }));
        if run.is_err() {
            eprintln!("lkgp: pool worker recovered from a panic on shard {si}");
        }
        let more = {
            let mut q = shared.queues.lock().unwrap();
            q.busy[si] = false;
            !q.pending[si].is_empty()
        };
        if more {
            shared.work_cv.notify_one();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::store::CurveStore;
    use crate::coordinator::trial::Registry;
    use crate::runtime::RustEngine;

    fn tiny_snapshot() -> Snapshot {
        let mut reg = Registry::new();
        for i in 0..6 {
            let id = reg.add(vec![i as f64 * 0.1, 0.5 - i as f64 * 0.05]);
            for j in 0..3 + i % 3 {
                reg.observe(id, 0.4 + 0.05 * j as f64 + 0.01 * i as f64, 8).unwrap();
            }
        }
        CurveStore::new(8).snapshot(&reg).unwrap()
    }

    #[test]
    fn refit_and_predict_roundtrip() {
        let service = PredictionService::spawn(Box::<RustEngine>::default());
        let snap = tiny_snapshot();
        let theta = service.refit(snap.clone(), vec![], 1).unwrap();
        assert_eq!(theta.len(), 2 + 3);
        let xq = Matrix::from_vec(2, 2, vec![0.2, 0.3, 0.8, 0.1]);
        let preds = service.predict_final(snap, theta, xq).unwrap();
        assert_eq!(preds.len(), 2);
        for (mu, var) in preds {
            assert!(mu.is_finite() && var > 0.0);
        }
    }

    #[test]
    fn concurrent_predictions_are_batched() {
        let service = PredictionService::spawn(Box::<RustEngine>::default());
        let snap = tiny_snapshot();
        let theta = Theta::default_packed(2);
        // enqueue many requests before the worker drains them
        let mut receivers = Vec::new();
        for i in 0..12 {
            let (rtx, rrx) = channel();
            service
                .sender()
                .send(Request::PredictFinal {
                    snapshot: snap.clone(),
                    theta: theta.clone(),
                    xq: Matrix::from_vec(1, 2, vec![0.1 * i as f64 % 1.0, 0.4]),
                    resp: rtx,
                })
                .unwrap();
            receivers.push(rrx);
        }
        for rrx in receivers {
            let preds = rrx.recv().unwrap().unwrap();
            assert_eq!(preds.len(), 1);
        }
        let reqs = service.stats.requests.load(Ordering::Relaxed);
        let batches = service.stats.batches.load(Ordering::Relaxed);
        assert_eq!(reqs, 12);
        assert!(batches <= reqs, "batches={batches} reqs={reqs}");
        // batching factor must be >= 1; with the pre-enqueued burst it is
        // typically well above 1 (the first recv may run solo).
        assert!(service.stats.batch_factor() >= 1.0);
    }

    #[test]
    fn sample_curves_via_service() {
        let service = PredictionService::spawn(Box::<RustEngine>::default());
        let snap = tiny_snapshot();
        let theta = Theta::default_packed(2);
        let xq = Matrix::from_vec(1, 2, vec![0.5, 0.5]);
        let samples = service.sample_curves(snap, theta, xq, 4, 9).unwrap();
        assert_eq!(samples.len(), 4);
        assert_eq!(samples[0].rows(), 6 + 1);
        assert_eq!(samples[0].cols(), 8);
    }

    #[test]
    fn shutdown_on_drop_joins_worker() {
        let service = PredictionService::spawn(Box::<RustEngine>::default());
        drop(service); // must not hang
    }

    fn pool_of(n: usize, cfg: PoolCfg) -> ServicePool {
        let engines: Vec<Box<dyn Engine>> = (0..n)
            .map(|_| Box::<RustEngine>::default() as Box<dyn Engine>)
            .collect();
        ServicePool::spawn(engines, cfg)
    }

    #[test]
    fn pool_roundtrip_and_routing() {
        let pool = pool_of(2, PoolCfg { workers: 2, ..Default::default() });
        let snap = tiny_snapshot();
        let theta = Theta::default_packed(2);
        for shard in 0..2 {
            let handle = pool.handle(shard);
            let xq = Matrix::from_vec(1, 2, vec![0.3, 0.6]);
            let preds = handle.predict_final(snap.clone(), theta.clone(), xq).unwrap();
            assert_eq!(preds.len(), 1);
            assert!(preds[0].0.is_finite() && preds[0].1 > 0.0);
            assert_eq!(pool.stats(shard).requests.load(Ordering::Relaxed), 1);
        }
        // shard 1's traffic never hit shard 0's engine
        assert_eq!(pool.stats(0).batches.load(Ordering::Relaxed), 1);
        assert_eq!(pool.stats(1).batches.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn pool_warm_cache_populates_and_hits() {
        let pool = pool_of(1, PoolCfg { workers: 1, ..Default::default() });
        let snap = tiny_snapshot();
        let theta = Theta::default_packed(2);
        let handle = pool.handle(0);
        let xq = Matrix::from_vec(1, 2, vec![0.4, 0.4]);
        let a = handle
            .predict_final(snap.clone(), theta.clone(), xq.clone())
            .unwrap();
        // second call hits the cached alpha (same generation -> exact guess)
        let b = handle.predict_final(snap, theta, xq).unwrap();
        assert_eq!(pool.stats(0).warm_hits.load(Ordering::Relaxed), 1);
        for (x, y) in a.iter().zip(&b) {
            assert!((x.0 - y.0).abs() < 1e-6 && (x.1 - y.1).abs() < 1e-6);
        }
    }

    #[test]
    fn pool_rejects_unknown_shard_and_drops_cleanly() {
        let pool = pool_of(1, PoolCfg { workers: 1, ..Default::default() });
        let (rtx, _rrx) = channel();
        let err = pool.submit(
            5,
            Request::PredictFinal {
                snapshot: tiny_snapshot(),
                theta: Theta::default_packed(2),
                xq: Matrix::from_vec(1, 2, vec![0.5, 0.5]),
                resp: rtx,
            },
        );
        assert!(err.is_err());
        drop(pool); // must not hang
    }
}
