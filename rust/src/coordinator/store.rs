//! Curve store: turns the trial registry into model-space snapshots.
//!
//! The GP engines consume transformed, immutable [`Snapshot`]s; the store
//! owns the epoch grid and re-fits the paper's §B transforms on every
//! snapshot (they depend on the observed data). Snapshots carry a
//! generation counter so the prediction service can batch requests that
//! refer to the same model state.

use std::sync::Arc;

use crate::gp::lkgp::Dataset;
use crate::gp::transforms::{TTransform, XTransform, YTransform};
use crate::linalg::Matrix;

use super::trial::{Registry, TrialId};

/// Immutable model-space view of the registry at some generation.
#[derive(Clone)]
pub struct Snapshot {
    /// Monotone generation counter (bumped per snapshot).
    pub generation: u64,
    /// Training data: one row per trial with >= 1 observation.
    pub data: Arc<Dataset>,
    /// Trial ids of the training rows, in row order.
    pub row_ids: Arc<Vec<TrialId>>,
    /// Normalized configs for ALL registered trials (query space).
    pub all_x: Arc<Matrix>,
    /// Trial ids in `all_x` row order.
    pub all_ids: Arc<Vec<TrialId>>,
    /// Output transform for undoing predictions.
    pub ytf: Arc<YTransform>,
}

/// Builds snapshots from a registry over a fixed epoch grid.
pub struct CurveStore {
    /// Raw epoch grid (1-based epochs).
    pub epochs: Vec<f64>,
    generation: u64,
}

impl CurveStore {
    pub fn new(max_epochs: usize) -> Self {
        CurveStore {
            epochs: (1..=max_epochs).map(|e| e as f64).collect(),
            generation: 0,
        }
    }

    pub fn max_epochs(&self) -> usize {
        self.epochs.len()
    }

    /// Build a snapshot: transforms fit on current observations.
    pub fn snapshot(&mut self, reg: &Registry) -> crate::Result<Snapshot> {
        let m = self.epochs.len();
        let observed = reg.observed();
        if observed.is_empty() {
            return Err(crate::LkgpError::Coordinator(
                "snapshot needs at least one observation".into(),
            ));
        }
        let d = reg.get(observed[0]).config.len();
        let n = observed.len();

        let mut xraw = Matrix::zeros(n, d);
        let mut y = Matrix::zeros(n, m);
        let mut mask = Matrix::zeros(n, m);
        for (row, &id) in observed.iter().enumerate() {
            let t = reg.get(id);
            xraw.row_mut(row).copy_from_slice(&t.config);
            for (j, &v) in t.curve.iter().enumerate().take(m) {
                y[(row, j)] = v;
                mask[(row, j)] = 1.0;
            }
        }

        // X transform must cover every registered config (queries too).
        let total = reg.len();
        let mut all_raw = Matrix::zeros(total, d);
        let mut all_ids = Vec::with_capacity(total);
        for (row, t) in reg.iter().enumerate() {
            all_raw.row_mut(row).copy_from_slice(&t.config);
            all_ids.push(t.id);
        }
        let xtf = XTransform::fit(&all_raw);
        let x = xtf.apply(&xraw);
        let all_x = xtf.apply(&all_raw);
        let ttf = TTransform::fit(&self.epochs);
        let t = ttf.apply(&self.epochs);
        let ytf = YTransform::fit(&y, &mask);
        let ys = ytf.apply(&y, &mask);

        self.generation += 1;
        Ok(Snapshot {
            generation: self.generation,
            data: Arc::new(Dataset { x, t, y: ys, mask }),
            row_ids: Arc::new(observed),
            all_x: Arc::new(all_x),
            all_ids: Arc::new(all_ids),
            ytf: Arc::new(ytf),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trial::TrialStatus;

    #[test]
    fn snapshot_shapes_and_transforms() {
        let mut reg = Registry::new();
        let a = reg.add(vec![1.0, 10.0]);
        let b = reg.add(vec![2.0, 20.0]);
        let _c = reg.add(vec![3.0, 30.0]); // never observed -> query only
        reg.set_status(a, TrialStatus::Running);
        reg.observe(a, 0.5, 5).unwrap();
        reg.observe(a, 0.6, 5).unwrap();
        reg.observe(b, 0.4, 5).unwrap();

        let mut store = CurveStore::new(5);
        let snap = store.snapshot(&reg).unwrap();
        assert_eq!(snap.generation, 1);
        assert_eq!(snap.data.n(), 2);
        assert_eq!(snap.data.m(), 5);
        assert_eq!(snap.all_x.rows(), 3);
        assert_eq!(snap.row_ids.len(), 2);
        // mask prefix lengths
        assert_eq!(snap.data.mask[(0, 1)], 1.0);
        assert_eq!(snap.data.mask[(0, 2)], 0.0);
        assert_eq!(snap.data.mask[(1, 0)], 1.0);
        assert_eq!(snap.data.mask[(1, 1)], 0.0);
        // x normalized to unit cube over ALL configs
        assert_eq!(snap.all_x[(0, 0)], 0.0);
        assert_eq!(snap.all_x[(2, 0)], 1.0);
        // generations increment
        let snap2 = store.snapshot(&reg).unwrap();
        assert_eq!(snap2.generation, 2);
    }

    #[test]
    fn snapshot_requires_observations() {
        let mut reg = Registry::new();
        reg.add(vec![0.5]);
        let mut store = CurveStore::new(4);
        assert!(store.snapshot(&reg).is_err());
    }

    #[test]
    fn y_standardization_applied() {
        let mut reg = Registry::new();
        let a = reg.add(vec![0.0]);
        reg.observe(a, 0.2, 4).unwrap();
        reg.observe(a, 0.8, 4).unwrap();
        let mut store = CurveStore::new(4);
        let snap = store.snapshot(&reg).unwrap();
        // max observed maps to 0
        assert!(snap.data.y[(0, 1)].abs() < 1e-12);
        assert!(snap.data.y[(0, 0)] < 0.0);
        // undo roundtrip
        assert!((snap.ytf.undo_mean(snap.data.y[(0, 0)]) - 0.2).abs() < 1e-12);
    }
}
