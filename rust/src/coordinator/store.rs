//! Curve store: turns the trial registry into model-space snapshots.
//!
//! The GP engines consume transformed, immutable [`Snapshot`]s; the store
//! owns the epoch grid and re-fits the paper's §B transforms on every
//! snapshot (they depend on the observed data). Snapshots carry a
//! generation counter so the prediction service can batch requests that
//! refer to the same model state, and a [`WarmStart`] lineage so solves
//! against the next generation's near-identical masked system can start
//! from the previous solution instead of zero.

use std::sync::Arc;

use crate::gp::lkgp::Dataset;
use crate::gp::operator::PrecondFactors;
use crate::gp::pathwise::PathLineage;
use crate::gp::transforms::{TTransform, XTransform, YTransform};
use crate::linalg::Matrix;

use super::trial::{Registry, TrialId};

/// Cross-generation warm-start lineage: the previous generation's fitted
/// hyper-parameters and (when a prediction ran) its converged training
/// solve, keyed by the trial rows it was computed for. Produced by the
/// scheduler (theta, after refits) and by prediction-service shards
/// (alpha, after solves); consumed wherever the next generation's
/// near-identical masked-Kronecker system is solved again.
#[derive(Clone, Debug)]
pub struct WarmStart {
    /// Generation this lineage was computed at.
    pub generation: u64,
    /// Packed theta the solve ran under (also the refit warm start).
    pub theta: Vec<f64>,
    /// Trial ids of the alpha rows, in row order.
    pub row_ids: Vec<TrialId>,
    /// Grid length the alpha was computed on.
    pub m: usize,
    /// Flattened `(row_ids.len(), m)` training solve; may be empty when
    /// the lineage carries only theta.
    pub alpha: Vec<f64>,
    /// Stacked query matrix of the cached prediction solve, when one ran.
    /// Scheduler rounds re-query a slowly-changing active set, so the
    /// cross-covariance solves are reusable warm starts too.
    pub xq: Option<Matrix>,
    /// Flattened `(xq.rows(), row_ids.len() * m)` cross-covariance solves
    /// matching `xq`; empty when no prediction is cached.
    pub cross: Vec<f64>,
    /// Factored CG preconditioner from the cached solve. Reused while
    /// hyper-parameters drift slowly (and, for the observed-Gram strategy,
    /// while the mask is unchanged) — staleness is checked by the solver
    /// via `PrecondFactors::compatible`, so carrying old factors is always
    /// safe. None when preconditioning is off.
    pub precond: Option<Arc<PrecondFactors>>,
    /// Pathwise-sampling lineage (prior-path factors + query cross
    /// blocks) from the cached solve's session. Staleness is checked by
    /// the sampler via `PathBase::compatible`/`PathQuery::matches`, so
    /// carrying old state is always safe; with a fresh alpha it makes
    /// `CurveSamples` solve-free (docs/sampling.md). None until a sampling
    /// query ran.
    pub path: Option<PathLineage>,
}

impl WarmStart {
    /// Embed the cached alpha into a problem whose training rows are
    /// `row_ids` (length n) on the same grid length `m`: rows shared with
    /// the cached generation copy their previous solution, new rows start
    /// at zero. Returns None when the grid changed, the cache carries no
    /// alpha, or nothing overlaps.
    pub fn embed_alpha(&self, row_ids: &[TrialId], m: usize) -> Option<Vec<f64>> {
        if m != self.m || self.alpha.is_empty() || self.alpha.len() != self.row_ids.len() * self.m
        {
            return None;
        }
        let pos: std::collections::HashMap<TrialId, usize> =
            row_ids.iter().enumerate().map(|(r, &id)| (id, r)).collect();
        let n = row_ids.len();
        let mut x0 = vec![0.0; n * m];
        let mut hit = false;
        for (old_row, id) in self.row_ids.iter().enumerate() {
            if let Some(&new_row) = pos.get(id) {
                x0[new_row * m..(new_row + 1) * m]
                    .copy_from_slice(&self.alpha[old_row * m..(old_row + 1) * m]);
                hit = true;
            }
        }
        if hit {
            Some(x0)
        } else {
            None
        }
    }

    /// Full warm start for a batched prediction solve `[y, c_1 .. c_q]`:
    /// the embedded alpha plus — when the training rows and the stacked
    /// query matrix are identical to the cached solve — every
    /// cross-covariance column. Returns a `(q + 1) * n * m` buffer, or
    /// None when not even the alpha can be embedded.
    pub fn embed_predict(&self, row_ids: &[TrialId], m: usize, xq: &Matrix) -> Option<Vec<f64>> {
        let alpha0 = self.embed_alpha(row_ids, m)?;
        let n = row_ids.len();
        let nm = n * m;
        let q = xq.rows();
        let mut x0 = vec![0.0; (q + 1) * nm];
        x0[..nm].copy_from_slice(&alpha0);
        if let Some(cached_xq) = &self.xq {
            if self.row_ids == row_ids
                && cached_xq.rows() == q
                && cached_xq.cols() == xq.cols()
                && cached_xq.data() == xq.data()
                && self.cross.len() == q * nm
            {
                x0[nm..].copy_from_slice(&self.cross);
            }
        }
        Some(x0)
    }
}

/// Immutable model-space view of the registry at some generation.
#[derive(Clone)]
pub struct Snapshot {
    /// Monotone generation counter (bumped per snapshot).
    pub generation: u64,
    /// Training data: one row per trial with >= 1 observation.
    pub data: Arc<Dataset>,
    /// Trial ids of the training rows, in row order.
    pub row_ids: Arc<Vec<TrialId>>,
    /// Normalized configs for ALL registered trials (query space).
    pub all_x: Arc<Matrix>,
    /// Trial ids in `all_x` row order.
    pub all_ids: Arc<Vec<TrialId>>,
    /// Output transform for undoing predictions.
    pub ytf: Arc<YTransform>,
    /// Warm-start lineage recorded on an earlier generation, if any.
    pub warm: Option<Arc<WarmStart>>,
}

impl Snapshot {
    /// Observed prefix length per registered config, in `all_ids` order
    /// (0 for configs with no observations yet). This is the per-config
    /// state a trace's generation line pins: replaying the lengths against
    /// the same corpus reconstructs this snapshot's training set exactly
    /// (coordinator::trace, docs/data.md).
    pub fn observed_lengths(&self) -> Vec<usize> {
        let pos: std::collections::HashMap<TrialId, usize> = self
            .row_ids
            .iter()
            .enumerate()
            .map(|(r, &id)| (id, r))
            .collect();
        let m = self.data.m();
        self.all_ids
            .iter()
            .map(|id| {
                pos.get(id).map_or(0, |&r| {
                    (0..m).filter(|&j| self.data.mask[(r, j)] > 0.0).count()
                })
            })
            .collect()
    }
}

/// Builds snapshots from a registry over a fixed epoch grid.
pub struct CurveStore {
    /// Raw epoch grid (1-based epochs).
    pub epochs: Vec<f64>,
    generation: u64,
    /// Most recent warm-start lineage, threaded into future snapshots.
    last_warm: Option<Arc<WarmStart>>,
}

impl CurveStore {
    pub fn new(max_epochs: usize) -> Self {
        CurveStore {
            epochs: (1..=max_epochs).map(|e| e as f64).collect(),
            generation: 0,
            last_warm: None,
        }
    }

    /// Record warm-start lineage (fitted theta and/or alpha); subsequent
    /// snapshots carry it so downstream solvers can warm start.
    pub fn record_warm(&mut self, warm: WarmStart) {
        self.last_warm = Some(Arc::new(warm));
    }

    /// The most recently recorded lineage, if any.
    pub fn last_warm(&self) -> Option<&Arc<WarmStart>> {
        self.last_warm.as_ref()
    }

    pub fn max_epochs(&self) -> usize {
        self.epochs.len()
    }

    /// Build a snapshot: transforms fit on current observations.
    pub fn snapshot(&mut self, reg: &Registry) -> crate::Result<Snapshot> {
        let m = self.epochs.len();
        let observed = reg.observed();
        if observed.is_empty() {
            return Err(crate::LkgpError::Coordinator(
                "snapshot needs at least one observation".into(),
            ));
        }
        let d = reg.get(observed[0]).config.len();
        let n = observed.len();

        let mut xraw = Matrix::zeros(n, d);
        let mut y = Matrix::zeros(n, m);
        let mut mask = Matrix::zeros(n, m);
        for (row, &id) in observed.iter().enumerate() {
            let t = reg.get(id);
            xraw.row_mut(row).copy_from_slice(&t.config);
            for (j, &v) in t.curve.iter().enumerate().take(m) {
                y[(row, j)] = v;
                mask[(row, j)] = 1.0;
            }
        }

        // X transform must cover every registered config (queries too).
        let total = reg.len();
        let mut all_raw = Matrix::zeros(total, d);
        let mut all_ids = Vec::with_capacity(total);
        for (row, t) in reg.iter().enumerate() {
            all_raw.row_mut(row).copy_from_slice(&t.config);
            all_ids.push(t.id);
        }
        let xtf = XTransform::fit(&all_raw);
        let x = xtf.apply(&xraw);
        let all_x = xtf.apply(&all_raw);
        let ttf = TTransform::fit(&self.epochs);
        let t = ttf.apply(&self.epochs);
        let ytf = YTransform::fit(&y, &mask);
        let ys = ytf.apply(&y, &mask);

        self.generation += 1;
        Ok(Snapshot {
            generation: self.generation,
            data: Arc::new(Dataset { x, t, y: ys, mask }),
            row_ids: Arc::new(observed),
            all_x: Arc::new(all_x),
            all_ids: Arc::new(all_ids),
            ytf: Arc::new(ytf),
            warm: self.last_warm.clone(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trial::TrialStatus;

    #[test]
    fn snapshot_shapes_and_transforms() {
        let mut reg = Registry::new();
        let a = reg.add(vec![1.0, 10.0]);
        let b = reg.add(vec![2.0, 20.0]);
        let _c = reg.add(vec![3.0, 30.0]); // never observed -> query only
        reg.set_status(a, TrialStatus::Running);
        reg.observe(a, 0.5, 5).unwrap();
        reg.observe(a, 0.6, 5).unwrap();
        reg.observe(b, 0.4, 5).unwrap();

        let mut store = CurveStore::new(5);
        let snap = store.snapshot(&reg).unwrap();
        assert_eq!(snap.generation, 1);
        assert_eq!(snap.data.n(), 2);
        assert_eq!(snap.data.m(), 5);
        assert_eq!(snap.all_x.rows(), 3);
        assert_eq!(snap.row_ids.len(), 2);
        // mask prefix lengths
        assert_eq!(snap.data.mask[(0, 1)], 1.0);
        assert_eq!(snap.data.mask[(0, 2)], 0.0);
        assert_eq!(snap.data.mask[(1, 0)], 1.0);
        assert_eq!(snap.data.mask[(1, 1)], 0.0);
        // x normalized to unit cube over ALL configs
        assert_eq!(snap.all_x[(0, 0)], 0.0);
        assert_eq!(snap.all_x[(2, 0)], 1.0);
        // generations increment
        let snap2 = store.snapshot(&reg).unwrap();
        assert_eq!(snap2.generation, 2);
    }

    #[test]
    fn warm_lineage_threads_through_snapshots() {
        let mut reg = Registry::new();
        let a = reg.add(vec![0.1]);
        let b = reg.add(vec![0.9]);
        reg.observe(a, 0.5, 4).unwrap();
        reg.observe(b, 0.4, 4).unwrap();
        let mut store = CurveStore::new(4);
        let snap1 = store.snapshot(&reg).unwrap();
        assert!(snap1.warm.is_none());
        store.record_warm(WarmStart {
            generation: snap1.generation,
            theta: vec![0.0, 0.0, 0.0, -4.0],
            row_ids: (*snap1.row_ids).clone(),
            m: 4,
            alpha: vec![1.0; 8],
            xq: None,
            cross: Vec::new(),
            precond: None,
            path: None,
        });
        reg.observe(a, 0.6, 4).unwrap();
        let snap2 = store.snapshot(&reg).unwrap();
        let warm = snap2.warm.as_ref().expect("lineage recorded");
        assert_eq!(warm.generation, snap1.generation);
        // embedding onto the same rows recovers the cached alpha
        let x0 = warm.embed_alpha(&snap2.row_ids, 4).unwrap();
        assert_eq!(x0, vec![1.0; 8]);
        // grid mismatch or empty alpha -> no embedding
        assert!(warm.embed_alpha(&snap2.row_ids, 5).is_none());
        let theta_only = WarmStart {
            generation: 1,
            theta: vec![],
            row_ids: (*snap1.row_ids).clone(),
            m: 4,
            alpha: vec![],
            xq: None,
            cross: Vec::new(),
            precond: None,
            path: None,
        };
        assert!(theta_only.embed_alpha(&snap2.row_ids, 4).is_none());
    }

    #[test]
    fn embed_predict_reuses_cross_solves_only_on_exact_query_match() {
        let xq = Matrix::from_vec(2, 1, vec![0.25, 0.75]);
        let warm = WarmStart {
            generation: 5,
            theta: vec![],
            row_ids: vec![TrialId(0), TrialId(1)],
            m: 2,
            alpha: vec![1.0, 2.0, 3.0, 4.0],
            xq: Some(xq.clone()),
            cross: vec![5.0; 8],
            precond: None,
            path: None,
        };
        // identical rows + queries: alpha and every cross column embed
        let full = warm
            .embed_predict(&[TrialId(0), TrialId(1)], 2, &xq)
            .unwrap();
        assert_eq!(&full[..4], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(&full[4..], &[5.0; 8]);
        // different queries: alpha embeds, cross columns stay cold
        let other = Matrix::from_vec(2, 1, vec![0.3, 0.75]);
        let partial = warm
            .embed_predict(&[TrialId(0), TrialId(1)], 2, &other)
            .unwrap();
        assert_eq!(&partial[..4], &[1.0, 2.0, 3.0, 4.0]);
        assert!(partial[4..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn embed_alpha_maps_rows_by_trial_id() {
        let warm = WarmStart {
            generation: 3,
            theta: vec![],
            row_ids: vec![TrialId(0), TrialId(2)],
            m: 2,
            alpha: vec![1.0, 2.0, 3.0, 4.0],
            xq: None,
            cross: Vec::new(),
            precond: None,
            path: None,
        };
        // new problem has an extra row inserted between the cached ones
        let x0 = warm
            .embed_alpha(&[TrialId(0), TrialId(1), TrialId(2)], 2)
            .unwrap();
        assert_eq!(x0, vec![1.0, 2.0, 0.0, 0.0, 3.0, 4.0]);
        // disjoint ids -> nothing to embed
        assert!(warm.embed_alpha(&[TrialId(7)], 2).is_none());
    }

    #[test]
    fn snapshot_requires_observations() {
        let mut reg = Registry::new();
        reg.add(vec![0.5]);
        let mut store = CurveStore::new(4);
        assert!(store.snapshot(&reg).is_err());
    }

    #[test]
    fn y_standardization_applied() {
        let mut reg = Registry::new();
        let a = reg.add(vec![0.0]);
        reg.observe(a, 0.2, 4).unwrap();
        reg.observe(a, 0.8, 4).unwrap();
        let mut store = CurveStore::new(4);
        let snap = store.snapshot(&reg).unwrap();
        // max observed maps to 0
        assert!(snap.data.y[(0, 1)].abs() < 1e-12);
        assert!(snap.data.y[(0, 0)] < 0.0);
        // undo roundtrip
        assert!((snap.ytf.undo_mean(snap.data.y[(0, 0)]) - 0.2).abs() < 1e-12);
    }
}
