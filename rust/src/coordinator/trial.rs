//! Trial registry: the coordinator's source of truth about every
//! hyper-parameter configuration and its observed learning curve.

/// Identifier of a trial within a run.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TrialId(pub usize);

/// Lifecycle of a trial under freeze-thaw scheduling.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrialStatus {
    /// Created, never trained.
    Pending,
    /// Currently allocated compute (training one epoch per round).
    Running,
    /// Frozen: may be thawed (resumed) later.
    Paused,
    /// Early-stopped: will never resume.
    Stopped,
    /// Reached the final epoch.
    Completed,
}

/// One hyper-parameter configuration and its observation history.
#[derive(Clone, Debug)]
pub struct Trial {
    pub id: TrialId,
    /// Raw (untransformed) configuration vector.
    pub config: Vec<f64>,
    pub status: TrialStatus,
    /// Observed validation-accuracy prefix (one entry per trained epoch).
    pub curve: Vec<f64>,
}

impl Trial {
    pub fn epochs_trained(&self) -> usize {
        self.curve.len()
    }

    pub fn last_value(&self) -> Option<f64> {
        self.curve.last().copied()
    }
}

/// In-memory trial store. Single-writer (the scheduler); snapshots are
/// cloned out for the prediction service, so no interior locking is
/// needed here.
#[derive(Clone, Debug, Default)]
pub struct Registry {
    trials: Vec<Trial>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a new trial; returns its id.
    pub fn add(&mut self, config: Vec<f64>) -> TrialId {
        let id = TrialId(self.trials.len());
        self.trials.push(Trial {
            id,
            config,
            status: TrialStatus::Pending,
            curve: Vec::new(),
        });
        id
    }

    pub fn len(&self) -> usize {
        self.trials.len()
    }

    pub fn is_empty(&self) -> bool {
        self.trials.is_empty()
    }

    pub fn get(&self, id: TrialId) -> &Trial {
        &self.trials[id.0]
    }

    pub fn iter(&self) -> impl Iterator<Item = &Trial> {
        self.trials.iter()
    }

    /// Append an epoch observation; completes the trial at `max_epochs`.
    pub fn observe(&mut self, id: TrialId, value: f64, max_epochs: usize) -> crate::Result<()> {
        let t = self
            .trials
            .get_mut(id.0)
            .ok_or_else(|| crate::LkgpError::Coordinator(format!("unknown trial {id:?}")))?;
        if matches!(t.status, TrialStatus::Stopped | TrialStatus::Completed) {
            return Err(crate::LkgpError::Coordinator(format!(
                "observation for finished trial {id:?}"
            )));
        }
        t.curve.push(value);
        if t.curve.len() >= max_epochs {
            t.status = TrialStatus::Completed;
        }
        Ok(())
    }

    pub fn set_status(&mut self, id: TrialId, status: TrialStatus) {
        // Completed/Stopped are terminal.
        let t = &mut self.trials[id.0];
        if !matches!(t.status, TrialStatus::Completed | TrialStatus::Stopped) {
            t.status = status;
        }
    }

    pub fn by_status(&self, status: TrialStatus) -> Vec<TrialId> {
        self.trials
            .iter()
            .filter(|t| t.status == status)
            .map(|t| t.id)
            .collect()
    }

    /// Trials with at least one observation (the GP's training rows).
    pub fn observed(&self) -> Vec<TrialId> {
        self.trials
            .iter()
            .filter(|t| !t.curve.is_empty())
            .map(|t| t.id)
            .collect()
    }

    /// Total epochs spent across all trials (the compute-cost metric).
    pub fn total_epochs(&self) -> usize {
        self.trials.iter().map(|t| t.curve.len()).sum()
    }

    /// Best observed value anywhere (running best for regret tracking).
    pub fn best_observed(&self) -> Option<(TrialId, f64)> {
        self.trials
            .iter()
            .filter_map(|t| {
                t.curve
                    .iter()
                    .cloned()
                    .fold(None::<f64>, |acc, v| Some(acc.map_or(v, |a| a.max(v))))
                    .map(|v| (t.id, v))
            })
            .max_by(|a, b| a.1.total_cmp(&b.1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle() {
        let mut reg = Registry::new();
        let id = reg.add(vec![0.1, 0.2]);
        assert_eq!(reg.get(id).status, TrialStatus::Pending);
        reg.set_status(id, TrialStatus::Running);
        reg.observe(id, 0.5, 3).unwrap();
        reg.observe(id, 0.6, 3).unwrap();
        assert_eq!(reg.get(id).epochs_trained(), 2);
        assert_eq!(reg.get(id).last_value(), Some(0.6));
        reg.observe(id, 0.7, 3).unwrap();
        assert_eq!(reg.get(id).status, TrialStatus::Completed);
        // terminal status survives set_status
        reg.set_status(id, TrialStatus::Running);
        assert_eq!(reg.get(id).status, TrialStatus::Completed);
        // no observations after completion
        assert!(reg.observe(id, 0.8, 3).is_err());
    }

    #[test]
    fn status_queries() {
        let mut reg = Registry::new();
        let a = reg.add(vec![0.0]);
        let b = reg.add(vec![1.0]);
        let c = reg.add(vec![2.0]);
        reg.set_status(a, TrialStatus::Running);
        reg.set_status(b, TrialStatus::Paused);
        assert_eq!(reg.by_status(TrialStatus::Running), vec![a]);
        assert_eq!(reg.by_status(TrialStatus::Paused), vec![b]);
        assert_eq!(reg.by_status(TrialStatus::Pending), vec![c]);
        reg.observe(a, 0.4, 10).unwrap();
        assert_eq!(reg.observed(), vec![a]);
        assert_eq!(reg.total_epochs(), 1);
    }

    #[test]
    fn best_observed_tracks_max() {
        let mut reg = Registry::new();
        let a = reg.add(vec![0.0]);
        let b = reg.add(vec![1.0]);
        reg.observe(a, 0.3, 10).unwrap();
        reg.observe(b, 0.9, 10).unwrap();
        reg.observe(a, 0.5, 10).unwrap();
        let (best_id, best) = reg.best_observed().unwrap();
        assert_eq!(best_id, b);
        assert_eq!(best, 0.9);
    }

    #[test]
    fn unknown_trial_errors() {
        let mut reg = Registry::new();
        assert!(reg.observe(TrialId(3), 0.1, 10).is_err());
    }
}
