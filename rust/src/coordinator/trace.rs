//! Request-trace record and replay: the data plane's regression harness.
//!
//! A trace is JSON lines (`#` comments ignored). The header pins the
//! corpus (`docs/data.md`); subsequent lines are generation definitions,
//! refits, and typed-query requests. Two versions coexist:
//!
//! * **v1** (hand-written smokes, `traces/smoke.jsonl`): the header pins a
//!   deterministic simulated corpus plus a generation ladder
//!   (`generation_epochs`), and every non-header line is a query request.
//!   Sequential v1 replay asserts the exact stats equalities the CI gate
//!   wall relies on — this path is bit-identical to the pre-corpus
//!   replayer.
//! * **v2** (written by `lkgp pool --record`): the header pins the corpus
//!   by kind + fingerprint (`sim` parameters or a dump-directory path),
//!   generation lines pin each generation's per-config observed lengths
//!   (`Snapshot::observed_lengths`), and refit lines replay the write
//!   path so the generation fence is exercised under load.
//! * **v3** (written by `lkgp pool --record` when the run used
//!   `--observe-storm` / `SchedulerCfg::observe_every`): v2 plus observe
//!   lines (`{"task":..,"generation":..,"observe":1}`) that replay
//!   [`Request::Observe`] — the zero-MLL warm re-solve write path.
//!   Replayed observes use the task's recorded lineage theta, so a
//!   sequential replay is bit-deterministic like v2; every v2 trace is a
//!   valid v3 trace with no observe lines.
//!
//! `--concurrent` replays the whole trace as a storm (every request
//! submitted before any answer is awaited) with **relaxed invariants**:
//! zero errors, per-shard solve counts bounded above by the submitted
//! request count (coalescing and replica lineage reuse only ever reduce
//! work), and a post-storm parity pass — each distinct
//! `(task, generation, query-signature)` is submitted twice back-to-back
//! and the two answers must match bit for bit (the warm-cache exact-
//! lineage path makes the second solve a zero-iteration replay of the
//! first; this is the same determinism contract `BENCH_replicas.json`
//! gates).

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::gp::session::{Answer, Query};
use crate::json::Json;
use crate::lcbench::corpus::{progressive_snapshots, Corpus, TraceCorpus};
use crate::lcbench::Task;
use crate::linalg::Matrix;
use crate::util::Args;

use super::service::{PoolCfg, PredictClient, Request, ServicePool, ShardHandle};
use super::store::{CurveStore, Snapshot};
use super::trial::Registry;

// ---------------------------------------------------------------------------
// Trace queries

/// One typed query parsed from a trace line. The trace stores config ROW
/// INDICES rather than coordinates — all generations share a task's
/// config set, so indices are stable and the file stays robust to
/// transform changes; [`TraceQuery::materialize`] substitutes the
/// snapshot's normalized rows right before submission.
enum TraceQuery {
    MeanAtFinal { rows: Vec<usize> },
    Variance { rows: Vec<usize> },
    Quantiles { rows: Vec<usize>, ps: Vec<f64> },
    MeanAtSteps { rows: Vec<usize>, steps: Vec<usize> },
    /// Seeded joint posterior draws. Samples are a deterministic function
    /// of `(theta, data, xq, seed)` (docs/sampling.md), so replaying the
    /// recorded seed reproduces the recorded run's draws bit for bit —
    /// the concurrent parity pass asserts exactly that.
    CurveSamples { rows: Vec<usize>, n: usize, seed: u64 },
}

impl TraceQuery {
    fn materialize(&self, snap: &Snapshot) -> Query {
        let xq = |rows: &[usize]| {
            let d = snap.all_x.cols();
            let mut m = Matrix::zeros(rows.len(), d);
            for (r, &i) in rows.iter().enumerate() {
                let src: Vec<f64> = snap.all_x.row(i).to_vec();
                m.row_mut(r).copy_from_slice(&src);
            }
            m
        };
        match self {
            TraceQuery::MeanAtFinal { rows } => Query::MeanAtFinal { xq: xq(rows) },
            TraceQuery::Variance { rows } => Query::Variance { xq: xq(rows) },
            TraceQuery::Quantiles { rows, ps } => {
                Query::Quantiles { xq: xq(rows), ps: ps.clone() }
            }
            TraceQuery::MeanAtSteps { rows, steps } => {
                Query::MeanAtSteps { xq: xq(rows), steps: steps.clone() }
            }
            TraceQuery::CurveSamples { rows, n, seed } => {
                Query::CurveSamples { xq: xq(rows), n: *n, seed: *seed }
            }
        }
    }

    fn to_json(&self) -> Json {
        match self {
            TraceQuery::MeanAtFinal { rows } => Json::obj(vec![
                ("kind", Json::Str("mean_at_final".into())),
                ("rows", Json::arr_usize(rows)),
            ]),
            TraceQuery::Variance { rows } => Json::obj(vec![
                ("kind", Json::Str("variance".into())),
                ("rows", Json::arr_usize(rows)),
            ]),
            TraceQuery::Quantiles { rows, ps } => Json::obj(vec![
                ("kind", Json::Str("quantiles".into())),
                ("rows", Json::arr_usize(rows)),
                ("ps", Json::arr_f64(ps)),
            ]),
            TraceQuery::MeanAtSteps { rows, steps } => Json::obj(vec![
                ("kind", Json::Str("mean_at_steps".into())),
                ("rows", Json::arr_usize(rows)),
                ("steps", Json::arr_usize(steps)),
            ]),
            TraceQuery::CurveSamples { rows, n, seed } => Json::obj(vec![
                ("kind", Json::Str("curve_samples".into())),
                ("rows", Json::arr_usize(rows)),
                ("n", Json::Num(*n as f64)),
                ("seed", Json::Num(*seed as f64)),
            ]),
        }
    }

    /// Map a live typed query back to trace form by locating each query
    /// row in the snapshot's normalized config matrix (bitwise). `None`
    /// when the query is not trace-representable (`Mll`, ad-hoc
    /// coordinates that match no registered config, or a `CurveSamples`
    /// seed at or above 2^53 that would not round-trip through JSON's
    /// f64 numbers).
    fn from_query(q: &Query, all_x: &Matrix) -> Option<TraceQuery> {
        let map_rows = |xq: &Matrix| -> Option<Vec<usize>> {
            let mut rows = Vec::with_capacity(xq.rows());
            'outer: for r in 0..xq.rows() {
                let target = xq.row(r);
                for i in 0..all_x.rows() {
                    if all_x.cols() == xq.cols()
                        && all_x
                            .row(i)
                            .iter()
                            .zip(target)
                            .all(|(a, b)| a.to_bits() == b.to_bits())
                    {
                        rows.push(i);
                        continue 'outer;
                    }
                }
                return None;
            }
            Some(rows)
        };
        match q {
            Query::MeanAtFinal { xq } => {
                map_rows(xq).map(|rows| TraceQuery::MeanAtFinal { rows })
            }
            Query::Variance { xq } => map_rows(xq).map(|rows| TraceQuery::Variance { rows }),
            Query::Quantiles { xq, ps } => {
                map_rows(xq).map(|rows| TraceQuery::Quantiles { rows, ps: ps.clone() })
            }
            Query::MeanAtSteps { xq, steps } => {
                map_rows(xq).map(|rows| TraceQuery::MeanAtSteps { rows, steps: steps.clone() })
            }
            Query::CurveSamples { xq, n, seed } => {
                if *seed >= 1u64 << 53 {
                    return None; // would not survive the JSON f64 round-trip
                }
                map_rows(xq).map(|rows| TraceQuery::CurveSamples { rows, n: *n, seed: *seed })
            }
            _ => None,
        }
    }
}

/// Parse one trace query object into a [`TraceQuery`], validating indices
/// against the task's config count and grid length.
fn parse_trace_query(
    v: &Json,
    configs: usize,
    max_epochs: usize,
) -> std::result::Result<TraceQuery, String> {
    let kind = v.get("kind").and_then(Json::as_str).ok_or("query needs kind")?;
    let rows: Vec<usize> = v
        .get("rows")
        .and_then(Json::as_arr)
        .ok_or("query needs rows")?
        .iter()
        .filter_map(Json::as_usize)
        .collect();
    if rows.is_empty() {
        return Err("query needs at least one row".into());
    }
    if rows.iter().any(|&r| r >= configs) {
        return Err(format!("row index out of range (task has {configs} configs)"));
    }
    match kind {
        "mean_at_final" => Ok(TraceQuery::MeanAtFinal { rows }),
        "variance" => Ok(TraceQuery::Variance { rows }),
        "quantiles" => {
            let ps: Vec<f64> = v
                .get("ps")
                .and_then(Json::as_arr)
                .ok_or("quantiles needs ps")?
                .iter()
                .filter_map(Json::as_f64)
                .collect();
            if ps.is_empty() || ps.iter().any(|&p| !(p > 0.0 && p < 1.0)) {
                return Err("quantiles ps must lie in (0, 1)".into());
            }
            Ok(TraceQuery::Quantiles { rows, ps })
        }
        "mean_at_steps" => {
            let steps: Vec<usize> = v
                .get("steps")
                .and_then(Json::as_arr)
                .ok_or("mean_at_steps needs steps")?
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            if steps.is_empty() || steps.iter().any(|&s| s >= max_epochs) {
                return Err(format!("steps must lie in 0..{max_epochs}"));
            }
            Ok(TraceQuery::MeanAtSteps { rows, steps })
        }
        "curve_samples" => {
            let n = v.get("n").and_then(Json::as_usize).unwrap_or(0);
            if n == 0 {
                return Err("curve_samples needs n >= 1".into());
            }
            let seed = v.get("seed").and_then(Json::as_f64).unwrap_or(0.0);
            // lint: allow(float_eq) — fract()!=0.0 is the exact
            // non-integer test guarding the u64 seed cast, mirroring the
            // corpus-pin check in TraceRecorder::new.
            if seed < 0.0 || seed.fract() != 0.0 || seed >= 9_007_199_254_740_992.0 {
                return Err("curve_samples seed must be an integer in [0, 2^53)".into());
            }
            Ok(TraceQuery::CurveSamples { rows, n, seed: seed as u64 })
        }
        other => Err(format!("unknown query kind '{other}'")),
    }
}

// ---------------------------------------------------------------------------
// Parsed traces

/// One replayable event, in file order.
enum TraceEvent {
    /// v2: pins generation `generation` of `task` (per-config observed
    /// lengths; replay reconstructs the snapshot from the corpus).
    Gen {
        line: usize,
        task: usize,
        generation: u64,
        lengths: Vec<usize>,
    },
    /// v2: a refit request (the write path; bumps the generation fence).
    Refit {
        line: usize,
        task: usize,
        generation: u64,
        seed: u64,
    },
    /// v3: an observe request — the O(warm-solve) write path. Replays
    /// `Request::Observe` with an empty theta, so the pool resolves the
    /// task's lineage theta exactly like the recorded run's policy did.
    Observe {
        line: usize,
        task: usize,
        generation: u64,
    },
    /// A typed-query request.
    Request {
        line: usize,
        task: usize,
        generation: u64,
        queries: Vec<TraceQuery>,
    },
}

struct ParsedTrace {
    version: usize,
    corpus: TraceCorpus,
    /// v1 only: the generation ladder the header pins.
    gen_epochs: Vec<usize>,
    /// v1 only: grid length of the simulated snapshots.
    max_epochs: usize,
    events: Vec<TraceEvent>,
    /// Highest generation any event references, per warm-cache sizing.
    max_generation: u64,
    tasks: usize,
    /// Per-shard engine_solves of the RECORDING run, when the trace
    /// carries a stats trailer — reported alongside the replay's own
    /// counts so solve regressions are visible in the output (the hard
    /// bound a replay enforces is its own submitted-request count; the
    /// recording coalesced under different timing, so its counts are a
    /// reference, not an invariant).
    recorded_solves: Option<Vec<usize>>,
}

fn parse_trace(path: &str) -> crate::Result<ParsedTrace> {
    let bad = |line: usize, msg: &str| {
        crate::LkgpError::Coordinator(format!("trace {path}:{line}: {msg}"))
    };
    let text = std::fs::read_to_string(path)?;
    let mut parsed: Vec<(usize, Json)> = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        let raw = raw.trim();
        if raw.is_empty() || raw.starts_with('#') {
            continue;
        }
        let v = Json::parse(raw).map_err(|e| bad(i + 1, &format!("bad json: {e}")))?;
        parsed.push((i + 1, v));
    }
    let Some((hline, header)) = parsed.first() else {
        return Err(crate::LkgpError::Coordinator(format!("trace {path} is empty")));
    };
    let hline = *hline;
    if header.get("trace").and_then(Json::as_str) != Some("lkgp.requests") {
        return Err(bad(hline, "header must set \"trace\": \"lkgp.requests\""));
    }
    let version = header
        .get("version")
        .and_then(Json::as_usize)
        .unwrap_or(1);
    let get_n = |key: &str| header.get(key).and_then(Json::as_usize);
    let seed = header.get("seed").and_then(Json::as_f64).unwrap_or(0.0) as u64;

    // --- corpus pin -------------------------------------------------------
    let (corpus, gen_epochs, max_epochs) = match version {
        1 => {
            let tasks = get_n("tasks").ok_or_else(|| bad(hline, "header needs tasks"))?.max(1);
            let configs = get_n("configs")
                .ok_or_else(|| bad(hline, "header needs configs"))?
                .max(2);
            let max_epochs =
                get_n("max_epochs").ok_or_else(|| bad(hline, "header needs max_epochs"))?;
            let gen_epochs: Vec<usize> = header
                .get("generation_epochs")
                .and_then(Json::as_arr)
                .ok_or_else(|| bad(hline, "header needs generation_epochs"))?
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            if gen_epochs.is_empty() || gen_epochs.iter().any(|&e| e == 0 || e > max_epochs) {
                return Err(bad(hline, "generation_epochs must be in 1..=max_epochs"));
            }
            (TraceCorpus::sim(tasks, configs, seed), gen_epochs, max_epochs)
        }
        2 | 3 => {
            let kind = header
                .get("corpus")
                .and_then(Json::as_str)
                .ok_or_else(|| bad(hline, "v2+ header needs corpus (\"sim\" or \"dir\")"))?;
            let corpus = match kind {
                "sim" => {
                    let tasks =
                        get_n("tasks").ok_or_else(|| bad(hline, "sim corpus needs tasks"))?;
                    let configs =
                        get_n("configs").ok_or_else(|| bad(hline, "sim corpus needs configs"))?;
                    TraceCorpus::sim(tasks.max(1), configs.max(2), seed)
                }
                "dir" => {
                    let dir = header
                        .get("path")
                        .and_then(Json::as_str)
                        .ok_or_else(|| bad(hline, "dir corpus needs path"))?;
                    let fp = header.get("fingerprint").and_then(Json::as_str);
                    TraceCorpus::dir(dir, fp)?
                }
                other => return Err(bad(hline, &format!("unknown corpus kind '{other}'"))),
            };
            // `TraceCorpus::dir` already verified its fingerprint against
            // the header's; only the sim pin still needs the check here.
            if matches!(corpus, TraceCorpus::Sim(_)) {
                if let Some(want) = header.get("fingerprint").and_then(Json::as_str) {
                    let got = corpus.fingerprint();
                    if got != want {
                        return Err(bad(
                            hline,
                            &format!("corpus fingerprint {got} does not match the trace's {want}"),
                        ));
                    }
                }
            }
            (corpus, Vec::new(), 0)
        }
        other => return Err(bad(hline, &format!("unsupported trace version {other}"))),
    };
    let tasks = corpus.len();

    // --- events -----------------------------------------------------------
    // Task shapes for validation (materialized lazily, errors isolated to
    // the tasks a line actually references).
    let mut shapes: Vec<Option<(usize, usize)>> = vec![None; tasks];
    let mut shape = |t: usize, line: usize| -> crate::Result<(usize, usize)> {
        if t >= tasks {
            return Err(bad(line, "task out of range"));
        }
        if shapes[t].is_none() {
            let task = corpus.task(t).map_err(|e| bad(line, &e.to_string()))?;
            shapes[t] = Some((task.n(), task.m()));
        }
        Ok(shapes[t].unwrap())
    };

    let mut events = Vec::new();
    let mut max_generation = 0u64;
    let mut recorded_solves: Option<Vec<usize>> = None;
    for (line, v) in parsed.iter().skip(1) {
        let line = *line;
        if v.get("trailer").is_some() {
            // stats trailer: keep the recording's solve counts for the
            // replay report
            recorded_solves = v.get("engine_solves").and_then(Json::as_arr).map(|xs| {
                xs.iter().filter_map(Json::as_usize).collect()
            });
            continue;
        }
        let task = v
            .get("task")
            .and_then(Json::as_usize)
            .ok_or_else(|| bad(line, "line needs task"))?;
        let generation = v
            .get("generation")
            .and_then(Json::as_usize)
            .ok_or_else(|| bad(line, "line needs generation"))? as u64;
        if generation == 0 {
            return Err(bad(line, "generation must be >= 1"));
        }
        if version == 1 && generation as usize > gen_epochs.len() {
            return Err(bad(line, "generation out of range"));
        }
        max_generation = max_generation.max(generation);
        let (n, m) = if version == 1 {
            if task >= tasks {
                return Err(bad(line, "task out of range"));
            }
            (
                header.get("configs").and_then(Json::as_usize).unwrap_or(2).max(2),
                max_epochs,
            )
        } else {
            shape(task, line)?
        };
        if let Some(lengths) = v.get("lengths").and_then(Json::as_arr) {
            if version == 1 {
                return Err(bad(line, "generation lines need a version-2 trace"));
            }
            let lengths: Vec<usize> = lengths.iter().filter_map(Json::as_usize).collect();
            if lengths.len() != n {
                return Err(bad(
                    line,
                    &format!("lengths has {} entries, task has {n} configs", lengths.len()),
                ));
            }
            if lengths.iter().any(|&l| l > m) {
                return Err(bad(line, &format!("lengths exceed the task grid ({m})")));
            }
            events.push(TraceEvent::Gen { line, task, generation, lengths });
            continue;
        }
        if v.get("refit").is_some() {
            if version == 1 {
                return Err(bad(line, "refit lines need a version-2 trace"));
            }
            let seed = v.get("seed").and_then(Json::as_f64).unwrap_or(0.0) as u64;
            events.push(TraceEvent::Refit { line, task, generation, seed });
            continue;
        }
        if v.get("observe").is_some() {
            if version < 3 {
                return Err(bad(line, "observe lines need a version-3 trace"));
            }
            events.push(TraceEvent::Observe { line, task, generation });
            continue;
        }
        let raw_queries = v
            .get("queries")
            .and_then(Json::as_arr)
            .ok_or_else(|| bad(line, "request needs queries"))?;
        if raw_queries.is_empty() {
            return Err(bad(line, "request needs at least one query"));
        }
        events.push(TraceEvent::Request {
            line,
            task,
            generation,
            queries: raw_queries
                .iter()
                .map(|q| parse_trace_query(q, n, m).map_err(|msg| bad(line, &msg)))
                .collect::<crate::Result<Vec<TraceQuery>>>()?,
        });
    }
    if !events
        .iter()
        .any(|e| matches!(e, TraceEvent::Request { .. }))
    {
        return Err(crate::LkgpError::Coordinator(format!(
            "trace {path} has a header but no requests"
        )));
    }
    Ok(ParsedTrace {
        version,
        corpus,
        gen_epochs,
        max_epochs,
        events,
        max_generation,
        tasks,
        recorded_solves,
    })
}

// ---------------------------------------------------------------------------
// Snapshot reconstruction

/// Rebuild every snapshot the trace references. v1 regenerates the
/// deterministic generation ladder (bit-identical to the historical
/// replayer); v2 replays each generation line's observed lengths against
/// the pinned corpus, reproducing the recorded run's training sets value
/// for value (the recorded observations came from the same corpus
/// curves).
fn build_snapshots(trace: &ParsedTrace) -> crate::Result<BTreeMap<(usize, u64), Snapshot>> {
    let mut snaps: BTreeMap<(usize, u64), Snapshot> = BTreeMap::new();
    if trace.version == 1 {
        for t in 0..trace.tasks {
            let task = trace.corpus.task(t)?;
            for (g, snap) in progressive_snapshots(&task, &trace.gen_epochs, trace.max_epochs)?
                .into_iter()
                .enumerate()
            {
                snaps.insert((t, g as u64 + 1), snap);
            }
        }
        return Ok(snaps);
    }
    struct TaskReplay {
        task: Arc<Task>,
        reg: Registry,
        store: CurveStore,
        observed: Vec<usize>,
    }
    let mut state: BTreeMap<usize, TaskReplay> = BTreeMap::new();
    for event in &trace.events {
        let TraceEvent::Gen { line, task: t, generation, lengths } = event else {
            continue;
        };
        let bad = |msg: String| crate::LkgpError::Coordinator(format!("trace line {line}: {msg}"));
        if !state.contains_key(t) {
            let task = trace.corpus.task(*t)?;
            let mut reg = Registry::new();
            for i in 0..task.n() {
                reg.add(task.configs.row(i).to_vec());
            }
            let m = task.m();
            state.insert(
                *t,
                TaskReplay {
                    observed: vec![0; task.n()],
                    task,
                    reg,
                    store: CurveStore::new(m),
                },
            );
        }
        let st = state.get_mut(t).expect("state inserted above");
        let m = st.task.m();
        for (i, &target) in lengths.iter().enumerate() {
            if target < st.observed[i] {
                return Err(bad(format!(
                    "config {i} lengths regressed ({} -> {target})",
                    st.observed[i]
                )));
            }
            while st.observed[i] < target.min(m) {
                // exactly the CorpusRunner clamp: epochs past an
                // early-stopped prefix repeat the last recorded value
                let j = st.observed[i]
                    .min(st.task.lengths[i].max(1) - 1)
                    .min(m - 1);
                st.reg
                    .observe(super::trial::TrialId(i), st.task.curves[(i, j)], m)?;
                st.observed[i] += 1;
            }
        }
        let snap = st.store.snapshot(&st.reg)?;
        if snap.generation != *generation {
            return Err(bad(format!(
                "generation lines must be consecutive per task (got {}, expected {generation})",
                snap.generation
            )));
        }
        snaps.insert((*t, *generation), snap);
    }
    // every refit/observe/request must reference a pinned generation
    for event in &trace.events {
        let (line, t, g) = match event {
            TraceEvent::Refit { line, task, generation, .. }
            | TraceEvent::Observe { line, task, generation }
            | TraceEvent::Request { line, task, generation, .. } => (line, task, generation),
            TraceEvent::Gen { .. } => continue,
        };
        if !snaps.contains_key(&(*t, *g)) {
            return Err(crate::LkgpError::Coordinator(format!(
                "trace line {line}: generation {g} of task {t} was never pinned by a \
                 generation line"
            )));
        }
    }
    Ok(snaps)
}

// ---------------------------------------------------------------------------
// Replay

/// Outcome of a trace replay, for callers that gate on it (ci.sh via the
/// CLI, the ingest bench via [`run_replay`]).
pub struct ReplaySummary {
    /// Query requests replayed (storm only; parity-pass submissions are
    /// accounted separately).
    pub requests: usize,
    /// Refit (write-path) requests replayed.
    pub refits: usize,
    /// Observe (warm re-solve write-path) requests replayed (v3 only;
    /// always 0 for v1/v2 traces).
    pub observes: usize,
    /// Request errors (must be zero for a passing replay).
    pub errors: usize,
    /// Distinct `(task, generation, signature)` parity groups checked
    /// (concurrent mode only).
    pub parity_checks: usize,
    /// Invariant violations (empty for a passing replay).
    pub violations: Vec<String>,
    /// Wall-clock of the storm/sequential request loop (excludes parsing,
    /// snapshot building, and the parity pass) — the replay-throughput
    /// number `BENCH_ingest.json` gates.
    pub wall: Duration,
}

/// Replay a trace through a fresh [`ServicePool`]. Sequential mode
/// (`concurrent = false`) asserts the exact v1 equalities (or their v2
/// relaxations); concurrent mode floods the pool first and then runs the
/// parity pass. See the module docs for the invariants.
pub fn run_replay(
    path: &str,
    concurrent: bool,
    workers: Option<usize>,
) -> crate::Result<ReplaySummary> {
    let trace = parse_trace(path)?;
    let snaps = build_snapshots(&trace)?;
    let tasks = trace.tasks;
    if snaps.is_empty() {
        return Err(crate::LkgpError::Coordinator("trace pins no generations".into()));
    }
    // theta per snapshot dimensionality (dir corpora may mix task d's)
    let theta_for = |snap: &Snapshot| crate::gp::Theta::default_packed(snap.data.d());

    let default_workers = if concurrent {
        // leave headroom for replicas to steal reads behind busy writers
        (tasks * 2).min(crate::util::num_threads()).max(2)
    } else {
        tasks.min(crate::util::num_threads()).max(1)
    };
    let workers = workers.unwrap_or(default_workers).max(1);
    let engines: Vec<Box<dyn crate::runtime::Engine>> = (0..tasks)
        .map(|_| Box::<crate::runtime::RustEngine>::default() as Box<dyn crate::runtime::Engine>)
        .collect();
    // The misses == distinct-generations invariant needs the keyed LRU to
    // retain every replayed generation, so size it from the trace.
    let warm_cache = (trace.max_generation as usize).max(PoolCfg::default().warm_cache);
    let pool = ServicePool::spawn(engines, PoolCfg { workers, warm_cache, ..Default::default() });
    println!(
        "replay: {path} v{} ({}) -> {tasks} shards, {} workers, {} events{}",
        trace.version,
        trace.corpus.fingerprint(),
        workers,
        trace.events.len(),
        if concurrent { ", concurrent" } else { "" },
    );

    let mut errors = 0usize;
    let mut refits = 0usize;
    let mut observes = 0usize;
    let mut per_shard_requests = vec![0u64; tasks];
    let mut per_shard_parity = vec![0u64; tasks];
    let mut shard_gens: Vec<BTreeSet<u64>> = vec![BTreeSet::new(); tasks];
    let snap_of = |t: usize, g: u64| snaps.get(&(t, g)).expect("validated above").clone();

    let t0 = Instant::now();
    if !concurrent {
        for event in &trace.events {
            match event {
                TraceEvent::Gen { .. } => {}
                TraceEvent::Refit { line, task, generation, seed } => {
                    refits += 1;
                    if let Err(e) =
                        pool.handle(*task).refit(snap_of(*task, *generation), vec![], *seed)
                    {
                        errors += 1;
                        eprintln!("replay line {line}: refit: {e}");
                    }
                }
                TraceEvent::Observe { line, task, generation } => {
                    observes += 1;
                    // Empty theta: the pool resolves the task's lineage
                    // theta, matching the recorded run's refit-free path.
                    if let Err(e) =
                        pool.handle(*task).observe(snap_of(*task, *generation), vec![])
                    {
                        errors += 1;
                        eprintln!("replay line {line}: observe: {e}");
                    }
                }
                TraceEvent::Request { line, task, generation, queries } => {
                    let snap = snap_of(*task, *generation);
                    let theta = theta_for(&snap);
                    let qs: Vec<Query> = queries.iter().map(|q| q.materialize(&snap)).collect();
                    let n_queries = qs.len();
                    per_shard_requests[*task] += 1;
                    shard_gens[*task].insert(*generation);
                    match pool.handle(*task).query(snap, theta, qs) {
                        Ok(a) if a.len() == n_queries => {}
                        Ok(_) => {
                            errors += 1;
                            eprintln!("replay line {line}: wrong answer count");
                        }
                        Err(e) => {
                            errors += 1;
                            eprintln!("replay line {line}: {e}");
                        }
                    }
                }
            }
        }
    } else {
        // ---- the storm: submit everything before awaiting anything ----
        enum PendingAnswer {
            Query(usize, std::sync::mpsc::Receiver<crate::Result<Vec<Answer>>>, usize),
            Refit(usize, std::sync::mpsc::Receiver<crate::Result<Vec<f64>>>),
            Observe(
                usize,
                std::sync::mpsc::Receiver<crate::Result<super::service::ObserveReport>>,
            ),
        }
        let mut pending = Vec::new();
        for event in &trace.events {
            match event {
                TraceEvent::Gen { .. } => {}
                TraceEvent::Refit { line, task, generation, seed } => {
                    refits += 1;
                    let (rtx, rrx) = std::sync::mpsc::channel();
                    pool.submit(
                        *task,
                        Request::Refit {
                            snapshot: snap_of(*task, *generation),
                            theta0: vec![],
                            seed: *seed,
                            resp: rtx,
                        },
                    )?;
                    pending.push(PendingAnswer::Refit(*line, rrx));
                }
                TraceEvent::Observe { line, task, generation } => {
                    observes += 1;
                    let (rtx, rrx) = std::sync::mpsc::channel();
                    pool.submit(
                        *task,
                        Request::Observe {
                            snapshot: snap_of(*task, *generation),
                            theta: vec![],
                            resp: rtx,
                        },
                    )?;
                    pending.push(PendingAnswer::Observe(*line, rrx));
                }
                TraceEvent::Request { line, task, generation, queries } => {
                    let snap = snap_of(*task, *generation);
                    let theta = theta_for(&snap);
                    let qs: Vec<Query> = queries.iter().map(|q| q.materialize(&snap)).collect();
                    let n = qs.len();
                    per_shard_requests[*task] += 1;
                    shard_gens[*task].insert(*generation);
                    let (rtx, rrx) = std::sync::mpsc::channel();
                    pool.submit(
                        *task,
                        Request::Query { snapshot: snap, theta, queries: qs, resp: rtx },
                    )?;
                    pending.push(PendingAnswer::Query(*line, rrx, n));
                }
            }
        }
        for p in pending {
            match p {
                PendingAnswer::Refit(line, rrx) => match rrx.recv() {
                    Ok(Ok(_)) => {}
                    Ok(Err(e)) => {
                        errors += 1;
                        eprintln!("replay line {line}: refit: {e}");
                    }
                    Err(_) => {
                        errors += 1;
                        eprintln!("replay line {line}: refit response dropped");
                    }
                },
                PendingAnswer::Observe(line, rrx) => match rrx.recv() {
                    Ok(Ok(_)) => {}
                    Ok(Err(e)) => {
                        errors += 1;
                        eprintln!("replay line {line}: observe: {e}");
                    }
                    Err(_) => {
                        errors += 1;
                        eprintln!("replay line {line}: observe response dropped");
                    }
                },
                PendingAnswer::Query(line, rrx, n) => match rrx.recv() {
                    Ok(Ok(a)) if a.len() == n => {}
                    Ok(Ok(_)) => {
                        errors += 1;
                        eprintln!("replay line {line}: wrong answer count");
                    }
                    Ok(Err(e)) => {
                        errors += 1;
                        eprintln!("replay line {line}: {e}");
                    }
                    Err(_) => {
                        errors += 1;
                        eprintln!("replay line {line}: response dropped");
                    }
                },
            }
        }
    }
    let wall = t0.elapsed();

    // ---- parity pass (concurrent mode) -----------------------------------
    let mut parity_checks = 0usize;
    let mut violations = Vec::new();
    if concurrent {
        let mut groups: BTreeMap<(usize, u64, String), (usize, &Vec<TraceQuery>)> =
            BTreeMap::new();
        for event in &trace.events {
            if let TraceEvent::Request { line, task, generation, queries } = event {
                let sig = Json::Arr(queries.iter().map(TraceQuery::to_json).collect()).compact();
                groups.entry((*task, *generation, sig)).or_insert((*line, queries));
            }
        }
        for ((task, generation, _sig), (line, queries)) in &groups {
            let snap = snap_of(*task, *generation);
            let theta = theta_for(&snap);
            let qs: Vec<Query> = queries.iter().map(|q| q.materialize(&snap)).collect();
            parity_checks += 1;
            per_shard_parity[*task] += 2;
            let a = pool.handle(*task).query(snap.clone(), theta.clone(), qs.clone());
            let b = pool.handle(*task).query(snap, theta, qs);
            match (a, b) {
                (Ok(a), Ok(b)) => {
                    let same =
                        a.len() == b.len() && a.iter().zip(&b).all(|(x, y)| x.bits_eq(y));
                    if !same {
                        violations.push(format!(
                            "line {line}: back-to-back replays of task {task} gen {generation} \
                             disagree bitwise"
                        ));
                    }
                }
                _ => {
                    errors += 1;
                    eprintln!("replay line {line}: parity query failed");
                }
            }
        }
    }

    // ---- invariants -------------------------------------------------------
    for t in 0..tasks {
        let stats = pool.stats(t);
        let hits = stats.warm_cache_hits.load(Ordering::Relaxed);
        let misses = stats.warm_cache_misses.load(Ordering::Relaxed);
        let solves = stats.engine_solves.load(Ordering::Relaxed);
        let want = per_shard_requests[t];
        let want_misses = shard_gens[t].len() as u64;
        let bound = want + per_shard_parity[t];
        let recorded = trace
            .recorded_solves
            .as_ref()
            .and_then(|rs| rs.get(t))
            .map(|s| format!(" (recording solved {s})"))
            .unwrap_or_default();
        println!(
            "shard {t}: requests={want} warm_cache={hits}h/{misses}m engine_solves={solves}{recorded} \
             prewarmed={} replicas={}h/{}s/{}r",
            stats.prewarmed.load(Ordering::Relaxed),
            stats.replica_hits.load(Ordering::Relaxed),
            stats.replica_solves.load(Ordering::Relaxed),
            stats.stale_replica_retires.load(Ordering::Relaxed),
        );
        if concurrent {
            // Relaxed: coalescing/replica reuse only ever reduce solves.
            if solves > bound {
                violations.push(format!(
                    "shard {t}: engine_solves = {solves} exceeds the submitted bound {bound}"
                ));
            }
            if hits + misses > bound {
                violations.push(format!(
                    "shard {t}: warm_cache lookups {} exceed the submitted bound {bound}",
                    hits + misses
                ));
            }
        } else if trace.version == 1 {
            // Exact v1 equalities (the historical gate wall).
            if hits + misses != want {
                violations.push(format!(
                    "shard {t}: warm_cache_hits + warm_cache_misses = {} != requests {want}",
                    hits + misses
                ));
            }
            if misses != want_misses {
                violations.push(format!(
                    "shard {t}: warm_cache_misses = {misses} != distinct generations {want_misses}"
                ));
            }
            if solves != want {
                violations.push(format!(
                    "shard {t}: engine_solves = {solves} != requests {want}"
                ));
            }
        } else {
            // Sequential v2: refits pre-warm fresh generations, so a
            // later query can exact-hit a generation that never missed —
            // equalities relax to bounds.
            if hits + misses != want {
                violations.push(format!(
                    "shard {t}: warm_cache_hits + warm_cache_misses = {} != requests {want}",
                    hits + misses
                ));
            }
            if misses > want_misses {
                violations.push(format!(
                    "shard {t}: warm_cache_misses = {misses} > distinct generations {want_misses}"
                ));
            }
            if solves > want {
                violations.push(format!(
                    "shard {t}: engine_solves = {solves} > requests {want}"
                ));
            }
        }
    }
    let requests: usize = per_shard_requests.iter().map(|&r| r as usize).sum();
    println!(
        "TRACE_REPLAY file={path} version={} requests={requests} refits={refits} \
         observes={observes} errors={errors} parity_checks={parity_checks} violations={} \
         wall_ms={:.1}",
        trace.version,
        violations.len(),
        wall.as_secs_f64() * 1e3,
    );
    Ok(ReplaySummary {
        requests,
        refits,
        observes,
        errors,
        parity_checks,
        violations,
        wall,
    })
}

/// CLI `lkgp pool --replay <file> [--concurrent] [--workers N]`: replay a
/// trace and exit non-zero on any request error or invariant violation.
/// Prints `REPLAY_OK` on success (ci.sh greps for it).
pub fn replay_trace(args: &Args, path: &str) -> crate::Result<()> {
    let concurrent = args.has("concurrent");
    let workers = args.get("workers").and_then(|w| w.parse::<usize>().ok());
    let summary = run_replay(path, concurrent, workers)?;
    if summary.errors > 0 || !summary.violations.is_empty() {
        for v in &summary.violations {
            eprintln!("REPLAY_VIOLATION {v}");
        }
        return Err(crate::LkgpError::Coordinator(format!(
            "trace replay failed: {} request errors, {} invariant violations",
            summary.errors,
            summary.violations.len()
        )));
    }
    println!("REPLAY_OK");
    Ok(())
}

// ---------------------------------------------------------------------------
// Recording

/// Captures live pool traffic as a version-2 trace. Shared behind an
/// `Arc<Mutex<_>>` by every [`RecordingHandle`]; lines append in arrival
/// order (per-task order is the issuing scheduler's own program order,
/// which is all replay relies on — generations are per task).
pub struct TraceRecorder {
    path: String,
    header: Json,
    lines: Vec<String>,
    seen_gens: BTreeSet<(usize, u64)>,
    /// Requests that could not be expressed in trace form (Mll, query
    /// rows matching no registered config, or a sampling seed at or above
    /// 2^53) — forwarded to the pool but not recorded.
    skipped: usize,
    requests: Vec<u64>,
    refits: Vec<u64>,
    observes: Vec<u64>,
}

impl TraceRecorder {
    /// New recorder writing to `path` on [`TraceRecorder::finish`]; the
    /// header pins `corpus` by kind and fingerprint. Fails up front when a
    /// numeric pin value (e.g. a `--seed` above 2^53) cannot round-trip
    /// through JSON's f64 numbers — recording it would produce a trace
    /// whose corpus can never be reconstructed, and the replay-side
    /// fingerprint mismatch would be far more confusing than this error.
    pub fn new(corpus: &dyn Corpus, path: &str) -> crate::Result<Self> {
        let mut map: BTreeMap<String, Json> = BTreeMap::new();
        map.insert("trace".into(), Json::Str("lkgp.requests".into()));
        map.insert("version".into(), Json::Num(2.0));
        map.insert("tasks".into(), Json::Num(corpus.len() as f64));
        map.insert("fingerprint".into(), Json::Str(corpus.fingerprint()));
        for (k, v) in corpus.trace_pin() {
            if let Json::Num(x) = &v {
                // lint: allow(float_eq) — fract()!=0.0 is the exact
                // non-integer test guarding the u64 replay-pin cast; a
                // tolerance would let lossy pins through silently.
                if x.fract() != 0.0 || x.abs() >= 9_007_199_254_740_992.0 {
                    return Err(crate::LkgpError::Coordinator(format!(
                        "corpus pin '{k}' = {x} does not round-trip through JSON numbers; \
                         pick a value below 2^53"
                    )));
                }
            }
            map.insert(k, v);
        }
        let tasks = corpus.len();
        Ok(TraceRecorder {
            path: path.to_string(),
            header: Json::Obj(map),
            lines: Vec::new(),
            seen_gens: BTreeSet::new(),
            skipped: 0,
            requests: vec![0; tasks],
            refits: vec![0; tasks],
            observes: vec![0; tasks],
        })
    }

    fn record_gen(&mut self, task: usize, snap: &Snapshot) {
        if !self.seen_gens.insert((task, snap.generation)) {
            return;
        }
        self.lines.push(
            Json::obj(vec![
                ("task", Json::Num(task as f64)),
                ("generation", Json::Num(snap.generation as f64)),
                ("lengths", Json::arr_usize(&snap.observed_lengths())),
            ])
            .compact(),
        );
    }

    fn record_refit(&mut self, task: usize, snap: &Snapshot, seed: u64) {
        self.record_gen(task, snap);
        if let Some(r) = self.refits.get_mut(task) {
            *r += 1;
        }
        self.lines.push(
            Json::obj(vec![
                ("task", Json::Num(task as f64)),
                ("generation", Json::Num(snap.generation as f64)),
                ("refit", Json::Num(1.0)),
                ("seed", Json::Num(seed as f64)),
            ])
            .compact(),
        );
    }

    fn record_observe(&mut self, task: usize, snap: &Snapshot) {
        self.record_gen(task, snap);
        if let Some(o) = self.observes.get_mut(task) {
            *o += 1;
        }
        self.lines.push(
            Json::obj(vec![
                ("task", Json::Num(task as f64)),
                ("generation", Json::Num(snap.generation as f64)),
                ("observe", Json::Num(1.0)),
            ])
            .compact(),
        );
    }

    fn record_query(&mut self, task: usize, snap: &Snapshot, queries: &[Query]) {
        let mapped: Option<Vec<TraceQuery>> = queries
            .iter()
            .map(|q| TraceQuery::from_query(q, &snap.all_x))
            .collect();
        let Some(mapped) = mapped else {
            self.skipped += 1;
            return;
        };
        self.record_gen(task, snap);
        if let Some(r) = self.requests.get_mut(task) {
            *r += 1;
        }
        self.lines.push(
            Json::obj(vec![
                ("task", Json::Num(task as f64)),
                ("generation", Json::Num(snap.generation as f64)),
                (
                    "queries",
                    Json::Arr(mapped.iter().map(TraceQuery::to_json).collect()),
                ),
            ])
            .compact(),
        );
    }

    /// Write the trace (header, lines, stats trailer). The trailer keeps
    /// the recording run's per-shard request/refit/solve counts: the
    /// replay report prints the recorded solves next to its own for
    /// regression eyeballing (the enforced solve bound is the replay's
    /// submitted-request count — the recording coalesced under different
    /// timing, so its counts are a reference, not an invariant).
    pub fn finish(&mut self, pool: &ServicePool) -> crate::Result<()> {
        let solves: Vec<usize> = (0..pool.shards())
            .map(|t| pool.stats(t).engine_solves.load(Ordering::Relaxed) as usize)
            .collect();
        let requests: Vec<usize> = self.requests.iter().map(|&r| r as usize).collect();
        let refits: Vec<usize> = self.refits.iter().map(|&r| r as usize).collect();
        let observes: Vec<usize> = self.observes.iter().map(|&o| o as usize).collect();
        let n_observes: usize = observes.iter().sum();
        // A run with no observes writes a plain v2 trace (older replayers
        // keep working); observe lines force the v3 header.
        let version = if n_observes > 0 { 3 } else { 2 };
        if let Json::Obj(map) = &mut self.header {
            map.insert("version".into(), Json::Num(version as f64));
        }
        let mut fields = vec![
            ("trailer", Json::Num(1.0)),
            ("requests", Json::arr_usize(&requests)),
            ("refits", Json::arr_usize(&refits)),
            ("engine_solves", Json::arr_usize(&solves)),
        ];
        if n_observes > 0 {
            fields.push(("observes", Json::arr_usize(&observes)));
        }
        let trailer = Json::obj(fields);
        let mut out = String::new();
        out.push_str(&format!(
            "# lkgp request trace v{version} (recorded by `lkgp pool --record`; replay with\n"
        ));
        out.push_str("# `lkgp pool --replay FILE [--concurrent]`, see docs/data.md).\n");
        out.push_str(&self.header.compact());
        out.push('\n');
        for line in &self.lines {
            out.push_str(line);
            out.push('\n');
        }
        out.push_str(&trailer.compact());
        out.push('\n');
        std::fs::write(&self.path, out)?;
        println!(
            "recorded {} requests + {} refits + {n_observes} observes \
             ({} unrepresentable skipped) -> {}",
            requests.iter().sum::<usize>(),
            refits.iter().sum::<usize>(),
            self.skipped,
            self.path,
        );
        Ok(())
    }
}

/// A [`PredictClient`] that records every replayable request before
/// forwarding it to its pool shard. Wraps a [`ShardHandle`], so a
/// `Scheduler` drives it unchanged (`lkgp pool --record`).
pub struct RecordingHandle {
    inner: ShardHandle,
    task: usize,
    rec: Arc<Mutex<TraceRecorder>>,
}

impl RecordingHandle {
    pub fn new(inner: ShardHandle, task: usize, rec: Arc<Mutex<TraceRecorder>>) -> Self {
        RecordingHandle { inner, task, rec }
    }
}

impl PredictClient for RecordingHandle {
    fn refit(&self, snapshot: Snapshot, theta0: Vec<f64>, seed: u64) -> crate::Result<Vec<f64>> {
        self.rec.lock().unwrap().record_refit(self.task, &snapshot, seed);
        self.inner.refit(snapshot, theta0, seed)
    }

    fn observe(
        &self,
        snapshot: Snapshot,
        theta: Vec<f64>,
    ) -> crate::Result<super::service::ObserveReport> {
        self.rec.lock().unwrap().record_observe(self.task, &snapshot);
        self.inner.observe(snapshot, theta)
    }

    fn query(
        &self,
        snapshot: Snapshot,
        theta: Vec<f64>,
        queries: Vec<Query>,
    ) -> crate::Result<Vec<Answer>> {
        self.rec
            .lock()
            .unwrap()
            .record_query(self.task, &snapshot, &queries);
        self.inner.query(snapshot, theta, queries)
    }

    fn predict_final(
        &self,
        snapshot: Snapshot,
        theta: Vec<f64>,
        xq: Matrix,
    ) -> crate::Result<Vec<(f64, f64)>> {
        let query = vec![Query::MeanAtFinal { xq: xq.clone() }];
        self.rec
            .lock()
            .unwrap()
            .record_query(self.task, &snapshot, &query);
        self.inner.predict_final(snapshot, theta, xq)
    }

    fn sample_curves(
        &self,
        snapshot: Snapshot,
        theta: Vec<f64>,
        xq: Matrix,
        samples: usize,
        seed: u64,
    ) -> crate::Result<Vec<Matrix>> {
        // Seeded draws are deterministic, so sampling IS
        // trace-representable: record the seeded query and let the
        // replay's parity pass assert bitwise sample parity. (A seed at
        // or above 2^53 is the one unrepresentable case — `from_query`
        // skips it rather than record a lossy pin.)
        let query = vec![Query::CurveSamples { xq: xq.clone(), n: samples, seed }];
        self.rec
            .lock()
            .unwrap()
            .record_query(self.task, &snapshot, &query);
        self.inner.sample_curves(snapshot, theta, xq, samples, seed)
    }

    fn batch_factor(&self) -> f64 {
        self.inner.batch_factor()
    }
}
