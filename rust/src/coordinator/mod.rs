//! L3 coordinator: the freeze-thaw AutoML service built on LKGP.
//!
//! Architecture (threads + channels; tokio is not in the offline set):
//!
//! ```text
//!   Scheduler (round loop)          PredictionService (worker thread)
//!   ├─ Registry: trial lifecycle    ├─ owns Box<dyn Engine> (xla|rust)
//!   ├─ CurveStore: snapshots     ──►├─ mpsc queue, dynamic batching:
//!   ├─ EpochRunner: the workload    │  coalesces same-generation typed
//!   └─ Policy: stop/pause/promote ◄─┘  Query batches into one shared
//!                                      solve (Engine::answer_batch)
//! ```
//!
//! See `examples/automl_loop.rs` for the end-to-end driver and
//! [`serve_simulated`] for the CLI entry.

pub mod policy;
pub mod scheduler;
pub mod service;
pub mod store;
pub mod trace;
pub mod trial;

pub use crate::gp::session::{Answer, Query};
pub use policy::{Decision, Policy, TrialForecast};
pub use scheduler::{CorpusRunner, EpochRunner, RunReport, Scheduler, SchedulerCfg};
pub use service::{
    EngineFactory, ObserveReport, PoolCfg, PredictClient, PredictionService, Request, ServicePool,
    ServiceStats, ShardHandle,
};
pub use store::{CurveStore, Snapshot, WarmStart};
pub use trace::{replay_trace, RecordingHandle, ReplaySummary, TraceRecorder};
pub use trial::{Registry, Trial, TrialId, TrialStatus};

use crate::util::Args;

/// CLI `lkgp serve`: run the coordinator on a simulated LCBench task and
/// print a run report (see examples/automl_loop.rs for the annotated
/// version of this flow).
pub fn serve_simulated(args: &Args) -> crate::Result<()> {
    let seed = args.get_u64("seed", 0);
    let n_configs = args.get_usize("configs", 24);
    let budget = args.get_usize("budget", 400);
    let concurrent = args.get_usize("concurrent", 4);
    let prefer_xla = args.get("engine").unwrap_or("xla") == "xla";

    let mut rng = crate::rng::Pcg64::new(seed);
    let task = crate::lcbench::Task::generate(crate::lcbench::Preset::FashionMnist, n_configs, &mut rng);
    let oracle_best = (0..task.n())
        .map(|i| task.curves[(i, task.m() - 1)])
        .fold(f64::NEG_INFINITY, f64::max);

    let cfg = SchedulerCfg {
        max_concurrent: concurrent,
        refit_every: 5,
        epoch_budget: budget,
        policy: Policy::PredictedFinal { delta: 0.0, threshold: 0.95 },
        seed,
    };
    let mut sched = Scheduler::new(task.m(), cfg);
    let configs: Vec<Vec<f64>> = (0..task.n()).map(|i| task.configs.row(i).to_vec()).collect();
    sched.add_candidates(&configs);

    struct SimRunner {
        task: crate::lcbench::Task,
    }
    impl EpochRunner for SimRunner {
        fn run_epoch(&mut self, trial: TrialId, _config: &[f64], epoch: usize) -> f64 {
            self.task.curves[(trial.0, epoch.min(self.task.m() - 1))]
        }
    }

    let engine = crate::runtime::open_engine(prefer_xla);
    println!("engine: {}", engine.name());
    let service = PredictionService::spawn(engine);
    let mut runner = SimRunner { task };
    let report = sched.run(&mut runner, &service)?;

    println!(
        "rounds={} epochs={}/{} (full grid would be {})",
        report.rounds,
        report.epochs_spent,
        budget,
        n_configs * sched.store.max_epochs()
    );
    println!(
        "best found={:.4} oracle={:.4} regret={:.4}",
        report.best_value,
        oracle_best,
        oracle_best - report.best_value
    );
    println!(
        "stopped={} completed={} batch_factor={:.2} p50={}us p99={}us",
        report.stopped,
        report.completed,
        report.batch_factor,
        service.stats.latency.lock().unwrap_or_else(|p| p.into_inner()).quantile_micros(0.5),
        service.stats.latency.lock().unwrap_or_else(|p| p.into_inner()).quantile_micros(0.99),
    );
    Ok(())
}

/// CLI `lkgp pool`: run one freeze-thaw coordinator per corpus task,
/// concurrently, through one multi-task [`ServicePool`] — the serving
/// topology the north-star calls for. The data plane is a
/// [`crate::lcbench::corpus::Corpus`]: the deterministic simulator by
/// default (`--corpus sim`, bit-identical to the historical inline
/// generation) or a directory of LCBench-style JSON dumps
/// (`--corpus data/lcbench_mini`), admitted lazily via
/// [`ServicePool::from_corpus`] with per-task error isolation (a corrupt
/// dump skips its shard, everything else serves). Prints a per-shard
/// report (regret, batching factor, warm hits, replica stats, pre-warm
/// count, preconditioner rank, latency, queue depth).
///
/// `--record FILE` captures the live typed-query + refit traffic as a
/// replayable trace whose header pins the corpus fingerprint;
/// `--replay FILE [--concurrent]` replays a recorded trace instead of
/// running schedulers (see [`trace`] and docs/data.md).
///
/// `--threads N` pins the compute-team width (equivalent to
/// `LKGP_THREADS`; the f64 path is bit-identical for every value) and
/// `--precision f64|f32` selects the solver's numeric mode — `f32` stores
/// Kronecker factors in single precision and recovers f64-grade residuals
/// through iterative refinement (see docs/parallelism.md).
///
/// Robustness controls (docs/robustness.md): `--deadline-ms N` attaches a
/// pool-wide deadline to every submitted request (expired work is shed
/// with a typed `Timeout` instead of occupying a worker), and
/// `--chaos SPEC` runs the whole pool under seeded fault injection
/// (`panic=0.05,diverge=0.2,slow=0.1,io=0.02,nan=0.01,seed=7` — see
/// [`crate::runtime::chaos::FaultPlan::parse`]). Under chaos, per-shard
/// scheduler failures are reported and tolerated rather than aborting the
/// run, and the final report includes injected-fault totals.
///
/// `--sample-storm` switches the pool into the posterior-sampling
/// demonstrator instead of the scheduler fleet: a seeded Hyperband/ASHA
/// Thompson-sampling loop that selects arms from pathwise `CurveSamples`
/// draws served by the pool, printing the
/// `ServiceStats::{pathwise_hits, sample_mvms}` counters and a bitwise
/// `STORM_CHECKSUM` determinism receipt (see [`sample_storm`] and
/// docs/sampling.md).
///
/// Scale-out controls (docs/serving.md): `--buckets N|auto` folds the
/// corpus onto N hash-routed shard buckets (`auto` = the worker count;
/// absent or `0` keeps the historical 1:1 task-to-shard layout), so a
/// 10k-task corpus no longer materializes 10k engines — per-task
/// generations, warm lineages, and fences stay task-keyed inside a
/// bucket. `--observe-storm` drives steady epoch-arrival traffic: every
/// scheduler round that is not a refit boundary extends its curves
/// through a `Request::Observe` warm re-solve (zero MLL evals; the
/// converged alpha seeds the PCG solve), and the pool-side refit policy —
/// tuned by `--refit-every K` (epochs between forced refits) and
/// `--refit-drift X` (relative data-fit drift threshold) — decides when
/// theta is actually stale and a real refit runs. The report's
/// `observes` / `observe_mvm_rows` / `refits_triggered` counters make
/// the savings visible.
pub fn serve_pool(args: &Args) -> crate::Result<()> {
    use crate::lcbench::corpus::{Corpus, JsonDirCorpus, SimCorpus};
    use std::sync::{Arc, Mutex};

    if let Some(path) = args.get("replay") {
        return trace::replay_trace(args, path);
    }
    if args.has("sample-storm") {
        return sample_storm(args);
    }
    let seed = args.get_u64("seed", 0);
    let n_configs = args.get_usize("configs", 16);
    let budget = args.get_usize("budget", 200);
    let warm = args.get("warm").unwrap_or("on") != "off";
    let replicas = args.get_usize("replicas", PoolCfg::default().max_replicas);
    let precond_arg = args.get("precond").unwrap_or("auto");
    let precond = crate::gp::PrecondCfg::parse(precond_arg).ok_or_else(|| {
        crate::LkgpError::Coordinator(format!(
            "bad --precond '{precond_arg}' (expected off, auto, or rank=R with R >= 1)"
        ))
    })?;
    let precision_arg = args.get("precision").unwrap_or("f64");
    let precision = crate::gp::Precision::parse(precision_arg).ok_or_else(|| {
        crate::LkgpError::Coordinator(format!(
            "bad --precision '{precision_arg}' (expected f64 or f32)"
        ))
    })?;
    // Pin the compute-team width before any engine touches it: the logical
    // thread count keys the deterministic work split (docs/parallelism.md),
    // so it must be resolved once, up front, for the whole process.
    if let Some(t) = args.get("threads") {
        let n: usize = t.parse().map_err(|_| {
            crate::LkgpError::Coordinator(format!("bad --threads '{t}' (expected a count >= 1)"))
        })?;
        if !crate::util::set_num_threads(n) && crate::util::num_threads() != n.max(1) {
            eprintln!(
                "warning: --threads {n} ignored; thread count already resolved to {}",
                crate::util::num_threads()
            );
        }
    }

    let deadline = match args.get("deadline-ms") {
        Some(v) => {
            let ms: u64 = v.parse().map_err(|_| {
                crate::LkgpError::Coordinator(format!(
                    "bad --deadline-ms '{v}' (expected milliseconds >= 1)"
                ))
            })?;
            Some(std::time::Duration::from_millis(ms.max(1)))
        }
        None => None,
    };
    let chaos_plan = match args.get("chaos") {
        Some(spec) => Some(
            crate::runtime::chaos::FaultPlan::parse(spec).ok_or_else(|| {
                crate::LkgpError::Coordinator(format!(
                    "bad --chaos '{spec}' (expected a key=value list over \
                     panic, diverge, slow, slow_ms, io, nan, seed with rates in [0, 1])"
                ))
            })?,
        ),
        None => None,
    };
    let chaos_stats = chaos_plan
        .map(|_| Arc::new(crate::runtime::chaos::ChaosStats::default()));

    let corpus_arg = args.get("corpus").unwrap_or("sim");
    let corpus: Arc<dyn Corpus> = if corpus_arg == "sim" {
        Arc::new(SimCorpus::new(
            args.get_usize("tasks", 3).max(1),
            n_configs,
            seed,
        ))
    } else {
        Arc::new(JsonDirCorpus::open(corpus_arg)?)
    };
    let corpus: Arc<dyn Corpus> = match (chaos_plan, &chaos_stats) {
        (Some(plan), Some(stats)) if plan.corpus_faults() => Arc::new(
            crate::runtime::chaos::ChaosCorpus::new(corpus, plan, stats.clone()),
        ),
        _ => corpus,
    };
    let tasks = corpus.len();
    let workers = args
        .get_usize("workers", crate::util::num_threads().min(tasks.max(1)))
        .max(1);
    // `--buckets auto` folds onto one bucket per worker; `0`/absent keeps
    // the historical 1:1 task<->shard layout (see PoolCfg::buckets).
    let buckets = match args.get("buckets") {
        None => 0,
        Some("auto") => workers,
        Some(v) => v.parse().map_err(|_| {
            crate::LkgpError::Coordinator(format!(
                "bad --buckets '{v}' (expected a count >= 0, or auto)"
            ))
        })?,
    };
    let observe_storm = args.has("observe-storm");
    let refit_every_epochs =
        args.get_usize("refit-every", PoolCfg::default().refit_every_epochs);
    let refit_drift = match args.get("refit-drift") {
        None => PoolCfg::default().refit_drift,
        Some(v) => {
            let x: f64 = v.parse().map_err(|_| {
                crate::LkgpError::Coordinator(format!(
                    "bad --refit-drift '{v}' (expected a relative threshold >= 0)"
                ))
            })?;
            if !(x >= 0.0) {
                return Err(crate::LkgpError::Coordinator(format!(
                    "bad --refit-drift '{v}' (expected a relative threshold >= 0)"
                )));
            }
            x
        }
    };

    let factory: EngineFactory = {
        let chaos_stats = chaos_stats.clone();
        Box::new(move |shard| {
            let mut eng = crate::runtime::RustEngine::default();
            eng.cfg.precond = precond;
            eng.cfg.precision = precision;
            match (chaos_plan, &chaos_stats) {
                // per-shard salt: each shard draws its own deterministic
                // fault stream instead of sharing one global sequence
                (Some(plan), Some(stats)) if plan.engine_faults() => {
                    Box::new(crate::runtime::chaos::ChaosEngine::new(
                        eng,
                        plan,
                        shard as u64,
                        stats.clone(),
                    )) as Box<dyn crate::runtime::Engine>
                }
                _ => Box::new(eng) as Box<dyn crate::runtime::Engine>,
            }
        })
    };
    let pool = ServicePool::from_corpus(
        &*corpus,
        factory,
        PoolCfg {
            workers,
            warm_start: warm,
            max_replicas: replicas,
            deadline,
            buckets,
            refit_every_epochs,
            refit_drift,
            ..Default::default()
        },
    );
    println!(
        "pool: {tasks} tasks on {} buckets from corpus {} ({}), {workers} workers, \
         warm_start={warm}, max_replicas={replicas}, precond={precond:?}, precision={}, \
         threads={}, observe_storm={observe_storm}, refit_every={refit_every_epochs}, \
         refit_drift={refit_drift}",
        pool.buckets(),
        corpus.name(),
        corpus.fingerprint(),
        precision.tag(),
        crate::util::num_threads(),
    );

    let recorder: Option<Arc<Mutex<TraceRecorder>>> = match args.get("record") {
        Some(path) => Some(Arc::new(Mutex::new(TraceRecorder::new(&*corpus, path)?))),
        None => None,
    };

    // Under fault injection (or tight deadlines) a shard's scheduler may
    // legitimately abort with a typed error; that is the harness working,
    // not a run failure, so those shards are reported instead of aborting
    // the whole pool.
    let tolerate_failures = chaos_plan.is_some() || deadline.is_some();
    let mut results: Vec<(usize, String, RunReport, f64)> = Vec::new();
    let mut skipped: Vec<(usize, String)> = Vec::new();
    let mut failed: Vec<(usize, String)> = Vec::new();
    std::thread::scope(|scope| -> crate::Result<()> {
        let mut joins = Vec::new();
        for t in 0..tasks {
            // per-task error isolation: a corrupt dump skips its shard
            let task = match corpus.task(t) {
                Ok(task) => task,
                Err(e) => {
                    skipped.push((t, e.to_string()));
                    continue;
                }
            };
            let handle = pool.handle(t);
            let recorder = recorder.clone();
            joins.push((t, scope.spawn(
                move || -> crate::Result<(usize, String, RunReport, f64)> {
                    let oracle = (0..task.n())
                        .map(|i| task.curves[(i, task.lengths[i].max(1) - 1)])
                        .fold(f64::NEG_INFINITY, f64::max);
                    let cfg = SchedulerCfg {
                        epoch_budget: budget,
                        seed: seed + t as u64,
                        // Under --observe-storm every non-refit round extends
                        // the curves via a warm Observe re-solve; the pool's
                        // refit policy escalates to a real refit on drift.
                        observe_every: if observe_storm { 1 } else { 0 },
                        ..Default::default()
                    };
                    let mut sched = Scheduler::new(task.m(), cfg);
                    let configs: Vec<Vec<f64>> =
                        (0..task.n()).map(|i| task.configs.row(i).to_vec()).collect();
                    sched.add_candidates(&configs);
                    let name = task.name.clone();
                    let mut runner = CorpusRunner { task };
                    let report = match recorder {
                        Some(rec) => {
                            let client = RecordingHandle::new(handle, t, rec);
                            sched.run(&mut runner, &client)?
                        }
                        None => sched.run(&mut runner, &handle)?,
                    };
                    Ok((t, name, report, oracle))
                },
            )));
        }
        for (t, j) in joins {
            match j.join() {
                Err(_) => {
                    return Err(crate::LkgpError::Coordinator(
                        "shard scheduler panicked".into(),
                    ))
                }
                Ok(Ok(out)) => results.push(out),
                Ok(Err(e)) if tolerate_failures => failed.push((t, e.to_string())),
                Ok(Err(e)) => return Err(e),
            }
        }
        Ok(())
    })?;

    for (t, e) in &skipped {
        eprintln!("shard {t}: skipped (corrupt task isolated, others served): {e}");
    }
    for (t, e) in &failed {
        eprintln!("shard {t}: scheduler aborted under fault injection: {e}");
    }
    results.sort_by_key(|r| r.0);
    for (t, name, report, oracle) in &results {
        let stats = pool.stats(*t);
        println!(
            "shard {t} ({name}): best={:.4} regret={:.4} epochs={} rounds={} \
             requests={} split={} batch_factor={:.2} warm_hits={} warm_cache={}h/{}m \
             solves={} replicas={}h/{}s/{}r prewarmed={} pathwise={}h/{}mvm \
             precond_rank={} cg_iters={} mvm_rows={} peak_queue={} p50={}us p99={}us",
            report.best_value,
            oracle - report.best_value,
            report.epochs_spent,
            report.rounds,
            stats.requests.load(std::sync::atomic::Ordering::Relaxed),
            stats.split_batches.load(std::sync::atomic::Ordering::Relaxed),
            report.batch_factor,
            stats.warm_hits.load(std::sync::atomic::Ordering::Relaxed),
            stats.warm_cache_hits.load(std::sync::atomic::Ordering::Relaxed),
            stats.warm_cache_misses.load(std::sync::atomic::Ordering::Relaxed),
            stats.engine_solves.load(std::sync::atomic::Ordering::Relaxed),
            stats.replica_hits.load(std::sync::atomic::Ordering::Relaxed),
            stats.replica_solves.load(std::sync::atomic::Ordering::Relaxed),
            stats.stale_replica_retires.load(std::sync::atomic::Ordering::Relaxed),
            stats.prewarmed.load(std::sync::atomic::Ordering::Relaxed),
            stats.pathwise_hits.load(std::sync::atomic::Ordering::Relaxed),
            stats.sample_mvms.load(std::sync::atomic::Ordering::Relaxed),
            stats.precond_rank.load(std::sync::atomic::Ordering::Relaxed),
            stats.cg_iters.load(std::sync::atomic::Ordering::Relaxed),
            stats.cg_mvm_rows.load(std::sync::atomic::Ordering::Relaxed),
            stats.peak_queue_depth.load(std::sync::atomic::Ordering::Relaxed),
            stats.latency.lock().unwrap_or_else(|p| p.into_inner()).quantile_micros(0.5),
            stats.latency.lock().unwrap_or_else(|p| p.into_inner()).quantile_micros(0.99),
        );
        println!(
            "shard {t} ingest: observes={} observe_mvm_rows={} refits_triggered={} \
             (bucket {})",
            stats.observes.load(std::sync::atomic::Ordering::Relaxed),
            stats.observe_solve_mvm_rows.load(std::sync::atomic::Ordering::Relaxed),
            stats.refits_triggered.load(std::sync::atomic::Ordering::Relaxed),
            pool.bucket_of(*t),
        );
        println!(
            "shard {t} health: escalations={} dense_fallbacks={} panics_recovered={} \
             timeouts={} shed={} solver_failures={} quarantine={}trips/{}rejects",
            stats.escalations.load(std::sync::atomic::Ordering::Relaxed),
            stats.dense_fallbacks.load(std::sync::atomic::Ordering::Relaxed),
            stats.panics_recovered.load(std::sync::atomic::Ordering::Relaxed),
            stats.timeouts.load(std::sync::atomic::Ordering::Relaxed),
            stats.shed.load(std::sync::atomic::Ordering::Relaxed),
            stats.solver_failures.load(std::sync::atomic::Ordering::Relaxed),
            stats.quarantine_trips.load(std::sync::atomic::Ordering::Relaxed),
            stats.quarantine_rejects.load(std::sync::atomic::Ordering::Relaxed),
        );
    }
    println!(
        "admission: {tasks} tasks admitted on {} buckets, {} materialized, {} evicted, \
         {} skipped",
        pool.buckets(),
        pool.materialized(),
        pool.evicted(),
        skipped.len(),
    );
    if let Some(stats) = &chaos_stats {
        use std::sync::atomic::Ordering::Relaxed;
        println!(
            "chaos: {} faults injected (panics={} diverges={} slows={} io={} nan={}), \
             {} shard scheduler(s) aborted",
            stats.total(),
            stats.panics.load(Relaxed),
            stats.diverges.load(Relaxed),
            stats.slows.load(Relaxed),
            stats.io_errors.load(Relaxed),
            stats.nans.load(Relaxed),
            failed.len(),
        );
    }
    if let Some(rec) = recorder {
        rec.lock().unwrap().finish(&pool)?;
    }
    Ok(())
}

/// CLI `lkgp pool --sample-storm`: a seeded Hyperband/ASHA-style
/// Thompson-sampling storm over one simulated task, served end to end by
/// the [`ServicePool`]. Each rung refits on the observed curve prefixes,
/// fires `--bursts` independently seeded `CurveSamples` requests (each
/// drawing `--draws` joint posterior curves), votes one Thompson argmax
/// per draw, and keeps the top `1/eta` arms; survivors train `eta` times
/// deeper before the next rung. After a generation's first draw builds
/// the pathwise base, every further burst is served solve-free from the
/// cached lineage — the printed `pathwise_hits`/`sample_mvms` counters
/// are the receipt (docs/sampling.md, docs/serving.md).
///
/// The default `--workers 1` driver is strictly serial, so for a fixed
/// `--seed` the printed `STORM_CHECKSUM` (FNV-1a over the bits of every
/// sampled value) is identical across processes and `--threads` settings;
/// ci.sh's `samples` gate compares it cross-process. Raising `--workers`
/// keeps every burst's seed-determinism but lets pre-warming race the
/// first burst of a rung, which may shift which lineage that burst lands
/// on (and therefore the counters).
///
/// The library-level version of this loop, with replica stealing enabled,
/// is `examples/automl_loop.rs`.
fn sample_storm(args: &Args) -> crate::Result<()> {
    use std::sync::atomic::Ordering::Relaxed;

    let seed = args.get_u64("seed", 0);
    let n_configs = args.get_usize("configs", 16).max(2);
    let draws = args.get_usize("draws", 16).max(1);
    let bursts = args.get_usize("bursts", 4).max(1);
    let eta = args.get_usize("eta", 2).max(2);
    let replicas = args.get_usize("replicas", PoolCfg::default().max_replicas);
    let workers = args.get_usize("workers", 1).max(1);
    let warm = args.get("warm").unwrap_or("on") != "off";
    if let Some(t) = args.get("threads") {
        let n: usize = t.parse().map_err(|_| {
            crate::LkgpError::Coordinator(format!("bad --threads '{t}' (expected a count >= 1)"))
        })?;
        let _ = crate::util::set_num_threads(n);
    }

    let mut rng = crate::rng::Pcg64::new(seed);
    let task =
        crate::lcbench::Task::generate(crate::lcbench::Preset::FashionMnist, n_configs, &mut rng);
    let m = task.m();
    let oracle = (0..task.n())
        .map(|i| task.curves[(i, m - 1)])
        .fold(f64::NEG_INFINITY, f64::max);

    let engine =
        Box::new(crate::runtime::RustEngine::default()) as Box<dyn crate::runtime::Engine>;
    let pool = ServicePool::spawn(
        vec![engine],
        PoolCfg { workers, warm_start: warm, max_replicas: replicas, ..Default::default() },
    );
    let handle = pool.handle(0);
    println!(
        "storm: {} arms, eta={eta}, {bursts} bursts x {draws} draws per rung, \
         warm_start={warm}, workers={workers}, max_replicas={replicas}, threads={}",
        task.n(),
        crate::util::num_threads(),
    );

    let mut reg = Registry::new();
    let ids: Vec<TrialId> =
        (0..task.n()).map(|i| reg.add(task.configs.row(i).to_vec())).collect();
    let mut store = CurveStore::new(m);
    let mut observed = vec![0usize; task.n()];
    for (i, &id) in ids.iter().enumerate() {
        // rung 0: every arm gets one epoch
        reg.observe(id, task.curves[(i, 0)], m)?;
        observed[i] = 1;
    }
    let mut epochs_spent = task.n();

    // FNV-1a over the bits of every sampled value: the determinism receipt.
    let fnv = |mut h: u64, bits: u64| -> u64 {
        for shift in [0u32, 8, 16, 24, 32, 40, 48, 56] {
            h = (h ^ ((bits >> shift) & 0xff)).wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    };
    let mut checksum = 0xcbf2_9ce4_8422_2325u64;

    let mut survivors: Vec<usize> = (0..task.n()).collect();
    let mut rung = 0usize;
    while survivors.len() > 1 {
        let snapshot = store.snapshot(&reg)?;
        let theta = handle.refit(snapshot.clone(), Vec::new(), seed.wrapping_add(rung as u64))?;
        let n_train = snapshot.data.n();
        // Query rows for the surviving arms, in normalized config space.
        let pos: std::collections::HashMap<TrialId, usize> = snapshot
            .all_ids
            .iter()
            .enumerate()
            .map(|(r, &id)| (id, r))
            .collect();
        let mut xq = crate::linalg::Matrix::zeros(survivors.len(), snapshot.all_x.cols());
        for (r, &arm) in survivors.iter().enumerate() {
            xq.row_mut(r).copy_from_slice(snapshot.all_x.row(pos[&ids[arm]]));
        }
        // The storm proper: independently seeded CurveSamples bursts. The
        // first burst of a fresh generation may pay the training solve;
        // the rest ride the cached pathwise lineage solve-free.
        let mut wins = vec![0usize; survivors.len()];
        for b in 0..bursts {
            // distinct per-burst seeds, pinned under 2^53 so a `--record`ed
            // storm stays trace-representable (coordinator::trace)
            let burst_seed = seed
                .wrapping_add(((rung * bursts + b) as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15))
                & ((1u64 << 53) - 1);
            let samples = handle.sample_curves(
                snapshot.clone(),
                theta.clone(),
                xq.clone(),
                draws,
                burst_seed,
            )?;
            for smp in &samples {
                // Thompson: one argmax vote per joint draw. Selection runs
                // on the standardized sampled final-epoch values — the
                // YTransform is monotone, so the argmax is unchanged.
                let (mut best, mut best_v) = (0usize, f64::NEG_INFINITY);
                for r in 0..survivors.len() {
                    let v = smp[(n_train + r, m - 1)];
                    checksum = fnv(checksum, v.to_bits());
                    if v > best_v {
                        best_v = v;
                        best = r;
                    }
                }
                wins[best] += 1;
            }
        }
        // ASHA-style successive halving on Thompson win counts (ties break
        // toward the lower row index, keeping selection deterministic).
        let keep = ((survivors.len() + eta - 1) / eta).max(1);
        let mut order: Vec<usize> = (0..survivors.len()).collect();
        order.sort_by(|&a, &b| wins[b].cmp(&wins[a]).then(a.cmp(&b)));
        let mut kept: Vec<usize> = order[..keep].iter().map(|&r| survivors[r]).collect();
        kept.sort_unstable();
        println!(
            "rung {rung}: {} arms -> {} survivors (top wins {}/{})",
            survivors.len(),
            keep,
            wins[order[0]],
            bursts * draws,
        );
        survivors = kept;
        // Promote survivors eta x deeper before the next rung.
        for &arm in &survivors {
            let target = (observed[arm] * eta).min(task.lengths[arm]).min(m);
            while observed[arm] < target {
                reg.observe(ids[arm], task.curves[(arm, observed[arm])], m)?;
                observed[arm] += 1;
                epochs_spent += 1;
            }
        }
        rung += 1;
    }

    let winner = survivors[0];
    let final_v = task.curves[(winner, m - 1)];
    let stats = pool.stats(0);
    println!(
        "winner: arm {winner} final={final_v:.4} oracle={oracle:.4} regret={:.4} \
         epochs={epochs_spent} (full grid would be {})",
        oracle - final_v,
        task.n() * m,
    );
    println!(
        "storm stats: requests={} solves={} pathwise_hits={} sample_mvms={} \
         replicas={}h/{}s prewarmed={} warm_cache={}h/{}m",
        stats.requests.load(Relaxed),
        stats.engine_solves.load(Relaxed),
        stats.pathwise_hits.load(Relaxed),
        stats.sample_mvms.load(Relaxed),
        stats.replica_hits.load(Relaxed),
        stats.replica_solves.load(Relaxed),
        stats.prewarmed.load(Relaxed),
        stats.warm_cache_hits.load(Relaxed),
        stats.warm_cache_misses.load(Relaxed),
    );
    println!("STORM_CHECKSUM=0x{checksum:016x}");
    Ok(())
}
