//! L3 coordinator: the freeze-thaw AutoML service built on LKGP.
//!
//! Architecture (threads + channels; tokio is not in the offline set):
//!
//! ```text
//!   Scheduler (round loop)          PredictionService (worker thread)
//!   ├─ Registry: trial lifecycle    ├─ owns Box<dyn Engine> (xla|rust)
//!   ├─ CurveStore: snapshots     ──►├─ mpsc queue, dynamic batching:
//!   ├─ EpochRunner: the workload    │  coalesces same-generation typed
//!   └─ Policy: stop/pause/promote ◄─┘  Query batches into one shared
//!                                      solve (Engine::answer_batch)
//! ```
//!
//! See `examples/automl_loop.rs` for the end-to-end driver and
//! [`serve_simulated`] for the CLI entry.

pub mod policy;
pub mod scheduler;
pub mod service;
pub mod store;
pub mod trial;

pub use crate::gp::session::{Answer, Query};
pub use policy::{Decision, Policy, TrialForecast};
pub use scheduler::{EpochRunner, RunReport, Scheduler, SchedulerCfg};
pub use service::{
    PoolCfg, PredictClient, PredictionService, Request, ServicePool, ServiceStats, ShardHandle,
};
pub use store::{CurveStore, Snapshot, WarmStart};
pub use trial::{Registry, Trial, TrialId, TrialStatus};

use crate::util::Args;

/// CLI `lkgp serve`: run the coordinator on a simulated LCBench task and
/// print a run report (see examples/automl_loop.rs for the annotated
/// version of this flow).
pub fn serve_simulated(args: &Args) -> crate::Result<()> {
    let seed = args.get_u64("seed", 0);
    let n_configs = args.get_usize("configs", 24);
    let budget = args.get_usize("budget", 400);
    let concurrent = args.get_usize("concurrent", 4);
    let prefer_xla = args.get("engine").unwrap_or("xla") == "xla";

    let mut rng = crate::rng::Pcg64::new(seed);
    let task = crate::lcbench::Task::generate(crate::lcbench::Preset::FashionMnist, n_configs, &mut rng);
    let oracle_best = (0..task.n())
        .map(|i| task.curves[(i, task.m() - 1)])
        .fold(f64::NEG_INFINITY, f64::max);

    let cfg = SchedulerCfg {
        max_concurrent: concurrent,
        refit_every: 5,
        epoch_budget: budget,
        policy: Policy::PredictedFinal { delta: 0.0, threshold: 0.95 },
        seed,
    };
    let mut sched = Scheduler::new(task.m(), cfg);
    let configs: Vec<Vec<f64>> = (0..task.n()).map(|i| task.configs.row(i).to_vec()).collect();
    sched.add_candidates(&configs);

    struct SimRunner {
        task: crate::lcbench::Task,
    }
    impl EpochRunner for SimRunner {
        fn run_epoch(&mut self, trial: TrialId, _config: &[f64], epoch: usize) -> f64 {
            self.task.curves[(trial.0, epoch.min(self.task.m() - 1))]
        }
    }

    let engine = crate::runtime::open_engine(prefer_xla);
    println!("engine: {}", engine.name());
    let service = PredictionService::spawn(engine);
    let mut runner = SimRunner { task };
    let report = sched.run(&mut runner, &service)?;

    println!(
        "rounds={} epochs={}/{} (full grid would be {})",
        report.rounds,
        report.epochs_spent,
        budget,
        n_configs * sched.store.max_epochs()
    );
    println!(
        "best found={:.4} oracle={:.4} regret={:.4}",
        report.best_value,
        oracle_best,
        oracle_best - report.best_value
    );
    println!(
        "stopped={} completed={} batch_factor={:.2} p50={}us p99={}us",
        report.stopped,
        report.completed,
        report.batch_factor,
        service.stats.latency.lock().unwrap().quantile_micros(0.5),
        service.stats.latency.lock().unwrap().quantile_micros(0.99),
    );
    Ok(())
}

/// CLI `lkgp pool`: run several freeze-thaw coordinators concurrently,
/// each on its own simulated LCBench task, through one multi-task
/// [`ServicePool`] — the serving topology the north-star calls for. Prints
/// a per-shard report (regret, batching factor, warm hits, replica stats,
/// latency, queue depth). With `--replay <file>` it instead replays a
/// recorded request trace through the pool (see [`replay_trace`]).
pub fn serve_pool(args: &Args) -> crate::Result<()> {
    if let Some(path) = args.get("replay") {
        return replay_trace(args, path);
    }
    let seed = args.get_u64("seed", 0);
    let tasks = args.get_usize("tasks", 3).max(1);
    let n_configs = args.get_usize("configs", 16);
    let budget = args.get_usize("budget", 200);
    let workers = args
        .get_usize("workers", crate::util::num_threads().min(tasks.max(1)))
        .max(1);
    let warm = args.get("warm").unwrap_or("on") != "off";
    let replicas = args.get_usize("replicas", PoolCfg::default().max_replicas);
    let precond_arg = args.get("precond").unwrap_or("auto");
    let precond = crate::gp::PrecondCfg::parse(precond_arg).ok_or_else(|| {
        crate::LkgpError::Coordinator(format!(
            "bad --precond '{precond_arg}' (expected off, auto, or rank=R with R >= 1)"
        ))
    })?;
    let presets = crate::lcbench::Preset::all();

    let engines: Vec<Box<dyn crate::runtime::Engine>> = (0..tasks)
        .map(|_| {
            let mut eng = crate::runtime::RustEngine::default();
            eng.cfg.precond = precond;
            Box::new(eng) as Box<dyn crate::runtime::Engine>
        })
        .collect();
    let pool = ServicePool::spawn(
        engines,
        PoolCfg {
            workers,
            warm_start: warm,
            max_replicas: replicas,
            ..Default::default()
        },
    );
    println!(
        "pool: {tasks} shards, {workers} workers, warm_start={warm}, \
         max_replicas={replicas}, precond={precond:?}"
    );

    struct SimRunner {
        task: crate::lcbench::Task,
    }
    impl EpochRunner for SimRunner {
        fn run_epoch(&mut self, trial: TrialId, _config: &[f64], epoch: usize) -> f64 {
            self.task.curves[(trial.0, epoch.min(self.task.m() - 1))]
        }
    }

    let mut results: Vec<(usize, &'static str, RunReport, f64)> = Vec::new();
    std::thread::scope(|scope| -> crate::Result<()> {
        let mut joins = Vec::new();
        for t in 0..tasks {
            let handle = pool.handle(t);
            let preset = presets[t % presets.len()];
            joins.push(scope.spawn(move || -> crate::Result<(usize, &'static str, RunReport, f64)> {
                let mut rng = crate::rng::Pcg64::new(seed + t as u64);
                let task = crate::lcbench::Task::generate(preset, n_configs, &mut rng);
                let oracle = (0..task.n())
                    .map(|i| task.curves[(i, task.m() - 1)])
                    .fold(f64::NEG_INFINITY, f64::max);
                let cfg = SchedulerCfg {
                    epoch_budget: budget,
                    seed: seed + t as u64,
                    ..Default::default()
                };
                let mut sched = Scheduler::new(task.m(), cfg);
                let configs: Vec<Vec<f64>> =
                    (0..task.n()).map(|i| task.configs.row(i).to_vec()).collect();
                sched.add_candidates(&configs);
                let mut runner = SimRunner { task };
                let report = sched.run(&mut runner, &handle)?;
                Ok((t, preset.name(), report, oracle))
            }));
        }
        for j in joins {
            let out = j
                .join()
                .map_err(|_| crate::LkgpError::Coordinator("shard scheduler panicked".into()))??;
            results.push(out);
        }
        Ok(())
    })?;

    results.sort_by_key(|r| r.0);
    for (t, name, report, oracle) in &results {
        let stats = pool.stats(*t);
        println!(
            "shard {t} ({name}): best={:.4} regret={:.4} epochs={} rounds={} \
             batch_factor={:.2} warm_hits={} warm_cache={}h/{}m solves={} \
             replicas={}h/{}s/{}r cg_iters={} mvm_rows={} peak_queue={} \
             p50={}us p99={}us",
            report.best_value,
            oracle - report.best_value,
            report.epochs_spent,
            report.rounds,
            report.batch_factor,
            stats.warm_hits.load(std::sync::atomic::Ordering::Relaxed),
            stats.warm_cache_hits.load(std::sync::atomic::Ordering::Relaxed),
            stats.warm_cache_misses.load(std::sync::atomic::Ordering::Relaxed),
            stats.engine_solves.load(std::sync::atomic::Ordering::Relaxed),
            stats.replica_hits.load(std::sync::atomic::Ordering::Relaxed),
            stats.replica_solves.load(std::sync::atomic::Ordering::Relaxed),
            stats.stale_replica_retires.load(std::sync::atomic::Ordering::Relaxed),
            stats.cg_iters.load(std::sync::atomic::Ordering::Relaxed),
            stats.cg_mvm_rows.load(std::sync::atomic::Ordering::Relaxed),
            stats.peak_queue_depth.load(std::sync::atomic::Ordering::Relaxed),
            stats.latency.lock().unwrap().quantile_micros(0.5),
            stats.latency.lock().unwrap().quantile_micros(0.99),
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Trace replay

/// One typed query parsed from a trace line. The trace stores config ROW
/// INDICES rather than coordinates — all generations share a task's
/// config set, so indices are stable and the file stays robust to
/// transform changes; [`TraceQuery::materialize`] substitutes the
/// snapshot's normalized rows right before submission.
enum TraceQuery {
    MeanAtFinal { rows: Vec<usize> },
    Variance { rows: Vec<usize> },
    Quantiles { rows: Vec<usize>, ps: Vec<f64> },
    MeanAtSteps { rows: Vec<usize>, steps: Vec<usize> },
}

impl TraceQuery {
    fn materialize(&self, snap: &Snapshot) -> Query {
        let xq = |rows: &[usize]| {
            let d = snap.all_x.cols();
            let mut m = crate::linalg::Matrix::zeros(rows.len(), d);
            for (r, &i) in rows.iter().enumerate() {
                let src: Vec<f64> = snap.all_x.row(i).to_vec();
                m.row_mut(r).copy_from_slice(&src);
            }
            m
        };
        match self {
            TraceQuery::MeanAtFinal { rows } => Query::MeanAtFinal { xq: xq(rows) },
            TraceQuery::Variance { rows } => Query::Variance { xq: xq(rows) },
            TraceQuery::Quantiles { rows, ps } => {
                Query::Quantiles { xq: xq(rows), ps: ps.clone() }
            }
            TraceQuery::MeanAtSteps { rows, steps } => {
                Query::MeanAtSteps { xq: xq(rows), steps: steps.clone() }
            }
        }
    }
}

/// One replayable request parsed from a trace line.
struct TraceRequest {
    line: usize,
    task: usize,
    generation: u64,
    queries: Vec<TraceQuery>,
}

/// CLI `lkgp pool --replay <file>`: replay a recorded request trace —
/// JSON lines of typed queries across several tasks and generations —
/// through a [`ServicePool`] and assert zero errors plus stats
/// invariants. This is the first concrete step toward the ROADMAP's
/// "replayable request trace" item: the trace pins the *request shapes*
/// (task, generation, query kinds, config rows) while the harness
/// regenerates the deterministic simulated datasets, so the file stays
/// tiny and diffable (see `traces/smoke.jsonl` and docs/ci.md).
///
/// Trace format (one JSON object per line, `#`-prefixed lines ignored):
///
/// ```text
/// {"trace":"lkgp.requests","version":1,"tasks":3,"configs":8,
///  "max_epochs":12,"seed":17,"generation_epochs":[4,7,10]}
/// {"task":0,"generation":2,"queries":[
///    {"kind":"mean_at_final","rows":[0,1]},
///    {"kind":"quantiles","rows":[2],"ps":[0.1,0.9]}]}
/// ```
///
/// `generation_epochs[i]` is the observed-epoch budget of generation
/// `i + 1`; `rows` index the task's config matrix. The replay is
/// sequential (each request blocks for its answer), which makes the
/// stats invariants exact:
///
/// * zero request errors;
/// * per shard, `warm_cache_hits + warm_cache_misses ==` replayed
///   requests (every request is one coalescing group);
/// * per shard, `engine_solves ==` replayed requests (every typed-query
///   batch runs exactly one underlying solve through the session layer);
/// * per shard, `warm_cache_misses ==` distinct generations replayed
///   (each generation cold-misses exactly once, then warm-hits).
pub fn replay_trace(args: &Args, path: &str) -> crate::Result<()> {
    use crate::json::Json;

    let bad = |line: usize, msg: &str| {
        crate::LkgpError::Coordinator(format!("trace {path}:{line}: {msg}"))
    };
    let text = std::fs::read_to_string(path)?;
    let mut parsed: Vec<(usize, Json)> = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        let raw = raw.trim();
        if raw.is_empty() || raw.starts_with('#') {
            continue;
        }
        let v = Json::parse(raw).map_err(|e| bad(i + 1, &format!("bad json: {e}")))?;
        parsed.push((i + 1, v));
    }
    let Some((hline, header)) = parsed.first() else {
        return Err(crate::LkgpError::Coordinator(format!("trace {path} is empty")));
    };
    if header.get("trace").and_then(Json::as_str) != Some("lkgp.requests") {
        return Err(bad(*hline, "header must set \"trace\": \"lkgp.requests\""));
    }
    let get_n = |key: &str| header.get(key).and_then(Json::as_usize);
    let tasks = get_n("tasks").ok_or_else(|| bad(*hline, "header needs tasks"))?.max(1);
    let configs = get_n("configs").ok_or_else(|| bad(*hline, "header needs configs"))?.max(2);
    let max_epochs = get_n("max_epochs").ok_or_else(|| bad(*hline, "header needs max_epochs"))?;
    let seed = header.get("seed").and_then(Json::as_f64).unwrap_or(0.0) as u64;
    let gen_epochs: Vec<usize> = header
        .get("generation_epochs")
        .and_then(Json::as_arr)
        .ok_or_else(|| bad(*hline, "header needs generation_epochs"))?
        .iter()
        .filter_map(Json::as_usize)
        .collect();
    if gen_epochs.is_empty() || gen_epochs.iter().any(|&e| e == 0 || e > max_epochs) {
        return Err(bad(*hline, "generation_epochs must be in 1..=max_epochs"));
    }

    // Parse request lines up front so a malformed trace fails before any
    // solve runs.
    let mut requests: Vec<TraceRequest> = Vec::new();
    for (line, v) in parsed.iter().skip(1) {
        let line = *line;
        let task = v
            .get("task")
            .and_then(Json::as_usize)
            .ok_or_else(|| bad(line, "request needs task"))?;
        if task >= tasks {
            return Err(bad(line, "task out of range"));
        }
        let generation = v
            .get("generation")
            .and_then(Json::as_usize)
            .ok_or_else(|| bad(line, "request needs generation"))? as u64;
        if generation == 0 || generation as usize > gen_epochs.len() {
            return Err(bad(line, "generation out of range"));
        }
        let raw_queries = v
            .get("queries")
            .and_then(Json::as_arr)
            .ok_or_else(|| bad(line, "request needs queries"))?;
        if raw_queries.is_empty() {
            return Err(bad(line, "request needs at least one query"));
        }
        requests.push(TraceRequest {
            line,
            task,
            generation,
            queries: raw_queries
                .iter()
                .map(|q| parse_trace_query(q, configs, max_epochs).map_err(|m| bad(line, &m)))
                .collect::<crate::Result<Vec<TraceQuery>>>()?,
        });
    }
    if requests.is_empty() {
        return Err(crate::LkgpError::Coordinator(format!(
            "trace {path} has a header but no requests"
        )));
    }

    // Deterministic simulated corpus: one LCBench-style task per shard,
    // observed progressively so generation g has `generation_epochs[g-1]`
    // epochs on config 0 (configs stagger by index for realistic masks).
    let presets = crate::lcbench::Preset::all();
    let mut snapshots: Vec<Vec<Snapshot>> = Vec::with_capacity(tasks);
    for t in 0..tasks {
        let mut rng = crate::rng::Pcg64::new(seed + t as u64);
        let task = crate::lcbench::Task::generate(presets[t % presets.len()], configs, &mut rng);
        let mut reg = Registry::new();
        let ids: Vec<TrialId> = (0..task.n())
            .map(|i| reg.add(task.configs.row(i).to_vec()))
            .collect();
        let mut store = CurveStore::new(max_epochs);
        let mut observed = vec![0usize; task.n()];
        let mut snaps = Vec::with_capacity(gen_epochs.len());
        for &budget in &gen_epochs {
            for (i, &id) in ids.iter().enumerate() {
                let upto = budget.saturating_sub(i % 3).max(1).min(max_epochs);
                while observed[i] < upto {
                    let j = observed[i].min(task.m() - 1);
                    reg.observe(id, task.curves[(i, j)], max_epochs)?;
                    observed[i] += 1;
                }
            }
            snaps.push(store.snapshot(&reg)?);
        }
        snapshots.push(snaps);
    }
    let d = snapshots[0][0].data.d();
    let theta = crate::gp::Theta::default_packed(d);

    let workers = args.get_usize("workers", tasks.min(crate::util::num_threads())).max(1);
    let engines: Vec<Box<dyn crate::runtime::Engine>> = (0..tasks)
        .map(|_| Box::<crate::runtime::RustEngine>::default() as Box<dyn crate::runtime::Engine>)
        .collect();
    // The misses == distinct-generations invariant needs the keyed LRU to
    // retain every replayed generation, so size it from the trace.
    let warm_cache = gen_epochs.len().max(PoolCfg::default().warm_cache);
    let pool = ServicePool::spawn(engines, PoolCfg { workers, warm_cache, ..Default::default() });
    println!(
        "replay: {path} -> {tasks} shards, {} generations, {} requests",
        gen_epochs.len(),
        requests.len()
    );

    // Sequential replay: deterministic coalescing (one group per request)
    // makes the stats invariants exact equalities.
    let mut errors = 0usize;
    let mut per_shard = vec![0u64; tasks];
    let mut shard_gens: Vec<std::collections::BTreeSet<u64>> =
        vec![std::collections::BTreeSet::new(); tasks];
    for req in &requests {
        let snap = snapshots[req.task][(req.generation - 1) as usize].clone();
        let queries: Vec<Query> = req.queries.iter().map(|q| q.materialize(&snap)).collect();
        let n_queries = queries.len();
        let answers = pool.handle(req.task).query(snap, theta.clone(), queries);
        per_shard[req.task] += 1;
        shard_gens[req.task].insert(req.generation);
        match answers {
            Ok(a) if a.len() == n_queries => {}
            Ok(_) => {
                errors += 1;
                eprintln!("replay line {}: wrong answer count", req.line);
            }
            Err(e) => {
                errors += 1;
                eprintln!("replay line {}: {e}", req.line);
            }
        }
    }

    let mut violations = Vec::new();
    for t in 0..tasks {
        let stats = pool.stats(t);
        let hits = stats.warm_cache_hits.load(std::sync::atomic::Ordering::Relaxed);
        let misses = stats.warm_cache_misses.load(std::sync::atomic::Ordering::Relaxed);
        let solves = stats.engine_solves.load(std::sync::atomic::Ordering::Relaxed);
        let want = per_shard[t];
        let want_misses = shard_gens[t].len() as u64;
        println!(
            "shard {t}: requests={want} warm_cache={hits}h/{misses}m engine_solves={solves}"
        );
        if hits + misses != want {
            violations.push(format!(
                "shard {t}: warm_cache_hits + warm_cache_misses = {} != requests {want}",
                hits + misses
            ));
        }
        if misses != want_misses {
            violations.push(format!(
                "shard {t}: warm_cache_misses = {misses} != distinct generations {want_misses}"
            ));
        }
        if solves != want {
            violations.push(format!(
                "shard {t}: engine_solves = {solves} != requests {want}"
            ));
        }
    }
    println!(
        "TRACE_REPLAY file={path} requests={} errors={errors} violations={}",
        requests.len(),
        violations.len()
    );
    if errors > 0 || !violations.is_empty() {
        for v in &violations {
            eprintln!("REPLAY_VIOLATION {v}");
        }
        return Err(crate::LkgpError::Coordinator(format!(
            "trace replay failed: {errors} request errors, {} invariant violations",
            violations.len()
        )));
    }
    println!("REPLAY_OK");
    Ok(())
}

/// Parse one trace query object into a [`TraceQuery`].
fn parse_trace_query(
    v: &crate::json::Json,
    configs: usize,
    max_epochs: usize,
) -> std::result::Result<TraceQuery, String> {
    use crate::json::Json;
    let kind = v.get("kind").and_then(Json::as_str).ok_or("query needs kind")?;
    let rows: Vec<usize> = v
        .get("rows")
        .and_then(Json::as_arr)
        .ok_or("query needs rows")?
        .iter()
        .filter_map(Json::as_usize)
        .collect();
    if rows.is_empty() {
        return Err("query needs at least one row".into());
    }
    if rows.iter().any(|&r| r >= configs) {
        return Err(format!("row index out of range (task has {configs} configs)"));
    }
    match kind {
        "mean_at_final" => Ok(TraceQuery::MeanAtFinal { rows }),
        "variance" => Ok(TraceQuery::Variance { rows }),
        "quantiles" => {
            let ps: Vec<f64> = v
                .get("ps")
                .and_then(Json::as_arr)
                .ok_or("quantiles needs ps")?
                .iter()
                .filter_map(Json::as_f64)
                .collect();
            if ps.is_empty() || ps.iter().any(|&p| !(p > 0.0 && p < 1.0)) {
                return Err("quantiles ps must lie in (0, 1)".into());
            }
            Ok(TraceQuery::Quantiles { rows, ps })
        }
        "mean_at_steps" => {
            let steps: Vec<usize> = v
                .get("steps")
                .and_then(Json::as_arr)
                .ok_or("mean_at_steps needs steps")?
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            if steps.is_empty() || steps.iter().any(|&s| s >= max_epochs) {
                return Err(format!("steps must lie in 0..{max_epochs}"));
            }
            Ok(TraceQuery::MeanAtSteps { rows, steps })
        }
        other => Err(format!("unknown query kind '{other}'")),
    }
}
