//! L3 coordinator: the freeze-thaw AutoML service built on LKGP.
//!
//! Architecture (threads + channels; tokio is not in the offline set):
//!
//! ```text
//!   Scheduler (round loop)          PredictionService (worker thread)
//!   ├─ Registry: trial lifecycle    ├─ owns Box<dyn Engine> (xla|rust)
//!   ├─ CurveStore: snapshots     ──►├─ mpsc queue, dynamic batching:
//!   ├─ EpochRunner: the workload    │  coalesces same-generation typed
//!   └─ Policy: stop/pause/promote ◄─┘  Query batches into one shared
//!                                      solve (Engine::answer_batch)
//! ```
//!
//! See `examples/automl_loop.rs` for the end-to-end driver and
//! [`serve_simulated`] for the CLI entry.

pub mod policy;
pub mod scheduler;
pub mod service;
pub mod store;
pub mod trial;

pub use crate::gp::session::{Answer, Query};
pub use policy::{Decision, Policy, TrialForecast};
pub use scheduler::{EpochRunner, RunReport, Scheduler, SchedulerCfg};
pub use service::{
    PoolCfg, PredictClient, PredictionService, Request, ServicePool, ServiceStats, ShardHandle,
};
pub use store::{CurveStore, Snapshot, WarmStart};
pub use trial::{Registry, Trial, TrialId, TrialStatus};

use crate::util::Args;

/// CLI `lkgp serve`: run the coordinator on a simulated LCBench task and
/// print a run report (see examples/automl_loop.rs for the annotated
/// version of this flow).
pub fn serve_simulated(args: &Args) -> crate::Result<()> {
    let seed = args.get_u64("seed", 0);
    let n_configs = args.get_usize("configs", 24);
    let budget = args.get_usize("budget", 400);
    let concurrent = args.get_usize("concurrent", 4);
    let prefer_xla = args.get("engine").unwrap_or("xla") == "xla";

    let mut rng = crate::rng::Pcg64::new(seed);
    let task = crate::lcbench::Task::generate(crate::lcbench::Preset::FashionMnist, n_configs, &mut rng);
    let oracle_best = (0..task.n())
        .map(|i| task.curves[(i, task.m() - 1)])
        .fold(f64::NEG_INFINITY, f64::max);

    let cfg = SchedulerCfg {
        max_concurrent: concurrent,
        refit_every: 5,
        epoch_budget: budget,
        policy: Policy::PredictedFinal { delta: 0.0, threshold: 0.95 },
        seed,
    };
    let mut sched = Scheduler::new(task.m(), cfg);
    let configs: Vec<Vec<f64>> = (0..task.n()).map(|i| task.configs.row(i).to_vec()).collect();
    sched.add_candidates(&configs);

    struct SimRunner {
        task: crate::lcbench::Task,
    }
    impl EpochRunner for SimRunner {
        fn run_epoch(&mut self, trial: TrialId, _config: &[f64], epoch: usize) -> f64 {
            self.task.curves[(trial.0, epoch.min(self.task.m() - 1))]
        }
    }

    let engine = crate::runtime::open_engine(prefer_xla);
    println!("engine: {}", engine.name());
    let service = PredictionService::spawn(engine);
    let mut runner = SimRunner { task };
    let report = sched.run(&mut runner, &service)?;

    println!(
        "rounds={} epochs={}/{} (full grid would be {})",
        report.rounds,
        report.epochs_spent,
        budget,
        n_configs * sched.store.max_epochs()
    );
    println!(
        "best found={:.4} oracle={:.4} regret={:.4}",
        report.best_value,
        oracle_best,
        oracle_best - report.best_value
    );
    println!(
        "stopped={} completed={} batch_factor={:.2} p50={}us p99={}us",
        report.stopped,
        report.completed,
        report.batch_factor,
        service.stats.latency.lock().unwrap().quantile_micros(0.5),
        service.stats.latency.lock().unwrap().quantile_micros(0.99),
    );
    Ok(())
}

/// CLI `lkgp pool`: run several freeze-thaw coordinators concurrently,
/// each on its own simulated LCBench task, through one multi-task
/// [`ServicePool`] — the serving topology the north-star calls for. Prints
/// a per-shard report (regret, batching factor, warm hits, latency,
/// queue depth).
pub fn serve_pool(args: &Args) -> crate::Result<()> {
    let seed = args.get_u64("seed", 0);
    let tasks = args.get_usize("tasks", 3).max(1);
    let n_configs = args.get_usize("configs", 16);
    let budget = args.get_usize("budget", 200);
    let workers = args
        .get_usize("workers", crate::util::num_threads().min(tasks.max(1)))
        .max(1);
    let warm = args.get("warm").unwrap_or("on") != "off";
    let precond_arg = args.get("precond").unwrap_or("auto");
    let precond = crate::gp::PrecondCfg::parse(precond_arg).ok_or_else(|| {
        crate::LkgpError::Coordinator(format!(
            "bad --precond '{precond_arg}' (expected off, auto, or rank=R with R >= 1)"
        ))
    })?;
    let presets = crate::lcbench::Preset::all();

    let engines: Vec<Box<dyn crate::runtime::Engine>> = (0..tasks)
        .map(|_| {
            let mut eng = crate::runtime::RustEngine::default();
            eng.cfg.precond = precond;
            Box::new(eng) as Box<dyn crate::runtime::Engine>
        })
        .collect();
    let pool = ServicePool::spawn(
        engines,
        PoolCfg { workers, warm_start: warm, ..Default::default() },
    );
    println!("pool: {tasks} shards, {workers} workers, warm_start={warm}, precond={precond:?}");

    struct SimRunner {
        task: crate::lcbench::Task,
    }
    impl EpochRunner for SimRunner {
        fn run_epoch(&mut self, trial: TrialId, _config: &[f64], epoch: usize) -> f64 {
            self.task.curves[(trial.0, epoch.min(self.task.m() - 1))]
        }
    }

    let mut results: Vec<(usize, &'static str, RunReport, f64)> = Vec::new();
    std::thread::scope(|scope| -> crate::Result<()> {
        let mut joins = Vec::new();
        for t in 0..tasks {
            let handle = pool.handle(t);
            let preset = presets[t % presets.len()];
            joins.push(scope.spawn(move || -> crate::Result<(usize, &'static str, RunReport, f64)> {
                let mut rng = crate::rng::Pcg64::new(seed + t as u64);
                let task = crate::lcbench::Task::generate(preset, n_configs, &mut rng);
                let oracle = (0..task.n())
                    .map(|i| task.curves[(i, task.m() - 1)])
                    .fold(f64::NEG_INFINITY, f64::max);
                let cfg = SchedulerCfg {
                    epoch_budget: budget,
                    seed: seed + t as u64,
                    ..Default::default()
                };
                let mut sched = Scheduler::new(task.m(), cfg);
                let configs: Vec<Vec<f64>> =
                    (0..task.n()).map(|i| task.configs.row(i).to_vec()).collect();
                sched.add_candidates(&configs);
                let mut runner = SimRunner { task };
                let report = sched.run(&mut runner, &handle)?;
                Ok((t, preset.name(), report, oracle))
            }));
        }
        for j in joins {
            let out = j
                .join()
                .map_err(|_| crate::LkgpError::Coordinator("shard scheduler panicked".into()))??;
            results.push(out);
        }
        Ok(())
    })?;

    results.sort_by_key(|r| r.0);
    for (t, name, report, oracle) in &results {
        let stats = pool.stats(*t);
        println!(
            "shard {t} ({name}): best={:.4} regret={:.4} epochs={} rounds={} \
             batch_factor={:.2} warm_hits={} warm_cache={}h/{}m solves={} cg_iters={} \
             mvm_rows={} peak_queue={} p50={}us p99={}us",
            report.best_value,
            oracle - report.best_value,
            report.epochs_spent,
            report.rounds,
            report.batch_factor,
            stats.warm_hits.load(std::sync::atomic::Ordering::Relaxed),
            stats.warm_cache_hits.load(std::sync::atomic::Ordering::Relaxed),
            stats.warm_cache_misses.load(std::sync::atomic::Ordering::Relaxed),
            stats.engine_solves.load(std::sync::atomic::Ordering::Relaxed),
            stats.cg_iters.load(std::sync::atomic::Ordering::Relaxed),
            stats.cg_mvm_rows.load(std::sync::atomic::Ordering::Relaxed),
            stats.peak_queue_depth.load(std::sync::atomic::Ordering::Relaxed),
            stats.latency.lock().unwrap().quantile_micros(0.5),
            stats.latency.lock().unwrap().quantile_micros(0.99),
        );
    }
    Ok(())
}
