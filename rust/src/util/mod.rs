//! Small shared utilities: thread-count resolution, the persistent worker
//! team behind the data-parallel kernels, timing helpers, CSV writing, and
//! a tiny CLI argument parser (clap is not in the offline crate set).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::{Duration, Instant};

pub mod team;

/// Lock a mutex, recovering the inner state if a previous holder panicked
/// mid-update. This is the canonical shape for recover-policy lock classes
/// (docs/robustness.md): a recovered engine panic must not poison a warm
/// cache, latency histogram, or task cache for every later request —
/// worst case the state holds a stale entry, which every consumer already
/// tolerates. Fail-loud classes (queues, handshake slots) must NOT use
/// this; `lkgp lint` enforces the split per lock class.
pub(crate) fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Resolved worker-thread count; 0 = not yet resolved.
static THREADS: AtomicUsize = AtomicUsize::new(0);

/// Number of worker threads for the data-parallel kernels.
///
/// Resolution order: an explicit [`set_num_threads`] call (the `--threads`
/// CLI flag), then the `LKGP_THREADS` env var, then available parallelism
/// minus one (leave a core for the coordinator), min 1. The first
/// resolution wins and is cached for the process lifetime — the worker
/// team and the parallel kernels key off one stable number.
pub fn num_threads() -> usize {
    let n = THREADS.load(Ordering::Relaxed);
    if n != 0 {
        return n;
    }
    let resolved = if let Some(n) = std::env::var("LKGP_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
    {
        n.max(1)
    } else {
        std::thread::available_parallelism()
            .map(|n| n.get().saturating_sub(1).max(1))
            .unwrap_or(1)
    };
    // Racing first readers resolve to the same value; keep whichever
    // store landed so every caller observes one stable count.
    match THREADS.compare_exchange(0, resolved, Ordering::Relaxed, Ordering::Relaxed) {
        Ok(_) => resolved,
        Err(cur) => cur,
    }
}

/// Pin the worker-thread count before first use (the `lkgp pool
/// --threads N` flag). Returns false — and changes nothing — when the
/// count was already resolved (env read or a kernel already ran); callers
/// should warn rather than silently serve with a different count.
pub fn set_num_threads(n: usize) -> bool {
    THREADS
        .compare_exchange(0, n.max(1), Ordering::Relaxed, Ordering::Relaxed)
        .is_ok()
}

/// Time a closure, returning (result, elapsed).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Write rows as CSV (first row = header) under `results/`.
pub fn write_csv(path: &str, header: &[&str], rows: &[Vec<String>]) -> std::io::Result<()> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut out = String::new();
    out.push_str(&header.join(","));
    out.push('\n');
    for row in rows {
        out.push_str(&row.join(","));
        out.push('\n');
    }
    std::fs::write(path, out)
}

/// Minimal `--key value` / `--flag` argument parser.
///
/// Supports `--key=value` and `--key value`; everything else is positional.
#[derive(Debug, Default)]
pub struct Args {
    pub flags: std::collections::BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args` (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Parse from an iterator of tokens.
    pub fn parse(tokens: impl Iterator<Item = String>) -> Self {
        let mut args = Args::default();
        let toks: Vec<String> = tokens.collect();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if let Some(stripped) = t.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < toks.len() && !toks[i + 1].starts_with("--") {
                    args.flags.insert(stripped.to_string(), toks[i + 1].clone());
                    i += 1;
                } else {
                    args.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                args.positional.push(t.clone());
            }
            i += 1;
        }
        args
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

/// Format a Duration as milliseconds with 2 decimals.
pub fn fmt_ms(d: Duration) -> String {
    format!("{:.2}", d.as_secs_f64() * 1e3)
}

/// Format bytes human-readably.
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut x = b as f64;
    let mut u = 0;
    while x >= 1024.0 && u < UNITS.len() - 1 {
        x /= 1024.0;
        u += 1;
    }
    format!("{x:.1} {}", UNITS[u])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn args_parse_forms() {
        // Positionals come before flags (a bare `--flag token` would bind
        // the token as the flag's value — documented limitation).
        let a = Args::parse(
            ["pos1", "--n", "32", "--tol=0.01", "--verbose"]
                .iter()
                .map(|s| s.to_string()),
        );
        assert_eq!(a.get_usize("n", 0), 32);
        assert_eq!(a.get_f64("tol", 0.0), 0.01);
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["pos1".to_string()]);
    }

    #[test]
    fn args_flag_before_flag() {
        let a = Args::parse(["--a", "--b", "7"].iter().map(|s| s.to_string()));
        assert_eq!(a.get("a"), Some("true"));
        assert_eq!(a.get_usize("b", 0), 7);
    }

    #[test]
    fn bytes_format() {
        assert_eq!(fmt_bytes(512), "512.0 B");
        assert_eq!(fmt_bytes(2048), "2.0 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.0 MiB");
    }

    #[test]
    fn csv_writes(){
        let path = "/tmp/lkgp_util_test.csv";
        write_csv(path, &["a", "b"], &[vec!["1".into(), "2".into()]]).unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
    }

    #[test]
    fn threads_at_least_one() {
        assert!(num_threads() >= 1);
    }
}
