//! Persistent scoped worker team — the process-wide thread pool behind
//! every data-parallel kernel (panel matmul, batched operator/
//! preconditioner applies, refinement sweeps).
//!
//! The seed crate parallelized with per-call `std::thread::scope` spawns;
//! correct, but each batched CG iteration paid thread spawn + join on the
//! hot path. The team keeps `num_threads() - 1` workers parked on a
//! condvar and hands them *jobs*: a part count and a borrowed
//! `Fn(usize)` closure. `run` does not return until every part has
//! executed, which is what makes lending stack references to the workers
//! sound (the lifetime is erased through a raw pointer, but no worker can
//! touch it after `run` returns — see the safety notes on [`WorkerTeam::run`]).
//!
//! Determinism contract: the team only decides *where* a part executes,
//! never *what* a part computes. Callers split work into parts by a
//! logical thread count (pinned or from [`crate::util::num_threads`]) and
//! each part performs the same arithmetic regardless of which worker runs
//! it — so results are bit-identical for every team size, including the
//! degenerate single-lane team that runs everything inline. The parity
//! gates in `benches/simd.rs` and `tests/parallel_determinism.rs` hold
//! the crate to this.
//!
//! Re-entrancy: a part that calls back into `run` (nested parallel
//! region), or a second thread calling `run` while a job is in flight,
//! executes its parts inline on the calling thread instead of blocking.
//! This keeps pool workers live (no nested-join deadlock, no
//! oversubscription) at the cost of sequential execution for the loser —
//! results are unchanged either way.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

thread_local! {
    /// Set while this thread is executing team parts (worker loop or a
    /// leading `run`); nested `run` calls then execute inline.
    static IN_TEAM: Cell<bool> = const { Cell::new(false) };
}

/// Type-erased borrowed job closure. The raw pointer strips the caller's
/// lifetime so the job can sit in the shared slot; `run`'s completion
/// barrier guarantees no dereference outlives the borrow.
#[derive(Clone, Copy)]
struct ErasedFn(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (shared calls from many threads are fine)
// and the pointer is only dereferenced between job publication and the
// completion barrier inside `run`, while the caller's borrow is live.
unsafe impl Send for ErasedFn {}
unsafe impl Sync for ErasedFn {}

/// One published job: claim part indices from `next` until exhausted.
#[derive(Clone)]
struct Job {
    epoch: u64,
    parts: usize,
    next: Arc<AtomicUsize>,
    finished: Arc<AtomicUsize>,
    panicked: Arc<AtomicBool>,
    f: ErasedFn,
}

struct Shared {
    /// Latest published job (workers compare epochs to spot new work).
    slot: Mutex<Option<Job>>,
    work_cv: Condvar,
    /// Completion barrier: leaders wait here for straggler parts.
    done: Mutex<()>,
    done_cv: Condvar,
    shutdown: AtomicBool,
}

/// A persistent worker team; see the module docs.
pub struct WorkerTeam {
    shared: Arc<Shared>,
    /// Execution lanes: parked workers + the leading caller.
    lanes: usize,
    /// Held by the single active leader; `try_lock` losers run inline.
    submit: Mutex<()>,
    epoch: AtomicU64,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerTeam {
    /// Team with `lanes` execution lanes (spawns `lanes - 1` workers; the
    /// caller of [`run`](Self::run) is the final lane).
    pub fn new(lanes: usize) -> Self {
        let lanes = lanes.max(1);
        let shared = Arc::new(Shared {
            slot: Mutex::new(None),
            work_cv: Condvar::new(),
            done: Mutex::new(()),
            done_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let handles = (1..lanes)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("lkgp-team-{i}"))
                    .spawn(move || worker_loop(&sh))
                    .expect("spawn worker team thread")
            })
            .collect();
        WorkerTeam { shared, lanes, submit: Mutex::new(()), epoch: AtomicU64::new(0), handles }
    }

    /// The process-wide team, sized by [`crate::util::num_threads`] on
    /// first use (so `--threads` / `LKGP_THREADS` must be applied before
    /// any parallel kernel runs).
    pub fn global() -> &'static WorkerTeam {
        static TEAM: OnceLock<WorkerTeam> = OnceLock::new();
        TEAM.get_or_init(|| WorkerTeam::new(crate::util::num_threads()))
    }

    /// Execution lanes (including the leading caller).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Execute `f(0), f(1), ..., f(parts - 1)` exactly once each, possibly
    /// concurrently, returning only after all parts finished. Parts must
    /// write disjoint state (or none); the part index is the only
    /// coordination the team provides.
    ///
    /// Runs inline (sequentially, same results) when the team has one
    /// lane, the caller is itself a team part, or another leader holds the
    /// team. Panics in any part are re-raised on the caller once all parts
    /// have finished.
    pub fn run(&self, parts: usize, f: &(dyn Fn(usize) + Sync)) {
        if parts == 0 {
            return;
        }
        let inline = parts == 1 || self.lanes <= 1 || IN_TEAM.with(|c| c.get());
        if inline {
            for p in 0..parts {
                f(p);
            }
            return;
        }
        // A poisoned lock only means a previous job panicked after its
        // barrier; the team itself is intact, so reclaim it.
        let _leader = match self.submit.try_lock() {
            Ok(g) => g,
            Err(std::sync::TryLockError::Poisoned(p)) => p.into_inner(),
            Err(std::sync::TryLockError::WouldBlock) => {
                // Another leader is mid-job; do not queue behind it.
                for p in 0..parts {
                    f(p);
                }
                return;
            }
        };
        let job = Job {
            epoch: self.epoch.fetch_add(1, Ordering::Relaxed) + 1,
            parts,
            next: Arc::new(AtomicUsize::new(0)),
            finished: Arc::new(AtomicUsize::new(0)),
            panicked: Arc::new(AtomicBool::new(false)),
            // Lifetime erasure — sound because this function does not
            // return until `finished == parts` and late workers that
            // missed every part never dereference `f`.
            f: ErasedFn(f as *const (dyn Fn(usize) + Sync)),
        };
        {
            let mut slot = self.shared.slot.lock().unwrap();
            *slot = Some(job.clone());
            self.shared.work_cv.notify_all();
        }
        // Lead from the calling thread (IN_TEAM makes nested runs inline).
        IN_TEAM.with(|c| c.set(true));
        run_parts(&self.shared, &job);
        IN_TEAM.with(|c| c.set(false));
        // Completion barrier for parts claimed by workers. The timeout
        // guards the notify-before-wait race without a busy spin.
        let mut g = self.shared.done.lock().unwrap();
        while job.finished.load(Ordering::Acquire) < job.parts {
            let (ng, _) = self
                .shared
                .done_cv
                .wait_timeout(g, std::time::Duration::from_millis(1))
                .unwrap();
            g = ng;
        }
        drop(g);
        if job.panicked.load(Ordering::Relaxed) {
            panic!("worker team job panicked");
        }
    }
}

impl Drop for WorkerTeam {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Relaxed);
        {
            let _slot = self.shared.slot.lock().unwrap();
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Claim and execute parts of `job` until none remain.
fn run_parts(shared: &Shared, job: &Job) {
    loop {
        let p = job.next.fetch_add(1, Ordering::Relaxed);
        if p >= job.parts {
            return;
        }
        // SAFETY: a claimed part implies the leader is still inside `run`
        // (it cannot pass the barrier before this part reports finished),
        // so the borrow behind the erased pointer is live.
        let f = unsafe { &*job.f.0 };
        if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(p))).is_err() {
            job.panicked.store(true, Ordering::Relaxed);
        }
        let done = job.finished.fetch_add(1, Ordering::Release) + 1;
        if done == job.parts {
            let _g = shared.done.lock().unwrap();
            shared.done_cv.notify_all();
        }
    }
}

fn worker_loop(shared: &Shared) {
    IN_TEAM.with(|c| c.set(true));
    let mut seen = 0u64;
    loop {
        let job = {
            let mut slot = shared.slot.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::Relaxed) {
                    return;
                }
                match &*slot {
                    Some(j) if j.epoch != seen => break j.clone(),
                    _ => {}
                }
                slot = shared.work_cv.wait(slot).unwrap();
            }
        };
        seen = job.epoch;
        run_parts(shared, &job);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn runs_every_part_exactly_once() {
        let team = WorkerTeam::new(4);
        let hits: Vec<AtomicU32> = (0..37).map(|_| AtomicU32::new(0)).collect();
        team.run(hits.len(), &|p| {
            hits[p].fetch_add(1, Ordering::Relaxed);
        });
        for (p, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "part {p}");
        }
    }

    #[test]
    fn single_lane_runs_inline() {
        let team = WorkerTeam::new(1);
        let sum = AtomicUsize::new(0);
        team.run(10, &|p| {
            sum.fetch_add(p, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 45);
    }

    #[test]
    fn nested_run_executes_inline_without_deadlock() {
        let team = WorkerTeam::new(3);
        let total = AtomicUsize::new(0);
        team.run(3, &|_outer| {
            // Nested region: must run inline on this worker, not block on
            // the busy team.
            team.run(4, &|_inner| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 12);
    }

    #[test]
    fn reusable_across_jobs() {
        let team = WorkerTeam::new(2);
        for round in 1..=5usize {
            let sum = AtomicUsize::new(0);
            team.run(round, &|p| {
                sum.fetch_add(p + 1, Ordering::Relaxed);
            });
            assert_eq!(sum.load(Ordering::Relaxed), round * (round + 1) / 2);
        }
    }

    #[test]
    fn parallel_disjoint_writes_land() {
        let team = WorkerTeam::new(4);
        let mut out = vec![0.0f64; 1000];
        let chunk = 97;
        let parts = out.len().div_ceil(chunk);
        // Lend disjoint chunks through a shared pointer, as the matrix
        // kernels do.
        struct SendPtr(*mut f64);
        // SAFETY: the pointer is only dereferenced through disjoint
        // per-part slices below, and `out` outlives the `team.run` call.
        unsafe impl Send for SendPtr {}
        // SAFETY: same as above — shared access is to the pointer value
        // only; each part writes a non-overlapping range.
        unsafe impl Sync for SendPtr {}
        let base = SendPtr(out.as_mut_ptr());
        let n = out.len();
        team.run(parts, &|p| {
            let start = p * chunk;
            let len = chunk.min(n - start);
            // SAFETY: parts cover [0, n) in disjoint `chunk`-sized ranges
            // (`len` is clamped at the tail), so no two parts alias.
            let dst = unsafe { std::slice::from_raw_parts_mut(base.0.add(start), len) };
            for (i, v) in dst.iter_mut().enumerate() {
                *v = (start + i) as f64;
            }
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as f64);
        }
    }

    #[test]
    fn panicking_part_propagates_after_all_parts() {
        let team = WorkerTeam::new(3);
        let ran = AtomicUsize::new(0);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            team.run(8, &|p| {
                ran.fetch_add(1, Ordering::Relaxed);
                if p == 3 {
                    panic!("boom");
                }
            });
        }));
        assert!(res.is_err());
        assert_eq!(ran.load(Ordering::Relaxed), 8, "all parts still execute");
        // Team survives a panicked job.
        let sum = AtomicUsize::new(0);
        team.run(4, &|p| {
            sum.fetch_add(p, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 6);
    }
}
