//! # lkgp — Latent Kronecker Gaussian Processes for learning curve prediction
//!
//! Reproduction of *"Scaling Gaussian Processes for Learning Curve
//! Prediction via Latent Kronecker Structure"* (Lin, Ament, Balandat,
//! Bakshy; 2024) as a three-layer Rust + JAX + Pallas system:
//!
//! * **L1/L2 (build-time python)** — Pallas kernels + JAX LKGP graphs,
//!   AOT-lowered to HLO text artifacts (`python/compile/`, `artifacts/`).
//! * **runtime** — loads the artifacts via the PJRT C API (`xla` crate)
//!   and executes them from rust; no Python on the request path.
//! * **L3 (this crate)** — the AutoML coordinator the paper motivates:
//!   trial registry, learning-curve store, batched prediction service and
//!   freeze-thaw scheduling, plus a pure-rust mirror of the GP engine, the
//!   naive dense baseline, an LCBench-like workload simulator, baseline
//!   predictors, and the benchmark harness that regenerates the paper's
//!   figures.
//!
//! Entry points:
//! * [`gp::lkgp`] — the Latent Kronecker GP engine (train / predict /
//!   sample via iterative methods).
//! * [`runtime`] — artifact-backed engine with rust fallback.
//! * [`coordinator`] — the freeze-thaw AutoML service.
//! * [`analysis`] — the in-tree invariant linter behind `lkgp lint`
//!   (lock ordering, unsafe audit, panic/float discipline; see
//!   docs/static_analysis.md).
//! * `examples/` — quickstart, Figure-1 extrapolation, end-to-end AutoML
//!   loop, Figure-3 scaling driver.

pub mod analysis;
pub mod baselines;
pub mod bench_util;
pub mod coordinator;
pub mod error;
pub mod gp;
pub mod json;
pub mod lcbench;
pub mod linalg;
pub mod metrics;
pub mod rng;
pub mod runtime;
pub mod testutil;
pub mod util;

pub use error::{LkgpError, Result};
