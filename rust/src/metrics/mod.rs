//! Metrics: prediction quality (MSE / log-likelihood, paper Figure 4),
//! aggregation over seeds (mean ± standard error), and the allocation /
//! RSS tracking behind the Figure-3 memory comparison.

pub mod alloc;

/// Mean squared error between predictions and targets.
pub fn mse(pred: &[f64], target: &[f64]) -> f64 {
    assert_eq!(pred.len(), target.len());
    if pred.is_empty() {
        return 0.0;
    }
    pred.iter()
        .zip(target)
        .map(|(p, t)| (p - t) * (p - t))
        .sum::<f64>()
        / pred.len() as f64
}

/// Mean Gaussian log-likelihood of targets under (mean, variance) pairs.
pub fn gaussian_llh(pred: &[(f64, f64)], target: &[f64]) -> f64 {
    assert_eq!(pred.len(), target.len());
    if pred.is_empty() {
        return 0.0;
    }
    let ln2pi = (2.0 * std::f64::consts::PI).ln();
    pred.iter()
        .zip(target)
        .map(|((mu, var), t)| {
            let v = var.max(1e-12);
            -0.5 * (ln2pi + v.ln() + (t - mu) * (t - mu) / v)
        })
        .sum::<f64>()
        / pred.len() as f64
}

/// Aggregate over seeds: (mean, standard error).
pub fn mean_stderr(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    if xs.len() < 2 {
        return (mean, 0.0);
    }
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1.0);
    (mean, (var / n).sqrt())
}

/// Simple online latency histogram (microsecond buckets, powers of two).
#[derive(Clone, Debug, Default)]
pub struct LatencyHist {
    counts: Vec<u64>,
    total: u64,
}

impl LatencyHist {
    pub fn record(&mut self, micros: u64) {
        let bucket = (64 - micros.max(1).leading_zeros()) as usize;
        if self.counts.len() <= bucket {
            self.counts.resize(bucket + 1, 0);
        }
        self.counts[bucket] += 1;
        self.total += 1;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    /// Approximate quantile (upper edge of the bucket).
    pub fn quantile_micros(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let want = (q * self.total as f64).ceil() as u64;
        let mut seen = 0;
        for (b, c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= want {
                return 1u64 << b;
            }
        }
        1u64 << (self.counts.len().saturating_sub(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mse_basics() {
        assert_eq!(mse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert_eq!(mse(&[1.0, 3.0], &[0.0, 1.0]), 2.5);
    }

    #[test]
    fn llh_peaks_at_truth() {
        let t = [0.5];
        let good = gaussian_llh(&[(0.5, 0.01)], &t);
        let off = gaussian_llh(&[(0.9, 0.01)], &t);
        let vague = gaussian_llh(&[(0.5, 10.0)], &t);
        assert!(good > off);
        assert!(good > vague);
    }

    #[test]
    fn llh_closed_form() {
        // standard normal at 0: -0.5 ln(2 pi)
        let v = gaussian_llh(&[(0.0, 1.0)], &[0.0]);
        assert!((v + 0.5 * (2.0 * std::f64::consts::PI).ln()).abs() < 1e-12);
    }

    #[test]
    fn mean_stderr_basics() {
        let (m, se) = mean_stderr(&[1.0, 1.0, 1.0]);
        assert_eq!(m, 1.0);
        assert_eq!(se, 0.0);
        let (m2, se2) = mean_stderr(&[0.0, 2.0]);
        assert_eq!(m2, 1.0);
        assert!(se2 > 0.0);
    }

    #[test]
    fn latency_hist_quantiles() {
        let mut h = LatencyHist::default();
        for i in 1..=1000u64 {
            h.record(i);
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile_micros(0.5);
        let p99 = h.quantile_micros(0.99);
        assert!(p50 <= p99);
        assert!(p99 >= 512);
    }
}
