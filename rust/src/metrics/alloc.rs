//! Allocation accounting for the Figure-3 memory comparison.
//!
//! The paper reports CUDA memory for LKGP vs the naive Cholesky model; our
//! substrate is CPU, so we report two numbers instead: (a) exact bytes
//! *noted* by the numeric containers (every `Matrix`/solver workspace calls
//! [`note_alloc`]) and (b) process RSS from /proc. Both engines share the
//! same containers, so (a) is an apples-to-apples structural measure and
//! shows the O(n^2+m^2) vs O(n^2 m^2) gap directly.
//!
//! A scope-based tracker records the high-water mark:
//!
//! ```ignore
//! let tracker = AllocTracker::start();
//! run_training();
//! let peak_bytes = tracker.peak();
//! ```

use std::sync::atomic::{AtomicU64, Ordering};

static LIVE: AtomicU64 = AtomicU64::new(0);
static PEAK: AtomicU64 = AtomicU64::new(0);

/// Record a numeric buffer allocation of `bytes` (called by containers).
///
/// The model is append-only within a tracked scope: we track cumulative
/// *allocation pressure* rather than live bytes (Vec drops are not hooked),
/// which upper-bounds live usage and has the same asymptotic shape. Peak is
/// taken over scope resets, so per-phase numbers stay meaningful.
#[inline]
pub fn note_alloc(bytes: usize) {
    let now = LIVE.fetch_add(bytes as u64, Ordering::Relaxed) + bytes as u64;
    PEAK.fetch_max(now, Ordering::Relaxed);
}

/// Scope tracker for allocation pressure + RSS high-water mark.
pub struct AllocTracker {
    start_noted: u64,
    start_rss: u64,
}

impl AllocTracker {
    /// Begin a tracked scope (resets the scope-relative peak).
    pub fn start() -> Self {
        let live = LIVE.load(Ordering::Relaxed);
        PEAK.store(live, Ordering::Relaxed);
        AllocTracker {
            start_noted: live,
            start_rss: rss_bytes(),
        }
    }

    /// Peak noted-bytes allocated since `start` (exact, deterministic).
    pub fn peak_noted(&self) -> u64 {
        PEAK.load(Ordering::Relaxed).saturating_sub(self.start_noted)
    }

    /// RSS growth since `start` (noisy; includes the allocator/XLA runtime).
    pub fn rss_growth(&self) -> u64 {
        rss_bytes().saturating_sub(self.start_rss)
    }
}

/// Current resident set size in bytes (linux /proc/self/statm).
pub fn rss_bytes() -> u64 {
    let statm = match std::fs::read_to_string("/proc/self/statm") {
        Ok(s) => s,
        Err(_) => return 0,
    };
    let pages: u64 = statm
        .split_whitespace()
        .nth(1)
        .and_then(|f| f.parse().ok())
        .unwrap_or(0);
    pages * 4096
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracker_sees_matrix_allocations() {
        let t = AllocTracker::start();
        let m = crate::linalg::Matrix::zeros(100, 100);
        assert!(t.peak_noted() >= 100 * 100 * 8);
        drop(m);
    }

    #[test]
    fn rss_is_nonzero_on_linux() {
        assert!(rss_bytes() > 0);
    }

    #[test]
    fn nested_scopes_are_monotone() {
        let outer = AllocTracker::start();
        let _a = crate::linalg::Matrix::zeros(10, 10);
        let p1 = outer.peak_noted();
        let _b = crate::linalg::Matrix::zeros(20, 20);
        let p2 = outer.peak_noted();
        assert!(p2 >= p1 + 20 * 20 * 8);
    }
}
